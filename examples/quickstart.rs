//! Quickstart: generate a synthetic gigapixel slide, run the pyramidal
//! analysis against the reference (highest-resolution-only) execution and
//! print the speedup/retention trade-off.
//!
//! Uses the AOT-compiled PJRT classifier when `artifacts/` exists (run
//! `make artifacts`), the calibrated oracle otherwise.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use pyramidai::experiments::ctx::{make_analyzer, ModelKind};
use pyramidai::metrics::retention::retention_and_speedup;
use pyramidai::predcache::SlidePredictions;
use pyramidai::pyramid::driver::{run_pyramidal, run_reference};
use pyramidai::pyramid::tree::Thresholds;
use pyramidai::slide::pyramid::Slide;
use pyramidai::synth::slide_gen::{SlideKind, SlideSpec};

fn main() -> anyhow::Result<()> {
    // 1. A synthetic whole-slide image: 48×32 level-0 tiles of 64px over a
    //    3-level pyramid with scale factor 2 — the paper's structure.
    let slide = Slide::from_spec(SlideSpec::new(
        "quickstart",
        7,
        48,
        32,
        3,
        64,
        SlideKind::LargeTumor,
    ));

    // 2. An analysis block A(.): the AOT TinyInception through PJRT, or
    //    the oracle fallback.
    let (analyzer, name) = make_analyzer(ModelKind::Auto, 1)?;
    println!("analyzer: {name}");

    // 3. Decision blocks D(.): zoom in when P(tumor) ≥ threshold.
    let thresholds = Thresholds {
        zoom: vec![0.5, 0.35, 0.35],
    };

    // 4. Pyramidal vs reference execution.
    let pyramid = run_pyramidal(&slide, analyzer.as_ref(), &thresholds, 32);
    let reference = run_reference(&slide, analyzer.as_ref(), 32);
    let preds = SlidePredictions::collect(&slide, analyzer.as_ref(), 32);
    let m = retention_and_speedup(&preds, &pyramid);

    println!(
        "tiles analyzed: pyramid {} vs reference {}",
        pyramid.total_analyzed(),
        reference.total_analyzed()
    );
    println!("per level (0=highest): {:?}", pyramid.analyzed_per_level());
    println!("speedup   : {:.2}× fewer tiles", m.speedup());
    println!("retention : {:.1}% of true positive tiles", m.retention() * 100.0);
    Ok(())
}
