//! Threshold tuning walkthrough: collect predictions over a training slide
//! set, then run both §3.2 strategies and compare them on held-out slides.
//!
//! ```sh
//! cargo run --release --example threshold_tuning [-- --model oracle]
//! ```

use pyramidai::cli::Args;
use pyramidai::experiments::{Ctx, CtxConfig, ModelKind};
use pyramidai::harness::print_table;
use pyramidai::tuning::{empirical, metric_based};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let model = ModelKind::from_str(&args.str_or("model", "auto")).expect("--model");
    let ctx = Ctx::load(CtxConfig {
        model,
        ..Default::default()
    })?;
    println!(
        "tuned on {} train slides, evaluated on {} test slides ({})",
        ctx.train_cache.slides.len(),
        ctx.test_cache.slides.len(),
        ctx.analyzer_name
    );

    let mut rows = Vec::new();
    for target in [0.80, 0.90, 0.95] {
        let emp = empirical::select(&ctx.train_cache, 3, target)?;
        let (ret, spd, _) = metric_based::evaluate(&ctx.test_cache, &emp.thresholds)?;
        rows.push(vec![
            format!("empirical(target {target})"),
            format!("β={}", emp.beta),
            format!("{ret:.3}"),
            format!("{spd:.2}×"),
        ]);
        let met = metric_based::select(&ctx.train_cache, 3, target)?;
        let (ret, spd, _) = metric_based::evaluate(&ctx.test_cache, &met.thresholds)?;
        rows.push(vec![
            format!("metric-based(objective {target})"),
            format!("β={:?}/{:?}", met.betas[1], met.betas[2]),
            format!("{ret:.3}"),
            format!("{spd:.2}×"),
        ]);
    }
    print_table(
        "strategy comparison on the held-out test set",
        &["strategy", "chosen β", "test retention", "test speedup"],
        &rows,
    );
    Ok(())
}
