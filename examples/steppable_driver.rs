//! The sans-IO pyramidal driver, stepped by hand: pull frontier requests
//! from a `PyramidRun`, execute them on any `ExecutionBackend`, feed the
//! probabilities back — and get the exact tree the blocking driver would
//! have produced, plus things the blocking driver cannot do (abandon a
//! run at a frontier boundary and keep the partial tree).
//!
//! ```sh
//! cargo run --release --example steppable_driver
//! ```

use std::sync::Arc;

use pyramidai::model::oracle::OracleAnalyzer;
use pyramidai::model::Analyzer;
use pyramidai::predcache::SlidePredictions;
use pyramidai::pyramid::driver::run_pyramidal;
use pyramidai::pyramid::tree::Thresholds;
use pyramidai::pyramid::{drive, ExecutionBackend, PoolBackend, PyramidRun, ReplayBackend};
use pyramidai::service::pool::AnalyzerPool;
use pyramidai::sim::SimBackend;
use pyramidai::slide::pyramid::Slide;
use pyramidai::synth::slide_gen::{SlideKind, SlideSpec};

fn main() {
    let spec = SlideSpec::new("steppable", 7, 32, 16, 3, 64, SlideKind::LargeTumor);
    let analyzer: Arc<dyn Analyzer> = Arc::new(OracleAnalyzer::new(1));
    let slide = Arc::new(Slide::from_spec(spec));
    let thr = Thresholds {
        zoom: vec![0.5, 0.35, 0.35],
    };

    // Reference: the classic blocking driver (itself a PyramidRun shim).
    let reference = run_pyramidal(&slide, analyzer.as_ref(), &thr, 8);
    println!(
        "blocking driver: {:?} tiles per level",
        reference.analyzed_per_level()
    );

    // 1. Manual stepping on the in-process pool, 6 tiles per request.
    let pool = Arc::new(AnalyzerPool::new(Arc::clone(&analyzer), 2));
    let mut backend = PoolBackend::new(pool, Arc::clone(&slide), 4);
    let mut run = PyramidRun::new(
        slide.id(),
        slide.levels(),
        reference.initial.clone(),
        thr.clone(),
        6,
    );
    let mut requests = 0usize;
    while !run.is_complete() {
        while let Some(req) = run.next_request() {
            requests += 1;
            backend.dispatch(req);
        }
        if let Some(c) = backend.poll(true) {
            run.feed(c.id, c.probs).expect("pool results fit requests");
        }
    }
    let tree = run.finish();
    assert_eq!(tree.nodes, reference.nodes);
    println!("pool backend:    identical tree from {requests} chunked requests");

    // 2. The same run abandoned after its first completed level — the
    //    partial tree is consistent and holds exactly the finished levels.
    let mut run = PyramidRun::new(
        slide.id(),
        slide.levels(),
        reference.initial.clone(),
        thr.clone(),
        0,
    );
    let req = run.next_request().expect("lowest level");
    let probs = analyzer.analyze(&slide, req.level, &req.tiles);
    run.feed(req.id, probs).unwrap();
    let partial = run.finish();
    partial.check_consistency().unwrap();
    println!(
        "abandoned run:   partial tree holds {} of {} tiles",
        partial.total_analyzed(),
        reference.total_analyzed()
    );

    // 3. Post-mortem replay and the simulator's virtual workers drive the
    //    very same state machine.
    let preds = SlidePredictions::collect(&slide, analyzer.as_ref(), 16);
    let mut replay = ReplayBackend::new(&preds);
    let mut run = PyramidRun::new(
        slide.id(),
        slide.levels(),
        reference.initial.clone(),
        thr.clone(),
        0,
    );
    drive(&mut run, &mut replay).unwrap();
    assert_eq!(run.finish().nodes, reference.nodes);
    println!("replay backend:  identical tree from the prediction cache");

    let mut sim = SimBackend::new(&reference, 4);
    let mut run = PyramidRun::new(
        slide.id(),
        slide.levels(),
        reference.initial.clone(),
        thr,
        4,
    );
    drive(&mut run, &mut sim).unwrap();
    assert_eq!(run.finish().nodes, reference.nodes);
    println!(
        "sim backend:     identical tree; virtual worker loads {:?} (makespan {})",
        sim.per_worker(),
        sim.makespan()
    );
}
