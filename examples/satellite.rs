//! Generality demo (paper §6): "though illustrated on a gigapixel
//! biomedical use case, the approach is generalizable to any gigapixel
//! images, such as satellite or spatial images."
//!
//! Same pyramid, same algorithm, different domain: a satellite-like
//! scene set where sparse built-up structures are the targets of
//! interest, detected by a ground-truth-driven analysis block.
//! Everything downstream — decision blocks, threshold tuning,
//! retention/speedup, the distributed simulator — is reused unchanged.
//!
//! ```sh
//! cargo run --release --example satellite
//! ```

use pyramidai::metrics::retention::retention_and_speedup;
use pyramidai::model::oracle::OracleAnalyzer;
use pyramidai::predcache::PredCache;
use pyramidai::pyramid::driver::run_pyramidal;
use pyramidai::sim::{simulate, Distribution, Policy};
use pyramidai::slide::pyramid::Slide;
use pyramidai::synth::slide_gen::{gen_slide_set, DatasetParams};
use pyramidai::tuning::empirical;

fn main() -> anyhow::Result<()> {
    // A "scene set": the generator's kinds map onto sparse/dense target
    // layouts (LargeTumor ↔ a city block, SmallScattered ↔ isolated
    // installations, Negative ↔ empty countryside).
    let params = DatasetParams {
        tiles_x: 64,
        tiles_y: 32,
        levels: 3,
        tile_px: 64,
    };
    let scenes: Vec<Slide> = gen_slide_set("scene", 9, 77, &params)
        .into_iter()
        .map(Slide::from_spec)
        .collect();
    // Analysis block: ground-truth-driven detector (the oracle reads the
    // same analytic fields regardless of palette — the algorithm never
    // looks at domain semantics, only at per-tile probabilities).
    let analyzer = OracleAnalyzer::new(3);

    // Tune on the first 6 scenes, deploy on the rest.
    let train: Vec<Slide> = scenes[..6]
        .iter()
        .map(|s| Slide::from_spec(s.spec.clone()))
        .collect();
    let cache = PredCache::collect_set(&train, &analyzer, 32);
    let sel = empirical::select(&cache, 3, 0.9)?;
    println!(
        "tuned on {} scenes: β={} thresholds {:?}",
        train.len(),
        sel.beta,
        sel.thresholds.zoom
    );

    for scene in &scenes[6..] {
        let tree = run_pyramidal(scene, &analyzer, &sel.thresholds, 32);
        let preds = pyramidai::predcache::SlidePredictions::collect(scene, &analyzer, 32);
        let m = retention_and_speedup(&preds, &tree);
        let sim = simulate(&tree, 8, Distribution::RoundRobin, Policy::WorkStealing, 1);
        println!(
            "{} ({}): {} of {} tiles analyzed → {:.2}× speedup, {:.0}% target retention; \
             8 stealing workers → busiest analyzes {} tiles",
            scene.id(),
            scene.spec.kind.as_str(),
            tree.total_analyzed(),
            preds.reference_count(),
            m.speedup(),
            m.retention() * 100.0,
            sim.max_tiles(),
        );
    }
    println!("\nsame pyramid, same tuning, same scheduler — different domain (paper §6)");
    Ok(())
}
