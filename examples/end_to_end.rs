//! End-to-end driver — proves all layers compose on a real workload:
//!
//! 1. L1/L2: the Pallas-kernel TinyInception, AOT-compiled at build time,
//!    loaded through PJRT (no Python anywhere in this binary).
//! 2. Synthetic gigapixel slide sets (train + test) with ground truth.
//! 3. Real inference over every lineage tile → prediction caches.
//! 4. Both §3.2 threshold-selection strategies on the train set.
//! 5. Pyramidal vs reference on the test set: retention + speedup.
//! 6. The distributed TCP cluster (12 workers, work stealing) on a slide.
//! 7. §4.6 whole-slide classification.
//!
//! The run is recorded in EXPERIMENTS.md. Requires `make artifacts`.
//!
//! ```sh
//! cargo run --release --example end_to_end
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use pyramidai::cluster::{run_cluster, ClusterConfig};
use pyramidai::experiments::ctx::{artifacts_dir, make_analyzer, ModelKind};
use pyramidai::harness::print_table;
use pyramidai::predcache::PredCache;
use pyramidai::sim::Distribution;
use pyramidai::slide::pyramid::Slide;
use pyramidai::synth::slide_gen::{gen_slide_set, DatasetParams};
use pyramidai::tuning::{empirical, metric_based};
use pyramidai::wsi::{tree_features, BaggingClassifier, BaggingParams, Sample};

fn main() -> anyhow::Result<()> {
    let t0 = Instant::now();
    anyhow::ensure!(
        artifacts_dir().join("meta.json").exists(),
        "artifacts/ missing — run `make artifacts` first"
    );
    let (analyzer, name) = make_analyzer(ModelKind::Pjrt, 1)?;
    println!("[1/7] analyzer: {name} (AOT TinyInception via PJRT, Pallas kernels inside)");

    let params = DatasetParams::default();
    let train: Vec<Slide> = gen_slide_set("e2e_train", 8, 31, &params)
        .into_iter()
        .map(Slide::from_spec)
        .collect();
    let test: Vec<Slide> = gen_slide_set("e2e_test", 6, 32, &params)
        .into_iter()
        .map(Slide::from_spec)
        .collect();
    println!(
        "[2/7] slide sets: {} train / {} test ({}×{} L0 tiles, 3 levels)",
        train.len(),
        test.len(),
        params.tiles_x,
        params.tiles_y
    );

    let t = Instant::now();
    let train_cache = PredCache::collect_set(&train, analyzer.as_ref(), 32);
    let test_cache = PredCache::collect_set(&test, analyzer.as_ref(), 32);
    let n_preds: usize = train_cache
        .slides
        .iter()
        .chain(&test_cache.slides)
        .map(|s| s.len())
        .sum();
    println!(
        "[3/7] real inference over {} tiles in {:.1}s ({:.2} ms/tile incl. rendering)",
        n_preds,
        t.elapsed().as_secs_f64(),
        t.elapsed().as_secs_f64() * 1e3 / n_preds as f64
    );

    let emp = empirical::select(&train_cache, 3, 0.90)?;
    let met = metric_based::select(&train_cache, 3, 0.90)?;
    println!(
        "[4/7] tuned: empirical β={} → thresholds {:?}; metric-based βs {:?}",
        emp.beta, emp.thresholds.zoom, met.betas
    );

    let (e_ret, e_spd, _) = metric_based::evaluate(&test_cache, &emp.thresholds)?;
    let (m_ret, m_spd, _) = metric_based::evaluate(&test_cache, &met.thresholds)?;
    print_table(
        "[5/7] test-set results (paper: 90% retention at 2.65× / 92% at 2.34×)",
        &["strategy", "retention", "speedup"],
        &[
            vec!["empirical".into(), format!("{e_ret:.3}"), format!("{e_spd:.2}×")],
            vec!["metric-based".into(), format!("{m_ret:.3}"), format!("{m_spd:.2}×")],
        ],
    );

    // Distributed run with the real PJRT analyzer on 12 workers.
    let spec = &test[0].spec;
    let res = run_cluster(
        spec,
        &emp.thresholds,
        Arc::clone(&analyzer),
        &ClusterConfig {
            workers: 12,
            distribution: Distribution::RoundRobin,
            steal: true,
            batch: 8,
            seed: 99,
        },
    )?;
    println!(
        "[6/7] 12-worker TCP cluster on {}: {} tiles in {:.2}s, busiest worker {} tiles, {} steals",
        spec.id,
        res.tree.total_analyzed(),
        res.wall.as_secs_f64(),
        res.max_tiles(),
        res.steals
    );

    // WSI classification.
    let label = |cache: &PredCache, i: usize| {
        cache.slides[i]
            .iter_level(0)
            .any(|(_, p)| p.tumor && p.prob >= 0.5)
    };
    let mk = |cache: &PredCache| -> Vec<Sample> {
        (0..cache.slides.len())
            .map(|i| Sample {
                x: tree_features(&cache.slides[i].replay(&emp.thresholds)),
                y: label(cache, i),
            })
            .collect()
    };
    let clf = BaggingClassifier::fit(&mk(&train_cache), &BaggingParams::default());
    let acc = clf.accuracy(&mk(&test_cache));
    println!("[7/7] WSI classification accuracy: {acc:.2} (paper: 0.84)");

    println!(
        "\nend-to-end OK in {} — all three layers composed: rust coordinator → PJRT → XLA(HLO from JAX+Pallas)",
        pyramidai::util::stats::fmt_duration(t0.elapsed())
    );
    let _ = Duration::ZERO;
    Ok(())
}
