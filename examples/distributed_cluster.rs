//! Distributed analysis on the decentralized TCP cluster: spawn workers
//! (threads with real localhost sockets, standing in for the paper's 12
//! mainstream computers), compare work-stealing on/off across worker
//! counts on one slide.
//!
//! ```sh
//! cargo run --release --example distributed_cluster [-- --per-tile-ms 10]
//! ```

use std::sync::Arc;
use std::time::Duration;

use pyramidai::cli::Args;
use pyramidai::cluster::{run_cluster, ClusterConfig};
use pyramidai::harness::print_table;
use pyramidai::model::oracle::OracleAnalyzer;
use pyramidai::model::{Analyzer, DelayAnalyzer};
use pyramidai::pyramid::tree::Thresholds;
use pyramidai::sim::Distribution;
use pyramidai::synth::slide_gen::{SlideKind, SlideSpec};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let per_tile = Duration::from_millis(args.u64_or("per-tile-ms", 10)?);
    let spec = SlideSpec::new("cluster_demo", 11, 48, 32, 3, 64, SlideKind::LargeTumor);
    let thresholds = Thresholds {
        zoom: vec![0.5, 0.35, 0.35],
    };
    // Per-tile delay emulates the paper's 0.33 s analysis block so worker
    // threads overlap like separate machines (see DESIGN.md S3).
    let analyzer: Arc<dyn Analyzer> =
        Arc::new(DelayAnalyzer::new(OracleAnalyzer::new(1), per_tile));

    let mut rows = Vec::new();
    for workers in [1usize, 2, 4, 8, 12] {
        for steal in [false, true] {
            let res = run_cluster(
                &spec,
                &thresholds,
                Arc::clone(&analyzer),
                &ClusterConfig {
                    workers,
                    distribution: Distribution::RoundRobin,
                    steal,
                    batch: 1,
                    seed: 5,
                },
            )?;
            rows.push(vec![
                workers.to_string(),
                if steal { "work-stealing" } else { "round-robin only" }.into(),
                format!("{:.2}s", res.wall.as_secs_f64()),
                res.max_tiles().to_string(),
                res.steals.to_string(),
            ]);
        }
    }
    print_table(
        "cluster execution (one slide)",
        &["workers", "policy", "wall", "max tiles/worker", "steals"],
        &rows,
    );
    Ok(())
}
