//! Multi-slide analysis service demo: a burst of slide jobs from two
//! tenants with mixed priorities, scheduled over a shared worker pool,
//! with a determinism check against the standalone single-slide driver.
//!
//! ```sh
//! cargo run --release --example multi_slide_service [-- --policy priority --workers 4]
//! ```

use std::sync::Arc;
use std::time::Duration;

use pyramidai::cli::Args;
use pyramidai::model::oracle::OracleAnalyzer;
use pyramidai::model::{Analyzer, DelayAnalyzer};
use pyramidai::pyramid::driver::run_pyramidal;
use pyramidai::pyramid::tree::Thresholds;
use pyramidai::service::{
    metrics, AnalysisService, JobSource, JobSpec, PolicySpec, Priority, ServiceConfig,
};
use pyramidai::slide::pyramid::Slide;
use pyramidai::synth::slide_gen::{SlideKind, SlideSpec};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let workers = args.usize_or("workers", 4)?;
    let policy_s = args.str_or("policy", "wfs");
    let policy = PolicySpec::parse(&policy_s)
        .ok_or_else(|| anyhow::anyhow!("unknown --policy {policy_s:?}"))?;
    let per_tile = Duration::from_millis(args.u64_or("per-tile-ms", 1)?);
    args.finish()?;

    let analyzer: Arc<dyn Analyzer> =
        Arc::new(DelayAnalyzer::new(OracleAnalyzer::new(1), per_tile));
    let thr = Thresholds {
        zoom: vec![0.5, 0.35, 0.35],
    };

    let kinds = [
        SlideKind::LargeTumor,
        SlideKind::SmallScattered,
        SlideKind::Negative,
    ];
    let specs: Vec<SlideSpec> = (0..6)
        .map(|i| {
            SlideSpec::new(
                format!("demo_{i}"),
                40 + i as u64,
                32,
                16,
                3,
                64,
                kinds[i % 3],
            )
        })
        .collect();

    println!("policy={} workers={workers}", policy.as_str());
    let svc = AnalysisService::start(
        Arc::clone(&analyzer),
        ServiceConfig {
            workers,
            queue_capacity: specs.len(),
            max_in_flight: 2,
            batch: 8,
            policy,
            ..ServiceConfig::default()
        },
    );
    let ids: Vec<_> = specs
        .iter()
        .enumerate()
        .map(|(i, sp)| {
            let job = JobSpec::new(JobSource::Spec(sp.clone()), thr.clone())
                .with_priority([Priority::Low, Priority::High][i % 2])
                .with_tenant(["pathology_lab", "research"][i / 3].to_string());
            svc.submit(job).expect("queue sized for the burst")
        })
        .collect();
    let report = svc.shutdown();
    metrics::print_report(&report.results, &report.metrics);

    // Determinism: every service tree equals the standalone driver's.
    for (i, (sp, id)) in specs.iter().zip(&ids).enumerate() {
        let slide = Slide::from_spec(sp.clone());
        let solo = run_pyramidal(&slide, &analyzer, &thr, 8);
        let served = report.job(*id).and_then(|r| r.tree.as_ref()).expect("tree");
        assert_eq!(served.nodes, solo.nodes, "job {i} diverged");
    }
    println!("\nall {} service trees match the standalone driver ✓", ids.len());
    Ok(())
}
