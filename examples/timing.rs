use std::time::Instant;
use pyramidai::slide::pyramid::Slide;
use pyramidai::slide::tile::TileId;
use pyramidai::synth::slide_gen::{SlideKind, SlideSpec};
fn main() {
    let slide = Slide::from_spec(SlideSpec::new("t", 7, 48, 32, 3, 64, SlideKind::LargeTumor));
    // render 50 tiles at level 0
    let t0 = Instant::now();
    let mut acc = 0.0f32;
    for i in 0..50 { let px = slide.tile_pixels(TileId::new(0, i % 48, i / 48)); acc += px[0]; }
    println!("render: {:.2} ms/tile (acc {acc})", t0.elapsed().as_secs_f64()*1e3/50.0);
    // PJRT load + infer
    let t0 = Instant::now();
    let reg = pyramidai::runtime::Registry::load_dir(std::path::Path::new("artifacts")).unwrap();
    println!("registry load+compile: {:.1} s", t0.elapsed().as_secs_f64());
    let tiles: Vec<Vec<f32>> = (0..32).map(|i| slide.tile_pixels(TileId::new(0, i, 0))).collect();
    let refs: Vec<&[f32]> = tiles.iter().map(|t| t.as_slice()).collect();
    let t0 = Instant::now();
    for _ in 0..5 { let _ = reg.infer(0, &refs).unwrap(); }
    println!("pjrt b32: {:.2} ms/tile", t0.elapsed().as_secs_f64()*1e3/(5.0*32.0));
    let one: Vec<&[f32]> = refs[..1].to_vec();
    let t0 = Instant::now();
    for _ in 0..20 { let _ = reg.infer(0, &one).unwrap(); }
    println!("pjrt b1: {:.2} ms/tile", t0.elapsed().as_secs_f64()*1e3/20.0);
    let eight: Vec<&[f32]> = refs[..8].to_vec();
    let t0 = Instant::now();
    for _ in 0..10 { let _ = reg.infer(0, &eight).unwrap(); }
    println!("pjrt b8: {:.2} ms/tile", t0.elapsed().as_secs_f64()*1e3/80.0);
    for level in [1usize, 2] {
        let t0 = Instant::now();
        for _ in 0..10 { let _ = reg.infer(level, &eight).unwrap(); }
        println!("pjrt L{level} b8: {:.2} ms/tile", t0.elapsed().as_secs_f64()*1e3/80.0);
    }
    // otsu bg removal
    let t0 = Instant::now();
    let m = pyramidai::preprocess::otsu::background_removal(&slide, 0.02);
    println!("bg removal: {:.1} ms ({} tissue tiles)", t0.elapsed().as_secs_f64()*1e3, m.tissue_tiles.len());
}
