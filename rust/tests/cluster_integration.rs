//! Cluster integration over real localhost TCP sockets, using the oracle
//! analyzer (no artifacts needed): conservation, consistency with the
//! single-worker execution, and work-stealing behavior.

use std::sync::Arc;

use pyramidai::cluster::{run_cluster, ClusterConfig};
use pyramidai::model::oracle::OracleAnalyzer;
use pyramidai::model::{Analyzer, DelayAnalyzer};
use pyramidai::pyramid::driver::run_pyramidal;
use pyramidai::pyramid::tree::Thresholds;
use pyramidai::sim::Distribution;
use pyramidai::slide::pyramid::Slide;
use pyramidai::synth::slide_gen::{SlideKind, SlideSpec};

fn spec(seed: u64, kind: SlideKind) -> SlideSpec {
    SlideSpec::new(format!("cl_{seed}"), seed, 32, 16, 3, 64, kind)
}

fn thresholds() -> Thresholds {
    Thresholds {
        zoom: vec![0.5, 0.35, 0.35],
    }
}

#[test]
fn cluster_matches_single_worker_execution() {
    let sp = spec(301, SlideKind::LargeTumor);
    let analyzer: Arc<dyn Analyzer> = Arc::new(OracleAnalyzer::new(1));
    let thr = thresholds();

    // Ground truth: single-worker in-process driver.
    let slide = Slide::from_spec(sp.clone());
    let solo = run_pyramidal(&slide, analyzer.as_ref(), &thr, 8);

    for workers in [1usize, 4] {
        let res = run_cluster(
            &sp,
            &thr,
            Arc::clone(&analyzer),
            &ClusterConfig {
                workers,
                distribution: Distribution::RoundRobin,
                steal: true,
                batch: 8,
                seed: 99,
            },
        )
        .expect("cluster run");
        // The oracle is deterministic, so the merged cluster tree must
        // analyze exactly the same tiles as the solo run.
        assert_eq!(
            res.tree.total_analyzed(),
            solo.total_analyzed(),
            "workers={workers}"
        );
        let mut a: Vec<_> = res.tree.level0().iter().map(|n| n.tile).collect();
        let mut b: Vec<_> = solo.level0().iter().map(|n| n.tile).collect();
        a.sort();
        b.sort();
        assert_eq!(a, b, "level-0 tile sets differ (workers={workers})");
        // Per-worker counts sum to the total.
        assert_eq!(res.per_worker.iter().sum::<usize>(), solo.total_analyzed());
    }
}

#[test]
fn work_stealing_balances_block_distribution() {
    // Block distribution is maximally imbalanced on a slide whose tumor
    // sits in one region; stealing must spread the load. A per-tile delay
    // emulates the paper's 0.33 s analysis block so workers genuinely
    // overlap on this single-core testbed and steals can happen.
    let sp = spec(302, SlideKind::LargeTumor);
    let analyzer: Arc<dyn Analyzer> = Arc::new(DelayAnalyzer::new(
        OracleAnalyzer::new(1),
        std::time::Duration::from_millis(2),
    ));
    let thr = thresholds();
    // The balance comparison is inherently timing-dependent (a steal only
    // happens when workers genuinely overlap), so judge it over repeated
    // runs instead of a single coin-flip: stealing must not worsen the
    // busiest worker in a majority of reps. Conservation and the
    // steals-happened signal stay hard assertions on every rep.
    let mut wins = 0usize;
    let mut total_steals = 0usize;
    const REPS: usize = 3;
    for rep in 0..REPS {
        let base = ClusterConfig {
            workers: 4,
            distribution: Distribution::Block,
            steal: false,
            batch: 4,
            seed: 7 + rep as u64,
        };
        let no_steal = run_cluster(&sp, &thr, Arc::clone(&analyzer), &base).unwrap();
        let steal = run_cluster(
            &sp,
            &thr,
            Arc::clone(&analyzer),
            &ClusterConfig {
                steal: true,
                ..base.clone()
            },
        )
        .unwrap();
        total_steals += steal.steals;
        if steal.max_tiles() <= no_steal.max_tiles() {
            wins += 1;
        }
        // Totals conserved in both modes, every rep.
        assert_eq!(
            steal.tree.total_analyzed(),
            no_steal.tree.total_analyzed(),
            "rep {rep}: stealing changed the analyzed set"
        );
    }
    assert!(
        total_steals > 0,
        "expected steals under block distribution in {REPS} reps"
    );
    assert!(
        wins * 2 > REPS,
        "stealing worsened the busiest worker in {}/{REPS} reps",
        REPS - wins
    );
}

#[test]
fn steal_accounting_is_consistent() {
    // `steals` / `steal_fails` must reconcile with what physically
    // happened: no stealing → both zero; stealing on → every successful
    // steal moved exactly one task, so steals is bounded by the total
    // tile count, and totals/per-worker loads are conserved either way.
    let sp = spec(304, SlideKind::LargeTumor);
    let analyzer: Arc<dyn Analyzer> = Arc::new(DelayAnalyzer::new(
        OracleAnalyzer::new(1),
        std::time::Duration::from_millis(1),
    ));
    let thr = thresholds();
    let base = ClusterConfig {
        workers: 4,
        distribution: Distribution::Block,
        steal: false,
        batch: 4,
        seed: 11,
    };

    let off = run_cluster(&sp, &thr, Arc::clone(&analyzer), &base).unwrap();
    assert_eq!(off.steals, 0, "steal disabled but steals counted");
    assert_eq!(off.steal_fails, 0, "steal disabled but failures counted");

    let on = run_cluster(
        &sp,
        &thr,
        Arc::clone(&analyzer),
        &ClusterConfig {
            steal: true,
            ..base.clone()
        },
    )
    .unwrap();
    let total = on.tree.total_analyzed();
    // A task can in principle be stolen more than once (thief re-victimized
    // before analyzing it), so bound with slack rather than exactly.
    assert!(
        on.steals <= total * 2,
        "{} steals for {} tasks — accounting runaway",
        on.steals,
        total
    );
    // Every worker that ran out of victims recorded at least one failed
    // attempt per pruned victim; the counter must be finite and sane.
    assert!(on.steal_fails >= on.per_worker.iter().filter(|&&n| n == 0).count());
    // Conservation under both policies.
    assert_eq!(on.per_worker.iter().sum::<usize>(), total);
    assert_eq!(off.per_worker.iter().sum::<usize>(), off.tree.total_analyzed());
    assert_eq!(total, off.tree.total_analyzed());
    on.tree.check_consistency().unwrap();
}

#[test]
fn twelve_workers_negative_slide() {
    // The paper's §5.4 validates on 12 machines incl. a negative image;
    // exercise the same worker count end to end.
    let sp = spec(303, SlideKind::Negative);
    let analyzer: Arc<dyn Analyzer> = Arc::new(OracleAnalyzer::new(1));
    let res = run_cluster(
        &sp,
        &thresholds(),
        analyzer,
        &ClusterConfig {
            workers: 12,
            distribution: Distribution::RoundRobin,
            steal: true,
            batch: 8,
            seed: 3,
        },
    )
    .unwrap();
    assert_eq!(res.per_worker.len(), 12);
    assert!(res.tree.total_analyzed() > 0);
    // Negative slide: hardly any zoom-ins, so level 0 nearly empty.
    let l0 = res.tree.level0().len();
    let l2 = res.tree.nodes[2].len();
    assert!(
        l0 < l2 * 4,
        "negative slide exploded: {l0} level-0 tiles from {l2} initial"
    );
}
