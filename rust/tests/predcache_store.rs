//! Sharded prediction store integration: streamed replay equivalence
//! under eviction pressure, corrupt-shard error paths, JSON→binary
//! migration, the service's `JobSource::Sharded` path, and the golden
//! shard fixture that pins the on-disk format.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use pyramidai::model::oracle::OracleAnalyzer;
use pyramidai::predcache::shard::{decode_slide, encode_slide};
use pyramidai::predcache::store::{import_json, save_sharded, MANIFEST_FILE};
use pyramidai::predcache::{PredCache, PredSource, ShardedPredStore, StoreError};
use pyramidai::pyramid::tree::Thresholds;
use pyramidai::service::{AnalysisService, JobSource, JobSpec, JobState, ServiceConfig};
use pyramidai::slide::pyramid::Slide;
use pyramidai::synth::slide_gen::{gen_slide_set, DatasetParams};
use pyramidai::tuning::empirical;

fn params() -> DatasetParams {
    DatasetParams {
        tiles_x: 16,
        tiles_y: 8,
        levels: 3,
        tile_px: 64,
    }
}

fn collect(n: usize, seed: u64) -> PredCache {
    let slides: Vec<Slide> = gen_slide_set("pcs", n, seed, &params())
        .into_iter()
        .map(Slide::from_spec)
        .collect();
    PredCache::collect_set(&slides, &OracleAnalyzer::new(1), 16)
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "pyramidai_itest_{tag}_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn streamed_replay_is_byte_identical_under_tiny_eviction_budget() {
    let cache = collect(5, 41);
    let dir = tmp_dir("equiv");
    save_sharded(&cache, &dir, 2).unwrap();
    // Budget 0 MiB: at most one shard resident — every slide switch
    // evicts, every replay of another slide streams back off disk.
    let store = Arc::new(ShardedPredStore::open_with_budget(&dir, Some(0)).unwrap());
    for thr in [0.2, 0.4, 0.7] {
        let t = Thresholds::uniform(3, thr);
        for i in 0..cache.slides.len() {
            let in_memory = cache.slides[i].replay(&t);
            let streamed = store.replay(i, &t).unwrap();
            assert_eq!(
                in_memory.nodes, streamed.nodes,
                "slide {i} thr {thr}: streamed tree diverged"
            );
            assert_eq!(in_memory.initial, streamed.initial);
        }
    }
    let st = store.stats();
    assert!(
        st.evictions > 0,
        "budget never bit — the test did not exercise streaming ({st:?})"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn service_sharded_jobs_match_pinned_cached_jobs() {
    let cache = collect(4, 43);
    let dir = tmp_dir("svc");
    save_sharded(&cache, &dir, 1).unwrap();
    let store = Arc::new(ShardedPredStore::open_with_budget(&dir, Some(0)).unwrap());
    let thr = Thresholds::uniform(3, 0.35);
    let expect: Vec<_> = cache.slides.iter().map(|s| s.replay(&thr)).collect();

    let svc = AnalysisService::start(
        Arc::new(OracleAnalyzer::new(1)),
        ServiceConfig {
            workers: 1,
            max_in_flight: 2,
            ..ServiceConfig::default()
        },
    );
    let ids: Vec<_> = (0..cache.slides.len())
        .map(|i| {
            svc.submit(JobSpec::new(
                JobSource::Sharded {
                    store: Arc::clone(&store),
                    slide: i,
                },
                thr.clone(),
            ))
            .unwrap()
        })
        .collect();
    let report = svc.shutdown();
    for (i, id) in ids.iter().enumerate() {
        let r = report.job(*id).unwrap();
        assert_eq!(r.state, JobState::Completed, "job {i}");
        assert_eq!(
            r.tree.as_ref().unwrap().nodes,
            expect[i].nodes,
            "sharded job {i} diverged from in-memory replay"
        );
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn corrupt_shard_fails_the_job_not_the_service() {
    let cache = collect(2, 47);
    let dir = tmp_dir("svccorrupt");
    save_sharded(&cache, &dir, 1).unwrap();
    // Corrupt slide 1's shard (flip a payload byte, size unchanged).
    let shard1 = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .find(|p| {
            p.file_name()
                .map(|n| n.to_string_lossy().starts_with("0001_"))
                .unwrap_or(false)
        })
        .unwrap();
    let mut bytes = std::fs::read(&shard1).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x20;
    std::fs::write(&shard1, &bytes).unwrap();

    let store = Arc::new(ShardedPredStore::open(&dir).unwrap());
    let thr = Thresholds::uniform(3, 0.35);
    let svc = AnalysisService::start(Arc::new(OracleAnalyzer::new(1)), ServiceConfig::default());
    let ok = svc
        .submit(JobSpec::new(
            JobSource::Sharded {
                store: Arc::clone(&store),
                slide: 0,
            },
            thr.clone(),
        ))
        .unwrap();
    let bad = svc
        .submit(JobSpec::new(
            JobSource::Sharded {
                store: Arc::clone(&store),
                slide: 1,
            },
            thr.clone(),
        ))
        .unwrap();
    let report = svc.shutdown();
    assert_eq!(report.job(ok).unwrap().state, JobState::Completed);
    assert!(
        matches!(report.job(bad).unwrap().state, JobState::Failed(_)),
        "corrupt shard must fail its job, got {:?}",
        report.job(bad).unwrap().state
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn corrupt_paths_error_never_panic() {
    let cache = collect(1, 53);
    let dir = tmp_dir("errors");
    save_sharded(&cache, &dir, 1).unwrap();
    let shard = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .find(|p| p.extension().map(|e| e == "shard").unwrap_or(false))
        .unwrap();
    let good = std::fs::read(&shard).unwrap();

    // Truncation at many lengths.
    for cut in [0usize, 5, 11, good.len() / 3, good.len() - 1] {
        assert!(decode_slide(&good[..cut]).is_err(), "cut={cut}");
    }
    // Bit flip.
    let mut flipped = good.clone();
    let mid = flipped.len() / 2;
    flipped[mid] ^= 1;
    assert!(decode_slide(&flipped).is_err());
    // Version skew (re-sealed checksum so the version check fires).
    let mut vskew = good.clone();
    vskew[4..8].copy_from_slice(&7u32.to_le_bytes());
    let n = vskew.len();
    let crc = {
        // Reuse the library's own encoder to find the correct CRC: a
        // freshly encoded shard ends with crc32(payload).
        // (Recompute via decode error message is overkill — flip the
        // version back and forth instead.)
        pyramidai::util::png::crc32(&vskew[..n - 4])
    };
    vskew[n - 4..].copy_from_slice(&crc.to_le_bytes());
    assert!(matches!(
        decode_slide(&vskew),
        Err(pyramidai::predcache::ShardError::Version(7))
    ));

    // Store-level: truncated file is a size mismatch, missing manifest a
    // manifest error.
    std::fs::write(&shard, &good[..good.len() / 2]).unwrap();
    let store = ShardedPredStore::open(&dir).unwrap();
    assert!(matches!(
        store.slide(0),
        Err(StoreError::SizeMismatch { .. })
    ));
    std::fs::remove_file(dir.join(MANIFEST_FILE)).unwrap();
    assert!(matches!(
        ShardedPredStore::open(&dir),
        Err(StoreError::Manifest(_))
    ));
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Property held across injected torn-write and ENOSPC schedules: a
/// failed re-save NEVER damages the existing store (atomic writes tear
/// the tmp, not the destination), and a *silent* write-side bit flip is
/// caught by the shard CRC and repaired away by `fsck`.
#[test]
fn faulted_saves_never_tear_the_store_and_fsck_repairs_silent_corruption() {
    use pyramidai::fault::{self, FaultKind, FaultPlan, FaultRule};
    use pyramidai::predcache::store::fsck;

    let cache = collect(3, 61);
    let dir = tmp_dir("faultsave");
    // Scope every rule to this test's unique directory name: the
    // injector is global, and sibling tests in this binary write shards
    // of their own concurrently.
    let tag = dir.file_name().unwrap().to_string_lossy().into_owned();
    save_sharded(&cache, &dir, 1).unwrap();
    ShardedPredStore::open(&dir).unwrap().validate().unwrap();
    let thr = Thresholds::uniform(3, 0.4);
    let golden: Vec<_> = cache.slides.iter().map(|s| s.replay(&thr)).collect();

    for (seed, kind) in [
        (1u64, FaultKind::DiskTornWrite),
        (2, FaultKind::DiskTornWrite),
        (3, FaultKind::DiskEnospc { after_bytes: 64 }),
        (4, FaultKind::DiskEnospc { after_bytes: 1024 }),
    ] {
        let mut rule = FaultRule::always(kind);
        rule.path = Some(tag.clone());
        fault::install(FaultPlan::new(seed).rule(rule));
        let err = save_sharded(&cache, &dir, 1).unwrap_err();
        fault::clear();
        let msg = err.to_string();
        assert!(
            msg.contains("torn") || msg.contains("ENOSPC"),
            "seed {seed}: unexpected error {msg}"
        );
        // The pre-existing store is byte-for-byte unharmed.
        let store = ShardedPredStore::open(&dir).unwrap();
        store.validate().unwrap();
        for (i, g) in golden.iter().enumerate() {
            assert_eq!(
                store.replay(i, &thr).unwrap().nodes,
                g.nodes,
                "slide {i} diverged after faulted save (seed {seed})"
            );
        }
        let rep = fsck(&dir, true).unwrap();
        assert!(rep.clean(), "residue after faulted save: {rep:?}");
    }

    // Silent corruption: a bit flip in slide 0's re-saved shard persists
    // without an error (the save "succeeds")…
    let mut rule = FaultRule::always(FaultKind::DiskBitflip);
    rule.path = Some(format!("{tag}/0000_"));
    fault::install(FaultPlan::new(9).rule(rule));
    let saved = save_sharded(&cache, &dir, 1);
    fault::clear();
    saved.unwrap();
    // …the CRC catches it on load…
    let store = ShardedPredStore::open(&dir).unwrap();
    assert!(store.validate().is_err(), "bit flip went undetected");
    drop(store);
    // …and fsck quarantines exactly that shard, leaving a degraded but
    // fully valid store whose surviving replays still match.
    let rep = fsck(&dir, false).unwrap();
    assert_eq!(rep.bad.len(), 1, "bad: {:?}", rep.bad);
    assert_eq!(rep.quarantined, 1);
    let store = ShardedPredStore::open(&dir).unwrap();
    assert_eq!(store.len(), 2);
    store.validate().unwrap();
    for i in 0..store.len() {
        let id = store.slide_id(i).unwrap().to_string();
        let j = cache
            .slides
            .iter()
            .position(|s| s.spec.id == id)
            .expect("surviving slide is one of the originals");
        assert_eq!(store.replay(i, &thr).unwrap().nodes, golden[j].nodes);
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn json_migration_preserves_replay_and_tuning_pairs() {
    let cache = collect(3, 59);
    let dir = tmp_dir("migrate");
    let json = dir.join("legacy.json");
    cache.save(&json).unwrap();
    let shards = dir.join("shards");
    assert_eq!(import_json(&json, &shards, 2).unwrap(), 3);

    let from_json = PredCache::load(&json).unwrap();
    let store = Arc::new(ShardedPredStore::open_with_budget(&shards, Some(0)).unwrap());
    // Tuning pairs: identical per level, pooled across slides.
    for level in 0..3 {
        assert_eq!(
            PredSource::pooled_pairs(&from_json, level).unwrap(),
            store.pooled_pairs(level).unwrap(),
            "level {level}"
        );
    }
    // Replay: identical trees at several thresholds.
    for thr in [0.25, 0.5] {
        let t = Thresholds::uniform(3, thr);
        for i in 0..3 {
            assert_eq!(
                from_json.slides[i].replay(&t).nodes,
                store.replay(i, &t).unwrap().nodes,
                "slide {i} thr {thr}"
            );
        }
    }
    // A full tuning selection over the streamed store matches in-memory.
    let a = empirical::select(&from_json, 3, 0.9).unwrap();
    let b = empirical::select(store.as_ref(), 3, 0.9).unwrap();
    assert_eq!(a.beta, b.beta);
    assert_eq!(a.thresholds.zoom, b.thresholds.zoom);
    std::fs::remove_dir_all(&dir).unwrap();
}

/// The checked-in golden shard pins the binary format: if an encoder or
/// decoder change alters the layout without a version bump, this fails
/// the build.
#[test]
fn golden_shard_fixture_decodes_and_reencodes_byte_identically() {
    let fixture = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust")
        .join("tests")
        .join("fixtures")
        .join("golden");
    let bytes = std::fs::read(fixture.join("0000_golden.shard")).unwrap();
    let preds = decode_slide(&bytes).unwrap();

    // Pinned contents (mirrors the generator that produced the fixture).
    assert_eq!(preds.spec.id, "golden");
    assert_eq!(preds.spec.seed, 7);
    assert_eq!(preds.spec.tiles_x, 4);
    assert_eq!(preds.spec.tiles_y, 4);
    assert_eq!(preds.spec.levels, 2);
    assert_eq!(preds.initial.len(), 4);
    assert_eq!(preds.len(), 4 + 16);
    use pyramidai::slide::tile::TileId;
    for i in 0..4 {
        let t = TileId::new(1, i % 2, i / 2);
        let p = preds.get(t).unwrap();
        assert!((p.prob - (i as f32 + 1.0) / 10.0).abs() < 1e-6, "{t}");
        assert_eq!(p.tumor, i % 2 == 0, "{t}");
    }
    for i in 0..16 {
        let t = TileId::new(0, i % 4, i / 4);
        let p = preds.get(t).unwrap();
        assert!((p.prob - i as f32 / 32.0).abs() < 1e-6, "{t}");
        assert_eq!(p.tumor, i % 3 == 0, "{t}");
    }

    // Re-encoding must reproduce the checked-in bytes exactly.
    assert_eq!(
        encode_slide(&preds),
        bytes,
        "shard encoder no longer matches the golden fixture — bump SHARD_VERSION"
    );

    // The fixture directory is a complete store: manifest opens, replay
    // runs.
    let store = Arc::new(ShardedPredStore::open(&fixture).unwrap());
    assert_eq!(store.len(), 1);
    assert_eq!(store.slide_id(0), Some("golden"));
    let tree = store.replay(0, &Thresholds::uniform(2, 0.25)).unwrap();
    tree.check_consistency().unwrap();
    assert_eq!(tree.nodes[1].len(), 4, "all four initial tiles analyzed");
}
