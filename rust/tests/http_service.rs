//! HTTP front-end end-to-end integration: a real TCP client submits jobs
//! across two tenants against both execution backends, streams results
//! progressively as per-level deltas, and reassembles them into trees
//! byte-identical to standalone `run_pyramidal` — plus mid-run
//! cancellation (partial tree), queue-full backpressure (`429` +
//! `Retry-After`), bearer auth, tenant isolation and keep-alive.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use pyramidai::cluster::ClusterExecConfig;
use pyramidai::model::oracle::OracleAnalyzer;
use pyramidai::model::{Analyzer, DelayAnalyzer};
use pyramidai::pyramid::driver::run_pyramidal;
use pyramidai::pyramid::tree::{ExecNode, ExecTree, Thresholds};
use pyramidai::service::http::{HttpConfig, HttpFrontend, TokenTable};
use pyramidai::service::{
    AnalysisService, ExecMode, PolicySpec, ServiceConfig, ServiceReport,
};
use pyramidai::slide::pyramid::Slide;
use pyramidai::slide::tile::TileId;
use pyramidai::synth::slide_gen::{SlideKind, SlideSpec};
use pyramidai::util::json::Json;

fn oracle() -> Arc<dyn Analyzer> {
    Arc::new(OracleAnalyzer::new(1))
}

fn slow_oracle(per_tile_ms: u64) -> Arc<dyn Analyzer> {
    Arc::new(DelayAnalyzer::new(
        OracleAnalyzer::new(1),
        Duration::from_millis(per_tile_ms),
    ))
}

/// Service + front-end with two tenants: `tok-a` → `lab_a`, `tok-b` → `lab_b`.
fn start(
    analyzer: Arc<dyn Analyzer>,
    exec: ExecMode,
    queue_capacity: usize,
    max_in_flight: usize,
) -> (Arc<AnalysisService>, HttpFrontend) {
    let svc = Arc::new(AnalysisService::start(
        analyzer,
        ServiceConfig {
            workers: 4,
            queue_capacity,
            max_in_flight,
            batch: 8,
            policy: PolicySpec::fifo(),
            exec,
            ..ServiceConfig::default()
        },
    ));
    let tokens = TokenTable::parse("tok-a lab_a\ntok-b lab_b\n").unwrap();
    let fe = HttpFrontend::start(Arc::clone(&svc), HttpConfig::new("127.0.0.1:0", tokens))
        .expect("bind ephemeral port");
    (svc, fe)
}

/// Stop the front-end (joining every handler) and drain the service.
fn finish(svc: Arc<AnalysisService>, fe: HttpFrontend) -> ServiceReport {
    fe.stop();
    Arc::try_unwrap(svc)
        .ok()
        .expect("front-end joined every handler")
        .shutdown()
}

// ---- minimal raw HTTP/1.1 client -------------------------------------------

struct Response {
    status: u16,
    headers: Vec<(String, String)>,
    body: Vec<u8>,
}

impl Response {
    fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }

    fn json(&self) -> Json {
        Json::parse(std::str::from_utf8(&self.body).unwrap()).unwrap()
    }

    /// Parse an NDJSON body into one `Json` per line.
    fn lines(&self) -> Vec<Json> {
        std::str::from_utf8(&self.body)
            .unwrap()
            .lines()
            .map(|l| Json::parse(l).unwrap())
            .collect()
    }
}

fn decode_chunked(mut b: &[u8]) -> Vec<u8> {
    let mut out = Vec::new();
    loop {
        let pos = b.windows(2).position(|w| w == b"\r\n").expect("chunk size line");
        let size = usize::from_str_radix(std::str::from_utf8(&b[..pos]).unwrap(), 16).unwrap();
        b = &b[pos + 2..];
        if size == 0 {
            break;
        }
        out.extend_from_slice(&b[..size]);
        b = &b[size + 2..];
    }
    out
}

fn parse_response(buf: &[u8]) -> Response {
    let head_end = buf
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .unwrap_or_else(|| panic!("no response head in {:?}", String::from_utf8_lossy(buf)));
    let head = std::str::from_utf8(&buf[..head_end]).unwrap();
    let mut it = head.split("\r\n");
    let status: u16 = it
        .next()
        .unwrap()
        .split(' ')
        .nth(1)
        .expect("status code")
        .parse()
        .unwrap();
    let headers: Vec<(String, String)> = it
        .map(|l| {
            let (k, v) = l.split_once(':').expect("header line");
            (k.trim().to_ascii_lowercase(), v.trim().to_string())
        })
        .collect();
    let raw_body = &buf[head_end + 4..];
    let chunked = headers
        .iter()
        .any(|(k, v)| k == "transfer-encoding" && v == "chunked");
    let body = if chunked {
        decode_chunked(raw_body)
    } else {
        raw_body.to_vec()
    };
    Response {
        status,
        headers,
        body,
    }
}

/// One `Connection: close` request/response round trip.
fn http(addr: SocketAddr, raw: &str) -> Response {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(raw.as_bytes()).unwrap();
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).unwrap();
    parse_response(&buf)
}

fn get(addr: SocketAddr, path: &str, token: &str) -> Response {
    http(
        addr,
        &format!(
            "GET {path} HTTP/1.1\r\nHost: t\r\nAuthorization: Bearer {token}\r\nConnection: close\r\n\r\n"
        ),
    )
}

fn delete(addr: SocketAddr, path: &str, token: &str) -> Response {
    http(
        addr,
        &format!(
            "DELETE {path} HTTP/1.1\r\nHost: t\r\nAuthorization: Bearer {token}\r\nConnection: close\r\n\r\n"
        ),
    )
}

fn post(addr: SocketAddr, path: &str, token: &str, body: &str) -> Response {
    http(
        addr,
        &format!(
            "POST {path} HTTP/1.1\r\nHost: t\r\nAuthorization: Bearer {token}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        ),
    )
}

// ---- wire ↔ tree helpers ----------------------------------------------------

fn submit_body(id: &str, seed: u64, tiles_x: usize, tiles_y: usize, kind: &str) -> String {
    Json::obj()
        .set(
            "slide",
            Json::obj()
                .set("id", id)
                .set("seed", seed)
                .set("tiles_x", tiles_x)
                .set("tiles_y", tiles_y)
                .set("levels", 3usize)
                .set("tile_px", 64usize)
                .set("kind", kind),
        )
        .set(
            "thresholds",
            Json::Arr(vec![0.5.into(), 0.35.into(), 0.35.into()]),
        )
        .to_string()
}

fn thresholds() -> Thresholds {
    Thresholds {
        zoom: vec![0.5, 0.35, 0.35],
    }
}

fn tile(v: &Json) -> TileId {
    let a = v.as_arr().unwrap();
    TileId::new(
        a[0].as_usize().unwrap(),
        a[1].as_usize().unwrap(),
        a[2].as_usize().unwrap(),
    )
}

/// Rebuild an [`ExecTree`] from a result stream's lines; returns the
/// tree and the terminal line.
fn reassemble(mut lines: Vec<Json>) -> (ExecTree, Json) {
    assert!(lines.len() >= 2, "header + terminal at minimum: {lines:?}");
    let terminal = lines.pop().unwrap();
    assert!(
        terminal.get("done").unwrap().as_bool().unwrap(),
        "stream must end with the terminal line: {terminal:?}"
    );
    let header = lines.remove(0);
    let levels = header.get("levels").unwrap().as_usize().unwrap();
    let slide = header.get("slide").unwrap().as_str().unwrap();
    let mut tree = ExecTree::new(slide, levels);
    for t in header.get("initial").unwrap().as_arr().unwrap() {
        tree.initial.push(tile(t));
    }
    for line in &lines {
        let level = line.get("level").unwrap().as_usize().unwrap();
        for n in line.get("nodes").unwrap().as_arr().unwrap() {
            let a = n.as_arr().unwrap();
            tree.nodes[level].push(ExecNode {
                tile: tile(n),
                prob: a[3].as_f64().unwrap() as f32,
                zoom: a[4].as_bool().unwrap(),
            });
        }
    }
    (tree, terminal)
}

// ---- tests ------------------------------------------------------------------

#[test]
fn streamed_deltas_reassemble_byte_identical_trees_on_both_backends() {
    let cases: [(u64, &str); 4] = [
        (900, "large_tumor"),
        (901, "small_scattered"),
        (902, "negative"),
        (903, "large_tumor"),
    ];
    let thr = thresholds();
    let solo: Vec<ExecTree> = cases
        .iter()
        .map(|&(seed, kind)| {
            let sp = SlideSpec::new(
                format!("http_{seed}"),
                seed,
                16,
                8,
                3,
                64,
                SlideKind::from_str(kind).unwrap(),
            );
            run_pyramidal(&Slide::from_spec(sp), oracle().as_ref(), &thr, 8)
        })
        .collect();

    let backends = [
        ExecMode::Pool,
        ExecMode::Cluster(ClusterExecConfig {
            workers: 2,
            steal: true,
            seed: 5,
            ..ClusterExecConfig::default()
        }),
    ];
    for exec in backends {
        let label = format!("{exec:?}");
        let (svc, fe) = start(oracle(), exec, 16, 2);
        let addr = fe.addr();
        let tokens = ["tok-a", "tok-b"];
        let mut ids = Vec::new();
        for (i, &(seed, kind)) in cases.iter().enumerate() {
            let body = submit_body(&format!("http_{seed}"), seed, 16, 8, kind);
            let r = post(addr, "/v1/jobs", tokens[i % 2], &body);
            assert_eq!(r.status, 201, "{label}: {}", String::from_utf8_lossy(&r.body));
            let v = r.json();
            assert_eq!(
                r.header("location"),
                Some(format!("/v1/jobs/{}", v.get("job").unwrap().as_u64().unwrap()).as_str())
            );
            assert_eq!(v.get("tenant").unwrap().as_str().unwrap(), ["lab_a", "lab_b"][i % 2]);
            ids.push(v.get("job").unwrap().as_u64().unwrap());
        }
        for (i, id) in ids.iter().enumerate() {
            let r = get(addr, &format!("/v1/jobs/{id}/result"), tokens[i % 2]);
            assert_eq!(r.status, 200, "{label} job {i}");
            let (tree, terminal) = reassemble(r.lines());
            assert_eq!(
                terminal.get("state").unwrap().as_str().unwrap(),
                "completed",
                "{label} job {i}"
            );
            tree.check_consistency().unwrap();
            assert_eq!(
                tree.to_json().to_string(),
                solo[i].to_json().to_string(),
                "{label}: job {i} stream did not reassemble the standalone tree"
            );
            assert_eq!(
                terminal.get("tiles").unwrap().as_usize().unwrap(),
                solo[i].total_analyzed()
            );
        }
        // Status after completion reports the terminal record.
        let r = get(addr, &format!("/v1/jobs/{}", ids[0]), "tok-a");
        assert_eq!(r.status, 200);
        let v = r.json();
        assert_eq!(v.get("phase").unwrap().as_str().unwrap(), "done");
        assert_eq!(v.get("state").unwrap().as_str().unwrap(), "completed");
        // Tenant isolation: the other tenant's token sees a 404, not a 403.
        assert_eq!(get(addr, &format!("/v1/jobs/{}", ids[0]), "tok-b").status, 404);
        let report = finish(svc, fe);
        assert_eq!(report.metrics.completed, cases.len(), "{label}");
    }
}

#[test]
fn cancel_mid_run_streams_a_partial_tree() {
    let sp = SlideSpec::new("http_cancel", 910, 48, 32, 3, 64, SlideKind::LargeTumor);
    let thr = thresholds();
    let solo = run_pyramidal(&Slide::from_spec(sp), oracle().as_ref(), &thr, 8);

    let (svc, fe) = start(slow_oracle(2), ExecMode::Pool, 4, 1);
    let addr = fe.addr();
    let body = submit_body("http_cancel", 910, 48, 32, "large_tumor");
    let r = post(addr, "/v1/jobs", "tok-a", &body);
    assert_eq!(r.status, 201, "{}", String::from_utf8_lossy(&r.body));
    let id = r.json().get("job").unwrap().as_u64().unwrap();

    // Wait until the scheduler picks the job up, give the first frontier
    // a head start, then cancel over the wire.
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let v = get(addr, &format!("/v1/jobs/{id}"), "tok-a").json();
        if v.get("phase").unwrap().as_str().unwrap() == "running" {
            break;
        }
        assert!(Instant::now() < deadline, "job never started: {v:?}");
        std::thread::sleep(Duration::from_millis(2));
    }
    std::thread::sleep(Duration::from_millis(30));
    let r = delete(addr, &format!("/v1/jobs/{id}"), "tok-a");
    assert_eq!(r.status, 202);
    assert!(r.json().get("cancelled").unwrap().as_bool().unwrap());

    let r = get(addr, &format!("/v1/jobs/{id}/result"), "tok-a");
    assert_eq!(r.status, 200);
    let (tree, terminal) = reassemble(r.lines());
    assert_eq!(terminal.get("state").unwrap().as_str().unwrap(), "cancelled");
    tree.check_consistency().unwrap();
    assert!(
        tree.total_analyzed() < solo.total_analyzed(),
        "cancellation must cut the run short ({} vs {})",
        tree.total_analyzed(),
        solo.total_analyzed()
    );
    // Frontier-boundary semantics survive the wire: every streamed level
    // is byte-identical to the standalone run's, or absent entirely.
    for (level, nodes) in tree.nodes.iter().enumerate() {
        assert!(
            nodes.is_empty() || *nodes == solo.nodes[level],
            "level {level} streamed partially"
        );
    }
    let report = finish(svc, fe);
    assert_eq!(report.metrics.cancelled, 1);
}

#[test]
fn full_queue_answers_429_with_retry_after() {
    let (svc, fe) = start(slow_oracle(3), ExecMode::Pool, 1, 1);
    let addr = fe.addr();
    let r = post(addr, "/v1/jobs", "tok-a", &submit_body("q0", 920, 16, 8, "large_tumor"));
    assert_eq!(r.status, 201);
    // Wait until the first job leaves the queue for its run slot, so the
    // single queue seat is genuinely free for the second submission.
    while svc.queued() > 0 {
        std::thread::sleep(Duration::from_micros(200));
    }
    let r = post(addr, "/v1/jobs", "tok-a", &submit_body("q1", 921, 16, 8, "large_tumor"));
    assert_eq!(r.status, 201);
    // Queue full (q1 parked in it, q0 running): backpressure surfaces.
    let r = post(addr, "/v1/jobs", "tok-b", &submit_body("q2", 922, 16, 8, "large_tumor"));
    assert_eq!(r.status, 429, "{}", String::from_utf8_lossy(&r.body));
    assert_eq!(r.header("retry-after"), Some("1"));
    let v = r.json();
    assert_eq!(v.get("capacity").unwrap().as_usize().unwrap(), 1);
    assert_eq!(v.get("retry_after").unwrap().as_u64().unwrap(), 1);
    let report = finish(svc, fe);
    assert_eq!(report.metrics.completed, 2, "only the admitted jobs ran");
}

#[test]
fn auth_routing_and_metrics_edges() {
    let (svc, fe) = start(oracle(), ExecMode::Pool, 4, 2);
    let addr = fe.addr();

    // Liveness probe needs no credentials.
    let r = http(addr, "GET /healthz HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n");
    assert_eq!(r.status, 200);
    assert!(r.json().get("ok").unwrap().as_bool().unwrap());

    // Every /v1 route requires a bearer token.
    let r = http(addr, "GET /v1/metrics HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n");
    assert_eq!(r.status, 401);
    assert_eq!(r.header("www-authenticate"), Some("Bearer"));
    assert_eq!(get(addr, "/v1/metrics", "wrong-token").status, 401);

    // Wrong method → 405 with Allow; unknown routes and ids → 404.
    let r = http(
        addr,
        "PUT /v1/jobs HTTP/1.1\r\nHost: t\r\nAuthorization: Bearer tok-a\r\nConnection: close\r\n\r\n",
    );
    assert_eq!(r.status, 405);
    assert_eq!(r.header("allow"), Some("POST"));
    assert_eq!(get(addr, "/v1/jobs/999", "tok-a").status, 404);
    assert_eq!(get(addr, "/v1/nope", "tok-a").status, 404);
    assert_eq!(get(addr, "/v1/jobs/12x", "tok-a").status, 404);

    // The metrics snapshot carries the http.* series.
    let r = get(addr, "/v1/metrics", "tok-a");
    assert_eq!(r.status, 200);
    let counters = r.json().get("counters").unwrap().clone();
    assert!(counters.get("http.requests").unwrap().as_u64().unwrap() >= 1);
    assert!(counters.get("http.auth_failures").unwrap().as_u64().unwrap() >= 2);
    finish(svc, fe);
}

/// Open file-descriptor count for this process (Linux); `None` where
/// `/proc` is absent so the fd-leak assertion degrades to a no-op.
fn count_fds() -> Option<usize> {
    std::fs::read_dir("/proc/self/fd").ok().map(|d| d.count())
}

#[test]
fn keep_alive_reuse_under_load_leaks_no_fds_and_keeps_histograms_sane() {
    const CONNS: usize = 12;
    const REQS: usize = 16;
    let (svc, fe) = start(oracle(), ExecMode::Pool, 4, 2);
    let addr = fe.addr();
    let before = get(addr, "/v1/metrics", "tok-a").json();
    let requests_before = before
        .get("counters")
        .unwrap()
        .get("http.requests")
        .unwrap()
        .as_u64()
        .unwrap();
    let fds_before = count_fds();

    for _ in 0..CONNS {
        let mut s = TcpStream::connect(addr).unwrap();
        let mut pipelined = String::new();
        for i in 0..REQS {
            let close = if i + 1 == REQS {
                "Connection: close\r\n"
            } else {
                ""
            };
            pipelined.push_str(&format!("GET /healthz HTTP/1.1\r\nHost: t\r\n{close}\r\n"));
        }
        s.write_all(pipelined.as_bytes()).unwrap();
        let mut buf = Vec::new();
        s.read_to_end(&mut buf).unwrap();
        let text = String::from_utf8_lossy(&buf);
        assert_eq!(
            text.matches("HTTP/1.1 200 OK").count(),
            REQS,
            "every pipelined request answered on one connection: {text}"
        );
    }

    // Handler sockets are released as each connection ends, not at
    // stop(); allow the last handler threads a moment to unwind. The
    // slack absorbs unrelated fds from concurrently running tests while
    // still catching a per-connection (12) or per-request (192) leak.
    if let Some(base) = fds_before {
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let now = count_fds().unwrap();
            if now <= base + 8 {
                break;
            }
            assert!(
                Instant::now() < deadline,
                "fd count never settled: {base} before load, {now} after"
            );
            std::thread::sleep(Duration::from_millis(10));
        }
    }

    let after = get(addr, "/v1/metrics", "tok-a").json();
    let requests_after = after
        .get("counters")
        .unwrap()
        .get("http.requests")
        .unwrap()
        .as_u64()
        .unwrap();
    assert!(
        requests_after >= requests_before + (CONNS * REQS) as u64,
        "request counter must cover the pipelined load: {requests_before} -> {requests_after}"
    );
    let lat = after
        .get("histograms")
        .unwrap()
        .get("http.request_latency_us")
        .unwrap()
        .clone();
    let count = lat.get("count").unwrap().as_u64().unwrap();
    assert!(count >= (CONNS * REQS) as u64, "one latency sample per request: {count}");
    let p50 = lat.get("p50").unwrap().as_f64().unwrap();
    let p99 = lat.get("p99").unwrap().as_f64().unwrap();
    assert!(p50 <= p99, "histogram percentiles stay ordered under load: p50 {p50} p99 {p99}");
    finish(svc, fe);
}

#[test]
fn result_stream_resumes_from_a_coarser_level() {
    let (svc, fe) = start(oracle(), ExecMode::Pool, 4, 2);
    let addr = fe.addr();
    let r = post(addr, "/v1/jobs", "tok-a", &submit_body("resume", 930, 16, 8, "large_tumor"));
    assert_eq!(r.status, 201, "{}", String::from_utf8_lossy(&r.body));
    let id = r.json().get("job").unwrap().as_u64().unwrap();

    let full = get(addr, &format!("/v1/jobs/{id}/result"), "tok-a");
    assert_eq!(full.status, 200);
    let full_lines = full.lines();
    let (tree, _) = reassemble(full_lines.clone());
    tree.check_consistency().unwrap();
    assert!(
        full_lines
            .iter()
            .any(|l| l.opt("level").is_some_and(|lv| lv.as_usize().unwrap() == 2)),
        "slide must zoom to level 2 for the resume test to bite"
    );

    // Levels publish coarsest-first, so a client that disconnected after
    // receiving the level-2 deltas resumes with `?from_level=1`: header,
    // the level<=1 deltas and the terminal line — byte-identical to the
    // corresponding suffix of the full stream.
    let resumed = get(addr, &format!("/v1/jobs/{id}/result?from_level=1"), "tok-a");
    assert_eq!(resumed.status, 200);
    let got: Vec<String> = resumed.lines().iter().map(|l| l.to_string()).collect();
    let want: Vec<String> = full_lines
        .iter()
        .filter(|l| l.opt("level").map_or(true, |lv| lv.as_usize().unwrap() <= 1))
        .map(|l| l.to_string())
        .collect();
    assert_eq!(got, want, "resume replays exactly the fine-level suffix");
    assert!(got.len() < full_lines.len(), "the level-2 delta was skipped");

    // Garbage resume points are rejected before the stream starts.
    let r = get(addr, &format!("/v1/jobs/{id}/result?from_level=zebra"), "tok-a");
    assert_eq!(r.status, 400);
    finish(svc, fe);
}

#[test]
fn degraded_health_sheds_submissions_until_recovery() {
    let svc = Arc::new(AnalysisService::start(
        oracle(),
        ServiceConfig {
            workers: 4,
            queue_capacity: 4,
            max_in_flight: 2,
            batch: 8,
            policy: PolicySpec::fifo(),
            exec: ExecMode::Pool,
            ..ServiceConfig::default()
        },
    ));
    let tokens = TokenTable::parse("tok-a lab_a\n").unwrap();
    let cfg = HttpConfig::new("127.0.0.1:0", tokens);
    let health = Arc::clone(&cfg.health);
    let fe = HttpFrontend::start(Arc::clone(&svc), cfg).expect("bind ephemeral port");
    let addr = fe.addr();

    let r = http(addr, "GET /healthz HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n");
    assert_eq!(r.status, 200);
    assert!(r.json().get("ok").unwrap().as_bool().unwrap());

    health.set_degraded("store: cache dir not writable");
    let r = http(addr, "GET /healthz HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n");
    assert_eq!(r.status, 503);
    let v = r.json();
    assert!(!v.get("ok").unwrap().as_bool().unwrap());
    let reasons: Vec<&str> = v
        .get("degraded")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|j| j.as_str().unwrap())
        .collect();
    assert_eq!(reasons, ["store: cache dir not writable"]);

    // New work is shed with a retry hint while degraded.
    let r = post(addr, "/v1/jobs", "tok-a", &submit_body("deg0", 940, 16, 8, "large_tumor"));
    assert_eq!(r.status, 503, "{}", String::from_utf8_lossy(&r.body));
    assert_eq!(r.header("retry-after"), Some("5"));
    assert_eq!(r.json().get("retry_after").unwrap().as_u64().unwrap(), 5);

    // Recovery is symmetric: clear the reason, service admits again.
    health.clear_degraded("store: cache dir not writable");
    let r = http(addr, "GET /healthz HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n");
    assert_eq!(r.status, 200);
    let r = post(addr, "/v1/jobs", "tok-a", &submit_body("deg1", 941, 16, 8, "large_tumor"));
    assert_eq!(r.status, 201, "{}", String::from_utf8_lossy(&r.body));
    let id = r.json().get("job").unwrap().as_u64().unwrap();
    let r = get(addr, &format!("/v1/jobs/{id}/result"), "tok-a");
    assert_eq!(r.status, 200);
    let (tree, terminal) = reassemble(r.lines());
    assert_eq!(terminal.get("state").unwrap().as_str().unwrap(), "completed");
    tree.check_consistency().unwrap();
    let report = finish(svc, fe);
    assert_eq!(report.metrics.completed, 1);
}

#[test]
fn keep_alive_serves_sequential_requests_on_one_connection() {
    let (svc, fe) = start(oracle(), ExecMode::Pool, 4, 2);
    let mut s = TcpStream::connect(fe.addr()).unwrap();
    // Two pipelined requests; the second closes the connection, so one
    // read_to_end captures both responses.
    s.write_all(
        b"GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n\
          GET /healthz HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n",
    )
    .unwrap();
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).unwrap();
    let text = String::from_utf8_lossy(&buf);
    assert_eq!(
        text.matches("HTTP/1.1 200 OK").count(),
        2,
        "both pipelined requests answered: {text}"
    );
    finish(svc, fe);
}
