//! Cross-backend equivalence matrix: the same slide driven through every
//! execution substrate — the classic blocking driver, the in-process pool
//! backend, predcache replay, the TCP cluster backend and the simulator's
//! virtual workers — must produce byte-identical ExecTrees. This is the
//! acceptance bar for the unified `PyramidRun`/`ExecutionBackend` API:
//! where work runs can never change what was analyzed.

use std::sync::Arc;

use pyramidai::cluster::{ClusterBackend, ClusterExecConfig};
use pyramidai::model::oracle::OracleAnalyzer;
use pyramidai::model::Analyzer;
use pyramidai::predcache::SlidePredictions;
use pyramidai::pyramid::backend::run_on_backend;
use pyramidai::pyramid::driver::run_pyramidal;
use pyramidai::pyramid::tree::{ExecTree, Thresholds};
use pyramidai::pyramid::{ExecutionBackend, PoolBackend, ReplayBackend};
use pyramidai::service::pool::AnalyzerPool;
use pyramidai::sim::SimBackend;
use pyramidai::slide::pyramid::Slide;
use pyramidai::synth::slide_gen::{SlideKind, SlideSpec};

fn check(name: &str, expect: &ExecTree, got: &ExecTree) {
    got.check_consistency().unwrap();
    assert_eq!(got.initial, expect.initial, "{name}: initial set");
    assert_eq!(got.nodes, expect.nodes, "{name}: tree diverged");
}

#[test]
fn all_backends_produce_identical_trees() {
    let spec = SlideSpec::new("bkeq", 801, 32, 16, 3, 64, SlideKind::LargeTumor);
    let analyzer: Arc<dyn Analyzer> = Arc::new(OracleAnalyzer::new(1));
    let slide = Arc::new(Slide::from_spec(spec.clone()));
    let thr = Thresholds {
        zoom: vec![0.5, 0.35, 0.35],
    };

    // Ground truth: the blocking compatibility driver (itself a shim over
    // PyramidRun with one whole-frontier request per level).
    let expect = run_pyramidal(&slide, analyzer.as_ref(), &thr, 8);
    let initial = expect.initial.clone();

    // Vary the request granularity across backends on purpose: chunking
    // must never matter.
    for chunk in [0usize, 5] {
        let pool = Arc::new(AnalyzerPool::new(Arc::clone(&analyzer), 3));
        let mut pool_backend = PoolBackend::new(pool, Arc::clone(&slide), 4);
        let got = run_on_backend(
            slide.id(),
            slide.levels(),
            initial.clone(),
            &thr,
            chunk,
            &mut pool_backend,
        )
        .unwrap();
        check("pool", &expect, &got);
        assert_eq!(pool_backend.in_flight(), 0, "no leaked pool work");

        let preds = SlidePredictions::collect(&slide, analyzer.as_ref(), 16);
        let mut replay_backend = ReplayBackend::new(&preds);
        let got = run_on_backend(
            slide.id(),
            slide.levels(),
            initial.clone(),
            &thr,
            chunk,
            &mut replay_backend,
        )
        .unwrap();
        check("replay", &expect, &got);

        let mut cluster_backend = ClusterBackend::start(
            spec.clone(),
            Arc::clone(&analyzer),
            &ClusterExecConfig {
                workers: 2,
                steal: true,
                seed: 17,
                ..ClusterExecConfig::default()
            },
        )
        .unwrap();
        let got = run_on_backend(
            slide.id(),
            slide.levels(),
            initial.clone(),
            &thr,
            chunk,
            &mut cluster_backend,
        )
        .unwrap();
        check("cluster", &expect, &got);
        assert_eq!(cluster_backend.in_flight(), 0, "no leaked cluster work");

        // The same cluster with a mixed wire: worker 0 is held on the
        // JSON v1 encoding while worker 1 speaks binary v2 (frame v2
        // rolling-upgrade scenario). The encoding must never leak into
        // the tree.
        let mut mixed_backend = ClusterBackend::start(
            spec.clone(),
            Arc::clone(&analyzer),
            &ClusterExecConfig {
                workers: 2,
                steal: true,
                seed: 17,
                v1_json_workers: 1,
                ..ClusterExecConfig::default()
            },
        )
        .unwrap();
        let got = run_on_backend(
            slide.id(),
            slide.levels(),
            initial.clone(),
            &thr,
            chunk,
            &mut mixed_backend,
        )
        .unwrap();
        check("cluster-mixed-wire", &expect, &got);
        assert_eq!(mixed_backend.in_flight(), 0, "no leaked cluster work");

        let mut sim_backend = SimBackend::new(&expect, 4);
        let got = run_on_backend(
            slide.id(),
            slide.levels(),
            initial.clone(),
            &thr,
            chunk,
            &mut sim_backend,
        )
        .unwrap();
        check("sim", &expect, &got);
        assert_eq!(
            sim_backend.per_worker().iter().sum::<usize>(),
            expect.total_analyzed(),
            "virtual workers conserve tiles"
        );
    }

    // And the cache's own replay entry point (PyramidRun under the hood).
    let preds = SlidePredictions::collect(&slide, analyzer.as_ref(), 16);
    check("predcache::replay", &expect, &preds.replay(&thr));
}

/// The §10 acceptance bar: killing a worker mid-run must not change the
/// resulting tree by a byte. A slow analyzer keeps the run alive long
/// enough for the crash to land mid-frontier; the heartbeat detects the
/// loss and the dead worker's chunks are resubmitted to the survivors.
#[test]
fn killing_a_worker_mid_run_preserves_the_tree() {
    use pyramidai::model::DelayAnalyzer;
    use std::time::{Duration, Instant};

    let spec = SlideSpec::new("bkkill", 811, 32, 16, 3, 64, SlideKind::LargeTumor);
    let oracle: Arc<dyn Analyzer> = Arc::new(OracleAnalyzer::new(1));
    let slide = Arc::new(Slide::from_spec(spec.clone()));
    let thr = Thresholds {
        zoom: vec![0.5, 0.35, 0.35],
    };
    // Ground truth with the plain (fast) oracle; the cluster runs the
    // same oracle behind a per-tile delay, so probabilities agree.
    let expect = run_pyramidal(&slide, oracle.as_ref(), &thr, 8);

    let slow: Arc<dyn Analyzer> = Arc::new(DelayAnalyzer::new(
        OracleAnalyzer::new(1),
        Duration::from_millis(2),
    ));
    // Stealing off: chunk placement is exactly the round-robin deal, so
    // the victim is guaranteed to hold work when the crash lands.
    let mut backend = ClusterBackend::start(
        spec,
        slow,
        &ClusterExecConfig {
            workers: 3,
            steal: false,
            seed: 23,
            heartbeat: Duration::from_millis(10),
            max_missed: 2,
            ..ClusterExecConfig::default()
        },
    )
    .unwrap();
    let exec = backend.exec_handle();
    let killer = std::thread::spawn(move || {
        // Wait for dealt work rather than sleeping a fixed interval, so
        // the crash always lands while the victim holds chunks.
        let deadline = Instant::now() + Duration::from_secs(10);
        while exec.pending_chunks() == 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(exec.pending_chunks() > 0, "run never dealt a chunk");
        assert!(exec.kill_worker(0), "kill order must be deliverable");
    });
    let got = run_on_backend(
        slide.id(),
        slide.levels(),
        expect.initial.clone(),
        &thr,
        4,
        &mut backend,
    )
    .unwrap();
    killer.join().unwrap();
    check("cluster+kill", &expect, &got);
    assert_eq!(backend.in_flight(), 0, "no leaked work after recovery");

    // The loss is eventually detected and accounted, even if the run
    // outpaced the heartbeat.
    let exec = backend.exec_handle();
    let deadline = Instant::now() + Duration::from_secs(10);
    while exec.fault_stats().workers_lost == 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    let stats = exec.fault_stats();
    assert_eq!(stats.workers_lost, 1, "heartbeat must declare the victim dead");
    assert_eq!(exec.alive_workers(), 2);
}
