//! Multi-slide service integration: determinism against the standalone
//! driver, scheduling-policy ordering, backpressure, cancellation,
//! deadlines and cached-replay jobs — all with the oracle analyzer (no
//! artifacts needed).

use std::sync::Arc;
use std::time::Duration;

use pyramidai::model::oracle::OracleAnalyzer;
use pyramidai::model::{Analyzer, DelayAnalyzer};
use pyramidai::predcache::SlidePredictions;
use pyramidai::pyramid::driver::run_pyramidal;
use pyramidai::pyramid::tree::Thresholds;
use pyramidai::service::{
    AnalysisService, JobSource, JobSpec, JobState, PolicySpec, Priority, ServiceConfig,
    SubmitError,
};
use pyramidai::slide::pyramid::Slide;
use pyramidai::synth::slide_gen::{SlideKind, SlideSpec};

fn spec(seed: u64, kind: SlideKind) -> SlideSpec {
    SlideSpec::new(format!("svc_{seed}"), seed, 32, 16, 3, 64, kind)
}

fn thresholds() -> Thresholds {
    Thresholds {
        zoom: vec![0.5, 0.35, 0.35],
    }
}

fn oracle() -> Arc<dyn Analyzer> {
    Arc::new(OracleAnalyzer::new(1))
}

/// Slow oracle: makes run phases long enough that admission order is
/// observable on a fast machine.
fn slow_oracle(per_tile_ms: u64) -> Arc<dyn Analyzer> {
    Arc::new(DelayAnalyzer::new(
        OracleAnalyzer::new(1),
        Duration::from_millis(per_tile_ms),
    ))
}

#[test]
fn service_trees_match_standalone_runs_for_every_policy() {
    // The acceptance bar: scheduling (any policy, any interleaving) must
    // not change a single job's ExecTree vs a standalone run_pyramidal.
    let kinds = [
        SlideKind::LargeTumor,
        SlideKind::SmallScattered,
        SlideKind::Negative,
    ];
    let specs: Vec<SlideSpec> = (0..6).map(|i| spec(500 + i, kinds[i as usize % 3])).collect();
    let thr = thresholds();
    let solo: Vec<_> = specs
        .iter()
        .map(|sp| {
            let slide = Slide::from_spec(sp.clone());
            run_pyramidal(&slide, oracle().as_ref(), &thr, 8)
        })
        .collect();

    for policy in [PolicySpec::fifo(), PolicySpec::priority(), PolicySpec::wfs(Vec::new())] {
        let svc = AnalysisService::start(
            oracle(),
            ServiceConfig {
                workers: 4,
                queue_capacity: 16,
                max_in_flight: 3,
                batch: 8,
                policy: policy.clone(),
                ..ServiceConfig::default()
            },
        );
        let ids: Vec<_> = specs
            .iter()
            .enumerate()
            .map(|(i, sp)| {
                let j = JobSpec::new(JobSource::Spec(sp.clone()), thr.clone())
                    .with_priority([Priority::Low, Priority::Normal, Priority::High][i % 3])
                    .with_tenant(format!("tenant{}", i % 2));
                svc.submit(j).unwrap()
            })
            .collect();
        let report = svc.shutdown();
        assert_eq!(report.metrics.completed, specs.len(), "policy {policy:?}");
        assert_eq!(report.pool_panics, 0);
        for (i, id) in ids.iter().enumerate() {
            let r = report.job(*id).expect("job recorded");
            assert_eq!(r.state, JobState::Completed, "policy {policy:?} job {i}");
            let tree = r.tree.as_ref().unwrap();
            tree.check_consistency().unwrap();
            assert_eq!(
                tree.nodes, solo[i].nodes,
                "policy {policy:?}: job {i} diverged from standalone driver"
            );
            assert_eq!(r.tiles, solo[i].total_analyzed());
        }
    }
}

#[test]
fn cached_replay_jobs_match_predcache_replay() {
    let sp = spec(600, SlideKind::LargeTumor);
    let slide = Slide::from_spec(sp.clone());
    let preds = Arc::new(SlidePredictions::collect(&slide, oracle().as_ref(), 16));
    let thr = thresholds();
    let expect = preds.replay(&thr);

    let svc = AnalysisService::start(oracle(), ServiceConfig::default());
    let id = svc
        .submit(JobSpec::new(JobSource::Cached(Arc::clone(&preds)), thr))
        .unwrap();
    let report = svc.shutdown();
    let r = report.job(id).unwrap();
    assert_eq!(r.state, JobState::Completed);
    assert_eq!(r.tree.as_ref().unwrap().nodes, expect.nodes);
}

#[test]
fn priority_policy_starts_high_before_low() {
    // One job at a time, slow tiles: completion order == admission order.
    // Submit low, low, high while the first low occupies the service; the
    // high-priority job must overtake the second low one.
    let svc = AnalysisService::start(
        slow_oracle(1),
        ServiceConfig {
            workers: 2,
            queue_capacity: 8,
            max_in_flight: 1,
            batch: 8,
            policy: PolicySpec::priority(),
            ..ServiceConfig::default()
        },
    );
    let first = svc
        .submit(
            JobSpec::new(JobSource::Spec(spec(610, SlideKind::Negative)), thresholds())
                .with_priority(Priority::Low),
        )
        .unwrap();
    let second_low = svc
        .submit(
            JobSpec::new(JobSource::Spec(spec(611, SlideKind::Negative)), thresholds())
                .with_priority(Priority::Low),
        )
        .unwrap();
    let high = svc
        .submit(
            JobSpec::new(JobSource::Spec(spec(612, SlideKind::Negative)), thresholds())
                .with_priority(Priority::High),
        )
        .unwrap();
    let report = svc.shutdown();
    assert_eq!(report.metrics.completed, 3);
    // results are recorded in completion order.
    let order: Vec<_> = report.results.iter().map(|r| r.id).collect();
    let pos = |id| order.iter().position(|&x| x == id).unwrap();
    assert!(
        pos(high) < pos(second_low),
        "high-priority job ran after a low one: order {order:?} (first={first})"
    );
}

#[test]
fn fair_share_lets_light_tenant_through() {
    // Tenant A floods the queue; tenant B submits one job last. Fair-share
    // must run B's job before A's backlog drains.
    let svc = AnalysisService::start(
        slow_oracle(1),
        ServiceConfig {
            workers: 2,
            queue_capacity: 16,
            max_in_flight: 1,
            batch: 8,
            policy: PolicySpec::wfs(Vec::new()),
            ..ServiceConfig::default()
        },
    );
    let mut heavy = Vec::new();
    for i in 0..4 {
        heavy.push(
            svc.submit(
                JobSpec::new(
                    JobSource::Spec(spec(620 + i, SlideKind::Negative)),
                    thresholds(),
                )
                .with_tenant("heavy"),
            )
            .unwrap(),
        );
    }
    let light = svc
        .submit(
            JobSpec::new(JobSource::Spec(spec(630, SlideKind::Negative)), thresholds())
                .with_tenant("light"),
        )
        .unwrap();
    let report = svc.shutdown();
    assert_eq!(report.metrics.completed, 5);
    let order: Vec<_> = report.results.iter().map(|r| r.id).collect();
    let pos = |id| order.iter().position(|&x| x == id).unwrap();
    // The light tenant overtakes at least the heavy tenant's tail.
    assert!(
        pos(light) < pos(*heavy.last().unwrap()),
        "fair-share starved the light tenant: order {order:?}"
    );
}

#[test]
fn backpressure_rejects_and_cancellation_records() {
    // Capacity 2, nothing admitted yet (slow first job occupies the
    // single run slot only after the scheduler picks it up) — so a burst
    // overflows, and a queued job can be cancelled.
    let svc = AnalysisService::start(
        slow_oracle(2),
        ServiceConfig {
            workers: 1,
            queue_capacity: 2,
            max_in_flight: 1,
            batch: 8,
            policy: PolicySpec::fifo(),
            ..ServiceConfig::default()
        },
    );
    let a = svc
        .submit(JobSpec::new(
            JobSource::Spec(spec(640, SlideKind::Negative)),
            thresholds(),
        ))
        .unwrap();
    // Wait until `a` leaves the queue so the two slots are genuinely free.
    while svc.queued() > 0 {
        std::thread::sleep(Duration::from_micros(200));
    }
    let b = svc
        .submit(JobSpec::new(
            JobSource::Spec(spec(641, SlideKind::Negative)),
            thresholds(),
        ))
        .unwrap();
    let c = svc
        .submit(JobSpec::new(
            JobSource::Spec(spec(642, SlideKind::Negative)),
            thresholds(),
        ))
        .unwrap();
    // Queue now holds b and c (a runs) → the next submission bounces.
    let overflow = svc.submit(JobSpec::new(
        JobSource::Spec(spec(643, SlideKind::Negative)),
        thresholds(),
    ));
    assert_eq!(overflow, Err(SubmitError::QueueFull(2)));

    assert!(svc.cancel(c), "c still queued, cancellable");
    let report = svc.shutdown();
    assert_eq!(report.job(a).unwrap().state, JobState::Completed);
    assert_eq!(report.job(b).unwrap().state, JobState::Completed);
    assert_eq!(report.job(c).unwrap().state, JobState::Cancelled);
    assert_eq!(report.metrics.completed, 2);
    assert_eq!(report.metrics.cancelled, 1);
}

#[test]
fn zero_deadline_job_expires_in_queue() {
    let svc = AnalysisService::start(
        slow_oracle(1),
        ServiceConfig {
            workers: 1,
            queue_capacity: 8,
            max_in_flight: 1,
            batch: 8,
            policy: PolicySpec::fifo(),
            ..ServiceConfig::default()
        },
    );
    let slow = svc
        .submit(JobSpec::new(
            JobSource::Spec(spec(650, SlideKind::LargeTumor)),
            thresholds(),
        ))
        .unwrap();
    // Admitted strictly after `slow`, with no tolerance for queue wait.
    let doomed = svc
        .submit(
            JobSpec::new(JobSource::Spec(spec(651, SlideKind::Negative)), thresholds())
                .with_deadline(Duration::ZERO),
        )
        .unwrap();
    let report = svc.shutdown();
    assert_eq!(report.job(slow).unwrap().state, JobState::Completed);
    assert_eq!(report.job(doomed).unwrap().state, JobState::Expired);
    assert_eq!(report.metrics.expired, 1);
}

#[test]
fn results_cover_every_submitted_job_exactly_once() {
    let svc = AnalysisService::start(oracle(), ServiceConfig::default());
    let mut ids = Vec::new();
    for i in 0..10 {
        ids.push(
            svc.submit(JobSpec::new(
                JobSource::Spec(spec(660 + i, SlideKind::SmallScattered)),
                thresholds(),
            ))
            .unwrap(),
        );
    }
    let report = svc.shutdown();
    assert_eq!(report.results.len(), 10);
    let mut seen: Vec<_> = report.results.iter().map(|r| r.id).collect();
    seen.sort_unstable();
    let mut want = ids.clone();
    want.sort_unstable();
    assert_eq!(seen, want, "every job exactly one terminal record");
}

#[test]
fn mid_run_cancellation_stops_at_a_frontier_boundary() {
    // A slow job is cancelled while running; the service must preempt it
    // at a level-frontier boundary and finalize it as Cancelled with a
    // consistent partial tree — and with no in-flight pool work leaked
    // (shutdown would hang or panic if a chunk callback outlived its job).
    let sp = SlideSpec::new("svc_cancel", 700, 48, 32, 3, 64, SlideKind::LargeTumor);
    let thr = thresholds();
    let slide = Slide::from_spec(sp.clone());
    let solo = run_pyramidal(&slide, oracle().as_ref(), &thr, 8);

    let svc = AnalysisService::start(
        slow_oracle(2),
        ServiceConfig {
            workers: 2,
            queue_capacity: 4,
            max_in_flight: 1,
            batch: 8,
            policy: PolicySpec::fifo(),
            ..ServiceConfig::default()
        },
    );
    let id = svc
        .submit(JobSpec::new(JobSource::Spec(sp), thr))
        .unwrap();
    // Wait until the scheduler picked it up, then let the first frontier
    // make some progress before cancelling mid-run.
    while svc.queued() > 0 {
        std::thread::sleep(Duration::from_micros(200));
    }
    std::thread::sleep(Duration::from_millis(30));
    assert!(svc.cancel(id), "running job accepts cancellation");
    let report = svc.shutdown();
    let r = report.job(id).expect("terminal record exists");
    assert_eq!(r.state, JobState::Cancelled, "cancelled mid-run");
    let partial = r.tree.as_ref().expect("partial tree recorded");
    partial.check_consistency().unwrap();
    assert!(
        partial.total_analyzed() < solo.total_analyzed(),
        "cancellation must cut the run short ({} vs {})",
        partial.total_analyzed(),
        solo.total_analyzed()
    );
    // Frontier-boundary semantics: each level is either untouched or
    // byte-identical to the standalone run's (no half-recorded frontier).
    for (level, nodes) in partial.nodes.iter().enumerate() {
        assert!(
            nodes.is_empty() || *nodes == solo.nodes[level],
            "level {level} recorded partially"
        );
    }
    assert_eq!(r.tiles, partial.total_analyzed());
    assert_eq!(report.pool_panics, 0);
}

#[test]
fn cluster_backend_service_matches_standalone_runs() {
    use pyramidai::cluster::ClusterExecConfig;
    use pyramidai::service::ExecMode;

    let specs: Vec<SlideSpec> = (0..3)
        .map(|i| spec(710 + i, [SlideKind::LargeTumor, SlideKind::Negative][i as usize % 2]))
        .collect();
    let thr = thresholds();
    let solo: Vec<_> = specs
        .iter()
        .map(|sp| {
            let slide = Slide::from_spec(sp.clone());
            run_pyramidal(&slide, oracle().as_ref(), &thr, 8)
        })
        .collect();

    let svc = AnalysisService::start(
        oracle(),
        ServiceConfig {
            workers: 2,
            queue_capacity: 8,
            max_in_flight: 2,
            batch: 8,
            policy: PolicySpec::fifo(),
            exec: ExecMode::Cluster(ClusterExecConfig {
                workers: 2,
                steal: true,
                seed: 13,
                ..ClusterExecConfig::default()
            }),
            ..ServiceConfig::default()
        },
    );
    let ids: Vec<_> = specs
        .iter()
        .map(|sp| {
            svc.submit(JobSpec::new(JobSource::Spec(sp.clone()), thr.clone()))
                .unwrap()
        })
        .collect();
    let report = svc.shutdown();
    assert_eq!(report.metrics.completed, specs.len());
    let faults = report.cluster_faults.expect("cluster mode reports faults");
    assert_eq!(faults.workers_lost, 0, "healthy run must not count losses");
    for (i, id) in ids.iter().enumerate() {
        let r = report.job(*id).unwrap();
        assert_eq!(r.state, JobState::Completed, "job {i}");
        assert_eq!(
            r.tree.as_ref().unwrap().nodes,
            solo[i].nodes,
            "cluster-backed job {i} diverged from standalone driver"
        );
    }
}

/// §10 at the service layer: a cluster worker dies while jobs are in
/// flight — every job still completes with the standalone-driver tree,
/// and the report surfaces the loss/resubmission counts so operators can
/// see the recovery (instead of silent self-healing).
#[test]
fn cluster_worker_loss_mid_service_recovers_and_is_reported() {
    use pyramidai::cluster::ClusterExecConfig;
    use pyramidai::service::ExecMode;

    let specs: Vec<SlideSpec> = (0..2).map(|i| spec(730 + i, SlideKind::LargeTumor)).collect();
    let thr = thresholds();
    let solo: Vec<_> = specs
        .iter()
        .map(|sp| {
            let slide = Slide::from_spec(sp.clone());
            run_pyramidal(&slide, oracle().as_ref(), &thr, 8)
        })
        .collect();

    let svc = AnalysisService::start(
        slow_oracle(2),
        ServiceConfig {
            workers: 2,
            queue_capacity: 8,
            max_in_flight: 2,
            batch: 6,
            policy: PolicySpec::fifo(),
            exec: ExecMode::Cluster(ClusterExecConfig {
                workers: 3,
                steal: false,
                seed: 41,
                heartbeat: Duration::from_millis(10),
                max_missed: 2,
                ..ClusterExecConfig::default()
            }),
            ..ServiceConfig::default()
        },
    );
    let cluster = svc.cluster().expect("cluster mode exposes the handle");
    let ids: Vec<_> = specs
        .iter()
        .map(|sp| {
            svc.submit(JobSpec::new(JobSource::Spec(sp.clone()), thr.clone()))
                .unwrap()
        })
        .collect();
    // Let chunks land on the victim, then crash it.
    std::thread::sleep(Duration::from_millis(30));
    assert!(cluster.kill_worker(0), "kill order must be deliverable");
    let report = svc.shutdown();
    assert_eq!(report.metrics.completed, specs.len(), "no job may wedge");
    for (i, id) in ids.iter().enumerate() {
        let r = report.job(*id).unwrap();
        assert_eq!(r.state, JobState::Completed, "job {i}");
        assert_eq!(
            r.tree.as_ref().unwrap().nodes,
            solo[i].nodes,
            "worker loss changed job {i}'s tree"
        );
    }
    let faults = report.cluster_faults.expect("cluster mode reports faults");
    assert_eq!(faults.workers_lost, 1, "the crash must be detected and counted");
}

/// §15 at the service layer: the leader's entire dispatch state vanishes
/// mid-run — exactly what a standby that took over from a crashed leader
/// presents to the scheduler (workers alive, pending map empty). The
/// scheduler must count the failover, requeue every outstanding cluster
/// chunk, and finish every job with the standalone-driver tree.
#[test]
fn leader_failover_mid_service_requeues_and_completes() {
    use pyramidai::cluster::ClusterExecConfig;
    use pyramidai::service::ExecMode;

    let specs: Vec<SlideSpec> = (0..3).map(|i| spec(750 + i, SlideKind::LargeTumor)).collect();
    let thr = thresholds();
    let solo: Vec<_> = specs
        .iter()
        .map(|sp| {
            let slide = Slide::from_spec(sp.clone());
            run_pyramidal(&slide, oracle().as_ref(), &thr, 8)
        })
        .collect();

    let svc = AnalysisService::start(
        slow_oracle(2),
        ServiceConfig {
            workers: 2,
            queue_capacity: 8,
            max_in_flight: 3,
            batch: 6,
            policy: PolicySpec::fifo(),
            exec: ExecMode::Cluster(ClusterExecConfig {
                workers: 2,
                steal: true,
                seed: 53,
                ..ClusterExecConfig::default()
            }),
            ..ServiceConfig::default()
        },
    );
    let cluster = svc.cluster().expect("cluster mode exposes the handle");
    let ids: Vec<_> = specs
        .iter()
        .map(|sp| {
            svc.submit(JobSpec::new(JobSource::Spec(sp.clone()), thr.clone()))
                .unwrap()
        })
        .collect();
    // Fire the failover only once chunks are genuinely in flight, so the
    // injection is guaranteed to hit dispatched work (readiness-driven,
    // not a fixed sleep).
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while cluster.pending_chunks() == 0 && std::time::Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(1));
    }
    assert!(cluster.pending_chunks() > 0, "no chunks were ever dealt");
    let dropped = cluster.trigger_failover();
    assert!(dropped > 0, "failover must drop the in-flight chunks");

    let report = svc.shutdown();
    assert_eq!(report.metrics.completed, specs.len(), "no job may wedge");
    assert!(
        report.sched_metrics.counter("sched.leader_failovers") >= 1,
        "the scheduler must count the failover it absorbed"
    );
    for (i, id) in ids.iter().enumerate() {
        let r = report.job(*id).unwrap();
        assert_eq!(r.state, JobState::Completed, "job {i}");
        assert_eq!(
            r.tree.as_ref().unwrap().nodes,
            solo[i].nodes,
            "leader failover changed job {i}'s tree"
        );
    }
}

#[test]
fn coalescing_toggle_does_not_change_trees() {
    let specs: Vec<SlideSpec> = (0..4).map(|i| spec(720 + i, SlideKind::LargeTumor)).collect();
    let thr = thresholds();
    let solo: Vec<_> = specs
        .iter()
        .map(|sp| {
            let slide = Slide::from_spec(sp.clone());
            run_pyramidal(&slide, oracle().as_ref(), &thr, 8)
        })
        .collect();
    for coalesce in [true, false] {
        let svc = AnalysisService::start(
            oracle(),
            ServiceConfig {
                workers: 3,
                queue_capacity: 8,
                max_in_flight: 4,
                batch: 8,
                policy: PolicySpec::fifo(),
                coalesce,
                ..ServiceConfig::default()
            },
        );
        let ids: Vec<_> = specs
            .iter()
            .map(|sp| {
                svc.submit(JobSpec::new(JobSource::Spec(sp.clone()), thr.clone()))
                    .unwrap()
            })
            .collect();
        let report = svc.shutdown();
        for (i, id) in ids.iter().enumerate() {
            let r = report.job(*id).unwrap();
            assert_eq!(r.state, JobState::Completed, "coalesce={coalesce} job {i}");
            assert_eq!(
                r.tree.as_ref().unwrap().nodes,
                solo[i].nodes,
                "coalesce={coalesce}: job {i} diverged"
            );
        }
    }
}

#[test]
fn preemption_parks_and_resumes_with_identical_tree() {
    // A big low-priority job occupies the single slot; a high-priority
    // job submitted mid-run must preempt it at a level-frontier boundary
    // (park), run to completion, and then the low job resumes — and its
    // final tree must be byte-identical to an uninterrupted standalone
    // run. This extends the backend-equivalence guarantee to preemption.
    let sp = SlideSpec::new("svc_preempt", 800, 48, 32, 3, 64, SlideKind::LargeTumor);
    let thr = thresholds();
    let slide = Slide::from_spec(sp.clone());
    let solo = run_pyramidal(&slide, oracle().as_ref(), &thr, 8);

    let svc = AnalysisService::start(
        slow_oracle(2),
        ServiceConfig {
            workers: 2,
            queue_capacity: 4,
            max_in_flight: 1,
            batch: 8,
            policy: PolicySpec::priority(),
            preempt: true,
            ..ServiceConfig::default()
        },
    );
    let low = svc
        .submit(
            JobSpec::new(JobSource::Spec(sp), thr.clone()).with_priority(Priority::Low),
        )
        .unwrap();
    // Wait until the low job is running, then give its first frontier a
    // head start before the preemptor arrives.
    while svc.queued() > 0 {
        std::thread::sleep(Duration::from_micros(200));
    }
    std::thread::sleep(Duration::from_millis(30));
    let high = svc
        .submit(
            JobSpec::new(JobSource::Spec(spec(801, SlideKind::Negative)), thr)
                .with_priority(Priority::High),
        )
        .unwrap();
    let report = svc.shutdown();
    let low_r = report.job(low).expect("low job recorded");
    let high_r = report.job(high).expect("high job recorded");
    assert_eq!(low_r.state, JobState::Completed, "parked job must resume and finish");
    assert_eq!(high_r.state, JobState::Completed);
    assert!(
        low_r.preemptions >= 1,
        "low job must have been parked at least once"
    );
    assert!(report.metrics.preemptions >= 1);
    let tree = low_r.tree.as_ref().expect("tree present");
    tree.check_consistency().unwrap();
    assert_eq!(
        tree.nodes, solo.nodes,
        "suspend/resume changed the low job's tree"
    );
    assert_eq!(low_r.tiles, solo.total_analyzed());
    // The preemptor overtakes: it completes before the job it parked.
    let order: Vec<_> = report.results.iter().map(|r| r.id).collect();
    let pos = |id| order.iter().position(|&x| x == id).unwrap();
    assert!(
        pos(high) < pos(low),
        "preemptor must finish first: order {order:?}"
    );
    // Per-tenant metrics surface the preemption.
    let t = report
        .metrics
        .per_tenant
        .get("default")
        .expect("default tenant tracked");
    assert!(t.preemptions >= 1);
    assert_eq!(t.completed, 2);
}

#[test]
fn wfs_quota_caps_concurrent_jobs_of_one_tenant() {
    // Quota 1 with two slots: the flood tenant's jobs serialize, so the
    // other tenant's single job never waits behind more than one of
    // them. (Smoke-level: all jobs must still complete.)
    let svc = AnalysisService::start(
        oracle(),
        ServiceConfig {
            workers: 2,
            queue_capacity: 8,
            max_in_flight: 2,
            batch: 8,
            policy: PolicySpec::wfs(Vec::new()).with_quota(1),
            ..ServiceConfig::default()
        },
    );
    let mut ids = Vec::new();
    for i in 0..4 {
        ids.push(
            svc.submit(
                JobSpec::new(
                    JobSource::Spec(spec(810 + i, SlideKind::Negative)),
                    thresholds(),
                )
                .with_tenant("flood"),
            )
            .unwrap(),
        );
    }
    ids.push(
        svc.submit(
            JobSpec::new(JobSource::Spec(spec(820, SlideKind::Negative)), thresholds())
                .with_tenant("calm"),
        )
        .unwrap(),
    );
    let report = svc.shutdown();
    assert_eq!(report.metrics.completed, ids.len());
    for id in ids {
        assert_eq!(report.job(id).unwrap().state, JobState::Completed);
    }
}
