//! Determinism gates for the observability layer: the same deterministic
//! work must emit the same event sequence (names, subsystems, structured
//! fields) on every run — only timestamps and durations may differ.
//! Anything less and traces can't be diffed across runs or machines.

use pyramidai::model::oracle::OracleAnalyzer;
use pyramidai::obs::{self, capture, TraceRecord};
use pyramidai::predcache::{PredCache, ShardedPredStore};
use pyramidai::pyramid::driver::run_pyramidal;
use pyramidai::pyramid::tree::Thresholds;
use pyramidai::slide::pyramid::Slide;
use pyramidai::synth::slide_gen::{gen_slide_set, DatasetParams};

/// The timestamp-free shape of a trace: everything that must be stable
/// across reruns of deterministic work.
fn shape(recs: &[TraceRecord]) -> Vec<String> {
    recs.iter()
        .map(|r| {
            let fields: Vec<String> = r
                .fields
                .iter()
                .map(|(k, v)| format!("{k}={v:?}"))
                .collect();
            format!("{}/{}/{}[{}]", r.level.as_str(), r.sub, r.ev, fields.join(","))
        })
        .collect()
}

fn params() -> DatasetParams {
    DatasetParams {
        tiles_x: 16,
        tiles_y: 8,
        levels: 3,
        tile_px: 64,
    }
}

#[test]
fn pyramidal_run_trace_is_deterministic() {
    let slide = Slide::from_spec(gen_slide_set("obsdet", 1, 41, &params()).remove(0));
    let analyzer = OracleAnalyzer::new(1);
    let thr = Thresholds::uniform(3, 0.35);
    let run = || run_pyramidal(&slide, &analyzer, &thr, 8);

    let (tree_a, recs_a) = capture(run);
    let (tree_b, recs_b) = capture(run);

    assert_eq!(tree_a.nodes, tree_b.nodes, "replayed trees must match");
    let pyr_a: Vec<_> = recs_a.iter().filter(|r| r.sub == "pyramid").cloned().collect();
    let pyr_b: Vec<_> = recs_b.iter().filter(|r| r.sub == "pyramid").cloned().collect();
    assert!(
        !pyr_a.is_empty(),
        "a pyramidal run must emit pyramid events under capture"
    );
    assert_eq!(
        shape(&pyr_a),
        shape(&pyr_b),
        "same work, same event sequence (timestamps aside)"
    );
    // Every frontier analysis is a span: durations present, timestamps
    // monotone within the thread.
    for r in &pyr_a {
        assert!(r.dur_us.is_some(), "{} must be a span", r.ev);
    }
    for w in recs_a.windows(2) {
        assert!(w[1].ts_us >= w[0].ts_us, "timestamps must be monotone");
    }
}

#[test]
fn shard_stream_trace_is_deterministic() {
    let slides: Vec<Slide> = gen_slide_set("obsstore", 3, 43, &params())
        .into_iter()
        .map(Slide::from_spec)
        .collect();
    let cache = PredCache::collect_set(&slides, &OracleAnalyzer::new(1), 16);
    let dir = std::env::temp_dir().join(format!(
        "pyramidai_obs_trace_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    pyramidai::predcache::store::save_sharded(&cache, &dir, 1).unwrap();

    let decode_before = obs::global_metrics()
        .histogram("predcache.decode_us")
        .snapshot()
        .count;

    let stream = || {
        // Budget 0: every slide switch decodes a shard off disk.
        let store = ShardedPredStore::open_with_budget(&dir, Some(0)).unwrap();
        for i in 0..store.len() {
            store.slide(i).unwrap();
        }
    };
    let ((), recs_a) = capture(stream);
    let ((), recs_b) = capture(stream);

    let pc = |recs: &[TraceRecord]| -> Vec<TraceRecord> {
        recs.iter().filter(|r| r.sub == "predcache").cloned().collect()
    };
    let (a, b) = (pc(&recs_a), pc(&recs_b));
    assert_eq!(a.len(), 3, "one shard_decode per slide");
    assert_eq!(shape(&a), shape(&b), "same stream, same decode events");

    // The decode histogram in the global registry advanced by at least
    // the decodes this test performed (other tests may add more).
    let decode_after = obs::global_metrics()
        .histogram("predcache.decode_us")
        .snapshot()
        .count;
    assert!(
        decode_after >= decode_before + 6,
        "decode histogram must count both streams: {decode_before} -> {decode_after}"
    );
    std::fs::remove_dir_all(&dir).ok();
}
