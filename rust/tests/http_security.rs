//! Adversarial parser suite against a live front-end: every malformed,
//! oversized, smuggling-shaped or stalling request must be answered with
//! a clean 4xx/5xx (or silently dropped when there is nothing to answer)
//! and must never panic or wedge the server — after the full barrage the
//! same listener still serves well-formed requests.

use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use pyramidai::model::oracle::OracleAnalyzer;
use pyramidai::service::http::{HttpConfig, HttpFrontend, TokenTable};
use pyramidai::service::{AnalysisService, ServiceConfig};

/// A front-end with a short read timeout so the slow-loris case runs in
/// test time rather than the 5 s production default.
fn start() -> (Arc<AnalysisService>, HttpFrontend) {
    let svc = Arc::new(AnalysisService::start(
        Arc::new(OracleAnalyzer::new(1)),
        ServiceConfig::default(),
    ));
    let mut cfg = HttpConfig::new("127.0.0.1:0", TokenTable::single("sec-tok", "lab"));
    cfg.limits.read_timeout = Duration::from_millis(250);
    let fe = HttpFrontend::start(Arc::clone(&svc), cfg).expect("bind");
    (svc, fe)
}

/// Send raw bytes, optionally half-close the write side (simulating a
/// peer that disconnects mid-request), and return the response status —
/// `None` when the server closed without answering.
fn roundtrip(addr: SocketAddr, raw: &[u8], half_close: bool) -> (Option<u16>, Vec<u8>) {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(raw).unwrap();
    if half_close {
        s.shutdown(Shutdown::Write).unwrap();
    }
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).unwrap();
    let status = buf
        .strip_prefix(b"HTTP/1.1 ")
        .and_then(|rest| std::str::from_utf8(&rest[..3]).ok())
        .and_then(|code| code.parse::<u16>().ok());
    (status, buf)
}

fn expect_status(addr: SocketAddr, raw: &[u8], want: u16, what: &str) {
    let (status, buf) = roundtrip(addr, raw, false);
    assert_eq!(
        status,
        Some(want),
        "{what}: {:?}",
        String::from_utf8_lossy(&buf[..buf.len().min(200)])
    );
}

#[test]
fn adversarial_requests_get_clean_rejections_and_never_kill_the_server() {
    let (svc, fe) = start();
    let addr = fe.addr();

    // -- size limits map to their statuses ------------------------------
    let long_uri = format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(9000));
    expect_status(addr, long_uri.as_bytes(), 414, "oversized request line");
    let long_header = format!("GET / HTTP/1.1\r\nX-Pad: {}\r\n\r\n", "b".repeat(9000));
    expect_status(addr, long_header.as_bytes(), 431, "oversized header line");
    let many_headers = format!("GET / HTTP/1.1\r\n{}\r\n", "X-A: 1\r\n".repeat(100));
    expect_status(addr, many_headers.as_bytes(), 431, "too many headers");
    expect_status(
        addr,
        b"POST / HTTP/1.1\r\nContent-Length: 2097152\r\n\r\n",
        413,
        "declared body over the cap",
    );

    // -- header splitting / CRLF-injection shapes ------------------------
    expect_status(
        addr,
        b"GET / HTTP/1.1\nHost: x\r\n\r\n",
        400,
        "bare-LF request line terminator",
    );
    expect_status(
        addr,
        b"GET / HTTP/1.1\r\nHost: x\nX-Inject: 1\r\n\r\n",
        400,
        "bare-LF header terminator",
    );
    expect_status(
        addr,
        b"GET / HTTP/1.1\r\nHost : x\r\n\r\n",
        400,
        "whitespace before header colon",
    );
    expect_status(
        addr,
        b"GET / HTTP/1.1\r\nA: b\r\n folded\r\n\r\n",
        400,
        "obsolete header folding",
    );
    expect_status(
        addr,
        b"GET / HTTP/1.1\r\nX-A: a\x01b\r\n\r\n",
        400,
        "control byte in header value",
    );

    // -- request-smuggling framing conflicts -----------------------------
    expect_status(
        addr,
        b"POST / HTTP/1.1\r\nContent-Length: 3\r\nTransfer-Encoding: chunked\r\n\r\n0\r\n\r\n",
        400,
        "CL + TE conflict",
    );
    expect_status(
        addr,
        b"POST / HTTP/1.1\r\nContent-Length: 3\r\nContent-Length: 3\r\n\r\nabc",
        400,
        "duplicate content-length",
    );
    expect_status(
        addr,
        b"POST / HTTP/1.1\r\nContent-Length: 1e3\r\n\r\n",
        400,
        "non-digit content-length",
    );

    // -- malformed chunked bodies ----------------------------------------
    expect_status(
        addr,
        b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\nzz\r\nabc\r\n0\r\n\r\n",
        400,
        "non-hex chunk size",
    );
    expect_status(
        addr,
        b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n3;x=1\r\nabc\r\n0\r\n\r\n",
        400,
        "chunk extension",
    );
    expect_status(
        addr,
        b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n0\r\nX-Trailer: 1\r\n\r\n",
        400,
        "trailer fields",
    );
    expect_status(
        addr,
        b"POST / HTTP/1.1\r\nTransfer-Encoding: gzip\r\n\r\n",
        400,
        "non-chunked transfer coding",
    );

    // -- request-line / version edges ------------------------------------
    expect_status(addr, b"GET / HTTP/2.0\r\n\r\n", 505, "HTTP/2 preface-ish");
    expect_status(addr, b"GET / HTTP/9.9\r\n\r\n", 505, "future version");
    expect_status(addr, b"G@T / HTTP/1.1\r\n\r\n", 400, "non-token method");
    expect_status(
        addr,
        b"GET http://evil/ HTTP/1.1\r\n\r\n",
        400,
        "absolute-form target (proxy probe)",
    );
    expect_status(addr, b"\x16\x03\x01\x02garbage\r\n\r\n", 400, "binary garbage");

    // -- truncation and stalls -------------------------------------------
    // Peer disconnects mid-chunked-body: nothing to answer, clean drop.
    let (status, buf) = roundtrip(
        addr,
        b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n3\r\nab",
        true,
    );
    assert_eq!(status, None, "truncated body answered: {buf:?}");
    // Slow-loris: a started-but-stalled request hits the read timeout.
    expect_status(addr, b"GET /v1/jo", 408, "slow-loris stall");

    // -- the server survived all of it -----------------------------------
    let (status, buf) = roundtrip(
        addr,
        b"GET /healthz HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n",
        false,
    );
    assert_eq!(
        status,
        Some(200),
        "server must still serve after the barrage: {:?}",
        String::from_utf8_lossy(&buf)
    );
    let snap = svc.registry().snapshot();
    assert!(
        snap.counter("http.parse_errors") >= 15,
        "every rejection recorded: {}",
        snap.counter("http.parse_errors")
    );

    fe.stop();
    let report = Arc::try_unwrap(svc).ok().expect("handlers joined").shutdown();
    assert_eq!(report.results.len(), 0, "no job ever admitted");
}

#[test]
fn unauthenticated_and_oversized_submissions_cannot_reach_the_scheduler() {
    let (svc, fe) = start();
    let addr = fe.addr();

    // Valid HTTP, no/wrong credentials: 401 before any body is parsed.
    let body = r#"{"slide":{"id":"x","seed":1,"tiles_x":16,"tiles_y":8,"levels":3,"tile_px":64,"kind":"negative"}}"#;
    let req = format!(
        "POST /v1/jobs HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    expect_status(addr, req.as_bytes(), 401, "submission without a token");

    // Authenticated but hostile geometry: rejected by validation (400),
    // never a SlideSpec::new panic.
    for bad in [
        r#"{"slide":{"id":"x","seed":1,"tiles_x":16,"tiles_y":8,"levels":0,"tile_px":64,"kind":"negative"}}"#,
        r#"{"slide":{"id":"x","seed":1,"tiles_x":15,"tiles_y":8,"levels":3,"tile_px":64,"kind":"negative"}}"#,
        r#"{"slide":{"id":"x","seed":1,"tiles_x":1000000,"tiles_y":8,"levels":3,"tile_px":64,"kind":"negative"}}"#,
        r#"{"slide":{"id":"x","seed":1,"tiles_x":16,"tiles_y":8,"levels":3,"tile_px":64,"kind":"exploit"}}"#,
        "not json at all",
        "{}",
    ] {
        let req = format!(
            "POST /v1/jobs HTTP/1.1\r\nHost: t\r\nAuthorization: Bearer sec-tok\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{bad}",
            bad.len()
        );
        expect_status(addr, req.as_bytes(), 400, "hostile submission body");
    }

    fe.stop();
    let report = Arc::try_unwrap(svc).ok().expect("handlers joined").shutdown();
    assert_eq!(report.results.len(), 0, "nothing reached the scheduler");
}
