//! Multi-process cluster smoke tests: workers as separate OS processes.
//!
//! `ClusterExec` spawns `pyramidai worker --connect <addr>` children
//! (via `CARGO_BIN_EXE_pyramidai`, which Cargo builds for integration
//! tests), so the serve/cluster paths exercise *real* process isolation:
//! separate address spaces, real sockets, and crashes that are actual
//! `SIGKILL`s. The trees must still be byte-identical to the in-process
//! blocking driver — and stay so when an external worker is killed
//! mid-run (DESIGN.md §10).

use std::sync::Arc;
use std::time::Duration;

use pyramidai::cluster::{ClusterBackend, ClusterExecConfig};
use pyramidai::model::oracle::OracleAnalyzer;
use pyramidai::model::{Analyzer, DelayAnalyzer};
use pyramidai::pyramid::backend::run_on_backend;
use pyramidai::pyramid::driver::run_pyramidal;
use pyramidai::pyramid::tree::Thresholds;
use pyramidai::slide::pyramid::Slide;
use pyramidai::synth::slide_gen::{SlideKind, SlideSpec};

/// Cluster config whose external workers run the real `pyramidai`
/// binary with an analyzer identical to the in-process oracle.
fn external_cfg(workers: usize, external: usize, seed: u64) -> ClusterExecConfig {
    ClusterExecConfig {
        workers,
        steal: false,
        seed,
        heartbeat: Duration::from_millis(15),
        max_missed: 3,
        external_workers: external,
        external_program: env!("CARGO_BIN_EXE_pyramidai").to_string(),
        // The in-process side of these tests uses OracleAnalyzer::new(1);
        // the worker processes must build the same model.
        external_args: vec![
            "--model".to_string(),
            "oracle".to_string(),
            "--analyzer-seed".to_string(),
            "1".to_string(),
        ],
        v1_json_workers: 0,
        ..ClusterExecConfig::default()
    }
}

#[test]
fn external_worker_processes_serve_chunks() {
    let spec = SlideSpec::new("mp", 901, 32, 16, 3, 64, SlideKind::LargeTumor);
    let analyzer: Arc<dyn Analyzer> = Arc::new(OracleAnalyzer::new(1));
    let slide = Slide::from_spec(spec.clone());
    let thr = Thresholds {
        zoom: vec![0.5, 0.35, 0.35],
    };
    let expect = run_pyramidal(&slide, analyzer.as_ref(), &thr, 8);

    // One in-process worker plus two external OS processes.
    let mut backend =
        ClusterBackend::start(spec, analyzer, &external_cfg(1, 2, 31)).unwrap();
    assert!(
        backend.exec().wait_for_workers(3, Duration::from_secs(30)),
        "external workers must register through the Hello handshake"
    );
    assert_eq!(backend.exec().fault_stats().workers_joined, 2);

    let got = run_on_backend(
        slide.id(),
        slide.levels(),
        expect.initial.clone(),
        &thr,
        4,
        &mut backend,
    )
    .unwrap();
    got.check_consistency().unwrap();
    assert_eq!(got.nodes, expect.nodes, "multi-process tree diverged");
    assert_eq!(backend.in_flight(), 0);
}

#[test]
fn v1_json_external_worker_interops_with_v2_cluster() {
    // Rolling-upgrade smoke: the in-process worker negotiates binary v2,
    // the external process is pinned to the JSON v1 wire with `--wire v1`
    // (a stand-in for a pre-v2 binary). The mixed cluster must produce
    // the same tree as the blocking driver.
    let spec = SlideSpec::new("mp_v1", 903, 32, 16, 3, 64, SlideKind::LargeTumor);
    let analyzer: Arc<dyn Analyzer> = Arc::new(OracleAnalyzer::new(1));
    let slide = Slide::from_spec(spec.clone());
    let thr = Thresholds {
        zoom: vec![0.5, 0.35, 0.35],
    };
    let expect = run_pyramidal(&slide, analyzer.as_ref(), &thr, 8);

    let mut cfg = external_cfg(1, 1, 41);
    cfg.external_args.push("--wire".to_string());
    cfg.external_args.push("v1".to_string());
    let mut backend = ClusterBackend::start(spec, analyzer, &cfg).unwrap();
    assert!(
        backend.exec().wait_for_workers(2, Duration::from_secs(30)),
        "the v1 worker must register through the Hello handshake"
    );
    let got = run_on_backend(
        slide.id(),
        slide.levels(),
        expect.initial.clone(),
        &thr,
        4,
        &mut backend,
    )
    .unwrap();
    got.check_consistency().unwrap();
    assert_eq!(got.nodes, expect.nodes, "mixed v1/v2 wire changed the tree");
}

#[test]
fn killed_external_worker_process_does_not_change_the_tree() {
    let spec = SlideSpec::new("mp_kill", 902, 32, 16, 3, 64, SlideKind::LargeTumor);
    let oracle: Arc<dyn Analyzer> = Arc::new(OracleAnalyzer::new(1));
    let slide = Slide::from_spec(spec.clone());
    let thr = Thresholds {
        zoom: vec![0.5, 0.35, 0.35],
    };
    let expect = run_pyramidal(&slide, oracle.as_ref(), &thr, 8);

    // The dispatcher side is slow (per-tile delay) so the SIGKILL lands
    // while the victim still holds chunks; note the external processes
    // run the *fast* oracle — only probabilities must match, not speed.
    let slow: Arc<dyn Analyzer> = Arc::new(DelayAnalyzer::new(
        OracleAnalyzer::new(1),
        Duration::from_millis(2),
    ));
    let mut backend =
        ClusterBackend::start(spec, slow, &external_cfg(2, 1, 37)).unwrap();
    assert!(
        backend.exec().wait_for_workers(3, Duration::from_secs(30)),
        "external worker must register before the run starts"
    );
    let exec = backend.exec_handle();
    let killer = std::thread::spawn(move || {
        // Readiness-driven, not a fixed sleep: wait until the leader has
        // actually dealt chunks, so the SIGKILL is guaranteed to land
        // while work is outstanding instead of racing the run's start.
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while exec.pending_chunks() == 0 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(exec.pending_chunks() > 0, "run never dealt a chunk");
        assert!(exec.kill_external_worker(0), "a child process must die");
    });
    let got = run_on_backend(
        slide.id(),
        slide.levels(),
        expect.initial.clone(),
        &thr,
        4,
        &mut backend,
    )
    .unwrap();
    killer.join().unwrap();
    got.check_consistency().unwrap();
    assert_eq!(
        got.nodes, expect.nodes,
        "killing an external worker changed the tree"
    );
}
