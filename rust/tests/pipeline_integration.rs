//! Full-pipeline integration with the oracle analyzer (artifact-free):
//! dataset → prediction cache → both tuning strategies → replay →
//! retention/speedup → simulator → WSI classification.
//!
//! This is the rust-side analogue of the paper's §4-§5 workflow end to end.

use pyramidai::metrics::retention::retention_and_speedup;
use pyramidai::model::oracle::OracleAnalyzer;
use pyramidai::predcache::PredCache;
use pyramidai::pyramid::tree::POSITIVE_THRESHOLD;
use pyramidai::sim::{simulate, Distribution, Policy};
use pyramidai::slide::pyramid::Slide;
use pyramidai::synth::slide_gen::{gen_slide_set, DatasetParams};
use pyramidai::tuning::{empirical, metric_based};
use pyramidai::wsi::{tree_features, BaggingClassifier, BaggingParams, Sample};

fn caches() -> (PredCache, PredCache, Vec<Slide>) {
    let params = DatasetParams::default();
    let analyzer = OracleAnalyzer::new(1);
    let train: Vec<Slide> = gen_slide_set("train", 12, 100, &params)
        .into_iter()
        .map(Slide::from_spec)
        .collect();
    let test: Vec<Slide> = gen_slide_set("test", 9, 200, &params)
        .into_iter()
        .map(Slide::from_spec)
        .collect();
    let train_cache = PredCache::collect_set(&train, &analyzer, 32);
    let test_cache = PredCache::collect_set(&test, &analyzer, 32);
    (train_cache, test_cache, test)
}

#[test]
fn full_pipeline_reproduces_paper_shape() {
    let (train_cache, test_cache, _) = caches();

    // --- empirical strategy (§4.5): tune on train, evaluate on test ----
    let sel = empirical::select(&train_cache, 3, 0.90).unwrap();
    let (test_ret, test_speedup, _) =
        metric_based::evaluate(&test_cache, &sel.thresholds).unwrap();
    assert!(
        test_ret >= 0.80,
        "test retention {test_ret} collapsed vs train target 0.90"
    );
    assert!(
        test_speedup > 1.5,
        "test speedup {test_speedup} — paper reports 2.65 at 90% retention"
    );

    // --- metric-based strategy (§4.4) ----------------------------------
    let mb = metric_based::select(&train_cache, 3, 0.90).unwrap();
    let (mb_ret, mb_speedup, _) = metric_based::evaluate(&test_cache, &mb.thresholds).unwrap();
    assert!(mb_ret >= 0.80, "metric-based test retention {mb_ret}");
    assert!(mb_speedup > 1.0, "metric-based speedup {mb_speedup}");

    // --- distributed simulation (§5): work stealing ≈ ideal ------------
    let sp = &test_cache.slides[0];
    let tree = sp.replay(&sel.thresholds);
    let ideal = simulate(&tree, 12, Distribution::RoundRobin, Policy::OracleIdeal, 1);
    let steal = simulate(&tree, 12, Distribution::RoundRobin, Policy::WorkStealing, 1);
    assert!(steal.max_tiles() as f64 <= ideal.max_tiles() as f64 * 1.5 + 4.0);

    // --- WSI classification (§4.6) --------------------------------------
    // Train on the train set's replayed trees, test on the test set.
    let label = |cache: &PredCache, i: usize| -> bool {
        cache.slides[i]
            .iter_level(0)
            .any(|(_, p)| p.tumor && p.prob >= POSITIVE_THRESHOLD as f32)
    };
    let mk_samples = |cache: &PredCache| -> Vec<Sample> {
        (0..cache.slides.len())
            .map(|i| Sample {
                x: tree_features(&cache.slides[i].replay(&sel.thresholds)),
                y: label(cache, i),
            })
            .collect()
    };
    let train_s = mk_samples(&train_cache);
    let test_s = mk_samples(&test_cache);
    let clf = BaggingClassifier::fit(&train_s, &BaggingParams::default());
    let acc = clf.accuracy(&test_s);
    assert!(acc >= 0.7, "WSI accuracy {acc} (paper: 0.84)");
}

#[test]
fn retention_speedup_tradeoff_exists_on_test_set() {
    let (train_cache, test_cache, _) = caches();
    let points = empirical::sweep(&train_cache, 3).unwrap();
    // Evaluate the extreme betas on the held-out test set.
    let (lo_ret, lo_speedup, _) =
        metric_based::evaluate(&test_cache, &points.first().unwrap().thresholds).unwrap();
    let (hi_ret, hi_speedup, _) =
        metric_based::evaluate(&test_cache, &points.last().unwrap().thresholds).unwrap();
    assert!(hi_ret > lo_ret, "retention: β=14 {hi_ret} vs β=1 {lo_ret}");
    assert!(lo_speedup > hi_speedup, "speedup: β=1 {lo_speedup} vs β=14 {hi_speedup}");
    // Fig 5 headline: low β should be dramatically faster.
    assert!(lo_speedup > 2.0, "β=1 speedup {lo_speedup}");
}

#[test]
fn metrics_consistent_between_cache_and_replay() {
    let (train_cache, _, _) = caches();
    let sel = empirical::select(&train_cache, 3, 0.9).unwrap();
    for sp in &train_cache.slides {
        let tree = sp.replay(&sel.thresholds);
        tree.check_consistency().unwrap();
        let m = retention_and_speedup(sp, &tree);
        assert!(m.pyramid_tiles <= (m.reference_tiles as f64 * 4.0 / 3.0).ceil() as usize + 1);
        assert!((0.0..=1.0).contains(&m.retention()));
    }
}
