//! Deterministic chaos harness for the decentralized control plane
//! (DESIGN.md §15): real OS processes, real SIGKILLs, seeded schedules.
//!
//! Each scenario spawns a standby, a leader replicating its chunk ledger
//! to it, and two external worker processes, then kills the leader AND
//! one worker at times drawn from a seeded PRNG. Whatever the schedule —
//! kill before the run starts, mid-run, or after it finished — exactly
//! one invariant must hold: the tree that survives (the leader's `--out`
//! on a clean finish, the standby's `run_1.json` after a takeover) is
//! byte-identical to the unfailed in-process run.
//!
//! Schedules are reproducible: `CHAOS_SEED=n cargo test -p pyramidai
//! --test chaos_cluster` replays exactly one seed, and every failure
//! message leads with the seed that produced it.
//!
//! The mixed-fault scenarios (DESIGN.md §16) compose process kills with
//! deterministic `--faults` plans: a slow-link worker (`net.delay`), a
//! worker behind a windowed `net.partition`, and a standby whose
//! takeover tree write suffers probabilistic `disk.torn_write` faults.
//! `CHAOS_MIXED_SEED=n` replays one mixed seed the same way.

use std::io::BufRead;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

use pyramidai::model::oracle::OracleAnalyzer;
use pyramidai::model::Analyzer;
use pyramidai::pyramid::driver::run_pyramidal;
use pyramidai::pyramid::tree::Thresholds;
use pyramidai::slide::pyramid::Slide;
use pyramidai::synth::slide_gen::{SlideKind, SlideSpec};
use pyramidai::util::prng::Pcg32;

const BIN: &str = env!("CARGO_BIN_EXE_pyramidai");
const SLIDE_SEED: u64 = 5;
const TILES_X: usize = 16;
const TILES_Y: usize = 8;

/// Kill-on-drop child wrapper so a failed assertion never leaks
/// processes into the test runner.
struct Proc(Child);

impl Drop for Proc {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

/// The tree an unfailed run must produce, in the exact byte format
/// `pyramidai leader --out` and the standby's `--out-dir` both write.
fn golden_tree_json() -> String {
    let spec = SlideSpec::new(
        format!("cli_{SLIDE_SEED}"),
        SLIDE_SEED,
        TILES_X,
        TILES_Y,
        3,
        64,
        SlideKind::LargeTumor,
    );
    let analyzer: Arc<dyn Analyzer> = Arc::new(OracleAnalyzer::new(1));
    let slide = Slide::from_spec(spec);
    let thr = Thresholds {
        zoom: vec![0.5, 0.35, 0.35],
    };
    run_pyramidal(&slide, analyzer.as_ref(), &thr, 8)
        .to_json()
        .to_string()
}

/// Poll until `path` exists and is non-empty (the writers rename into
/// place, so existence means complete content).
fn wait_for_file(path: &Path, timeout: Duration) -> bool {
    let deadline = Instant::now() + timeout;
    while Instant::now() < deadline {
        if std::fs::metadata(path).map(|m| m.len() > 0).unwrap_or(false) {
            return true;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    false
}

fn wait_for_exit(child: &mut Child, timeout: Duration) -> bool {
    let deadline = Instant::now() + timeout;
    while Instant::now() < deadline {
        if child.try_wait().ok().flatten().is_some() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    false
}

/// One seeded scenario. Returns whether the standby took over (i.e. the
/// surviving tree came from `run_1.json`).
fn run_scenario(seed: u64, golden: &str) -> bool {
    let dir = std::env::temp_dir().join(format!(
        "pyramidai_chaos_{}_{}",
        std::process::id(),
        seed
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let standby_addr_file = dir.join("standby.addr");
    let leader_addr_file = dir.join("leader.addr");
    let leader_out = dir.join("leader_tree.json");
    let out_dir = dir.join("trees");

    // Seeded fault schedule: independent kill delays for the leader and
    // one worker, both measured from the moment the leader reports its
    // worker quorum (the start of the run proper).
    let mut rng = Pcg32::new(0xC4A0_5EED ^ seed);
    let leader_kill_ms = rng.usize_range(20, 150) as u64;
    let worker_kill_ms = rng.usize_range(20, 150) as u64;

    let mut standby = Proc(
        Command::new(BIN)
            .args([
                "leader",
                "--standby",
                "--listen",
                "127.0.0.1:0",
                "--addr-file",
                standby_addr_file.to_str().unwrap(),
                "--out-dir",
                out_dir.to_str().unwrap(),
                "--model",
                "oracle",
                "--analyzer-seed",
                "1",
                "--heartbeat-ms",
                "15",
            ])
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn standby"),
    );
    assert!(
        wait_for_file(&standby_addr_file, Duration::from_secs(30)),
        "chaos seed {seed}: standby never published its address"
    );
    let standby_addr = std::fs::read_to_string(&standby_addr_file).unwrap();

    let mut leader = Proc(
        Command::new(BIN)
            .args([
                "leader",
                "--slide-seed",
                &SLIDE_SEED.to_string(),
                "--kind",
                "large_tumor",
                "--tiles-x",
                &TILES_X.to_string(),
                "--tiles-y",
                &TILES_Y.to_string(),
                "--workers",
                "0",
                "--wait-workers",
                "2",
                "--chunk",
                "4",
                "--standby-addr",
                standby_addr.trim(),
                "--addr-file",
                leader_addr_file.to_str().unwrap(),
                "--out",
                leader_out.to_str().unwrap(),
                "--model",
                "oracle",
                "--analyzer-seed",
                "1",
                "--heartbeat-ms",
                "15",
            ])
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn leader"),
    );
    assert!(
        wait_for_file(&leader_addr_file, Duration::from_secs(30)),
        "chaos seed {seed}: leader never published its address"
    );
    let leader_addr = std::fs::read_to_string(&leader_addr_file).unwrap();

    let spawn_worker = || {
        Proc(
            Command::new(BIN)
                .args([
                    "worker",
                    "--connect",
                    leader_addr.trim(),
                    "--model",
                    "oracle",
                    "--analyzer-seed",
                    "1",
                    "--per-tile-ms",
                    "4",
                ])
                .stdout(Stdio::null())
                .stderr(Stdio::null())
                .spawn()
                .expect("spawn worker"),
        )
    };
    let mut workers = [spawn_worker(), spawn_worker()];

    // The kill clocks start when the leader confirms its quorum; killing
    // earlier could strand the run before it ever registered in the
    // ledger, which tests setup, not failover.
    {
        let stdout = leader.0.stdout.take().expect("leader stdout piped");
        let mut lines = std::io::BufReader::new(stdout).lines();
        let ready = loop {
            match lines.next() {
                Some(Ok(l)) if l.starts_with("workers ready") => break true,
                Some(Ok(_)) => continue,
                _ => break false,
            }
        };
        assert!(ready, "chaos seed {seed}: leader exited before quorum");
        // Keep draining in the background so the leader never blocks on a
        // full pipe after we stop reading.
        std::thread::spawn(move || for _ in lines {});
    }

    let t0 = Instant::now();
    let victim = (seed % 2) as usize;
    let mut killed_leader = false;
    let mut killed_worker = false;
    while !(killed_leader && killed_worker) {
        let elapsed = t0.elapsed();
        if !killed_leader && elapsed >= Duration::from_millis(leader_kill_ms) {
            let _ = leader.0.kill(); // SIGKILL; no-op if already done
            killed_leader = true;
        }
        if !killed_worker && elapsed >= Duration::from_millis(worker_kill_ms) {
            let _ = workers[victim].0.kill();
            killed_worker = true;
        }
        std::thread::sleep(Duration::from_millis(1));
    }

    // The standby exits on its own in every outcome: clean leader
    // shutdown (no takeover) or takeover + resume of the ledgered runs.
    assert!(
        wait_for_exit(&mut standby.0, Duration::from_secs(120)),
        "chaos seed {seed}: standby never exited \
         (leader@{leader_kill_ms}ms, worker{victim}@{worker_kill_ms}ms)"
    );

    let standby_tree = out_dir.join("run_1.json");
    let (took_over, tree_path): (bool, PathBuf) = if standby_tree.exists() {
        (true, standby_tree)
    } else {
        (false, leader_out.clone())
    };
    assert!(
        tree_path.exists(),
        "chaos seed {seed}: no tree survived \
         (leader@{leader_kill_ms}ms, worker{victim}@{worker_kill_ms}ms)"
    );
    let got = std::fs::read_to_string(&tree_path).unwrap();
    assert_eq!(
        got, golden,
        "chaos seed {seed}: tree diverged from the unfailed run \
         (leader@{leader_kill_ms}ms, worker{victim}@{worker_kill_ms}ms, \
         took_over={took_over})"
    );

    // Reap the children before removing their tempdir.
    drop(workers);
    drop(leader);
    drop(standby);
    let _ = std::fs::remove_dir_all(&dir);
    took_over
}

/// Write a fault plan file into the scenario dir and return its path.
fn write_plan(dir: &Path, name: &str, json: &str) -> PathBuf {
    let path = dir.join(name);
    std::fs::write(&path, json).unwrap();
    path
}

/// One seeded mixed-fault scenario: three workers — one on a seeded
/// slow link, one behind a windowed partition, one SIGKILLed — plus a
/// leader SIGKILL and a standby whose takeover tree write is hit by
/// probabilistic torn writes. Whatever composes, the surviving tree
/// must be byte-identical to the unfailed run. Returns whether the
/// standby took over.
fn run_mixed_scenario(seed: u64, golden: &str) -> bool {
    let dir = std::env::temp_dir().join(format!(
        "pyramidai_chaosmix_{}_{}",
        std::process::id(),
        seed
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let standby_addr_file = dir.join("standby.addr");
    let leader_addr_file = dir.join("leader.addr");
    let leader_out = dir.join("leader_tree.json");
    let out_dir = dir.join("trees");

    // Seeded schedule: kill clocks (measured from worker quorum) plus
    // fault-plan windows (measured from each faulted process's start).
    let mut rng = Pcg32::new(0x0C4A_F417 ^ seed);
    let leader_kill_ms = rng.usize_range(40, 160) as u64;
    let worker_kill_ms = rng.usize_range(40, 160) as u64;
    let delay_min_us = rng.usize_range(200, 800) as u64;
    let delay_max_us = delay_min_us + rng.usize_range(500, 1500) as u64;
    let partition_after_ms = rng.usize_range(150, 400) as u64;
    let partition_dur_ms = rng.usize_range(60, 200) as u64;

    // The standby's only disk write is the resumed tree; torn writes at
    // p=0.6 force its retry loop to re-draw until a write survives.
    let standby_plan = write_plan(
        &dir,
        "standby_faults.json",
        &format!(
            r#"{{"seed": {seed}, "rules": [
                {{"kind": "disk.torn_write", "p": 0.6, "path": "run_1.json"}}
            ]}}"#
        ),
    );
    // Worker 0: every wire op crawls (slow link, whole run).
    let slow_plan = write_plan(
        &dir,
        "w0_faults.json",
        &format!(
            r#"{{"seed": {seed}, "rules": [
                {{"kind": "net.delay", "p": 1.0,
                  "min_us": {delay_min_us}, "max_us": {delay_max_us}}}
            ]}}"#
        ),
    );
    // Worker 1: a gray window in which every wire op fails, then heals.
    let partition_plan = write_plan(
        &dir,
        "w1_faults.json",
        &format!(
            r#"{{"seed": {seed}, "rules": [
                {{"kind": "net.partition", "p": 1.0,
                  "after_ms": {partition_after_ms}, "dur_ms": {partition_dur_ms}}}
            ]}}"#
        ),
    );

    let mut standby = Proc(
        Command::new(BIN)
            .args([
                "leader",
                "--standby",
                "--listen",
                "127.0.0.1:0",
                "--addr-file",
                standby_addr_file.to_str().unwrap(),
                "--out-dir",
                out_dir.to_str().unwrap(),
                "--model",
                "oracle",
                "--analyzer-seed",
                "1",
                "--heartbeat-ms",
                "15",
                "--faults",
                standby_plan.to_str().unwrap(),
            ])
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn standby"),
    );
    assert!(
        wait_for_file(&standby_addr_file, Duration::from_secs(30)),
        "mixed seed {seed}: standby never published its address"
    );
    let standby_addr = std::fs::read_to_string(&standby_addr_file).unwrap();

    let mut leader = Proc(
        Command::new(BIN)
            .args([
                "leader",
                "--slide-seed",
                &SLIDE_SEED.to_string(),
                "--kind",
                "large_tumor",
                "--tiles-x",
                &TILES_X.to_string(),
                "--tiles-y",
                &TILES_Y.to_string(),
                "--workers",
                "0",
                "--wait-workers",
                "3",
                "--chunk",
                "4",
                "--standby-addr",
                standby_addr.trim(),
                "--addr-file",
                leader_addr_file.to_str().unwrap(),
                "--out",
                leader_out.to_str().unwrap(),
                "--model",
                "oracle",
                "--analyzer-seed",
                "1",
                "--heartbeat-ms",
                "15",
            ])
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn leader"),
    );
    assert!(
        wait_for_file(&leader_addr_file, Duration::from_secs(30)),
        "mixed seed {seed}: leader never published its address"
    );
    let leader_addr = std::fs::read_to_string(&leader_addr_file).unwrap();

    let spawn_worker = |plan: Option<&Path>| {
        let mut cmd = Command::new(BIN);
        cmd.args([
            "worker",
            "--connect",
            leader_addr.trim(),
            "--model",
            "oracle",
            "--analyzer-seed",
            "1",
            "--per-tile-ms",
            "4",
        ]);
        if let Some(p) = plan {
            cmd.args(["--faults", p.to_str().unwrap()]);
        }
        Proc(
            cmd.stdout(Stdio::null())
                .stderr(Stdio::null())
                .spawn()
                .expect("spawn worker"),
        )
    };
    // w0 crawls, w1 gets partitioned, w2 is the kill victim.
    let w0 = spawn_worker(Some(slow_plan.as_path()));
    let w1 = spawn_worker(Some(partition_plan.as_path()));
    let mut w2 = spawn_worker(None);

    {
        let stdout = leader.0.stdout.take().expect("leader stdout piped");
        let mut lines = std::io::BufReader::new(stdout).lines();
        let ready = loop {
            match lines.next() {
                Some(Ok(l)) if l.starts_with("workers ready") => break true,
                Some(Ok(_)) => continue,
                _ => break false,
            }
        };
        assert!(ready, "mixed seed {seed}: leader exited before quorum");
        std::thread::spawn(move || for _ in lines {});
    }

    let t0 = Instant::now();
    let mut killed_leader = false;
    let mut killed_worker = false;
    while !(killed_leader && killed_worker) {
        let elapsed = t0.elapsed();
        if !killed_leader && elapsed >= Duration::from_millis(leader_kill_ms) {
            let _ = leader.0.kill();
            killed_leader = true;
        }
        if !killed_worker && elapsed >= Duration::from_millis(worker_kill_ms) {
            let _ = w2.0.kill();
            killed_worker = true;
        }
        std::thread::sleep(Duration::from_millis(1));
    }

    assert!(
        wait_for_exit(&mut standby.0, Duration::from_secs(120)),
        "mixed seed {seed}: standby never exited (leader@{leader_kill_ms}ms, \
         w2@{worker_kill_ms}ms, partition@{partition_after_ms}+{partition_dur_ms}ms, \
         delay {delay_min_us}-{delay_max_us}us)"
    );

    let standby_tree = out_dir.join("run_1.json");
    let (took_over, tree_path): (bool, PathBuf) = if standby_tree.exists() {
        (true, standby_tree)
    } else {
        (false, leader_out.clone())
    };
    assert!(
        tree_path.exists(),
        "mixed seed {seed}: no tree survived (leader@{leader_kill_ms}ms, \
         w2@{worker_kill_ms}ms, partition@{partition_after_ms}+{partition_dur_ms}ms)"
    );
    let got = std::fs::read_to_string(&tree_path).unwrap();
    assert_eq!(
        got, golden,
        "mixed seed {seed}: tree diverged from the unfailed run \
         (leader@{leader_kill_ms}ms, w2@{worker_kill_ms}ms, \
         partition@{partition_after_ms}+{partition_dur_ms}ms, \
         delay {delay_min_us}-{delay_max_us}us, took_over={took_over})"
    );

    drop(w2);
    drop(w1);
    drop(w0);
    drop(leader);
    drop(standby);
    let _ = std::fs::remove_dir_all(&dir);
    took_over
}

#[test]
fn seeded_mixed_fault_schedules_never_change_the_tree() {
    let golden = golden_tree_json();
    let seeds: Vec<u64> = match std::env::var("CHAOS_MIXED_SEED") {
        Ok(s) => vec![s.parse().expect("CHAOS_MIXED_SEED must be an integer")],
        Err(_) => (1..=4).collect(),
    };
    let mut takeovers = 0usize;
    for &seed in &seeds {
        eprintln!("mixed chaos seed {seed}: starting");
        if run_mixed_scenario(seed, &golden) {
            takeovers += 1;
        }
        eprintln!("mixed chaos seed {seed}: ok");
    }
    // Leader kills land 40-160 ms into a run that takes hundreds of ms;
    // the full default schedule must see at least one takeover.
    if seeds.len() >= 4 {
        assert!(
            takeovers > 0,
            "no mixed seed exercised a standby takeover — kill windows too late?"
        );
    }
}

#[test]
fn seeded_kill_schedules_never_change_the_tree() {
    let golden = golden_tree_json();
    let seeds: Vec<u64> = match std::env::var("CHAOS_SEED") {
        Ok(s) => vec![s.parse().expect("CHAOS_SEED must be an integer")],
        Err(_) => (1..=8).collect(),
    };
    let mut takeovers = 0usize;
    for &seed in &seeds {
        eprintln!("chaos seed {seed}: starting");
        if run_scenario(seed, &golden) {
            takeovers += 1;
        }
        eprintln!("chaos seed {seed}: ok");
    }
    // With kill times of 20–150 ms against a run slowed to ~4 ms/tile,
    // the full default schedule must exercise the takeover path at least
    // once; a single CHAOS_SEED replay may legitimately miss it.
    if seeds.len() >= 8 {
        assert!(
            takeovers > 0,
            "no seed exercised a standby takeover — kill windows too late?"
        );
    }
}
