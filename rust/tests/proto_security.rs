//! Adversarial tests of the binary frame format v2 (`cluster::framev2`),
//! mirroring `http_security`: the decoder faces truncations at every
//! byte boundary, forged counts, bad magic/version/tag bytes, bit flips
//! and raw socket garbage — and must always answer with a typed
//! [`FrameError`] (or an `anyhow` error at the socket layer), never a
//! panic, never an unbounded allocation, and a live cluster must keep
//! serving chunks afterwards.

use std::io::Write;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use std::collections::{HashMap, HashSet};

use pyramidai::cluster::framev2::{
    decode_body, encode_body, FrameError, MAGIC, TAG_CHUNK_DONE, TAG_CHUNK_MOVED, TAG_LEDGER,
    VERSION,
};
use pyramidai::cluster::ledger::pack_key;
use pyramidai::cluster::proto::{ChunkTask, Msg};
use pyramidai::cluster::{ClusterExec, ClusterExecConfig, LedgerOp, LedgerRecord, LedgerState};
use pyramidai::model::oracle::OracleAnalyzer;
use pyramidai::model::Analyzer;
use pyramidai::slide::pyramid::Slide;
use pyramidai::slide::tile::TileId;
use pyramidai::synth::slide_gen::{SlideKind, SlideSpec};
use pyramidai::util::prng::Pcg32;
use pyramidai::util::quickcheck::forall_explain;

fn sample_chunk(key: u64) -> ChunkTask {
    ChunkTask {
        key,
        spec: SlideSpec::new("sec", 42, 16, 8, 3, 64, SlideKind::LargeTumor),
        level: 2,
        tiles: vec![TileId::new(2, 0, 0), TileId::new(2, 1, 0), TileId::new(2, 2, 1)],
        exclude: vec![1, 3],
        trace: 77,
    }
}

/// Every hot message, encoded to a valid v2 body.
fn valid_bodies() -> Vec<Vec<u8>> {
    let msgs = [
        Msg::Chunk(sample_chunk(1)),
        Msg::ChunkDone {
            key: 2,
            worker: 1,
            probs: vec![0.25, 0.5, 0.75],
            trace: 9,
        },
        Msg::ChunkMoved {
            key: 3,
            worker: 0,
            trace: 10,
        },
        Msg::ChunkBatch(vec![sample_chunk(4), sample_chunk(5)]),
        // Replicated-ledger records (§15): every op variant rides the same
        // wire, so the truncation/bit-flip sweeps below cover them too.
        Msg::Ledger(LedgerRecord {
            seq: 1,
            op: LedgerOp::RunStart {
                run: 1,
                spec: SlideSpec::new("sec", 42, 16, 8, 3, 64, SlideKind::LargeTumor),
                thresholds: vec![0.5, 0.35, 0.35],
                initial: vec![TileId::new(2, 0, 0), TileId::new(2, 1, 1)],
                chunk: 4,
            },
        }),
        Msg::Ledger(LedgerRecord {
            seq: 2,
            op: LedgerOp::Append(sample_chunk(pack_key(1, 6))),
        }),
        Msg::Ledger(LedgerRecord {
            seq: 3,
            op: LedgerOp::Ack {
                key: pack_key(1, 6),
                probs: vec![0.1, 0.9],
            },
        }),
        Msg::Ledger(LedgerRecord {
            seq: 4,
            op: LedgerOp::Lost {
                key: pack_key(1, 6),
            },
        }),
        Msg::Ledger(LedgerRecord {
            seq: 5,
            op: LedgerOp::RunDone { run: 1 },
        }),
    ];
    msgs.iter()
        .map(|m| {
            let mut b = Vec::new();
            assert!(encode_body(m, &mut b), "hot message must encode");
            b
        })
        .collect()
}

#[test]
fn every_truncation_is_a_typed_error() {
    // Any strict prefix of a valid body must decode to an error — the
    // decoder consumes exactly the full body, so a cut at any boundary
    // lands mid-field (Truncated) or invalidates a count (BadCount).
    for body in valid_bodies() {
        for cut in 0..body.len() {
            match decode_body(&body[..cut]) {
                Err(
                    FrameError::Truncated { .. }
                    | FrameError::BadCount { .. }
                    | FrameError::BadUtf8,
                ) => {}
                Err(other) => panic!("cut at {cut}/{}: unexpected error {other}", body.len()),
                Ok(m) => panic!("cut at {cut}/{} decoded as {m:?}", body.len()),
            }
        }
    }
}

#[test]
fn forged_counts_do_not_allocate() {
    // A ChunkDone claiming u32::MAX probabilities with an empty payload:
    // the count guard must reject it before `Vec::with_capacity` ever
    // sees the number (this test OOMs or hangs if it does not).
    let mut body = vec![MAGIC, VERSION, TAG_CHUNK_DONE];
    body.extend_from_slice(&1u64.to_le_bytes()); // key
    body.extend_from_slice(&0u64.to_le_bytes()); // worker
    body.extend_from_slice(&0u64.to_le_bytes()); // trace
    body.extend_from_slice(&u32::MAX.to_le_bytes()); // probs count
    match decode_body(&body) {
        Err(FrameError::BadCount {
            what: "done.probs",
            count,
            remaining: 0,
        }) => assert_eq!(count, u32::MAX as usize),
        other => panic!("unexpected {other:?}"),
    }

    // Same for a batch header: count * CHUNK_MIN_BYTES overflows usize on
    // 32-bit and vastly exceeds the payload on 64-bit — both must land in
    // BadCount via the checked multiply.
    let mut body = vec![MAGIC, VERSION, pyramidai::cluster::framev2::TAG_CHUNK_BATCH];
    body.extend_from_slice(&u32::MAX.to_le_bytes());
    assert!(matches!(
        decode_body(&body),
        Err(FrameError::BadCount { what: "batch.chunks", .. })
    ));
}

#[test]
fn bad_magic_version_tag_kind_and_trailing_bytes() {
    // Magic: anything that is not 0xB5 (JSON bodies never reach
    // decode_body — `Msg::read_from` dispatches on the first byte).
    assert_eq!(decode_body(&[0x00, VERSION, 1]), Err(FrameError::BadMagic(0x00)));
    assert_eq!(decode_body(&[b'{', VERSION, 1]), Err(FrameError::BadMagic(b'{')));

    // Version skew: a frame from a hypothetical v3 peer must be refused,
    // not half-parsed.
    assert_eq!(decode_body(&[MAGIC, 3, TAG_CHUNK_MOVED]), Err(FrameError::BadVersion(3)));
    assert_eq!(decode_body(&[MAGIC, 0, 1]), Err(FrameError::BadVersion(0)));

    // Unknown tag.
    assert_eq!(decode_body(&[MAGIC, VERSION, 99]), Err(FrameError::BadTag(99)));

    // Unknown slide-kind code inside a chunk: corrupt the kind byte of a
    // valid Chunk body (offset: magic+ver+tag=3, key 8, trace 8, level 4,
    // seed 8, 4×u32 geometry = 16 → kind at 3+8+8+4+8+16 = 47).
    let mut body = Vec::new();
    assert!(encode_body(&Msg::Chunk(sample_chunk(1)), &mut body));
    body[47] = 9;
    assert_eq!(decode_body(&body), Err(FrameError::BadKind(9)));

    // Non-UTF-8 slide id: the id "sec" starts right after kind + id_len.
    let mut body = Vec::new();
    assert!(encode_body(&Msg::Chunk(sample_chunk(1)), &mut body));
    body[50] = 0xFF;
    assert_eq!(decode_body(&body), Err(FrameError::BadUtf8));

    // Trailing bytes after a complete message.
    let mut body = Vec::new();
    assert!(encode_body(
        &Msg::ChunkMoved {
            key: 1,
            worker: 2,
            trace: 3
        },
        &mut body
    ));
    body.push(0xAA);
    assert_eq!(decode_body(&body), Err(FrameError::TrailingBytes(1)));
}

#[test]
fn single_bit_flips_never_panic() {
    // Exhaustive single-bit corruption of every valid hot-message body.
    // Many flips decode fine (a different key, a different probability);
    // the invariant is that none of them panic or hang — every outcome
    // is Ok(_) or a typed FrameError.
    for body in valid_bodies() {
        for i in 0..body.len() {
            for bit in 0..8 {
                let mut fuzzed = body.clone();
                fuzzed[i] ^= 1 << bit;
                let _ = decode_body(&fuzzed);
            }
        }
    }
}

#[test]
fn live_cluster_survives_socket_garbage() {
    let analyzer: Arc<dyn Analyzer> = Arc::new(OracleAnalyzer::new(1));
    let exec = ClusterExec::start(
        Arc::clone(&analyzer),
        &ClusterExecConfig {
            workers: 1,
            steal: false,
            seed: 3,
            ..ClusterExecConfig::default()
        },
    )
    .unwrap();
    let addr = exec.leader_addr();

    // Hostile frames at the leader's control port: raw noise, an
    // oversized length prefix, a length prefix with no body (early
    // close), a v2 frame with a bad tag, and a forged-count ChunkDone.
    let mut forged = vec![MAGIC, VERSION, TAG_CHUNK_DONE];
    forged.extend_from_slice(&[0u8; 24]);
    forged.extend_from_slice(&u32::MAX.to_le_bytes());
    let payloads: Vec<Vec<u8>> = vec![
        b"not a frame at all".to_vec(),
        u32::MAX.to_le_bytes().to_vec(),
        {
            let mut v = 100u32.to_le_bytes().to_vec();
            v.extend_from_slice(b"abc"); // promises 100 bytes, sends 3
            v
        },
        {
            let mut v = 3u32.to_le_bytes().to_vec();
            v.extend_from_slice(&[MAGIC, VERSION, 200]);
            v
        },
        {
            let mut v = (forged.len() as u32).to_le_bytes().to_vec();
            v.extend_from_slice(&forged);
            v
        },
    ];
    for p in &payloads {
        let mut s = TcpStream::connect(&addr).unwrap();
        let _ = s.write_all(p);
        let _ = s.flush();
        // Dropping the stream closes it — the truncated-body case makes
        // the leader's read_exact fail fast instead of waiting.
    }

    // The cluster still serves real work after all of that.
    let sp = SlideSpec::new("sec_live", 7, 16, 8, 3, 64, SlideKind::LargeTumor);
    let slide = Slide::from_spec(sp.clone());
    let tiles = slide.level_tile_ids(2);
    let want = analyzer.analyze(&slide, 2, &tiles);
    exec.submit(1, &sp, 2, tiles).unwrap();
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    let got = loop {
        if let Some((key, probs)) = exec.try_result() {
            assert_eq!(key, 1);
            break probs;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "cluster wedged by garbage frames"
        );
        std::thread::sleep(Duration::from_millis(2));
    };
    assert_eq!(got, want);
    exec.shutdown();
}

/// Corrupt ledger op byte: refused as a typed error, like any bad tag.
#[test]
fn unknown_ledger_op_is_a_typed_error() {
    let mut body = vec![MAGIC, VERSION, TAG_LEDGER];
    body.extend_from_slice(&7u64.to_le_bytes()); // seq
    body.push(99); // no such op
    assert_eq!(decode_body(&body), Err(FrameError::BadTag(99)));
}

/// What the leader knows at the moment it emits a record — the oracle the
/// standby's replay is checked against.
#[derive(Debug, Default)]
struct LiveRun {
    pending: HashSet<u64>,
    done: HashMap<u64, Vec<f32>>,
    blind_acks: usize,
    complete: bool,
    appended: HashSet<u64>,
}

/// Seeded leader simulation: an arbitrary interleaving of
/// start/append/ack/lost/truncate ops across up to three concurrent runs,
/// with strictly increasing sequence numbers, plus the matching live
/// state at every step.
fn gen_schedule(rng: &mut Pcg32) -> (Vec<LedgerRecord>, HashMap<u64, LiveRun>) {
    let runs: Vec<u64> = (1..=(rng.usize_range(1, 4) as u64)).collect();
    let mut live: HashMap<u64, LiveRun> = HashMap::new();
    let mut recs = Vec::new();
    let mut seq = 0u64;
    let mut next_req: HashMap<u64, u64> = HashMap::new();
    let steps = rng.usize_range(5, 60);
    for _ in 0..steps {
        let run = *rng.choose(&runs).unwrap();
        let started = live.contains_key(&run);
        let complete = started && live[&run].complete;
        if complete {
            continue;
        }
        seq += 1;
        let op = if !started {
            live.insert(run, LiveRun::default());
            LedgerOp::RunStart {
                run,
                spec: SlideSpec::new(
                    format!("prop_{run}"),
                    run,
                    16,
                    8,
                    3,
                    64,
                    SlideKind::LargeTumor,
                ),
                thresholds: vec![0.5, 0.35, 0.35],
                initial: vec![TileId::new(2, 0, 0)],
                chunk: 4,
            }
        } else {
            let state = live.get_mut(&run).unwrap();
            let outstanding: Vec<u64> = state.pending.iter().copied().collect();
            match rng.usize_range(0, 10) {
                0..=3 => {
                    let req = next_req.entry(run).or_insert(0);
                    let key = pack_key(run, *req);
                    *req += 1;
                    state.pending.insert(key);
                    state.appended.insert(key);
                    LedgerOp::Append(sample_chunk(key))
                }
                4..=6 if !outstanding.is_empty() => {
                    let key = outstanding[rng.usize_range(0, outstanding.len())];
                    let probs = vec![rng.f32(), rng.f32()];
                    state.pending.remove(&key);
                    state.done.insert(key, probs.clone());
                    LedgerOp::Ack { key, probs }
                }
                7 if !outstanding.is_empty() => {
                    let key = outstanding[rng.usize_range(0, outstanding.len())];
                    state.pending.remove(&key);
                    LedgerOp::Lost { key }
                }
                8 => {
                    // Ack for a chunk whose Append the leader never dealt
                    // under this run id (e.g. a pre-failover orphan): the
                    // replay must park it as a blind ack, not invent work.
                    state.blind_acks += 1;
                    LedgerOp::Ack {
                        key: pack_key(run, 1_000_000),
                        probs: vec![0.5],
                    }
                }
                _ => {
                    // Truncation: RunDone clears the run's recovery state.
                    state.pending.clear();
                    state.done.clear();
                    state.blind_acks = 0;
                    state.complete = true;
                    LedgerOp::RunDone { run }
                }
            }
        };
        recs.push(LedgerRecord { seq, op });
    }
    (recs, live)
}

/// Encode one record to a v2 body and decode it back, as the repl wire
/// would.
fn wire_roundtrip(rec: &LedgerRecord) -> LedgerRecord {
    let mut body = Vec::new();
    assert!(encode_body(&Msg::Ledger(rec.clone()), &mut body));
    match decode_body(&body) {
        Ok(Msg::Ledger(back)) => back,
        other => panic!("ledger frame decoded as {other:?}"),
    }
}

fn check_against_live(state: &LedgerState, live: &HashMap<u64, LiveRun>) -> Result<(), String> {
    for (run, l) in live {
        let r = state
            .runs
            .get(run)
            .ok_or_else(|| format!("run {run} missing after replay"))?;
        if r.complete != l.complete {
            return Err(format!("run {run}: complete {} vs live {}", r.complete, l.complete));
        }
        let pending: HashSet<u64> = r.pending.keys().copied().collect();
        if pending != l.pending {
            return Err(format!("run {run}: pending {pending:?} vs live {:?}", l.pending));
        }
        let done: HashMap<u64, Vec<f32>> =
            r.done.iter().map(|(k, (_, p))| (*k, p.clone())).collect();
        if done != l.done {
            return Err(format!("run {run}: done sets diverge"));
        }
        if r.blind_acks.len() != l.blind_acks {
            return Err(format!(
                "run {run}: {} blind acks vs live {}",
                r.blind_acks.len(),
                l.blind_acks
            ));
        }
    }
    Ok(())
}

#[test]
fn replayed_ledger_matches_live_state_even_with_duplicate_delivery() {
    // Property: for any interleaving of ops across concurrent runs, a
    // standby that replays the wire-roundtripped records — including
    // reconnect-style duplicate re-delivery of an arbitrary suffix —
    // reconstructs exactly the pending/done/blind/complete sets the
    // leader's live ledger held.
    forall_explain(
        0x1ED6E4,
        150,
        |rng| {
            let (recs, live) = gen_schedule(rng);
            // Reconnect replay: re-deliver a suffix of what was already
            // streamed, possibly several times.
            let mut delivered = Vec::new();
            for (i, rec) in recs.iter().enumerate() {
                delivered.push(rec.clone());
                if rng.bool(0.1) && i > 0 {
                    let from = rng.usize_range(0, i);
                    delivered.extend(recs[from..=i].iter().cloned());
                }
            }
            (delivered, recs.len(), live)
        },
        |(delivered, n_unique, live)| {
            let mut state = LedgerState::new();
            for rec in delivered {
                state.apply(&wire_roundtrip(rec));
            }
            check_against_live(&state, live)?;
            let dups = (delivered.len() - n_unique) as u64;
            if state.duplicates != dups {
                return Err(format!(
                    "{} duplicates counted, {dups} injected",
                    state.duplicates
                ));
            }
            if state.orphaned != 0 {
                return Err(format!("{} orphaned records", state.orphaned));
            }
            Ok(())
        },
    );
}

#[test]
fn ledger_replay_tolerates_arbitrary_gaps() {
    // Dropped records (the repl link gives up after bounded retries) must
    // never panic or corrupt the state: whatever survives is a subset of
    // what the live leader knew, and completed runs stay recognizable
    // whenever their RunDone made it through.
    forall_explain(
        0x6A95,
        150,
        |rng| {
            let (recs, live) = gen_schedule(rng);
            let kept: Vec<LedgerRecord> =
                recs.into_iter().filter(|_| !rng.bool(0.3)).collect();
            (kept, live)
        },
        |(kept, live)| {
            let mut state = LedgerState::new();
            for rec in kept {
                state.apply(&wire_roundtrip(rec));
            }
            for (run, r) in &state.runs {
                let l = live
                    .get(run)
                    .ok_or_else(|| format!("replay invented run {run}"))?;
                for key in r.pending.keys() {
                    if !l.appended.contains(key) {
                        return Err(format!("run {run}: pending {key} never appended live"));
                    }
                }
                for (key, (_, probs)) in &r.done {
                    match l.done.get(key) {
                        Some(p) if p == probs => {}
                        Some(_) => return Err(format!("run {run}: done {key} probs diverge")),
                        None => {
                            return Err(format!("run {run}: done {key} not done live"))
                        }
                    }
                }
            }
            for run in state.incomplete_runs() {
                if !live.contains_key(&run) {
                    return Err(format!("incomplete run {run} never started live"));
                }
            }
            Ok(())
        },
    );
}
