//! Adversarial tests of the binary frame format v2 (`cluster::framev2`),
//! mirroring `http_security`: the decoder faces truncations at every
//! byte boundary, forged counts, bad magic/version/tag bytes, bit flips
//! and raw socket garbage — and must always answer with a typed
//! [`FrameError`] (or an `anyhow` error at the socket layer), never a
//! panic, never an unbounded allocation, and a live cluster must keep
//! serving chunks afterwards.

use std::io::Write;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use pyramidai::cluster::framev2::{
    decode_body, encode_body, FrameError, MAGIC, TAG_CHUNK_DONE, TAG_CHUNK_MOVED, VERSION,
};
use pyramidai::cluster::proto::{ChunkTask, Msg};
use pyramidai::cluster::{ClusterExec, ClusterExecConfig};
use pyramidai::model::oracle::OracleAnalyzer;
use pyramidai::model::Analyzer;
use pyramidai::slide::pyramid::Slide;
use pyramidai::slide::tile::TileId;
use pyramidai::synth::slide_gen::{SlideKind, SlideSpec};

fn sample_chunk(key: u64) -> ChunkTask {
    ChunkTask {
        key,
        spec: SlideSpec::new("sec", 42, 16, 8, 3, 64, SlideKind::LargeTumor),
        level: 2,
        tiles: vec![TileId::new(2, 0, 0), TileId::new(2, 1, 0), TileId::new(2, 2, 1)],
        exclude: vec![1, 3],
        trace: 77,
    }
}

/// Every hot message, encoded to a valid v2 body.
fn valid_bodies() -> Vec<Vec<u8>> {
    let msgs = [
        Msg::Chunk(sample_chunk(1)),
        Msg::ChunkDone {
            key: 2,
            worker: 1,
            probs: vec![0.25, 0.5, 0.75],
            trace: 9,
        },
        Msg::ChunkMoved {
            key: 3,
            worker: 0,
            trace: 10,
        },
        Msg::ChunkBatch(vec![sample_chunk(4), sample_chunk(5)]),
    ];
    msgs.iter()
        .map(|m| {
            let mut b = Vec::new();
            assert!(encode_body(m, &mut b), "hot message must encode");
            b
        })
        .collect()
}

#[test]
fn every_truncation_is_a_typed_error() {
    // Any strict prefix of a valid body must decode to an error — the
    // decoder consumes exactly the full body, so a cut at any boundary
    // lands mid-field (Truncated) or invalidates a count (BadCount).
    for body in valid_bodies() {
        for cut in 0..body.len() {
            match decode_body(&body[..cut]) {
                Err(
                    FrameError::Truncated { .. }
                    | FrameError::BadCount { .. }
                    | FrameError::BadUtf8,
                ) => {}
                Err(other) => panic!("cut at {cut}/{}: unexpected error {other}", body.len()),
                Ok(m) => panic!("cut at {cut}/{} decoded as {m:?}", body.len()),
            }
        }
    }
}

#[test]
fn forged_counts_do_not_allocate() {
    // A ChunkDone claiming u32::MAX probabilities with an empty payload:
    // the count guard must reject it before `Vec::with_capacity` ever
    // sees the number (this test OOMs or hangs if it does not).
    let mut body = vec![MAGIC, VERSION, TAG_CHUNK_DONE];
    body.extend_from_slice(&1u64.to_le_bytes()); // key
    body.extend_from_slice(&0u64.to_le_bytes()); // worker
    body.extend_from_slice(&0u64.to_le_bytes()); // trace
    body.extend_from_slice(&u32::MAX.to_le_bytes()); // probs count
    match decode_body(&body) {
        Err(FrameError::BadCount {
            what: "done.probs",
            count,
            remaining: 0,
        }) => assert_eq!(count, u32::MAX as usize),
        other => panic!("unexpected {other:?}"),
    }

    // Same for a batch header: count * CHUNK_MIN_BYTES overflows usize on
    // 32-bit and vastly exceeds the payload on 64-bit — both must land in
    // BadCount via the checked multiply.
    let mut body = vec![MAGIC, VERSION, pyramidai::cluster::framev2::TAG_CHUNK_BATCH];
    body.extend_from_slice(&u32::MAX.to_le_bytes());
    assert!(matches!(
        decode_body(&body),
        Err(FrameError::BadCount { what: "batch.chunks", .. })
    ));
}

#[test]
fn bad_magic_version_tag_kind_and_trailing_bytes() {
    // Magic: anything that is not 0xB5 (JSON bodies never reach
    // decode_body — `Msg::read_from` dispatches on the first byte).
    assert_eq!(decode_body(&[0x00, VERSION, 1]), Err(FrameError::BadMagic(0x00)));
    assert_eq!(decode_body(&[b'{', VERSION, 1]), Err(FrameError::BadMagic(b'{')));

    // Version skew: a frame from a hypothetical v3 peer must be refused,
    // not half-parsed.
    assert_eq!(decode_body(&[MAGIC, 3, TAG_CHUNK_MOVED]), Err(FrameError::BadVersion(3)));
    assert_eq!(decode_body(&[MAGIC, 0, 1]), Err(FrameError::BadVersion(0)));

    // Unknown tag.
    assert_eq!(decode_body(&[MAGIC, VERSION, 99]), Err(FrameError::BadTag(99)));

    // Unknown slide-kind code inside a chunk: corrupt the kind byte of a
    // valid Chunk body (offset: magic+ver+tag=3, key 8, trace 8, level 4,
    // seed 8, 4×u32 geometry = 16 → kind at 3+8+8+4+8+16 = 47).
    let mut body = Vec::new();
    assert!(encode_body(&Msg::Chunk(sample_chunk(1)), &mut body));
    body[47] = 9;
    assert_eq!(decode_body(&body), Err(FrameError::BadKind(9)));

    // Non-UTF-8 slide id: the id "sec" starts right after kind + id_len.
    let mut body = Vec::new();
    assert!(encode_body(&Msg::Chunk(sample_chunk(1)), &mut body));
    body[50] = 0xFF;
    assert_eq!(decode_body(&body), Err(FrameError::BadUtf8));

    // Trailing bytes after a complete message.
    let mut body = Vec::new();
    assert!(encode_body(
        &Msg::ChunkMoved {
            key: 1,
            worker: 2,
            trace: 3
        },
        &mut body
    ));
    body.push(0xAA);
    assert_eq!(decode_body(&body), Err(FrameError::TrailingBytes(1)));
}

#[test]
fn single_bit_flips_never_panic() {
    // Exhaustive single-bit corruption of every valid hot-message body.
    // Many flips decode fine (a different key, a different probability);
    // the invariant is that none of them panic or hang — every outcome
    // is Ok(_) or a typed FrameError.
    for body in valid_bodies() {
        for i in 0..body.len() {
            for bit in 0..8 {
                let mut fuzzed = body.clone();
                fuzzed[i] ^= 1 << bit;
                let _ = decode_body(&fuzzed);
            }
        }
    }
}

#[test]
fn live_cluster_survives_socket_garbage() {
    let analyzer: Arc<dyn Analyzer> = Arc::new(OracleAnalyzer::new(1));
    let exec = ClusterExec::start(
        Arc::clone(&analyzer),
        &ClusterExecConfig {
            workers: 1,
            steal: false,
            seed: 3,
            ..ClusterExecConfig::default()
        },
    )
    .unwrap();
    let addr = exec.leader_addr();

    // Hostile frames at the leader's control port: raw noise, an
    // oversized length prefix, a length prefix with no body (early
    // close), a v2 frame with a bad tag, and a forged-count ChunkDone.
    let mut forged = vec![MAGIC, VERSION, TAG_CHUNK_DONE];
    forged.extend_from_slice(&[0u8; 24]);
    forged.extend_from_slice(&u32::MAX.to_le_bytes());
    let payloads: Vec<Vec<u8>> = vec![
        b"not a frame at all".to_vec(),
        u32::MAX.to_le_bytes().to_vec(),
        {
            let mut v = 100u32.to_le_bytes().to_vec();
            v.extend_from_slice(b"abc"); // promises 100 bytes, sends 3
            v
        },
        {
            let mut v = 3u32.to_le_bytes().to_vec();
            v.extend_from_slice(&[MAGIC, VERSION, 200]);
            v
        },
        {
            let mut v = (forged.len() as u32).to_le_bytes().to_vec();
            v.extend_from_slice(&forged);
            v
        },
    ];
    for p in &payloads {
        let mut s = TcpStream::connect(&addr).unwrap();
        let _ = s.write_all(p);
        let _ = s.flush();
        // Dropping the stream closes it — the truncated-body case makes
        // the leader's read_exact fail fast instead of waiting.
    }

    // The cluster still serves real work after all of that.
    let sp = SlideSpec::new("sec_live", 7, 16, 8, 3, 64, SlideKind::LargeTumor);
    let slide = Slide::from_spec(sp.clone());
    let tiles = slide.level_tile_ids(2);
    let want = analyzer.analyze(&slide, 2, &tiles);
    exec.submit(1, &sp, 2, tiles).unwrap();
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    let got = loop {
        if let Some((key, probs)) = exec.try_result() {
            assert_eq!(key, 1);
            break probs;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "cluster wedged by garbage frames"
        );
        std::thread::sleep(Duration::from_millis(2));
    };
    assert_eq!(got, want);
    exec.shutdown();
}
