//! End-to-end integration across the three layers: rust-generated slides →
//! PJRT-compiled TinyInception (Pallas kernels inside) → pyramidal driver.
//!
//! These tests are gated on `artifacts/` (run `make artifacts` first); they
//! are the proof that the python-trained model transfers to rust-generated
//! tiles, i.e. that the two texture implementations really match.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use pyramidai::model::pjrt::PjrtAnalyzer;
use pyramidai::model::Analyzer;
use pyramidai::pyramid::driver::{run_pyramidal, run_reference};
use pyramidai::pyramid::tree::Thresholds;
use pyramidai::runtime::Registry;
use pyramidai::slide::pyramid::Slide;
use pyramidai::synth::slide_gen::{SlideKind, SlideSpec};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("meta.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts/ not built");
                return;
            }
        }
    };
}

fn registry() -> Option<Arc<Registry>> {
    use std::sync::OnceLock;
    static REG: OnceLock<Option<Arc<Registry>>> = OnceLock::new();
    REG.get_or_init(|| {
        artifacts_dir().map(|d| Arc::new(Registry::load_dir(&d).expect("load registry")))
    })
    .clone()
}

#[test]
fn batch_sizes_agree_on_same_tiles() {
    let _ = require_artifacts!();
    let reg = registry().unwrap();
    let analyzer = PjrtAnalyzer::from_registry(reg);
    let slide = Slide::from_spec(SlideSpec::new(
        "int_b",
        505,
        16,
        8,
        3,
        64,
        SlideKind::LargeTumor,
    ));
    let tiles = slide.level_tile_ids(1);
    // Same tiles through different batching plans must give identical
    // probabilities (padding must not leak).
    let one_by_one: Vec<f32> = tiles
        .iter()
        .flat_map(|&t| analyzer.analyze(&slide, 1, &[t]))
        .collect();
    let batched = analyzer.analyze(&slide, 1, &tiles);
    assert_eq!(one_by_one.len(), batched.len());
    for (a, b) in one_by_one.iter().zip(&batched) {
        assert!((a - b).abs() < 1e-5, "batching changed prob: {a} vs {b}");
    }
}

#[test]
fn model_transfers_to_rust_tiles() {
    let _ = require_artifacts!();
    let reg = registry().unwrap();
    let analyzer = PjrtAnalyzer::from_registry(reg);
    // Accuracy of the python-trained model on rust-generated tiles, over
    // clear-cut cases (background-free, decisively tumor or decisively
    // normal): must be well above chance at every level.
    let slides: Vec<Slide> = (0..4)
        .map(|i| {
            Slide::from_spec(SlideSpec::new(
                format!("int_{i}"),
                900 + i as u64,
                32,
                16,
                3,
                64,
                if i % 2 == 0 {
                    SlideKind::LargeTumor
                } else {
                    SlideKind::SmallScattered
                },
            ))
        })
        .collect();
    for level in 0..3 {
        let mut correct = 0usize;
        let mut total = 0usize;
        for slide in &slides {
            let tiles: Vec<_> = slide
                .level_tile_ids(level)
                .into_iter()
                .filter(|&t| {
                    let tf = slide.tumor_fraction(t);
                    slide.tissue_fraction(t) > 0.6 && (tf == 0.0 || tf > 0.3)
                })
                .collect();
            if tiles.is_empty() {
                continue;
            }
            let probs = analyzer.analyze(slide, level, &tiles);
            for (&t, &p) in tiles.iter().zip(&probs) {
                let pred = p >= 0.5;
                if pred == (slide.tumor_fraction(t) > 0.3) {
                    correct += 1;
                }
                total += 1;
            }
        }
        let acc = correct as f64 / total.max(1) as f64;
        assert!(
            acc > 0.85,
            "level {level}: cross-language accuracy {acc} ({correct}/{total})"
        );
    }
}

#[test]
fn pyramidal_run_with_real_model() {
    let _ = require_artifacts!();
    let reg = registry().unwrap();
    let analyzer = PjrtAnalyzer::from_registry(reg);
    let slide = Slide::from_spec(SlideSpec::new(
        "int_pyr",
        777,
        32,
        16,
        3,
        64,
        SlideKind::LargeTumor,
    ));
    let thresholds = Thresholds {
        zoom: vec![0.5, 0.3, 0.3],
    };
    let pyr = run_pyramidal(&slide, &analyzer, &thresholds, 32);
    pyr.check_consistency().unwrap();
    let reference = run_reference(&slide, &analyzer, 32);
    assert!(pyr.total_analyzed() > 0);
    assert!(
        pyr.total_analyzed() < reference.total_analyzed(),
        "pyramid {} should beat reference {}",
        pyr.total_analyzed(),
        reference.total_analyzed()
    );
    // The pyramid must find positives on a large-tumor slide.
    let positives = pyr.level0().iter().filter(|n| n.prob >= 0.5).count();
    assert!(positives > 0, "no positives found at level 0");
}

#[test]
fn stain_normalization_keeps_predictions_sane() {
    let _ = require_artifacts!();
    let reg = registry().unwrap();
    let plain = PjrtAnalyzer::from_registry(reg.clone());
    let normed = PjrtAnalyzer::from_registry(reg).with_stain_normalization(true);
    let slide = Slide::from_spec(SlideSpec::new(
        "int_s",
        606,
        16,
        8,
        3,
        64,
        SlideKind::LargeTumor,
    ));
    let tiles: Vec<_> = slide
        .level_tile_ids(0)
        .into_iter()
        .filter(|&t| slide.tissue_fraction(t) > 0.8)
        .take(16)
        .collect();
    if tiles.is_empty() {
        return;
    }
    let a = plain.analyze(&slide, 0, &tiles);
    let b = normed.analyze(&slide, 0, &tiles);
    // Normalization shifts colors toward the reference stains; the model
    // was trained on un-normalized tiles, so probabilities move, but they
    // must stay finite probabilities.
    for p in a.iter().chain(&b) {
        assert!((0.0..=1.0).contains(p) && p.is_finite());
    }
}
