//! Benchmark harness (criterion-lite): warmup + sampled measurement with
//! mean ± σ, aligned table printing and CSV output. Used by every
//! `benches/*.rs` target and the `pyramidai report` CLI.

use std::io::Write;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::util::stats::{fmt_duration, Summary};

/// Timing result of one benchmark case.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// What was measured.
    pub name: String,
    /// Repetitions aggregated into the statistics.
    pub samples: usize,
    /// Mean wall time per repetition.
    pub mean: Duration,
    /// Standard deviation of the wall time.
    pub std: Duration,
    /// Fastest repetition.
    pub min: Duration,
    /// Slowest repetition.
    pub max: Duration,
}

impl Measurement {
    /// The measurement as table cells (name, n, mean, std, min, max).
    pub fn row(&self) -> Vec<String> {
        vec![
            self.name.clone(),
            fmt_duration(self.mean),
            format!("±{}", fmt_duration(self.std)),
            fmt_duration(self.min),
            fmt_duration(self.max),
            self.samples.to_string(),
        ]
    }
}

/// Measure a closure: `warmup` unrecorded runs, then `samples` timed runs.
pub fn measure<F: FnMut()>(name: &str, warmup: usize, samples: usize, mut f: F) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut s = Summary::new();
    for _ in 0..samples.max(1) {
        let t0 = Instant::now();
        f();
        s.push(t0.elapsed().as_secs_f64());
    }
    Measurement {
        name: name.to_string(),
        samples: s.count() as usize,
        mean: Duration::from_secs_f64(s.mean()),
        std: Duration::from_secs_f64(s.std()),
        min: Duration::from_secs_f64(s.min()),
        max: Duration::from_secs_f64(s.max()),
    }
}

/// Print an aligned table with a header row.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n=== {title} ===");
    let ncol = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.chars().count());
        }
    }
    let fmt_row = |cells: &[String]| {
        let mut line = String::new();
        for (i, c) in cells.iter().enumerate().take(ncol) {
            line.push_str(&format!("{:<width$}  ", c, width = widths[i]));
        }
        line.trim_end().to_string()
    };
    let header_cells: Vec<String> = header.iter().map(|s| s.to_string()).collect();
    println!("{}", fmt_row(&header_cells));
    println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * ncol));
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

/// CSV writer under `bench_results/`.
pub struct CsvOut {
    path: PathBuf,
    file: std::fs::File,
}

impl CsvOut {
    /// Create `bench_results/<name>` and write the header row.
    pub fn create(name: &str, header: &[&str]) -> std::io::Result<CsvOut> {
        let dir = Path::new("bench_results");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(name);
        let mut file = std::fs::File::create(&path)?;
        writeln!(file, "{}", header.join(","))?;
        Ok(CsvOut { path, file })
    }

    /// Append one data row.
    pub fn row(&mut self, cells: &[String]) -> std::io::Result<()> {
        // Minimal CSV quoting: cells with commas/quotes get quoted.
        let enc: Vec<String> = cells
            .iter()
            .map(|c| {
                if c.contains(',') || c.contains('"') {
                    format!("\"{}\"", c.replace('"', "\"\""))
                } else {
                    c.clone()
                }
            })
            .collect();
        writeln!(self.file, "{}", enc.join(","))
    }

    /// Where the CSV is being written.
    pub fn path(&self) -> &Path {
        &self.path
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_samples() {
        let mut n = 0;
        let m = measure("t", 2, 5, || n += 1);
        assert_eq!(n, 7); // 2 warmup + 5 samples
        assert_eq!(m.samples, 5);
        assert!(m.mean >= Duration::ZERO);
        assert!(m.min <= m.max);
    }

    #[test]
    fn csv_writes_and_quotes() {
        let mut csv = CsvOut::create("test_harness.csv", &["a", "b"]).unwrap();
        csv.row(&["x".into(), "y,z".into()]).unwrap();
        let text = std::fs::read_to_string(csv.path()).unwrap();
        assert!(text.contains("a,b"));
        assert!(text.contains("x,\"y,z\""));
        std::fs::remove_file(csv.path()).ok();
    }

    #[test]
    fn table_prints_without_panic() {
        print_table(
            "t",
            &["col1", "c2"],
            &[vec!["a".into(), "b".into()], vec!["longer".into(), "x".into()]],
        );
    }
}
