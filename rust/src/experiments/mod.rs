//! Experiment drivers — one module per paper table/figure (see DESIGN.md
//! §5 for the index). Shared by `benches/*` and the `pyramidai report`
//! CLI; every run prints the paper-style table and writes
//! `bench_results/*.csv`.

pub mod ctx;
pub mod fig2;
pub mod fig345;
pub mod fig6;
pub mod fig7;
pub mod fig7b;
pub mod table12;
pub mod table3;
pub mod wsi46;

pub use ctx::{Ctx, CtxConfig, ModelKind};
