//! Experiment drivers — one module per paper table/figure (see DESIGN.md
//! §5 for the index). Shared by `benches/*` and the `pyramidai report`
//! CLI; every run prints the paper-style table and writes
//! `bench_results/*.csv`.

/// Shared slide sets, caches and analyzer plumbing.
pub mod ctx;
/// Fig 2: probability heatmaps.
pub mod fig2;
/// Figs 3–5: accuracy/performance trade-off curves.
pub mod fig345;
/// Fig 6: simulated load-balancing sweep.
pub mod fig6;
/// Fig 7: real TCP-cluster sweep.
pub mod fig7;
/// Fig 7b: persistent service vs one-shot cluster.
pub mod fig7b;
/// Tables 1–2: dataset and model summaries.
pub mod table12;
/// Table 3: phase timing breakdown.
pub mod table3;
/// §4.6: whole-slide classification.
pub mod wsi46;

pub use ctx::{Ctx, CtxConfig, ModelKind};
