//! §4.6: whole-slide image classification under the three execution modes
//! — reference (baseline), PyramidAI with empirical thresholds, PyramidAI
//! with metric-based thresholds. Paper: 0.84 / 0.84 / 0.77, the
//! metric-based strategy trading accuracy for more detected-positive
//! slides (higher false-positive rate).

use anyhow::Result;

use crate::harness::{print_table, CsvOut};
use crate::predcache::PredCache;
use crate::pyramid::tree::Thresholds;
use crate::tuning::{empirical, metric_based};
use crate::wsi::{tree_features, BaggingClassifier, BaggingParams, Sample};

use super::ctx::Ctx;

#[derive(Debug, Clone)]
/// One row of the §4.6 whole-slide classification comparison.
pub struct WsiRow {
    /// Exhaustive vs pyramidal analysis mode.
    pub mode: &'static str,
    /// Slide-level classification accuracy.
    pub accuracy: f64,
    /// Slides flagged positive.
    pub detected: usize,
    /// Correctly flagged positives.
    pub true_pos: usize,
    /// Incorrectly flagged negatives.
    pub false_pos: usize,
    /// Tile-count speedup vs exhaustive.
    pub speedup: f64,
}

fn samples(cache: &PredCache, thresholds: &Thresholds) -> Vec<Sample> {
    (0..cache.slides.len())
        .map(|i| Sample {
            x: tree_features(&cache.slides[i].replay(thresholds)),
            y: Ctx::slide_label(cache, i),
        })
        .collect()
}

/// Run the §4.6 comparison on the test set.
pub fn run(ctx: &Ctx) -> Result<Vec<WsiRow>> {
    let levels = ctx.cfg.params.levels;
    let emp = empirical::select(&ctx.train_cache, levels, 0.90)?;
    let met = metric_based::select(&ctx.train_cache, levels, 0.90)?;
    let reference = Thresholds::pass_through(levels);

    let modes: [(&'static str, &Thresholds); 3] = [
        ("reference", &reference),
        ("empirical β", &emp.thresholds),
        ("metric-based", &met.thresholds),
    ];
    let mut rows = Vec::new();
    for (mode, thr) in modes {
        let train = samples(&ctx.train_cache, thr);
        let test = samples(&ctx.test_cache, thr);
        let clf = BaggingClassifier::fit(&train, &BaggingParams::default());
        let (accuracy, tp, fp, detected) = clf.confusion(&test);
        let (_, speedup, _) = metric_based::evaluate(&ctx.test_cache, thr)?;
        rows.push(WsiRow {
            mode,
            accuracy,
            detected,
            true_pos: tp,
            false_pos: fp,
            speedup,
        });
    }
    Ok(rows)
}

/// Print the comparison and write its CSV.
pub fn print_report(rows: &[WsiRow]) -> Result<()> {
    let mut csv = CsvOut::create(
        "wsi_classification.csv",
        &["mode", "accuracy", "detected", "tp", "fp", "speedup"],
    )?;
    let out: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            let row = vec![
                r.mode.to_string(),
                format!("{:.3}", r.accuracy),
                r.detected.to_string(),
                r.true_pos.to_string(),
                r.false_pos.to_string(),
                format!("{:.2}", r.speedup),
            ];
            csv.row(&row).ok();
            row
        })
        .collect();
    print_table(
        "§4.6 WSI classification (paper: baseline 0.84, empirical 0.84 @2.65×, metric-based 0.77 with more FPs)",
        &["mode", "accuracy", "detected+", "TP", "FP", "speedup"],
        &out,
    );
    Ok(())
}
