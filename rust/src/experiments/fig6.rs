//! Figure 6: simulated load balancing — max tiles analyzed by the busiest
//! worker for every (distribution × policy) combination over a sweep of
//! worker counts, averaged over the test set (§5.2-5.3).

use anyhow::Result;

use crate::harness::{print_table, CsvOut};
use crate::sim::{simulate, Distribution, Policy};
use crate::tuning::empirical;

use super::ctx::Ctx;

#[derive(Debug, Clone)]
/// One (workers × distribution × policy) cell of Fig 6.
pub struct Fig6Row {
    /// Simulated worker count.
    pub workers: usize,
    /// Initial tile distribution.
    pub distribution: Distribution,
    /// Load-balancing policy.
    pub policy: Policy,
    /// Busiest-worker tile count, averaged over slides.
    pub avg_max_tiles: f64,
    /// Steals per run, averaged over slides.
    pub avg_steals: f64,
}

/// Run the Fig-6 load-balancing sweep.
pub fn run(ctx: &Ctx, workers: &[usize]) -> Result<Vec<Fig6Row>> {
    // Thresholds per §5.1: "the pyramidal execution tree retrieved using
    // thresholds from §4.5" — empirical selection at 0.90.
    let sel = empirical::select(&ctx.train_cache, ctx.cfg.params.levels, 0.90)?;
    let trees: Vec<_> = ctx
        .test_cache
        .slides
        .iter()
        .map(|sp| sp.replay(&sel.thresholds))
        .collect();

    // Fig 6a: sync policy × all distributions; Fig 6b: none × all + RR+WS
    // + ideal. We sweep everything and let the bench print both panels.
    let mut rows = Vec::new();
    for &w in workers {
        for dist in Distribution::ALL {
            for policy in Policy::ALL {
                let mut max_sum = 0.0;
                let mut steal_sum = 0.0;
                for (i, tree) in trees.iter().enumerate() {
                    let r = simulate(tree, w, dist, policy, ctx.cfg.seed ^ i as u64);
                    max_sum += r.max_tiles() as f64;
                    steal_sum += r.steals as f64;
                }
                rows.push(Fig6Row {
                    workers: w,
                    distribution: dist,
                    policy,
                    avg_max_tiles: max_sum / trees.len() as f64,
                    avg_steals: steal_sum / trees.len() as f64,
                });
            }
        }
    }
    Ok(rows)
}

/// Average reference (highest-resolution-only) tile count — the "R." line.
pub fn reference_line(ctx: &Ctx) -> f64 {
    let n = ctx.test_cache.slides.len().max(1);
    ctx.test_cache
        .slides
        .iter()
        .map(|s| s.reference_count() as f64)
        .sum::<f64>()
        / n as f64
}

/// Print the sweep and write its CSV.
pub fn print_report(ctx: &Ctx, rows: &[Fig6Row]) -> Result<()> {
    let mut csv = CsvOut::create(
        "fig6_load_balancing.csv",
        &["workers", "distribution", "policy", "avg_max_tiles", "avg_steals"],
    )?;
    for r in rows {
        csv.row(&[
            r.workers.to_string(),
            r.distribution.as_str().into(),
            r.policy.as_str().into(),
            format!("{:.1}", r.avg_max_tiles),
            format!("{:.1}", r.avg_steals),
        ])?;
    }

    let panel = |title: &str, select: &dyn Fn(&Fig6Row) -> bool| {
        let mut out: Vec<Vec<String>> = Vec::new();
        for r in rows.iter().filter(|r| select(r)) {
            out.push(vec![
                r.workers.to_string(),
                format!("{}+{}", r.distribution.as_str(), r.policy.as_str()),
                format!("{:.1}", r.avg_max_tiles),
            ]);
        }
        print_table(title, &["workers", "strategy", "avg max tiles/worker"], &out);
    };
    panel(
        "Fig 6a: synchronization-based balancing (paper: round-robin ≈ random ≫ block)",
        &|r| r.policy == Policy::SyncPerLevel,
    );
    panel(
        "Fig 6b: no-sync policies (paper: work-stealing ≈ ideal from ≥4 workers)",
        &|r| {
            r.policy == Policy::NoBalancing
                || (r.policy == Policy::WorkStealing
                    && r.distribution == Distribution::RoundRobin)
                || (r.policy == Policy::OracleIdeal
                    && r.distribution == Distribution::RoundRobin)
        },
    );
    println!(
        "\nR. (reference execution on one worker): {:.0} tiles",
        reference_line(ctx)
    );
    Ok(())
}
