//! Table 3: computation time per phase — initialization (background
//! removal at the lowest level), the per-level analysis blocks, and task
//! creation. The paper measures 1000 repetitions; the sample count here is
//! configurable so `cargo bench` stays fast while the report CLI can go
//! the full distance.

use anyhow::Result;

use crate::harness::{measure, print_table, CsvOut, Measurement};
use crate::preprocess::otsu::background_removal;
use crate::pyramid::driver::BG_MARGIN;
use crate::slide::pyramid::Slide;
use crate::synth::slide_gen::{DatasetParams, SlideKind, SlideSpec};

use super::ctx::{make_analyzer, ModelKind};

/// Phase timing breakdown (Table 3).
pub struct Table3 {
    /// One measurement per phase.
    pub rows: Vec<Measurement>,
    /// Which analyzer produced the timings.
    pub analyzer_name: &'static str,
}

/// Measure the per-phase costs.
pub fn run(model: ModelKind, samples: usize, batch: usize) -> Result<Table3> {
    let (analyzer, analyzer_name) = make_analyzer(model, 7)?;
    let p = DatasetParams::default();
    let slide = Slide::from_spec(SlideSpec::new(
        "t3",
        4242,
        p.tiles_x,
        p.tiles_y,
        p.levels,
        p.tile_px,
        SlideKind::LargeTumor,
    ));

    let mut rows = Vec::new();

    // Initialization: tile retrieval + Otsu at the lowest resolution.
    rows.push(measure("initialization", 1, samples.min(50), || {
        let mask = background_removal(&slide, BG_MARGIN);
        std::hint::black_box(mask.tissue_tiles.len());
    }));

    // Analysis block per level, per `batch` tiles (reported per tile).
    for level in (0..slide.levels()).rev() {
        let tiles: Vec<_> = slide
            .level_tile_ids(level)
            .into_iter()
            .filter(|&t| slide.tissue_fraction(t) > 0.5)
            .take(batch)
            .collect();
        let name = format!("level {level} analysis block ({} tiles)", tiles.len());
        let m = measure(&name, 1, samples, || {
            std::hint::black_box(analyzer.analyze(&slide, level, &tiles));
        });
        rows.push(m);
    }

    // Task creation: spawning the f² children of a zoomed tile.
    let parent = slide.level_tile_ids(1)[0];
    rows.push(measure("task creation", 10, samples * 10, || {
        std::hint::black_box(parent.children());
    }));

    Ok(Table3 {
        rows,
        analyzer_name,
    })
}

/// Print the table and write its CSV.
pub fn print_report(t: &Table3) -> Result<()> {
    let mut csv = CsvOut::create(
        "table3_phases.csv",
        &["phase", "mean", "std", "min", "max", "samples"],
    )?;
    let rows: Vec<Vec<String>> = t
        .rows
        .iter()
        .map(|m| {
            let row = m.row();
            csv.row(&row).ok();
            row
        })
        .collect();
    print_table(
        &format!(
            "Table 3: per-phase time, {} analyzer (paper on i5-9500: init 0.02s, analysis 0.31-0.33s/tile, task 2.8e-5 s)",
            t.analyzer_name
        ),
        &["phase", "mean", "std", "min", "max", "n"],
        &rows,
    );
    Ok(())
}
