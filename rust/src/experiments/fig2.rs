//! Figure 2: tumor-probability heatmaps per pyramid level vs ground truth.
//!
//! Emits one CSV per level (`fig2_heatmap_l{level}.csv` with columns
//! tx, ty, probability, truth) plus PGM and PNG images (the tiny
//! `util::png` encoder) for quick eyeballing — the repo's stand-in for
//! the paper's color renderings.

use std::io::Write;
use std::path::Path;

use anyhow::Result;

use crate::harness::CsvOut;
use crate::slide::pyramid::Slide;
use crate::synth::slide_gen::{DatasetParams, SlideKind, SlideSpec};
use crate::util::png::write_gray_png;

use super::ctx::{make_analyzer, ModelKind};

/// Emit the Fig-2 probability heatmaps (CSV + PNG); returns the
/// written paths.
pub fn run(model: ModelKind) -> Result<Vec<String>> {
    let (analyzer, _) = make_analyzer(model, 5)?;
    let p = DatasetParams::default();
    let slide = Slide::from_spec(SlideSpec::new(
        "fig2",
        31337,
        p.tiles_x,
        p.tiles_y,
        p.levels,
        p.tile_px,
        SlideKind::LargeTumor,
    ));
    let mut outputs = Vec::new();
    for level in (0..slide.levels()).rev() {
        let tiles = slide.level_tile_ids(level);
        let probs = analyzer.analyze(&slide, level, &tiles);
        let (nx, ny) = slide.level_tiles(level);

        let mut csv = CsvOut::create(
            &format!("fig2_heatmap_l{level}.csv"),
            &["tx", "ty", "probability", "tumor_truth"],
        )?;
        for (&t, &prob) in tiles.iter().zip(&probs) {
            csv.row(&[
                t.tx.to_string(),
                t.ty.to_string(),
                format!("{prob:.4}"),
                format!("{}", slide.is_tumor(t) as u8),
            ])?;
        }
        outputs.push(csv.path().display().to_string());

        // PGM + PNG heatmap (prob) and ground truth mask.
        for (suffix, vals) in [
            (
                "prob",
                probs.iter().map(|&p| (p * 255.0) as u8).collect::<Vec<u8>>(),
            ),
            (
                "truth",
                tiles
                    .iter()
                    .map(|&t| if slide.is_tumor(t) { 255 } else { 0 })
                    .collect(),
            ),
        ] {
            let path = Path::new("bench_results").join(format!("fig2_l{level}_{suffix}.pgm"));
            let mut f = std::fs::File::create(&path)?;
            write!(f, "P5\n{nx} {ny}\n255\n")?;
            f.write_all(&vals)?;
            outputs.push(path.display().to_string());

            let png_path =
                Path::new("bench_results").join(format!("fig2_l{level}_{suffix}.png"));
            write_gray_png(&png_path, nx, ny, &vals)?;
            outputs.push(png_path.display().to_string());
        }
    }
    Ok(outputs)
}
