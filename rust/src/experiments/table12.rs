//! Tables 1 & 2: dataset sizes and per-level model accuracies.
//!
//! The python build step records its train/val/test sizes and accuracies
//! in `artifacts/meta.json`; this experiment reports them next to the
//! paper's values, and additionally measures the deployed model's accuracy
//! on rust-generated tiles (the cross-language transfer number).

use anyhow::Result;

use crate::harness::{print_table, CsvOut};
use crate::runtime::ArtifactsMeta;
use crate::slide::pyramid::Slide;
use crate::synth::slide_gen::{gen_slide_set, DatasetParams};

use super::ctx::{artifacts_dir, make_analyzer, ModelKind};

/// Paper values for the comparison columns.
pub const PAPER_T1: [(usize, usize, usize); 3] = [
    (26576, 38400, 92000),
    (26134, 38400, 92000),
    (25504, 38400, 72568),
];
/// Paper Table 2 per-level accuracies (train, val, test).
pub const PAPER_T2: [(f64, f64, f64); 3] = [
    (0.9328, 0.9498, 0.9480),
    (0.9439, 0.9590, 0.9584),
    (0.8982, 0.9110, 0.9166),
];

#[derive(Debug, Clone)]
/// One pyramid level's dataset sizes and accuracies.
pub struct LevelReport {
    /// Pyramid level.
    pub level: usize,
    /// (train, val, test) sample counts, when artifacts exist.
    pub sizes: Option<(usize, usize, usize)>,
    /// (train, val, test) accuracies, when artifacts exist.
    pub accs: Option<(f64, f64, f64)>,
    /// Accuracy of the deployed (PJRT) model on decisive rust tiles.
    pub rust_acc: Option<f64>,
}

/// Build Tables 1–2 from the compiled artifacts.
pub fn run(measure_rust_transfer: bool) -> Result<Vec<LevelReport>> {
    let meta = ArtifactsMeta::load(&artifacts_dir())?;
    let mut reports: Vec<LevelReport> = (0..meta.levels)
        .map(|level| LevelReport {
            level,
            sizes: meta.dataset_sizes.get(level).copied().flatten(),
            accs: meta.accuracies.get(level).copied().flatten(),
            rust_acc: None,
        })
        .collect();

    if measure_rust_transfer {
        let (analyzer, _) = make_analyzer(ModelKind::Pjrt, 1)?;
        let slides: Vec<Slide> = gen_slide_set("t2", 4, 999, &DatasetParams::default())
            .into_iter()
            .map(Slide::from_spec)
            .collect();
        for report in reports.iter_mut() {
            let level = report.level;
            let mut correct = 0usize;
            let mut total = 0usize;
            for slide in &slides {
                let tiles: Vec<_> = slide
                    .level_tile_ids(level)
                    .into_iter()
                    .filter(|&t| {
                        let tf = slide.tumor_fraction(t);
                        slide.tissue_fraction(t) > 0.6 && (tf == 0.0 || tf > 0.3)
                    })
                    .collect();
                if tiles.is_empty() {
                    continue;
                }
                let probs = analyzer.analyze(slide, level, &tiles);
                for (&t, &p) in tiles.iter().zip(&probs) {
                    if (p >= 0.5) == (slide.tumor_fraction(t) > 0.3) {
                        correct += 1;
                    }
                    total += 1;
                }
            }
            report.rust_acc = Some(correct as f64 / total.max(1) as f64);
        }
    }
    Ok(reports)
}

/// Print the tables and write their CSV.
pub fn print_report(reports: &[LevelReport]) -> Result<()> {
    let mut csv = CsvOut::create(
        "table1_2.csv",
        &[
            "level",
            "train_size",
            "val_size",
            "test_size",
            "train_acc",
            "val_acc",
            "test_acc",
            "rust_transfer_acc",
            "paper_test_acc",
        ],
    )?;
    let rows: Vec<Vec<String>> = reports
        .iter()
        .map(|r| {
            let (ts, vs, xs) = r.sizes.unwrap_or((0, 0, 0));
            let (ta, va, xa) = r.accs.unwrap_or((f64::NAN, f64::NAN, f64::NAN));
            let row = vec![
                format!("{}", r.level),
                ts.to_string(),
                vs.to_string(),
                xs.to_string(),
                format!("{ta:.4}"),
                format!("{va:.4}"),
                format!("{xa:.4}"),
                r.rust_acc.map_or("-".into(), |a| format!("{a:.4}")),
                format!("{:.4}", PAPER_T2[r.level.min(2)].2),
            ];
            csv.row(&row).ok();
            row
        })
        .collect();
    print_table(
        "Tables 1-2: dataset sizes and model accuracies (paper: 26k/38k/92k tiles, acc 0.90-0.96)",
        &[
            "level",
            "train",
            "val",
            "test",
            "train_acc",
            "val_acc",
            "test_acc",
            "rust_acc",
            "paper_acc",
        ],
        &rows,
    );
    Ok(())
}
