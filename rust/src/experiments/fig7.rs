//! Figure 7: real-cluster execution time per image vs number of workers,
//! with and without work stealing (§5.4).
//!
//! Three slides (large tumors / several small ones / negative), each
//! measured `reps` times per configuration on the TCP cluster. A per-tile
//! delay stands in for the paper's 0.33 s analysis block so the run is
//! latency-bound and worker threads overlap like separate machines
//! (DESIGN.md S3); the oracle provides probabilities so the tree shape
//! matches the tuned execution.

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::cluster::{run_cluster, ClusterConfig};
use crate::harness::{print_table, CsvOut};
use crate::model::oracle::OracleAnalyzer;
use crate::model::{Analyzer, DelayAnalyzer};
use crate::sim::Distribution;
use crate::synth::slide_gen::{DatasetParams, SlideKind, SlideSpec};
use crate::tuning::empirical;
use crate::util::stats::Summary;

use super::ctx::Ctx;

#[derive(Debug, Clone)]
/// One (slide kind × workers × steal) cell of Fig 7.
pub struct Fig7Row {
    /// Which synthetic slide family ran.
    pub slide_kind: &'static str,
    /// Cluster worker count.
    pub workers: usize,
    /// Work stealing on/off.
    pub steal: bool,
    /// Mean wall seconds over the repetitions.
    pub mean_secs: f64,
    /// Standard deviation of the wall seconds.
    pub std_secs: f64,
    /// Busiest-worker tile count (mean).
    pub max_tiles: f64,
    /// Steals per run (mean).
    pub steals: f64,
}

/// Run the Fig-7 TCP-cluster sweep.
pub fn run(
    ctx: &Ctx,
    workers: &[usize],
    reps: usize,
    per_tile: Duration,
) -> Result<Vec<Fig7Row>> {
    let sel = empirical::select(&ctx.train_cache, ctx.cfg.params.levels, 0.90)?;
    let p = DatasetParams::default();
    let slides = [
        ("large_tumor", SlideKind::LargeTumor),
        ("small_scattered", SlideKind::SmallScattered),
        ("negative", SlideKind::Negative),
    ];
    let analyzer: Arc<dyn Analyzer> = Arc::new(DelayAnalyzer::new(
        OracleAnalyzer::new(1),
        per_tile,
    ));

    let mut rows = Vec::new();
    for (name, kind) in slides {
        let spec = SlideSpec::new(
            format!("fig7_{name}"),
            0xF16_7 ^ kind as u64,
            p.tiles_x,
            p.tiles_y,
            p.levels,
            p.tile_px,
            kind,
        );
        for &w in workers {
            for steal in [false, true] {
                let mut secs = Summary::new();
                let mut max_tiles = 0.0;
                let mut steals = 0.0;
                for rep in 0..reps {
                    // TCP setup can flake under heavy thread contention on
                    // this 1-core box (listener backlog, bind timing);
                    // retry the whole run like a real deployment would.
                    let mut attempt = 0;
                    let mut backoff = crate::fault::Backoff::new(
                        "fig7.cluster_run",
                        &crate::fault::RetryPolicy::link(Duration::from_secs(5)),
                    );
                    let res = loop {
                        attempt += 1;
                        match run_cluster(
                            &spec,
                            &sel.thresholds,
                            Arc::clone(&analyzer),
                            &ClusterConfig {
                                workers: w,
                                distribution: Distribution::RoundRobin,
                                steal,
                                batch: 1, // per-tile tasks, like the paper
                                seed: 1000 + rep as u64 + attempt * 7919,
                            },
                        ) {
                            Ok(r) => break r,
                            Err(e) if attempt < 3 => {
                                log::warn!("cluster run retry {attempt}: {e:#}");
                                backoff.sleep();
                            }
                            Err(e) => return Err(e),
                        }
                    };
                    secs.push(res.wall.as_secs_f64());
                    max_tiles += res.max_tiles() as f64 / reps as f64;
                    steals += res.steals as f64 / reps as f64;
                }
                rows.push(Fig7Row {
                    slide_kind: name,
                    workers: w,
                    steal,
                    mean_secs: secs.mean(),
                    std_secs: secs.std(),
                    max_tiles,
                    steals,
                });
            }
        }
    }
    Ok(rows)
}

/// Print the sweep and write its CSV.
pub fn print_report(rows: &[Fig7Row]) -> Result<()> {
    let mut csv = CsvOut::create(
        "fig7_cluster.csv",
        &[
            "slide",
            "workers",
            "steal",
            "mean_secs",
            "std_secs",
            "avg_max_tiles",
            "avg_steals",
        ],
    )?;
    let mut out = Vec::new();
    for r in rows {
        let row = vec![
            r.slide_kind.to_string(),
            r.workers.to_string(),
            if r.steal { "ws" } else { "no-ws" }.to_string(),
            format!("{:.3}", r.mean_secs),
            format!("{:.3}", r.std_secs),
            format!("{:.1}", r.max_tiles),
            format!("{:.1}", r.steals),
        ];
        csv.row(&row)?;
        out.push(row);
    }
    print_table(
        "Fig 7: real TCP cluster — avg time per image vs workers (paper: >1h → ~15min at 12 workers, WS best)",
        &["slide", "workers", "policy", "mean_s", "std_s", "max_tiles", "steals"],
        &out,
    );
    Ok(())
}
