//! Figure 7b: scaling sweep for the *persistent* chunk cluster — the
//! multi-slide service with `--backend cluster` vs the one-shot
//! [`run_cluster`] path, across worker counts.
//!
//! Both modes execute the same six-slide job set (two of each Fig-7
//! slide kind) with the same per-tile delay standing in for the paper's
//! 0.33 s analysis block. The one-shot path pays a fresh cluster
//! spin-up, initial distribution and tear-down per slide (the paper's
//! §5.4 regime); the service keeps one TCP cluster alive, deals every
//! job's frontier chunks to the same workers and overlaps jobs up to
//! `max_in_flight` — the regime a production deployment actually runs.
//! The gap between the two rows at each worker count is the price of
//! not keeping the cluster warm.

use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::cluster::{run_cluster, ClusterConfig, ClusterExecConfig};
use crate::harness::{print_table, CsvOut};
use crate::model::oracle::OracleAnalyzer;
use crate::model::{Analyzer, DelayAnalyzer};
use crate::service::{
    AnalysisService, ExecMode, JobSource, JobSpec, PolicySpec, ServiceConfig,
};
use crate::sim::Distribution;
use crate::synth::slide_gen::{DatasetParams, SlideKind, SlideSpec};
use crate::tuning::empirical;
use crate::util::stats::{timed, Summary};

use super::ctx::Ctx;

#[derive(Debug, Clone)]
/// One worker-count cell of the service-vs-one-shot sweep.
pub struct Fig7bRow {
    /// Cluster worker count.
    pub workers: usize,
    /// `one-shot` ([`run_cluster`] per slide) or `service` (persistent
    /// cluster behind the multi-slide scheduler).
    pub mode: &'static str,
    /// Wall time for the whole job set.
    pub mean_secs: f64,
    /// Standard deviation of the wall seconds.
    pub std_secs: f64,
    /// Jobs analyzed per repetition.
    pub jobs: usize,
}

/// The shared job set: two of each Fig-7 slide kind.
fn job_specs() -> Vec<SlideSpec> {
    let p = DatasetParams::default();
    let kinds = [
        SlideKind::LargeTumor,
        SlideKind::SmallScattered,
        SlideKind::Negative,
    ];
    (0..6)
        .map(|i| {
            SlideSpec::new(
                format!("fig7b_{i}"),
                0xF1B7 ^ ((i as u64) << 3),
                p.tiles_x,
                p.tiles_y,
                p.levels,
                p.tile_px,
                kinds[i % 3],
            )
        })
        .collect()
}

/// Run the Fig-7b service-backed vs one-shot comparison.
pub fn run(
    ctx: &Ctx,
    workers: &[usize],
    reps: usize,
    per_tile: Duration,
) -> Result<Vec<Fig7bRow>> {
    let sel = empirical::select(&ctx.train_cache, ctx.cfg.params.levels, 0.90)?;
    let specs = job_specs();
    let analyzer: Arc<dyn Analyzer> =
        Arc::new(DelayAnalyzer::new(OracleAnalyzer::new(1), per_tile));

    let mut rows = Vec::new();
    for &w in workers {
        // One-shot: a fresh cluster per slide, slides strictly in
        // sequence (the §5.4 single-image regime, repeated).
        let mut oneshot = Summary::new();
        for rep in 0..reps {
            let (res, wall) = timed(|| -> Result<()> {
                for spec in &specs {
                    // TCP setup can flake under heavy thread contention
                    // on a small box; retry like a real deployment would.
                    let mut attempt = 0;
                    let mut backoff = crate::fault::Backoff::new(
                        "fig7b.cluster_run",
                        &crate::fault::RetryPolicy::link(Duration::from_secs(5)),
                    );
                    loop {
                        attempt += 1;
                        match run_cluster(
                            spec,
                            &sel.thresholds,
                            Arc::clone(&analyzer),
                            &ClusterConfig {
                                workers: w,
                                distribution: Distribution::RoundRobin,
                                steal: true,
                                batch: 1,
                                seed: 7000 + rep as u64 + attempt * 7919,
                            },
                        ) {
                            Ok(_) => break,
                            Err(e) if attempt < 3 => {
                                log::warn!("one-shot cluster retry {attempt}: {e:#}");
                                backoff.sleep();
                            }
                            Err(e) => return Err(e),
                        }
                    }
                }
                Ok(())
            });
            res?;
            oneshot.push(wall.as_secs_f64());
        }
        rows.push(Fig7bRow {
            workers: w,
            mode: "one-shot",
            mean_secs: oneshot.mean(),
            std_secs: oneshot.std(),
            jobs: specs.len(),
        });

        // Service: one persistent cluster, every job's chunks dealt to
        // the same warm workers, jobs overlapping up to max_in_flight.
        let mut service = Summary::new();
        for rep in 0..reps {
            let (res, wall) = timed(|| -> Result<()> {
                let svc = AnalysisService::start(
                    Arc::clone(&analyzer),
                    ServiceConfig {
                        workers: w,
                        queue_capacity: specs.len(),
                        max_in_flight: 2,
                        batch: 8,
                        policy: PolicySpec::fifo(),
                        coalesce: false,
                        preempt: false,
                        exec: ExecMode::Cluster(ClusterExecConfig {
                            workers: w,
                            steal: true,
                            seed: 7700 + rep as u64,
                            ..ClusterExecConfig::default()
                        }),
                    },
                );
                for spec in &specs {
                    svc.submit(JobSpec::new(
                        JobSource::Spec(spec.clone()),
                        sel.thresholds.clone(),
                    ))
                    .map_err(|e| anyhow!("submit failed: {e}"))?;
                }
                let report = svc.shutdown();
                if report.metrics.completed != specs.len() {
                    return Err(anyhow!(
                        "service completed {}/{} jobs",
                        report.metrics.completed,
                        specs.len()
                    ));
                }
                Ok(())
            });
            res?;
            service.push(wall.as_secs_f64());
        }
        rows.push(Fig7bRow {
            workers: w,
            mode: "service",
            mean_secs: service.mean(),
            std_secs: service.std(),
            jobs: specs.len(),
        });
    }
    Ok(rows)
}

/// Print the comparison and write its CSV.
pub fn print_report(rows: &[Fig7bRow]) -> Result<()> {
    let mut csv = CsvOut::create(
        "fig7b_cluster_service.csv",
        &["workers", "mode", "mean_secs", "std_secs", "jobs"],
    )?;
    let mut out = Vec::new();
    for r in rows {
        let row = vec![
            r.workers.to_string(),
            r.mode.to_string(),
            format!("{:.3}", r.mean_secs),
            format!("{:.3}", r.std_secs),
            r.jobs.to_string(),
        ];
        csv.row(&row)?;
        out.push(row);
    }
    print_table(
        "Fig 7b: persistent chunk cluster (service --backend cluster) vs one-shot run_cluster",
        &["workers", "mode", "mean_s", "std_s", "jobs"],
        &out,
    );
    Ok(())
}
