//! Shared experiment context: slide sets, analyzer selection, prediction
//! caches (collected once, cached on disk under `bench_results/.cache/`).
//!
//! Every bench target and the `report` CLI build on this, so all
//! tables/figures are computed over the same data and the expensive
//! inference pass runs once per (model, dataset) pair.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::Result;

use crate::model::oracle::OracleAnalyzer;
use crate::model::pjrt::PjrtAnalyzer;
use crate::model::Analyzer;
use crate::predcache::store::MANIFEST_FILE;
use crate::predcache::{PredCache, ShardedPredStore};
use crate::slide::pyramid::Slide;
use crate::synth::slide_gen::{gen_slide_set, DatasetParams, SlideSpec};

/// Which analysis block to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelKind {
    /// Calibrated synthetic model (no artifacts needed).
    Oracle,
    /// AOT-compiled TinyInception through PJRT.
    Pjrt,
    /// Pjrt when `artifacts/` exists, else Oracle.
    Auto,
}

impl ModelKind {
    /// Parse a `--model` flag value.
    pub fn from_str(s: &str) -> Option<ModelKind> {
        match s {
            "oracle" => Some(ModelKind::Oracle),
            "pjrt" => Some(ModelKind::Pjrt),
            "auto" => Some(ModelKind::Auto),
            _ => None,
        }
    }

    fn resolve(self) -> ModelKind {
        match self {
            ModelKind::Auto => {
                if artifacts_dir().join("meta.json").exists() {
                    ModelKind::Pjrt
                } else {
                    ModelKind::Oracle
                }
            }
            k => k,
        }
    }
}

/// Where the compiled L1/L2 artifacts live.
pub fn artifacts_dir() -> PathBuf {
    // Respect the layout: the binary runs from the workspace root;
    // fall back to the manifest dir for `cargo test`/`cargo bench`.
    let cwd = PathBuf::from("artifacts");
    if cwd.join("meta.json").exists() {
        return cwd;
    }
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Build the analyzer for a model kind (auto-resolves to pjrt
/// when artifacts exist, oracle otherwise).
pub fn make_analyzer(kind: ModelKind, seed: u64) -> Result<(Arc<dyn Analyzer>, &'static str)> {
    Ok(match kind.resolve() {
        ModelKind::Pjrt => (
            Arc::new(PjrtAnalyzer::load(&artifacts_dir())?) as Arc<dyn Analyzer>,
            "pjrt",
        ),
        _ => (Arc::new(OracleAnalyzer::new(seed)) as Arc<dyn Analyzer>, "oracle"),
    })
}

/// Standard experiment sizes. The paper tunes on 30 train slides and
/// evaluates on the Camelyon16 test set; scaled to this machine.
#[derive(Debug, Clone)]
pub struct CtxConfig {
    /// Which tile model to run.
    pub model: ModelKind,
    /// Training-set size (threshold tuning).
    pub n_train: usize,
    /// Test-set size (evaluation).
    pub n_test: usize,
    /// Slide geometry shared by both sets.
    pub params: DatasetParams,
    /// Master seed for generation and prediction.
    pub seed: u64,
}

impl Default for CtxConfig {
    fn default() -> Self {
        Self {
            model: ModelKind::Auto,
            n_train: 12,
            n_test: 9,
            params: DatasetParams::default(),
            seed: 2025,
        }
    }
}

/// Shared experiment context: generated slide sets with their
/// prediction caches, ready for replay-based experiments.
pub struct Ctx {
    /// The configuration this context was built from.
    pub cfg: CtxConfig,
    /// The live analyzer (for non-replay experiments).
    pub analyzer: Arc<dyn Analyzer>,
    /// Stable analyzer name for tables.
    pub analyzer_name: &'static str,
    /// Training slide recipes.
    pub train_specs: Vec<SlideSpec>,
    /// Test slide recipes.
    pub test_specs: Vec<SlideSpec>,
    /// Predictions for the training set.
    pub train_cache: PredCache,
    /// Predictions for the test set.
    pub test_cache: PredCache,
}

fn cache_key(tag: &str, model: &str, n: usize, p: &DatasetParams, seed: u64) -> String {
    // Key PJRT caches by the artifacts build stamp so retrained models
    // invalidate stale predictions.
    let stamp = if model == "pjrt" {
        std::fs::read_to_string(artifacts_dir().join("meta.json"))
            .ok()
            .and_then(|t| crate::util::json::Json::parse(&t).ok())
            .and_then(|v| v.get("built_at").ok().and_then(|b| b.as_str().ok().map(String::from)))
            .unwrap_or_default()
            .replace([':', '-'], "")
    } else {
        String::new()
    };
    format!(
        "preds_{tag}_{model}{stamp}_{n}x{}x{}_s{seed}",
        p.tiles_x, p.tiles_y
    )
}

/// On-disk prediction cache for one (tag, model, dataset) triple: a
/// binary shard directory (fast path), with the pre-shard JSON file of
/// the same key imported transparently when present.
fn load_or_collect(
    tag: &str,
    model: &str,
    specs: &[SlideSpec],
    analyzer: &Arc<dyn Analyzer>,
    cfg: &CtxConfig,
) -> Result<PredCache> {
    let root = Path::new("bench_results").join(".cache");
    let key = cache_key(tag, model, specs.len(), &cfg.params, cfg.seed);
    let dir = root.join(format!("{key}.shards"));
    if dir.join(MANIFEST_FILE).exists() {
        if let Ok(store) = ShardedPredStore::open(&dir) {
            if store.len() == specs.len() {
                if let Ok(c) = store.load_all() {
                    log::info!("loaded shard cache {}", dir.display());
                    return Ok(c);
                }
            }
        }
    }
    // Migration: a legacy JSON cache of the same key converts to shards
    // once, then the binary path serves every later run.
    let legacy = root.join(format!("{key}.json"));
    if legacy.exists() {
        if let Ok(c) = PredCache::load(&legacy) {
            if c.slides.len() == specs.len() {
                log::info!("migrating JSON cache {} to shards", legacy.display());
                c.save_sharded(&dir, 2)?;
                return Ok(c);
            }
        }
    }
    log::info!("collecting predictions for {} ({} slides)…", tag, specs.len());
    let slides: Vec<Slide> = specs.iter().cloned().map(Slide::from_spec).collect();
    let cache = PredCache::collect_set(&slides, analyzer.as_ref(), 32);
    std::fs::create_dir_all(&root)?;
    cache.save_sharded(&dir, 2)?;
    Ok(cache)
}

impl Ctx {
    /// Build (or load from disk cache) the full experiment context.
    pub fn load(cfg: CtxConfig) -> Result<Ctx> {
        let (analyzer, analyzer_name) = make_analyzer(cfg.model, cfg.seed ^ 0xA11A)?;
        let train_specs = gen_slide_set("train", cfg.n_train, cfg.seed, &cfg.params);
        let test_specs = gen_slide_set("test", cfg.n_test, cfg.seed ^ 0x7E57, &cfg.params);
        let train_cache =
            load_or_collect("train", analyzer_name, &train_specs, &analyzer, &cfg)?;
        let test_cache = load_or_collect("test", analyzer_name, &test_specs, &analyzer, &cfg)?;
        Ok(Ctx {
            cfg,
            analyzer,
            analyzer_name,
            train_specs,
            test_specs,
            train_cache,
            test_cache,
        })
    }

    /// Ground-truth WSI label of a cached slide: does the reference
    /// execution detect any true positive tile?
    pub fn slide_label(cache: &PredCache, i: usize) -> bool {
        cache.slides[i].iter_level(0).any(|(_, p)| {
            p.tumor && p.prob >= crate::pyramid::tree::POSITIVE_THRESHOLD as f32
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_ctx_builds_and_reuses_cache() {
        let cfg = CtxConfig {
            model: ModelKind::Oracle,
            n_train: 2,
            n_test: 2,
            params: DatasetParams {
                tiles_x: 16,
                tiles_y: 8,
                levels: 3,
                tile_px: 64,
            },
            seed: 42424,
        };
        let ctx = Ctx::load(cfg.clone()).unwrap();
        assert_eq!(ctx.train_cache.slides.len(), 2);
        assert_eq!(ctx.analyzer_name, "oracle");
        // Second load hits the disk cache (just verify it round-trips).
        let ctx2 = Ctx::load(cfg).unwrap();
        assert_eq!(
            ctx2.train_cache.slides[0].len(),
            ctx.train_cache.slides[0].len()
        );
        // cleanup
        let _ = std::fs::remove_dir_all("bench_results/.cache");
    }
}
