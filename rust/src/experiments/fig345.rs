//! Figures 3, 4 and 5: the accuracy-performance trade-off studies.
//!
//! * **Fig. 3** — isolated per-level β sweep: positive retention rate and
//!   speedup when only one level filters (others pass through).
//! * **Fig. 4** — metric-based selection: for each objective retention
//!   rate, the per-level βs chosen on the train set and the achieved
//!   retention/speedup on the test set.
//! * **Fig. 5** — empirical β sweep: one β for all levels, retention and
//!   speedup on train and test sets.

use anyhow::Result;

use crate::harness::{print_table, CsvOut};
use crate::tuning::empirical;
use crate::tuning::metric_based::{self, evaluate, isolated_curve};

use super::ctx::Ctx;

/// Fig. 3 rows: per level × β.
pub fn fig3(ctx: &Ctx) -> Result<()> {
    let levels = ctx.cfg.params.levels;
    let mut csv = CsvOut::create(
        "fig3_isolated_levels.csv",
        &["level", "beta", "threshold", "retention", "speedup"],
    )?;
    let mut rows = Vec::new();
    for level in 1..levels {
        let curve = isolated_curve(&ctx.train_cache, levels, level)?;
        for p in &curve.points {
            let row = vec![
                level.to_string(),
                p.beta.to_string(),
                format!("{:.3}", p.threshold),
                format!("{:.4}", p.retention),
                format!("{:.3}", p.speedup),
            ];
            csv.row(&row)?;
            rows.push(row);
        }
    }
    print_table(
        "Fig 3: isolated resolution levels — retention & speedup vs β (train set)",
        &["level", "beta", "threshold", "retention", "speedup"],
        &rows,
    );
    Ok(())
}

/// Fig. 4 rows: objective sweep for the metric-based strategy.
pub fn fig4(ctx: &Ctx) -> Result<()> {
    let levels = ctx.cfg.params.levels;
    let mut csv = CsvOut::create(
        "fig4_metric_tradeoff.csv",
        &[
            "objective",
            "beta_l1",
            "beta_l2",
            "train_retention",
            "train_speedup",
            "test_retention",
            "test_speedup",
        ],
    )?;
    let mut rows = Vec::new();
    for objective in [0.70, 0.75, 0.80, 0.85, 0.90, 0.95, 0.99] {
        let sel = metric_based::select(&ctx.train_cache, levels, objective)?;
        let (tr_ret, tr_sp, _) = evaluate(&ctx.train_cache, &sel.thresholds)?;
        let (te_ret, te_sp, _) = evaluate(&ctx.test_cache, &sel.thresholds)?;
        let row = vec![
            format!("{objective:.2}"),
            sel.betas[1].map_or("-".into(), |b| b.to_string()),
            sel.betas
                .get(2)
                .copied()
                .flatten()
                .map_or("-".into(), |b| b.to_string()),
            format!("{tr_ret:.4}"),
            format!("{tr_sp:.3}"),
            format!("{te_ret:.4}"),
            format!("{te_sp:.3}"),
        ];
        csv.row(&row)?;
        rows.push(row);
    }
    print_table(
        "Fig 4: metric-based strategy — objective retention vs achieved (paper: objective 0.90 → test retention 0.92, speedup 2.34)",
        &[
            "objective",
            "β L1",
            "β L2",
            "train_ret",
            "train_spd",
            "test_ret",
            "test_spd",
        ],
        &rows,
    );
    Ok(())
}

/// Fig. 5 rows: empirical β sweep on train + test.
pub fn fig5(ctx: &Ctx) -> Result<()> {
    let levels = ctx.cfg.params.levels;
    let sweep = empirical::sweep(&ctx.train_cache, levels)?;
    let mut csv = CsvOut::create(
        "fig5_empirical_tradeoff.csv",
        &[
            "beta",
            "train_retention",
            "train_speedup",
            "test_retention",
            "test_speedup",
        ],
    )?;
    let mut rows = Vec::new();
    for p in &sweep {
        let (te_ret, te_sp, _) = evaluate(&ctx.test_cache, &p.thresholds)?;
        let row = vec![
            p.beta.to_string(),
            format!("{:.4}", p.retention),
            format!("{:.3}", p.speedup),
            format!("{te_ret:.4}"),
            format!("{te_sp:.3}"),
        ];
        csv.row(&row)?;
        rows.push(row);
    }
    print_table(
        "Fig 5: empirical strategy — β sweep (paper: β=8 → 90% retention, 2.65× speedup; β=5 → 80%, 5.63×)",
        &["beta", "train_ret", "train_spd", "test_ret", "test_spd"],
        &rows,
    );
    Ok(())
}
