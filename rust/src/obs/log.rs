//! Leveled stderr logger behind `--log-level` / `PYRAMIDAI_LOG`.
//!
//! The level gate is a single relaxed atomic load, so disabled levels cost
//! a branch. Records render as one line:
//!
//! ```text
//! 12.345s  INFO cluster worker_joined worker=1 port=41233
//! ```
//!
//! All structured emission goes through [`super::trace::event`]; this
//! module owns only the level state and the stderr rendering.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Log/trace severity, ordered from most to least severe.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Unrecoverable or surprising failures.
    Error = 0,
    /// Faults the system absorbed (worker death, resubmission).
    Warn = 1,
    /// Lifecycle milestones (join, admit, done).
    Info = 2,
    /// Per-chunk decision detail.
    Debug = 3,
    /// Per-tile / per-message firehose.
    Trace = 4,
}

impl Level {
    /// Lower-case name, as accepted by `--log-level`.
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
            Level::Trace => "trace",
        }
    }

    /// Parse a level name (case-insensitive). `off` maps to `Error` with
    /// the stderr sink disabled separately.
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    fn from_u8(v: u8) -> Level {
        match v {
            0 => Level::Error,
            1 => Level::Warn,
            3 => Level::Debug,
            4 => Level::Trace,
            _ => Level::Info,
        }
    }
}

/// 255 = uninitialized (resolve from env on first use).
static LOG_LEVEL: AtomicU8 = AtomicU8::new(255);

fn env_default() -> Level {
    static FROM_ENV: OnceLock<Level> = OnceLock::new();
    *FROM_ENV.get_or_init(|| {
        std::env::var("PYRAMIDAI_LOG")
            .ok()
            .and_then(|s| Level::parse(&s))
            .unwrap_or(Level::Info)
    })
}

/// Current stderr log level. Defaults to `PYRAMIDAI_LOG`, else `info`.
pub fn log_level() -> Level {
    let raw = LOG_LEVEL.load(Ordering::Relaxed);
    if raw == 255 {
        let l = env_default();
        LOG_LEVEL.store(l as u8, Ordering::Relaxed);
        l
    } else {
        Level::from_u8(raw)
    }
}

/// Override the stderr log level (e.g. from `--log-level`).
pub fn set_log_level(l: Level) {
    LOG_LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Would a record at `l` print to stderr right now?
pub fn log_enabled(l: Level) -> bool {
    l <= log_level()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip_and_aliases() {
        for l in [Level::Error, Level::Warn, Level::Info, Level::Debug, Level::Trace] {
            assert_eq!(Level::parse(l.as_str()), Some(l));
            assert_eq!(Level::parse(&l.as_str().to_uppercase()), Some(l));
        }
        assert_eq!(Level::parse("warning"), Some(Level::Warn));
        assert_eq!(Level::parse("nope"), None);
    }

    #[test]
    fn severity_orders_error_lowest() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
        assert!(Level::Debug < Level::Trace);
    }
}
