//! Merge per-process JSONL trace files into a Chrome trace-event file
//! (loadable in Perfetto / `chrome://tracing`) plus text summaries.
//!
//! Input: every `trace-*.jsonl` under a directory, one JSON record per
//! line in the [`super::trace::TraceRecord`] schema. Records are merged
//! and sorted by their wall-anchored timestamps, so events from the
//! leader and external worker processes interleave correctly on one
//! timeline.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;
use crate::util::stats::percentile;

/// Required keys of one JSONL trace record; [`validate_record`] enforces
/// them, and CI round-trips a real run through this check.
pub const REQUIRED_KEYS: &[&str] = &["ts", "pid", "tid", "proc", "lvl", "sub", "ev", "f"];

/// Check one parsed JSONL record against the schema. Returns a
/// description of the first violation, if any.
pub fn validate_record(rec: &Json) -> std::result::Result<(), String> {
    for k in REQUIRED_KEYS {
        if rec.opt(k).is_none() {
            return Err(format!("missing key {k:?}"));
        }
    }
    for k in ["ts", "pid", "tid"] {
        if rec.get(k).unwrap().as_u64().is_err() {
            return Err(format!("key {k:?} is not an unsigned integer"));
        }
    }
    for k in ["proc", "lvl", "sub", "ev"] {
        if rec.get(k).unwrap().as_str().is_err() {
            return Err(format!("key {k:?} is not a string"));
        }
    }
    if rec.get("f").unwrap().as_obj().is_err() {
        return Err("key \"f\" is not an object".to_string());
    }
    Ok(())
}

/// Load and merge every `trace-*.jsonl` under `dir`, sorted by
/// timestamp. Fails on unparseable lines or schema violations (line
/// numbers included), so it doubles as the CI validator.
pub fn merge_dir(dir: &Path) -> Result<Vec<Json>> {
    let mut records = Vec::new();
    let mut files = 0usize;
    for entry in std::fs::read_dir(dir).with_context(|| format!("read {}", dir.display()))? {
        let path = entry?.path();
        let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if !(name.starts_with("trace-") && name.ends_with(".jsonl")) {
            continue;
        }
        files += 1;
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))?;
        for (i, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let rec = Json::parse(line)
                .with_context(|| format!("{}:{}", path.display(), i + 1))?;
            validate_record(&rec)
                .map_err(|e| anyhow::anyhow!("{}:{}: {e}", path.display(), i + 1))?;
            records.push(rec);
        }
    }
    anyhow::ensure!(files > 0, "no trace-*.jsonl files under {}", dir.display());
    records.sort_by(|a, b| {
        let ta = a.get("ts").unwrap().as_u64().unwrap();
        let tb = b.get("ts").unwrap().as_u64().unwrap();
        ta.cmp(&tb)
    });
    Ok(records)
}

/// Convert merged records to the Chrome trace-event JSON object
/// (`{"traceEvents": [...]}`). Spans (`dur` set) become complete `"X"`
/// events; the rest become instant `"i"` events. Per-process metadata
/// events name each pid after its recorded role.
pub fn to_chrome_trace(records: &[Json]) -> Json {
    let mut events = Vec::new();
    let mut proc_names: BTreeMap<u64, String> = BTreeMap::new();
    for rec in records {
        let pid = rec.get("pid").unwrap().as_u64().unwrap();
        let proc = rec.get("proc").unwrap().as_str().unwrap();
        proc_names.entry(pid).or_insert_with(|| proc.to_string());
        let sub = rec.get("sub").unwrap().as_str().unwrap();
        let ev = rec.get("ev").unwrap().as_str().unwrap();
        let mut e = Json::obj()
            .set("name", format!("{sub}.{ev}"))
            .set("cat", sub)
            .set("ts", rec.get("ts").unwrap().as_f64().unwrap())
            .set("pid", pid as f64)
            .set("tid", rec.get("tid").unwrap().as_f64().unwrap())
            .set("args", rec.get("f").unwrap().clone());
        e = match rec.opt("dur") {
            Some(d) => e
                .set("ph", "X")
                .set("dur", d.as_f64().unwrap_or(0.0))
                // "X" events describe [ts-dur, ts] here: records are
                // stamped at span *end*, Chrome wants the start.
                .set(
                    "ts",
                    rec.get("ts").unwrap().as_f64().unwrap() - d.as_f64().unwrap_or(0.0),
                ),
            None => e.set("ph", "i").set("s", "t"),
        };
        events.push(e);
    }
    for (pid, name) in proc_names {
        events.push(
            Json::obj()
                .set("name", "process_name")
                .set("ph", "M")
                .set("pid", pid as f64)
                .set("args", Json::obj().set("name", name)),
        );
    }
    Json::obj()
        .set("traceEvents", Json::Arr(events))
        .set("displayTimeUnit", "ms")
}

/// Per-(subsystem, event) aggregate over merged records.
#[derive(Debug, Clone)]
pub struct EventSummary {
    /// `"cluster"`, `"sched"`, ...
    pub sub: String,
    /// Event name.
    pub ev: String,
    /// Occurrences.
    pub count: usize,
    /// Span durations in µs (empty for instant events).
    pub durs_us: Vec<f64>,
}

impl EventSummary {
    /// p-th percentile of span durations (NaN when instant-only).
    pub fn dur_percentile(&self, p: f64) -> f64 {
        percentile(&self.durs_us, p)
    }
}

/// Aggregate merged records per (subsystem, event), sorted by subsystem
/// then event name.
pub fn summarize(records: &[Json]) -> Vec<EventSummary> {
    let mut map: BTreeMap<(String, String), EventSummary> = BTreeMap::new();
    for rec in records {
        let sub = rec.get("sub").unwrap().as_str().unwrap().to_string();
        let ev = rec.get("ev").unwrap().as_str().unwrap().to_string();
        let entry = map
            .entry((sub.clone(), ev.clone()))
            .or_insert_with(|| EventSummary {
                sub,
                ev,
                count: 0,
                durs_us: Vec::new(),
            });
        entry.count += 1;
        if let Some(d) = rec.opt("dur") {
            entry.durs_us.push(d.as_f64().unwrap_or(0.0));
        }
    }
    map.into_values().collect()
}

/// One step of a chunk's cross-process life.
#[derive(Debug, Clone)]
pub struct TimelineStep {
    /// Wall-anchored µs timestamp.
    pub ts_us: u64,
    /// Role of the emitting process.
    pub proc: String,
    /// Event name (`chunk_dealt`, `chunk_resubmitted`, `chunk_done`, ...).
    pub ev: String,
    /// Worker id involved, when the record carried one.
    pub worker: Option<u64>,
}

/// Reconstruct per-chunk timelines: every record whose fields carry a
/// `key` (the chunk routing key), grouped by key, in timestamp order.
pub fn chunk_timelines(records: &[Json]) -> BTreeMap<u64, Vec<TimelineStep>> {
    let mut out: BTreeMap<u64, Vec<TimelineStep>> = BTreeMap::new();
    for rec in records {
        let f = rec.get("f").unwrap();
        let Some(key) = f.opt("key").and_then(|k| k.as_u64().ok()) else {
            continue;
        };
        out.entry(key).or_default().push(TimelineStep {
            ts_us: rec.get("ts").unwrap().as_u64().unwrap(),
            proc: rec.get("proc").unwrap().as_str().unwrap().to_string(),
            ev: rec.get("ev").unwrap().as_str().unwrap().to_string(),
            worker: f.opt("worker").and_then(|w| w.as_u64().ok()),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::log::Level;
    use crate::obs::trace::{FieldVal, TraceRecord};

    fn rec(ts: u64, ev: &'static str, dur: Option<u64>, key: Option<u64>) -> Json {
        let mut fields: Vec<(&'static str, FieldVal)> = Vec::new();
        if let Some(k) = key {
            fields.push(("key", FieldVal::U(k)));
        }
        TraceRecord {
            ts_us: ts,
            pid: 100,
            tid: 1,
            level: Level::Info,
            sub: "cluster",
            ev,
            dur_us: dur,
            fields,
        }
        .to_json()
    }

    #[test]
    fn validate_accepts_real_records_and_rejects_broken_ones() {
        let good = rec(5, "chunk_dealt", None, Some(9));
        assert!(validate_record(&good).is_ok());
        let bad = Json::obj().set("ts", 1.0);
        assert!(validate_record(&bad).is_err());
        let wrong_type = Json::parse(
            r#"{"ts":"soon","pid":1,"tid":1,"proc":"x","lvl":"info","sub":"s","ev":"e","f":{}}"#,
        )
        .unwrap();
        assert!(validate_record(&wrong_type).is_err());
    }

    #[test]
    fn chrome_conversion_spans_and_instants() {
        let records = vec![rec(100, "chunk_exec", Some(40), Some(1)), rec(10, "chunk_dealt", None, Some(1))];
        let chrome = to_chrome_trace(&records);
        let events = chrome.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 records + 1 process_name metadata event
        assert_eq!(events.len(), 3);
        let span = &events[0];
        assert_eq!(span.get("ph").unwrap().as_str().unwrap(), "X");
        // stamped at end ⇒ chrome ts is start = 100 - 40
        assert_eq!(span.get("ts").unwrap().as_u64().unwrap(), 60);
        assert_eq!(span.get("dur").unwrap().as_u64().unwrap(), 40);
        let inst = &events[1];
        assert_eq!(inst.get("ph").unwrap().as_str().unwrap(), "i");
        let meta = &events[2];
        assert_eq!(meta.get("ph").unwrap().as_str().unwrap(), "M");
        // The whole thing must serialize to parseable JSON (round-trip).
        let txt = chrome.to_string();
        assert!(Json::parse(&txt).is_ok());
    }

    #[test]
    fn merge_dir_sorts_across_files_and_validates() {
        let dir = std::env::temp_dir().join(format!("pyr_obs_chrome_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("trace-leader-1.jsonl"),
            format!("{}\n{}\n", rec(30, "b", None, None).to_string(), rec(10, "a", None, None).to_string()),
        )
        .unwrap();
        std::fs::write(
            dir.join("trace-worker-2.jsonl"),
            format!("{}\n", rec(20, "m", None, None).to_string()),
        )
        .unwrap();
        std::fs::write(dir.join("notes.txt"), "ignored").unwrap();
        let merged = merge_dir(&dir).unwrap();
        let evs: Vec<&str> = merged
            .iter()
            .map(|r| r.get("ev").unwrap().as_str().unwrap())
            .collect();
        assert_eq!(evs, vec!["a", "m", "b"]);
        // A malformed line fails the merge with its location.
        std::fs::write(dir.join("trace-bad-3.jsonl"), "{not json\n").unwrap();
        assert!(merge_dir(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn timelines_group_by_chunk_key() {
        let records = vec![
            rec(10, "chunk_dealt", None, Some(7)),
            rec(20, "chunk_dealt", None, Some(8)),
            rec(30, "chunk_resubmitted", None, Some(7)),
            rec(40, "chunk_done", None, Some(7)),
            rec(5, "worker_joined", None, None),
        ];
        let tl = chunk_timelines(&records);
        assert_eq!(tl.len(), 2);
        let seven: Vec<&str> = tl[&7].iter().map(|s| s.ev.as_str()).collect();
        assert_eq!(seven, vec!["chunk_dealt", "chunk_resubmitted", "chunk_done"]);
    }
}
