//! The `pyramidai bench` harness: run the end-to-end service bench and
//! the predcache I/O bench off the shared metrics registry and produce a
//! `BENCH_<n>.json` record for the repo's perf trajectory.
//!
//! Keeping the harness in the library (instead of a `benches/` binary)
//! lets CI and the CLI run the exact same measurement with `--smoke`
//! sizing, and lets the output embed the live [`super::metrics`]
//! snapshot so regressions show up per-subsystem, not just end-to-end.

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::model::oracle::OracleAnalyzer;
use crate::model::{Analyzer, DelayAnalyzer};
use crate::obs::metrics;
use crate::predcache::{PredCache, ShardedPredStore};
use crate::pyramid::tree::Thresholds;
use crate::service::{AnalysisService, JobSource, JobSpec, PolicySpec, ServiceConfig};
use crate::slide::pyramid::Slide;
use crate::synth::slide_gen::{gen_slide_set, DatasetParams};
use crate::util::json::Json;
use crate::util::stats::percentile;

/// Sizing knobs for one bench run.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    /// Smoke mode: seconds-scale sizes for CI gating; full mode sizes
    /// measure meaningfully on a laptop-class machine.
    pub smoke: bool,
}

fn dataset(smoke: bool) -> DatasetParams {
    if smoke {
        DatasetParams {
            tiles_x: 16,
            tiles_y: 8,
            levels: 3,
            tile_px: 64,
        }
    } else {
        DatasetParams {
            tiles_x: 32,
            tiles_y: 16,
            levels: 3,
            tile_px: 64,
        }
    }
}

/// End-to-end service throughput: the same synthetic stream as the
/// `service_throughput` cargo bench (delay-per-tile analyzer over a pool),
/// reported as tiles/s plus job-latency percentiles.
pub fn bench_service_e2e(cfg: BenchConfig) -> Json {
    let (jobs, workers, per_tile) = if cfg.smoke {
        (3usize, 2usize, Duration::from_micros(200))
    } else {
        (9usize, 4usize, Duration::from_millis(2))
    };
    let analyzer: Arc<dyn Analyzer> =
        Arc::new(DelayAnalyzer::new(OracleAnalyzer::new(1), per_tile));
    let svc = AnalysisService::start(
        analyzer,
        ServiceConfig {
            workers,
            queue_capacity: jobs,
            max_in_flight: 4,
            batch: 4,
            policy: PolicySpec::fifo(),
            coalesce: true,
            ..ServiceConfig::default()
        },
    );
    let thr = Thresholds {
        zoom: vec![0.5, 0.35, 0.35],
    };
    for spec in gen_slide_set("bench", jobs, 77, &dataset(cfg.smoke)) {
        svc.submit(JobSpec::new(JobSource::Spec(spec), thr.clone()))
            .expect("queue sized for all jobs");
    }
    let report = svc.shutdown();
    assert_eq!(report.metrics.completed, jobs, "all bench jobs must complete");
    let job_ms: Vec<f64> = report
        .results
        .iter()
        .map(|r| r.run_time.as_secs_f64() * 1e3)
        .collect();
    let chunk = report.sched_metrics.histogram("sched.chunk_latency_us");
    Json::obj()
        .set("jobs", jobs as f64)
        .set("workers", workers as f64)
        .set("tiles", report.metrics.tiles as f64)
        .set("wall_s", report.metrics.wall.as_secs_f64())
        .set("tiles_per_sec", report.metrics.tiles_per_sec())
        .set("job_ms_p50", percentile(&job_ms, 50.0))
        .set("job_ms_p95", percentile(&job_ms, 95.0))
        .set("chunks", chunk.count as f64)
        .set(
            "chunk_us_p50",
            if chunk.count == 0 { 0.0 } else { chunk.percentile(50.0) },
        )
        .set(
            "chunk_us_p95",
            if chunk.count == 0 { 0.0 } else { chunk.percentile(95.0) },
        )
}

/// Predcache shard I/O: collect a synthetic prediction set, time
/// `save_sharded`, then stream every slide back through a zero-budget
/// store (every access decodes off disk), reporting bytes/s and decode
/// percentiles off the global registry.
pub fn bench_predcache_io(cfg: BenchConfig) -> Result<Json> {
    let (slides, rounds) = if cfg.smoke { (3usize, 1usize) } else { (10usize, 3usize) };
    let set: Vec<Slide> = gen_slide_set("benchpc", slides, 91, &dataset(cfg.smoke))
        .into_iter()
        .map(Slide::from_spec)
        .collect();
    let cache = PredCache::collect_set(&set, &OracleAnalyzer::new(1), 16);
    let dir = std::env::temp_dir().join(format!("pyramidai_bench_pc_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir)?;

    let t0 = Instant::now();
    crate::predcache::store::save_sharded(&cache, &dir, 2)?;
    let save_s = t0.elapsed().as_secs_f64();
    let bytes: u64 = std::fs::read_dir(&dir)?
        .filter_map(|e| e.ok())
        .filter_map(|e| e.metadata().ok())
        .map(|m| m.len())
        .sum();

    // Budget 0 ⇒ at most one shard resident: every slide switch streams
    // a shard back off disk, exercising the decode path `rounds` times.
    let store = ShardedPredStore::open_with_budget(&dir, Some(0))?;
    let t1 = Instant::now();
    for _ in 0..rounds {
        for i in 0..store.len() {
            let _ = store.slide(i)?;
        }
    }
    let load_s = t1.elapsed().as_secs_f64();
    let stats = store.stats();
    let decode = metrics::global().histogram("predcache.decode_us").snapshot();
    // With a zero budget each full pass streams every shard once, so the
    // bytes pulled off disk are ≈ the shard set size per round.
    let loaded_bytes = bytes as f64 * rounds as f64;
    std::fs::remove_dir_all(&dir).ok();
    Ok(Json::obj()
        .set("slides", slides as f64)
        .set("shard_bytes", bytes as f64)
        .set("save_s", save_s)
        .set("save_mb_per_s", bytes as f64 / 1e6 / save_s.max(1e-9))
        .set("load_s", load_s)
        .set("load_mb_per_s", loaded_bytes / 1e6 / load_s.max(1e-9))
        .set("loads", stats.loads as f64)
        .set("evictions", stats.evictions as f64)
        .set("decode_count", decode.count as f64)
        .set(
            "decode_us_p50",
            if decode.count == 0 { 0.0 } else { decode.percentile(50.0) },
        )
        .set(
            "decode_us_p95",
            if decode.count == 0 { 0.0 } else { decode.percentile(95.0) },
        ))
}

/// HTTP ingest: sustained submit + poll + stream against a live
/// loopback front-end, one raw `Connection: close` request per call —
/// the cost a `curl`-driven client actually pays, including connection
/// setup, parsing and chunked-stream framing. Reports jobs/s and
/// request-latency percentiles across every request of the run.
pub fn bench_http_ingest(cfg: BenchConfig) -> Result<Json> {
    use std::io::{Read as _, Write as _};
    use std::net::TcpStream;

    use crate::service::http::{HttpConfig, HttpFrontend, TokenTable};

    let (jobs, per_tile) = if cfg.smoke {
        (4usize, Duration::from_micros(200))
    } else {
        (16usize, Duration::from_millis(1))
    };
    let analyzer: Arc<dyn Analyzer> =
        Arc::new(DelayAnalyzer::new(OracleAnalyzer::new(1), per_tile));
    let svc = Arc::new(AnalysisService::start(
        analyzer,
        ServiceConfig {
            workers: 4,
            queue_capacity: jobs,
            max_in_flight: 4,
            batch: 8,
            policy: PolicySpec::fifo(),
            ..ServiceConfig::default()
        },
    ));
    let tokens =
        TokenTable::parse("bench-a lab_a\nbench-b lab_b\n").map_err(anyhow::Error::msg)?;
    let fe = HttpFrontend::start(Arc::clone(&svc), HttpConfig::new("127.0.0.1:0", tokens))
        .map_err(anyhow::Error::msg)?;
    let addr = fe.addr();
    let d = dataset(cfg.smoke);

    let mut req_ms: Vec<f64> = Vec::new();
    let mut request = |raw: String| -> Result<(u16, Vec<u8>)> {
        let t = Instant::now();
        let mut s = TcpStream::connect(addr)?;
        s.write_all(raw.as_bytes())?;
        let mut buf = Vec::new();
        s.read_to_end(&mut buf)?;
        req_ms.push(t.elapsed().as_secs_f64() * 1e3);
        let head = buf
            .windows(4)
            .position(|w| w == b"\r\n\r\n")
            .ok_or_else(|| anyhow::anyhow!("response without head"))?;
        if buf.len() < 12 || !buf.starts_with(b"HTTP/1.1 ") {
            anyhow::bail!("malformed status line");
        }
        let status: u16 = std::str::from_utf8(&buf[9..12])?.parse()?;
        Ok((status, buf.split_off(head + 4)))
    };

    let t0 = Instant::now();
    let mut ids = Vec::new();
    for i in 0..jobs {
        let body = Json::obj()
            .set(
                "slide",
                Json::obj()
                    .set("id", format!("bench_http_{i}"))
                    .set("seed", 300 + i as u64)
                    .set("tiles_x", d.tiles_x)
                    .set("tiles_y", d.tiles_y)
                    .set("levels", d.levels)
                    .set("tile_px", d.tile_px)
                    .set("kind", ["large_tumor", "small_scattered", "negative"][i % 3]),
            )
            .to_string();
        let token = ["bench-a", "bench-b"][i % 2];
        let raw = format!(
            "POST /v1/jobs HTTP/1.1\r\nHost: b\r\nAuthorization: Bearer {token}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        );
        let (status, resp) = request(raw)?;
        if status != 201 {
            anyhow::bail!("submit {i} answered {status}");
        }
        let v = Json::parse(std::str::from_utf8(&resp)?)?;
        ids.push((v.get("job")?.as_u64()?, token));
    }
    let mut stream_bytes = 0usize;
    for &(id, token) in &ids {
        let raw = format!(
            "GET /v1/jobs/{id} HTTP/1.1\r\nHost: b\r\nAuthorization: Bearer {token}\r\nConnection: close\r\n\r\n"
        );
        let (status, _) = request(raw)?;
        if status != 200 {
            anyhow::bail!("status poll for job {id} answered {status}");
        }
        let raw = format!(
            "GET /v1/jobs/{id}/result HTTP/1.1\r\nHost: b\r\nAuthorization: Bearer {token}\r\nConnection: close\r\n\r\n"
        );
        let (status, body) = request(raw)?;
        if status != 200 {
            anyhow::bail!("result stream for job {id} answered {status}");
        }
        if !body.windows(11).any(|w| w == b"\"done\":true") {
            anyhow::bail!("stream for job {id} ended without a terminal line");
        }
        stream_bytes += body.len();
    }
    let wall = t0.elapsed().as_secs_f64();
    fe.stop();
    let report = Arc::try_unwrap(svc)
        .map_err(|_| anyhow::anyhow!("front-end left live service handles"))?
        .shutdown();
    if report.metrics.completed != jobs {
        anyhow::bail!(
            "{} of {jobs} HTTP-submitted jobs completed",
            report.metrics.completed
        );
    }
    let requests = req_ms.len();
    Ok(Json::obj()
        .set("jobs", jobs as f64)
        .set("requests", requests as f64)
        .set("wall_s", wall)
        .set("jobs_per_sec", jobs as f64 / wall.max(1e-9))
        .set("req_ms_p50", percentile(&req_ms, 50.0))
        .set("req_ms_p95", percentile(&req_ms, 95.0))
        .set("stream_bytes", stream_bytes as f64)
        .set(
            "stream_mb_per_s",
            stream_bytes as f64 / 1e6 / wall.max(1e-9),
        ))
}

/// Tile-synthesis hot path: render the same level-0 tiles once through
/// the scalar per-pixel `Texture::pixel` reference and once through the
/// flat-array [`TileRenderer`](crate::synth::render::TileRenderer), and
/// report ns/pixel for both plus the speedup. The two outputs are
/// asserted bit-identical first, so the numbers always compare the same
/// work (the golden tests in `synth/render.rs` are the real gate; this
/// is a belt on top of suspenders).
pub fn bench_synth_tile(cfg: BenchConfig) -> Json {
    use crate::synth::render::TileRenderer;
    use crate::synth::slide_gen::{SlideKind, SlideSpec};
    use crate::synth::texture::{Texture, TextureParams};

    let d = dataset(cfg.smoke);
    let reps = if cfg.smoke { 1usize } else { 4 };
    // SmallScattered is the renderer's hardest case: the most blobs and
    // the most nuclei lattice work per pixel.
    let spec = SlideSpec::new(
        "benchsynth",
        4321,
        d.tiles_x,
        d.tiles_y,
        d.levels,
        d.tile_px,
        SlideKind::SmallScattered,
    );
    let (tissue, tumor, distractor) = spec.fields();
    let params = TextureParams::default();
    let tex = Texture {
        seed: spec.seed,
        tissue: &tissue,
        tumor: &tumor,
        distractor: &distractor,
        params: &params,
    };
    let tp = spec.tile_px;
    let (w_px, h_px) = (spec.tiles_x * tp, spec.tiles_y * tp);
    // A diagonal band of level-0 tiles: tissue, tumor and background mix.
    let tiles: Vec<(usize, usize)> = (0..if cfg.smoke { 4usize } else { 8 })
        .map(|i| (i * 2 % spec.tiles_x, i % spec.tiles_y))
        .collect();
    let px_total = (tiles.len() * tp * tp * reps) as f64;

    // Scalar reference: one full `Texture::pixel` call tree per pixel.
    let mut scalar_out: Vec<f32> = Vec::with_capacity(tp * tp * 3);
    let t0 = Instant::now();
    for _ in 0..reps {
        for &(tx, ty) in &tiles {
            scalar_out.clear();
            for py in ty * tp..(ty + 1) * tp {
                for px in tx * tp..(tx + 1) * tp {
                    scalar_out.extend_from_slice(&tex.pixel(0, px, py, w_px, h_px));
                }
            }
        }
    }
    let scalar_ns = t0.elapsed().as_nanos() as f64 / px_total;

    // Hot path: the flat-array renderer `Slide::tile_pixels` actually
    // runs, one renderer reused across all tiles (the level-sweep shape).
    let mut r = TileRenderer::new(&tex, 0, w_px, h_px);
    let mut fast_out = Vec::new();
    let t1 = Instant::now();
    for _ in 0..reps {
        for &(tx, ty) in &tiles {
            fast_out = r.render_rect(tx * tp, ty * tp, tp, tp);
        }
    }
    let fast_ns = t1.elapsed().as_nanos() as f64 / px_total;

    // Bit-identity on the last tile rendered by both loops.
    let (tx, ty) = *tiles.last().expect("bench tile set is never empty");
    scalar_out.clear();
    for py in ty * tp..(ty + 1) * tp {
        for px in tx * tp..(tx + 1) * tp {
            scalar_out.extend_from_slice(&tex.pixel(0, px, py, w_px, h_px));
        }
    }
    assert_eq!(scalar_out, fast_out, "bench paths diverged — numbers are void");

    Json::obj()
        .set("tiles", tiles.len() as f64)
        .set("reps", reps as f64)
        .set("tile_px", tp as f64)
        .set("scalar_ns_per_px", scalar_ns)
        .set("fast_ns_per_px", fast_ns)
        .set("speedup", scalar_ns / fast_ns.max(1e-9))
}

/// Protocol framing hot path: round-trip a representative `ChunkDone`
/// (the highest-volume cluster message — one per chunk, carrying the
/// probability slice) through the JSON v1 encoding and through the
/// binary frame v2 encoding, reporting ns/message for both. The binary
/// path reuses one [`FrameBuf`](crate::cluster::framev2::FrameBuf)
/// exactly as a worker's upload loop does.
pub fn bench_proto_framing(cfg: BenchConfig) -> Json {
    use crate::cluster::framev2::{decode_body, FrameBuf};
    use crate::cluster::proto::Msg;

    let msgs = if cfg.smoke { 200usize } else { 5000 };
    // 128 probabilities ≈ a whole level-1 frontier chunk of the full-size
    // bench slide; realistic, not flattering (bigger slices favor v2).
    let probs_len = 128usize;
    let probs: Vec<f32> = (0..probs_len).map(|i| (i % 97) as f32 / 96.0).collect();
    let msg = Msg::ChunkDone {
        key: 0x0123_4567_89AB_CDEF,
        worker: 3,
        probs,
        trace: 42,
    };

    // v1: length-prefixed JSON — serialize to text, parse, rebuild.
    let mut sink = 0usize;
    let json_bytes = msg.to_json().to_string().len();
    let t0 = Instant::now();
    for _ in 0..msgs {
        let text = msg.to_json().to_string();
        let back = Msg::from_json(&Json::parse(&text).expect("own JSON parses"))
            .expect("own JSON decodes");
        if let Msg::ChunkDone { probs, .. } = back {
            sink += probs.len();
        }
    }
    let json_ns = t0.elapsed().as_nanos() as f64 / msgs as f64;

    // v2: binary frame into a reused buffer, then decode the body.
    let mut fb = FrameBuf::new();
    let binary_bytes = fb.encode_frame(&msg).expect("hot message encodes").len();
    let t1 = Instant::now();
    for _ in 0..msgs {
        let frame = fb.encode_frame(&msg).expect("hot message encodes");
        let back = decode_body(&frame[4..]).expect("own frame decodes");
        if let Msg::ChunkDone { probs, .. } = back {
            sink += probs.len();
        }
    }
    let binary_ns = t1.elapsed().as_nanos() as f64 / msgs as f64;
    assert_eq!(sink, 2 * msgs * probs_len, "round trips must preserve the slice");

    Json::obj()
        .set("msgs", msgs as f64)
        .set("probs_per_msg", probs_len as f64)
        .set("json_bytes_per_msg", json_bytes as f64)
        .set("binary_bytes_per_msg", binary_bytes as f64)
        .set("json_ns_per_msg", json_ns)
        .set("binary_ns_per_msg", binary_ns)
        .set("speedup", json_ns / binary_ns.max(1e-9))
}

/// Run every bench and assemble the `BENCH_<n>.json` document, embedding
/// the end-of-run global metrics snapshot.
pub fn run_benches(cfg: BenchConfig, label: u64) -> Result<Json> {
    let service = bench_service_e2e(cfg);
    let predcache = bench_predcache_io(cfg)?;
    let http = bench_http_ingest(cfg)?;
    let synth = bench_synth_tile(cfg);
    let framing = bench_proto_framing(cfg);
    Ok(Json::obj()
        .set("schema", "pyramidai-bench-v1")
        .set("label", label as f64)
        .set("smoke", cfg.smoke)
        .set(
            "benches",
            Json::obj()
                .set("service_e2e", service)
                .set("predcache_io", predcache)
                .set("http_ingest", http)
                .set("synth_tile", synth)
                .set("proto_framing", framing),
        )
        .set("metrics", metrics::global().snapshot().to_json()))
}

/// Validate a `BENCH_<n>.json` document (CI gate for the checked-in
/// trajectory): schema tag, label, and the required throughput/latency
/// keys of both benches.
pub fn validate_bench_json(doc: &Json) -> std::result::Result<(), String> {
    if doc.opt("schema").and_then(|s| s.as_str().ok().map(str::to_string))
        != Some("pyramidai-bench-v1".to_string())
    {
        return Err("missing or wrong schema tag".into());
    }
    doc.opt("label")
        .and_then(|l| l.as_u64().ok())
        .ok_or("missing label")?;
    let benches = doc.opt("benches").ok_or("missing benches")?;
    let svc = benches.opt("service_e2e").ok_or("missing benches.service_e2e")?;
    for k in ["tiles_per_sec", "wall_s", "job_ms_p50", "job_ms_p95"] {
        if svc.opt(k).and_then(|v| v.as_f64().ok()).is_none() {
            return Err(format!("service_e2e missing {k}"));
        }
    }
    let pc = benches.opt("predcache_io").ok_or("missing benches.predcache_io")?;
    for k in ["load_mb_per_s", "save_s", "decode_us_p50", "decode_us_p95"] {
        if pc.opt(k).and_then(|v| v.as_f64().ok()).is_none() {
            return Err(format!("predcache_io missing {k}"));
        }
    }
    // http_ingest joined the suite later; docs from before it are still
    // valid v1, but when the section is present its keys are mandatory.
    if let Some(http) = benches.opt("http_ingest") {
        for k in ["jobs_per_sec", "req_ms_p50", "req_ms_p95", "wall_s"] {
            if http.opt(k).and_then(|v| v.as_f64().ok()).is_none() {
                return Err(format!("http_ingest missing {k}"));
            }
        }
    }
    // Same deal for the hot-path sections (synth_tile / proto_framing):
    // optional for pre-existing docs, keys mandatory once present.
    if let Some(st) = benches.opt("synth_tile") {
        for k in ["scalar_ns_per_px", "fast_ns_per_px", "speedup"] {
            if st.opt(k).and_then(|v| v.as_f64().ok()).is_none() {
                return Err(format!("synth_tile missing {k}"));
            }
        }
    }
    if let Some(pf) = benches.opt("proto_framing") {
        for k in ["json_ns_per_msg", "binary_ns_per_msg", "speedup"] {
            if pf.opt(k).and_then(|v| v.as_f64().ok()).is_none() {
                return Err(format!("proto_framing missing {k}"));
            }
        }
    }
    Ok(())
}

/// Next free label in `dir`: one past the highest existing
/// `BENCH_<n>.json`, or 0 when the trajectory is empty.
pub fn next_bench_label(dir: &Path) -> u64 {
    let mut next = 0u64;
    if let Ok(entries) = std::fs::read_dir(dir) {
        for e in entries.filter_map(|e| e.ok()) {
            let name = e.file_name();
            let name = name.to_string_lossy();
            if let Some(n) = name
                .strip_prefix("BENCH_")
                .and_then(|s| s.strip_suffix(".json"))
                .and_then(|s| s.parse::<u64>().ok())
            {
                next = next.max(n + 1);
            }
        }
    }
    next
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_bench_produces_valid_doc() {
        let doc = run_benches(BenchConfig { smoke: true }, 3).unwrap();
        validate_bench_json(&doc).expect("smoke bench doc validates");
        assert_eq!(doc.get("label").unwrap().as_u64().unwrap(), 3);
        let tps = doc
            .get("benches")
            .unwrap()
            .get("service_e2e")
            .unwrap()
            .get("tiles_per_sec")
            .unwrap()
            .as_f64()
            .unwrap();
        assert!(tps > 0.0);
        let jps = doc
            .get("benches")
            .unwrap()
            .get("http_ingest")
            .unwrap()
            .get("jobs_per_sec")
            .unwrap()
            .as_f64()
            .unwrap();
        assert!(jps > 0.0, "http ingest bench must push jobs through");
        for (section, key) in [
            ("synth_tile", "fast_ns_per_px"),
            ("proto_framing", "binary_ns_per_msg"),
        ] {
            let v = doc
                .get("benches")
                .unwrap()
                .get(section)
                .unwrap()
                .get(key)
                .unwrap()
                .as_f64()
                .unwrap();
            assert!(v > 0.0, "{section}.{key} must be a real measurement");
        }
        // Round-trip through text like the checked-in file will.
        let reparsed = Json::parse(&doc.to_pretty()).unwrap();
        validate_bench_json(&reparsed).unwrap();
    }

    #[test]
    fn validator_gates_hot_path_sections_when_present() {
        let svc = Json::obj()
            .set("tiles_per_sec", 1.0)
            .set("wall_s", 1.0)
            .set("job_ms_p50", 1.0)
            .set("job_ms_p95", 1.0);
        let pc = Json::obj()
            .set("load_mb_per_s", 1.0)
            .set("save_s", 1.0)
            .set("decode_us_p50", 1.0)
            .set("decode_us_p95", 1.0);
        let doc = |benches: Json| {
            Json::obj()
                .set("schema", "pyramidai-bench-v1")
                .set("label", 1.0)
                .set("benches", benches)
        };
        let base = Json::obj()
            .set("service_e2e", svc)
            .set("predcache_io", pc);
        // Docs from before the hot-path sections stay valid v1.
        validate_bench_json(&doc(base.clone())).unwrap();
        // But a present section with a missing key is rejected.
        let bad = doc(base.clone().set(
            "synth_tile",
            Json::obj().set("scalar_ns_per_px", 1.0).set("fast_ns_per_px", 1.0),
        ));
        assert!(validate_bench_json(&bad).unwrap_err().contains("synth_tile"));
        let bad = doc(base.set("proto_framing", Json::obj().set("speedup", 2.0)));
        assert!(validate_bench_json(&bad).unwrap_err().contains("proto_framing"));
    }

    #[test]
    fn label_scan_picks_next_free() {
        let dir = std::env::temp_dir().join(format!("pyr_bench_label_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        assert_eq!(next_bench_label(&dir), 0);
        std::fs::write(dir.join("BENCH_0.json"), "{}").unwrap();
        std::fs::write(dir.join("BENCH_4.json"), "{}").unwrap();
        std::fs::write(dir.join("BENCH_x.json"), "{}").unwrap();
        assert_eq!(next_bench_label(&dir), 5);
        std::fs::remove_dir_all(&dir).ok();
    }
}
