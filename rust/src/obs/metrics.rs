//! Global metrics registry: atomic counters, gauges and log-bucketed
//! histograms, snapshotable mid-run.
//!
//! The registry is name-keyed (`"cluster.chunks_dealt"`) and get-or-create:
//! any subsystem may ask for a handle and increment it without coordination.
//! Handles are `Arc`s over plain atomics, so the hot path after the first
//! lookup is a single `fetch_add` — hot loops should resolve handles once
//! at construction time and keep them.
//!
//! Histograms are log-bucketed (16 sub-buckets per power of two, ≈4.5 %
//! relative bucket width) so p50/p95/p99 can be estimated without storing
//! samples; a histogram is ~8 KiB of atomics regardless of sample count.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::util::json::Json;

/// Monotonically increasing event count.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Add 1.
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current total.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous signed level (queue depth, resident bytes, ...).
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    /// Set to an absolute value.
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Add a (possibly negative) delta.
    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    /// Current level.
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Bucket layout: values `< 16` are exact (one bucket per integer); above
/// that, 16 sub-buckets per power of two. Index space tops out at u64::MAX.
const HIST_BUCKETS: usize = 976;

fn bucket_index(v: u64) -> usize {
    if v < 16 {
        v as usize
    } else {
        let exp = 63 - v.leading_zeros() as usize; // >= 4
        let sub = ((v >> (exp - 4)) & 0xF) as usize;
        (exp - 3) * 16 + sub
    }
}

/// Lower bound of the value range covered by bucket `i`.
fn bucket_lower(i: usize) -> u64 {
    if i < 16 {
        i as u64
    } else {
        let exp = i / 16 + 3;
        let sub = (i % 16) as u64;
        (16 + sub) << (exp - 4)
    }
}

/// Upper bound (exclusive) of bucket `i`.
fn bucket_upper(i: usize) -> u64 {
    if i + 1 >= HIST_BUCKETS {
        u64::MAX
    } else {
        bucket_lower(i + 1)
    }
}

/// Log-bucketed histogram of u64 samples (durations in µs, sizes in
/// bytes, ...). Records into fixed atomic buckets; percentiles are
/// estimated by midpoint interpolation inside the matched bucket.
pub struct Histogram {
    buckets: Box<[AtomicU64; HIST_BUCKETS]>,
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
}

impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Histogram")
            .field("count", &self.count.load(Ordering::Relaxed))
            .finish()
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// Empty histogram.
    pub fn new() -> Self {
        // `AtomicU64` is not Copy; build the array through a Vec.
        let v: Vec<AtomicU64> = (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect();
        let buckets: Box<[AtomicU64; HIST_BUCKETS]> =
            v.into_boxed_slice().try_into().expect("bucket count");
        Self {
            buckets,
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }

    /// Record one sample.
    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Record a duration as whole microseconds.
    pub fn record_duration(&self, d: std::time::Duration) {
        self.record(d.as_micros() as u64);
    }

    /// Samples recorded so far.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Point-in-time copy of the bucket state.
    pub fn snapshot(&self) -> HistogramSnapshot {
        let count = self.count.load(Ordering::Relaxed);
        let buckets = self
            .buckets
            .iter()
            .enumerate()
            .filter_map(|(i, b)| {
                let n = b.load(Ordering::Relaxed);
                (n > 0).then_some((i, n))
            })
            .collect();
        HistogramSnapshot {
            count,
            sum: self.sum.load(Ordering::Relaxed),
            min: if count == 0 {
                0
            } else {
                self.min.load(Ordering::Relaxed)
            },
            max: self.max.load(Ordering::Relaxed),
            buckets,
        }
    }
}

/// Frozen histogram state: sparse `(bucket index, count)` pairs plus
/// count/sum/min/max. Mergeable and queryable for percentiles.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct HistogramSnapshot {
    /// Total samples.
    pub count: u64,
    /// Sum of all samples.
    pub sum: u64,
    /// Smallest sample (0 when empty).
    pub min: u64,
    /// Largest sample (0 when empty).
    pub max: u64,
    /// Non-empty buckets as `(index, count)`, ascending by index.
    pub buckets: Vec<(usize, u64)>,
}

impl HistogramSnapshot {
    /// Estimate the p-th percentile (p in [0, 100]). Returns the midpoint
    /// of the bucket containing the target rank, clamped to the observed
    /// min/max so single-sample and narrow distributions stay exact-ish.
    /// NaN when empty.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let target = (p / 100.0 * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for &(i, n) in &self.buckets {
            seen += n;
            if seen >= target {
                let lo = bucket_lower(i) as f64;
                let hi = bucket_upper(i).min(self.max.max(1)) as f64;
                let mid = (lo + hi) / 2.0;
                return mid.clamp(self.min as f64, self.max as f64);
            }
        }
        self.max as f64
    }

    /// Mean of all samples (NaN when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Merge another snapshot into this one (bucket-wise addition), e.g.
    /// to combine per-process histograms of the same metric.
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        if other.count == 0 {
            return;
        }
        let mut merged: BTreeMap<usize, u64> = self.buckets.iter().copied().collect();
        for &(i, n) in &other.buckets {
            *merged.entry(i).or_insert(0) += n;
        }
        self.buckets = merged.into_iter().collect();
        if self.count == 0 {
            self.min = other.min;
        } else {
            self.min = self.min.min(other.min);
        }
        self.max = self.max.max(other.max);
        self.count += other.count;
        self.sum += other.sum;
    }

    /// JSON form used by `pyramidai bench` and metric dumps.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("count", self.count as f64)
            .set("sum", self.sum as f64)
            .set("min", self.min as f64)
            .set("max", self.max as f64)
            .set("mean", if self.count == 0 { 0.0 } else { self.mean() })
            .set("p50", if self.count == 0 { 0.0 } else { self.percentile(50.0) })
            .set("p95", if self.count == 0 { 0.0 } else { self.percentile(95.0) })
            .set("p99", if self.count == 0 { 0.0 } else { self.percentile(99.0) })
    }
}

/// Name-keyed registry of counters, gauges and histograms.
///
/// A process has one [`global()`] registry; scoped registries (the
/// scheduler's, the simulator's) exist where a run needs its own isolated
/// totals — e.g. the sim-vs-service parity check.
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl Registry {
    /// Empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Get or create the counter `name`.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = self.counters.lock().unwrap();
        Arc::clone(m.entry(name.to_string()).or_default())
    }

    /// Get or create the gauge `name`.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = self.gauges.lock().unwrap();
        Arc::clone(m.entry(name.to_string()).or_default())
    }

    /// Get or create the histogram `name`.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut m = self.histograms.lock().unwrap();
        Arc::clone(
            m.entry(name.to_string())
                .or_insert_with(|| Arc::new(Histogram::new())),
        )
    }

    /// Point-in-time snapshot of every registered metric.
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            counters: self
                .counters
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.clone(), v.get()))
                .collect(),
            gauges: self
                .gauges
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.clone(), v.get()))
                .collect(),
            histograms: self
                .histograms
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.clone(), v.snapshot()))
                .collect(),
        }
    }
}

/// Frozen registry state: every counter/gauge total and histogram summary
/// at snapshot time.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsSnapshot {
    /// Counter totals by name.
    pub counters: BTreeMap<String, u64>,
    /// Gauge levels by name.
    pub gauges: BTreeMap<String, i64>,
    /// Histogram snapshots by name.
    pub histograms: BTreeMap<String, HistogramSnapshot>,
}

impl MetricsSnapshot {
    /// Counter total (0 when the counter was never touched).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Gauge level (0 when absent).
    pub fn gauge(&self, name: &str) -> i64 {
        self.gauges.get(name).copied().unwrap_or(0)
    }

    /// Histogram snapshot (empty when absent).
    pub fn histogram(&self, name: &str) -> HistogramSnapshot {
        self.histograms.get(name).cloned().unwrap_or_default()
    }

    /// JSON form: `{counters: {...}, gauges: {...}, histograms: {...}}`.
    pub fn to_json(&self) -> Json {
        let mut counters = Json::obj();
        for (k, v) in &self.counters {
            counters = counters.set(k.as_str(), *v as f64);
        }
        let mut gauges = Json::obj();
        for (k, v) in &self.gauges {
            gauges = gauges.set(k.as_str(), *v as f64);
        }
        let mut hists = Json::obj();
        for (k, v) in &self.histograms {
            hists = hists.set(k.as_str(), v.to_json());
        }
        Json::obj()
            .set("counters", counters)
            .set("gauges", gauges)
            .set("histograms", hists)
    }
}

/// The process-wide registry. Cluster, predcache, thread-pool and pyramid
/// instrumentation all record here.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_layout_is_continuous_and_monotone() {
        // Every value maps to a bucket whose [lower, upper) contains it,
        // and indices are non-decreasing in the value.
        let mut prev = 0usize;
        for v in [
            0u64,
            1,
            15,
            16,
            17,
            31,
            32,
            100,
            1_000,
            65_535,
            65_536,
            1 << 30,
            (1 << 40) + 12345,
            u64::MAX / 2,
            u64::MAX,
        ] {
            let i = bucket_index(v);
            assert!(bucket_lower(i) <= v, "lower({i}) <= {v}");
            assert!(v < bucket_upper(i) || i == HIST_BUCKETS - 1, "{v} < upper({i})");
            assert!(i >= prev, "index monotone at {v}");
            prev = i;
        }
    }

    #[test]
    fn histogram_empty() {
        let h = Histogram::new();
        let s = h.snapshot();
        assert_eq!(s.count, 0);
        assert!(s.percentile(50.0).is_nan());
        assert!(s.mean().is_nan());
        assert_eq!(s.min, 0);
        assert_eq!(s.max, 0);
    }

    #[test]
    fn histogram_single_sample_is_exact() {
        let h = Histogram::new();
        h.record(1234);
        let s = h.snapshot();
        assert_eq!(s.count, 1);
        assert_eq!(s.min, 1234);
        assert_eq!(s.max, 1234);
        // clamped to [min, max] ⇒ exact for a single sample
        assert_eq!(s.percentile(0.0), 1234.0);
        assert_eq!(s.percentile(50.0), 1234.0);
        assert_eq!(s.percentile(100.0), 1234.0);
        assert_eq!(s.mean(), 1234.0);
    }

    #[test]
    fn histogram_percentiles_are_monotone_and_bounded() {
        let h = Histogram::new();
        // Skewed distribution: many fast samples, a slow tail.
        for i in 0..1000u64 {
            h.record(10 + i % 50);
        }
        for _ in 0..10 {
            h.record(100_000);
        }
        let s = h.snapshot();
        let ps: Vec<f64> = [1.0, 25.0, 50.0, 75.0, 90.0, 95.0, 99.0, 99.9, 100.0]
            .iter()
            .map(|&p| s.percentile(p))
            .collect();
        for w in ps.windows(2) {
            assert!(w[0] <= w[1], "percentiles must be monotone: {ps:?}");
        }
        assert!(ps[0] >= s.min as f64);
        assert!(*ps.last().unwrap() <= s.max as f64);
        // p50 is inside the fast cluster, p99.9+ reaches the tail bucket.
        assert!(s.percentile(50.0) < 100.0, "p50 {}", s.percentile(50.0));
        assert!(s.percentile(99.9) > 50_000.0, "p99.9 {}", s.percentile(99.9));
    }

    #[test]
    fn histogram_relative_error_is_bounded() {
        let h = Histogram::new();
        for v in [100u64, 1_000, 10_000, 100_000, 1_000_000] {
            for _ in 0..100 {
                h.record(v);
            }
        }
        let s = h.snapshot();
        // p50 of this 5-spike distribution is the 10_000 spike; the
        // bucket midpoint must land within one bucket width (≈ 4.5 %).
        let p50 = s.percentile(50.0);
        assert!((p50 - 10_000.0).abs() / 10_000.0 < 0.05, "p50 {p50}");
    }

    #[test]
    fn snapshot_merge_matches_combined_recording() {
        let a = Histogram::new();
        let b = Histogram::new();
        let both = Histogram::new();
        for v in [5u64, 17, 900, 42] {
            a.record(v);
            both.record(v);
        }
        for v in [1u64, 1_000_000, 33] {
            b.record(v);
            both.record(v);
        }
        let mut m = a.snapshot();
        m.merge(&b.snapshot());
        assert_eq!(m, both.snapshot());
        // Merging an empty snapshot is the identity.
        let before = m.clone();
        m.merge(&HistogramSnapshot::default());
        assert_eq!(m, before);
        // Merging *into* an empty snapshot copies.
        let mut e = HistogramSnapshot::default();
        e.merge(&before);
        assert_eq!(e, before);
    }

    #[test]
    fn registry_get_or_create_and_snapshot() {
        let r = Registry::new();
        r.counter("a.ticks").add(3);
        r.counter("a.ticks").inc();
        r.gauge("a.depth").set(7);
        r.gauge("a.depth").add(-2);
        r.histogram("a.lat").record(50);
        let s = r.snapshot();
        assert_eq!(s.counter("a.ticks"), 4);
        assert_eq!(s.gauge("a.depth"), 5);
        assert_eq!(s.histogram("a.lat").count, 1);
        assert_eq!(s.counter("never.touched"), 0);
        let j = s.to_json();
        assert_eq!(j.get("counters").unwrap().get("a.ticks").unwrap().as_u64().unwrap(), 4);
    }
}
