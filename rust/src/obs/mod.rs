//! Observability: structured tracing, leveled logging, and a global
//! metrics registry — zero external dependencies, one schema end-to-end.
//!
//! The paper's argument is quantitative (how much data each worker
//! touches, where time goes as slides stream through the cluster), so
//! the reproduction needs to *measure itself*: this module is how a
//! chunk's life — dealt → stolen → resubmitted-after-death → done — is
//! reconstructed across leader and worker OS processes, and how perf
//! becomes a versioned artifact (`BENCH_<n>.json`).
//!
//! Layout:
//! - [`log`] — severity levels and the stderr gate
//!   (`--log-level` / `PYRAMIDAI_LOG`);
//! - [`trace`] — span/event records, per-process JSONL sinks
//!   (`--trace-out`), thread-local capture for deterministic tests;
//! - [`metrics`] — atomic counters / gauges / log-bucketed histograms in
//!   name-keyed registries, snapshotable mid-run;
//! - [`chrome`] — merging per-process JSONL into a Chrome trace-event
//!   file (`pyramidai trace`);
//! - [`bench`] — the `pyramidai bench` harness behind the repo's
//!   `BENCH_<n>.json` trajectory.
//!
//! Cross-process propagation: cluster wire messages carry a `trace` id
//! (the chunk's routing key namespace) so records emitted by different
//! processes join on `f.key`/`f.trace`; see `cluster::proto`.
//!
//! Overhead budget: with no sink installed and the level disabled, an
//! [`event`] call is an atomic load and a branch — the `service_throughput`
//! bench stays within 2 % of the uninstrumented baseline.

pub mod bench;
pub mod chrome;
pub mod log;
pub mod metrics;
pub mod trace;

pub use log::{log_enabled, log_level, set_log_level, Level};
pub use metrics::{global as global_metrics, MetricsSnapshot, Registry};
pub use trace::{
    capture, event, flush_trace, init_trace_dir, now_us, set_proc_name, span, span_event,
    FieldVal, SpanGuard, TraceRecord,
};
