//! Span/event tracing with monotonic timestamps and per-process JSONL
//! sinks.
//!
//! Every record carries a wall-anchored monotonic timestamp (unix µs at
//! process start plus a monotonic offset), the pid, a small per-process
//! tid, a process role name ("leader", "worker-3", ...), a level, a
//! subsystem, an event name, optional duration and structured fields.
//! One schema serves three sinks:
//!
//! - **stderr** — rendered as a log line when the level passes
//!   [`super::log::log_enabled`];
//! - **JSONL trace file** — one JSON object per line when a sink was
//!   installed via [`init_trace_dir`] (the `--trace-out` flag);
//! - **thread-local capture** — for deterministic tests
//!   ([`capture`]).
//!
//! With no sink installed and the level disabled, [`event`] is a few
//! atomic loads — cheap enough to leave call sites unconditional.

use std::cell::RefCell;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{Instant, SystemTime};

use super::log::{log_enabled, Level};
use crate::util::json::Json;

/// One structured field value.
#[derive(Debug, Clone, PartialEq)]
pub enum FieldVal {
    /// Unsigned integer.
    U(u64),
    /// Signed integer.
    I(i64),
    /// Float.
    F(f64),
    /// String.
    S(String),
    /// Boolean.
    B(bool),
}

impl FieldVal {
    fn to_json(&self) -> Json {
        match self {
            FieldVal::U(v) => Json::Num(*v as f64),
            FieldVal::I(v) => Json::Num(*v as f64),
            FieldVal::F(v) => Json::Num(*v),
            FieldVal::S(v) => Json::Str(v.clone()),
            FieldVal::B(v) => Json::Bool(*v),
        }
    }

    fn render(&self) -> String {
        match self {
            FieldVal::U(v) => v.to_string(),
            FieldVal::I(v) => v.to_string(),
            FieldVal::F(v) => format!("{v:.3}"),
            FieldVal::S(v) => v.clone(),
            FieldVal::B(v) => v.to_string(),
        }
    }
}

macro_rules! fieldval_from {
    ($($t:ty => $variant:ident as $conv:ty),*) => {
        $(impl From<$t> for FieldVal {
            fn from(v: $t) -> FieldVal { FieldVal::$variant(v as $conv) }
        })*
    };
}
fieldval_from!(u32 => U as u64, u16 => U as u64, u8 => U as u64,
               usize => U as u64, i32 => I as i64);

impl From<u64> for FieldVal {
    fn from(v: u64) -> FieldVal {
        FieldVal::U(v)
    }
}

impl From<i64> for FieldVal {
    fn from(v: i64) -> FieldVal {
        FieldVal::I(v)
    }
}

impl From<f64> for FieldVal {
    fn from(v: f64) -> FieldVal {
        FieldVal::F(v)
    }
}

impl From<bool> for FieldVal {
    fn from(v: bool) -> FieldVal {
        FieldVal::B(v)
    }
}

impl From<&str> for FieldVal {
    fn from(v: &str) -> FieldVal {
        FieldVal::S(v.to_string())
    }
}

impl From<String> for FieldVal {
    fn from(v: String) -> FieldVal {
        FieldVal::S(v)
    }
}

/// One trace record (an event, or a completed span when `dur_us` is set).
#[derive(Debug, Clone)]
pub struct TraceRecord {
    /// Wall-anchored monotonic timestamp, µs since the unix epoch.
    pub ts_us: u64,
    /// OS process id.
    pub pid: u32,
    /// Small per-process thread id (assignment order, not the OS tid).
    pub tid: u64,
    /// Severity.
    pub level: Level,
    /// Subsystem ("cluster", "sched", "predcache", ...).
    pub sub: &'static str,
    /// Event name ("chunk_dealt", "job_admitted", ...).
    pub ev: &'static str,
    /// Span duration in µs; `None` for instant events.
    pub dur_us: Option<u64>,
    /// Structured fields.
    pub fields: Vec<(&'static str, FieldVal)>,
}

impl TraceRecord {
    /// JSONL wire form (one line of a trace file).
    pub fn to_json(&self) -> Json {
        let mut f = Json::obj();
        for (k, v) in &self.fields {
            f = f.set(k, v.to_json());
        }
        let mut j = Json::obj()
            .set("ts", self.ts_us as f64)
            .set("pid", self.pid as f64)
            .set("tid", self.tid as f64)
            .set("proc", proc_name().as_str())
            .set("lvl", self.level.as_str())
            .set("sub", self.sub)
            .set("ev", self.ev)
            .set("f", f);
        if let Some(d) = self.dur_us {
            j = j.set("dur", d as f64);
        }
        j
    }
}

fn epoch() -> &'static (u64, Instant) {
    static EPOCH: OnceLock<(u64, Instant)> = OnceLock::new();
    EPOCH.get_or_init(|| {
        let unix = SystemTime::now()
            .duration_since(SystemTime::UNIX_EPOCH)
            .map(|d| d.as_micros() as u64)
            .unwrap_or(0);
        (unix, Instant::now())
    })
}

/// Current timestamp: unix µs anchored at process start, advanced by the
/// monotonic clock (never goes backwards within a process).
pub fn now_us() -> u64 {
    let (unix, start) = epoch();
    unix + start.elapsed().as_micros() as u64
}

fn tid() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    thread_local! {
        static TID: u64 = NEXT.fetch_add(1, Ordering::Relaxed);
    }
    TID.with(|t| *t)
}

fn proc_name_cell() -> &'static Mutex<String> {
    static NAME: OnceLock<Mutex<String>> = OnceLock::new();
    NAME.get_or_init(|| Mutex::new("main".to_string()))
}

/// Role name of this process in trace output ("leader", "worker-2", ...).
pub fn proc_name() -> String {
    proc_name_cell().lock().unwrap().clone()
}

/// Set the process role name (once, early; workers call this on join).
pub fn set_proc_name(name: &str) {
    *proc_name_cell().lock().unwrap() = name.to_string();
}

static SINK_ACTIVE: AtomicBool = AtomicBool::new(false);

fn sink() -> &'static Mutex<Option<BufWriter<File>>> {
    static SINK: OnceLock<Mutex<Option<BufWriter<File>>>> = OnceLock::new();
    SINK.get_or_init(|| Mutex::new(None))
}

/// Install a per-process JSONL sink under `dir` (created if missing).
/// The file is named `trace-<proc>-<pid>.jsonl`; returns its path. A
/// `trace_meta` record with the process role is written first so the
/// merger can label processes.
pub fn init_trace_dir(dir: &Path, proc_name: &str) -> std::io::Result<PathBuf> {
    std::fs::create_dir_all(dir)?;
    set_proc_name(proc_name);
    let path = dir.join(format!("trace-{}-{}.jsonl", proc_name, std::process::id()));
    let file = File::create(&path)?;
    *sink().lock().unwrap() = Some(BufWriter::new(file));
    SINK_ACTIVE.store(true, Ordering::Release);
    event(Level::Info, "obs", "trace_meta", &[("role", proc_name.into())]);
    Ok(path)
}

/// Flush the JSONL sink (no-op when none is installed). Call before
/// process exit; events are buffered.
pub fn flush_trace() {
    if let Some(w) = sink().lock().unwrap().as_mut() {
        let _ = w.flush();
    }
}

thread_local! {
    static CAPTURE: RefCell<Option<Vec<TraceRecord>>> = const { RefCell::new(None) };
}

fn capture_active() -> bool {
    CAPTURE.with(|c| c.borrow().is_some())
}

/// Run `f` with this thread's trace events captured, returning them
/// alongside the result. Only events emitted on the calling thread are
/// captured; sinks and stderr still receive them as usual.
pub fn capture<R>(f: impl FnOnce() -> R) -> (R, Vec<TraceRecord>) {
    CAPTURE.with(|c| *c.borrow_mut() = Some(Vec::new()));
    let r = f();
    let recs = CAPTURE.with(|c| c.borrow_mut().take().unwrap_or_default());
    (r, recs)
}

/// Would an event at `level` reach any sink right now? Call sites in hot
/// loops may pre-check this, but plain [`event`] calls are already cheap
/// when everything is disabled.
pub fn wanted(level: Level) -> bool {
    log_enabled(level) || SINK_ACTIVE.load(Ordering::Acquire) || capture_active()
}

/// Emit an instant event.
pub fn event(level: Level, sub: &'static str, ev: &'static str, fields: &[(&'static str, FieldVal)]) {
    emit(level, sub, ev, None, fields);
}

/// Emit a completed span of `dur_us` microseconds.
pub fn span_event(
    level: Level,
    sub: &'static str,
    ev: &'static str,
    dur_us: u64,
    fields: &[(&'static str, FieldVal)],
) {
    emit(level, sub, ev, Some(dur_us), fields);
}

fn emit(
    level: Level,
    sub: &'static str,
    ev: &'static str,
    dur_us: Option<u64>,
    fields: &[(&'static str, FieldVal)],
) {
    if !wanted(level) {
        return;
    }
    let rec = TraceRecord {
        ts_us: now_us(),
        pid: std::process::id(),
        tid: tid(),
        level,
        sub,
        ev,
        dur_us,
        fields: fields.to_vec(),
    };
    if log_enabled(level) {
        let (unix, _) = epoch();
        let rel = (rec.ts_us - unix) as f64 / 1e6;
        let mut line = format!("{rel:9.3}s {:>5} {} {}", level.as_str().to_uppercase(), sub, ev);
        for (k, v) in &rec.fields {
            line.push_str(&format!(" {k}={}", v.render()));
        }
        if let Some(d) = dur_us {
            line.push_str(&format!(" dur={d}µs"));
        }
        eprintln!("{line}");
    }
    if SINK_ACTIVE.load(Ordering::Acquire) {
        if let Some(w) = sink().lock().unwrap().as_mut() {
            let _ = writeln!(w, "{}", rec.to_json().to_string());
        }
    }
    CAPTURE.with(|c| {
        if let Some(buf) = c.borrow_mut().as_mut() {
            buf.push(rec);
        }
    });
}

/// RAII span: measures from construction to drop, then emits a record
/// with `dur_us` set. Created via [`span`].
pub struct SpanGuard {
    level: Level,
    sub: &'static str,
    ev: &'static str,
    start: Instant,
    fields: Vec<(&'static str, FieldVal)>,
}

impl SpanGuard {
    /// Attach another field before the span closes.
    pub fn field(&mut self, k: &'static str, v: impl Into<FieldVal>) {
        self.fields.push((k, v.into()));
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let dur = self.start.elapsed().as_micros() as u64;
        emit(self.level, self.sub, self.ev, Some(dur), &self.fields);
    }
}

/// Open a span; the record is emitted when the guard drops.
pub fn span(
    level: Level,
    sub: &'static str,
    ev: &'static str,
    fields: &[(&'static str, FieldVal)],
) -> SpanGuard {
    SpanGuard {
        level,
        sub,
        ev,
        start: Instant::now(),
        fields: fields.to_vec(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capture_collects_this_threads_events() {
        let ((), recs) = capture(|| {
            event(Level::Error, "test", "alpha", &[("k", 1u64.into())]);
            event(Level::Error, "test", "beta", &[("s", "x".into())]);
        });
        let names: Vec<&str> = recs.iter().filter(|r| r.sub == "test").map(|r| r.ev).collect();
        assert_eq!(names, vec!["alpha", "beta"]);
        assert_eq!(recs[0].fields, vec![("k", FieldVal::U(1))]);
    }

    #[test]
    fn span_records_duration() {
        let ((), recs) = capture(|| {
            let mut g = span(Level::Error, "test", "work", &[]);
            g.field("n", 3u64);
            // timer: make the span long enough to measure
            std::thread::sleep(std::time::Duration::from_millis(2));
        });
        let r = recs.iter().find(|r| r.ev == "work").expect("span emitted");
        assert!(r.dur_us.unwrap() >= 1_000, "dur {:?}", r.dur_us);
        assert_eq!(r.fields, vec![("n", FieldVal::U(3))]);
    }

    #[test]
    fn record_json_schema_has_required_keys() {
        let rec = TraceRecord {
            ts_us: 42,
            pid: 7,
            tid: 1,
            level: Level::Info,
            sub: "cluster",
            ev: "chunk_dealt",
            dur_us: Some(10),
            fields: vec![("key", FieldVal::U(5)), ("ok", FieldVal::B(true))],
        };
        let j = rec.to_json();
        for k in ["ts", "pid", "tid", "proc", "lvl", "sub", "ev", "f", "dur"] {
            assert!(j.opt(k).is_some(), "missing {k}");
        }
        assert_eq!(j.get("f").unwrap().get("key").unwrap().as_u64().unwrap(), 5);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("ev").unwrap().as_str().unwrap(), "chunk_dealt");
    }

    #[test]
    fn timestamps_are_monotone() {
        let a = now_us();
        let b = now_us();
        assert!(b >= a);
    }
}
