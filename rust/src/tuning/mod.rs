//! Decision-block threshold tuning (§3.2): F_β machinery and the two
//! selection strategies (metric-based §4.4, empirical §4.5).

pub mod empirical;
pub mod fbeta;
pub mod metric_based;

pub use fbeta::{best_threshold, Confusion, BETA_RANGE};
