//! Decision-block threshold tuning (§3.2): F_β machinery and the two
//! selection strategies (metric-based §4.4, empirical §4.5).

/// §4.5: one global β tuned on end-to-end retention/speedup.
pub mod empirical;
/// Confusion counts and F_β scores.
pub mod fbeta;
/// §4.4: per-level thresholds from isolated F_β curves.
pub mod metric_based;

pub use fbeta::{best_threshold, Confusion, BETA_RANGE};
