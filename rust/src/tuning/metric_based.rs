//! Metric-based threshold selection (§3.2, first strategy; §4.4).
//!
//! Given an objective positive-retention rate `r` and `n` intermediate
//! levels, each level in isolation must retain at least `r^(1/n)`:
//! the *isolated* execution zooms in everywhere except at the level under
//! study. For each level, the chosen β is the smallest one whose isolated
//! retention (averaged over the train slides) meets the per-level
//! objective; the level's threshold is then argmax F_β.

use anyhow::Result;

use crate::metrics::retention::{retention_and_speedup, RunMetrics};
use crate::predcache::PredSource;
use crate::pyramid::tree::Thresholds;
use crate::util::json::Json;

use super::fbeta::{best_threshold, BETA_RANGE};

/// One (β, threshold) point of an isolated-level study — a row of Fig. 3.
#[derive(Debug, Clone, Copy)]
pub struct IsolatedPoint {
    /// Candidate β value.
    pub beta: usize,
    /// The F_β-optimal threshold for that β.
    pub threshold: f64,
    /// Mean positive retention rate over the slide set.
    pub retention: f64,
    /// Mean speedup over the slide set.
    pub speedup: f64,
}

/// The full isolated-level curve for one resolution level (Fig. 3 series).
#[derive(Debug, Clone)]
pub struct IsolatedCurve {
    /// Pyramid level the curve was measured on.
    pub level: usize,
    /// The β-sweep points of this level.
    pub points: Vec<IsolatedPoint>,
}

/// Thresholds where every level passes through except `level`, which uses
/// `t`.
pub fn isolated_thresholds(levels: usize, level: usize, t: f64) -> Thresholds {
    let mut thr = Thresholds::pass_through(levels);
    thr.zoom[level] = t;
    thr
}

/// Mean retention and speedup of a threshold setting over a slide set.
/// Slides are visited one at a time through [`PredSource`], so a
/// [`ShardedPredStore`](crate::predcache::ShardedPredStore) source
/// evaluates out-of-core under its memory budget; errors are disk/codec
/// failures from such streaming sources.
pub fn evaluate(
    cache: &impl PredSource,
    thresholds: &Thresholds,
) -> Result<(f64, f64, Vec<RunMetrics>)> {
    let mut metrics = Vec::with_capacity(cache.n_slides());
    for i in 0..cache.n_slides() {
        cache.with_slide(i, &mut |sp| {
            let tree = sp.replay(thresholds);
            metrics.push(retention_and_speedup(sp, &tree));
        })?;
    }
    let n = metrics.len().max(1) as f64;
    let retention = metrics.iter().map(|m| m.retention()).sum::<f64>() / n;
    let speedup = metrics.iter().map(|m| m.speedup()).sum::<f64>() / n;
    Ok((retention, speedup, metrics))
}

/// Sweep β over one isolated level (Fig. 3 for that level).
pub fn isolated_curve(
    cache: &impl PredSource,
    levels: usize,
    level: usize,
) -> Result<IsolatedCurve> {
    let pairs = cache.pooled_pairs(level)?;
    let points = BETA_RANGE
        .map(|beta| -> Result<IsolatedPoint> {
            let threshold = best_threshold(&pairs, beta as f64);
            let thr = isolated_thresholds(levels, level, threshold);
            let (retention, speedup, _) = evaluate(cache, &thr)?;
            Ok(IsolatedPoint {
                beta,
                threshold,
                retention,
                speedup,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(IsolatedCurve { level, points })
}

/// Result of the metric-based selection.
#[derive(Debug, Clone)]
pub struct MetricBasedSelection {
    /// The per-level objective (target recall).
    pub objective: f64,
    /// Per-level objective = objective^(1/n_intermediate).
    pub per_level_objective: f64,
    /// Chosen β per intermediate level (index = level, level ≥ 1).
    pub betas: Vec<Option<usize>>,
    /// The selected thresholds.
    pub thresholds: Thresholds,
    /// The isolated curves used for the selection (Fig. 3 data).
    pub curves: Vec<IsolatedCurve>,
}

/// Run the §4.4 procedure: isolated β sweep per intermediate level, pick
/// the smallest β whose isolated retention meets `objective^(1/n)`.
/// Falls back to the largest β (max recall) when no β reaches the
/// per-level objective.
pub fn select(
    cache: &impl PredSource,
    levels: usize,
    objective: f64,
) -> Result<MetricBasedSelection> {
    assert!((0.0..=1.0).contains(&objective));
    let n_intermediate = levels - 1; // levels 1..levels-1 carry decisions
    let per_level_objective = objective.powf(1.0 / n_intermediate as f64);

    let mut thresholds = Thresholds::pass_through(levels);
    let mut betas = vec![None; levels];
    let mut curves = Vec::new();
    for level in 1..levels {
        let curve = isolated_curve(cache, levels, level)?;
        let chosen = curve
            .points
            .iter()
            .find(|p| p.retention >= per_level_objective)
            .or_else(|| curve.points.last());
        if let Some(p) = chosen {
            thresholds.zoom[level] = p.threshold;
            betas[level] = Some(p.beta);
        }
        curves.push(curve);
    }
    Ok(MetricBasedSelection {
        objective,
        per_level_objective,
        betas,
        thresholds,
        curves,
    })
}

impl MetricBasedSelection {
    /// Serialize for threshold files.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("strategy", "metric_based")
            .set("objective", self.objective)
            .set("per_level_objective", self.per_level_objective)
            .set(
                "betas",
                Json::Arr(
                    self.betas
                        .iter()
                        .map(|b| match b {
                            Some(b) => Json::Num(*b as f64),
                            None => Json::Null,
                        })
                        .collect(),
                ),
            )
            .set("thresholds", self.thresholds.to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::oracle::OracleAnalyzer;
    use crate::predcache::PredCache;
    use crate::slide::pyramid::Slide;
    use crate::synth::slide_gen::{gen_slide_set, DatasetParams};

    fn train_cache(n: usize) -> PredCache {
        let slides: Vec<Slide> = gen_slide_set("mb", n, 7, &DatasetParams::default())
            .into_iter()
            .map(Slide::from_spec)
            .collect();
        PredCache::collect_set(&slides, &OracleAnalyzer::new(1), 32)
    }

    #[test]
    fn isolated_curve_monotone_retention_in_beta() {
        let cache = train_cache(6);
        let curve = isolated_curve(&cache, 3, 2).unwrap();
        assert_eq!(curve.points.len(), 14);
        // Higher β → lower threshold → weakly higher retention.
        for w in curve.points.windows(2) {
            assert!(
                w[1].retention >= w[0].retention - 1e-9,
                "retention must not drop with β: {:?} -> {:?}",
                w[0],
                w[1]
            );
        }
    }

    #[test]
    fn isolated_execution_only_filters_at_that_level() {
        let cache = train_cache(3);
        let sp = &cache.slides[0];
        // Isolate level 1 with an impossible threshold: level-2 passes
        // through, so level-1 analyzes the full lineage, level-0 nothing.
        let thr = isolated_thresholds(3, 1, 1.1);
        let tree = sp.replay(&thr);
        assert_eq!(tree.nodes[2].len(), sp.initial.len());
        assert_eq!(tree.nodes[1].len(), sp.initial.len() * 4);
        assert_eq!(tree.nodes[0].len(), 0);
    }

    #[test]
    fn selection_meets_objective_on_train_set() {
        let cache = train_cache(9);
        let sel = select(&cache, 3, 0.90).unwrap();
        assert!((sel.per_level_objective - 0.90f64.sqrt()).abs() < 1e-12);
        // Betas chosen for both intermediate levels.
        assert!(sel.betas[1].is_some());
        assert!(sel.betas[2].is_some());
        // The combined execution should meet (approximately) the global
        // objective on the train set: per-level isolation guarantees the
        // product bound, allow small slack for interactions.
        let (retention, speedup, _) = evaluate(&cache, &sel.thresholds).unwrap();
        assert!(
            retention >= 0.85,
            "train retention {retention} far below objective"
        );
        assert!(speedup > 1.0, "speedup {speedup} should beat reference");
    }

    #[test]
    fn stricter_objective_needs_higher_or_equal_betas() {
        let cache = train_cache(6);
        let loose = select(&cache, 3, 0.80).unwrap();
        let strict = select(&cache, 3, 0.97).unwrap();
        for level in 1..3 {
            let (l, s) = (loose.betas[level].unwrap(), strict.betas[level].unwrap());
            assert!(s >= l, "level {level}: strict β {s} < loose β {l}");
        }
    }
}
