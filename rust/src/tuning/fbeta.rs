//! F_β score machinery (Equation 2 of the paper) and per-level threshold
//! selection: for a given β, the decision-block threshold is the one
//! maximizing F_β over the collected (probability, label) pairs, searched
//! over a finite grid of sampled thresholds.

/// Confusion counts of a probability threshold over (prob, label) pairs.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Confusion {
    /// True positives.
    pub tp: usize,
    /// False positives.
    pub fp: usize,
    /// False negatives (`fn` is a keyword, hence the underscore).
    pub fn_: usize,
    /// True negatives.
    pub tn: usize,
}

impl Confusion {
    /// Confusion counts of `prob ≥ thr` against the labels.
    pub fn at_threshold(pairs: &[(f32, bool)], thr: f64) -> Confusion {
        let mut c = Confusion::default();
        let thr = thr as f32;
        for &(p, y) in pairs {
            match (p >= thr, y) {
                (true, true) => c.tp += 1,
                (true, false) => c.fp += 1,
                (false, true) => c.fn_ += 1,
                (false, false) => c.tn += 1,
            }
        }
        c
    }

    /// tp / (tp + fp); 1.0 on no positives.
    pub fn precision(&self) -> f64 {
        let denom = self.tp + self.fp;
        if denom == 0 {
            0.0
        } else {
            self.tp as f64 / denom as f64
        }
    }

    /// tp / (tp + fn); 1.0 on no ground-truth positives.
    pub fn recall(&self) -> f64 {
        let denom = self.tp + self.fn_;
        if denom == 0 {
            0.0
        } else {
            self.tp as f64 / denom as f64
        }
    }

    /// (tp + tn) / total.
    pub fn accuracy(&self) -> f64 {
        let total = self.tp + self.fp + self.fn_ + self.tn;
        if total == 0 {
            0.0
        } else {
            (self.tp + self.tn) as f64 / total as f64
        }
    }

    /// F_β from the counts (Equation 2, right-hand form):
    /// `(1+β²)·TP / ((1+β²)·TP + β²·FN + FP)`.
    pub fn fbeta(&self, beta: f64) -> f64 {
        let b2 = beta * beta;
        let denom = (1.0 + b2) * self.tp as f64 + b2 * self.fn_ as f64 + self.fp as f64;
        if denom == 0.0 {
            0.0
        } else {
            (1.0 + b2) * self.tp as f64 / denom
        }
    }
}

/// F_β from precision and recall (Equation 2, left-hand form).
pub fn fbeta_pr(precision: f64, recall: f64, beta: f64) -> f64 {
    let b2 = beta * beta;
    let denom = b2 * precision + recall;
    if denom == 0.0 {
        0.0
    } else {
        (1.0 + b2) * precision * recall / denom
    }
}

/// Number of sampled thresholds in the argmax search (the paper
/// approximates `argmax_{t∈[0,1]} F_β(t)` over a finite set).
pub const THRESHOLD_GRID: usize = 99;

/// The threshold in (0,1) maximizing F_β over the pairs, searched on a
/// uniform grid. Ties break toward the *higher* threshold (more pruning
/// for equal F_β).
pub fn best_threshold(pairs: &[(f32, bool)], beta: f64) -> f64 {
    let mut best_t = 0.5;
    let mut best_f = -1.0;
    for i in 1..=THRESHOLD_GRID {
        let t = i as f64 / (THRESHOLD_GRID + 1) as f64;
        let f = Confusion::at_threshold(pairs, t).fbeta(beta);
        if f >= best_f {
            best_f = f;
            best_t = t;
        }
    }
    best_t
}

/// β sweep range used throughout the paper's evaluation (§4.4: "β values
/// ranging from 1 to 14").
pub const BETA_RANGE: std::ops::RangeInclusive<usize> = 1..=14;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;

    fn sample_pairs(seed: u64, n: usize) -> Vec<(f32, bool)> {
        // positives ~ N(0.7, 0.15), negatives ~ N(0.3, 0.15)
        let mut rng = Pcg32::new(seed);
        (0..n)
            .map(|i| {
                let y = i % 3 == 0;
                let mu = if y { 0.7 } else { 0.3 };
                ((mu + 0.15 * rng.normal()).clamp(0.0, 1.0) as f32, y)
            })
            .collect()
    }

    #[test]
    fn equation2_forms_agree() {
        let pairs = sample_pairs(1, 500);
        for thr in [0.2, 0.5, 0.8] {
            let c = Confusion::at_threshold(&pairs, thr);
            for beta in [0.5, 1.0, 4.0, 9.0] {
                let lhs = fbeta_pr(c.precision(), c.recall(), beta);
                let rhs = c.fbeta(beta);
                assert!((lhs - rhs).abs() < 1e-12, "β={beta} thr={thr}");
            }
        }
    }

    #[test]
    fn f1_is_harmonic_mean() {
        let c = Confusion {
            tp: 30,
            fp: 10,
            fn_: 20,
            tn: 40,
        };
        let p = c.precision();
        let r = c.recall();
        assert!((c.fbeta(1.0) - 2.0 * p * r / (p + r)).abs() < 1e-12);
    }

    #[test]
    fn higher_beta_lowers_best_threshold() {
        // Favoring recall (higher β) must not raise the decision threshold.
        let pairs = sample_pairs(2, 2000);
        let mut last = f64::INFINITY;
        for beta in [1.0, 2.0, 4.0, 8.0, 14.0] {
            let t = best_threshold(&pairs, beta);
            assert!(t <= last + 1e-12, "β={beta}: t={t} > prev {last}");
            last = t;
        }
    }

    #[test]
    fn recall_at_high_beta_threshold_is_high() {
        let pairs = sample_pairs(3, 2000);
        let t = best_threshold(&pairs, 10.0);
        let c = Confusion::at_threshold(&pairs, t);
        assert!(c.recall() > 0.95, "recall {}", c.recall());
    }

    #[test]
    fn confusion_totals() {
        let pairs = sample_pairs(4, 321);
        let c = Confusion::at_threshold(&pairs, 0.5);
        assert_eq!(c.tp + c.fp + c.fn_ + c.tn, 321);
    }

    #[test]
    fn degenerate_inputs() {
        assert_eq!(Confusion::at_threshold(&[], 0.5), Confusion::default());
        assert_eq!(Confusion::default().fbeta(2.0), 0.0);
        assert_eq!(fbeta_pr(0.0, 0.0, 1.0), 0.0);
        // All-negative pairs: F_β = 0 at any threshold, best_threshold
        // still returns something in (0,1).
        let t = best_threshold(&[(0.3, false), (0.6, false)], 2.0);
        assert!((0.0..1.0).contains(&t));
    }

    #[test]
    fn perfect_separation_yields_perfect_fbeta() {
        let pairs: Vec<(f32, bool)> = (0..100)
            .map(|i| ((i as f32) / 100.0, i >= 50))
            .collect();
        let t = best_threshold(&pairs, 1.0);
        let c = Confusion::at_threshold(&pairs, t);
        assert_eq!(c.fp, 0);
        assert_eq!(c.fn_, 0);
        assert!((c.fbeta(1.0) - 1.0).abs() < 1e-12);
    }
}
