//! Empirical threshold selection (§3.2, second strategy; §4.5).
//!
//! One β is applied at *all* levels: each level's threshold is the argmax
//! of F_β on that level's pooled train predictions. For each β in 1..=14
//! the full pyramidal execution is replayed on every train slide, giving a
//! retention-vs-speedup curve (Fig. 5) from which the user picks a single
//! β for the desired trade-off.

use anyhow::Result;

use crate::predcache::PredSource;
use crate::pyramid::tree::Thresholds;
use crate::util::json::Json;

use super::fbeta::{best_threshold, BETA_RANGE};
use super::metric_based::evaluate;

/// One row of the empirical sweep — a point of Fig. 5.
#[derive(Debug, Clone)]
pub struct EmpiricalPoint {
    /// Candidate β (zoom-budget) value.
    pub beta: usize,
    /// Thresholds the β induces.
    pub thresholds: Thresholds,
    /// Positive retention at those thresholds.
    pub retention: f64,
    /// Tile-count speedup at those thresholds.
    pub speedup: f64,
}

/// Full β sweep (Fig. 5 series). Works over any [`PredSource`] — a
/// fully-resident cache or a disk-sharded store streaming slides under
/// its memory budget; errors are disk/codec failures from such sources.
pub fn sweep(cache: &impl PredSource, levels: usize) -> Result<Vec<EmpiricalPoint>> {
    // Per-level pooled pairs, computed once.
    let pairs_per_level: Vec<Vec<(f32, bool)>> = (0..levels)
        .map(|l| cache.pooled_pairs(l))
        .collect::<Result<_>>()?;
    BETA_RANGE
        .map(|beta| {
            let mut thresholds = Thresholds::pass_through(levels);
            for level in 1..levels {
                thresholds.zoom[level] =
                    best_threshold(&pairs_per_level[level], beta as f64);
            }
            let (retention, speedup, _) = evaluate(cache, &thresholds)?;
            Ok(EmpiricalPoint {
                beta,
                thresholds,
                retention,
                speedup,
            })
        })
        .collect()
}

/// Result of the empirical selection.
#[derive(Debug, Clone)]
pub struct EmpiricalSelection {
    /// Minimum train retention the user asked for (e.g. 0.90 → β=8 in the
    /// paper).
    pub target_retention: f64,
    /// The chosen β.
    pub beta: usize,
    /// The selected thresholds.
    pub thresholds: Thresholds,
    /// The full sweep (Fig. 5 data).
    pub points: Vec<EmpiricalPoint>,
}

/// Pick the smallest β whose train retention meets the target (the paper
/// picks β=8 for a 0.90 target). Falls back to the largest β.
pub fn select(
    cache: &impl PredSource,
    levels: usize,
    target_retention: f64,
) -> Result<EmpiricalSelection> {
    let points = sweep(cache, levels)?;
    let chosen = points
        .iter()
        .find(|p| p.retention >= target_retention)
        .or_else(|| points.last())
        .expect("non-empty β range");
    Ok(EmpiricalSelection {
        target_retention,
        beta: chosen.beta,
        thresholds: chosen.thresholds.clone(),
        points,
    })
}

impl EmpiricalSelection {
    /// Serialize for threshold files.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("strategy", "empirical")
            .set("target_retention", self.target_retention)
            .set("beta", self.beta)
            .set("thresholds", self.thresholds.to_json())
            .set(
                "sweep",
                Json::Arr(
                    self.points
                        .iter()
                        .map(|p| {
                            Json::obj()
                                .set("beta", p.beta)
                                .set("retention", p.retention)
                                .set("speedup", p.speedup)
                        })
                        .collect(),
                ),
            )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::oracle::OracleAnalyzer;
    use crate::predcache::PredCache;
    use crate::slide::pyramid::Slide;
    use crate::synth::slide_gen::{gen_slide_set, DatasetParams};

    fn train_cache(n: usize) -> PredCache {
        let slides: Vec<Slide> = gen_slide_set("emp", n, 11, &DatasetParams::default())
            .into_iter()
            .map(Slide::from_spec)
            .collect();
        PredCache::collect_set(&slides, &OracleAnalyzer::new(1), 32)
    }

    #[test]
    fn sweep_has_14_points_with_tradeoff_shape() {
        let cache = train_cache(6);
        let points = sweep(&cache, 3).unwrap();
        assert_eq!(points.len(), 14);
        for w in points.windows(2) {
            // retention weakly increases with β, speedup weakly decreases
            assert!(w[1].retention >= w[0].retention - 1e-9);
            assert!(w[1].speedup <= w[0].speedup + 1e-9);
        }
        // The sweep must include a genuinely fast point and a genuinely
        // accurate point — otherwise there is no trade-off to pick.
        assert!(points.first().unwrap().speedup > 1.2);
        assert!(points.last().unwrap().retention > 0.9);
    }

    #[test]
    fn select_meets_target_on_train() {
        let cache = train_cache(9);
        let sel = select(&cache, 3, 0.90).unwrap();
        assert!(
            sel.points
                .iter()
                .find(|p| p.beta == sel.beta)
                .unwrap()
                .retention
                >= 0.90
        );
        // Headline shape (paper: speedup 2.65 at 90% retention): demand a
        // material speedup, not the exact constant.
        let p = sel.points.iter().find(|p| p.beta == sel.beta).unwrap();
        assert!(p.speedup > 1.3, "speedup {} too small", p.speedup);
    }

    #[test]
    fn lower_target_picks_smaller_or_equal_beta() {
        let cache = train_cache(6);
        let lo = select(&cache, 3, 0.75).unwrap();
        let hi = select(&cache, 3, 0.95).unwrap();
        assert!(lo.beta <= hi.beta);
    }

    #[test]
    fn json_has_sweep_rows() {
        let cache = train_cache(3);
        let sel = select(&cache, 3, 0.9).unwrap();
        let j = sel.to_json();
        assert_eq!(j.get("sweep").unwrap().as_arr().unwrap().len(), 14);
    }
}
