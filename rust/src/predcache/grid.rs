//! Dense per-level columnar storage for cached tile predictions.
//!
//! One [`LevelGrid`] holds everything the replay/tuning paths need about
//! one resolution level of one slide: a dense `Vec<f32>` probability
//! plane plus two packed bitsets (presence and ground-truth label),
//! all indexed by `(tx, ty)` in row-major order. Lookups are O(1) array
//! reads — no hashing, no pointer chasing — and per-level tuning pairs
//! come from a single slice sweep instead of a full-map scan.

use crate::slide::tile::TileId;

/// Cached per-tile data.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TilePred {
    /// Predicted tumor probability.
    pub prob: f32,
    /// Ground-truth tumor label at this tile's level.
    pub tumor: bool,
}

/// Dense storage for every cached tile of one pyramid level: a row-major
/// probability plane and packed presence/label bitsets.
#[derive(Debug, Clone, PartialEq)]
pub struct LevelGrid {
    /// Grid width in tiles at this level.
    tiles_x: usize,
    /// Grid height in tiles at this level.
    tiles_y: usize,
    /// Probability plane, `tiles_x * tiles_y` entries; cells outside the
    /// cached lineage hold NaN and are masked by `present`.
    probs: Vec<f32>,
    /// One bit per cell: is this tile part of the cached lineage?
    present: Vec<u64>,
    /// One bit per cell: ground-truth tumor label (meaningful only where
    /// `present` is set).
    tumor: Vec<u64>,
    /// Number of set bits in `present` (kept incrementally).
    count: usize,
}

#[inline]
fn word_bit(idx: usize) -> (usize, u64) {
    (idx >> 6, 1u64 << (idx & 63))
}

impl LevelGrid {
    /// An empty grid of `tiles_x × tiles_y` cells.
    pub fn new(tiles_x: usize, tiles_y: usize) -> LevelGrid {
        let cells = tiles_x * tiles_y;
        let words = cells.div_ceil(64);
        LevelGrid {
            tiles_x,
            tiles_y,
            probs: vec![f32::NAN; cells],
            present: vec![0; words],
            tumor: vec![0; words],
            count: 0,
        }
    }

    /// Rebuild a grid from its raw parts (the binary shard decoder).
    /// Returns `None` when the slice lengths are inconsistent with the
    /// grid dimensions.
    pub(crate) fn from_parts(
        tiles_x: usize,
        tiles_y: usize,
        probs: Vec<f32>,
        present: Vec<u64>,
        tumor: Vec<u64>,
    ) -> Option<LevelGrid> {
        let cells = tiles_x.checked_mul(tiles_y)?;
        let words = cells.div_ceil(64);
        if probs.len() != cells || present.len() != words || tumor.len() != words {
            return None;
        }
        // Padding bits past `cells` must be clear: `count` and the pair
        // sweep trust the popcount.
        if cells % 64 != 0 {
            let tail_mask = !0u64 << (cells % 64);
            if present.last().is_some_and(|w| w & tail_mask != 0) {
                return None;
            }
        }
        let count = present.iter().map(|w| w.count_ones() as usize).sum();
        Some(LevelGrid {
            tiles_x,
            tiles_y,
            probs,
            present,
            tumor,
            count,
        })
    }

    /// Grid width in tiles.
    pub fn tiles_x(&self) -> usize {
        self.tiles_x
    }

    /// Grid height in tiles.
    pub fn tiles_y(&self) -> usize {
        self.tiles_y
    }

    /// Number of cached tiles at this level.
    pub fn len(&self) -> usize {
        self.count
    }

    /// True when no tile is cached at this level.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Raw probability plane (row-major; NaN outside the lineage).
    pub(crate) fn probs(&self) -> &[f32] {
        &self.probs
    }

    /// Raw presence bitset words.
    pub(crate) fn present_words(&self) -> &[u64] {
        &self.present
    }

    /// Raw label bitset words.
    pub(crate) fn tumor_words(&self) -> &[u64] {
        &self.tumor
    }

    #[inline]
    fn idx(&self, tx: usize, ty: usize) -> Option<usize> {
        if tx < self.tiles_x && ty < self.tiles_y {
            Some(ty * self.tiles_x + tx)
        } else {
            None
        }
    }

    /// Insert (or overwrite) one tile. Returns `false` when `(tx, ty)` is
    /// outside the grid.
    pub fn insert(&mut self, tx: usize, ty: usize, prob: f32, tumor: bool) -> bool {
        let Some(idx) = self.idx(tx, ty) else {
            return false;
        };
        let (w, b) = word_bit(idx);
        if self.present[w] & b == 0 {
            self.present[w] |= b;
            self.count += 1;
        }
        self.probs[idx] = prob;
        if tumor {
            self.tumor[w] |= b;
        } else {
            self.tumor[w] &= !b;
        }
        true
    }

    /// Remove one tile (corrupt-cache tests). Returns `true` when the
    /// tile was present.
    pub fn remove(&mut self, tx: usize, ty: usize) -> bool {
        let Some(idx) = self.idx(tx, ty) else {
            return false;
        };
        let (w, b) = word_bit(idx);
        if self.present[w] & b == 0 {
            return false;
        }
        self.present[w] &= !b;
        self.tumor[w] &= !b;
        self.probs[idx] = f32::NAN;
        self.count -= 1;
        true
    }

    /// The cached prediction at `(tx, ty)`, or `None` outside the lineage.
    #[inline]
    pub fn get(&self, tx: usize, ty: usize) -> Option<TilePred> {
        let idx = self.idx(tx, ty)?;
        let (w, b) = word_bit(idx);
        if self.present[w] & b == 0 {
            return None;
        }
        Some(TilePred {
            prob: self.probs[idx],
            tumor: self.tumor[w] & b != 0,
        })
    }

    /// The cached probability at `(tx, ty)` — the replay hot path.
    #[inline]
    pub fn prob(&self, tx: usize, ty: usize) -> Option<f32> {
        let idx = self.idx(tx, ty)?;
        let (w, b) = word_bit(idx);
        if self.present[w] & b == 0 {
            return None;
        }
        Some(self.probs[idx])
    }

    /// (probability, label) pairs of every cached tile, in row-major
    /// order — one slice sweep, the tuning input for this level.
    pub fn pairs(&self) -> impl Iterator<Item = (f32, bool)> + '_ {
        self.iter().map(|(_, _, p)| (p.prob, p.tumor))
    }

    /// Every cached tile as `(tx, ty, pred)`, row-major.
    pub fn iter(&self) -> impl Iterator<Item = (usize, usize, TilePred)> + '_ {
        self.present
            .iter()
            .enumerate()
            .flat_map(move |(w, &word)| {
                let mut word = word;
                std::iter::from_fn(move || {
                    if word == 0 {
                        return None;
                    }
                    let bit = word.trailing_zeros() as usize;
                    word &= word - 1;
                    Some(w * 64 + bit)
                })
            })
            .map(move |idx| {
                let (w, b) = word_bit(idx);
                (
                    idx % self.tiles_x,
                    idx / self.tiles_x,
                    TilePred {
                        prob: self.probs[idx],
                        tumor: self.tumor[w] & b != 0,
                    },
                )
            })
    }

    /// Every cached tile as a full [`TileId`] at `level`.
    pub fn iter_ids(&self, level: usize) -> impl Iterator<Item = (TileId, TilePred)> + '_ {
        self.iter()
            .map(move |(tx, ty, p)| (TileId::new(level, tx, ty), p))
    }

    /// Approximate resident heap size in bytes (LRU budget accounting).
    pub fn resident_bytes(&self) -> usize {
        self.probs.len() * std::mem::size_of::<f32>()
            + (self.present.len() + self.tumor.len()) * std::mem::size_of::<u64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_remove_roundtrip() {
        let mut g = LevelGrid::new(7, 3);
        assert!(g.is_empty());
        assert!(g.insert(6, 2, 0.25, true));
        assert!(g.insert(0, 0, 0.5, false));
        assert_eq!(g.len(), 2);
        assert_eq!(
            g.get(6, 2),
            Some(TilePred {
                prob: 0.25,
                tumor: true
            })
        );
        assert_eq!(g.prob(0, 0), Some(0.5));
        assert_eq!(g.get(1, 1), None);
        assert!(!g.insert(7, 0, 0.1, false), "out of bounds rejected");
        assert!(g.remove(6, 2));
        assert!(!g.remove(6, 2));
        assert_eq!(g.len(), 1);
        assert_eq!(g.get(6, 2), None);
    }

    #[test]
    fn overwrite_does_not_grow_count() {
        let mut g = LevelGrid::new(4, 4);
        g.insert(1, 1, 0.2, false);
        g.insert(1, 1, 0.9, true);
        assert_eq!(g.len(), 1);
        assert_eq!(
            g.get(1, 1),
            Some(TilePred {
                prob: 0.9,
                tumor: true
            })
        );
    }

    #[test]
    fn pairs_sweep_row_major_and_complete() {
        let mut g = LevelGrid::new(3, 2);
        g.insert(2, 1, 0.3, true);
        g.insert(0, 0, 0.1, false);
        g.insert(1, 0, 0.2, true);
        let pairs: Vec<_> = g.pairs().collect();
        assert_eq!(pairs, vec![(0.1, false), (0.2, true), (0.3, true)]);
        let ids: Vec<_> = g.iter_ids(2).map(|(t, _)| t).collect();
        assert_eq!(
            ids,
            vec![TileId::new(2, 0, 0), TileId::new(2, 1, 0), TileId::new(2, 2, 1)]
        );
    }

    #[test]
    fn from_parts_validates_lengths_and_padding() {
        let g = LevelGrid::from_parts(3, 2, vec![0.0; 6], vec![0b111], vec![0]).unwrap();
        assert_eq!(g.len(), 3);
        assert!(LevelGrid::from_parts(3, 2, vec![0.0; 5], vec![0], vec![0]).is_none());
        assert!(LevelGrid::from_parts(3, 2, vec![0.0; 6], vec![0, 0], vec![0]).is_none());
        // A presence bit beyond the 6 real cells must be rejected.
        assert!(LevelGrid::from_parts(3, 2, vec![0.0; 6], vec![1 << 6], vec![0]).is_none());
    }

    #[test]
    fn word_boundary_tiles_survive() {
        // A grid spanning >64 cells exercises multi-word bitsets.
        let mut g = LevelGrid::new(16, 8);
        for i in 0..128 {
            assert!(g.insert(i % 16, i / 16, i as f32, i % 3 == 0));
        }
        assert_eq!(g.len(), 128);
        assert_eq!(g.pairs().count(), 128);
        assert_eq!(g.get(15, 3).unwrap().prob, 63.0);
        assert_eq!(g.get(0, 4).unwrap().prob, 64.0);
    }
}
