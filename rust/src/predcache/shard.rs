//! The versioned binary shard format — one file per slide.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! magic    b"PYSH"                                    4 bytes
//! version  u32                                        (= SHARD_VERSION)
//! spec     u32 length + canonical SlideSpec JSON      (UTF-8)
//! initial  u32 count + count × (level u32, tx u32, ty u32)
//! levels   u32 count, then per level:
//!   tiles_x u32, tiles_y u32
//!   present bitset   ceil(tiles_x·tiles_y/64) × u64
//!   tumor   bitset   ceil(tiles_x·tiles_y/64) × u64
//!   probs   u32 count + count × f32   (row-major order of present bits)
//! crc32    u32 over every preceding byte (magic included)
//! ```
//!
//! Probabilities are stored only for present tiles, so a shard is a
//! fraction of the dense plane's size on disk while decoding back into
//! the dense [`LevelGrid`](super::LevelGrid) representation. Every
//! decode validates magic, version, structural bounds and the trailing
//! CRC — corrupt or truncated shards surface as [`ShardError`]s, never
//! panics.

use crate::slide::tile::TileId;
use crate::synth::slide_gen::SlideSpec;
use crate::util::json::{Json, JsonError};
use crate::util::png::crc32;

use super::grid::LevelGrid;
use super::SlidePredictions;

/// Shard file magic bytes.
pub const SHARD_MAGIC: [u8; 4] = *b"PYSH";
/// Current shard format version. Bump on any layout change.
pub const SHARD_VERSION: u32 = 1;

/// Why a shard failed to decode.
#[derive(Debug, thiserror::Error)]
pub enum ShardError {
    /// The file does not start with [`SHARD_MAGIC`].
    #[error("not a prediction shard (bad magic)")]
    BadMagic,
    /// The shard was written by an unknown format version.
    #[error("unsupported shard version {0} (this build reads {SHARD_VERSION})")]
    Version(u32),
    /// The file ended before the structure did.
    #[error("shard truncated at byte {at}: needed {needed} more bytes")]
    Truncated {
        /// Offset at which the read ran out.
        at: usize,
        /// How many bytes the next field needed.
        needed: usize,
    },
    /// The trailing CRC does not match the content.
    #[error("shard checksum mismatch: stored {stored:#010x}, computed {computed:#010x}")]
    Checksum {
        /// Checksum stored in the shard footer.
        stored: u32,
        /// Checksum recomputed over the payload.
        computed: u32,
    },
    /// Structurally invalid content (bounds, counts, geometry).
    #[error("corrupt shard: {0}")]
    Corrupt(String),
    /// The embedded slide spec failed to parse.
    #[error("corrupt shard spec: {0}")]
    Spec(#[from] JsonError),
}

struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], ShardError> {
        if self.bytes.len() - self.pos < n {
            return Err(ShardError::Truncated {
                at: self.pos,
                needed: n - (self.bytes.len() - self.pos),
            });
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32, ShardError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f32_vec(&mut self, n: usize) -> Result<Vec<f32>, ShardError> {
        let raw = self.take(n.checked_mul(4).ok_or_else(|| {
            ShardError::Corrupt("f32 vector length overflows".to_string())
        })?)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn u64_vec(&mut self, n: usize) -> Result<Vec<u64>, ShardError> {
        let raw = self.take(n.checked_mul(8).ok_or_else(|| {
            ShardError::Corrupt("u64 vector length overflows".to_string())
        })?)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

/// Encode one slide's predictions into the binary shard format
/// (checksummed, self-contained).
pub fn encode_slide(preds: &SlidePredictions) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&SHARD_MAGIC);
    out.extend_from_slice(&SHARD_VERSION.to_le_bytes());

    let spec = preds.spec.to_json().to_string();
    out.extend_from_slice(&(spec.len() as u32).to_le_bytes());
    out.extend_from_slice(spec.as_bytes());

    out.extend_from_slice(&(preds.initial.len() as u32).to_le_bytes());
    for t in &preds.initial {
        out.extend_from_slice(&(t.level as u32).to_le_bytes());
        out.extend_from_slice(&t.tx.to_le_bytes());
        out.extend_from_slice(&t.ty.to_le_bytes());
    }

    let grids = preds.grids();
    out.extend_from_slice(&(grids.len() as u32).to_le_bytes());
    for g in grids {
        out.extend_from_slice(&(g.tiles_x() as u32).to_le_bytes());
        out.extend_from_slice(&(g.tiles_y() as u32).to_le_bytes());
        for w in g.present_words() {
            out.extend_from_slice(&w.to_le_bytes());
        }
        for w in g.tumor_words() {
            out.extend_from_slice(&w.to_le_bytes());
        }
        out.extend_from_slice(&(g.len() as u32).to_le_bytes());
        // Probabilities for present tiles only, in row-major bit order —
        // the same order `pairs()` sweeps, so decode is a linear fill.
        for (prob, _) in g.pairs() {
            out.extend_from_slice(&prob.to_le_bytes());
        }
    }

    let crc = crc32(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Decode a binary shard back into a slide's predictions. Validates
/// magic, version, structure and checksum; returns [`ShardError`] on any
/// corruption — truncation, bit flips, version skew — and never panics.
pub fn decode_slide(bytes: &[u8]) -> Result<SlidePredictions, ShardError> {
    if bytes.len() < 12 {
        return Err(ShardError::Truncated {
            at: bytes.len(),
            needed: 12 - bytes.len(),
        });
    }
    if bytes[..4] != SHARD_MAGIC {
        return Err(ShardError::BadMagic);
    }
    // Checksum first: a corrupt length field must not turn into a
    // confusing structural error (or a huge allocation).
    let stored = u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().unwrap());
    let computed = crc32(&bytes[..bytes.len() - 4]);
    if stored != computed {
        return Err(ShardError::Checksum { stored, computed });
    }
    let mut r = Reader {
        bytes: &bytes[..bytes.len() - 4],
        pos: 4,
    };
    let version = r.u32()?;
    if version != SHARD_VERSION {
        return Err(ShardError::Version(version));
    }

    let spec_len = r.u32()? as usize;
    let spec_raw = std::str::from_utf8(r.take(spec_len)?)
        .map_err(|e| ShardError::Corrupt(format!("spec is not UTF-8: {e}")))?;
    let spec_json = Json::parse(spec_raw)?;
    // SlideSpec::new panics on inconsistent geometry; a crafted shard
    // must surface that as an error, not an unwind.
    let spec = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        SlideSpec::from_json(&spec_json)
    }))
    .map_err(|_| ShardError::Corrupt("spec geometry failed validation".to_string()))??;

    let n_initial = r.u32()? as usize;
    let mut initial = Vec::with_capacity(n_initial.min(1 << 20));
    for _ in 0..n_initial {
        let (level, tx, ty) = (r.u32()?, r.u32()?, r.u32()?);
        initial.push(TileId::new(level as usize, tx as usize, ty as usize));
    }

    let n_levels = r.u32()? as usize;
    if n_levels != spec.levels {
        return Err(ShardError::Corrupt(format!(
            "shard has {n_levels} level planes but the spec declares {}",
            spec.levels
        )));
    }
    let mut grids = Vec::with_capacity(n_levels);
    for level in 0..n_levels {
        let (nx, ny) = (r.u32()? as usize, r.u32()? as usize);
        if nx != spec.tiles_x >> level || ny != spec.tiles_y >> level {
            return Err(ShardError::Corrupt(format!(
                "level {level} plane is {nx}x{ny}, expected {}x{}",
                spec.tiles_x >> level,
                spec.tiles_y >> level
            )));
        }
        let words = (nx * ny).div_ceil(64);
        let present = r.u64_vec(words)?;
        let tumor = r.u64_vec(words)?;
        let n_probs = r.u32()? as usize;
        let expected: usize = present.iter().map(|w| w.count_ones() as usize).sum();
        if n_probs != expected {
            return Err(ShardError::Corrupt(format!(
                "level {level} stores {n_probs} probabilities for {expected} present tiles"
            )));
        }
        let packed = r.f32_vec(n_probs)?;
        // Scatter the packed probabilities back onto the dense plane.
        let mut probs = vec![f32::NAN; nx * ny];
        let mut it = packed.into_iter();
        for (w, &word) in present.iter().enumerate() {
            let mut word = word;
            while word != 0 {
                let idx = w * 64 + word.trailing_zeros() as usize;
                word &= word - 1;
                if idx >= probs.len() {
                    return Err(ShardError::Corrupt(format!(
                        "level {level} presence bit {idx} outside the {nx}x{ny} plane"
                    )));
                }
                probs[idx] = it.next().expect("count matches popcount");
            }
        }
        let grid = LevelGrid::from_parts(nx, ny, probs, present, tumor).ok_or_else(|| {
            ShardError::Corrupt(format!("level {level} plane failed validation"))
        })?;
        grids.push(grid);
    }
    if r.pos != r.bytes.len() {
        return Err(ShardError::Corrupt(format!(
            "{} trailing bytes after the last level plane",
            r.bytes.len() - r.pos
        )));
    }
    SlidePredictions::from_parts(spec, initial, grids)
        .map_err(|e| ShardError::Corrupt(e.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::oracle::OracleAnalyzer;
    use crate::slide::pyramid::Slide;
    use crate::synth::slide_gen::SlideKind;

    fn sample() -> SlidePredictions {
        let s = Slide::from_spec(SlideSpec::new(
            "shard",
            5,
            16,
            8,
            3,
            64,
            SlideKind::SmallScattered,
        ));
        SlidePredictions::collect(&s, &OracleAnalyzer::new(1), 16)
    }

    #[test]
    fn binary_roundtrip_is_exact() {
        let p = sample();
        let bytes = encode_slide(&p);
        let back = decode_slide(&bytes).unwrap();
        assert_eq!(back.spec, p.spec);
        assert_eq!(back.initial, p.initial);
        assert_eq!(back.len(), p.len());
        for (t, pred) in p.iter() {
            assert_eq!(back.get(t), Some(pred), "mismatch at {t}");
        }
    }

    #[test]
    fn truncation_is_an_error_at_every_length() {
        let bytes = encode_slide(&sample());
        // Every strict prefix must fail loudly, never panic. (Checksum
        // catches most; short headers hit Truncated.)
        for cut in [0, 3, 8, 11, 40, bytes.len() / 2, bytes.len() - 1] {
            let err = decode_slide(&bytes[..cut]).unwrap_err();
            assert!(
                matches!(
                    err,
                    ShardError::Truncated { .. } | ShardError::Checksum { .. }
                ),
                "cut={cut} gave {err}"
            );
        }
    }

    #[test]
    fn bitflip_fails_the_checksum() {
        let mut bytes = encode_slide(&sample());
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        assert!(matches!(
            decode_slide(&bytes).unwrap_err(),
            ShardError::Checksum { .. }
        ));
    }

    #[test]
    fn bad_magic_and_future_version_are_rejected() {
        let mut bytes = encode_slide(&sample());
        bytes[0] = b'X';
        assert!(matches!(
            decode_slide(&bytes).unwrap_err(),
            ShardError::BadMagic
        ));

        let mut bytes = encode_slide(&sample());
        bytes[4..8].copy_from_slice(&99u32.to_le_bytes());
        // Re-seal the checksum so the version check is what fires.
        let n = bytes.len();
        let crc = crc32(&bytes[..n - 4]);
        bytes[n - 4..].copy_from_slice(&crc.to_le_bytes());
        assert!(matches!(
            decode_slide(&bytes).unwrap_err(),
            ShardError::Version(99)
        ));
    }

    #[test]
    fn binary_is_smaller_than_json() {
        let p = sample();
        let bytes = encode_slide(&p);
        let json = p.to_json().to_string();
        assert!(
            bytes.len() * 2 < json.len(),
            "shard {} bytes vs json {} bytes",
            bytes.len(),
            json.len()
        );
    }
}
