//! Prediction cache: every lineage tile's probability and ground truth,
//! for every resolution level of a slide set.
//!
//! This mirrors the paper's methodology (§4.3-4.5): inference runs *once*
//! over all tiles of all levels; threshold tuning, pyramidal replay,
//! speedup estimation and the distributed simulator are then deterministic
//! post-mortem computations over the cached probabilities.
//!
//! Storage is columnar and sharded:
//!
//! * In memory, a slide's predictions are dense per-level grids
//!   ([`grid::LevelGrid`]) — a probability plane plus packed
//!   presence/label bitsets — so replay lookups are O(1) array reads and
//!   per-level tuning pairs are one slice sweep.
//! * On disk, each slide is a checksummed binary shard ([`shard`]) next
//!   to a manifest, loaded lazily under a memory budget with LRU
//!   eviction by [`store::ShardedPredStore`]. The legacy whole-cache JSON
//!   format remains readable/writable as a migration path.
//!
//! Code that only *consumes* predictions should accept [`PredSource`] —
//! both the fully-resident [`PredCache`] and the streaming
//! [`store::ShardedPredStore`] implement it, so tuning sweeps run
//! unchanged in-core or out-of-core.

/// Dense per-level columnar grids.
pub mod grid;
/// The versioned binary per-slide shard codec.
pub mod shard;
/// The sharded on-disk store with budgeted LRU residency.
pub mod store;

use std::path::Path;

use crate::model::Analyzer;
use crate::preprocess::otsu::background_removal;
use crate::pyramid::driver::BG_MARGIN;
use crate::pyramid::tree::{ExecTree, Thresholds};
use crate::slide::pyramid::Slide;
use crate::slide::tile::TileId;
use crate::synth::slide_gen::SlideSpec;
use crate::util::json::{Json, JsonError};

pub use grid::{LevelGrid, TilePred};
pub use shard::{ShardError, SHARD_VERSION};
pub use store::{ShardedPredStore, StoreError, StoreStats};

/// Level-0 lineage size of a pyramidal run: `initial · (f²)^(levels-1)`
/// tiles, computed in u128 so deep pyramids cannot silently wrap.
/// `None` when `levels` is zero or the count overflows u128.
pub fn reference_tile_count(initial: usize, levels: usize) -> Option<u128> {
    let f2 = (crate::slide::tile::SCALE_FACTOR as u128).checked_pow(2)?;
    let depth = u32::try_from(levels.checked_sub(1)?).ok()?;
    f2.checked_pow(depth)?.checked_mul(initial as u128)
}

/// All predictions for one slide, as dense per-level grids.
#[derive(Debug, Clone)]
pub struct SlidePredictions {
    /// The slide recipe the predictions were collected from.
    pub spec: SlideSpec,
    /// Lowest-level working set after background removal.
    pub initial: Vec<TileId>,
    /// One dense grid per level (index = level; level 0 is full
    /// resolution).
    levels: Vec<LevelGrid>,
}

impl SlidePredictions {
    /// An empty prediction set for `spec`'s geometry.
    pub fn new(spec: SlideSpec, initial: Vec<TileId>) -> SlidePredictions {
        let levels = (0..spec.levels)
            .map(|l| LevelGrid::new(spec.tiles_x >> l, spec.tiles_y >> l))
            .collect();
        SlidePredictions {
            spec,
            initial,
            levels,
        }
    }

    /// Rebuild from decoded parts (the shard decoder). Validates that the
    /// grids match the spec's geometry.
    pub(crate) fn from_parts(
        spec: SlideSpec,
        initial: Vec<TileId>,
        levels: Vec<LevelGrid>,
    ) -> Result<SlidePredictions, String> {
        if levels.len() != spec.levels {
            return Err(format!(
                "{} level grids for a {}-level spec",
                levels.len(),
                spec.levels
            ));
        }
        for (l, g) in levels.iter().enumerate() {
            if g.tiles_x() != spec.tiles_x >> l || g.tiles_y() != spec.tiles_y >> l {
                return Err(format!("level {l} grid does not match the spec geometry"));
            }
        }
        for t in &initial {
            if t.level as usize >= spec.levels {
                return Err(format!("initial tile {t} outside the pyramid"));
            }
        }
        Ok(SlidePredictions {
            spec,
            initial,
            levels,
        })
    }

    /// Run the analyzer over the full lineage of the initial working set at
    /// every level (pass-through execution) and record everything.
    pub fn collect(slide: &Slide, analyzer: &dyn Analyzer, batch: usize) -> SlidePredictions {
        let initial = background_removal(slide, BG_MARGIN).tissue_tiles;
        let mut out = SlidePredictions::new(slide.spec.clone(), initial.clone());
        let mut frontier = initial;
        let mut level = slide.lowest_level();
        loop {
            for chunk in frontier.chunks(batch.max(1)) {
                let ps = analyzer.analyze(slide, level, chunk);
                for (&tile, &prob) in chunk.iter().zip(&ps) {
                    out.insert(tile, prob, slide.is_tumor(tile));
                }
            }
            if level == 0 {
                break;
            }
            frontier = frontier.iter().flat_map(|t| t.children()).collect();
            level -= 1;
        }
        out
    }

    /// The per-level grids (level 0 first).
    pub fn grids(&self) -> &[LevelGrid] {
        &self.levels
    }

    /// One level's dense grid, or `None` beyond the pyramid.
    pub fn grid(&self, level: usize) -> Option<&LevelGrid> {
        self.levels.get(level)
    }

    /// Record one tile. Returns `false` when the tile lies outside the
    /// pyramid (wrong level or grid bounds).
    pub fn insert(&mut self, tile: TileId, prob: f32, tumor: bool) -> bool {
        match self.levels.get_mut(tile.level as usize) {
            Some(g) => g.insert(tile.tx as usize, tile.ty as usize, prob, tumor),
            None => false,
        }
    }

    /// Drop one tile from the cache (corrupt-cache tests). Returns `true`
    /// when it was present.
    pub fn remove(&mut self, tile: TileId) -> bool {
        match self.levels.get_mut(tile.level as usize) {
            Some(g) => g.remove(tile.tx as usize, tile.ty as usize),
            None => false,
        }
    }

    /// The cached prediction for `tile` — an O(1) grid read.
    #[inline]
    pub fn get(&self, tile: TileId) -> Option<TilePred> {
        self.levels
            .get(tile.level as usize)?
            .get(tile.tx as usize, tile.ty as usize)
    }

    /// The cached probability for `tile` — the replay hot path.
    #[inline]
    pub fn prob(&self, tile: TileId) -> Option<f32> {
        self.levels
            .get(tile.level as usize)?
            .prob(tile.tx as usize, tile.ty as usize)
    }

    /// Total cached tiles across all levels.
    pub fn len(&self) -> usize {
        self.levels.iter().map(|g| g.len()).sum()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.levels.iter().all(|g| g.is_empty())
    }

    /// Every cached tile, lowest level (coarsest) first, row-major within
    /// a level.
    pub fn iter(&self) -> impl Iterator<Item = (TileId, TilePred)> + '_ {
        self.levels
            .iter()
            .enumerate()
            .rev()
            .flat_map(|(l, g)| g.iter_ids(l))
    }

    /// Every cached tile of one level, row-major.
    pub fn iter_level(&self, level: usize) -> impl Iterator<Item = (TileId, TilePred)> + '_ {
        self.levels
            .get(level)
            .into_iter()
            .flat_map(move |g| g.iter_ids(level))
    }

    /// Approximate resident heap size in bytes (store budget accounting).
    pub fn resident_bytes(&self) -> usize {
        self.levels.iter().map(|g| g.resident_bytes()).sum::<usize>()
            + self.initial.len() * std::mem::size_of::<TileId>()
    }

    /// Replay a pyramidal execution under `thresholds` (post-mortem run):
    /// a [`crate::pyramid::PyramidRun`] driven by a
    /// [`crate::pyramid::ReplayBackend`] over this cache. Panics when a
    /// lineage tile is missing (corrupt cache).
    pub fn replay(&self, thresholds: &Thresholds) -> ExecTree {
        let mut backend = crate::pyramid::ReplayBackend::new(self);
        crate::pyramid::backend::run_on_backend(
            &self.spec.id,
            self.spec.levels,
            self.initial.clone(),
            thresholds,
            0,
            &mut backend,
        )
        .expect("every lineage tile cached")
    }

    /// (probability, label) pairs for all cached tiles at one level — the
    /// tuning input for that level's decision block. A single slice sweep
    /// over the level's dense plane.
    pub fn level_pairs(&self, level: usize) -> Vec<(f32, bool)> {
        match self.levels.get(level) {
            Some(g) => g.pairs().collect(),
            None => Vec::new(),
        }
    }

    /// Level-0 lineage size = the reference execution's tile count.
    /// Computed with checked arithmetic; panics loudly (never wraps) if
    /// the count exceeds `usize` on this platform.
    pub fn reference_count(&self) -> usize {
        reference_tile_count(self.initial.len(), self.spec.levels)
            .and_then(|n| usize::try_from(n).ok())
            .expect("reference tile count overflows usize")
    }

    /// Serialize for the legacy JSON cache format (migration path).
    pub fn to_json(&self) -> Json {
        // Compact encoding: per tile [level, tx, ty, prob, tumor].
        let mut preds: Vec<Json> = Vec::with_capacity(self.len());
        for (l, g) in self.levels.iter().enumerate() {
            for (tx, ty, p) in g.iter() {
                preds.push(Json::Arr(vec![
                    Json::Num(l as f64),
                    Json::Num(tx as f64),
                    Json::Num(ty as f64),
                    Json::Num((p.prob as f64 * 1e6).round() / 1e6),
                    Json::Bool(p.tumor),
                ]));
            }
        }
        let initial: Vec<Json> = self
            .initial
            .iter()
            .map(|t| {
                Json::Arr(vec![
                    Json::Num(t.level as f64),
                    Json::Num(t.tx as f64),
                    Json::Num(t.ty as f64),
                ])
            })
            .collect();
        Json::obj()
            .set("spec", self.spec.to_json())
            .set("initial", Json::Arr(initial))
            .set("preds", Json::Arr(preds))
    }

    /// Parse one slide's entry of the legacy JSON cache format.
    pub fn from_json(v: &Json) -> Result<SlidePredictions, JsonError> {
        let spec = SlideSpec::from_json(v.get("spec")?)?;
        let initial = v
            .get("initial")?
            .as_arr()?
            .iter()
            .map(|t| {
                let t = t.as_arr()?;
                Ok(TileId::new(
                    t[0].as_usize()?,
                    t[1].as_usize()?,
                    t[2].as_usize()?,
                ))
            })
            .collect::<Result<Vec<_>, JsonError>>()?;
        let mut out = SlidePredictions::new(spec, initial);
        for e in v.get("preds")?.as_arr()? {
            let e = e.as_arr()?;
            let tile = TileId::new(e[0].as_usize()?, e[1].as_usize()?, e[2].as_usize()?);
            if !out.insert(tile, e[3].as_f64()? as f32, e[4].as_bool()?) {
                return Err(JsonError::Value(format!(
                    "cached tile {tile} outside the {}x{}x{} pyramid",
                    out.spec.tiles_x, out.spec.tiles_y, out.spec.levels
                )));
            }
        }
        Ok(out)
    }
}

/// A read-only source of per-slide predictions: the seam between
/// prediction *consumers* (tuning sweeps, evaluation, experiments) and
/// prediction *storage*. [`PredCache`] serves slides from memory;
/// [`ShardedPredStore`] streams them from disk shards under its LRU
/// budget. Consumers written against this trait run unchanged either
/// way.
pub trait PredSource {
    /// Number of slides in the source.
    fn n_slides(&self) -> usize;

    /// Run `f` over one slide's predictions. Streaming sources load (and
    /// may later evict) the slide; errors surface I/O or corruption.
    fn with_slide(
        &self,
        index: usize,
        f: &mut dyn FnMut(&SlidePredictions),
    ) -> anyhow::Result<()>;

    /// Pooled (probability, label) pairs at one level across all slides.
    fn pooled_pairs(&self, level: usize) -> anyhow::Result<Vec<(f32, bool)>> {
        let mut out = Vec::new();
        for i in 0..self.n_slides() {
            self.with_slide(i, &mut |s| out.extend(s.level_pairs(level)))?;
        }
        Ok(out)
    }
}

impl<T: PredSource + ?Sized> PredSource for Box<T> {
    fn n_slides(&self) -> usize {
        (**self).n_slides()
    }

    fn with_slide(
        &self,
        index: usize,
        f: &mut dyn FnMut(&SlidePredictions),
    ) -> anyhow::Result<()> {
        (**self).with_slide(index, f)
    }

    fn pooled_pairs(&self, level: usize) -> anyhow::Result<Vec<(f32, bool)>> {
        (**self).pooled_pairs(level)
    }
}

/// A fully-resident cache over a whole slide set, with file I/O.
#[derive(Debug, Clone, Default)]
pub struct PredCache {
    /// Per-slide prediction sets, in collection order.
    pub slides: Vec<SlidePredictions>,
}

impl PredCache {
    /// Collect predictions for a whole slide set, serially.
    pub fn collect_set(slides: &[Slide], analyzer: &dyn Analyzer, batch: usize) -> PredCache {
        PredCache {
            slides: slides
                .iter()
                .map(|s| SlidePredictions::collect(s, analyzer, batch))
                .collect(),
        }
    }

    /// Parallel collection over a thread pool (PJRT executions are
    /// thread-safe; useful on multi-core deployments — on this one-core
    /// testbed it matches `collect_set`).
    pub fn collect_set_parallel(
        specs: &[crate::synth::slide_gen::SlideSpec],
        analyzer: std::sync::Arc<dyn Analyzer>,
        batch: usize,
        jobs: usize,
    ) -> PredCache {
        if jobs <= 1 {
            let slides: Vec<Slide> = specs.iter().cloned().map(Slide::from_spec).collect();
            return Self::collect_set(&slides, analyzer.as_ref(), batch);
        }
        let pool = crate::util::threadpool::ThreadPool::new(jobs);
        let slides = pool.map(specs.to_vec(), move |spec| {
            let slide = Slide::from_spec(spec);
            SlidePredictions::collect(&slide, analyzer.as_ref(), batch)
        });
        PredCache { slides }
    }

    /// Pooled (probability, label) pairs at one level across all slides.
    pub fn level_pairs(&self, level: usize) -> Vec<(f32, bool)> {
        self.slides
            .iter()
            .flat_map(|s| s.level_pairs(level))
            .collect()
    }

    /// Serialize the whole cache (legacy JSON format).
    pub fn to_json(&self) -> Json {
        Json::obj().set(
            "slides",
            Json::Arr(self.slides.iter().map(|s| s.to_json()).collect()),
        )
    }

    /// Parse a whole JSON cache.
    pub fn from_json(v: &Json) -> Result<PredCache, JsonError> {
        Ok(PredCache {
            slides: v
                .get("slides")?
                .as_arr()?
                .iter()
                .map(SlidePredictions::from_json)
                .collect::<Result<Vec<_>, _>>()?,
        })
    }

    /// Write the cache to `path` as compact JSON, streamed slide-by-slide
    /// through a buffered writer — the serialized cache is never
    /// materialized as one string.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        use std::io::Write;
        let file = std::fs::File::create(path)?;
        let mut w = std::io::BufWriter::new(file);
        // Envelope matches `to_json()`'s canonical single-key object.
        w.write_all(b"{\"slides\":[")?;
        for (i, s) in self.slides.iter().enumerate() {
            if i > 0 {
                w.write_all(b",")?;
            }
            s.to_json().write_to(&mut w)?;
        }
        w.write_all(b"]}")?;
        w.flush()
    }

    /// Load a cache written by [`PredCache::save`].
    pub fn load(path: &Path) -> anyhow::Result<PredCache> {
        let text = std::fs::read_to_string(path)?;
        Ok(PredCache::from_json(&Json::parse(&text)?)?)
    }

    /// Write the cache as binary per-slide shards plus a manifest under
    /// `dir` (see [`store::save_sharded`]).
    pub fn save_sharded(&self, dir: &Path, jobs: usize) -> Result<(), StoreError> {
        store::save_sharded(self, dir, jobs)
    }
}

impl PredSource for PredCache {
    fn n_slides(&self) -> usize {
        self.slides.len()
    }

    fn with_slide(
        &self,
        index: usize,
        f: &mut dyn FnMut(&SlidePredictions),
    ) -> anyhow::Result<()> {
        let s = self
            .slides
            .get(index)
            .ok_or_else(|| anyhow::anyhow!("slide {index} out of range"))?;
        f(s);
        Ok(())
    }

    fn pooled_pairs(&self, level: usize) -> anyhow::Result<Vec<(f32, bool)>> {
        Ok(self.level_pairs(level))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::oracle::OracleAnalyzer;
    use crate::synth::slide_gen::SlideKind;

    fn cache_one() -> (Slide, SlidePredictions) {
        let s = Slide::from_spec(SlideSpec::new(
            "pc",
            31,
            16,
            8,
            3,
            64,
            SlideKind::LargeTumor,
        ));
        let a = OracleAnalyzer::new(1);
        let c = SlidePredictions::collect(&s, &a, 8);
        (s, c)
    }

    #[test]
    fn lineage_is_complete() {
        let (_, c) = cache_one();
        let n = c.initial.len();
        let l2 = c.level_pairs(2).len();
        let l1 = c.level_pairs(1).len();
        let l0 = c.level_pairs(0).len();
        assert_eq!(l2, n);
        assert_eq!(l1, n * 4);
        assert_eq!(l0, n * 16);
        assert_eq!(c.reference_count(), n * 16);
        assert_eq!(c.len(), n + n * 4 + n * 16);
    }

    #[test]
    fn replay_matches_live_run() {
        let (s, c) = cache_one();
        let a = OracleAnalyzer::new(1);
        let thr = Thresholds::uniform(3, 0.4);
        let live = crate::pyramid::driver::run_pyramidal(&s, &a, &thr, 8);
        let replayed = c.replay(&thr);
        assert_eq!(live.analyzed_per_level(), replayed.analyzed_per_level());
        assert_eq!(live.nodes[0], replayed.nodes[0]);
    }

    #[test]
    fn replay_is_consistent_for_any_threshold() {
        let (_, c) = cache_one();
        for thr in [0.0, 0.2, 0.5, 0.8, 1.1] {
            let t = c.replay(&Thresholds::uniform(3, thr));
            t.check_consistency().unwrap();
        }
    }

    #[test]
    fn reference_count_uses_checked_arithmetic() {
        // 4^(levels-1) would silently wrap a u32/usize pow chain on deep
        // pyramids; the u128 path stays exact far beyond real depths.
        assert_eq!(reference_tile_count(3, 1), Some(3));
        assert_eq!(reference_tile_count(5, 3), Some(80));
        assert_eq!(reference_tile_count(1, 33), Some(1u128 << 64));
        assert_eq!(reference_tile_count(1, 0), None, "zero levels");
        // Way past any real pyramid: overflow is reported, not wrapped.
        assert_eq!(reference_tile_count(usize::MAX, 64), None);
    }

    #[test]
    fn json_roundtrip() {
        let (_, c) = cache_one();
        let cache = PredCache {
            slides: vec![c.clone()],
        };
        let parsed =
            PredCache::from_json(&Json::parse(&cache.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(parsed.slides.len(), 1);
        let p = &parsed.slides[0];
        assert_eq!(p.spec, c.spec);
        assert_eq!(p.initial, c.initial);
        assert_eq!(p.len(), c.len());
        // probabilities quantized to 1e-6 in the encoding
        for (t, v) in c.iter() {
            let got = p.get(t).unwrap();
            assert!((got.prob - v.prob).abs() < 1e-5);
            assert_eq!(got.tumor, v.tumor);
        }
    }

    #[test]
    fn out_of_pyramid_json_tile_is_an_error_not_a_panic() {
        let (_, c) = cache_one();
        let mut j = c.to_json();
        if let Json::Obj(m) = &mut j {
            if let Some(Json::Arr(preds)) = m.get_mut("preds") {
                preds.push(Json::Arr(vec![
                    Json::Num(9.0), // level 9 of a 3-level pyramid
                    Json::Num(0.0),
                    Json::Num(0.0),
                    Json::Num(0.5),
                    Json::Bool(false),
                ]));
            }
        }
        assert!(SlidePredictions::from_json(&j).is_err());
    }

    #[test]
    fn parallel_collection_matches_serial() {
        use crate::synth::slide_gen::{gen_slide_set, DatasetParams};
        let specs = gen_slide_set(
            "pp",
            4,
            5,
            &DatasetParams {
                tiles_x: 16,
                tiles_y: 8,
                levels: 3,
                tile_px: 64,
            },
        );
        let analyzer: std::sync::Arc<dyn crate::model::Analyzer> =
            std::sync::Arc::new(OracleAnalyzer::new(1));
        let serial = {
            let slides: Vec<Slide> = specs.iter().cloned().map(Slide::from_spec).collect();
            PredCache::collect_set(&slides, analyzer.as_ref(), 8)
        };
        let parallel =
            PredCache::collect_set_parallel(&specs, std::sync::Arc::clone(&analyzer), 8, 3);
        assert_eq!(serial.slides.len(), parallel.slides.len());
        for (a, b) in serial.slides.iter().zip(&parallel.slides) {
            assert_eq!(a.spec.id, b.spec.id);
            assert_eq!(a.len(), b.len());
            for (t, p) in a.iter() {
                assert_eq!(b.get(t), Some(p), "mismatch at {t}");
            }
        }
    }

    #[test]
    fn file_roundtrip() {
        let (_, c) = cache_one();
        let cache = PredCache { slides: vec![c] };
        let dir = std::env::temp_dir().join(format!("pyramidai_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cache.json");
        cache.save(&path).unwrap();
        let loaded = PredCache::load(&path).unwrap();
        assert_eq!(loaded.slides.len(), 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn streamed_save_matches_to_json_exactly() {
        // The streamed writer hand-rolls the envelope; it must stay
        // byte-identical to the canonical serializer or cache files stop
        // being diffable.
        let (_, c) = cache_one();
        let cache = PredCache { slides: vec![c] };
        let dir = std::env::temp_dir().join(format!("pyramidai_stream_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cache.json");
        cache.save(&path).unwrap();
        let on_disk = std::fs::read_to_string(&path).unwrap();
        assert_eq!(on_disk, cache.to_json().to_string());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn file_roundtrip_preserves_replay_and_tuning_inputs() {
        // Save → load must preserve everything downstream code consumes:
        // replayed trees (1e-6 prob quantization must not flip any zoom
        // decision at these thresholds) and per-level tuning pairs.
        let (_, c) = cache_one();
        let cache = PredCache {
            slides: vec![c.clone()],
        };
        let dir = std::env::temp_dir().join(format!("pyramidai_replay_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cache.json");
        cache.save(&path).unwrap();
        let loaded = PredCache::load(&path).unwrap();
        let lp = &loaded.slides[0];
        assert_eq!(lp.initial, c.initial, "initial working set survives I/O");
        for thr in [0.2, 0.4, 0.7] {
            let t = Thresholds::uniform(3, thr);
            let orig = c.replay(&t);
            let back = lp.replay(&t);
            back.check_consistency().unwrap();
            assert_eq!(orig.analyzed_per_level(), back.analyzed_per_level());
            assert_eq!(
                orig.nodes.iter().flatten().map(|n| n.tile).collect::<Vec<_>>(),
                back.nodes.iter().flatten().map(|n| n.tile).collect::<Vec<_>>(),
                "replayed tile sets differ at thr={thr}"
            );
        }
        for level in 0..3 {
            assert_eq!(
                lp.level_pairs(level).len(),
                c.level_pairs(level).len(),
                "tuning pairs lost at level {level}"
            );
        }
        assert_eq!(lp.reference_count(), c.reference_count());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
