//! The sharded on-disk prediction store: one binary shard per slide, a
//! JSON manifest with sizes and checksums, and budgeted lazy loading.
//!
//! [`save_sharded`] writes a [`PredCache`](super::PredCache) as
//! `NNNN_<slide-id>.shard` files (encoded and written in parallel on
//! scoped threads that *borrow* the cache — no per-slide deep clone of
//! a possibly near-RAM-sized slide set) plus a `manifest.json`; the
//! manifest is written last, so a crashed or interrupted save never
//! looks like a complete store.
//!
//! [`ShardedPredStore`] opens the manifest and serves slides on demand:
//! a shard is read, checksummed and decoded only when first touched,
//! kept resident under a configurable memory budget, and evicted LRU
//! when the budget is exceeded — replay jobs over huge slide sets stream
//! shards instead of pinning the whole set in memory.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::obs::{self, Level};
use crate::pyramid::tree::{ExecTree, Thresholds};
use crate::util::json::{Json, JsonError};

use super::shard::{decode_slide, encode_slide, ShardError, SHARD_VERSION};
use super::{PredCache, PredSource, SlidePredictions};

/// Manifest file name inside a store directory.
pub const MANIFEST_FILE: &str = "manifest.json";

/// Why a store operation failed.
#[derive(Debug, thiserror::Error)]
pub enum StoreError {
    /// Filesystem failure.
    #[error("store i/o: {0}")]
    Io(#[from] std::io::Error),
    /// The manifest is missing or malformed.
    #[error("store manifest: {0}")]
    Manifest(String),
    /// Manifest JSON failed to parse.
    #[error("store manifest json: {0}")]
    Json(#[from] JsonError),
    /// A shard failed to decode (truncation, checksum, version…).
    #[error("shard for slide {slide:?}: {source}")]
    Shard {
        /// Slide id of the offending shard.
        slide: String,
        /// The underlying decode failure.
        source: ShardError,
    },
    /// A shard's on-disk size diverged from the manifest (partial write
    /// or external tampering).
    #[error("shard for slide {slide:?} is {actual} bytes on disk, manifest says {expected}")]
    SizeMismatch {
        /// Slide id of the offending shard.
        slide: String,
        /// Byte size recorded in the manifest.
        expected: u64,
        /// Byte size observed on disk.
        actual: u64,
    },
    /// Slide index outside the manifest.
    #[error("slide index {index} out of range ({len} slides)")]
    OutOfRange {
        /// The requested index.
        index: usize,
        /// Number of slides in the store.
        len: usize,
    },
    /// A streamed replay failed (the underlying shard load error is
    /// formatted into the message).
    #[error("streamed replay failed: {0}")]
    Replay(String),
}

/// One manifest row.
#[derive(Debug, Clone)]
struct ShardEntry {
    /// Slide id (matches the embedded spec).
    id: String,
    /// Shard file name relative to the store directory.
    file: String,
    /// Shard byte size (validated on load).
    bytes: u64,
    /// Shard CRC-32 (the shard's own footer; cross-checked on load).
    crc32: u32,
    /// Pyramid depth (service admission needs it without loading).
    levels: usize,
}

/// Residency and traffic counters of a store (see
/// [`ShardedPredStore::stats`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// Shard files read and decoded (a reload after eviction counts
    /// again).
    pub loads: u64,
    /// Requests served from resident memory.
    pub hits: u64,
    /// Shards evicted to stay under the budget.
    pub evictions: u64,
    /// Bytes currently resident.
    pub resident_bytes: usize,
    /// Slides currently resident.
    pub resident_slides: usize,
}

struct Residency {
    /// Resident slides by index.
    resident: HashMap<usize, Arc<SlidePredictions>>,
    /// LRU order: front = least recently used.
    order: Vec<usize>,
    bytes: usize,
    loads: u64,
    hits: u64,
    evictions: u64,
}

/// Lazily-loading, budgeted view over a shard directory.
pub struct ShardedPredStore {
    dir: PathBuf,
    entries: Vec<ShardEntry>,
    /// Resident-set budget in bytes (`usize::MAX` = unlimited).
    budget: usize,
    state: Mutex<Residency>,
}

fn sanitize(id: &str) -> String {
    id.chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || c == '_' || c == '-' {
                c
            } else {
                '_'
            }
        })
        .collect()
}

/// Write `cache` as one binary shard per slide plus a manifest under
/// `dir` (created if needed). Shards are encoded and written in parallel
/// when `jobs > 1`; the manifest goes last so a torn save is never
/// openable.
pub fn save_sharded(cache: &PredCache, dir: &Path, jobs: usize) -> Result<(), StoreError> {
    std::fs::create_dir_all(dir)?;
    let names: Vec<String> = cache
        .slides
        .iter()
        .enumerate()
        .map(|(i, s)| format!("{i:04}_{}.shard", sanitize(&s.spec.id)))
        .collect();
    let write_one = |slide: &SlidePredictions, file: &str| -> Result<(u64, u32), StoreError> {
        let bytes = encode_slide(slide);
        let crc = u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().expect("crc footer"));
        // Atomic (tmp + fsync + rename): a crash or injected disk fault
        // mid-save leaves no half-written shard under the final name.
        crate::fault::write_atomic(&dir.join(file), &bytes)?;
        Ok((bytes.len() as u64, crc))
    };
    let n = cache.slides.len();
    let mut written: Vec<Option<Result<(u64, u32), StoreError>>> = (0..n).map(|_| None).collect();
    let workers = jobs.max(1).min(n.max(1));
    if workers > 1 {
        // Scoped threads borrow the cache directly — no per-slide deep
        // clone, so a near-RAM-sized cache saves without doubling its
        // footprint (the whole point of the sharded store).
        let slides = &cache.slides;
        let names = &names;
        let write_one = &write_one;
        let chunks: Vec<Vec<(usize, Result<(u64, u32), StoreError>)>> =
            std::thread::scope(|scope| {
                let handles: Vec<_> = (0..workers)
                    .map(|t| {
                        scope.spawn(move || {
                            (t..n)
                                .step_by(workers)
                                .map(|i| (i, write_one(&slides[i], &names[i])))
                                .collect::<Vec<_>>()
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("shard writer thread"))
                    .collect()
            });
        for chunk in chunks {
            for (i, r) in chunk {
                written[i] = Some(r);
            }
        }
    } else {
        for (i, (s, name)) in cache.slides.iter().zip(&names).enumerate() {
            written[i] = Some(write_one(s, name));
        }
    }
    let mut rows = Vec::with_capacity(n);
    for ((slide, name), res) in cache.slides.iter().zip(&names).zip(written) {
        let (bytes, crc) = res.expect("every slide written")?;
        rows.push(ShardEntry {
            id: slide.spec.id.clone(),
            file: name.clone(),
            bytes,
            crc32: crc,
            levels: slide.spec.levels,
        });
    }
    write_manifest(dir, &rows)
}

/// Write the manifest for `rows` atomically (tmp + fsync + rename): a
/// reader opening the store concurrently sees the old complete manifest
/// or the new one, never a torn hybrid.
fn write_manifest(dir: &Path, rows: &[ShardEntry]) -> Result<(), StoreError> {
    let json_rows: Vec<Json> = rows
        .iter()
        .map(|e| {
            Json::obj()
                .set("id", e.id.as_str())
                .set("file", e.file.as_str())
                .set("bytes", e.bytes as f64)
                .set("crc32", e.crc32 as f64)
                .set("levels", e.levels as f64)
        })
        .collect();
    let manifest = Json::obj()
        .set("version", SHARD_VERSION as f64)
        .set("slides", Json::Arr(json_rows));
    crate::fault::write_atomic(&dir.join(MANIFEST_FILE), manifest.to_pretty().as_bytes())?;
    Ok(())
}

/// Parse a store directory's manifest into its rows.
fn read_manifest(dir: &Path) -> Result<Vec<ShardEntry>, StoreError> {
    let path = dir.join(MANIFEST_FILE);
    let text = std::fs::read_to_string(&path)
        .map_err(|e| StoreError::Manifest(format!("cannot read {}: {e}", path.display())))?;
    let v = Json::parse(&text)?;
    let version = v.get("version")?.as_u64()? as u32;
    if version != SHARD_VERSION {
        return Err(StoreError::Manifest(format!(
            "manifest version {version}, this build reads {SHARD_VERSION}"
        )));
    }
    let mut entries = Vec::new();
    for row in v.get("slides")?.as_arr()? {
        entries.push(ShardEntry {
            id: row.get("id")?.as_str()?.to_string(),
            file: row.get("file")?.as_str()?.to_string(),
            bytes: row.get("bytes")?.as_u64()?,
            crc32: row.get("crc32")?.as_u64()? as u32,
            levels: row.get("levels")?.as_usize()?,
        });
    }
    Ok(entries)
}

impl ShardedPredStore {
    /// Open a store directory with no memory budget (everything touched
    /// stays resident).
    pub fn open(dir: &Path) -> Result<ShardedPredStore, StoreError> {
        Self::open_with_budget(dir, None)
    }

    /// Open a store directory keeping at most `budget_mb` MiB of decoded
    /// slides resident (LRU eviction; the most recent slide always
    /// stays). `None` = unlimited.
    pub fn open_with_budget(
        dir: &Path,
        budget_mb: Option<usize>,
    ) -> Result<ShardedPredStore, StoreError> {
        let entries = read_manifest(dir)?;
        Ok(ShardedPredStore {
            dir: dir.to_path_buf(),
            entries,
            budget: budget_mb.map_or(usize::MAX, |mb| mb.saturating_mul(1 << 20)),
            state: Mutex::new(Residency {
                resident: HashMap::new(),
                order: Vec::new(),
                bytes: 0,
                loads: 0,
                hits: 0,
                evictions: 0,
            }),
        })
    }

    /// The store directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Number of slides in the manifest.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when the manifest lists no slides.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Slide id at `index` (manifest order = collection order).
    pub fn slide_id(&self, index: usize) -> Option<&str> {
        self.entries.get(index).map(|e| e.id.as_str())
    }

    /// Pyramid depth of the slide at `index`, without loading its shard.
    pub fn slide_levels(&self, index: usize) -> Option<usize> {
        self.entries.get(index).map(|e| e.levels)
    }

    /// Residency/traffic counters (loads, hits, evictions, bytes).
    pub fn stats(&self) -> StoreStats {
        let s = self.state.lock().unwrap();
        StoreStats {
            loads: s.loads,
            hits: s.hits,
            evictions: s.evictions,
            resident_bytes: s.bytes,
            resident_slides: s.resident.len(),
        }
    }

    /// One slide's predictions, loading (and possibly evicting) under
    /// the budget. The returned `Arc` stays valid after eviction — the
    /// store merely drops *its* reference.
    pub fn slide(&self, index: usize) -> Result<Arc<SlidePredictions>, StoreError> {
        let entry = self.entries.get(index).ok_or(StoreError::OutOfRange {
            index,
            len: self.entries.len(),
        })?;
        {
            let mut s = self.state.lock().unwrap();
            if let Some(p) = s.resident.get(&index) {
                let p = Arc::clone(p);
                s.hits += 1;
                obs::global_metrics().counter("predcache.hits").inc();
                // Move to most-recently-used.
                s.order.retain(|&i| i != index);
                s.order.push(index);
                return Ok(p);
            }
        }
        // Read + checksum + decode happen outside the residency lock, so
        // a concurrent user hitting an already-resident slide never
        // stalls behind this miss's disk work.
        let decode_start = Instant::now();
        let path = self.dir.join(&entry.file);
        // `fault::io::read` = `fs::read` plus any injected transient
        // read-side bit flip; the CRC checks below are the detectors.
        let bytes = crate::fault::io::read(&path)?;
        if bytes.len() as u64 != entry.bytes {
            return Err(StoreError::SizeMismatch {
                slide: entry.id.clone(),
                expected: entry.bytes,
                actual: bytes.len() as u64,
            });
        }
        // Guard the footer slice: a manifest that (corruptly) records a
        // sub-header size must error, not panic.
        if bytes.len() < 12 {
            return Err(StoreError::Shard {
                slide: entry.id.clone(),
                source: ShardError::Truncated {
                    at: bytes.len(),
                    needed: 12 - bytes.len(),
                },
            });
        }
        // Cross-check the shard footer against the manifest row. This is
        // *not* a content checksum (decode recomputes that); a mismatch
        // here means the shard was replaced without rewriting the
        // manifest — say so, instead of masquerading as file corruption.
        let stored_crc = u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().unwrap());
        if stored_crc != entry.crc32 {
            return Err(StoreError::Manifest(format!(
                "shard {} footer crc {stored_crc:#010x} does not match manifest crc \
                 {:#010x} — stale or tampered manifest",
                entry.file, entry.crc32
            )));
        }
        let decoded = decode_slide(&bytes).map_err(|source| StoreError::Shard {
            slide: entry.id.clone(),
            source,
        })?;
        if decoded.spec.id != entry.id {
            return Err(StoreError::Manifest(format!(
                "shard {} contains slide {:?}, manifest says {:?}",
                entry.file, decoded.spec.id, entry.id
            )));
        }
        let decode_us = decode_start.elapsed().as_micros() as u64;
        obs::global_metrics()
            .histogram("predcache.decode_us")
            .record(decode_us);
        obs::span_event(
            Level::Debug,
            "predcache",
            "shard_decode",
            decode_us,
            &[
                ("slide", index.into()),
                ("bytes", entry.bytes.into()),
            ],
        );
        let p = Arc::new(decoded);
        let mut s = self.state.lock().unwrap();
        if let Some(existing) = s.resident.get(&index) {
            // A concurrent caller loaded the same slide while we read the
            // disk; keep its copy (one resident instance per slide).
            let existing = Arc::clone(existing);
            s.hits += 1;
            obs::global_metrics().counter("predcache.hits").inc();
            s.order.retain(|&i| i != index);
            s.order.push(index);
            return Ok(existing);
        }
        s.loads += 1;
        obs::global_metrics().counter("predcache.loads").inc();
        s.bytes += p.resident_bytes();
        s.resident.insert(index, Arc::clone(&p));
        s.order.push(index);
        // Evict least-recently-used shards until back under budget; the
        // slide just loaded is always allowed to stay (a budget smaller
        // than one slide degrades to load-per-touch, not failure).
        while s.bytes > self.budget && s.order.len() > 1 {
            let victim = s.order.remove(0);
            if let Some(v) = s.resident.remove(&victim) {
                s.bytes -= v.resident_bytes();
                s.evictions += 1;
                obs::global_metrics().counter("predcache.evictions").inc();
            }
        }
        Ok(p)
    }

    /// Decode every shard once, sequentially under the budget — a cheap
    /// integrity pass for CLI entry points.
    pub fn validate(&self) -> Result<(), StoreError> {
        for i in 0..self.len() {
            self.slide(i)?;
        }
        Ok(())
    }

    /// Load the whole store into a fully-resident [`PredCache`]
    /// (collection order). Ignores the budget — use only when the caller
    /// genuinely needs everything in memory (the experiment context).
    pub fn load_all(&self) -> Result<PredCache, StoreError> {
        let mut slides = Vec::with_capacity(self.len());
        for i in 0..self.len() {
            slides.push(self.slide(i)?.as_ref().clone());
        }
        Ok(PredCache { slides })
    }

    /// Replay one slide under `thresholds`, streaming its shard through
    /// the budgeted store (the shard may be evicted and reloaded between
    /// frontier requests). The tree is byte-identical to
    /// [`SlidePredictions::replay`] on the same data.
    pub fn replay(&self, index: usize, thresholds: &Thresholds) -> Result<ExecTree, StoreError> {
        let (id, levels, initial) = {
            let s = self.slide(index)?;
            (s.spec.id.clone(), s.spec.levels, s.initial.clone())
        };
        let mut backend = crate::pyramid::backend::StoreReplayBackend::new(self, index);
        let tree = crate::pyramid::backend::run_on_backend(
            &id, levels, initial, thresholds, 0, &mut backend,
        );
        match tree {
            Ok(t) => Ok(t),
            Err(e) => Err(backend
                .take_error()
                .unwrap_or_else(|| StoreError::Replay(e.to_string()))),
        }
    }
}

impl PredSource for ShardedPredStore {
    fn n_slides(&self) -> usize {
        self.len()
    }

    fn with_slide(
        &self,
        index: usize,
        f: &mut dyn FnMut(&SlidePredictions),
    ) -> anyhow::Result<()> {
        let s = self.slide(index)?;
        f(&s);
        Ok(())
    }
}

/// Convert a legacy whole-cache JSON file into a shard directory.
/// Returns the number of slides migrated.
pub fn import_json(json_path: &Path, dir: &Path, jobs: usize) -> anyhow::Result<usize> {
    let cache = PredCache::load(json_path)?;
    let n = cache.slides.len();
    save_sharded(&cache, dir, jobs)?;
    Ok(n)
}

/// Subdirectory bad shards are moved into by a repairing [`fsck`].
pub const QUARANTINE_DIR: &str = "quarantine";

/// Outcome of one [`fsck`] pass over a shard store.
#[derive(Debug, Default)]
pub struct FsckReport {
    /// Manifest rows examined.
    pub checked: usize,
    /// Bad shards as `(file, reason)` — missing, truncated, corrupt,
    /// mislabeled, or diverged from the manifest.
    pub bad: Vec<(String, String)>,
    /// Files in the store directory the manifest does not account for:
    /// leftover `*.tmp` from torn writes, unlisted shards, strays.
    pub orphans: Vec<String>,
    /// Shards moved to [`QUARANTINE_DIR`] (always 0 on a dry run).
    pub quarantined: usize,
}

impl FsckReport {
    /// True when every shard verified clean and nothing was orphaned.
    pub fn clean(&self) -> bool {
        self.bad.is_empty() && self.orphans.is_empty()
    }
}

/// Check every shard a store's manifest lists — existence, manifest
/// size, footer CRC against the manifest row, full decode (payload
/// checksum, version, truncation) and slide-id cross-check — plus a
/// directory sweep for files the manifest does not account for.
///
/// With `dry_run` the report only describes the damage. Without it the
/// store is *repaired in place to a degraded but openable state*: bad
/// and orphaned shards move to `quarantine/`, leftover `*.tmp` files
/// from torn writes are deleted, and the manifest is atomically
/// rewritten without the quarantined rows — readers lose the bad
/// slides instead of losing the store (DESIGN.md §16 degraded-mode
/// contract).
pub fn fsck(dir: &Path, dry_run: bool) -> Result<FsckReport, StoreError> {
    let entries = read_manifest(dir)?;
    let mut report = FsckReport {
        checked: entries.len(),
        ..FsckReport::default()
    };
    let mut good = Vec::with_capacity(entries.len());
    for entry in entries {
        match check_shard(dir, &entry) {
            None => good.push(entry),
            Some(reason) => report.bad.push((entry.file.clone(), reason)),
        }
    }
    // Sweep for files the manifest does not explain. Shard saves are
    // tmp+rename, so a `.tmp` here is the debris of a torn write.
    let listed: std::collections::HashSet<&str> = good.iter().map(|e| e.file.as_str()).collect();
    let bad_files: std::collections::HashSet<&str> =
        report.bad.iter().map(|(f, _)| f.as_str()).collect();
    for e in std::fs::read_dir(dir)? {
        let e = e?;
        let name = e.file_name().to_string_lossy().into_owned();
        if name == MANIFEST_FILE
            || name == QUARANTINE_DIR
            || listed.contains(name.as_str())
            || bad_files.contains(name.as_str())
        {
            continue;
        }
        if e.file_type()?.is_file() {
            report.orphans.push(name);
        }
    }
    report.orphans.sort();
    if report.clean() || dry_run {
        return Ok(report);
    }

    // --- repair: quarantine, sweep, rewrite ------------------------------
    let qdir = dir.join(QUARANTINE_DIR);
    std::fs::create_dir_all(&qdir)?;
    for (file, _) in &report.bad {
        // A missing shard has nothing to move; everything else is
        // preserved for post-mortem rather than deleted.
        if std::fs::rename(dir.join(file), qdir.join(file)).is_ok() {
            report.quarantined += 1;
        }
    }
    for name in &report.orphans {
        if name.ends_with(".tmp") {
            std::fs::remove_file(dir.join(name))?;
        } else if std::fs::rename(dir.join(name), qdir.join(name)).is_ok() {
            report.quarantined += 1;
        }
    }
    write_manifest(dir, &good)?;
    obs::global_metrics()
        .counter("predcache.fsck_quarantined")
        .add(report.quarantined as u64);
    obs::event(
        Level::Warn,
        "predcache",
        "fsck_repair",
        &[
            ("bad", report.bad.len().into()),
            ("orphans", report.orphans.len().into()),
            ("quarantined", report.quarantined.into()),
            ("kept", good.len().into()),
        ],
    );
    Ok(report)
}

/// Validate one manifest row against its on-disk shard; `None` = clean,
/// `Some(reason)` = every detectable corruption class from the §16
/// fault taxonomy (torn write → size mismatch or truncated decode,
/// bit flip → CRC mismatch, replaced file → footer or id divergence).
fn check_shard(dir: &Path, entry: &ShardEntry) -> Option<String> {
    let bytes = match std::fs::read(dir.join(&entry.file)) {
        Ok(b) => b,
        Err(e) => return Some(format!("unreadable: {e}")),
    };
    if bytes.len() as u64 != entry.bytes {
        return Some(format!(
            "{} bytes on disk, manifest says {} (torn write?)",
            bytes.len(),
            entry.bytes
        ));
    }
    if bytes.len() < 12 {
        return Some(format!("{} bytes is below the shard header", bytes.len()));
    }
    let footer = u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().unwrap());
    if footer != entry.crc32 {
        return Some(format!(
            "footer crc {footer:#010x} != manifest crc {:#010x}",
            entry.crc32
        ));
    }
    match decode_slide(&bytes) {
        Err(e) => Some(format!("decode failed: {e}")),
        Ok(decoded) if decoded.spec.id != entry.id => Some(format!(
            "contains slide {:?}, manifest says {:?}",
            decoded.spec.id, entry.id
        )),
        Ok(_) => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::oracle::OracleAnalyzer;
    use crate::slide::pyramid::Slide;
    use crate::synth::slide_gen::{gen_slide_set, DatasetParams};

    fn small_cache(n: usize, seed: u64) -> PredCache {
        let params = DatasetParams {
            tiles_x: 16,
            tiles_y: 8,
            levels: 3,
            tile_px: 64,
        };
        let slides: Vec<Slide> = gen_slide_set("st", n, seed, &params)
            .into_iter()
            .map(Slide::from_spec)
            .collect();
        PredCache::collect_set(&slides, &OracleAnalyzer::new(1), 16)
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "pyramidai_store_{tag}_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn save_open_roundtrip_preserves_everything() {
        let cache = small_cache(3, 7);
        let dir = tmp_dir("rt");
        save_sharded(&cache, &dir, 2).unwrap();
        let store = ShardedPredStore::open(&dir).unwrap();
        assert_eq!(store.len(), 3);
        for i in 0..3 {
            assert_eq!(store.slide_id(i).unwrap(), cache.slides[i].spec.id);
            assert_eq!(store.slide_levels(i), Some(3));
            let s = store.slide(i).unwrap();
            assert_eq!(s.len(), cache.slides[i].len());
            for (t, p) in cache.slides[i].iter() {
                assert_eq!(s.get(t), Some(p), "slide {i} tile {t}");
            }
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn lru_budget_evicts_and_reloads() {
        let cache = small_cache(4, 9);
        let dir = tmp_dir("lru");
        save_sharded(&cache, &dir, 1).unwrap();
        // Budget of 0 MiB: only the most recent slide is ever resident.
        let store = ShardedPredStore::open_with_budget(&dir, Some(0)).unwrap();
        for i in 0..4 {
            store.slide(i).unwrap();
        }
        let st = store.stats();
        assert_eq!(st.resident_slides, 1, "tiny budget keeps one shard");
        assert_eq!(st.loads, 4);
        assert_eq!(st.evictions, 3);
        // Touching an evicted slide reloads it.
        store.slide(0).unwrap();
        assert_eq!(store.stats().loads, 5);
        // Touching the resident one is a hit.
        store.slide(0).unwrap();
        assert_eq!(store.stats().hits, 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn pooled_pairs_match_in_memory_cache() {
        let cache = small_cache(3, 11);
        let dir = tmp_dir("pairs");
        save_sharded(&cache, &dir, 1).unwrap();
        let store = ShardedPredStore::open_with_budget(&dir, Some(0)).unwrap();
        for level in 0..3 {
            let a = PredSource::pooled_pairs(&cache, level).unwrap();
            let b = store.pooled_pairs(level).unwrap();
            assert_eq!(a, b, "level {level}");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupt_shard_is_an_error_not_a_panic() {
        let cache = small_cache(1, 13);
        let dir = tmp_dir("corrupt");
        save_sharded(&cache, &dir, 1).unwrap();
        let store = ShardedPredStore::open(&dir).unwrap();
        let file = dir.join(
            std::fs::read_dir(&dir)
                .unwrap()
                .filter_map(|e| e.ok())
                .map(|e| e.file_name().to_string_lossy().into_owned())
                .find(|n| n.ends_with(".shard"))
                .unwrap(),
        );
        // Flip one payload byte without changing the size.
        let mut bytes = std::fs::read(&file).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        std::fs::write(&file, &bytes).unwrap();
        assert!(matches!(
            store.slide(0).unwrap_err(),
            StoreError::Shard { .. }
        ));
        // Truncate: size mismatch against the manifest.
        std::fs::write(&file, &bytes[..bytes.len() / 2]).unwrap();
        assert!(matches!(
            store.slide(0).unwrap_err(),
            StoreError::SizeMismatch { .. }
        ));
        assert!(store.validate().is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn fsck_detects_and_quarantines_every_corruption_class() {
        let cache = small_cache(3, 19);
        let dir = tmp_dir("fsck");
        save_sharded(&cache, &dir, 1).unwrap();
        let mut shards: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| n.ends_with(".shard"))
            .collect();
        shards.sort();
        assert_eq!(shards.len(), 3);
        // Class 1: payload bit flip (footer stays → decode CRC catches it).
        let f0 = dir.join(&shards[0]);
        let mut b = std::fs::read(&f0).unwrap();
        let mid = b.len() / 2;
        b[mid] ^= 0x01;
        std::fs::write(&f0, &b).unwrap();
        // Class 2: torn write (size diverges from the manifest).
        let f1 = dir.join(&shards[1]);
        let b = std::fs::read(&f1).unwrap();
        std::fs::write(&f1, &b[..b.len() / 3]).unwrap();
        // Class 3: torn-write debris — a stray tmp the sweep must flag.
        std::fs::write(dir.join(".9999_junk.shard.tmp"), b"partial").unwrap();

        let dry = fsck(&dir, true).unwrap();
        assert_eq!(dry.checked, 3);
        assert_eq!(dry.bad.len(), 2, "bad: {:?}", dry.bad);
        assert_eq!(dry.orphans, vec![".9999_junk.shard.tmp".to_string()]);
        assert_eq!(dry.quarantined, 0, "dry run must not touch the store");
        assert!(!dry.clean());
        // Dry run left the damage in place: the store still errors.
        assert!(ShardedPredStore::open(&dir).unwrap().validate().is_err());

        let rep = fsck(&dir, false).unwrap();
        assert_eq!(rep.bad.len(), 2);
        assert_eq!(rep.quarantined, 2, "both bad shards moved");
        assert!(!dir.join(".9999_junk.shard.tmp").exists(), "tmp swept");
        assert!(dir.join(QUARANTINE_DIR).join(&shards[0]).exists());
        assert!(dir.join(QUARANTINE_DIR).join(&shards[1]).exists());
        // The repaired store opens degraded (one slide) but fully valid.
        let store = ShardedPredStore::open(&dir).unwrap();
        assert_eq!(store.len(), 1);
        store.validate().unwrap();
        assert!(fsck(&dir, true).unwrap().clean());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn fsck_flags_missing_and_mislabeled_shards() {
        let cache = small_cache(2, 23);
        let dir = tmp_dir("fsck2");
        save_sharded(&cache, &dir, 1).unwrap();
        let mut shards: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| n.ends_with(".shard"))
            .collect();
        shards.sort();
        // Missing file + mislabeled content (slide 1's bytes under slide
        // 0's name — footer crc diverges from the manifest row).
        let b1 = std::fs::read(dir.join(&shards[1])).unwrap();
        std::fs::write(dir.join(&shards[0]), &b1).unwrap();
        std::fs::remove_file(dir.join(&shards[1])).unwrap();
        let dry = fsck(&dir, true).unwrap();
        assert_eq!(dry.bad.len(), 2, "bad: {:?}", dry.bad);
        let rep = fsck(&dir, false).unwrap();
        // The missing shard has nothing to move; the mislabeled one does.
        assert_eq!(rep.quarantined, 1);
        let store = ShardedPredStore::open(&dir).unwrap();
        assert_eq!(store.len(), 0);
        assert!(fsck(&dir, true).unwrap().clean());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_manifest_fails_cleanly() {
        let dir = tmp_dir("nomanifest");
        assert!(matches!(
            ShardedPredStore::open(&dir).unwrap_err(),
            StoreError::Manifest(_)
        ));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn json_import_preserves_replay() {
        let cache = small_cache(2, 17);
        let dir = tmp_dir("import");
        let json = dir.join("cache.json");
        cache.save(&json).unwrap();
        let shard_dir = dir.join("shards");
        let n = import_json(&json, &shard_dir, 1).unwrap();
        assert_eq!(n, 2);
        let store = ShardedPredStore::open(&shard_dir).unwrap();
        // The JSON format quantizes probabilities to 1e-6, so compare
        // against the *JSON-loaded* cache — the shard must preserve it
        // exactly from there.
        let from_json = PredCache::load(&json).unwrap();
        let thr = Thresholds::uniform(3, 0.4);
        for i in 0..2 {
            let a = from_json.slides[i].replay(&thr);
            let b = store.slide(i).unwrap().replay(&thr);
            assert_eq!(a.nodes, b.nodes, "slide {i}");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
