//! Flat-array tile renderer: the hot-path replacement for per-pixel
//! [`Texture::pixel`] calls, **bit-identical by construction**.
//!
//! `Texture::pixel` is beautiful and slow: every pixel re-walks every
//! metaball blob of three fields (recomputing `2r²` denominators and the
//! row-constant `dv²` terms), re-hashes the 3×3 nuclei neighborhood, and
//! re-derives per-column quantities like `u = (px+0.5)/w` from scratch.
//! [`TileRenderer`] renders a whole span of columns row by row and hoists
//! everything that is constant along one of the two axes:
//!
//! * **Per span (column axis)** — `u`, `x0 = (px+0.5)·scale`, the nuclei
//!   cell index `⌊x0/cell⌋`, and the per-blob `du²` table, laid out
//!   per-pixel-contiguous (`du2[col·n + i]`) so the inner blob loop walks
//!   one cache line instead of striding across the span.
//! * **Per row (row axis)** — `v`, `y0`, each field's `dv²` terms, and an
//!   *active-blob compaction*: blobs whose row distance alone already puts
//!   them past the far cutoff are dropped from the row's working set, so
//!   the inner loop does literally zero work for them.
//! * **Per cell row** — when columns advance by less than one nuclei cell
//!   (contiguous rendering at fine levels) the 3 lattice rows covering the
//!   span are cached; the cheap presence hash is taken eagerly, the jitter
//!   and radius hashes lazily on first contribution. When the sampling
//!   stride jumps whole cells (the strided Otsu luma pass at coarse
//!   levels) the cache would be built and thrown away, so the renderer
//!   falls back to the scalar 3×3 scan there.
//!
//! # Bit-identity
//!
//! The scalar path stays in `texture.rs` as the reference implementation,
//! and `golden_*` tests below assert bit-identical `f32` output across
//! levels, tile sizes, strides and boundary tiles. Identity holds because
//! every floating-point operation that *feeds a result* is performed in
//! the same order on the same values as the scalar code; hoisting only
//! changes *when* a value is computed, never *how*. Two transformations
//! need an argument beyond reordering:
//!
//! * **Far-blob skip.** A blob is skipped when `d² ≥ 77·(2r²)`, i.e. its
//!   term `w·exp(-d²/2r²) < w·e⁻⁷⁷ ≈ w·3.6e-34 < 2⁻¹⁰⁸` for any sane
//!   weight (|w| ≤ 10⁴; generated weights are ≤ 4). Field sums feed
//!   `sigmoid((s-1)·8)` with s otherwise ≥ 0 terms; a perturbation below
//!   2⁻¹⁰⁸ is smaller than half an ulp of every downstream double, so the
//!   rounded result cannot change. Validated exhaustively against the
//!   scalar path in tests.
//! * **Empty-sum shortcut.** If no blob contributes (compacted set empty,
//!   or every candidate skipped, so `s == 0.0` exactly) the scalar path
//!   computes `sigmoid((0.0-1.0)·8.0)`; the renderer returns that exact
//!   cached constant.
//!
//! The C/Python prototypes of this scheme (see EXPERIMENTS.md, "Hot-path
//! overhaul") measured 1.6x on small-scattered slides (the paper's hard
//! case, many small blobs → heavy compaction wins) and 1.2–1.3x on the
//! other kinds at level 0, with zero mismatching pixels.

use super::field::{sigmoid, Field};
use super::texture::{hash2, unit, Texture, TextureParams, NUCLEI_CELL_L0};

/// Skip a blob when `d² ≥ FAR_CUT · 2r²`: its term is below `e⁻⁷⁷` of its
/// weight, far under half an ulp of anything the sum feeds (see module
/// docs).
const FAR_CUT: f64 = 77.0;

/// One metaball field, preprocessed for row-major span rendering.
struct FieldRows {
    n: usize,
    cx: Vec<f64>,
    cy: Vec<f64>,
    w: Vec<f64>,
    /// `2r²` per blob — the Gaussian denominator the scalar path
    /// recomputes per pixel.
    denom: Vec<f64>,
    /// `FAR_CUT · denom` per blob.
    cut: Vec<f64>,
    /// Per-span `du²` table, per-pixel-contiguous: `du2[col·n + i]`.
    du2: Vec<f64>,
    /// Indices of blobs not already past the cutoff on the current row.
    act: Vec<u32>,
    /// `dv²` of each active blob (parallel to `act`).
    adv2: Vec<f64>,
    /// `sigmoid((0.0-1.0)·8.0)` — the scalar result when the sum is 0.
    sig_empty: f64,
}

impl FieldRows {
    fn new(f: &Field) -> FieldRows {
        let n = f.blobs.len();
        let mut r = FieldRows {
            n,
            cx: Vec::with_capacity(n),
            cy: Vec::with_capacity(n),
            w: Vec::with_capacity(n),
            denom: Vec::with_capacity(n),
            cut: Vec::with_capacity(n),
            du2: Vec::new(),
            act: Vec::with_capacity(n),
            adv2: Vec::with_capacity(n),
            sig_empty: sigmoid((0.0 - 1.0) * 8.0),
        };
        for b in &f.blobs {
            r.cx.push(b.cx);
            r.cy.push(b.cy);
            r.w.push(b.w);
            // Same association as the scalar `2.0 * b.r * b.r`.
            let denom = 2.0 * b.r * b.r;
            r.denom.push(denom);
            r.cut.push(FAR_CUT * denom);
        }
        r
    }

    /// Precompute `du²` for every (column, blob) pair of the span.
    fn set_cols(&mut self, us: &[f64]) {
        self.du2.clear();
        self.du2.reserve(us.len() * self.n);
        for &u in us {
            for &cx in &self.cx {
                let du = u - cx;
                self.du2.push(du * du);
            }
        }
    }

    /// Enter a row: compute `dv²` and compact the active blob set.
    fn set_row(&mut self, v: f64) {
        self.act.clear();
        self.adv2.clear();
        for i in 0..self.n {
            let dv = v - self.cy[i];
            let dv2 = dv * dv;
            if dv2 < self.cut[i] {
                self.act.push(i as u32);
                self.adv2.push(dv2);
            }
        }
    }

    /// `Field::soft` at span column `col` of the current row.
    #[inline]
    fn soft_at(&self, col: usize) -> f64 {
        if self.act.is_empty() {
            return self.sig_empty;
        }
        let du2 = &self.du2[col * self.n..(col + 1) * self.n];
        let mut s = 0.0;
        for (k, &i) in self.act.iter().enumerate() {
            let i = i as usize;
            // Same order as scalar `du*du + dv*dv`.
            let d2 = du2[i] + self.adv2[k];
            if d2 >= self.cut[i] {
                continue;
            }
            s += self.w[i] * (-d2 / self.denom[i]).exp();
        }
        if s == 0.0 {
            return self.sig_empty;
        }
        sigmoid((s - 1.0) * 8.0)
    }
}

/// Per-column precomputed values of the current span.
#[derive(Clone, Copy)]
struct ColPre {
    /// Column position in the level's pixel grid.
    px: usize,
    /// `x0 = (px+0.5)·scale` in level-0 pixel space.
    x0: f64,
    /// Nuclei lattice column `⌊x0/cell⌋`.
    cx: i64,
}

/// One nuclei lattice cell of the cached 3-row neighborhood.
struct Cell {
    /// Presence hash value `unit(h)` (taken eagerly: one hash per cell).
    uh: f64,
    /// The cell's base hash, for lazy jitter/radius derivation.
    h: u64,
    gx: i64,
    gy: i64,
    /// Jittered nucleus center (valid when `filled`).
    nx: f64,
    ny: f64,
    /// Radius hash `unit(hash2(h,3,0))` (valid when `filled`).
    u3: f64,
    filled: bool,
}

impl Cell {
    #[inline]
    fn fill(&mut self) {
        let jx = unit(hash2(self.h, 1, 0));
        let jy = unit(hash2(self.h, 2, 0));
        self.nx = (self.gx as f64 + jx) * NUCLEI_CELL_L0;
        self.ny = (self.gy as f64 + jy) * NUCLEI_CELL_L0;
        self.u3 = unit(hash2(self.h, 3, 0));
        self.filled = true;
    }
}

/// Row-major span renderer over one slide level. Build once per tile (or
/// reuse across a whole level's tiles), call [`set_span`](Self::set_span)
/// per column set, [`begin_row`](Self::begin_row) per row, and
/// [`pixel`](Self::pixel) per span column.
pub struct TileRenderer<'a> {
    params: &'a TextureParams,
    seed: u64,
    noise_seed: u64,
    nuc_seed: u64,
    w_px: usize,
    h_px: usize,
    w_f: f64,
    h_f: f64,
    scale: f64,
    blur2: f64,
    attenuation: f64,
    tissue: FieldRows,
    tumor: FieldRows,
    distractor: FieldRows,
    // --- span state -----------------------------------------------------
    cols: Vec<ColPre>,
    /// Use the cached 3-row nuclei neighborhood (columns advance by less
    /// than a lattice cell) vs the direct scalar 3×3 scan.
    use_cell_cache: bool,
    // --- row state ------------------------------------------------------
    py: usize,
    y0: f64,
    row_cy: i64,
    cells: Vec<Cell>,
    cells_cy: i64,
    cells_gx0: i64,
    cells_nx: usize,
    cells_valid: bool,
}

impl<'a> TileRenderer<'a> {
    /// Prepare a renderer for `tex` at pyramid `level`, whose full image
    /// is `w_px × h_px` pixels.
    pub fn new(tex: &Texture<'a>, level: usize, w_px: usize, h_px: usize) -> TileRenderer<'a> {
        let scale = (1u64 << level) as f64;
        TileRenderer {
            params: tex.params,
            seed: tex.seed,
            noise_seed: tex.seed ^ 0xA5A5_0000 ^ level as u64,
            nuc_seed: tex.seed ^ 0x5EED_0001,
            w_px,
            h_px,
            w_f: w_px as f64,
            h_f: h_px as f64,
            scale,
            blur2: (scale * 0.5) * (scale * 0.5),
            attenuation: 1.0 / (1.0 + 0.30 * (scale - 1.0)),
            tissue: FieldRows::new(tex.tissue),
            tumor: FieldRows::new(tex.tumor),
            distractor: FieldRows::new(tex.distractor),
            cols: Vec::new(),
            use_cell_cache: true,
            py: 0,
            y0: 0.0,
            row_cy: 0,
            cells: Vec::new(),
            cells_cy: i64::MIN,
            cells_gx0: 0,
            cells_nx: 0,
            cells_valid: false,
        }
    }

    /// Define the span: columns `px0 + k·stride` for `k < n_cols`. All
    /// per-column work (u, x0, cell index, `du²` tables) happens here.
    pub fn set_span(&mut self, px0: usize, n_cols: usize, stride: usize) {
        let stride = stride.max(1);
        self.cols.clear();
        self.cols.reserve(n_cols);
        let mut us = Vec::with_capacity(n_cols);
        for k in 0..n_cols {
            let px = px0 + k * stride;
            let u = (px as f64 + 0.5) / self.w_f;
            let x0 = (px as f64 + 0.5) * self.scale;
            self.cols.push(ColPre {
                px,
                x0,
                cx: (x0 / NUCLEI_CELL_L0).floor() as i64,
            });
            us.push(u);
        }
        self.tissue.set_cols(&us);
        self.tumor.set_cols(&us);
        self.distractor.set_cols(&us);
        // A cache of 3 lattice rows only pays off when consecutive columns
        // land in the same or adjacent cells.
        self.use_cell_cache = (stride as f64) * self.scale < NUCLEI_CELL_L0;
        self.cells_valid = false;
    }

    /// Enter row `py`: per-row field terms, active-blob compaction, and
    /// (when caching) the 3-row nuclei neighborhood.
    pub fn begin_row(&mut self, py: usize) {
        let v = (py as f64 + 0.5) / self.h_f;
        self.tissue.set_row(v);
        self.tumor.set_row(v);
        self.distractor.set_row(v);
        self.py = py;
        self.y0 = (py as f64 + 0.5) * self.scale;
        self.row_cy = (self.y0 / NUCLEI_CELL_L0).floor() as i64;
        if !self.use_cell_cache || self.cols.is_empty() {
            return;
        }
        let cy = self.row_cy;
        let gx0 = self.cols[0].cx - 1;
        let gx1 = self.cols[self.cols.len() - 1].cx + 1;
        let nx = (gx1 - gx0 + 1) as usize;
        if self.cells_valid && cy == self.cells_cy && gx0 == self.cells_gx0 && nx == self.cells_nx
        {
            return; // same lattice rows as the previous pixel row
        }
        self.cells.clear();
        self.cells.reserve(3 * nx);
        for gy in cy - 1..=cy + 1 {
            for gx in gx0..=gx1 {
                let h = hash2(self.nuc_seed, gx, gy);
                self.cells.push(Cell {
                    uh: unit(h),
                    h,
                    gx,
                    gy,
                    nx: 0.0,
                    ny: 0.0,
                    u3: 0.0,
                    filled: false,
                });
            }
        }
        self.cells_cy = cy;
        self.cells_gx0 = gx0;
        self.cells_nx = nx;
        self.cells_valid = true;
    }

    /// Nucleus darkening at span column `c` — mirrors
    /// `Texture::nuclei_darkening` exactly (same 3×3 neighborhood walked
    /// in the same dy-outer/dx-inner order).
    fn darkening(&mut self, c: usize, s_tissue: f64, s_tumor: f64, s_distr: f64) -> f64 {
        if s_tissue < 0.02 {
            return 0.0;
        }
        let p = self.params;
        let dense = (s_tumor + s_distr).min(1.0);
        let p_nucleus = p.p_nucleus_normal * (1.0 - dense) + p.p_nucleus_tumor * dense;
        let strength = (p.dark_normal * (1.0 - s_tumor - 0.45 * s_distr)
            + p.dark_tumor * (s_tumor + 0.45 * s_distr))
            * self.attenuation;
        let x0 = self.cols[c].x0;
        let cx = self.cols[c].cx;
        let y0 = self.y0;
        let blur2 = self.blur2;
        let mut dark: f64 = 0.0;
        if self.cells_valid {
            let cells_nx = self.cells_nx;
            let col0 = (cx - 1 - self.cells_gx0) as usize;
            for row in 0..3 {
                let base = row * cells_nx + col0;
                for e in &mut self.cells[base..base + 3] {
                    if e.uh >= p_nucleus {
                        continue;
                    }
                    if !e.filled {
                        e.fill();
                    }
                    let r = 2.2 + 1.8 * (0.35 * e.u3 + 0.65 * s_tumor);
                    let r2 = r * r;
                    let r_eff2 = r2 + blur2;
                    let d2 = (x0 - e.nx) * (x0 - e.nx) + (y0 - e.ny) * (y0 - e.ny);
                    let amp = strength * r2 / r_eff2;
                    dark += amp * (-d2 / (2.0 * r_eff2)).exp();
                }
            }
        } else {
            // Strided access: the scalar 3×3 scan, verbatim.
            let cell = NUCLEI_CELL_L0;
            let cy = self.row_cy;
            for dy in -1..=1i64 {
                for dx in -1..=1i64 {
                    let gx = cx + dx;
                    let gy = cy + dy;
                    let h = hash2(self.nuc_seed, gx, gy);
                    if unit(h) >= p_nucleus {
                        continue;
                    }
                    let jx = unit(hash2(h, 1, 0));
                    let jy = unit(hash2(h, 2, 0));
                    let nx = (gx as f64 + jx) * cell;
                    let ny = (gy as f64 + jy) * cell;
                    let r = 2.2 + 1.8 * (0.35 * unit(hash2(h, 3, 0)) + 0.65 * s_tumor);
                    let r2 = r * r;
                    let r_eff2 = r2 + blur2;
                    let d2 = (x0 - nx) * (x0 - nx) + (y0 - ny) * (y0 - ny);
                    let amp = strength * r2 / r_eff2;
                    dark += amp * (-d2 / (2.0 * r_eff2)).exp();
                }
            }
        }
        (dark * s_tissue).min(0.95)
    }

    /// RGB of span column `c` on the current row. Bit-identical to
    /// `Texture::pixel(level, cols[c].px, py, w_px, h_px)`.
    #[inline]
    pub fn pixel(&mut self, c: usize) -> [f32; 3] {
        let s_tissue = self.tissue.soft_at(c);
        let s_tumor = self.tumor.soft_at(c) * s_tissue;
        let s_distr = self.distractor.soft_at(c) * s_tissue * (1.0 - s_tumor);

        let p = self.params;
        let mut rgb = [0.0f64; 3];
        for ch in 0..3 {
            let tissue_c = p.tissue[ch] * (1.0 - s_tumor) + p.tumor[ch] * s_tumor;
            rgb[ch] = p.bg[ch] * (1.0 - s_tissue) + tissue_c * s_tissue;
        }

        let dark = self.darkening(c, s_tissue, s_tumor, s_distr);
        for ch in 0..3 {
            rgb[ch] *= 1.0 - dark * p.nucleus_tint[ch];
        }

        let nh = hash2(self.noise_seed, self.cols[c].px as i64, self.py as i64);
        for (ch, v) in rgb.iter_mut().enumerate() {
            let n = unit(hash2(nh, ch as i64, 0)) - 0.5;
            *v = (*v + n * 2.0 * p.noise_amp).clamp(0.0, 1.0);
        }

        [rgb[0] as f32, rgb[1] as f32, rgb[2] as f32]
    }

    /// Render the `w×h` pixel rectangle at `(px0, py0)` into HWC f32 RGB
    /// (the tile extraction hot path).
    pub fn render_rect(&mut self, px0: usize, py0: usize, w: usize, h: usize) -> Vec<f32> {
        self.set_span(px0, w, 1);
        let mut out = vec![0.0f32; w * h * 3];
        let mut o = 0;
        for py in py0..py0 + h {
            self.begin_row(py);
            for c in 0..w {
                let rgb = self.pixel(c);
                out[o..o + 3].copy_from_slice(&rgb);
                o += 3;
            }
        }
        out
    }

    /// Mean luma of tile `(tx, ty)` sampled with `stride`, clamped to the
    /// image bounds — bit-identical to the (fixed) scalar
    /// `Texture::tile_mean_luma`. Returns 0.0 for tiles fully outside the
    /// image.
    pub fn tile_mean_luma(&mut self, tx: usize, ty: usize, tile_px: usize, stride: usize) -> f64 {
        let stride = stride.max(1);
        let px_lo = tx * tile_px;
        let py_lo = ty * tile_px;
        let px_hi = ((tx + 1) * tile_px).min(self.w_px);
        let py_hi = ((ty + 1) * tile_px).min(self.h_px);
        if px_lo >= px_hi || py_lo >= py_hi {
            return 0.0;
        }
        let n_cols = (px_hi - px_lo).div_ceil(stride);
        self.set_span(px_lo, n_cols, stride);
        let mut sum = 0.0;
        let mut n = 0usize;
        let mut py = py_lo;
        while py < py_hi {
            self.begin_row(py);
            for c in 0..n_cols {
                let [r, g, b] = self.pixel(c);
                sum += 0.299 * r as f64 + 0.587 * g as f64 + 0.114 * b as f64;
                n += 1;
            }
            py += stride;
        }
        sum / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::field::Blob;
    use crate::synth::slide_gen::{SlideKind, SlideSpec};

    fn fields_of(kind: SlideKind) -> (Field, Field, Field) {
        SlideSpec::new("rtest", 4321, 16, 8, 3, 64, kind).fields()
    }

    /// Bit-exact comparison helper: f32 bits, not approximate equality.
    fn assert_px_eq(a: [f32; 3], b: [f32; 3], ctx: &str) {
        let ab: Vec<u32> = a.iter().map(|v| v.to_bits()).collect();
        let bb: Vec<u32> = b.iter().map(|v| v.to_bits()).collect();
        assert_eq!(ab, bb, "pixel bits differ at {ctx}: {a:?} vs {b:?}");
    }

    #[test]
    fn golden_bit_identity_across_levels_and_kinds() {
        for kind in [
            SlideKind::LargeTumor,
            SlideKind::SmallScattered,
            SlideKind::Negative,
        ] {
            let (tissue, tumor, distractor) = fields_of(kind);
            let params = TextureParams::default();
            let tex = Texture {
                seed: 77,
                tissue: &tissue,
                tumor: &tumor,
                distractor: &distractor,
                params: &params,
            };
            for level in 0..3usize {
                let (w_px, h_px) = (1024 >> level, 512 >> level);
                let mut r = TileRenderer::new(&tex, level, w_px, h_px);
                r.set_span(0, w_px.min(96), 1);
                for py in (0..h_px.min(48)).chain([h_px - 1]) {
                    r.begin_row(py);
                    for c in 0..w_px.min(96) {
                        let got = r.pixel(c);
                        let want = tex.pixel(level, c, py, w_px, h_px);
                        assert_px_eq(got, want, &format!("{kind:?} L{level} ({c},{py})"));
                    }
                }
            }
        }
    }

    #[test]
    fn golden_bit_identity_on_odd_dims_and_strides() {
        // Dimensions that are not tile-aligned and strided spans (the luma
        // pass shape), including the last row/column.
        let (tissue, tumor, distractor) = fields_of(SlideKind::SmallScattered);
        let params = TextureParams::default();
        let tex = Texture {
            seed: 9,
            tissue: &tissue,
            tumor: &tumor,
            distractor: &distractor,
            params: &params,
        };
        let (w_px, h_px) = (1000usize, 514usize);
        for level in [0usize, 2] {
            for stride in [1usize, 4, 7] {
                let mut r = TileRenderer::new(&tex, level, w_px, h_px);
                let n_cols = w_px.div_ceil(stride);
                r.set_span(0, n_cols, stride);
                for py in (0..h_px).step_by(61).chain([h_px - 1]) {
                    r.begin_row(py);
                    for c in (0..n_cols).step_by(3) {
                        let px = c * stride;
                        let got = r.pixel(c);
                        let want = tex.pixel(level, px, py, w_px, h_px);
                        assert_px_eq(got, want, &format!("L{level} s{stride} ({px},{py})"));
                    }
                }
            }
        }
    }

    #[test]
    fn golden_render_rect_matches_scalar_tiles() {
        let (tissue, tumor, distractor) = fields_of(SlideKind::LargeTumor);
        let params = TextureParams::default();
        let tex = Texture {
            seed: 31,
            tissue: &tissue,
            tumor: &tumor,
            distractor: &distractor,
            params: &params,
        };
        let (w_px, h_px) = (256usize, 128usize);
        // Tile sizes that divide and don't divide the image.
        for tp in [32usize, 48] {
            let mut r = TileRenderer::new(&tex, 0, w_px, h_px);
            for (tx, ty) in [(0usize, 0usize), (1, 1), (w_px / tp - 1, h_px / tp - 1)] {
                let got = r.render_rect(tx * tp, ty * tp, tp, tp);
                let mut want = Vec::with_capacity(tp * tp * 3);
                for py in 0..tp {
                    for px in 0..tp {
                        want.extend_from_slice(&tex.pixel(
                            0,
                            tx * tp + px,
                            ty * tp + py,
                            w_px,
                            h_px,
                        ));
                    }
                }
                let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
                let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
                assert_eq!(gb, wb, "tile ({tx},{ty}) tp={tp} differs");
            }
        }
    }

    #[test]
    fn tile_mean_luma_matches_scalar_including_boundary_tiles() {
        let (tissue, tumor, distractor) = fields_of(SlideKind::LargeTumor);
        let params = TextureParams::default();
        let tex = Texture {
            seed: 55,
            tissue: &tissue,
            tumor: &tumor,
            distractor: &distractor,
            params: &params,
        };
        // 100×70 image with 32-px tiles: right/bottom tiles are partial.
        let (w_px, h_px) = (100usize, 70usize);
        let tp = 32usize;
        let mut r = TileRenderer::new(&tex, 0, w_px, h_px);
        for ty in 0..=2 {
            for tx in 0..=3 {
                let got = r.tile_mean_luma(tx, ty, tp, 4);
                let want = tex.tile_mean_luma(0, tx, ty, tp, w_px, h_px, 4);
                assert_eq!(
                    got.to_bits(),
                    want.to_bits(),
                    "tile ({tx},{ty}) luma differs: {got} vs {want}"
                );
            }
        }
        // Fully-out-of-range tile: defined as 0.0 on both paths.
        assert_eq!(r.tile_mean_luma(4, 0, tp, 4), 0.0);
        assert_eq!(tex.tile_mean_luma(0, 4, 0, tp, w_px, h_px, 4), 0.0);
    }

    #[test]
    fn boundary_tile_sampling_stays_in_range() {
        // Regression for the edge-tile bug: boundary tiles must only
        // sample coordinates inside the image. The clamped luma of a
        // partial tile equals the mean over only its in-range pixels.
        let tissue = Field {
            blobs: vec![Blob {
                cx: 0.5,
                cy: 0.5,
                r: 0.3,
                w: 3.0,
            }],
        };
        let empty = Field { blobs: vec![] };
        let params = TextureParams::default();
        let tex = Texture {
            seed: 2,
            tissue: &tissue,
            tumor: &empty,
            distractor: &empty,
            params: &params,
        };
        let (w_px, h_px) = (90usize, 90usize);
        let tp = 64usize;
        // Tile (1,1) covers px 64..90 only. Manual mean over the clamped range:
        let mut sum = 0.0;
        let mut n = 0usize;
        let mut py = 64;
        while py < 90 {
            let mut px = 64;
            while px < 90 {
                let [r, g, b] = tex.pixel(0, px, py, w_px, h_px);
                sum += 0.299 * r as f64 + 0.587 * g as f64 + 0.114 * b as f64;
                n += 1;
                px += 4;
            }
            py += 4;
        }
        let want = sum / n as f64;
        let got = tex.tile_mean_luma(0, 1, 1, tp, w_px, h_px, 4);
        assert_eq!(got.to_bits(), want.to_bits(), "clamped luma mismatch");
        let mut r = TileRenderer::new(&tex, 0, w_px, h_px);
        assert_eq!(r.tile_mean_luma(1, 1, tp, 4).to_bits(), want.to_bits());
    }
}
