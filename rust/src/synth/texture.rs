//! Procedural H&E-like texture, evaluated per pixel at any pyramid level.
//!
//! The texture must (a) give tumor vs normal tissue a *learnable but not
//! trivial* appearance difference — the paper's per-level models sit at
//! 0.90–0.96 accuracy, and the pyramidal trade-off curves only make sense
//! in that regime — and (b) weaken at lower resolution the way real
//! pyramids do, so the level-2 model is the weakest (paper Table 2).
//!
//! Ingredients, all deterministic functions of `(slide_seed, level, pixel)`:
//!
//! * **Regions** — analytic tissue / tumor metaball fields (`field.rs`).
//! * **Nuclei** — Worley-style jittered lattice points in *level-0 pixel
//!   space*; each nucleus darkens nearby pixels with a Gaussian splat.
//!   Tumor tissue has denser, larger, darker nuclei (the real H&E cue).
//!   At level ℓ one pixel covers 2^ℓ level-0 pixels, so splats are
//!   convolved with the pixel footprint: radius → sqrt(r² + (2^ℓ/2)²) with
//!   energy-preserving amplitude scaling. This reproduces the information
//!   loss of box-downsampling without materializing level-0 pixels.
//! * **Noise** — per-pixel hash noise so tiles are not flat.
//!
//! `python/compile/texture.py` mirrors these formulas (vectorized numpy)
//! to synthesize the training corpus; the statistics match, which is all
//! the classifier transfer needs (see DESIGN.md S1/S2 and the integration
//! test `rust/tests/pjrt_integration.rs`).

use super::field::Field;

/// Stable 2-D integer hash (SplitMix64-flavored finalizers). Mirrored in
/// `python/compile/texture.py::hash2`.
#[inline]
pub fn hash2(seed: u64, x: i64, y: i64) -> u64 {
    let mut h = seed ^ 0x517c_c1b7_2722_0a95;
    h = (h ^ (x as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
        .wrapping_mul(0xBF58_476D_1CE4_E5B9);
    h = (h ^ (y as u64).wrapping_mul(0x94D0_49BB_1331_11EB))
        .wrapping_mul(0xD6E8_FEB8_6659_FD93);
    h ^ (h >> 32)
}

/// Map a hash to f64 in [0,1).
#[inline]
pub fn unit(h: u64) -> f64 {
    (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Nuclei lattice cell size, in level-0 pixels.
pub const NUCLEI_CELL_L0: f64 = 10.0;

/// Parameters of the H&E-like compositor. One set is shared by all slides;
/// variation comes from the per-slide fields and seeds.
#[derive(Debug, Clone)]
pub struct TextureParams {
    /// Background (glass) base color.
    pub bg: [f64; 3],
    /// Normal tissue base color (eosin pink).
    pub tissue: [f64; 3],
    /// Tumor-region base color (denser, more hematoxylin).
    pub tumor: [f64; 3],
    /// Nucleus presence probability per lattice cell, normal tissue.
    pub p_nucleus_normal: f64,
    /// Nucleus presence probability per lattice cell, tumor tissue.
    pub p_nucleus_tumor: f64,
    /// Nucleus splat strength (normal / tumor).
    pub dark_normal: f64,
    /// Nucleus splat strength in tumor tissue.
    pub dark_tumor: f64,
    /// Per-channel darkening weights of a nucleus splat.
    pub nucleus_tint: [f64; 3],
    /// Amplitude of per-pixel hash noise.
    pub noise_amp: f64,
}

impl Default for TextureParams {
    fn default() -> Self {
        Self {
            bg: [0.93, 0.92, 0.94],
            tissue: [0.86, 0.67, 0.79],
            tumor: [0.83, 0.63, 0.77],
            p_nucleus_normal: 0.42,
            p_nucleus_tumor: 0.95,
            dark_normal: 0.34,
            dark_tumor: 0.68,
            nucleus_tint: [0.52, 0.62, 0.38],
            noise_amp: 0.02,
        }
    }
}

/// Everything needed to evaluate one slide's texture.
pub struct Texture<'a> {
    /// Per-slide texture seed.
    pub seed: u64,
    /// Tissue-density field.
    pub tissue: &'a Field,
    /// Tumor-density field.
    pub tumor: &'a Field,
    /// Dense benign regions (lymphoid-aggregate stand-ins): same base
    /// color as normal tissue, near-tumor nucleus *density* but
    /// normal-sized nuclei — separable at full resolution, confusable
    /// once blurring washes out nucleus size.
    pub distractor: &'a Field,
    /// Color/noise parameters.
    pub params: &'a TextureParams,
}

impl<'a> Texture<'a> {
    /// RGB at a given pyramid `level` for the pixel at integer coordinates
    /// `(px, py)` in that level's pixel grid, where the full level-ℓ image
    /// is `w_px × h_px` pixels. Returns channels in [0,1].
    pub fn pixel(&self, level: usize, px: usize, py: usize, w_px: usize, h_px: usize) -> [f32; 3] {
        let u = (px as f64 + 0.5) / w_px as f64;
        let v = (py as f64 + 0.5) / h_px as f64;

        let s_tissue = self.tissue.soft(u, v);
        let s_tumor = self.tumor.soft(u, v) * s_tissue;
        let s_distr = self.distractor.soft(u, v) * s_tissue * (1.0 - s_tumor);

        // --- base color: background → tissue → tumor mix --------------
        let p = self.params;
        let mut rgb = [0.0f64; 3];
        for c in 0..3 {
            let tissue_c = p.tissue[c] * (1.0 - s_tumor) + p.tumor[c] * s_tumor;
            rgb[c] = p.bg[c] * (1.0 - s_tissue) + tissue_c * s_tissue;
        }

        // --- nuclei splats (in level-0 pixel space) --------------------
        let scale = (1u64 << level) as f64; // level-ℓ pixel covers `scale` L0 px
        let x0 = (px as f64 + 0.5) * scale;
        let y0 = (py as f64 + 0.5) * scale;
        let dark = self.nuclei_darkening(x0, y0, scale, s_tissue, s_tumor, s_distr);
        for c in 0..3 {
            rgb[c] *= 1.0 - dark * p.nucleus_tint[c];
        }

        // --- pixel noise ------------------------------------------------
        let nh = hash2(self.seed ^ 0xA5A5_0000 ^ level as u64, px as i64, py as i64);
        for (c, v) in rgb.iter_mut().enumerate() {
            let n = unit(hash2(nh, c as i64, 0)) - 0.5;
            *v = (*v + n * 2.0 * p.noise_amp).clamp(0.0, 1.0);
        }

        [rgb[0] as f32, rgb[1] as f32, rgb[2] as f32]
    }

    /// Total nucleus darkening at a level-0 position `(x0, y0)`, where the
    /// querying pixel has a footprint of `scale` level-0 pixels.
    fn nuclei_darkening(
        &self,
        x0: f64,
        y0: f64,
        scale: f64,
        s_tissue: f64,
        s_tumor: f64,
        s_distr: f64,
    ) -> f64 {
        if s_tissue < 0.02 {
            return 0.0;
        }
        let p = self.params;
        let cell = NUCLEI_CELL_L0;
        let cx = (x0 / cell).floor() as i64;
        let cy = (y0 / cell).floor() as i64;
        // Effective splat of a nucleus with radius r, blurred by the pixel
        // footprint (σ_px ≈ scale/2): r_eff² = r² + (scale/2)², amplitude
        // scaled by r²/r_eff² to conserve splat energy.
        let blur2 = (scale * 0.5) * (scale * 0.5);
        // Downsampling destroys the high-frequency morphology real CNNs
        // key on; attenuate nuclei contrast with the pixel footprint so
        // lower-resolution models face a genuinely harder problem
        // (paper Table 2: the level-2 model is the weakest).
        let attenuation = 1.0 / (1.0 + 0.30 * (scale - 1.0));
        // Distractors share the tumor's nucleus *density* (that is what
        // fools a blurred view) but keep normal nucleus size/strength.
        let dense = (s_tumor + s_distr).min(1.0);
        let p_nucleus =
            p.p_nucleus_normal * (1.0 - dense) + p.p_nucleus_tumor * dense;
        let strength = (p.dark_normal * (1.0 - s_tumor - 0.45 * s_distr)
            + p.dark_tumor * (s_tumor + 0.45 * s_distr))
            * attenuation;

        let mut dark: f64 = 0.0;
        for dy in -1..=1i64 {
            for dx in -1..=1i64 {
                let gx = cx + dx;
                let gy = cy + dy;
                let h = hash2(self.seed ^ 0x5EED_0001, gx, gy);
                if unit(h) >= p_nucleus {
                    continue;
                }
                // Jittered nucleus center inside the cell.
                let jx = unit(hash2(h, 1, 0));
                let jy = unit(hash2(h, 2, 0));
                let nx = (gx as f64 + jx) * cell;
                let ny = (gy as f64 + jy) * cell;
                // Radius 2.2..4.0 L0 px, tumor nuclei at the large end.
                let r = 2.2 + 1.8 * (0.35 * unit(hash2(h, 3, 0)) + 0.65 * s_tumor);
                let r2 = r * r;
                let r_eff2 = r2 + blur2;
                let d2 = (x0 - nx) * (x0 - nx) + (y0 - ny) * (y0 - ny);
                let amp = strength * r2 / r_eff2;
                dark += amp * (-d2 / (2.0 * r_eff2)).exp();
            }
        }
        (dark * s_tissue).min(0.95)
    }

    /// Mean grayscale of a tile, cheap proxy used by tests and by the Otsu
    /// histogram builder (luma = 0.299R+0.587G+0.114B).
    pub fn tile_mean_luma(
        &self,
        level: usize,
        tx: usize,
        ty: usize,
        tile_px: usize,
        w_px: usize,
        h_px: usize,
        stride: usize,
    ) -> f64 {
        let stride = stride.max(1);
        // Clamp to the image: right/bottom boundary tiles cover fewer than
        // tile_px pixels, and sampling past `w_px`/`h_px` would feed the
        // fields out-of-range UV coordinates.
        let px_hi = ((tx + 1) * tile_px).min(w_px);
        let py_hi = ((ty + 1) * tile_px).min(h_px);
        let mut sum = 0.0;
        let mut n = 0usize;
        let mut py = ty * tile_px;
        while py < py_hi {
            let mut px = tx * tile_px;
            while px < px_hi {
                let [r, g, b] = self.pixel(level, px, py, w_px, h_px);
                sum += 0.299 * r as f64 + 0.587 * g as f64 + 0.114 * b as f64;
                n += 1;
                px += stride;
            }
            py += stride;
        }
        if n == 0 {
            return 0.0; // tile entirely outside the image
        }
        sum / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::field::Blob;

    fn fixture() -> (Field, Field, Field, TextureParams) {
        let tissue = Field {
            blobs: vec![Blob {
                cx: 0.5,
                cy: 0.5,
                r: 0.28,
                w: 3.0,
            }],
        };
        let tumor = Field {
            blobs: vec![Blob {
                cx: 0.42,
                cy: 0.42,
                r: 0.08,
                w: 2.0,
            }],
        };
        let distractor = Field {
            blobs: vec![Blob {
                cx: 0.62,
                cy: 0.42,
                r: 0.05,
                w: 2.0,
            }],
        };
        (tissue, tumor, distractor, TextureParams::default())
    }

    #[test]
    fn hash_is_stable_and_spread() {
        assert_eq!(hash2(1, 2, 3), hash2(1, 2, 3));
        assert_ne!(hash2(1, 2, 3), hash2(1, 3, 2));
        assert_ne!(hash2(1, 2, 3), hash2(2, 2, 3));
        // unit() in [0,1)
        for i in 0..1000 {
            let u = unit(hash2(7, i, -i));
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn pixels_deterministic_and_in_range() {
        let (tissue, tumor, distractor, params) = fixture();
        let t = Texture {
            seed: 11,
            tissue: &tissue,
            tumor: &tumor,
            distractor: &distractor,
            params: &params,
        };
        let a = t.pixel(0, 100, 120, 1024, 1024);
        let b = t.pixel(0, 100, 120, 1024, 1024);
        assert_eq!(a, b);
        for c in a {
            assert!((0.0..=1.0).contains(&c));
        }
    }

    #[test]
    fn background_is_brighter_than_tissue_and_tumor_darker() {
        let (tissue, tumor, distractor, params) = fixture();
        let t = Texture {
            seed: 3,
            tissue: &tissue,
            tumor: &tumor,
            distractor: &distractor,
            params: &params,
        };
        let w = 2048;
        let mean = |cx: f64, cy: f64| {
            // average a small patch to wash out nuclei/noise
            let mut s = 0.0;
            let n = 24;
            for j in 0..n {
                for i in 0..n {
                    let px = (cx * w as f64) as usize + i;
                    let py = (cy * w as f64) as usize + j;
                    let [r, g, b] = t.pixel(0, px, py, w, w);
                    s += (r + g + b) as f64 / 3.0;
                }
            }
            s / (n * n) as f64
        };
        let bg = mean(0.02, 0.02);
        let normal = mean(0.60, 0.60); // inside tissue, outside tumor
        let tum = mean(0.42, 0.42);
        assert!(bg > normal, "bg={bg} normal={normal}");
        assert!(normal > tum, "normal={normal} tumor={tum}");
    }

    #[test]
    fn tumor_contrast_shrinks_at_lower_resolution() {
        // The level-2 model must face a harder problem than level-0
        // (paper Table 2). Proxy: |mean(normal patch) - mean(tumor patch)|
        // measured at level 0 vs level 2.
        let (tissue, tumor, distractor, params) = fixture();
        let t = Texture {
            seed: 8,
            tissue: &tissue,
            tumor: &tumor,
            distractor: &distractor,
            params: &params,
        };
        let contrast = |level: usize| {
            let w = 2048usize >> level;
            let patch = |cx: f64, cy: f64| {
                let mut s = 0.0;
                let n = 16;
                for j in 0..n {
                    for i in 0..n {
                        let px = (cx * w as f64) as usize + i;
                        let py = (cy * w as f64) as usize + j;
                        let [r, g, b] = t.pixel(level, px, py, w, w);
                        s += (r + g + b) as f64 / 3.0;
                    }
                }
                s / (n * n) as f64
            };
            (patch(0.60, 0.60) - patch(0.42, 0.42)).abs()
        };
        let c0 = contrast(0);
        let c2 = contrast(2);
        assert!(c2 < c0, "c0={c0} c2={c2}");
    }

    #[test]
    fn mean_luma_separates_background_from_tissue() {
        let (tissue, tumor, distractor, params) = fixture();
        let t = Texture {
            seed: 5,
            tissue: &tissue,
            tumor: &tumor,
            distractor: &distractor,
            params: &params,
        };
        // 16x16 tiles of 64px at level 0 → 1024px image
        let bg_tile = t.tile_mean_luma(0, 0, 0, 64, 1024, 1024, 4);
        let tis_tile = t.tile_mean_luma(0, 8, 8, 64, 1024, 1024, 4);
        assert!(bg_tile > tis_tile + 0.03, "bg={bg_tile} tissue={tis_tile}");
    }
}
