//! Synthetic gigapixel-slide substrate (substitution S1 in DESIGN.md):
//! analytic tissue/tumor fields, an H&E-like procedural texture, and
//! deterministic slide/dataset specs.

pub mod field;
pub mod slide_gen;
pub mod texture;

pub use field::Field;
pub use slide_gen::{gen_slide_set, DatasetParams, SlideKind, SlideSpec};
pub use texture::{Texture, TextureParams};
