//! Synthetic gigapixel-slide substrate (substitution S1 in DESIGN.md):
//! analytic tissue/tumor fields, an H&E-like procedural texture, and
//! deterministic slide/dataset specs.

/// Gaussian-blob density fields (tumor/distractor layouts).
pub mod field;
/// Flat-array hot-path tile renderer (bit-identical to `Texture::pixel`).
pub mod render;
/// Slide recipes ([`slide_gen::SlideSpec`]) and set generation.
pub mod slide_gen;
/// Deterministic per-tile texture statistics and hashing.
pub mod texture;

pub use field::Field;
pub use render::TileRenderer;
pub use slide_gen::{gen_slide_set, DatasetParams, SlideKind, SlideSpec};
pub use texture::{Texture, TextureParams};
