//! Analytic ground-truth fields for synthetic slides.
//!
//! Tissue and tumor regions are defined as *metaball* fields — sums of
//! Gaussian blobs in normalized slide coordinates `[0,1]²`. Because the
//! fields are analytic they can be evaluated consistently at every pyramid
//! level, which is exactly the property the real multiresolution images
//! have: the tumor mask at level n is the downsampled mask of level n-1.

use crate::util::prng::Pcg32;

/// One Gaussian blob: contributes `w · exp(-d² / (2r²))` at distance d.
#[derive(Debug, Clone, PartialEq)]
pub struct Blob {
    /// Center x in unit slide coordinates.
    pub cx: f64,
    /// Center y in unit slide coordinates.
    pub cy: f64,
    /// Radius in unit coordinates.
    pub r: f64,
    /// Peak weight (density at the center).
    pub w: f64,
}

/// A sum-of-blobs scalar field with an iso-threshold of 1.0.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Field {
    /// The Gaussian blobs summed into the field.
    pub blobs: Vec<Blob>,
}

impl Field {
    /// Field value at normalized coordinates (u, v).
    pub fn value(&self, u: f64, v: f64) -> f64 {
        let mut s = 0.0;
        for b in &self.blobs {
            let du = u - b.cx;
            let dv = v - b.cy;
            let d2 = du * du + dv * dv;
            s += b.w * (-d2 / (2.0 * b.r * b.r)).exp();
        }
        s
    }

    /// Hard membership: inside the iso-surface.
    pub fn inside(&self, u: f64, v: f64) -> bool {
        self.value(u, v) > 1.0
    }

    /// Smooth membership in [0,1] (sigmoid around the iso-surface), used by
    /// the texture compositor so region borders anti-alias.
    pub fn soft(&self, u: f64, v: f64) -> f64 {
        sigmoid((self.value(u, v) - 1.0) * 8.0)
    }

    /// Fraction of a rectangle [u0,u1]×[v0,v1] inside the iso-surface,
    /// estimated on an `n×n` sample grid. This is the per-tile ground
    /// truth (tumor fraction / tissue fraction).
    pub fn coverage(&self, u0: f64, v0: f64, u1: f64, v1: f64, n: usize) -> f64 {
        let n = n.max(1);
        let mut hits = 0usize;
        for j in 0..n {
            let v = v0 + (v1 - v0) * (j as f64 + 0.5) / n as f64;
            for i in 0..n {
                let u = u0 + (u1 - u0) * (i as f64 + 0.5) / n as f64;
                if self.inside(u, v) {
                    hits += 1;
                }
            }
        }
        hits as f64 / (n * n) as f64
    }

    /// Generate `count` blobs with radii in [r_lo, r_hi], weights in
    /// [w_lo, w_hi], centers padded away from the border by `pad`.
    pub fn random(
        rng: &mut Pcg32,
        count: usize,
        r_lo: f64,
        r_hi: f64,
        w_lo: f64,
        w_hi: f64,
        pad: f64,
    ) -> Field {
        let blobs = (0..count)
            .map(|_| Blob {
                cx: rng.f64_range(pad, 1.0 - pad),
                cy: rng.f64_range(pad, 1.0 - pad),
                r: rng.f64_range(r_lo, r_hi),
                w: rng.f64_range(w_lo, w_hi),
            })
            .collect();
        Field { blobs }
    }

    /// Generate blobs clustered *inside* a host field (tumors grow in
    /// tissue): candidate centers are rejection-sampled until the host
    /// field is above threshold there.
    pub fn random_inside(
        rng: &mut Pcg32,
        host: &Field,
        count: usize,
        r_lo: f64,
        r_hi: f64,
        w_lo: f64,
        w_hi: f64,
    ) -> Field {
        let mut blobs = Vec::with_capacity(count);
        let mut attempts = 0;
        while blobs.len() < count && attempts < count * 200 {
            attempts += 1;
            let cx = rng.f64_range(0.02, 0.98);
            let cy = rng.f64_range(0.02, 0.98);
            if host.inside(cx, cy) {
                blobs.push(Blob {
                    cx,
                    cy,
                    r: rng.f64_range(r_lo, r_hi),
                    w: rng.f64_range(w_lo, w_hi),
                });
            }
        }
        Field { blobs }
    }
}

#[inline]
/// Logistic squashing: 1 / (1 + e^-x).
pub fn sigmoid(x: f64) -> f64 {
    1.0 / (1.0 + (-x).exp())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_blob_geometry() {
        let f = Field {
            blobs: vec![Blob {
                cx: 0.5,
                cy: 0.5,
                r: 0.1,
                w: 2.0,
            }],
        };
        assert!(f.inside(0.5, 0.5));
        assert!(!f.inside(0.0, 0.0));
        // iso-contour radius: w·exp(-d²/2r²) = 1 → d = r·sqrt(2 ln w)
        let d_iso = 0.1 * (2.0f64 * 2.0f64.ln()).sqrt();
        assert!(f.inside(0.5 + d_iso - 1e-3, 0.5));
        assert!(!f.inside(0.5 + d_iso + 1e-3, 0.5));
    }

    #[test]
    fn empty_field_is_everywhere_outside() {
        let f = Field::default();
        assert_eq!(f.value(0.3, 0.7), 0.0);
        assert!(!f.inside(0.3, 0.7));
        assert_eq!(f.coverage(0.0, 0.0, 1.0, 1.0, 8), 0.0);
    }

    #[test]
    fn coverage_bounds_and_monotonicity() {
        let mut rng = Pcg32::new(9);
        let f = Field::random(&mut rng, 5, 0.05, 0.2, 1.2, 3.0, 0.1);
        let c = f.coverage(0.0, 0.0, 1.0, 1.0, 16);
        assert!((0.0..=1.0).contains(&c));
        // A blob-centered small box should be fully covered.
        let b = &f.blobs[0];
        let eps = b.r * 0.05;
        let c2 = f.coverage(b.cx - eps, b.cy - eps, b.cx + eps, b.cy + eps, 4);
        assert!(c2 > 0.99, "c2={c2}");
    }

    #[test]
    fn soft_matches_hard_far_from_border() {
        let f = Field {
            blobs: vec![Blob {
                cx: 0.5,
                cy: 0.5,
                r: 0.15,
                w: 4.0,
            }],
        };
        assert!(f.soft(0.5, 0.5) > 0.99);
        assert!(f.soft(0.0, 0.0) < 0.01);
    }

    #[test]
    fn random_inside_lands_in_host() {
        let mut rng = Pcg32::new(4);
        let host = Field::random(&mut rng, 4, 0.15, 0.3, 1.5, 3.0, 0.2);
        let inner = Field::random_inside(&mut rng, &host, 6, 0.01, 0.05, 1.5, 2.5);
        for b in &inner.blobs {
            assert!(host.inside(b.cx, b.cy));
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let f1 = Field::random(&mut Pcg32::new(5), 3, 0.1, 0.2, 1.0, 2.0, 0.1);
        let f2 = Field::random(&mut Pcg32::new(5), 3, 0.1, 0.2, 1.0, 2.0, 0.1);
        assert_eq!(f1, f2);
    }
}
