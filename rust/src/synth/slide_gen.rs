//! Slide specifications and dataset generation.
//!
//! A `SlideSpec` is a few dozen bytes: seed + geometry + tumor-burden kind.
//! Workers rebuild the full slide procedurally from the spec, which is the
//! repo's analogue of the paper's "data is replicated among workers" —
//! shipping a spec replicates the whole image.

use crate::util::json::{Json, JsonError};
use crate::util::prng::Pcg32;

use super::field::Field;

/// Tumor burden archetypes. The paper validates on "one image with large
/// tumors, one with several small ones, and one negative image" (§5.4);
/// datasets here mix the three kinds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlideKind {
    /// No metastasis anywhere.
    Negative,
    /// Several small scattered metastases (hard case for retention).
    SmallScattered,
    /// One to three large contiguous tumors.
    LargeTumor,
}

impl SlideKind {
    /// Stable name for CLI flags and tables.
    pub fn as_str(self) -> &'static str {
        match self {
            SlideKind::Negative => "negative",
            SlideKind::SmallScattered => "small_scattered",
            SlideKind::LargeTumor => "large_tumor",
        }
    }

    /// Inverse of [`SlideKind::as_str`].
    pub fn from_str(s: &str) -> Option<SlideKind> {
        match s {
            "negative" => Some(SlideKind::Negative),
            "small_scattered" => Some(SlideKind::SmallScattered),
            "large_tumor" => Some(SlideKind::LargeTumor),
            _ => None,
        }
    }
}

/// Geometry + identity of one synthetic whole-slide image.
#[derive(Debug, Clone, PartialEq)]
pub struct SlideSpec {
    /// Unique slide id (cache keys, worker-side slide cache).
    pub id: String,
    /// Seed every deterministic layer derives from.
    pub seed: u64,
    /// Tile grid at level 0 (highest resolution). Must be divisible by
    /// `2^(levels-1)`.
    pub tiles_x: usize,
    /// Level-0 grid height in tiles.
    pub tiles_y: usize,
    /// Number of pyramid levels (paper: 3, scale factor 2).
    pub levels: usize,
    /// Tile side in pixels (model input size).
    pub tile_px: usize,
    /// Tumor layout family (large, scattered, negative…).
    pub kind: SlideKind,
}

impl SlideSpec {
    /// Build a spec; `validate` panics early on nonsense sizes.
    pub fn new(
        id: impl Into<String>,
        seed: u64,
        tiles_x: usize,
        tiles_y: usize,
        levels: usize,
        tile_px: usize,
        kind: SlideKind,
    ) -> SlideSpec {
        let s = SlideSpec {
            id: id.into(),
            seed,
            tiles_x,
            tiles_y,
            levels,
            tile_px,
            kind,
        };
        s.validate();
        s
    }

    /// Panic on inconsistent geometry (0 levels, non-divisible grid…).
    pub fn validate(&self) {
        // Check levels before using it: `levels - 1` in the shift would
        // underflow first and mask this assert with an overflow panic.
        assert!(self.levels >= 1, "at least one level");
        let div = 1usize << (self.levels - 1);
        assert!(
            self.tiles_x % div == 0 && self.tiles_y % div == 0,
            "tile grid {}x{} not divisible by 2^(levels-1)={div}",
            self.tiles_x,
            self.tiles_y
        );
        assert!(self.tile_px >= 8);
    }

    /// Build the slide's ground-truth fields from the seed:
    /// (tissue, tumor, distractor). Distractors are dense *benign*
    /// regions (lymphoid aggregates and the like): every slide kind has
    /// them, they look tumor-like at low resolution but are separable at
    /// full resolution — the source of the low-level false positives that
    /// make the paper's accuracy-performance trade-off non-trivial.
    pub fn fields(&self) -> (Field, Field, Field) {
        let mut rng = Pcg32::new(self.seed);
        // Tissue: a handful of large blobs covering roughly half the slide.
        let n_tissue = rng.usize_range(3, 7);
        let tissue = Field::random(&mut rng, n_tissue, 0.14, 0.26, 1.4, 2.8, 0.18);
        let tumor = match self.kind {
            SlideKind::Negative => Field::default(),
            SlideKind::SmallScattered => {
                let n = rng.usize_range(6, 15);
                Field::random_inside(&mut rng, &tissue, n, 0.015, 0.04, 1.4, 2.4)
            }
            SlideKind::LargeTumor => {
                let n = rng.usize_range(2, 5);
                Field::random_inside(&mut rng, &tissue, n, 0.07, 0.15, 1.6, 2.6)
            }
        };
        let n_distr = rng.usize_range(4, 10);
        let distractor = Field::random_inside(&mut rng, &tissue, n_distr, 0.02, 0.06, 1.4, 2.4);
        (tissue, tumor, distractor)
    }

    /// Serialize (slide-set files, cluster wire format).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("id", self.id.as_str())
            .set("seed", self.seed)
            .set("tiles_x", self.tiles_x)
            .set("tiles_y", self.tiles_y)
            .set("levels", self.levels)
            .set("tile_px", self.tile_px)
            .set("kind", self.kind.as_str())
    }

    /// Parse a spec written by [`SlideSpec::to_json`].
    pub fn from_json(v: &Json) -> Result<SlideSpec, JsonError> {
        let kind_s = v.get("kind")?.as_str()?.to_string();
        let kind = SlideKind::from_str(&kind_s).ok_or(JsonError::Type {
            expected: "slide kind",
            got: "string",
        })?;
        Ok(SlideSpec::new(
            v.get("id")?.as_str()?,
            v.get("seed")?.as_u64()?,
            v.get("tiles_x")?.as_usize()?,
            v.get("tiles_y")?.as_usize()?,
            v.get("levels")?.as_usize()?,
            v.get("tile_px")?.as_usize()?,
            kind,
        ))
    }
}

/// Dataset geometry knobs (defaults give a CPU-friendly slide: 48×32
/// level-0 tiles of 64 px → a 3072×2048 px "gigapixel" stand-in with the
/// exact pyramid structure of the paper's 3-level, f=2 setup).
#[derive(Debug, Clone)]
pub struct DatasetParams {
    /// Level-0 grid width in tiles.
    pub tiles_x: usize,
    /// Level-0 grid height in tiles.
    pub tiles_y: usize,
    /// Pyramid depth.
    pub levels: usize,
    /// Tile edge in pixels.
    pub tile_px: usize,
}

impl Default for DatasetParams {
    fn default() -> Self {
        Self {
            tiles_x: 48,
            tiles_y: 32,
            levels: 3,
            tile_px: 64,
        }
    }
}

/// Generate a deterministic slide set. Kinds cycle
/// LargeTumor / SmallScattered / Negative / LargeTumor / … with the ratio
/// ~2:1 positive:negative, echoing Camelyon16's 110/160 (train) and 49/80
/// (test) positive/negative mix; `prefix` keeps train/test ids distinct.
pub fn gen_slide_set(
    prefix: &str,
    count: usize,
    base_seed: u64,
    params: &DatasetParams,
) -> Vec<SlideSpec> {
    let mut rng = Pcg32::new(base_seed);
    (0..count)
        .map(|i| {
            let kind = match i % 3 {
                0 => SlideKind::LargeTumor,
                1 => SlideKind::SmallScattered,
                _ => SlideKind::Negative,
            };
            SlideSpec::new(
                format!("{prefix}_{i:03}"),
                rng.next_u64(),
                params.tiles_x,
                params.tiles_y,
                params.levels,
                params.tile_px,
                kind,
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_json_roundtrip() {
        let s = SlideSpec::new("train_007", 42, 48, 32, 3, 64, SlideKind::SmallScattered);
        let j = s.to_json();
        let back = SlideSpec::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(s, back);
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn invalid_grid_rejected() {
        SlideSpec::new("x", 1, 50, 32, 3, 64, SlideKind::Negative);
    }

    #[test]
    #[should_panic(expected = "at least one level")]
    fn zero_levels_rejected_with_clear_message() {
        // Regression: validate() computed `1 << (levels - 1)` before the
        // levels assert, so levels == 0 died on overflow instead.
        SlideSpec::new("x", 1, 48, 32, 0, 64, SlideKind::Negative);
    }

    #[test]
    fn fields_deterministic_and_kind_sensitive() {
        let mk = |kind| SlideSpec::new("s", 9, 48, 32, 3, 64, kind);
        let (t1, u1, d1) = mk(SlideKind::LargeTumor).fields();
        let (t2, u2, d2) = mk(SlideKind::LargeTumor).fields();
        assert_eq!(t1, t2);
        assert_eq!(u1, u2);
        assert_eq!(d1, d2);
        assert!(!d1.blobs.is_empty(), "every slide has distractors");
        let (_, neg, _) = mk(SlideKind::Negative).fields();
        assert!(neg.blobs.is_empty());
        let (_, small, _) = mk(SlideKind::SmallScattered).fields();
        assert!(!small.blobs.is_empty());
        for b in &small.blobs {
            assert!(b.r <= 0.04 + 1e-12);
        }
    }

    #[test]
    fn slide_set_ids_unique_and_kinds_cycle() {
        let set = gen_slide_set("train", 9, 1, &DatasetParams::default());
        assert_eq!(set.len(), 9);
        let mut ids: Vec<&str> = set.iter().map(|s| s.id.as_str()).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 9);
        assert_eq!(set[0].kind, SlideKind::LargeTumor);
        assert_eq!(set[1].kind, SlideKind::SmallScattered);
        assert_eq!(set[2].kind, SlideKind::Negative);
        // Seeds differ per slide.
        assert_ne!(set[0].seed, set[1].seed);
    }
}
