//! A realized slide: spec + analytic fields + texture, exposing the
//! pyramid geometry, tile pixel extraction and per-tile ground truth.

use crate::synth::field::Field;
use crate::synth::render::TileRenderer;
use crate::synth::slide_gen::SlideSpec;
use crate::synth::texture::{Texture, TextureParams};

use super::tile::{TileId, SCALE_FACTOR};

/// Minimum tumor coverage for a tile to count as a (ground-truth) positive.
pub const MIN_TUMOR_FRAC: f64 = 0.03;
/// Minimum tissue coverage for a tile to count as tissue (non-background).
pub const MIN_TISSUE_FRAC: f64 = 0.05;
/// Ground-truth coverage sampling grid (n×n per tile).
const COVERAGE_SAMPLES: usize = 8;

/// A slide ready for analysis. Building one from a spec is cheap (a few
/// dozen Gaussian blobs); pixels are produced on demand.
pub struct Slide {
    /// The recipe this slide was built from.
    pub spec: SlideSpec,
    tissue: Field,
    tumor: Field,
    distractor: Field,
    params: TextureParams,
}

impl Slide {
    /// Materialize a slide from its recipe (deterministic).
    pub fn from_spec(spec: SlideSpec) -> Slide {
        spec.validate();
        let (tissue, tumor, distractor) = spec.fields();
        Slide {
            spec,
            tissue,
            tumor,
            distractor,
            params: TextureParams::default(),
        }
    }

    /// The slide's unique id.
    pub fn id(&self) -> &str {
        &self.spec.id
    }

    /// Pyramid depth.
    pub fn levels(&self) -> usize {
        self.spec.levels
    }

    /// The lowest-resolution level index (analysis entry point).
    pub fn lowest_level(&self) -> usize {
        self.spec.levels - 1
    }

    /// Tile-grid dimensions at `level`.
    pub fn level_tiles(&self, level: usize) -> (usize, usize) {
        assert!(level < self.spec.levels);
        let f = SCALE_FACTOR.pow(level as u32);
        (self.spec.tiles_x / f, self.spec.tiles_y / f)
    }

    /// Pixel dimensions of the full image at `level`.
    pub fn level_px(&self, level: usize) -> (usize, usize) {
        let (tx, ty) = self.level_tiles(level);
        (tx * self.spec.tile_px, ty * self.spec.tile_px)
    }

    /// Total number of tiles at `level`.
    pub fn tile_count(&self, level: usize) -> usize {
        let (tx, ty) = self.level_tiles(level);
        tx * ty
    }

    /// All tile ids at `level`, row-major.
    pub fn level_tile_ids(&self, level: usize) -> Vec<TileId> {
        let (nx, ny) = self.level_tiles(level);
        let mut out = Vec::with_capacity(nx * ny);
        for ty in 0..ny {
            for tx in 0..nx {
                out.push(TileId::new(level, tx, ty));
            }
        }
        out
    }

    fn texture(&self) -> Texture<'_> {
        Texture {
            seed: self.spec.seed,
            tissue: &self.tissue,
            tumor: &self.tumor,
            distractor: &self.distractor,
            params: &self.params,
        }
    }

    /// Extract a tile as HWC f32 RGB (len = tile_px² · 3), channels in
    /// [0,1]. This is the L2 model's expected input layout.
    ///
    /// Rendered by the flat-array [`TileRenderer`] hot path, which is
    /// bit-identical to evaluating `Texture::pixel` per pixel (golden
    /// tests in `synth/render.rs`).
    pub fn tile_pixels(&self, t: TileId) -> Vec<f32> {
        let level = t.level as usize;
        let (w_px, h_px) = self.level_px(level);
        let tp = self.spec.tile_px;
        let tex = self.texture();
        let mut r = TileRenderer::new(&tex, level, w_px, h_px);
        r.render_rect(t.tx as usize * tp, t.ty as usize * tp, tp, tp)
    }

    /// Normalized-coordinate bounds of a tile.
    fn tile_bounds(&self, t: TileId) -> (f64, f64, f64, f64) {
        let (nx, ny) = self.level_tiles(t.level as usize);
        let u0 = t.tx as f64 / nx as f64;
        let v0 = t.ty as f64 / ny as f64;
        (u0, v0, u0 + 1.0 / nx as f64, v0 + 1.0 / ny as f64)
    }

    /// Ground-truth tumor coverage of a tile, in [0,1].
    pub fn tumor_fraction(&self, t: TileId) -> f64 {
        let (u0, v0, u1, v1) = self.tile_bounds(t);
        self.tumor.coverage(u0, v0, u1, v1, COVERAGE_SAMPLES)
    }

    /// Ground-truth tissue coverage of a tile, in [0,1].
    pub fn tissue_fraction(&self, t: TileId) -> f64 {
        let (u0, v0, u1, v1) = self.tile_bounds(t);
        self.tissue.coverage(u0, v0, u1, v1, COVERAGE_SAMPLES)
    }

    /// Ground-truth distractor (dense benign region) coverage of a tile.
    pub fn distractor_fraction(&self, t: TileId) -> f64 {
        let (u0, v0, u1, v1) = self.tile_bounds(t);
        self.distractor.coverage(u0, v0, u1, v1, COVERAGE_SAMPLES)
    }

    /// Ground-truth positive label (metastasis present in the tile).
    pub fn is_tumor(&self, t: TileId) -> bool {
        self.tumor_fraction(t) >= MIN_TUMOR_FRAC
    }

    /// Ground-truth tissue label (tile is not background).
    pub fn is_tissue(&self, t: TileId) -> bool {
        self.tissue_fraction(t) >= MIN_TISSUE_FRAC
    }

    /// Mean luma of a tile sampled with `stride` (Otsu histogram input).
    /// Bit-identical to the scalar `Texture::tile_mean_luma` reference.
    pub fn tile_mean_luma(&self, t: TileId, stride: usize) -> f64 {
        let level = t.level as usize;
        let (w_px, h_px) = self.level_px(level);
        let tex = self.texture();
        let mut r = TileRenderer::new(&tex, level, w_px, h_px);
        r.tile_mean_luma(t.tx as usize, t.ty as usize, self.spec.tile_px, stride)
    }

    /// Mean lumas of *every* tile at `level`, row-major (the order of
    /// [`level_tile_ids`](Self::level_tile_ids)). One [`TileRenderer`] is
    /// reused across the whole sweep, so the per-slide field/nuclei setup
    /// and the span scratch buffers are paid once per level instead of
    /// once per tile — this is the Otsu histogram builder's input path.
    /// Each element is bit-identical to `tile_mean_luma` on that tile.
    pub fn level_tile_lumas(&self, level: usize, stride: usize) -> Vec<f64> {
        let (ntx, nty) = self.level_tiles(level);
        let (w_px, h_px) = self.level_px(level);
        let tp = self.spec.tile_px;
        let tex = self.texture();
        let mut r = TileRenderer::new(&tex, level, w_px, h_px);
        let mut out = Vec::with_capacity(ntx * nty);
        for ty in 0..nty {
            for tx in 0..ntx {
                out.push(r.tile_mean_luma(tx, ty, tp, stride));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::slide_gen::SlideKind;

    fn slide(kind: SlideKind) -> Slide {
        Slide::from_spec(SlideSpec::new("t", 1234, 16, 8, 3, 64, kind))
    }

    #[test]
    fn geometry() {
        let s = slide(SlideKind::LargeTumor);
        assert_eq!(s.level_tiles(0), (16, 8));
        assert_eq!(s.level_tiles(1), (8, 4));
        assert_eq!(s.level_tiles(2), (4, 2));
        assert_eq!(s.level_px(0), (1024, 512));
        assert_eq!(s.tile_count(2), 8);
        assert_eq!(s.lowest_level(), 2);
        assert_eq!(s.level_tile_ids(2).len(), 8);
    }

    #[test]
    fn tile_pixels_shape_and_range() {
        let s = slide(SlideKind::LargeTumor);
        let px = s.tile_pixels(TileId::new(2, 1, 1));
        assert_eq!(px.len(), 64 * 64 * 3);
        assert!(px.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn tile_pixels_deterministic() {
        let s1 = slide(SlideKind::SmallScattered);
        let s2 = slide(SlideKind::SmallScattered);
        let t = TileId::new(1, 3, 2);
        assert_eq!(s1.tile_pixels(t), s2.tile_pixels(t));
    }

    #[test]
    fn negative_slide_has_no_tumor_tiles() {
        let s = slide(SlideKind::Negative);
        for level in 0..3 {
            for t in s.level_tile_ids(level) {
                assert_eq!(s.tumor_fraction(t), 0.0);
                assert!(!s.is_tumor(t));
            }
        }
    }

    #[test]
    fn tumor_slide_has_tumor_tiles_and_mask_nests_across_levels() {
        let s = slide(SlideKind::LargeTumor);
        let pos0: Vec<TileId> = s
            .level_tile_ids(0)
            .into_iter()
            .filter(|&t| s.is_tumor(t))
            .collect();
        assert!(!pos0.is_empty(), "large-tumor slide should have positives");
        // A positive child implies a parent with positive tumor coverage
        // (analytic fields nest exactly; thresholds are equal per level).
        for t in &pos0 {
            let p = t.parent();
            assert!(
                s.tumor_fraction(p) > 0.0,
                "parent {p} of positive {t} has zero coverage"
            );
        }
    }

    #[test]
    fn tumor_tiles_are_tissue_tiles() {
        let s = slide(SlideKind::LargeTumor);
        for t in s.level_tile_ids(1) {
            if s.is_tumor(t) {
                assert!(s.is_tissue(t), "tumor tile {t} not tissue");
            }
        }
    }

    #[test]
    fn tissue_fraction_sane() {
        let s = slide(SlideKind::LargeTumor);
        let total: f64 = s
            .level_tile_ids(2)
            .iter()
            .map(|&t| s.tissue_fraction(t))
            .sum::<f64>()
            / s.tile_count(2) as f64;
        assert!(
            (0.05..=0.95).contains(&total),
            "slide tissue coverage {total} outside sane band"
        );
    }
}
