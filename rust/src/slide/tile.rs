//! Tile identity and the pyramid parent/child relation.
//!
//! Levels follow the paper's convention: `R_0` is the *highest* resolution,
//! `R_{N-1}` the lowest. With scale factor `f = 2`, one tile at level `n`
//! corresponds to `f² = 4` tiles of the same pixel size at level `n-1`.

/// Pyramid scale factor between adjacent levels (paper: f = 2).
pub const SCALE_FACTOR: usize = 2;

/// Identifies one tile: (level, tile-x, tile-y) within the level grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TileId {
    /// Pyramid level (0 = full resolution).
    pub level: u8,
    /// Column within the level's grid.
    pub tx: u32,
    /// Row within the level's grid.
    pub ty: u32,
}

impl TileId {
    /// Build a tile id (level must fit in a byte).
    pub fn new(level: usize, tx: usize, ty: usize) -> TileId {
        TileId {
            level: level as u8,
            tx: tx as u32,
            ty: ty as u32,
        }
    }

    /// The f² children of this tile at the next higher resolution
    /// (level - 1). Returns an empty vec at level 0.
    pub fn children(&self) -> Vec<TileId> {
        if self.level == 0 {
            return Vec::new();
        }
        let f = SCALE_FACTOR as u32;
        let mut out = Vec::with_capacity((SCALE_FACTOR * SCALE_FACTOR) as usize);
        for dy in 0..f {
            for dx in 0..f {
                out.push(TileId {
                    level: self.level - 1,
                    tx: self.tx * f + dx,
                    ty: self.ty * f + dy,
                });
            }
        }
        out
    }

    /// The parent tile at the next lower resolution (level + 1).
    pub fn parent(&self) -> TileId {
        let f = SCALE_FACTOR as u32;
        TileId {
            level: self.level + 1,
            tx: self.tx / f,
            ty: self.ty / f,
        }
    }

    /// Flat index within a level grid of width `tiles_x`.
    pub fn flat(&self, tiles_x: usize) -> usize {
        self.ty as usize * tiles_x + self.tx as usize
    }
}

impl std::fmt::Display for TileId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "L{}({},{})", self.level, self.tx, self.ty)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;
    use crate::util::quickcheck::forall;

    #[test]
    fn children_of_level0_empty() {
        assert!(TileId::new(0, 3, 4).children().is_empty());
    }

    #[test]
    fn four_children_with_correct_coords() {
        let t = TileId::new(2, 1, 2);
        let c = t.children();
        assert_eq!(c.len(), 4);
        assert_eq!(c[0], TileId::new(1, 2, 4));
        assert_eq!(c[3], TileId::new(1, 3, 5));
        assert!(c.iter().all(|x| x.level == 1));
    }

    #[test]
    fn parent_child_bijection_property() {
        // Every child's parent is the original tile; children are distinct.
        forall(
            42,
            500,
            |r: &mut Pcg32| {
                TileId::new(
                    r.usize_range(1, 6),
                    r.usize_range(0, 1000),
                    r.usize_range(0, 1000),
                )
            },
            |t| {
                let cs = t.children();
                let mut uniq = cs.clone();
                uniq.sort();
                uniq.dedup();
                uniq.len() == cs.len() && cs.iter().all(|c| c.parent() == *t)
            },
        );
    }

    #[test]
    fn flat_index_is_row_major() {
        assert_eq!(TileId::new(0, 3, 2).flat(10), 23);
    }
}
