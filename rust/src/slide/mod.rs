//! Slide pyramid model: tile identity, geometry and on-demand pixel
//! extraction with per-tile ground truth.

/// The synthetic multi-resolution slide.
pub mod pyramid;
/// Tile addressing across pyramid levels.
pub mod tile;

pub use pyramid::Slide;
pub use tile::{TileId, SCALE_FACTOR};
