//! Slide pyramid model: tile identity, geometry and on-demand pixel
//! extraction with per-tile ground truth.

pub mod pyramid;
pub mod tile;

pub use pyramid::Slide;
pub use tile::{TileId, SCALE_FACTOR};
