//! # PyramidAI
//!
//! Reproduction of *"Efficient Pyramidal Analysis of Gigapixel Images on a
//! Decentralized Modest Computer Cluster"* (Reinbigler et al., 2025).
//!
//! The library is the L3 (rust) layer of a three-layer stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): conv-as-matmul,
//!   pooling and the classifier head, lowered at build time.
//! * **L2** — JAX TinyInception tile classifier (`python/compile/model.py`),
//!   AOT-exported to `artifacts/*.hlo.txt`.
//! * **L3** — this crate: the pyramidal analysis coordinator (the sans-IO
//!   [`pyramid::PyramidRun`] state machine over unified
//!   [`pyramid::ExecutionBackend`] substrates), threshold tuning, the
//!   distributed simulator, the TCP work-stealing cluster, the
//!   multi-slide analysis service, the whole-slide classifier and the
//!   experiment harness.
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index,
//! and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod cli;
pub mod cluster;
pub mod experiments;
pub mod harness;
pub mod preprocess;
pub mod sim;
pub mod slide;
pub mod synth;
pub mod util;
pub mod wsi;
pub mod metrics;
pub mod model;
pub mod predcache;
pub mod runtime;
pub mod pyramid;
pub mod sched;
pub mod service;
pub mod tuning;
