//! # PyramidAI
//!
//! Reproduction of *"Efficient Pyramidal Analysis of Gigapixel Images on a
//! Decentralized Modest Computer Cluster"* (Reinbigler et al., 2025).
//!
//! The library is the L3 (rust) layer of a three-layer stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): conv-as-matmul,
//!   pooling and the classifier head, lowered at build time.
//! * **L2** — JAX TinyInception tile classifier (`python/compile/model.py`),
//!   AOT-exported to `artifacts/*.hlo.txt`.
//! * **L3** — this crate: the pyramidal analysis coordinator (the sans-IO
//!   [`pyramid::PyramidRun`] state machine over unified
//!   [`pyramid::ExecutionBackend`] substrates), threshold tuning, the
//!   distributed simulator, the fault-tolerant TCP work-stealing cluster,
//!   the multi-slide analysis service, the whole-slide classifier and the
//!   experiment harness.
//!
//! See `README.md` for the build/quickstart walkthrough, `DESIGN.md` for
//! the system inventory (and the §10 failure-model spec), and
//! `EXPERIMENTS.md` for paper-vs-measured results.

#![warn(missing_docs)]

/// Tiny flag/subcommand parser (no `clap` in the offline vendor set).
pub mod cli;
/// Decentralized TCP cluster: one-shot §5.4 runs and the persistent,
/// fault-tolerant execution backend (heartbeats, chunk resubmission,
/// worker rejoin — DESIGN.md §10).
pub mod cluster;
/// Paper figure/table reproductions and their shared context.
pub mod experiments;
/// Deterministic fault injection (seeded plans over every I/O seam) and
/// the crate-wide retry/backoff policy (DESIGN.md §16).
pub mod fault;
/// Table/CSV rendering shared by experiments and the service.
pub mod harness;
/// Background removal (Otsu) and stain normalization.
pub mod preprocess;
/// Distributed-execution simulator: load balancing (§5.1–5.3), the
/// multi-job workload simulator, and §10 failure injection.
pub mod sim;
/// Synthetic gigapixel slides: pyramids, tiles, ground truth.
pub mod slide;
/// Synthetic slide generation (specs, textures, tumor fields).
pub mod synth;
/// Support code: JSON, PRNG, stats, thread pool, PNG, quickcheck.
pub mod util;
/// Whole-slide classification (§4.6): features, trees, bagging.
pub mod wsi;
/// Retention/speedup metrics against exhaustive reference runs.
pub mod metrics;
/// Tile analyzers: the calibrated oracle, the PJRT model, delay shims.
pub mod model;
/// Observability: structured tracing with per-process JSONL sinks, the
/// leveled stderr logger, the global metrics registry, the Chrome
/// trace-event merger and the `pyramidai bench` harness.
pub mod obs;
/// Columnar per-slide prediction caches for post-mortem replay (§4.3):
/// dense level grids in memory, binary shards + budgeted LRU store on
/// disk.
pub mod predcache;
/// PJRT/XLA runtime bindings for the compiled L2 artifacts.
pub mod runtime;
/// The pyramidal analysis core: [`pyramid::PyramidRun`], execution
/// backends, the classic blocking driver and the execution tree.
pub mod pyramid;
/// The scheduling-policy core shared by service and simulator.
pub mod sched;
/// Multi-slide analysis service: admission, scheduling, pooling.
pub mod service;
/// Zoom-threshold tuning (empirical and metric-based, §4.4–4.5).
pub mod tuning;
