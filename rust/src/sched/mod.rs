//! One scheduling-policy core shared by the multi-slide service, the
//! cluster-backed service mode and the distributed simulator.
//!
//! The paper's §5 claim is that load-balancing conclusions drawn in the
//! simulator transfer to the real cluster. For that to be *structural*
//! rather than coincidental, the simulator and the service must not
//! re-implement scheduling — they must run the same code. This module is
//! that code: a [`SchedulingPolicy`] ranks *frontier requests* (not whole
//! jobs) given a [`SchedContext`] of per-tenant consumption, weights,
//! quotas, deadlines and queue age. The service scheduler
//! ([`crate::service::scheduler`]) consults a policy object for
//! admission, dispatch order and preemption; the workload simulator
//! ([`crate::sim::engine::simulate_workload`]) drives the *same trait
//! objects* over virtual workers. A policy decision reproduced by both is
//! therefore the same branch of the same function, never a re-derivation.
//!
//! Policies act at level-frontier granularity because that is where a
//! [`crate::pyramid::PyramidRun`] has natural suspension points: between
//! frontiers a run holds no in-flight work, so a scheduler can park it
//! under preemption and resume it later with a byte-identical final
//! `ExecTree` (the tree depends only on what was analyzed, never on
//! scheduling order).
//!
//! Four policies are provided:
//!
//! * [`Fifo`] — strict submission order.
//! * [`StrictPriority`] — higher [`priority_rank`] first; preempts lower
//!   ranks when the scheduler allows preemption.
//! * [`WeightedFairShare`] — per-tenant weights over consumed tiles, with
//!   an optional per-tenant running-jobs quota; one heavy tenant cannot
//!   starve the rest.
//! * [`Edf`] — earliest absolute deadline first, with natural preemption
//!   at frontier boundaries.
//!
//! [`priority_rank`]: SchedCandidate::priority_rank

use std::collections::HashMap;

/// Everything a policy may know about one schedulable unit — a queued
/// job waiting for admission, a parked job waiting to resume, or a
/// running job's next frontier request. Clock fields (`arrival`,
/// `deadline`, [`SchedContext::now`]) are plain integers in whatever
/// clock the caller uses — microseconds since service start for the real
/// scheduler, virtual ticks for the simulator — so the same policy code
/// is exact and deterministic in both worlds.
#[derive(Debug, Clone, Copy)]
pub struct SchedCandidate<'a> {
    /// Submission-ordered id; the universal deterministic tiebreak.
    pub job: u64,
    /// Numeric priority (higher = more urgent).
    pub priority_rank: u8,
    /// Fair-share accounting key.
    pub tenant: &'a str,
    /// Arrival stamp in the caller's clock (queue age = now − arrival).
    pub arrival: u64,
    /// Absolute deadline in the caller's clock; `None` = none.
    pub deadline: Option<u64>,
}

impl SchedCandidate<'_> {
    /// Time spent waiting so far.
    pub fn queue_age(&self, now: u64) -> u64 {
        now.saturating_sub(self.arrival)
    }
}

/// Shared accounting the policies rank against.
#[derive(Debug, Clone, Copy)]
pub struct SchedContext<'a> {
    /// Tiles dispatched so far, per tenant (the fair-share currency).
    pub usage: &'a HashMap<String, u64>,
    /// Jobs currently in the running set, per tenant (quota currency).
    pub running_per_tenant: &'a HashMap<String, usize>,
    /// Current time in the caller's clock.
    pub now: u64,
}

impl<'a> SchedContext<'a> {
    /// Tiles consumed by `tenant` so far.
    pub fn tenant_usage(&self, tenant: &str) -> u64 {
        self.usage.get(tenant).copied().unwrap_or(0)
    }

    /// `tenant`'s jobs currently in the running set.
    pub fn tenant_running(&self, tenant: &str) -> usize {
        self.running_per_tenant.get(tenant).copied().unwrap_or(0)
    }
}

/// A scheduling policy over frontier requests. One object serves three
/// decision points:
///
/// * **admission** — [`admit`](SchedulingPolicy::admit) gates a candidate
///   (quotas), [`select`](SchedulingPolicy::select) picks among the
///   admissible (queued *and* parked) candidates;
/// * **dispatch** — `select` orders the pending frontier requests of the
///   running set;
/// * **preemption** — [`preempts`](SchedulingPolicy::preempts) decides
///   whether a waiting candidate should displace a running one at its
///   next frontier boundary.
///
/// Implementations must be deterministic for a fixed candidate set and
/// context: ties always fall back to the lowest `job` id. That is what
/// lets the simulator and the service reproduce each other's decisions
/// exactly.
///
/// # Example
///
/// Policies rank plain candidate snapshots, so they can be exercised
/// without a service or simulator in sight:
///
/// ```
/// use std::collections::HashMap;
/// use pyramidai::sched::{Fifo, SchedCandidate, SchedContext, SchedulingPolicy, StrictPriority};
///
/// let cands = [
///     SchedCandidate { job: 2, priority_rank: 0, tenant: "a", arrival: 5, deadline: None },
///     SchedCandidate { job: 7, priority_rank: 9, tenant: "b", arrival: 9, deadline: None },
/// ];
/// let (usage, running) = (HashMap::new(), HashMap::new());
/// let ctx = SchedContext { usage: &usage, running_per_tenant: &running, now: 10 };
///
/// // FIFO picks the lowest job id; strict priority the highest rank.
/// assert_eq!(Fifo.select(&cands, &ctx), Some(0));
/// assert_eq!(StrictPriority.select(&cands, &ctx), Some(1));
/// // ...and rank 9 would preempt rank 0 at its next frontier boundary.
/// assert!(StrictPriority.preempts(&cands[1], &cands[0], &ctx));
/// ```
pub trait SchedulingPolicy: Send {
    /// Stable name for tables/CSV.
    fn name(&self) -> &str;

    /// Index of the best candidate, or `None` when `cands` is empty.
    fn select(&self, cands: &[SchedCandidate<'_>], ctx: &SchedContext<'_>) -> Option<usize>;

    /// May this candidate enter the running set now? (Quota gate; ranking
    /// is `select`'s job.) Default: always.
    fn admit(&self, cand: &SchedCandidate<'_>, ctx: &SchedContext<'_>) -> bool {
        let _ = (cand, ctx);
        true
    }

    /// Should `incoming` (waiting) displace `running` at its next
    /// frontier boundary? Must be consistent with `select`: whenever this
    /// returns `true`, `select` over `{incoming, running}` must pick
    /// `incoming` — otherwise park/resume would livelock. Default: never.
    fn preempts(
        &self,
        incoming: &SchedCandidate<'_>,
        running: &SchedCandidate<'_>,
        ctx: &SchedContext<'_>,
    ) -> bool {
        let _ = (incoming, running, ctx);
        false
    }
}

/// Admission pick — the quota-gate-then-rank protocol: candidates the
/// policy refuses to [`admit`](SchedulingPolicy::admit) (tenant quotas)
/// are removed, then [`select`](SchedulingPolicy::select) ranks the
/// rest. Returns an index into `cands`.
///
/// This free function (and [`pick_preemption_victim`]) *is* the
/// consultation protocol: the service scheduler and the workload
/// simulator both call it rather than re-implementing the gate/rank
/// sequence, so their decisions cannot drift.
pub fn pick_admission(
    policy: &dyn SchedulingPolicy,
    cands: &[SchedCandidate<'_>],
    ctx: &SchedContext<'_>,
) -> Option<usize> {
    let admissible: Vec<usize> = (0..cands.len())
        .filter(|&i| policy.admit(&cands[i], ctx))
        .collect();
    let sub: Vec<SchedCandidate<'_>> = admissible.iter().map(|&i| cands[i]).collect();
    Some(admissible[policy.select(&sub, ctx)?])
}

/// Preemption pick: the best admissible `waiting` candidate (same
/// gate/rank as [`pick_admission`]) is the prospective preemptor; among
/// the `running` candidates it [`preempts`](SchedulingPolicy::preempts),
/// the policy-*worst* one (found by dropping the policy's picks one by
/// one) is the victim. Returns an index into `running`, or `None` when
/// nothing waits or nothing must yield. Callers pass only healthy
/// running jobs and only waiting candidates that could actually be
/// admitted (e.g. not lapsed-deadline queue entries, which expire at
/// admission instead of running).
pub fn pick_preemption_victim(
    policy: &dyn SchedulingPolicy,
    waiting: &[SchedCandidate<'_>],
    running: &[SchedCandidate<'_>],
    ctx: &SchedContext<'_>,
) -> Option<usize> {
    pick_preemption_victims(policy, waiting, running, ctx, 1)
        .into_iter()
        .next()
        .map(|(_, victim)| victim)
}

/// Multi-victim generalization of [`pick_preemption_victim`]: repeatedly
/// pair the best admissible waiter with the policy-worst running
/// candidate it preempts, removing both from contention, until `max`
/// pairs are formed or no further preemption is justified. Returns
/// `(waiting_index, running_index)` pairs — decision order, so schedulers
/// can park several victims in one pass instead of serializing one park
/// per frontier boundary. The single-victim helper is the `max = 1`
/// special case, so existing callers keep byte-identical decisions.
pub fn pick_preemption_victims(
    policy: &dyn SchedulingPolicy,
    waiting: &[SchedCandidate<'_>],
    running: &[SchedCandidate<'_>],
    ctx: &SchedContext<'_>,
    max: usize,
) -> Vec<(usize, usize)> {
    let mut pairs = Vec::new();
    let mut waiters: Vec<usize> = (0..waiting.len()).collect();
    let mut runners: Vec<usize> = (0..running.len()).collect();
    while pairs.len() < max && !waiters.is_empty() && !runners.is_empty() {
        let wsub: Vec<SchedCandidate<'_>> = waiters.iter().map(|&i| waiting[i]).collect();
        let Some(wbest) = pick_admission(policy, &wsub, ctx) else {
            break;
        };
        let incoming = wsub[wbest];
        let mut preemptible: Vec<usize> = runners
            .iter()
            .copied()
            .filter(|&i| policy.preempts(&incoming, &running[i], ctx))
            .collect();
        if preemptible.is_empty() {
            break;
        }
        while preemptible.len() > 1 {
            let cands: Vec<SchedCandidate<'_>> =
                preemptible.iter().map(|&i| running[i]).collect();
            let best = policy.select(&cands, ctx).expect("nonempty candidate set");
            preemptible.remove(best);
        }
        let victim = preemptible[0];
        pairs.push((waiters[wbest], victim));
        waiters.remove(wbest);
        runners.retain(|&i| i != victim);
    }
    pairs
}

/// Starvation aging for parked jobs: the effective priority rank of a
/// candidate that has waited `waited` clock units grows by one rank per
/// `interval` (saturating at `u8::MAX`). `interval == 0` disables aging.
/// Both the service scheduler and the simulator feed parked candidates
/// through this before consulting the policy, so a low-priority job
/// parked under sustained high-priority load eventually outranks fresh
/// arrivals and resumes — the same arithmetic in both worlds keeps the
/// parity tests exact.
pub fn aged_rank(base: u8, waited: u64, interval: u64) -> u8 {
    if interval == 0 {
        return base;
    }
    let boost = (waited / interval).min(u8::MAX as u64) as u8;
    base.saturating_add(boost)
}

/// Select helper: minimize a key, break ties by lowest job id.
fn min_by_key<K: PartialOrd>(
    cands: &[SchedCandidate<'_>],
    mut key: impl FnMut(&SchedCandidate<'_>) -> K,
) -> Option<usize> {
    let mut best: Option<(usize, K, u64)> = None;
    for (i, c) in cands.iter().enumerate() {
        let k = key(c);
        let better = match &best {
            None => true,
            Some((_, bk, bid)) => match k.partial_cmp(bk) {
                Some(std::cmp::Ordering::Less) => true,
                Some(std::cmp::Ordering::Equal) => c.job < *bid,
                _ => false,
            },
        };
        if better {
            best = Some((i, k, c.job));
        }
    }
    best.map(|(i, _, _)| i)
}

/// Strict submission order: lowest job id first. Never preempts.
#[derive(Debug, Clone, Copy, Default)]
pub struct Fifo;

impl SchedulingPolicy for Fifo {
    fn name(&self) -> &str {
        "fifo"
    }

    fn select(&self, cands: &[SchedCandidate<'_>], _ctx: &SchedContext<'_>) -> Option<usize> {
        min_by_key(cands, |c| c.job)
    }
}

/// Higher priority rank first; submission order breaks ties. With
/// preemption enabled in the scheduler, a waiting candidate displaces any
/// strictly lower-ranked running job at its next frontier boundary.
#[derive(Debug, Clone, Copy, Default)]
pub struct StrictPriority;

impl SchedulingPolicy for StrictPriority {
    fn name(&self) -> &str {
        "priority"
    }

    fn select(&self, cands: &[SchedCandidate<'_>], _ctx: &SchedContext<'_>) -> Option<usize> {
        min_by_key(cands, |c| std::cmp::Reverse(c.priority_rank))
    }

    fn preempts(
        &self,
        incoming: &SchedCandidate<'_>,
        running: &SchedCandidate<'_>,
        _ctx: &SchedContext<'_>,
    ) -> bool {
        incoming.priority_rank > running.priority_rank
    }
}

/// Weighted fair share over consumed tiles: the candidate whose tenant
/// has the lowest `usage / weight` goes first, so a tenant with weight 3
/// is entitled to 3× the tiles of a weight-1 tenant before yielding.
/// An optional per-tenant quota caps how many of one tenant's jobs may
/// occupy the running set at once. Never preempts — fairness is enforced
/// continuously at request granularity, which converges without parking.
#[derive(Debug, Clone)]
pub struct WeightedFairShare {
    weights: HashMap<String, f64>,
    default_weight: f64,
    /// Max running jobs per tenant (`None` = unlimited).
    quota: Option<usize>,
}

impl Default for WeightedFairShare {
    fn default() -> Self {
        WeightedFairShare::new(HashMap::new(), 1.0, None)
    }
}

impl WeightedFairShare {
    /// `default_weight` applies to tenants absent from `weights`; weights
    /// are clamped to a small positive floor so no tenant divides by
    /// zero. `quota` of `Some(0)` is treated as `Some(1)` — a tenant that
    /// may never run would deadlock a drain.
    pub fn new(
        weights: HashMap<String, f64>,
        default_weight: f64,
        quota: Option<usize>,
    ) -> WeightedFairShare {
        const FLOOR: f64 = 1e-6;
        WeightedFairShare {
            weights: weights
                .into_iter()
                .map(|(t, w)| (t, w.max(FLOOR)))
                .collect(),
            default_weight: default_weight.max(FLOOR),
            quota: quota.map(|q| q.max(1)),
        }
    }

    /// The tenant's fair-share weight (default for unknowns).
    pub fn weight(&self, tenant: &str) -> f64 {
        self.weights
            .get(tenant)
            .copied()
            .unwrap_or(self.default_weight)
    }
}

impl SchedulingPolicy for WeightedFairShare {
    fn name(&self) -> &str {
        "wfs"
    }

    fn select(&self, cands: &[SchedCandidate<'_>], ctx: &SchedContext<'_>) -> Option<usize> {
        min_by_key(cands, |c| ctx.tenant_usage(c.tenant) as f64 / self.weight(c.tenant))
    }

    fn admit(&self, cand: &SchedCandidate<'_>, ctx: &SchedContext<'_>) -> bool {
        match self.quota {
            None => true,
            Some(q) => ctx.tenant_running(cand.tenant) < q,
        }
    }
}

/// Earliest (absolute) deadline first; deadline-free candidates rank
/// after every deadlined one, in submission order. With preemption
/// enabled, a waiting candidate with a strictly earlier deadline parks a
/// running job at its next frontier boundary — the natural EDF
/// preemption point in a pyramidal run.
#[derive(Debug, Clone, Copy, Default)]
pub struct Edf;

impl SchedulingPolicy for Edf {
    fn name(&self) -> &str {
        "edf"
    }

    fn select(&self, cands: &[SchedCandidate<'_>], _ctx: &SchedContext<'_>) -> Option<usize> {
        min_by_key(cands, |c| c.deadline.unwrap_or(u64::MAX))
    }

    fn preempts(
        &self,
        incoming: &SchedCandidate<'_>,
        running: &SchedCandidate<'_>,
        _ctx: &SchedContext<'_>,
    ) -> bool {
        match (incoming.deadline, running.deadline) {
            (Some(i), Some(r)) => i < r,
            (Some(_), None) => true,
            _ => false,
        }
    }
}

/// Which policy family a [`PolicySpec`] builds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    /// Strict submission order.
    Fifo,
    /// Higher priority rank first; preempts lower ranks.
    Priority,
    /// Per-tenant weighted fair share with optional quotas.
    WeightedFairShare,
    /// Earliest absolute deadline first.
    Edf,
}

impl PolicyKind {
    /// Stable name for CLI flags and tables.
    pub fn as_str(self) -> &'static str {
        match self {
            PolicyKind::Fifo => "fifo",
            PolicyKind::Priority => "priority",
            PolicyKind::WeightedFairShare => "wfs",
            PolicyKind::Edf => "edf",
        }
    }
}

/// Declarative, cloneable policy configuration: what the CLI parses and
/// `ServiceConfig` carries; [`PolicySpec::build`] turns it into the trait
/// object both the service scheduler and the simulator drive.
///
/// Syntax accepted by [`PolicySpec::parse`]:
///
/// ```text
/// fifo
/// priority
/// edf
/// wfs                       # every tenant weight 1
/// wfs:tenantA=3,tenantB=1   # per-tenant weights
/// wfs:tenantA=3;quota=2     # ... plus per-tenant running-jobs quota
/// ```
///
/// `fair` / `fair_share` / `fair-share` are accepted as aliases of `wfs`
/// (the PR-1 policy name).
#[derive(Debug, Clone, PartialEq)]
pub struct PolicySpec {
    /// Which policy family to build.
    pub kind: PolicyKind,
    /// Per-tenant weights (WFS only; empty = every tenant weight 1).
    pub weights: Vec<(String, f64)>,
    /// Per-tenant running-jobs quota (WFS only).
    pub quota: Option<usize>,
}

impl PolicySpec {
    /// Strict submission order.
    pub fn fifo() -> PolicySpec {
        PolicySpec {
            kind: PolicyKind::Fifo,
            weights: Vec::new(),
            quota: None,
        }
    }

    /// Higher priority rank first.
    pub fn priority() -> PolicySpec {
        PolicySpec {
            kind: PolicyKind::Priority,
            weights: Vec::new(),
            quota: None,
        }
    }

    /// Earliest deadline first.
    pub fn edf() -> PolicySpec {
        PolicySpec {
            kind: PolicyKind::Edf,
            weights: Vec::new(),
            quota: None,
        }
    }

    /// Weighted fair share with the given per-tenant weights.
    pub fn wfs(weights: impl IntoIterator<Item = (String, f64)>) -> PolicySpec {
        PolicySpec {
            kind: PolicyKind::WeightedFairShare,
            weights: weights.into_iter().collect(),
            quota: None,
        }
    }

    /// Add a per-tenant running-jobs quota (builder style).
    pub fn with_quota(mut self, quota: usize) -> PolicySpec {
        self.quota = Some(quota);
        self
    }

    /// Parse the CLI syntax (see the type docs). `None` on malformed
    /// input.
    pub fn parse(s: &str) -> Option<PolicySpec> {
        let (head, rest) = match s.split_once(':') {
            Some((h, r)) => (h, Some(r)),
            None => (s, None),
        };
        match head {
            "fifo" => rest.is_none().then(PolicySpec::fifo),
            "priority" => rest.is_none().then(PolicySpec::priority),
            "edf" => rest.is_none().then(PolicySpec::edf),
            "wfs" | "fair" | "fair_share" | "fair-share" => {
                let mut spec = PolicySpec::wfs(Vec::new());
                if let Some(rest) = rest {
                    for part in rest.split([',', ';']).filter(|p| !p.is_empty()) {
                        let (k, v) = part.split_once('=')?;
                        let (k, v) = (k.trim(), v.trim());
                        if k == "quota" {
                            spec.quota = Some(v.parse::<usize>().ok().filter(|&q| q > 0)?);
                        } else {
                            let w = v.parse::<f64>().ok().filter(|w| *w > 0.0)?;
                            spec.weights.push((k.to_string(), w));
                        }
                    }
                }
                Some(spec)
            }
            _ => None,
        }
    }

    /// Canonical string form (round-trips through [`PolicySpec::parse`]).
    pub fn as_str(&self) -> String {
        match self.kind {
            PolicyKind::WeightedFairShare if !self.weights.is_empty() || self.quota.is_some() => {
                let mut parts: Vec<String> = self
                    .weights
                    .iter()
                    .map(|(t, w)| format!("{t}={w}"))
                    .collect();
                if let Some(q) = self.quota {
                    parts.push(format!("quota={q}"));
                }
                format!("wfs:{}", parts.join(","))
            }
            kind => kind.as_str().to_string(),
        }
    }

    /// Build the policy object that the service scheduler and the
    /// simulator both drive.
    pub fn build(&self) -> Box<dyn SchedulingPolicy> {
        match self.kind {
            PolicyKind::Fifo => Box::new(Fifo),
            PolicyKind::Priority => Box::new(StrictPriority),
            PolicyKind::Edf => Box::new(Edf),
            PolicyKind::WeightedFairShare => Box::new(WeightedFairShare::new(
                self.weights.iter().cloned().collect(),
                1.0,
                self.quota,
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(job: u64, rank: u8, tenant: &str) -> SchedCandidate<'_> {
        SchedCandidate {
            job,
            priority_rank: rank,
            tenant,
            arrival: 0,
            deadline: None,
        }
    }

    fn ctx<'a>(
        usage: &'a HashMap<String, u64>,
        running: &'a HashMap<String, usize>,
    ) -> SchedContext<'a> {
        SchedContext {
            usage,
            running_per_tenant: running,
            now: 0,
        }
    }

    #[test]
    fn fifo_picks_lowest_id_and_never_preempts() {
        let usage = HashMap::new();
        let running = HashMap::new();
        let c = ctx(&usage, &running);
        let cands = [cand(3, 2, "a"), cand(1, 0, "b"), cand(2, 2, "a")];
        assert_eq!(Fifo.select(&cands, &c), Some(1));
        assert_eq!(Fifo.select(&[], &c), None);
        assert!(!Fifo.preempts(&cands[0], &cands[1], &c));
    }

    #[test]
    fn priority_ranks_then_ties_by_id_and_preempts_lower() {
        let usage = HashMap::new();
        let running = HashMap::new();
        let c = ctx(&usage, &running);
        let cands = [cand(1, 1, "a"), cand(2, 2, "a"), cand(3, 2, "a")];
        assert_eq!(StrictPriority.select(&cands, &c), Some(1));
        assert!(StrictPriority.preempts(&cands[1], &cands[0], &c));
        assert!(!StrictPriority.preempts(&cands[1], &cands[2], &c), "equal rank");
        // Consistency: whenever preempts() is true, select prefers incoming.
        let pair = [cands[0], cands[1]];
        assert_eq!(StrictPriority.select(&pair, &c), Some(1));
    }

    #[test]
    fn wfs_prefers_lowest_weighted_usage() {
        let mut usage = HashMap::new();
        usage.insert("heavy".to_string(), 300u64);
        usage.insert("light".to_string(), 150u64);
        let running = HashMap::new();
        let c = ctx(&usage, &running);
        let wfs = WeightedFairShare::default();
        let cands = [cand(1, 1, "heavy"), cand(2, 1, "light")];
        assert_eq!(wfs.select(&cands, &c), Some(1));
        // Weight 3 entitles "heavy" to 3× the tiles: 300/3 < 150/1.
        let wfs = WeightedFairShare::new(
            [("heavy".to_string(), 3.0)].into_iter().collect(),
            1.0,
            None,
        );
        assert_eq!(wfs.select(&cands, &c), Some(0));
        // Unknown tenants fall back to the default weight; ties → FIFO.
        let empty = HashMap::new();
        let c0 = ctx(&empty, &running);
        assert_eq!(wfs.select(&cands, &c0), Some(0));
    }

    #[test]
    fn wfs_quota_gates_admission() {
        let usage = HashMap::new();
        let mut running = HashMap::new();
        running.insert("a".to_string(), 2usize);
        let c = ctx(&usage, &running);
        let wfs = WeightedFairShare::new(HashMap::new(), 1.0, Some(2));
        assert!(!wfs.admit(&cand(1, 1, "a"), &c), "tenant at quota");
        assert!(wfs.admit(&cand(2, 1, "b"), &c), "fresh tenant admissible");
        // Quota 0 is clamped to 1 so drains cannot deadlock.
        let wfs = WeightedFairShare::new(HashMap::new(), 1.0, Some(0));
        let none = HashMap::new();
        let c = ctx(&usage, &none);
        assert!(wfs.admit(&cand(1, 1, "a"), &c));
    }

    #[test]
    fn edf_ranks_by_deadline_and_preempts_later() {
        let usage = HashMap::new();
        let running = HashMap::new();
        let c = ctx(&usage, &running);
        let mut early = cand(2, 1, "a");
        early.deadline = Some(100);
        let mut late = cand(1, 1, "a");
        late.deadline = Some(900);
        let free = cand(3, 1, "a");
        assert_eq!(Edf.select(&[late, early, free], &c), Some(1));
        // Deadline-free candidates rank last, FIFO among themselves.
        assert_eq!(Edf.select(&[free, cand(4, 1, "a")], &c), Some(0));
        assert!(Edf.preempts(&early, &late, &c));
        assert!(Edf.preempts(&early, &free, &c));
        assert!(!Edf.preempts(&free, &early, &c));
        assert!(!Edf.preempts(&late, &early, &c));
    }

    #[test]
    fn queue_age_saturates() {
        let c = cand(1, 1, "a");
        assert_eq!(c.queue_age(5), 5);
        let mut c = c;
        c.arrival = 10;
        assert_eq!(c.queue_age(5), 0);
    }

    #[test]
    fn policy_spec_parse_and_roundtrip() {
        for s in ["fifo", "priority", "edf", "wfs"] {
            let spec = PolicySpec::parse(s).unwrap();
            assert_eq!(spec.as_str(), s);
            assert_eq!(PolicySpec::parse(&spec.as_str()), Some(spec));
        }
        let spec = PolicySpec::parse("wfs:tenantA=3,tenantB=1").unwrap();
        assert_eq!(spec.kind, PolicyKind::WeightedFairShare);
        assert_eq!(
            spec.weights,
            vec![("tenantA".to_string(), 3.0), ("tenantB".to_string(), 1.0)]
        );
        assert_eq!(PolicySpec::parse(&spec.as_str()), Some(spec));
        let spec = PolicySpec::parse("wfs:a=2;quota=1").unwrap();
        assert_eq!(spec.quota, Some(1));
        assert_eq!(PolicySpec::parse(&spec.as_str()), Some(spec));
        // PR-1 aliases.
        assert_eq!(
            PolicySpec::parse("fair").unwrap().kind,
            PolicyKind::WeightedFairShare
        );
        assert_eq!(
            PolicySpec::parse("fair_share").unwrap().kind,
            PolicyKind::WeightedFairShare
        );
        for bad in ["lifo", "wfs:novalue", "wfs:w=0", "wfs:quota=0", "edf:x=1", ""] {
            assert_eq!(PolicySpec::parse(bad), None, "{bad:?} must not parse");
        }
    }

    #[test]
    fn pick_admission_gates_then_ranks() {
        let usage = HashMap::new();
        let mut running = HashMap::new();
        running.insert("full".to_string(), 1usize);
        let c = ctx(&usage, &running);
        let wfs = WeightedFairShare::new(HashMap::new(), 1.0, Some(1));
        // Candidate 0 would win FIFO-wise but its tenant is at quota.
        let cands = [cand(1, 1, "full"), cand(2, 1, "free")];
        assert_eq!(pick_admission(&wfs, &cands, &c), Some(1));
        // Everyone gated → no pick.
        let cands = [cand(1, 1, "full")];
        assert_eq!(pick_admission(&wfs, &cands, &c), None);
        assert_eq!(pick_admission(&wfs, &[], &c), None);
    }

    #[test]
    fn pick_preemption_victim_names_the_policy_worst() {
        let usage = HashMap::new();
        let running_m = HashMap::new();
        let c = ctx(&usage, &running_m);
        let waiting = [cand(9, 2, "a")];
        // Two outranked running jobs: the *worse* one (lower rank; id
        // tiebreak) must be the victim, not the first preemptible found.
        let running = [cand(1, 1, "a"), cand(2, 0, "a"), cand(3, 2, "a")];
        assert_eq!(
            pick_preemption_victim(&StrictPriority, &waiting, &running, &c),
            Some(1),
            "rank-0 job is the policy-worst victim"
        );
        // Equal ranks everywhere → nothing must yield.
        let peers = [cand(1, 2, "a"), cand(2, 2, "a")];
        assert_eq!(
            pick_preemption_victim(&StrictPriority, &waiting, &peers, &c),
            None
        );
        // No waiting candidates → no preemption.
        assert_eq!(
            pick_preemption_victim(&StrictPriority, &[], &running, &c),
            None
        );
    }

    #[test]
    fn pick_preemption_victims_pairs_waiters_with_worst_runners() {
        let usage = HashMap::new();
        let running_m = HashMap::new();
        let c = ctx(&usage, &running_m);
        // Two high-rank waiters, three running jobs of ranks 0/1/2.
        let waiting = [cand(10, 3, "a"), cand(11, 3, "a")];
        let running = [cand(1, 1, "a"), cand(2, 0, "a"), cand(3, 2, "a")];
        let pairs =
            pick_preemption_victims(&StrictPriority, &waiting, &running, &c, 8);
        // First pair: best waiter (id 10) evicts the rank-0 job; second
        // pair: remaining waiter evicts the rank-1 job. The rank-2 peer
        // is never preemptible (equal rank).
        assert_eq!(pairs, vec![(0, 1), (1, 0)]);
        // max bounds the pair count and the first pair matches the
        // singular helper exactly.
        let one = pick_preemption_victims(&StrictPriority, &waiting, &running, &c, 1);
        assert_eq!(one, vec![(0, 1)]);
        assert_eq!(
            pick_preemption_victim(&StrictPriority, &waiting, &running, &c),
            Some(1)
        );
        // No waiters or no preemptible runners → no pairs.
        assert!(pick_preemption_victims(&StrictPriority, &[], &running, &c, 4).is_empty());
        let peers = [cand(1, 3, "a")];
        assert!(pick_preemption_victims(&StrictPriority, &waiting, &peers, &c, 4).is_empty());
    }

    #[test]
    fn aged_rank_boosts_per_interval_and_saturates() {
        assert_eq!(aged_rank(1, 0, 100), 1);
        assert_eq!(aged_rank(1, 99, 100), 1);
        assert_eq!(aged_rank(1, 100, 100), 2);
        assert_eq!(aged_rank(1, 350, 100), 4);
        assert_eq!(aged_rank(1, u64::MAX, 1), u8::MAX, "saturates");
        assert_eq!(aged_rank(250, 1000, 100), u8::MAX, "saturating add");
        assert_eq!(aged_rank(1, 10_000, 0), 1, "interval 0 disables aging");
    }

    #[test]
    fn built_policies_report_names() {
        assert_eq!(PolicySpec::fifo().build().name(), "fifo");
        assert_eq!(PolicySpec::priority().build().name(), "priority");
        assert_eq!(PolicySpec::edf().build().name(), "edf");
        assert_eq!(PolicySpec::wfs(Vec::new()).build().name(), "wfs");
    }
}
