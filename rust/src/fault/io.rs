//! Fault-carrying I/O wrappers and the atomic-write primitive.
//!
//! [`FaultyStream`] wraps any `Read`/`Write` byte stream (the HTTP
//! connection halves, in-memory test pipes) and consults an
//! [`Injector`] on every call; [`FaultyFile`] wraps a writer with the
//! disk fault classes (torn write, bit flip, `ENOSPC`). [`write_atomic`]
//! is the crash-safe file write — tmp + `fsync` + rename — every
//! persistent artifact in the repo goes through; it is also the seam the
//! disk faults inject at, so a "torn" write tears the *temp* file and
//! the destination is never left half-written (exactly the guarantee
//! `pyramidai fsck` then verifies).

use std::io::{self, Read, Write};
use std::path::Path;
use std::sync::Arc;

use super::Injector;

/// A byte stream that runs every read and write through an injector,
/// scoped by a peer label.
#[derive(Debug)]
pub struct FaultyStream<S> {
    inner: S,
    peer: String,
    inj: Arc<Injector>,
}

impl<S> FaultyStream<S> {
    /// Wrap `inner`; faults whose `peer` scope matches `peer` apply.
    pub fn new(inner: S, peer: impl Into<String>, inj: Arc<Injector>) -> FaultyStream<S> {
        FaultyStream {
            inner,
            peer: peer.into(),
            inj,
        }
    }

    /// The wrapped stream.
    pub fn get_ref(&self) -> &S {
        &self.inner
    }

    /// Unwrap.
    pub fn into_inner(self) -> S {
        self.inner
    }
}

fn sever_err(label: &'static str, peer: &str) -> io::Error {
    io::Error::new(
        io::ErrorKind::ConnectionReset,
        format!("{label}: {peer}"),
    )
}

impl<S: Read> Read for FaultyStream<S> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        let d = self.inj.net_decision(&self.peer, false);
        if let Some(delay) = d.delay {
            std::thread::sleep(delay); // timer: injected network latency
        }
        if let Some(label) = d.sever {
            return Err(sever_err(label, &self.peer));
        }
        self.inner.read(buf)
    }
}

impl<S: Write> Write for FaultyStream<S> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        let d = self.inj.net_decision(&self.peer, true);
        if let Some(delay) = d.delay {
            std::thread::sleep(delay); // timer: injected network latency
        }
        if let Some(label) = d.sever {
            return Err(sever_err(label, &self.peer));
        }
        if d.corrupt && !buf.is_empty() {
            let (at, mask) = self.inj.pick_bit(buf.len());
            let mut garbled = buf.to_vec();
            garbled[at] ^= mask;
            let n = self.inner.write(&garbled)?;
            return if n == buf.len() {
                Err(sever_err("frame corrupted (injected)", &self.peer))
            } else {
                Ok(n)
            };
        }
        self.inner.write(buf)
    }

    fn flush(&mut self) -> io::Result<()> {
        self.inner.flush()
    }
}

/// A writer that injects the disk fault classes. The faults are drawn
/// once at wrap time (one file = one failure story); a file that drew
/// none behaves exactly like the inner writer.
#[derive(Debug)]
pub struct FaultyFile<W: Write> {
    inner: W,
    inj: Arc<Injector>,
    faults: super::DiskWriteFaults,
    written: u64,
    dead: bool,
}

impl<W: Write> FaultyFile<W> {
    /// Wrap `inner` for a write to `path`, drawing this file's faults
    /// from `inj`'s rules.
    pub fn new(inner: W, path: &str, inj: Arc<Injector>) -> FaultyFile<W> {
        let faults = inj.disk_write_faults(path);
        FaultyFile {
            inner,
            inj,
            faults,
            written: 0,
            dead: false,
        }
    }

    /// Unwrap (for the final `sync_all`).
    pub fn into_inner(self) -> W {
        self.inner
    }
}

/// The error an injected full disk produces (`ErrorKind::Other`, message
/// mentions ENOSPC — callers must not match on a real `StorageFull`).
pub fn enospc_error() -> io::Error {
    io::Error::other("ENOSPC (injected): no space left on device")
}

fn torn_error() -> io::Error {
    io::Error::other("torn write (injected): power lost mid-write")
}

impl<W: Write> Write for FaultyFile<W> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        if self.dead {
            return Err(torn_error());
        }
        if let Some(budget) = self.faults.enospc_after {
            if self.written + buf.len() as u64 > budget {
                let room = budget.saturating_sub(self.written) as usize;
                if room > 0 {
                    let n = self.inner.write(&buf[..room])?;
                    self.written += n as u64;
                    if n < room {
                        return Ok(n);
                    }
                }
                self.inj.count_enospc();
                self.dead = true;
                return Err(enospc_error());
            }
        }
        if self.faults.torn {
            // Persist a random prefix, then "lose power": everything
            // after the cut — including any later write call — errors.
            let cut = self.inj.pick_bit(buf.len().max(1)).0;
            if cut > 0 {
                let n = self.inner.write(&buf[..cut])?;
                self.written += n as u64;
                if n < cut {
                    return Ok(n);
                }
            }
            let _ = self.inner.flush();
            self.inj.count_torn();
            self.dead = true;
            return Err(torn_error());
        }
        if self.faults.bitflip && !buf.is_empty() {
            let (at, mask) = self.inj.pick_bit(buf.len());
            let mut garbled = buf.to_vec();
            garbled[at] ^= mask;
            self.inj.count_bitflip();
            // One flip per file is enough to model silent corruption.
            self.faults.bitflip = false;
            let n = self.inner.write(&garbled)?;
            self.written += n as u64;
            return Ok(n);
        }
        let n = self.inner.write(buf)?;
        self.written += n as u64;
        Ok(n)
    }

    fn flush(&mut self) -> io::Result<()> {
        self.inner.flush()
    }
}

/// Crash-safe file write: write `bytes` to a dot-prefixed `*.tmp`
/// sibling, `fsync`, rename over `path`, then `fsync` the directory. A
/// crash (or injected fault) at any point leaves either the old file or
/// the new one — never a truncated hybrid. The temp file is cleaned up
/// on failure; a stale one from a hard crash is swept by `fsck`.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> io::Result<()> {
    let dir = path.parent().filter(|d| !d.as_os_str().is_empty());
    let name = path
        .file_name()
        .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidInput, "write_atomic: no file name"))?
        .to_string_lossy();
    let tmp = match dir {
        Some(d) => d.join(format!(".{name}.tmp")),
        None => std::path::PathBuf::from(format!(".{name}.tmp")),
    };
    let label = path.to_string_lossy();
    let result = (|| {
        let f = std::fs::File::create(&tmp)?;
        let f = match super::active() {
            Some(inj) => {
                let mut ff = FaultyFile::new(f, &label, inj);
                ff.write_all(bytes)?;
                ff.flush()?;
                ff.into_inner()
            }
            None => {
                let mut f = f;
                f.write_all(bytes)?;
                f
            }
        };
        f.sync_all()?;
        drop(f);
        std::fs::rename(&tmp, path)?;
        if let Some(d) = dir {
            // Directory fsync makes the rename itself durable; best
            // effort — not every filesystem supports opening a dir.
            if let Ok(dh) = std::fs::File::open(d) {
                let _ = dh.sync_all();
            }
        }
        Ok(())
    })();
    if result.is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
    result
}

/// Read a whole file, applying any injected read-side bit flip (the
/// on-disk bytes stay intact — this models a flaky controller, not rot).
pub fn read(path: &Path) -> io::Result<Vec<u8>> {
    let mut bytes = std::fs::read(path)?;
    if let Some(inj) = super::active() {
        if !bytes.is_empty() && inj.disk_read_bitflip(&path.to_string_lossy()) {
            let (at, mask) = inj.pick_bit(bytes.len());
            bytes[at] ^= mask;
            inj.count_bitflip();
        }
    }
    Ok(bytes)
}

/// Sleep an injected delay and fail reads during an injected partition,
/// for loops that poll a socket they cannot wrap (the cluster wire goes
/// through [`crate::cluster::proto::Msg`] instead).
pub fn gate_read(inj: &Injector, peer: &str) -> io::Result<()> {
    let d = inj.net_decision(peer, false);
    if let Some(delay) = d.delay {
        std::thread::sleep(delay); // timer: injected network latency
    }
    if let Some(label) = d.sever {
        return Err(sever_err(label, peer));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::super::{FaultKind, FaultPlan, FaultRule};
    use super::*;

    fn injector(plan: FaultPlan) -> Arc<Injector> {
        Arc::new(Injector::new(plan))
    }

    #[test]
    fn clean_stream_passes_bytes_through() {
        let inj = injector(FaultPlan::new(1));
        let mut s = FaultyStream::new(Vec::<u8>::new(), "p:1", inj);
        s.write_all(b"hello").unwrap();
        assert_eq!(s.get_ref(), b"hello");
    }

    #[test]
    fn partitioned_stream_errors_both_ways() {
        let inj = injector(
            FaultPlan::new(2).rule(FaultRule::always(FaultKind::NetPartition)),
        );
        let mut s = FaultyStream::new(std::io::Cursor::new(vec![1, 2, 3]), "p:1", inj);
        let mut buf = [0u8; 3];
        assert_eq!(
            s.read(&mut buf).unwrap_err().kind(),
            io::ErrorKind::ConnectionReset
        );
        assert_eq!(
            s.write(b"x").unwrap_err().kind(),
            io::ErrorKind::ConnectionReset
        );
    }

    #[test]
    fn corrupt_stream_garbles_exactly_one_bit_then_dies() {
        let inj = injector(
            FaultPlan::new(3).rule(FaultRule::always(FaultKind::NetCorrupt)),
        );
        let payload = vec![0u8; 64];
        let mut s = FaultyStream::new(Vec::<u8>::new(), "p:1", inj);
        let err = s.write(&payload).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::ConnectionReset);
        let written = s.into_inner();
        assert_eq!(written.len(), 64);
        let flipped: u32 = written
            .iter()
            .zip(&payload)
            .map(|(a, b)| (a ^ b).count_ones())
            .sum();
        assert_eq!(flipped, 1, "exactly one bit must differ");
    }

    #[test]
    fn torn_write_persists_only_a_prefix() {
        let inj = injector(
            FaultPlan::new(4).rule(FaultRule::always(FaultKind::DiskTornWrite)),
        );
        let mut f = FaultyFile::new(Vec::<u8>::new(), "/x/shard.pysh", inj);
        let payload = vec![0xAB; 4096];
        let err = f.write_all(&payload).unwrap_err();
        assert!(err.to_string().contains("torn"), "{err}");
        let persisted = f.into_inner();
        assert!(persisted.len() < payload.len());
        assert_eq!(&payload[..persisted.len()], &persisted[..]);
    }

    #[test]
    fn enospc_stops_at_the_byte_budget() {
        let inj = injector(
            FaultPlan::new(5)
                .rule(FaultRule::always(FaultKind::DiskEnospc { after_bytes: 100 })),
        );
        let mut f = FaultyFile::new(Vec::<u8>::new(), "/x/big.bin", inj);
        let err = f.write_all(&[0u8; 4096]).unwrap_err();
        assert!(err.to_string().contains("ENOSPC"), "{err}");
        assert_eq!(f.into_inner().len(), 100);
    }

    #[test]
    fn bitflip_corrupts_one_bit_without_erroring() {
        let inj = injector(
            FaultPlan::new(6).rule(FaultRule::always(FaultKind::DiskBitflip)),
        );
        let payload = vec![0x55; 512];
        let mut f = FaultyFile::new(Vec::<u8>::new(), "/x/s.pysh", inj);
        f.write_all(&payload).unwrap();
        let persisted = f.into_inner();
        assert_eq!(persisted.len(), payload.len());
        let flipped: u32 = persisted
            .iter()
            .zip(&payload)
            .map(|(a, b)| (a ^ b).count_ones())
            .sum();
        assert_eq!(flipped, 1);
    }

    #[test]
    fn write_atomic_leaves_no_tmp_on_failure() {
        let _guard = super::super::test_guard();
        let dir = std::env::temp_dir().join(format!(
            "pyramidai_fault_io_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let dest = dir.join("out.bin");

        // Clean path first.
        write_atomic(&dest, b"v1").unwrap();
        assert_eq!(std::fs::read(&dest).unwrap(), b"v1");

        // Torn path: the destination keeps the old content, no *.tmp
        // residue survives.
        super::super::install(
            FaultPlan::new(7).rule(FaultRule::always(FaultKind::DiskTornWrite)),
        );
        let err = write_atomic(&dest, &vec![9u8; 2048]).unwrap_err();
        super::super::clear();
        assert!(err.to_string().contains("torn"), "{err}");
        assert_eq!(std::fs::read(&dest).unwrap(), b"v1", "old content survives");
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().ends_with(".tmp"))
            .collect();
        assert!(leftovers.is_empty(), "tmp residue: {leftovers:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn faulty_read_flips_one_transient_bit() {
        let _guard = super::super::test_guard();
        let dir = std::env::temp_dir().join(format!(
            "pyramidai_fault_read_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("data.bin");
        std::fs::write(&p, vec![0xF0; 256]).unwrap();
        super::super::install(
            FaultPlan::new(8).rule(FaultRule::always(FaultKind::DiskBitflip)),
        );
        let seen = read(&p).unwrap();
        super::super::clear();
        let flipped: u32 = seen.iter().map(|b| (b ^ 0xF0).count_ones()).sum();
        assert_eq!(flipped, 1);
        // On-disk bytes are untouched.
        assert!(std::fs::read(&p).unwrap().iter().all(|&b| b == 0xF0));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
