//! Deterministic fault injection and the one sanctioned retry policy
//! (DESIGN.md §16).
//!
//! The paper's target is a *modest* cluster: commodity NICs that slow
//! down before they die, disks that tear writes under power loss, links
//! that drop or garble frames. This module gives every I/O seam in the
//! repo one switchboard for such gray failures:
//!
//! * a [`FaultPlan`] — a seeded, windowed rule list (`net.delay`,
//!   `net.drop`, `net.corrupt`, `net.partition`, `disk.torn_write`,
//!   `disk.bitflip`, `disk.enospc`) parsed from JSON and driven by the
//!   repo's own [`Pcg32`] so every schedule replays bit-identically;
//! * an [`Injector`] consulted by the cluster wire
//!   ([`crate::cluster::proto::Msg`]), the shard store
//!   ([`crate::predcache`]) and the HTTP front door via the
//!   [`io::FaultyStream`]/[`io::FaultyFile`] wrappers — installed
//!   globally by `--faults plan.json` or handed around explicitly in
//!   tests;
//! * the [`retry`] submodule: exponential backoff with decorrelated
//!   jitter, per-op deadline and attempt budget — the only place in the
//!   crate allowed to sleep inside a retry loop (CI greps for strays).
//!
//! Injected faults never forge success: a dropped frame surfaces as a
//! connection error the existing recovery paths (redeal, rehello,
//! standby takeover) already handle, a corrupted frame is guaranteed to
//! fail framing on the receiver, and a torn shard write is caught by the
//! store's CRC on the next load. Under any plan the surviving execution
//! tree stays byte-identical to the unfailed run — that is the invariant
//! `tests/chaos_cluster.rs` holds the whole stack to.

use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::obs;
use crate::obs::metrics::Counter;
use crate::util::json::Json;
use crate::util::prng::Pcg32;

pub mod io;
pub mod retry;

pub use io::{write_atomic, FaultyFile, FaultyStream};
pub use retry::{poll_until, retry, Backoff, RetryPolicy};

/// One fault class, with its kind-specific parameters.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultKind {
    /// Stall matching net operations by a uniform random duration.
    NetDelay {
        /// Minimum injected latency, microseconds.
        min_us: u64,
        /// Maximum injected latency, microseconds (exclusive).
        max_us: u64,
    },
    /// Lose an outgoing frame: the connection is severed and the caller
    /// sees a connection error (never a silent fake success).
    NetDrop,
    /// Garble an outgoing frame so the receiver's framing rejects it
    /// (one bit of the first body byte is flipped — breaking both the
    /// JSON opening brace and the v2 magic — and the connection dies).
    NetCorrupt,
    /// Two-way cut: every matching read and write errors for the rule's
    /// window, then traffic resumes.
    NetPartition,
    /// A write persists only a random prefix before erroring — the
    /// classic power-loss torn write.
    DiskTornWrite,
    /// One random bit of the payload is flipped (on write: persisted
    /// corrupt; on read: transient corruption of the loaded bytes).
    DiskBitflip,
    /// Writes fail with an `ENOSPC`-style error once the file exceeds a
    /// byte budget.
    DiskEnospc {
        /// Bytes allowed before the device "fills up".
        after_bytes: u64,
    },
}

impl FaultKind {
    /// The wire name used in plan files (`net.delay`, `disk.enospc`, …).
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::NetDelay { .. } => "net.delay",
            FaultKind::NetDrop => "net.drop",
            FaultKind::NetCorrupt => "net.corrupt",
            FaultKind::NetPartition => "net.partition",
            FaultKind::DiskTornWrite => "disk.torn_write",
            FaultKind::DiskBitflip => "disk.bitflip",
            FaultKind::DiskEnospc { .. } => "disk.enospc",
        }
    }
}

/// One scoped, windowed, probabilistic rule of a [`FaultPlan`].
#[derive(Debug, Clone, PartialEq)]
pub struct FaultRule {
    /// What to inject.
    pub kind: FaultKind,
    /// Per-operation trigger probability in `[0, 1]`.
    pub p: f64,
    /// Substring filter on the connection's peer label (`host:port`).
    /// `None` or `"*"` matches every connection.
    pub peer: Option<String>,
    /// Substring filter on the file path for disk rules. `None` or `"*"`
    /// matches every path.
    pub path: Option<String>,
    /// Window start, ms after the injector was installed.
    pub after_ms: u64,
    /// Window length, ms; `None` = open-ended.
    pub dur_ms: Option<u64>,
}

impl FaultRule {
    /// Unconditional rule: `p = 1.0`, no peer/path scope, open window.
    pub fn always(kind: FaultKind) -> FaultRule {
        FaultRule {
            kind,
            p: 1.0,
            peer: None,
            path: None,
            after_ms: 0,
            dur_ms: None,
        }
    }

    fn in_window(&self, elapsed_ms: u64) -> bool {
        elapsed_ms >= self.after_ms
            && self
                .dur_ms
                .map_or(true, |d| elapsed_ms < self.after_ms.saturating_add(d))
    }

    fn matches_peer(&self, peer: &str) -> bool {
        match self.peer.as_deref() {
            None | Some("*") => true,
            Some(scope) => peer.contains(scope),
        }
    }

    fn matches_path(&self, path: &str) -> bool {
        match self.path.as_deref() {
            None | Some("*") => true,
            Some(scope) => path.contains(scope),
        }
    }
}

/// A seeded, deterministic fault schedule: the unit of replay.
///
/// Plan files are JSON:
///
/// ```json
/// {
///   "seed": 7,
///   "rules": [
///     {"kind": "net.delay", "p": 1.0, "peer": "127.0.0.1:9001",
///      "after_ms": 50, "dur_ms": 200, "min_us": 20000, "max_us": 30000},
///     {"kind": "net.partition", "p": 1.0, "after_ms": 100, "dur_ms": 150},
///     {"kind": "disk.torn_write", "p": 0.5, "path": "cache"},
///     {"kind": "disk.enospc", "after_bytes": 4096}
///   ]
/// }
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// PRNG seed all probabilistic draws derive from.
    pub seed: u64,
    /// Rules, evaluated in order; their effects compose.
    pub rules: Vec<FaultRule>,
}

impl FaultPlan {
    /// Empty plan (injects nothing).
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            rules: Vec::new(),
        }
    }

    /// Builder-style rule append.
    pub fn rule(mut self, rule: FaultRule) -> FaultPlan {
        self.rules.push(rule);
        self
    }

    /// Parse a plan from its JSON text.
    pub fn parse(text: &str) -> Result<FaultPlan> {
        let v = Json::parse(text).context("fault plan JSON")?;
        let seed = match v.opt("seed") {
            Some(s) => s.as_u64().context("fault plan seed")?,
            None => 0,
        };
        let mut rules = Vec::new();
        if let Some(rs) = v.opt("rules") {
            for (i, r) in rs.as_arr().context("fault plan rules")?.iter().enumerate() {
                rules.push(
                    parse_rule(r).with_context(|| format!("fault plan rule #{i}"))?,
                );
            }
        }
        Ok(FaultPlan { seed, rules })
    }

    /// Load and parse a plan file (the `--faults plan.json` path).
    pub fn from_file(path: &std::path::Path) -> Result<FaultPlan> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read fault plan {}", path.display()))?;
        FaultPlan::parse(&text)
            .with_context(|| format!("parse fault plan {}", path.display()))
    }
}

fn parse_rule(r: &Json) -> Result<FaultRule> {
    let kind_name = r.get("kind")?.as_str()?;
    let u64_or = |key: &str, dflt: u64| -> Result<u64> {
        match r.opt(key) {
            Some(v) => Ok(v.as_u64()?),
            None => Ok(dflt),
        }
    };
    let kind = match kind_name {
        "net.delay" => {
            let min_us = u64_or("min_us", 1_000)?;
            let max_us = u64_or("max_us", min_us.saturating_mul(5).max(min_us + 1))?;
            if max_us <= min_us {
                return Err(anyhow!("net.delay needs max_us > min_us"));
            }
            FaultKind::NetDelay { min_us, max_us }
        }
        "net.drop" => FaultKind::NetDrop,
        "net.corrupt" => FaultKind::NetCorrupt,
        "net.partition" => FaultKind::NetPartition,
        "disk.torn_write" => FaultKind::DiskTornWrite,
        "disk.bitflip" => FaultKind::DiskBitflip,
        "disk.enospc" => FaultKind::DiskEnospc {
            after_bytes: u64_or("after_bytes", 0)?,
        },
        other => return Err(anyhow!("unknown fault kind {other:?}")),
    };
    let p = match r.opt("p") {
        Some(v) => v.as_f64()?,
        None => 1.0,
    };
    if !(0.0..=1.0).contains(&p) {
        return Err(anyhow!("fault probability {p} outside [0, 1]"));
    }
    let opt_str = |key: &str| -> Result<Option<String>> {
        match r.opt(key) {
            Some(v) => Ok(Some(v.as_str()?.to_string())),
            None => Ok(None),
        }
    };
    Ok(FaultRule {
        kind,
        p,
        peer: opt_str("peer")?,
        path: opt_str("path")?,
        after_ms: u64_or("after_ms", 0)?,
        dur_ms: match r.opt("dur_ms") {
            Some(v) => Some(v.as_u64()?),
            None => None,
        },
    })
}

/// What the injector decided for one network operation. Effects compose:
/// a delayed *and* partitioned write sleeps, then errors.
#[derive(Debug, Default)]
pub struct NetDecision {
    /// Sleep this long before touching the socket.
    pub delay: Option<Duration>,
    /// Sever the connection with this error label instead of performing
    /// the operation.
    pub sever: Option<&'static str>,
    /// Flip a framing bit in the outgoing frame (writes only).
    pub corrupt: bool,
}

/// Verdict for one disk write, decided when the [`FaultyFile`] wraps the
/// destination.
#[derive(Debug, Default, Clone)]
pub struct DiskWriteFaults {
    /// Tear the write: persist a random prefix of the first write call,
    /// then error.
    pub torn: bool,
    /// Flip one random payload bit before it reaches the device.
    pub bitflip: bool,
    /// Fail with `ENOSPC` once this many bytes are written.
    pub enospc_after: Option<u64>,
}

impl DiskWriteFaults {
    /// True when no fault was drawn for this file.
    pub fn is_clean(&self) -> bool {
        !self.torn && !self.bitflip && self.enospc_after.is_none()
    }
}

struct FaultCounters {
    net_delays: Arc<Counter>,
    net_drops: Arc<Counter>,
    net_corrupts: Arc<Counter>,
    net_partition_hits: Arc<Counter>,
    disk_torn_writes: Arc<Counter>,
    disk_bitflips: Arc<Counter>,
    disk_enospc: Arc<Counter>,
}

impl FaultCounters {
    fn new() -> FaultCounters {
        let m = obs::global_metrics();
        FaultCounters {
            net_delays: m.counter("fault.net_delays"),
            net_drops: m.counter("fault.net_drops"),
            net_corrupts: m.counter("fault.net_corrupts"),
            net_partition_hits: m.counter("fault.net_partition_hits"),
            disk_torn_writes: m.counter("fault.disk_torn_writes"),
            disk_bitflips: m.counter("fault.disk_bitflips"),
            disk_enospc: m.counter("fault.disk_enospc"),
        }
    }
}

/// A live fault plan: rules + the seeded PRNG + the install-time clock
/// that anchors every rule window.
pub struct Injector {
    plan: FaultPlan,
    rng: Mutex<Pcg32>,
    t0: Instant,
    m: FaultCounters,
}

impl std::fmt::Debug for Injector {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Injector")
            .field("plan", &self.plan)
            .finish_non_exhaustive()
    }
}

impl Injector {
    /// Arm a plan. The window clock starts now.
    pub fn new(plan: FaultPlan) -> Injector {
        let rng = Mutex::new(Pcg32::new(plan.seed ^ 0xFA_017));
        Injector {
            plan,
            rng,
            t0: Instant::now(),
            m: FaultCounters::new(),
        }
    }

    /// Milliseconds since the injector was armed (rule windows are
    /// relative to this clock).
    pub fn elapsed_ms(&self) -> u64 {
        self.t0.elapsed().as_millis() as u64
    }

    fn roll(&self, p: f64) -> bool {
        if p >= 1.0 {
            return true;
        }
        if p <= 0.0 {
            return false;
        }
        self.rng.lock().unwrap().bool(p)
    }

    fn rand_range(&self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi > lo);
        lo + self.rng.lock().unwrap().next_u64() % (hi - lo)
    }

    /// Decide the fate of one network operation against `peer`.
    /// `write` selects direction: drops and corruptions only hit writes,
    /// partitions and delays hit both.
    pub fn net_decision(&self, peer: &str, write: bool) -> NetDecision {
        let elapsed = self.elapsed_ms();
        let mut d = NetDecision::default();
        for rule in &self.plan.rules {
            if !rule.in_window(elapsed) || !rule.matches_peer(peer) {
                continue;
            }
            match rule.kind {
                FaultKind::NetDelay { min_us, max_us } => {
                    if self.roll(rule.p) {
                        let us = self.rand_range(min_us, max_us);
                        let add = Duration::from_micros(us);
                        d.delay = Some(d.delay.map_or(add, |prev| prev + add));
                        self.m.net_delays.inc();
                    }
                }
                FaultKind::NetDrop if write => {
                    if d.sever.is_none() && self.roll(rule.p) {
                        d.sever = Some("frame dropped (injected)");
                        self.m.net_drops.inc();
                    }
                }
                FaultKind::NetPartition => {
                    if d.sever.is_none() && self.roll(rule.p) {
                        d.sever = Some("network partition (injected)");
                        self.m.net_partition_hits.inc();
                    }
                }
                FaultKind::NetCorrupt if write => {
                    if !d.corrupt && self.roll(rule.p) {
                        d.corrupt = true;
                        self.m.net_corrupts.inc();
                    }
                }
                _ => {}
            }
        }
        d
    }

    /// Decide the faults for one disk write to `path`.
    pub fn disk_write_faults(&self, path: &str) -> DiskWriteFaults {
        let elapsed = self.elapsed_ms();
        let mut f = DiskWriteFaults::default();
        for rule in &self.plan.rules {
            if !rule.in_window(elapsed) || !rule.matches_path(path) {
                continue;
            }
            match rule.kind {
                FaultKind::DiskTornWrite => f.torn = f.torn || self.roll(rule.p),
                FaultKind::DiskBitflip => f.bitflip = f.bitflip || self.roll(rule.p),
                FaultKind::DiskEnospc { after_bytes } => {
                    if f.enospc_after.is_none() && self.roll(rule.p) {
                        f.enospc_after = Some(after_bytes);
                    }
                }
                _ => {}
            }
        }
        f
    }

    /// Whether a read of `path` should see one bit flipped (transient —
    /// the on-disk bytes stay intact).
    pub fn disk_read_bitflip(&self, path: &str) -> bool {
        let elapsed = self.elapsed_ms();
        self.plan.rules.iter().any(|rule| {
            matches!(rule.kind, FaultKind::DiskBitflip)
                && rule.in_window(elapsed)
                && rule.matches_path(path)
                && self.roll(rule.p)
        })
    }

    /// Pick a random bit offset within `len` bytes.
    pub(crate) fn pick_bit(&self, len: usize) -> (usize, u8) {
        if len == 0 {
            return (0, 1);
        }
        let r = self.rng.lock().unwrap().next_u64();
        ((r as usize / 8) % len, 1u8 << (r % 8) as u8)
    }

    pub(crate) fn count_torn(&self) {
        self.m.disk_torn_writes.inc();
    }

    pub(crate) fn count_bitflip(&self) {
        self.m.disk_bitflips.inc();
    }

    pub(crate) fn count_enospc(&self) {
        self.m.disk_enospc.inc();
    }

    /// Gate + perform one framed write on the cluster wire: sleep any
    /// injected delay, sever on drop/partition (shutting the socket so
    /// the peer sees the break too), flip a framing bit on corruption.
    /// `prefix` is the 4-byte length header, `body` the frame body.
    pub fn net_send(
        &self,
        stream: &mut TcpStream,
        prefix: &[u8],
        body: &[u8],
    ) -> Result<()> {
        use std::io::Write;
        let peer = peer_label(stream);
        let d = self.net_decision(&peer, true);
        if let Some(delay) = d.delay {
            std::thread::sleep(delay); // timer: injected network latency
        }
        if let Some(label) = d.sever {
            let _ = stream.shutdown(std::net::Shutdown::Both);
            return Err(anyhow!("{label}: send to {peer}"));
        }
        stream.write_all(prefix)?;
        if d.corrupt && !body.is_empty() {
            // Flipping a bit of the first body byte breaks both valid
            // framings (`{` for JSON, the v2 magic), so the receiver is
            // guaranteed to reject the frame rather than silently accept
            // garbled payload.
            let (_, mask) = self.pick_bit(1);
            let mut corrupted = body.to_vec();
            corrupted[0] ^= mask;
            stream.write_all(&corrupted)?;
            stream.flush()?;
            let _ = stream.shutdown(std::net::Shutdown::Both);
            return Err(anyhow!("frame corrupted (injected): send to {peer}"));
        }
        stream.write_all(body)?;
        stream.flush()?;
        Ok(())
    }

    /// Gate one framed read on the cluster wire: sleep any injected
    /// delay, sever on partition.
    pub fn net_recv_gate(&self, stream: &TcpStream) -> Result<()> {
        let peer = peer_label(stream);
        let d = self.net_decision(&peer, false);
        if let Some(delay) = d.delay {
            std::thread::sleep(delay); // timer: injected network latency
        }
        if let Some(label) = d.sever {
            let _ = stream.shutdown(std::net::Shutdown::Both);
            return Err(anyhow!("{label}: recv from {peer}"));
        }
        Ok(())
    }
}

/// The peer label faults are scoped by: the remote `host:port`, or `"?"`
/// when the socket is already dead.
pub fn peer_label(stream: &TcpStream) -> String {
    stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| "?".to_string())
}

// --- global installation (the `--faults` path) --------------------------

static ENABLED: AtomicBool = AtomicBool::new(false);
static GLOBAL: Mutex<Option<Arc<Injector>>> = Mutex::new(None);

/// Arm `plan` process-wide: every seam that consults [`active`] starts
/// injecting. Returns the injector for direct use (tests, assertions).
pub fn install(plan: FaultPlan) -> Arc<Injector> {
    let inj = Arc::new(Injector::new(plan));
    *GLOBAL.lock().unwrap() = Some(Arc::clone(&inj));
    ENABLED.store(true, Ordering::Release);
    inj
}

/// Disarm the process-wide injector.
pub fn clear() {
    ENABLED.store(false, Ordering::Release);
    *GLOBAL.lock().unwrap() = None;
}

/// The process-wide injector, if one is armed. The disarmed fast path is
/// a single atomic load — the production seams pay nothing when faults
/// are off.
pub fn active() -> Option<Arc<Injector>> {
    if !ENABLED.load(Ordering::Acquire) {
        return None;
    }
    GLOBAL.lock().unwrap().clone()
}

#[cfg(test)]
pub(crate) fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    // Tests that arm the process-wide injector serialize here so
    // parallel test threads never see each other's plans.
    static GUARD: Mutex<()> = Mutex::new(());
    GUARD.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_parses_every_kind() {
        let plan = FaultPlan::parse(
            r#"{"seed": 9, "rules": [
                {"kind": "net.delay", "min_us": 100, "max_us": 200, "peer": "1.2.3.4"},
                {"kind": "net.drop", "p": 0.5},
                {"kind": "net.corrupt", "after_ms": 10, "dur_ms": 20},
                {"kind": "net.partition"},
                {"kind": "disk.torn_write", "path": "cache"},
                {"kind": "disk.bitflip"},
                {"kind": "disk.enospc", "after_bytes": 4096}
            ]}"#,
        )
        .unwrap();
        assert_eq!(plan.seed, 9);
        assert_eq!(plan.rules.len(), 7);
        assert_eq!(
            plan.rules[0].kind,
            FaultKind::NetDelay {
                min_us: 100,
                max_us: 200
            }
        );
        assert_eq!(plan.rules[0].peer.as_deref(), Some("1.2.3.4"));
        assert_eq!(plan.rules[1].p, 0.5);
        assert_eq!(plan.rules[2].after_ms, 10);
        assert_eq!(plan.rules[2].dur_ms, Some(20));
        assert_eq!(plan.rules[4].path.as_deref(), Some("cache"));
        assert_eq!(
            plan.rules[6].kind,
            FaultKind::DiskEnospc { after_bytes: 4096 }
        );
    }

    #[test]
    fn plan_rejects_garbage() {
        assert!(FaultPlan::parse("{").is_err());
        assert!(FaultPlan::parse(r#"{"rules": [{"kind": "net.meow"}]}"#).is_err());
        assert!(
            FaultPlan::parse(r#"{"rules": [{"kind": "net.drop", "p": 1.5}]}"#).is_err()
        );
        assert!(FaultPlan::parse(
            r#"{"rules": [{"kind": "net.delay", "min_us": 5, "max_us": 5}]}"#
        )
        .is_err());
    }

    #[test]
    fn windows_scope_rules() {
        let rule = FaultRule {
            after_ms: 100,
            dur_ms: Some(50),
            ..FaultRule::always(FaultKind::NetPartition)
        };
        assert!(!rule.in_window(99));
        assert!(rule.in_window(100));
        assert!(rule.in_window(149));
        assert!(!rule.in_window(150));
        let open = FaultRule::always(FaultKind::NetPartition);
        assert!(open.in_window(0));
        assert!(open.in_window(u64::MAX));
    }

    #[test]
    fn peer_scoping_is_substring() {
        let inj = Injector::new(FaultPlan::new(1).rule(FaultRule {
            peer: Some("127.0.0.1:9000".to_string()),
            ..FaultRule::always(FaultKind::NetPartition)
        }));
        assert!(inj.net_decision("127.0.0.1:9000", false).sever.is_some());
        assert!(inj.net_decision("127.0.0.1:9001", false).sever.is_none());
        let any = Injector::new(
            FaultPlan::new(1).rule(FaultRule::always(FaultKind::NetPartition)),
        );
        assert!(any.net_decision("10.0.0.7:1234", true).sever.is_some());
    }

    #[test]
    fn drop_and_corrupt_only_hit_writes() {
        let inj = Injector::new(
            FaultPlan::new(2)
                .rule(FaultRule::always(FaultKind::NetDrop))
                .rule(FaultRule::always(FaultKind::NetCorrupt)),
        );
        let w = inj.net_decision("a:1", true);
        assert!(w.sever.is_some());
        let r = inj.net_decision("a:1", false);
        assert!(r.sever.is_none() && !r.corrupt);
    }

    #[test]
    fn probability_draws_are_seed_deterministic() {
        let draws = |seed: u64| -> Vec<bool> {
            let inj = Injector::new(FaultPlan::new(seed).rule(FaultRule {
                p: 0.5,
                ..FaultRule::always(FaultKind::NetDrop)
            }));
            (0..64)
                .map(|_| inj.net_decision("x:1", true).sever.is_some())
                .collect()
        };
        assert_eq!(draws(42), draws(42));
        assert_ne!(draws(42), draws(43));
    }

    #[test]
    fn disk_faults_compose_and_scope_by_path() {
        let inj = Injector::new(
            FaultPlan::new(3)
                .rule(FaultRule {
                    path: Some("cache".to_string()),
                    ..FaultRule::always(FaultKind::DiskTornWrite)
                })
                .rule(FaultRule::always(FaultKind::DiskEnospc { after_bytes: 10 })),
        );
        let f = inj.disk_write_faults("/tmp/cache/shard_0.pysh");
        assert!(f.torn);
        assert_eq!(f.enospc_after, Some(10));
        let g = inj.disk_write_faults("/tmp/other.bin");
        assert!(!g.torn);
        assert_eq!(g.enospc_after, Some(10));
        assert!(Injector::new(FaultPlan::new(3))
            .disk_write_faults("/x")
            .is_clean());
    }

    #[test]
    fn global_install_round_trips() {
        let _guard = test_guard();
        assert!(active().is_none());
        let inj = install(FaultPlan::new(5));
        let seen = active().expect("armed");
        assert!(Arc::ptr_eq(&inj, &seen));
        clear();
        assert!(active().is_none());
    }
}
