//! The crate's one retry/backoff policy: exponential growth with
//! decorrelated jitter, a per-operation deadline and an attempt budget.
//!
//! Before this module the repo had ~10 hand-rolled `loop { try; sleep }`
//! constructs, each with its own fixed delay — the classic retry-storm
//! recipe when a whole cluster hits the same failure at once. Every
//! retry loop now goes through [`Backoff`]/[`retry`] (CI greps for
//! strays), which:
//!
//! * grows sleeps exponentially from `base` toward `cap` with
//!   *decorrelated jitter* (`sleep = clamp(base + rand·(3·prev − base),
//!   cap)`, after Brooker's "Exponential Backoff And Jitter") so
//!   contending retriers spread out instead of thundering in phase;
//! * stops at a wall-clock `deadline` *and* an attempt budget,
//!   whichever comes first — no retry loop can hang a shutdown;
//! * records every attempt in the obs registry (`retry.attempts`,
//!   `retry.exhausted` counters, `retry.backoff_us` histogram) so a run
//!   that survived on retries is visible in `/v1/metrics`.
//!
//! Plain wait-for-condition polls (not error retries) use
//! [`poll_until`], which bounds the wait and keeps the sleep here too.

use std::time::{Duration, Instant};

use crate::obs;
use crate::util::prng::Pcg32;

/// Bounds for one class of retried operation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// First / minimum sleep.
    pub base: Duration,
    /// Largest single sleep the jitter may reach.
    pub cap: Duration,
    /// Total wall-clock budget measured from the first failure; once
    /// exceeded the caller gets the last error back.
    pub deadline: Duration,
    /// Attempt budget (sleeps, not tries: `max_attempts = 0` means fail
    /// immediately on the first error).
    pub max_attempts: u32,
}

impl RetryPolicy {
    /// Policy for connecting to a peer that may still be binding its
    /// listener: fast first probes, capped growth, caller-chosen
    /// patience.
    pub fn connect(patience: Duration) -> RetryPolicy {
        RetryPolicy {
            base: Duration::from_micros(200),
            cap: Duration::from_millis(50),
            deadline: patience,
            max_attempts: u32::MAX,
        }
    }

    /// Policy for re-sending over a link that is expected to heal
    /// (replication stream, worker uploads): patient, coarser sleeps.
    pub fn link(patience: Duration) -> RetryPolicy {
        RetryPolicy {
            base: Duration::from_millis(2),
            cap: Duration::from_millis(250),
            deadline: patience,
            max_attempts: u32::MAX,
        }
    }

    /// Replace the attempt budget.
    pub fn attempts(mut self, max_attempts: u32) -> RetryPolicy {
        self.max_attempts = max_attempts;
        self
    }
}

/// Stateful backoff: one per retry loop. Construction is free; metrics
/// are only touched when a sleep actually happens.
#[derive(Debug)]
pub struct Backoff {
    op: &'static str,
    policy: RetryPolicy,
    deadline: Instant,
    prev_us: u64,
    attempts: u32,
    rng: Pcg32,
}

impl Backoff {
    /// Start a backoff for operation `op` (a static label used for the
    /// exhaustion event).
    pub fn new(op: &'static str, policy: &RetryPolicy) -> Backoff {
        // Seed from the op label plus a process-wide counter: jitter
        // streams across concurrent retriers must *differ* (that is the
        // whole point of decorrelation), while everything that needs
        // replay determinism draws from explicit seeds elsewhere.
        use std::sync::atomic::{AtomicU64, Ordering};
        static NONCE: AtomicU64 = AtomicU64::new(0);
        let mut seed = 0xcbf2_9ce4_8422_2325u64;
        for b in op.bytes() {
            seed = (seed ^ b as u64).wrapping_mul(0x1000_0000_01b3);
        }
        seed ^= NONCE.fetch_add(1, Ordering::Relaxed).wrapping_mul(0x9E37);
        Backoff {
            op,
            policy: *policy,
            deadline: Instant::now() + policy.deadline,
            prev_us: policy.base.as_micros() as u64,
            attempts: 0,
            rng: Pcg32::new(seed),
        }
    }

    /// Sleeps performed so far.
    pub fn attempts(&self) -> u32 {
        self.attempts
    }

    /// True once the deadline or attempt budget is spent.
    pub fn exhausted(&self) -> bool {
        self.attempts >= self.policy.max_attempts || Instant::now() >= self.deadline
    }

    /// Rewind the budget (an operation succeeded; the next failure
    /// starts a fresh window). Keeps the jitter stream.
    pub fn reset(&mut self) {
        self.deadline = Instant::now() + self.policy.deadline;
        self.prev_us = self.policy.base.as_micros() as u64;
        self.attempts = 0;
    }

    /// Sleep the next jittered interval. Returns `false` — without
    /// sleeping — once the deadline or attempt budget is exhausted, at
    /// which point the caller must give up and surface its last error.
    pub fn sleep(&mut self) -> bool {
        let now = Instant::now();
        if self.attempts >= self.policy.max_attempts || now >= self.deadline {
            obs::global_metrics().counter("retry.exhausted").inc();
            obs::event(
                obs::Level::Debug,
                "fault",
                "retry_exhausted",
                &[("op", self.op.into()), ("attempts", self.attempts.into())],
            );
            return false;
        }
        let base = (self.policy.base.as_micros() as u64).max(1);
        let cap = (self.policy.cap.as_micros() as u64).max(base);
        // Decorrelated jitter: uniform in [base, 3·prev), clamped to cap.
        let hi = self.prev_us.saturating_mul(3).max(base + 1);
        let us = (base + self.rng.next_u64() % (hi - base)).min(cap);
        let left = self.deadline - now;
        let nap = Duration::from_micros(us).min(left);
        std::thread::sleep(nap); // the one sanctioned retry sleep
        self.prev_us = us;
        self.attempts += 1;
        let m = obs::global_metrics();
        m.counter("retry.attempts").inc();
        m.histogram("retry.backoff_us").record(us);
        true
    }
}

/// Run `f` until it succeeds or `policy` is exhausted; the final error
/// is returned unchanged.
pub fn retry<T, E>(
    op: &'static str,
    policy: &RetryPolicy,
    mut f: impl FnMut() -> Result<T, E>,
) -> Result<T, E> {
    let mut backoff = Backoff::new(op, policy);
    loop {
        match f() {
            Ok(v) => return Ok(v),
            Err(e) => {
                if !backoff.sleep() {
                    return Err(e);
                }
            }
        }
    }
}

/// Poll `f` every `step` until it returns true or `timeout` elapses.
/// The sanctioned wait-for-condition loop (a poll is not an error retry,
/// so it gets a fixed step, not backoff).
pub fn poll_until(timeout: Duration, step: Duration, mut f: impl FnMut() -> bool) -> bool {
    let deadline = Instant::now() + timeout;
    loop {
        if f() {
            return true;
        }
        let now = Instant::now();
        if now >= deadline {
            return false;
        }
        std::thread::sleep(step.min(deadline - now)); // timer: bounded poll
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retry_returns_first_success() {
        let mut calls = 0;
        let policy = RetryPolicy {
            base: Duration::from_micros(10),
            cap: Duration::from_micros(50),
            deadline: Duration::from_secs(5),
            max_attempts: 100,
        };
        let out: Result<u32, &str> = retry("test.flaky", &policy, || {
            calls += 1;
            if calls < 4 {
                Err("nope")
            } else {
                Ok(99)
            }
        });
        assert_eq!(out, Ok(99));
        assert_eq!(calls, 4);
    }

    #[test]
    fn retry_respects_attempt_budget() {
        let mut calls = 0;
        let policy = RetryPolicy {
            base: Duration::from_micros(1),
            cap: Duration::from_micros(5),
            deadline: Duration::from_secs(5),
            max_attempts: 3,
        };
        let out: Result<(), &str> = retry("test.doomed", &policy, || {
            calls += 1;
            Err("always")
        });
        assert_eq!(out, Err("always"));
        // max_attempts sleeps separate max_attempts + 1 tries.
        assert_eq!(calls, 4);
    }

    #[test]
    fn retry_respects_deadline() {
        let policy = RetryPolicy {
            base: Duration::from_millis(5),
            cap: Duration::from_millis(10),
            deadline: Duration::from_millis(40),
            max_attempts: u32::MAX,
        };
        let t0 = Instant::now();
        let out: Result<(), &str> = retry("test.slow", &policy, || Err("down"));
        assert_eq!(out, Err("down"));
        let took = t0.elapsed();
        assert!(took >= Duration::from_millis(35), "gave up early: {took:?}");
        assert!(took < Duration::from_secs(2), "overshot: {took:?}");
    }

    #[test]
    fn backoff_grows_toward_cap_with_jitter() {
        let policy = RetryPolicy {
            base: Duration::from_micros(100),
            cap: Duration::from_micros(2_000),
            deadline: Duration::from_secs(10),
            max_attempts: u32::MAX,
        };
        let mut b = Backoff::new("test.growth", &policy);
        let mut prev_seen = Vec::new();
        for _ in 0..12 {
            assert!(b.sleep());
            prev_seen.push(b.prev_us);
        }
        assert!(prev_seen.iter().all(|&us| (100..=2_000).contains(&us)));
        // The late draws must be able to exceed the first (growth), and
        // the stream must not be constant (jitter).
        assert!(prev_seen.windows(2).any(|w| w[1] != w[0]));
    }

    #[test]
    fn backoff_reset_restores_budget() {
        let policy = RetryPolicy {
            base: Duration::from_micros(1),
            cap: Duration::from_micros(2),
            deadline: Duration::from_secs(5),
            max_attempts: 2,
        };
        let mut b = Backoff::new("test.reset", &policy);
        assert!(b.sleep());
        assert!(b.sleep());
        assert!(!b.sleep());
        b.reset();
        assert!(b.sleep());
    }

    #[test]
    fn poll_until_true_and_timeout() {
        let mut n = 0;
        assert!(poll_until(
            Duration::from_secs(2),
            Duration::from_micros(50),
            || {
                n += 1;
                n >= 3
            }
        ));
        assert_eq!(n, 3);
        let t0 = Instant::now();
        assert!(!poll_until(
            Duration::from_millis(20),
            Duration::from_millis(2),
            || false
        ));
        assert!(t0.elapsed() >= Duration::from_millis(18));
    }

    #[test]
    fn retry_metrics_flow_into_the_registry() {
        let before = obs::global_metrics().snapshot().counter("retry.attempts");
        let policy = RetryPolicy {
            base: Duration::from_micros(1),
            cap: Duration::from_micros(2),
            deadline: Duration::from_secs(1),
            max_attempts: 2,
        };
        let _: Result<(), &str> = retry("test.metrics", &policy, || Err("x"));
        let after = obs::global_metrics().snapshot().counter("retry.attempts");
        assert!(after >= before + 2, "attempts {before} -> {after}");
    }
}
