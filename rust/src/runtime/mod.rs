//! PJRT runtime: load `artifacts/*.hlo.txt` (AOT-lowered by
//! `python/compile/aot.py`), compile on the CPU PJRT client, execute from
//! the L3 hot path. Python never runs here.

/// Shared PJRT client.
pub mod client;
/// One compiled per-level executable.
pub mod executable;
/// Artifact discovery and the executable registry.
pub mod registry;

pub use registry::{ArtifactsMeta, Registry};
