//! PJRT runtime: load `artifacts/*.hlo.txt` (AOT-lowered by
//! `python/compile/aot.py`), compile on the CPU PJRT client, execute from
//! the L3 hot path. Python never runs here.

pub mod client;
pub mod executable;
pub mod registry;

pub use registry::{ArtifactsMeta, Registry};
