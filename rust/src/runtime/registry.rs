//! Executable registry: per-(level, batch) compiled classifiers plus the
//! batching policy that maps an arbitrary tile count onto fixed-shape
//! executables (HLO shapes are static).
//!
//! Policy: the registry *calibrates* at load time — it times one warm
//! inference per batch size and records the per-tile cost — then plans an
//! arbitrary tile count as repeated uses of the cheapest batch size plus a
//! cost-minimal tail (padded with zero tiles whose outputs are dropped).
//! On TPU the large batches would win (dispatch amortization); on this
//! CPU, interpret-lowered Pallas grids favor small batches — measuring
//! beats guessing (EXPERIMENTS.md §Perf has the numbers).

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

use super::executable::Executable;

/// Metadata parsed from `artifacts/meta.json`.
#[derive(Debug, Clone)]
pub struct ArtifactsMeta {
    /// Tile edge in pixels.
    pub tile_px: usize,
    /// Pyramid depth the model was trained for.
    pub levels: usize,
    /// Batch sizes compiled per level.
    pub batch_sizes: Vec<usize>,
    /// Per-level (train, val, test) accuracy when the build step trained
    /// fresh weights (Table 2 data).
    pub accuracies: Vec<Option<(f64, f64, f64)>>,
    /// (train, val, test) sample counts per level, if recorded.
    pub dataset_sizes: Vec<Option<(usize, usize, usize)>>,
}

impl ArtifactsMeta {
    /// Load `meta.json` from the artifacts directory.
    pub fn load(dir: &Path) -> Result<ArtifactsMeta> {
        let text = std::fs::read_to_string(dir.join("meta.json"))
            .with_context(|| format!("read {}/meta.json — run `make artifacts`", dir.display()))?;
        let v = Json::parse(&text)?;
        let levels = v.get("levels")?.as_usize()?;
        let mut accuracies = Vec::new();
        let mut dataset_sizes = Vec::new();
        for lm in v.get("levels_meta")?.as_arr()? {
            accuracies.push(match (lm.opt("train_accuracy"), lm.opt("val_accuracy"), lm.opt("test_accuracy")) {
                (Some(a), Some(b), Some(c)) => {
                    Some((a.as_f64()?, b.as_f64()?, c.as_f64()?))
                }
                _ => None,
            });
            dataset_sizes.push(match (lm.opt("train_size"), lm.opt("val_size"), lm.opt("test_size")) {
                (Some(a), Some(b), Some(c)) => {
                    Some((a.as_usize()?, b.as_usize()?, c.as_usize()?))
                }
                _ => None,
            });
        }
        Ok(ArtifactsMeta {
            tile_px: v.get("tile_px")?.as_usize()?,
            levels,
            batch_sizes: v
                .get("batch_sizes")?
                .as_arr()?
                .iter()
                .map(|b| b.as_usize())
                .collect::<Result<_, _>>()?,
            accuracies,
            dataset_sizes,
        })
    }
}

/// All compiled executables, indexed by level then batch (ascending).
pub struct Registry {
    /// The artifacts' metadata.
    pub meta: ArtifactsMeta,
    /// `per_level[level]` sorted by batch size ascending.
    per_level: Vec<Vec<Executable>>,
    /// Calibrated per-tile cost (seconds) per batch size, parallel to the
    /// sorted batch list. Uniform when calibration is disabled.
    per_tile_cost: Vec<f64>,
}

impl Registry {
    /// Load and compile every artifact in `dir`, then calibrate.
    pub fn load_dir(dir: &Path) -> Result<Registry> {
        let meta = ArtifactsMeta::load(dir)?;
        let mut batches = meta.batch_sizes.clone();
        batches.sort_unstable();
        let mut per_level = Vec::with_capacity(meta.levels);
        for level in 0..meta.levels {
            let mut exes = Vec::with_capacity(batches.len());
            for &b in &batches {
                let path = dir.join(Executable::artifact_name(level, b));
                exes.push(Executable::load(&path, level, b, meta.tile_px)?);
            }
            per_level.push(exes);
        }
        let mut reg = Registry {
            meta,
            per_level,
            per_tile_cost: vec![1.0; batches.len()],
        };
        reg.calibrate()?;
        log::info!(
            "registry: {} levels × {:?} batch sizes, per-tile costs {:?}",
            reg.meta.levels,
            batches,
            reg.per_tile_cost
        );
        Ok(reg)
    }

    /// Time one warm inference per batch size (level 0 — all levels share
    /// the architecture) and record per-tile costs for the planner.
    fn calibrate(&mut self) -> Result<()> {
        let tl = self.tile_len();
        for (i, exe) in self.per_level[0].iter().enumerate() {
            let buf = vec![0.5f32; exe.batch * tl];
            exe.run(&buf)?; // warm-up (first run may page in code)
            let t0 = std::time::Instant::now();
            let reps = 3;
            for _ in 0..reps {
                exe.run(&buf)?;
            }
            self.per_tile_cost[i] =
                t0.elapsed().as_secs_f64() / (reps * exe.batch) as f64;
        }
        Ok(())
    }

    /// Pyramid depth of the loaded model.
    pub fn levels(&self) -> usize {
        self.per_level.len()
    }

    /// Tile edge in pixels of the loaded model.
    pub fn tile_px(&self) -> usize {
        self.meta.tile_px
    }

    /// Floats per tile.
    pub fn tile_len(&self) -> usize {
        self.meta.tile_px * self.meta.tile_px * 3
    }

    /// Split `n` tiles into executable chunks: (batch_size, used) pairs,
    /// where `used ≤ batch_size` and Σ used = n. Cost-aware: full chunks
    /// use the calibrated cheapest batch; the tail picks whichever option
    /// (several small runs vs one padded larger run) costs least.
    pub fn plan(&self, level: usize, n: usize) -> Vec<(usize, usize)> {
        let sizes: Vec<usize> = self.per_level[level].iter().map(|e| e.batch).collect();
        plan_with_costs(&sizes, &self.per_tile_cost, n)
    }

    /// Run inference on `tiles.len()` tiles at `level`. `tiles` holds each
    /// tile's NHWC f32 pixels (each of length `tile_len()`).
    pub fn infer(&self, level: usize, tiles: &[&[f32]]) -> Result<Vec<f32>> {
        if level >= self.per_level.len() {
            return Err(anyhow!("level {level} out of range"));
        }
        let tl = self.tile_len();
        let mut out = Vec::with_capacity(tiles.len());
        let mut idx = 0usize;
        let mut buf: Vec<f32> = Vec::new();
        for (batch, used) in self.plan(level, tiles.len()) {
            let exe = self.per_level[level]
                .iter()
                .find(|e| e.batch == batch)
                .expect("planned batch exists");
            buf.clear();
            buf.reserve(batch * tl);
            for t in &tiles[idx..idx + used] {
                if t.len() != tl {
                    return Err(anyhow!("tile has {} floats, want {tl}", t.len()));
                }
                buf.extend_from_slice(t);
            }
            buf.resize(batch * tl, 0.0); // zero-pad unused slots
            let probs = exe.run(&buf)?;
            out.extend_from_slice(&probs[..used]);
            idx += used;
        }
        Ok(out)
    }
}

/// Pure planning over (sizes, per-tile costs): repeated cheapest batch for
/// the bulk, then an exact dynamic program over the small tail (tail <
/// cheapest batch size, so the DP domain is tiny).
pub fn plan_with_costs(sizes: &[usize], costs: &[f64], n: usize) -> Vec<(usize, usize)> {
    assert_eq!(sizes.len(), costs.len());
    assert!(!sizes.is_empty());
    let best = (0..sizes.len())
        .min_by(|&a, &b| costs[a].partial_cmp(&costs[b]).unwrap())
        .unwrap();
    let mut out = Vec::new();
    let mut left = n;
    while left >= sizes[best] {
        out.push((sizes[best], sizes[best]));
        left -= sizes[best];
    }
    if left == 0 {
        return out;
    }
    // DP: cover[j] = min cost to run exactly j more tiles; choice[j] = the
    // batch used first. A batch b covers min(b, j) tiles (padding beyond).
    let mut cover = vec![f64::INFINITY; left + 1];
    let mut choice = vec![usize::MAX; left + 1];
    cover[0] = 0.0;
    for j in 1..=left {
        for (i, &b) in sizes.iter().enumerate() {
            let run_cost = costs[i] * b as f64; // full batch cost (padded or not)
            let rest = j.saturating_sub(b);
            let c = run_cost + cover[rest];
            if c < cover[j] {
                cover[j] = c;
                choice[j] = i;
            }
        }
    }
    let mut j = left;
    while j > 0 {
        let i = choice[j];
        let b = sizes[i];
        let used = b.min(j);
        out.push((b, used));
        j -= used;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;
    use crate::util::quickcheck::forall_explain;

    #[test]
    fn plan_covers_exactly_n_property() {
        forall_explain(
            11,
            300,
            |r: &mut Pcg32| {
                let n = r.usize_range(0, 300);
                let costs = [
                    r.f64_range(0.1, 2.0),
                    r.f64_range(0.1, 2.0),
                    r.f64_range(0.1, 2.0),
                ];
                (n, costs)
            },
            |&(n, costs)| {
                let sizes = [1usize, 8, 32];
                let plan = plan_with_costs(&sizes, &costs, n);
                let used: usize = plan.iter().map(|(_, u)| u).sum();
                if used != n {
                    return Err(format!("covered {used} of {n}: {plan:?}"));
                }
                for (b, u) in plan {
                    if u > b || !sizes.contains(&b) {
                        return Err("invalid chunk".into());
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn plan_prefers_cheapest_batch() {
        // b=1 cheapest per tile → bulk should be all singles.
        let plan = plan_with_costs(&[1, 8, 32], &[0.5, 1.0, 3.0], 20);
        assert!(plan.iter().all(|&(b, _)| b == 1));
        // b=32 cheapest → two chunks of 32, then tail.
        let plan = plan_with_costs(&[1, 8, 32], &[3.0, 1.0, 0.2], 70);
        assert_eq!(plan[0], (32, 32));
        assert_eq!(plan[1], (32, 32));
        let used: usize = plan.iter().map(|(_, u)| u).sum();
        assert_eq!(used, 70);
    }

    #[test]
    fn tail_padding_when_cheaper() {
        // Covering 7 with expensive singles (7·1.0) vs one padded 8-run
        // (8·0.5 = 4): padding wins.
        let plan = plan_with_costs(&[1, 8, 32], &[1.0, 0.5, 0.5], 7);
        assert_eq!(plan, vec![(8, 7)]);
        // And the reverse: cheap singles beat a padded run.
        let plan = plan_with_costs(&[1, 8, 32], &[0.1, 1.0, 1.0], 7);
        assert!(plan.iter().all(|&(b, _)| b == 1));
        assert_eq!(plan.len(), 7);
    }
}
