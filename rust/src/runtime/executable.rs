//! One AOT-compiled classifier executable: load HLO text → compile on the
//! PJRT CPU client → execute on f32 NHWC tile batches.
//!
//! HLO *text* is the interchange format (jax ≥ 0.5 emits 64-bit
//! instruction ids that xla_extension 0.5.1 rejects in proto form; the
//! text parser reassigns ids — see /opt/xla-example/README.md).

use std::path::Path;

use anyhow::{anyhow, Result};

/// A compiled classifier for one (level, batch) pair.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Pyramid level this executable serves.
    pub level: usize,
    /// Compiled batch size.
    pub batch: usize,
    /// Tile edge in pixels.
    pub tile_px: usize,
    /// Floats per tile (tile_px² · 3).
    pub tile_len: usize,
}

// SAFETY: see runtime::client — PJRT executables are thread-safe to
// execute concurrently; the wrapper type lacks the auto traits only
// because of its raw handle field.
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    /// Load and compile `classifier_l{level}_b{batch}.hlo.txt`.
    pub fn load(path: &Path, level: usize, batch: usize, tile_px: usize) -> Result<Executable> {
        let client = super::client::client()?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .0
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e}", path.display()))?;
        Ok(Executable {
            exe,
            level,
            batch,
            tile_px,
            tile_len: tile_px * tile_px * 3,
        })
    }

    /// Run one full batch. `pixels` must hold exactly `batch` tiles in
    /// NHWC f32 layout; returns `batch` probabilities.
    pub fn run(&self, pixels: &[f32]) -> Result<Vec<f32>> {
        let want = self.batch * self.tile_len;
        if pixels.len() != want {
            return Err(anyhow!(
                "batch-{} executable got {} floats, want {want}",
                self.batch,
                pixels.len()
            ));
        }
        let lit = xla::Literal::vec1(pixels)
            .reshape(&[
                self.batch as i64,
                self.tile_px as i64,
                self.tile_px as i64,
                3,
            ])
            .map_err(|e| anyhow!("reshape input: {e}"))?;
        let result = self
            .exe
            .execute::<xla::Literal>(&[lit])
            .map_err(|e| anyhow!("execute: {e}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch output: {e}"))?;
        // aot.py lowers with return_tuple=True → 1-tuple of (batch,) f32.
        let probs = out
            .to_tuple1()
            .map_err(|e| anyhow!("untuple output: {e}"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("read output: {e}"))?;
        if probs.len() != self.batch {
            return Err(anyhow!(
                "executable returned {} probs, want {}",
                probs.len(),
                self.batch
            ));
        }
        Ok(probs)
    }

    /// Convenience: artifact filename convention.
    pub fn artifact_name(level: usize, batch: usize) -> String {
        format!("classifier_l{level}_b{batch}.hlo.txt")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_naming() {
        assert_eq!(
            Executable::artifact_name(2, 32),
            "classifier_l2_b32.hlo.txt"
        );
    }
}
