//! Process-wide PJRT CPU client.
//!
//! One `PjRtClient` serves every executable in the process (clients are
//! expensive: thread pools, allocator state). PJRT's C++ API is
//! thread-safe; the rust wrapper type just isn't marked `Send`/`Sync`, so
//! a small wrapper restores that (see `SAFETY` note).

use once_cell::sync::OnceCell;

/// Process-wide PJRT client shared by every executable.
pub struct SharedClient(pub xla::PjRtClient);

// SAFETY: PJRT clients are documented thread-safe (the C++
// `PjRtClient`/TFRT CPU client synchronizes internally; IFRT/PJRT users
// share one client across threads as a matter of course). The rust `xla`
// crate wraps a refcounted handle without declaring auto traits.
unsafe impl Send for SharedClient {}
unsafe impl Sync for SharedClient {}

static CLIENT: OnceCell<SharedClient> = OnceCell::new();

/// The process-wide CPU client (created on first use).
pub fn client() -> anyhow::Result<&'static SharedClient> {
    CLIENT.get_or_try_init(|| {
        let c = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("create PJRT CPU client: {e}"))?;
        log::info!(
            "PJRT client: platform={} devices={}",
            c.platform_name(),
            c.device_count()
        );
        Ok(SharedClient(c))
    })
}
