//! Prediction cache: every lineage tile's probability and ground truth,
//! for every resolution level of a slide set.
//!
//! This mirrors the paper's methodology (§4.3-4.5): inference runs *once*
//! over all tiles of all levels; threshold tuning, pyramidal replay,
//! speedup estimation and the distributed simulator are then deterministic
//! post-mortem computations over the cached probabilities.

use std::collections::HashMap;
use std::path::Path;

use crate::model::Analyzer;
use crate::preprocess::otsu::background_removal;
use crate::pyramid::driver::BG_MARGIN;
use crate::pyramid::tree::{ExecTree, Thresholds};
use crate::slide::pyramid::Slide;
use crate::slide::tile::TileId;
use crate::synth::slide_gen::SlideSpec;
use crate::util::json::{Json, JsonError};

/// Cached per-tile data.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TilePred {
    /// Predicted tumor probability.
    pub prob: f32,
    /// Ground-truth tumor label at this tile's level.
    pub tumor: bool,
}

/// All predictions for one slide.
#[derive(Debug, Clone)]
pub struct SlidePredictions {
    /// The slide recipe the predictions were collected from.
    pub spec: SlideSpec,
    /// Lowest-level working set after background removal.
    pub initial: Vec<TileId>,
    /// Probability + label for every tile in the lineage of `initial`, at
    /// every level.
    pub preds: HashMap<TileId, TilePred>,
}

impl SlidePredictions {
    /// Run the analyzer over the full lineage of the initial working set at
    /// every level (pass-through execution) and record everything.
    pub fn collect(slide: &Slide, analyzer: &dyn Analyzer, batch: usize) -> SlidePredictions {
        let initial = background_removal(slide, BG_MARGIN).tissue_tiles;
        let mut preds = HashMap::new();
        let mut frontier = initial.clone();
        let mut level = slide.lowest_level();
        loop {
            for chunk in frontier.chunks(batch.max(1)) {
                let ps = analyzer.analyze(slide, level, chunk);
                for (&tile, &prob) in chunk.iter().zip(&ps) {
                    preds.insert(
                        tile,
                        TilePred {
                            prob,
                            tumor: slide.is_tumor(tile),
                        },
                    );
                }
            }
            if level == 0 {
                break;
            }
            frontier = frontier.iter().flat_map(|t| t.children()).collect();
            level -= 1;
        }
        SlidePredictions {
            spec: slide.spec.clone(),
            initial,
            preds,
        }
    }

    /// Replay a pyramidal execution under `thresholds` (post-mortem run):
    /// a [`crate::pyramid::PyramidRun`] driven by a
    /// [`crate::pyramid::ReplayBackend`] over this cache. Panics when a
    /// lineage tile is missing (corrupt cache).
    pub fn replay(&self, thresholds: &Thresholds) -> ExecTree {
        let mut backend = crate::pyramid::ReplayBackend::new(self);
        crate::pyramid::backend::run_on_backend(
            &self.spec.id,
            self.spec.levels,
            self.initial.clone(),
            thresholds,
            0,
            &mut backend,
        )
        .expect("every lineage tile cached")
    }

    /// (probability, label) pairs for all cached tiles at one level — the
    /// tuning input for that level's decision block.
    pub fn level_pairs(&self, level: usize) -> Vec<(f32, bool)> {
        self.preds
            .iter()
            .filter(|(t, _)| t.level as usize == level)
            .map(|(_, p)| (p.prob, p.tumor))
            .collect()
    }

    /// Level-0 lineage size = the reference execution's tile count.
    pub fn reference_count(&self) -> usize {
        let f2 = crate::slide::tile::SCALE_FACTOR.pow(2);
        self.initial.len() * f2.pow(self.spec.levels as u32 - 1)
    }

    /// Serialize for the on-disk cache format.
    pub fn to_json(&self) -> Json {
        // Compact encoding: per tile [level, tx, ty, prob, tumor].
        let mut entries: Vec<(&TileId, &TilePred)> = self.preds.iter().collect();
        entries.sort_by_key(|(t, _)| **t);
        let preds: Vec<Json> = entries
            .into_iter()
            .map(|(t, p)| {
                Json::Arr(vec![
                    Json::Num(t.level as f64),
                    Json::Num(t.tx as f64),
                    Json::Num(t.ty as f64),
                    Json::Num((p.prob as f64 * 1e6).round() / 1e6),
                    Json::Bool(p.tumor),
                ])
            })
            .collect();
        let initial: Vec<Json> = self
            .initial
            .iter()
            .map(|t| {
                Json::Arr(vec![
                    Json::Num(t.level as f64),
                    Json::Num(t.tx as f64),
                    Json::Num(t.ty as f64),
                ])
            })
            .collect();
        Json::obj()
            .set("spec", self.spec.to_json())
            .set("initial", Json::Arr(initial))
            .set("preds", Json::Arr(preds))
    }

    /// Parse one slide's entry of the on-disk cache format.
    pub fn from_json(v: &Json) -> Result<SlidePredictions, JsonError> {
        let spec = SlideSpec::from_json(v.get("spec")?)?;
        let initial = v
            .get("initial")?
            .as_arr()?
            .iter()
            .map(|t| {
                let t = t.as_arr()?;
                Ok(TileId::new(
                    t[0].as_usize()?,
                    t[1].as_usize()?,
                    t[2].as_usize()?,
                ))
            })
            .collect::<Result<Vec<_>, JsonError>>()?;
        let mut preds = HashMap::new();
        for e in v.get("preds")?.as_arr()? {
            let e = e.as_arr()?;
            preds.insert(
                TileId::new(e[0].as_usize()?, e[1].as_usize()?, e[2].as_usize()?),
                TilePred {
                    prob: e[3].as_f64()? as f32,
                    tumor: e[4].as_bool()?,
                },
            );
        }
        Ok(SlidePredictions {
            spec,
            initial,
            preds,
        })
    }
}

/// A cache over a whole slide set, with file I/O.
#[derive(Debug, Clone, Default)]
pub struct PredCache {
    /// Per-slide prediction sets, in collection order.
    pub slides: Vec<SlidePredictions>,
}

impl PredCache {
    /// Collect predictions for a whole slide set, serially.
    pub fn collect_set(
        slides: &[Slide],
        analyzer: &dyn Analyzer,
        batch: usize,
    ) -> PredCache {
        PredCache {
            slides: slides
                .iter()
                .map(|s| SlidePredictions::collect(s, analyzer, batch))
                .collect(),
        }
    }

    /// Parallel collection over a thread pool (PJRT executions are
    /// thread-safe; useful on multi-core deployments — on this one-core
    /// testbed it matches `collect_set`).
    pub fn collect_set_parallel(
        specs: &[crate::synth::slide_gen::SlideSpec],
        analyzer: std::sync::Arc<dyn Analyzer>,
        batch: usize,
        jobs: usize,
    ) -> PredCache {
        if jobs <= 1 {
            let slides: Vec<Slide> = specs.iter().cloned().map(Slide::from_spec).collect();
            return Self::collect_set(&slides, analyzer.as_ref(), batch);
        }
        let pool = crate::util::threadpool::ThreadPool::new(jobs);
        let slides = pool.map(specs.to_vec(), move |spec| {
            let slide = Slide::from_spec(spec);
            SlidePredictions::collect(&slide, analyzer.as_ref(), batch)
        });
        PredCache { slides }
    }

    /// Pooled (probability, label) pairs at one level across all slides.
    pub fn level_pairs(&self, level: usize) -> Vec<(f32, bool)> {
        self.slides
            .iter()
            .flat_map(|s| s.level_pairs(level))
            .collect()
    }

    /// Serialize the whole cache.
    pub fn to_json(&self) -> Json {
        Json::obj().set(
            "slides",
            Json::Arr(self.slides.iter().map(|s| s.to_json()).collect()),
        )
    }

    /// Parse a whole cache.
    pub fn from_json(v: &Json) -> Result<PredCache, JsonError> {
        Ok(PredCache {
            slides: v
                .get("slides")?
                .as_arr()?
                .iter()
                .map(SlidePredictions::from_json)
                .collect::<Result<Vec<_>, _>>()?,
        })
    }

    /// Write the cache to `path` as pretty JSON.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string())
    }

    /// Load a cache written by [`PredCache::save`].
    pub fn load(path: &Path) -> anyhow::Result<PredCache> {
        let text = std::fs::read_to_string(path)?;
        Ok(PredCache::from_json(&Json::parse(&text)?)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::oracle::OracleAnalyzer;
    use crate::synth::slide_gen::SlideKind;

    fn cache_one() -> (Slide, SlidePredictions) {
        let s = Slide::from_spec(SlideSpec::new(
            "pc",
            31,
            16,
            8,
            3,
            64,
            SlideKind::LargeTumor,
        ));
        let a = OracleAnalyzer::new(1);
        let c = SlidePredictions::collect(&s, &a, 8);
        (s, c)
    }

    #[test]
    fn lineage_is_complete() {
        let (_, c) = cache_one();
        let n = c.initial.len();
        let l2 = c.level_pairs(2).len();
        let l1 = c.level_pairs(1).len();
        let l0 = c.level_pairs(0).len();
        assert_eq!(l2, n);
        assert_eq!(l1, n * 4);
        assert_eq!(l0, n * 16);
        assert_eq!(c.reference_count(), n * 16);
    }

    #[test]
    fn replay_matches_live_run() {
        let (s, c) = cache_one();
        let a = OracleAnalyzer::new(1);
        let thr = Thresholds::uniform(3, 0.4);
        let live = crate::pyramid::driver::run_pyramidal(&s, &a, &thr, 8);
        let replayed = c.replay(&thr);
        assert_eq!(live.analyzed_per_level(), replayed.analyzed_per_level());
        assert_eq!(live.nodes[0], replayed.nodes[0]);
    }

    #[test]
    fn replay_is_consistent_for_any_threshold() {
        let (_, c) = cache_one();
        for thr in [0.0, 0.2, 0.5, 0.8, 1.1] {
            let t = c.replay(&Thresholds::uniform(3, thr));
            t.check_consistency().unwrap();
        }
    }

    #[test]
    fn json_roundtrip() {
        let (_, c) = cache_one();
        let cache = PredCache {
            slides: vec![c.clone()],
        };
        let parsed = PredCache::from_json(&Json::parse(&cache.to_json().to_string()).unwrap())
            .unwrap();
        assert_eq!(parsed.slides.len(), 1);
        let p = &parsed.slides[0];
        assert_eq!(p.spec, c.spec);
        assert_eq!(p.initial, c.initial);
        assert_eq!(p.preds.len(), c.preds.len());
        // probabilities quantized to 1e-6 in the encoding
        for (t, v) in &c.preds {
            let got = p.preds[t];
            assert!((got.prob - v.prob).abs() < 1e-5);
            assert_eq!(got.tumor, v.tumor);
        }
    }

    #[test]
    fn parallel_collection_matches_serial() {
        use crate::synth::slide_gen::{gen_slide_set, DatasetParams};
        let specs = gen_slide_set("pp", 4, 5, &DatasetParams {
            tiles_x: 16,
            tiles_y: 8,
            levels: 3,
            tile_px: 64,
        });
        let analyzer: std::sync::Arc<dyn crate::model::Analyzer> =
            std::sync::Arc::new(OracleAnalyzer::new(1));
        let serial = {
            let slides: Vec<Slide> = specs.iter().cloned().map(Slide::from_spec).collect();
            PredCache::collect_set(&slides, analyzer.as_ref(), 8)
        };
        let parallel =
            PredCache::collect_set_parallel(&specs, std::sync::Arc::clone(&analyzer), 8, 3);
        assert_eq!(serial.slides.len(), parallel.slides.len());
        for (a, b) in serial.slides.iter().zip(&parallel.slides) {
            assert_eq!(a.spec.id, b.spec.id);
            assert_eq!(a.preds.len(), b.preds.len());
            for (t, p) in &a.preds {
                assert_eq!(b.preds[t], *p, "mismatch at {t}");
            }
        }
    }

    #[test]
    fn file_roundtrip() {
        let (_, c) = cache_one();
        let cache = PredCache { slides: vec![c] };
        let dir = std::env::temp_dir().join(format!("pyramidai_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cache.json");
        cache.save(&path).unwrap();
        let loaded = PredCache::load(&path).unwrap();
        assert_eq!(loaded.slides.len(), 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn file_roundtrip_preserves_replay_and_tuning_inputs() {
        // Save → load must preserve everything downstream code consumes:
        // replayed trees (1e-6 prob quantization must not flip any zoom
        // decision at these thresholds) and per-level tuning pairs.
        let (_, c) = cache_one();
        let cache = PredCache {
            slides: vec![c.clone()],
        };
        let dir = std::env::temp_dir().join(format!("pyramidai_replay_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cache.json");
        cache.save(&path).unwrap();
        let loaded = PredCache::load(&path).unwrap();
        let lp = &loaded.slides[0];
        assert_eq!(lp.initial, c.initial, "initial working set survives I/O");
        for thr in [0.2, 0.4, 0.7] {
            let t = Thresholds::uniform(3, thr);
            let orig = c.replay(&t);
            let back = lp.replay(&t);
            back.check_consistency().unwrap();
            assert_eq!(orig.analyzed_per_level(), back.analyzed_per_level());
            assert_eq!(
                orig.nodes.iter().flatten().map(|n| n.tile).collect::<Vec<_>>(),
                back.nodes.iter().flatten().map(|n| n.tile).collect::<Vec<_>>(),
                "replayed tile sets differ at thr={thr}"
            );
        }
        for level in 0..3 {
            assert_eq!(
                lp.level_pairs(level).len(),
                c.level_pairs(level).len(),
                "tuning pairs lost at level {level}"
            );
        }
        assert_eq!(lp.reference_count(), c.reference_count());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
