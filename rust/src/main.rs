//! `pyramidai` — command-line entry point of the L3 coordinator.
//!
//! Subcommands mirror the workflow in DESIGN.md §6:
//!
//! ```text
//! pyramidai gen       --out slides.json [--count 9] [--seed 2025]
//! pyramidai predict   --slides slides.json --cache-dir preds/ [--model auto]
//!                     [--out cache.json]
//! pyramidai tune      --cache-dir preds/ --out thresholds.json
//!                     [--cache-budget-mb 64]
//!                     [--strategy empirical|metric] [--target 0.9]
//! pyramidai analyze   --slide-seed 1 [--kind large_tumor] [--model auto]
//!                     [--thresholds thresholds.json]
//! pyramidai simulate  --workers 1,2,4,8,12 [--model oracle]
//! pyramidai cluster   --workers 4 [--steal=true] [--per-tile-ms 20]
//! pyramidai worker    --connect 127.0.0.1:PORT [--model auto] [--advertise HOST]
//! pyramidai leader    [--standby-addr HOST:PORT] [--out tree.json] | --standby
//!                     [--out-dir trees/]
//! pyramidai trace     --dir traces/ [--out trace_chrome.json] [--timelines]
//! pyramidai bench     [--smoke] [--out BENCH_1.json] [--label 1]
//! pyramidai report    [--model auto] [--fast=true]
//! ```
//!
//! Every subcommand also honors the global observability flags
//! `--log-level error|warn|info|debug|trace` (stderr verbosity, default
//! `info` or `PYRAMIDAI_LOG`) and `--trace-out DIR` (write this process's
//! structured events to `DIR/trace-<role>-<pid>.jsonl`; `serve
//! --external-workers N` forwards the flag to the worker processes so one
//! directory collects the whole cluster's timeline).

use std::path::Path;
use std::time::Duration;

use anyhow::{anyhow, Result};

use pyramidai::cli::Args;
use pyramidai::experiments::{self, Ctx, CtxConfig, ModelKind};
use pyramidai::harness::print_table;
use pyramidai::obs;
use pyramidai::metrics::retention::retention_and_speedup;
use pyramidai::predcache::{PredCache, PredSource, ShardedPredStore, SlidePredictions};
use pyramidai::pyramid::driver::{run_pyramidal, run_reference};
use pyramidai::pyramid::tree::Thresholds;
use pyramidai::slide::pyramid::Slide;
use pyramidai::synth::slide_gen::{gen_slide_set, DatasetParams, SlideKind, SlideSpec};
use pyramidai::tuning::{empirical, metric_based};
use pyramidai::util::json::Json;

fn main() {
    let args = Args::from_env();
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            obs::event(
                obs::Level::Error,
                "cli",
                "fatal",
                &[("err", format!("{e:#}").into())],
            );
            2
        }
    };
    obs::flush_trace();
    std::process::exit(code);
}

fn dispatch(args: &Args) -> Result<()> {
    // Global observability flags, honored before any subcommand runs:
    // --log-level gates the stderr logger, --trace-out installs this
    // process's JSONL trace sink (named after the subcommand role).
    if let Some(s) = args.get("log-level") {
        let level = obs::Level::parse(s).ok_or_else(|| {
            anyhow!("unknown --log-level {s:?} (error|warn|info|debug|trace)")
        })?;
        obs::set_log_level(level);
    }
    if let Some(dir) = args.get("trace-out") {
        let role = args.subcommand.as_deref().unwrap_or("main");
        let path = obs::init_trace_dir(Path::new(dir), role)?;
        obs::event(
            obs::Level::Info,
            "cli",
            "trace_sink",
            &[("path", path.display().to_string().into())],
        );
    }
    // --faults plan.json arms deterministic fault injection process-wide
    // (DESIGN.md §16): every wire frame, shard write and HTTP connection
    // consults the installed plan. Parsed before dispatch so serve,
    // leader and worker all honor it.
    if let Some(plan_path) = args.get("faults") {
        let plan = pyramidai::fault::FaultPlan::from_file(Path::new(plan_path))?;
        obs::event(
            obs::Level::Warn,
            "cli",
            "faults_armed",
            &[
                ("plan", plan_path.into()),
                ("seed", plan.seed.into()),
                ("rules", plan.rules.len().into()),
            ],
        );
        pyramidai::fault::install(plan);
    }
    match args.subcommand.as_deref() {
        Some("gen") => cmd_gen(args),
        Some("predict") => cmd_predict(args),
        Some("tune") => cmd_tune(args),
        Some("analyze") => cmd_analyze(args),
        Some("simulate") => cmd_simulate(args),
        Some("cluster") => cmd_cluster(args),
        Some("worker") => cmd_worker(args),
        Some("leader") => cmd_leader(args),
        Some("serve") => cmd_serve(args),
        Some("fsck") => cmd_fsck(args),
        Some("trace") => cmd_trace(args),
        Some("bench") => cmd_bench(args),
        Some("report") => cmd_report(args),
        Some(other) => Err(anyhow!("unknown subcommand {other:?}\n{USAGE}")),
        None => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

const USAGE: &str = "\
pyramidai — pyramidal analysis of gigapixel images (paper reproduction)

subcommands:
  gen       generate a synthetic slide set        (--out --count --seed)
  predict   collect predictions for a slide set   (--slides --model, plus
                                                   --cache-dir DIR for binary
                                                   per-slide shards and/or
                                                   --out FILE.json for legacy JSON)
  tune      tune decision thresholds from a cache (--cache FILE.json or
                                                   --cache-dir DIR [--cache-budget-mb N]
                                                   --out --strategy --target;
                                                   a shard dir streams slides
                                                   under the memory budget)
  analyze   pyramidal vs reference on one slide   (--slide-seed --kind --model --thresholds)
  simulate  Fig-6 load-balancing simulation       (--workers --model)
  cluster   run the TCP work-stealing cluster     (--workers --per-tile-ms --reps
                                                   --compare-service=true for the Fig-7b
                                                   service-vs-one-shot sweep)
  worker    standalone cluster worker process     (--connect host:port --model
                                                   --analyzer-seed --per-tile-ms
                                                   --advertise HOST (host the leader
                                                   reaches this worker at; default
                                                   127.0.0.1)
                                                   --wire v1|v2 (default v2; v1
                                                   forces JSON frames for
                                                   pre-v2 leaders); joins a serve
                                                   --backend cluster leader and serves
                                                   chunks until shutdown)
  leader    one-shot cluster leader / standby     (--slide-seed --kind --workers
                                                   --wait-workers N --chunk
                                                   --standby-addr HOST:PORT
                                                   --listen --advertise --addr-file
                                                   --out FILE.json --per-tile-ms;
                                                   with --standby: warm standby that
                                                   replays the replicated ledger on
                                                   leader death and resumes its runs
                                                   (--out-dir DIR writes run_<id>.json
                                                   trees byte-identical to --out;
                                                   --reconnect-grace-ms N debounces
                                                   takeover on replication EOF,
                                                   default 500))
  serve     multi-slide analysis service          (--jobs --workers --backend pool|cluster|replay
                                                   --policy fifo|priority|edf|wfs[:t=w,..][;quota=n]
                                                   --preempt --park-aging-ms --deadline-ms
                                                   --max-in-flight --queue-cap --batch
                                                   --coalesce --per-tile-ms
                                                   --tenants --seed --model --csv
                                                   --external-workers --heartbeat-ms
                                                   --standby-addr HOST:PORT (replicate
                                                   the chunk ledger) --advertise HOST
                                                   --fail-leader-after-ms N (chaos:
                                                   drop all dispatch state mid-run)
                                                   --cache-dir DIR --cache-budget-mb N
                                                   for streamed shard replay;
                                                   --listen HOST:PORT --tokens-file FILE
                                                   --listen-secs N starts the HTTP
                                                   admission front-end instead of the
                                                   synthetic stream: POST /v1/jobs,
                                                   GET /v1/jobs/<id>[/result], DELETE
                                                   /v1/jobs/<id>, GET /v1/metrics)
  fsck      verify & repair a shard cache dir     (--cache-dir DIR [--dry-run];
                                                   checks every shard against the
                                                   manifest — size, CRC, decode,
                                                   id — sweeps torn-write debris,
                                                   moves bad shards to quarantine/
                                                   and rewrites the manifest;
                                                   --dry-run reports only and
                                                   exits nonzero on damage)
  trace     merge --trace-out JSONL shards        (--dir DIR --out FILE
                                                   --check --timelines; writes a
                                                   Chrome trace-event file and
                                                   prints per-event latency and
                                                   per-chunk cross-process
                                                   timelines)
  bench     measured perf record                  (--smoke --out FILE --label N;
                                                   writes BENCH_<n>.json with
                                                   service + predcache throughput,
                                                   tile-synthesis and wire-framing
                                                   hot-path numbers, and the
                                                   metrics snapshot)
  report    regenerate every paper table/figure   (--model --fast)

global flags: --log-level error|warn|info|debug|trace   (default info, or
              PYRAMIDAI_LOG)
              --trace-out DIR   write structured events to
              DIR/trace-<role>-<pid>.jsonl (serve forwards the flag to
              external workers)
              --faults PLAN.json   arm deterministic fault injection on
              every I/O seam (net.delay/drop/corrupt/partition,
              disk.torn_write/bitflip/enospc; DESIGN.md §16)";

fn model_kind(args: &Args) -> Result<ModelKind> {
    let s = args.str_or("model", "auto");
    ModelKind::from_str(&s).ok_or_else(|| anyhow!("unknown --model {s:?} (oracle|pjrt|auto)"))
}

fn dataset_params(args: &Args) -> Result<DatasetParams> {
    Ok(DatasetParams {
        tiles_x: args.usize_or("tiles-x", 48)?,
        tiles_y: args.usize_or("tiles-y", 32)?,
        levels: args.usize_or("levels", 3)?,
        tile_px: args.usize_or("tile-px", 64)?,
    })
}

fn cmd_gen(args: &Args) -> Result<()> {
    let out = args.require("out")?;
    let count = args.usize_or("count", 9)?;
    let seed = args.u64_or("seed", 2025)?;
    let prefix = args.str_or("prefix", "slide");
    let params = dataset_params(args)?;
    args.finish()?;
    let specs = gen_slide_set(&prefix, count, seed, &params);
    let json = Json::Arr(specs.iter().map(|s| s.to_json()).collect());
    std::fs::write(&out, json.to_pretty())?;
    println!("wrote {count} slide specs to {out}");
    Ok(())
}

fn load_specs(path: &str) -> Result<Vec<SlideSpec>> {
    let v = Json::parse(&std::fs::read_to_string(path)?)?;
    Ok(v.as_arr()?
        .iter()
        .map(SlideSpec::from_json)
        .collect::<Result<Vec<_>, _>>()?)
}

fn cmd_predict(args: &Args) -> Result<()> {
    let slides = args.require("slides")?;
    let out = args.get("out").map(String::from);
    let cache_dir = args.get("cache-dir").map(String::from);
    let kind = model_kind(args)?;
    let batch = args.usize_or("batch", 32)?;
    let jobs = args.usize_or("jobs", 1)?;
    args.finish()?;
    if out.is_none() && cache_dir.is_none() {
        return Err(anyhow!(
            "predict needs --cache-dir DIR (binary shards) and/or --out FILE.json (legacy JSON)"
        ));
    }
    let (analyzer, name) = experiments::ctx::make_analyzer(kind, 7)?;
    let specs = load_specs(&slides)?;
    println!("predicting {} slides ({name}, {jobs} jobs)…", specs.len());
    let cache = PredCache::collect_set_parallel(&specs, analyzer, batch, jobs);
    if let Some(dir) = &cache_dir {
        cache.save_sharded(Path::new(dir), jobs)?;
        println!("wrote {} binary shards + manifest to {dir}", cache.slides.len());
    }
    if let Some(out) = &out {
        cache.save(Path::new(out))?;
        println!("wrote JSON prediction cache to {out}");
    }
    Ok(())
}

/// The `tune` input: a legacy JSON cache fully in memory, or a shard
/// directory streamed under `--cache-budget-mb`.
fn open_tuning_source(args: &Args) -> Result<(Box<dyn PredSource>, usize)> {
    let budget = args.usize_or("cache-budget-mb", 0)?;
    match (args.get("cache"), args.get("cache-dir")) {
        (Some(path), None) => {
            let cache = PredCache::load(Path::new(path))?;
            let levels = cache
                .slides
                .first()
                .ok_or_else(|| anyhow!("empty cache"))?
                .spec
                .levels;
            Ok((Box::new(cache), levels))
        }
        (None, Some(dir)) => {
            let budget = if budget == 0 { None } else { Some(budget) };
            let store = ShardedPredStore::open_with_budget(Path::new(dir), budget)?;
            let levels = store
                .slide_levels(0)
                .ok_or_else(|| anyhow!("empty shard store"))?;
            Ok((Box::new(store), levels))
        }
        (Some(_), Some(_)) => Err(anyhow!("--cache and --cache-dir are mutually exclusive")),
        (None, None) => Err(anyhow!("tune needs --cache FILE.json or --cache-dir DIR")),
    }
}

fn cmd_tune(args: &Args) -> Result<()> {
    let out = args.require("out")?;
    let strategy = args.str_or("strategy", "empirical");
    let target = args.f64_or("target", 0.90)?;
    let (source, levels) = open_tuning_source(args)?;
    args.finish()?;
    let json = match strategy.as_str() {
        "empirical" => {
            let sel = empirical::select(&source, levels, target)?;
            println!(
                "empirical: β={} thresholds={:?}",
                sel.beta, sel.thresholds.zoom
            );
            sel.to_json()
        }
        "metric" => {
            let sel = metric_based::select(&source, levels, target)?;
            println!(
                "metric-based: βs={:?} thresholds={:?}",
                sel.betas, sel.thresholds.zoom
            );
            sel.to_json()
        }
        other => return Err(anyhow!("unknown --strategy {other:?}")),
    };
    std::fs::write(&out, json.to_pretty())?;
    println!("wrote thresholds to {out}");
    Ok(())
}

fn load_thresholds(path: &str) -> Result<Thresholds> {
    let v = Json::parse(&std::fs::read_to_string(path)?)?;
    Ok(Thresholds::from_json(v.get("thresholds")?)?)
}

fn cmd_analyze(args: &Args) -> Result<()> {
    let seed = args.u64_or("slide-seed", 1)?;
    let kind_s = args.str_or("kind", "large_tumor");
    let kind = SlideKind::from_str(&kind_s).ok_or_else(|| anyhow!("bad --kind"))?;
    let model = model_kind(args)?;
    let batch = args.usize_or("batch", 32)?;
    let thr = match args.get("thresholds") {
        Some(p) => load_thresholds(p)?,
        None => Thresholds {
            zoom: vec![0.5, 0.35, 0.35],
        },
    };
    let params = dataset_params(args)?;
    args.finish()?;

    let (analyzer, name) = experiments::ctx::make_analyzer(model, 7)?;
    let slide = Slide::from_spec(SlideSpec::new(
        format!("cli_{seed}"),
        seed,
        params.tiles_x,
        params.tiles_y,
        params.levels,
        params.tile_px,
        kind,
    ));
    println!("analyzing {} with {name}…", slide.id());
    let (pyr, t_pyr) =
        pyramidai::util::stats::timed(|| run_pyramidal(&slide, analyzer.as_ref(), &thr, batch));
    let (reference, t_ref) =
        pyramidai::util::stats::timed(|| run_reference(&slide, analyzer.as_ref(), batch));
    let preds = SlidePredictions::collect(&slide, analyzer.as_ref(), batch);
    let m = retention_and_speedup(&preds, &pyr);
    print_table(
        "pyramidal vs reference",
        &["metric", "value"],
        &[
            vec!["tiles (pyramid)".into(), pyr.total_analyzed().to_string()],
            vec![
                "tiles (reference)".into(),
                reference.total_analyzed().to_string(),
            ],
            vec!["tile speedup".into(), format!("{:.2}×", m.speedup())],
            vec![
                "positive retention".into(),
                format!("{:.3}", m.retention()),
            ],
            vec![
                "wall (pyramid)".into(),
                pyramidai::util::stats::fmt_duration(t_pyr),
            ],
            vec![
                "wall (reference)".into(),
                pyramidai::util::stats::fmt_duration(t_ref),
            ],
            vec![
                "per-level".into(),
                format!("{:?}", pyr.analyzed_per_level()),
            ],
        ],
    );
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let workers = args.usize_list_or("workers", &[1, 2, 4, 8, 12, 16, 24])?;
    let model = model_kind(args)?;
    args.finish()?;
    let ctx = Ctx::load(CtxConfig {
        model,
        ..Default::default()
    })?;
    let rows = experiments::fig6::run(&ctx, &workers)?;
    experiments::fig6::print_report(&ctx, &rows)?;
    Ok(())
}

fn cmd_cluster(args: &Args) -> Result<()> {
    let workers = args.usize_list_or("workers", &[1, 2, 4, 8, 12])?;
    let reps = args.usize_or("reps", 3)?;
    let per_tile_ms = args.u64_or("per-tile-ms", 20)?;
    let compare_service = args.bool("compare-service");
    let model = model_kind(args)?;
    args.finish()?;
    let ctx = Ctx::load(CtxConfig {
        model,
        ..Default::default()
    })?;
    if compare_service {
        // Fig 7b: persistent service-backed cluster vs one-shot runs.
        let rows =
            experiments::fig7b::run(&ctx, &workers, reps, Duration::from_millis(per_tile_ms))?;
        experiments::fig7b::print_report(&rows)?;
    } else {
        let rows =
            experiments::fig7::run(&ctx, &workers, reps, Duration::from_millis(per_tile_ms))?;
        experiments::fig7::print_report(&rows)?;
    }
    Ok(())
}

fn cmd_worker(args: &Args) -> Result<()> {
    use pyramidai::cluster::proto::WireVersion;
    use pyramidai::model::DelayAnalyzer;
    let connect = args.require("connect")?;
    let model = model_kind(args)?;
    // Must match the leader's analyzer for byte-identical trees — the
    // default mirrors `make_analyzer`'s everywhere else.
    let analyzer_seed = args.u64_or("analyzer-seed", 7)?;
    // Host this worker tells the leader to reach it at (loopback is only
    // valid when leader and worker share a machine).
    let advertise = args.str_or("advertise", "127.0.0.1");
    // Per-tile analysis delay, e.g. to make chaos tests reliably catch a
    // leader kill mid-run. Purely additive: results are unchanged.
    let per_tile_ms = args.u64_or("per-tile-ms", 0)?;
    let wire = match args.str_or("wire", "v2").as_str() {
        "v1" | "1" | "json" => WireVersion::V1Json,
        "v2" | "2" | "binary" => WireVersion::V2Binary,
        other => anyhow::bail!("unknown --wire {other:?} (expected v1 or v2)"),
    };
    args.finish()?;
    let (analyzer, name) = experiments::ctx::make_analyzer(model, analyzer_seed)?;
    let analyzer: std::sync::Arc<dyn pyramidai::model::Analyzer> = if per_tile_ms > 0 {
        std::sync::Arc::new(DelayAnalyzer::new(
            analyzer,
            Duration::from_millis(per_tile_ms),
        ))
    } else {
        analyzer
    };
    obs::event(
        obs::Level::Info,
        "cli",
        "worker_connecting",
        &[
            ("model", name.into()),
            ("leader", connect.as_str().into()),
            ("advertise", advertise.as_str().into()),
            ("wire", wire.as_u64().into()),
        ],
    );
    let id = pyramidai::cluster::run_standalone_worker(
        &connect,
        &advertise,
        analyzer,
        analyzer_seed,
        wire,
    )?;
    obs::event(
        obs::Level::Info,
        "cli",
        "worker_exit",
        &[("worker", id.into())],
    );
    obs::flush_trace();
    Ok(())
}

/// One-shot cluster leader (active mode) or warm standby (`--standby`).
///
/// Active mode runs a single synthetic slide on the work-stealing
/// cluster, streaming every ledger op to `--standby-addr` so a SIGKILL
/// mid-run loses nothing: the standby replays the log, workers re-Hello
/// the address they were told about in Welcome, and the finished tree is
/// byte-identical to an unfailed run (DESIGN.md §15). `--addr-file`
/// publishes the control address for scripts that spawn workers; `--out`
/// writes the finished tree as JSON in the exact format the standby's
/// `--out-dir` uses, so CI can byte-compare the two.
fn cmd_leader(args: &Args) -> Result<()> {
    use pyramidai::cluster::standby::Standby;
    use pyramidai::cluster::{ClusterBackend, ClusterExec, ClusterExecConfig, StandbyConfig};
    use pyramidai::model::DelayAnalyzer;
    use pyramidai::preprocess::background_removal;
    use pyramidai::pyramid::driver::BG_MARGIN;
    use pyramidai::pyramid::run_on_backend;
    use std::sync::Arc;

    let standby_mode = args.bool("standby");
    let model = model_kind(args)?;
    let analyzer_seed = args.u64_or("analyzer-seed", 7)?;
    let per_tile_ms = args.u64_or("per-tile-ms", 0)?;
    let listen = args.str_or("listen", "127.0.0.1:0");
    let advertise = args.str_or("advertise", "127.0.0.1");
    let heartbeat_ms = args.u64_or("heartbeat-ms", 25)?;
    let addr_file = args.get("addr-file").map(String::from);

    let (analyzer, name) = experiments::ctx::make_analyzer(model, analyzer_seed)?;
    let analyzer: Arc<dyn pyramidai::model::Analyzer> = if per_tile_ms > 0 {
        Arc::new(DelayAnalyzer::new(
            analyzer,
            Duration::from_millis(per_tile_ms),
        ))
    } else {
        analyzer
    };

    if standby_mode {
        let out_dir = args.get("out-dir").map(std::path::PathBuf::from);
        let reconnect_grace_ms = args.u64_or("reconnect-grace-ms", 500)?;
        args.finish()?;
        let standby = Standby::bind(StandbyConfig {
            listen,
            advertise_host: advertise,
            out_dir,
            heartbeat: Duration::from_millis(heartbeat_ms.max(1)),
            reconnect_grace: Duration::from_millis(reconnect_grace_ms.max(1)),
            ..StandbyConfig::default()
        })?;
        if let Some(path) = &addr_file {
            write_text_atomic(Path::new(path), &standby.addr())?;
        }
        println!(
            "standby on {} ({name}), waiting for a leader…",
            standby.addr()
        );
        let report = standby.run(analyzer)?;
        if report.took_over {
            println!(
                "standby took over: {} ledger record(s) replayed, {} run(s) resumed",
                report.records_applied,
                report.resumed.len()
            );
            for (run, tree) in &report.resumed {
                println!("  run {run}: {} tiles analyzed", tree.total_analyzed());
            }
        } else {
            println!(
                "leader shut down cleanly after {} record(s); standby exiting",
                report.records_applied
            );
        }
        return Ok(());
    }

    let seed = args.u64_or("slide-seed", 1)?;
    let kind_s = args.str_or("kind", "large_tumor");
    let kind = SlideKind::from_str(&kind_s).ok_or_else(|| anyhow!("bad --kind"))?;
    let params = dataset_params(args)?;
    let workers = args.usize_or("workers", 0)?;
    let wait_workers = args.usize_or("wait-workers", 0)?;
    let chunk = args.usize_or("chunk", 8)?;
    let standby_addr = args.get("standby-addr").map(String::from);
    let out = args.get("out").map(String::from);
    let thr = match args.get("thresholds") {
        Some(p) => load_thresholds(p)?,
        None if params.levels == 3 => Thresholds {
            zoom: vec![0.5, 0.35, 0.35],
        },
        None => Thresholds::uniform(params.levels, 0.35),
    };
    args.finish()?;

    // Same slide + initial-tile derivation as the scheduler's cluster
    // jobs, so the tree here is comparable with every other path.
    let spec = SlideSpec::new(
        format!("cli_{seed}"),
        seed,
        params.tiles_x,
        params.tiles_y,
        params.levels,
        params.tile_px,
        kind,
    );
    let slide = Slide::from_spec(spec.clone());
    let initial = background_removal(&slide, BG_MARGIN).tissue_tiles;

    let exec = Arc::new(ClusterExec::start(
        Arc::clone(&analyzer),
        &ClusterExecConfig {
            workers,
            steal: true,
            heartbeat: Duration::from_millis(heartbeat_ms.max(1)),
            standby: standby_addr,
            advertise_host: advertise,
            listen,
            ..ClusterExecConfig::default()
        },
    )?);
    if let Some(path) = &addr_file {
        write_text_atomic(Path::new(path), &exec.leader_addr())?;
    }
    println!(
        "leader on {} ({name}, {workers} in-process worker(s), chunk={chunk})",
        exec.leader_addr()
    );
    if wait_workers > 0 && !exec.wait_for_workers(wait_workers, Duration::from_secs(60)) {
        exec.shutdown();
        return Err(anyhow!("timed out waiting for {wait_workers} worker(s)"));
    }
    // Chaos harnesses key their kill clocks off this line: everything
    // before it is setup, everything after is the run proper.
    println!("workers ready: {}", exec.alive_workers());

    const RUN_ID: u64 = 1;
    exec.register_run(RUN_ID, &spec, &thr.zoom, &initial, chunk);
    let mut backend = ClusterBackend::with_exec(Arc::clone(&exec), spec.clone(), RUN_ID);
    let tree = run_on_backend(&spec.id, spec.levels, initial, &thr, chunk, &mut backend)?;
    println!(
        "run complete: {} tiles analyzed across {} level(s)",
        tree.total_analyzed(),
        spec.levels
    );
    // Persist the tree *before* recording RunDone in the ledger: a crash
    // in between leaves the run incomplete from the standby's point of
    // view, so it re-finishes and writes the identical tree — whereas the
    // opposite order has a window where the run is ledger-complete but no
    // tree exists anywhere.
    if let Some(path) = &out {
        write_text_atomic(Path::new(path), &tree.to_json().to_string())?;
        println!("wrote {path}");
    }
    exec.ledger_run_done(RUN_ID);
    exec.shutdown();
    Ok(())
}

/// Write `text` to `path` atomically (tmp + rename), so concurrent
/// readers — scripts polling an `--addr-file`, the chaos harness
/// byte-comparing trees — never observe a partial file.
fn write_text_atomic(path: &Path, text: &str) -> Result<()> {
    use std::io::Write;
    let tmp = path.with_extension("tmp");
    let mut f = std::fs::File::create(&tmp)?;
    f.write_all(text.as_bytes())?;
    f.sync_all()?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use pyramidai::cluster::ClusterExecConfig;
    use pyramidai::model::DelayAnalyzer;
    use pyramidai::service::{
        metrics as svc_metrics, AnalysisService, ExecMode, JobSource, JobSpec, PolicySpec,
        Priority, ServiceConfig, SubmitError,
    };

    let jobs = args.usize_or("jobs", 32)?;
    let workers = args.usize_or("workers", 8)?;
    let policy_s = args.str_or("policy", "fifo");
    let policy = PolicySpec::parse(&policy_s).ok_or_else(|| {
        anyhow!(
            "unknown --policy {policy_s:?} (fifo|priority|edf|wfs[:tenant=weight,..][;quota=n])"
        )
    })?;
    let preempt = args.bool("preempt");
    // Base relative deadline for the synthetic jobs (0 = no deadlines).
    // Staggered per job so EDF has an order to exploit: job i gets
    // deadline-ms × (1 + i mod 4).
    let deadline_ms = args.u64_or("deadline-ms", 0)?;
    let max_in_flight = args.usize_or("max-in-flight", workers.max(1))?;
    let queue_cap = args.usize_or("queue-cap", jobs.max(1))?;
    let batch = args.usize_or("batch", 16)?;
    let per_tile_ms = args.u64_or("per-tile-ms", 0)?;
    let tenants = args.usize_or("tenants", 3)?.max(1);
    let seed = args.u64_or("seed", 2025)?;
    let backend = args.str_or("backend", "pool");
    let coalesce = args.str_or("coalesce", "true") != "false";
    // Fault-tolerance knobs (cluster backend): external OS-process
    // workers spawned alongside the in-process ones, and the liveness
    // probe interval (DESIGN.md §10).
    let external_workers = args.usize_or("external-workers", 0)?;
    let heartbeat_ms = args.u64_or("heartbeat-ms", 25)?;
    // Decentralized control plane (DESIGN.md §15): stream the chunk
    // ledger to a standby so a leader crash never loses a run, advertise
    // a reachable host for cross-machine workers, and optionally inject a
    // leader failover mid-run to exercise the recovery path end to end.
    let standby_addr = args.get("standby-addr").map(String::from);
    let advertise = args.str_or("advertise", "127.0.0.1");
    let fail_leader_after_ms = args.u64_or("fail-leader-after-ms", 0)?;
    let model = model_kind(args)?;
    let params = dataset_params(args)?;
    let csv = args.bool("csv");
    // Replay-backend cache placement: shard directory + residency budget
    // (0 = unlimited). Without --cache-dir replay jobs pin their cache in
    // memory as before.
    let cache_dir = args.get("cache-dir").map(String::from);
    let cache_budget_mb = args.usize_or("cache-budget-mb", 0)?;
    // HTTP admission front-end: with --listen the service takes jobs over
    // the wire instead of synthesizing a stream. --tokens-file maps bearer
    // tokens onto scheduler tenants; --listen-secs bounds the server's
    // lifetime (0 = run until killed), which is how CI smoke-tests it.
    let listen = args.get("listen").map(String::from);
    let tokens_file = args.get("tokens-file").map(String::from);
    let listen_secs = args.u64_or("listen-secs", 0)?;
    // Parked-job starvation aging (0 = off): parked jobs accrue rank
    // credit over time so a hot tenant cannot strand them indefinitely.
    let park_aging_ms = args.u64_or("park-aging-ms", 500)?;
    args.finish()?;

    if listen.is_some() && backend == "replay" {
        return Err(anyhow!(
            "--listen serves jobs submitted over HTTP (--backend pool|cluster); \
             it cannot replay a synthetic set"
        ));
    }

    let (base_analyzer, name) = experiments::ctx::make_analyzer(model, 7)?;
    let analyzer: std::sync::Arc<dyn pyramidai::model::Analyzer> = if per_tile_ms > 0 {
        std::sync::Arc::new(DelayAnalyzer::new(
            std::sync::Arc::clone(&base_analyzer),
            Duration::from_millis(per_tile_ms),
        ))
    } else {
        std::sync::Arc::clone(&base_analyzer)
    };

    let exec = match backend.as_str() {
        "pool" | "replay" => ExecMode::Pool,
        "cluster" => {
            // External worker processes must build the *same* analyzer
            // as the leader (same resolved model, same seed) or their
            // chunks would silently produce a mixed tree.
            let mut external_args = vec![
                "--model".to_string(),
                name.to_string(),
                "--analyzer-seed".to_string(),
                "7".to_string(),
            ];
            // Forward the observability flags so every worker process
            // writes its own JSONL shard into the same trace directory.
            if let Some(dir) = args.get("trace-out") {
                external_args.push("--trace-out".to_string());
                external_args.push(dir.to_string());
            }
            if let Some(level) = args.get("log-level") {
                external_args.push("--log-level".to_string());
                external_args.push(level.to_string());
            }
            ExecMode::Cluster(ClusterExecConfig {
                workers,
                steal: true,
                seed,
                heartbeat: Duration::from_millis(heartbeat_ms.max(1)),
                external_workers,
                external_args,
                standby: standby_addr.clone(),
                advertise_host: advertise.clone(),
                ..ClusterExecConfig::default()
            })
        }
        other => return Err(anyhow!("unknown --backend {other:?} (pool|cluster|replay)")),
    };

    let policy_desc = policy.as_str();
    if listen.is_none() {
        println!(
            "serving {jobs} jobs on {workers} workers ({name}, backend={backend}, policy={policy_desc}, preempt={preempt}, max-in-flight={max_in_flight}, queue-cap={queue_cap})…"
        );
    }

    // Synthetic job stream: kinds, priorities and tenants cycle so every
    // policy has something to bite on; seeds derive from --seed.
    let specs = gen_slide_set("serve", jobs, seed, &params);
    let thr = if params.levels == 3 {
        Thresholds {
            zoom: vec![0.5, 0.35, 0.35],
        }
    } else {
        Thresholds::uniform(params.levels, 0.35)
    };

    // Replay backend: run inference once up front (undelayed), then serve
    // the jobs as pure post-mortem replays — the §4.3 regime as a service.
    // With --cache-dir the predictions live in binary shards and jobs
    // stream them through a budgeted store (--cache-budget-mb) instead of
    // pinning every slide behind an Arc.
    enum ReplaySource {
        None,
        Pinned(Vec<std::sync::Arc<SlidePredictions>>),
        Store(std::sync::Arc<ShardedPredStore>),
    }
    let replay_source = if backend == "replay" {
        println!("collecting prediction caches for {} slides…", specs.len());
        let cache = PredCache::collect_set_parallel(
            &specs,
            std::sync::Arc::clone(&base_analyzer),
            batch,
            1,
        );
        match &cache_dir {
            Some(dir) => {
                let dir = Path::new(dir);
                cache.save_sharded(dir, 2)?;
                let budget = if cache_budget_mb == 0 {
                    None
                } else {
                    Some(cache_budget_mb)
                };
                let store =
                    std::sync::Arc::new(ShardedPredStore::open_with_budget(dir, budget)?);
                println!(
                    "replay jobs stream {} shards from {} (budget: {})",
                    store.len(),
                    dir.display(),
                    if cache_budget_mb == 0 {
                        "unlimited".to_string()
                    } else {
                        format!("{cache_budget_mb} MiB")
                    }
                );
                ReplaySource::Store(store)
            }
            None => ReplaySource::Pinned(
                cache.slides.into_iter().map(std::sync::Arc::new).collect(),
            ),
        }
    } else {
        ReplaySource::None
    };

    let svc = AnalysisService::start(
        analyzer,
        ServiceConfig {
            // Replay jobs run inline on the scheduler; a full pool would
            // sit idle.
            workers: if backend == "replay" { 1 } else { workers },
            queue_capacity: queue_cap,
            max_in_flight,
            batch,
            policy,
            coalesce,
            preempt,
            park_aging: if park_aging_ms == 0 {
                None
            } else {
                Some(Duration::from_millis(park_aging_ms))
            },
            exec,
        },
    );

    // Chaos injection: after N ms, discard the leader's dispatch state as
    // if the process had been SIGKILLed. The scheduler requeues every
    // outstanding chunk and the run must still finish with an identical
    // tree — CI asserts the exit code, which cmd_serve ties to
    // completeness below.
    if fail_leader_after_ms > 0 {
        if let Some(cluster) = svc.cluster() {
            std::thread::spawn(move || {
                // timer: scheduled chaos trigger, not a retry loop
                std::thread::sleep(Duration::from_millis(fail_leader_after_ms));
                cluster.trigger_failover();
            });
        }
    }

    // Server mode: hand the service to the HTTP front-end and idle until
    // the lifetime elapses; jobs, priorities and tenants all come from
    // authenticated clients instead of the synthetic stream below.
    if let Some(listen_addr) = listen {
        use pyramidai::service::http::{HttpConfig, HttpFrontend, TokenTable};
        use std::sync::atomic::{AtomicBool, Ordering};
        let tokens_path = tokens_file.ok_or_else(|| {
            anyhow!("--listen requires --tokens-file FILE (`token tenant` lines)")
        })?;
        let tokens = TokenTable::load(&tokens_path).map_err(|e| anyhow!(e))?;
        let n_tokens = tokens.len();
        let svc = std::sync::Arc::new(svc);
        let cfg = HttpConfig::new(listen_addr, tokens);
        let health = std::sync::Arc::clone(&cfg.health);
        let frontend = HttpFrontend::start(std::sync::Arc::clone(&svc), cfg)
            .map_err(|e| anyhow!(e))?;
        // Gray-failure watchdog: probe the shard-store directory and the
        // cluster for impairment, and flip the front-end's degraded
        // state accordingly. While degraded the service answers 503 on
        // /healthz and submission instead of accepting work it cannot
        // finish; recovery clears the flag and admission resumes.
        let watch_stop = std::sync::Arc::new(AtomicBool::new(false));
        let watchdog = {
            let svc = std::sync::Arc::clone(&svc);
            let stop = std::sync::Arc::clone(&watch_stop);
            let probe_dir = cache_dir.clone();
            std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    if let Some(cluster) = svc.cluster() {
                        let impaired = cluster.registered_workers() > 0
                            && cluster.alive_workers() == 0;
                        if impaired {
                            health.set_degraded("cluster: no live workers");
                        } else {
                            health.clear_degraded("cluster: no live workers");
                        }
                    }
                    if let Some(dir) = &probe_dir {
                        let probe = Path::new(dir).join(".health_probe.tmp");
                        let ok = std::fs::write(&probe, b"ok").is_ok();
                        let _ = std::fs::remove_file(&probe);
                        if ok {
                            health.clear_degraded("store: cache dir not writable");
                        } else {
                            health.set_degraded("store: cache dir not writable");
                        }
                    }
                    // timer: health probe cadence
                    std::thread::sleep(Duration::from_millis(250));
                }
            })
        };
        println!(
            "HTTP admission front-end on http://{} ({n_tokens} credential(s), backend={backend}, policy={policy_desc}, queue-cap={queue_cap})",
            frontend.addr()
        );
        if listen_secs > 0 {
            // timer: configured server lifetime
            std::thread::sleep(Duration::from_secs(listen_secs));
        } else {
            loop {
                // timer: serve until killed
                std::thread::sleep(Duration::from_secs(3600));
            }
        }
        frontend.stop();
        watch_stop.store(true, Ordering::Relaxed);
        let _ = watchdog.join();
        let svc = std::sync::Arc::try_unwrap(svc)
            .map_err(|_| anyhow!("HTTP handlers still hold the service after stop"))?;
        let report = svc.shutdown();
        svc_metrics::print_report(&report.results, &report.metrics);
        let m = &report.sched_metrics;
        println!(
            "http: {} request(s), {} job(s) submitted, {} cancelled, {} rejected (queue full), {} stream byte(s)",
            m.counter("http.requests"),
            m.counter("http.jobs_submitted"),
            m.counter("http.jobs_cancelled"),
            m.counter("http.rejected_queue_full"),
            m.counter("http.bytes_streamed"),
        );
        if report.pool_panics > 0 {
            println!("pool absorbed {} analyzer panics", report.pool_panics);
        }
        if let Some(f) = report.cluster_faults {
            println!(
                "cluster recovery: {} worker(s) lost, {} joined, {} chunk(s) resubmitted, {} abandoned",
                f.workers_lost, f.workers_joined, f.chunks_resubmitted, f.chunks_abandoned
            );
        }
        if csv {
            let path = svc_metrics::write_csv(&report.results, "service_jobs.csv")?;
            println!("wrote {}", path.display());
        }
        return Ok(());
    }

    let prios = [Priority::Low, Priority::Normal, Priority::High];
    for (i, spec) in specs.into_iter().enumerate() {
        let source = match &replay_source {
            ReplaySource::Pinned(caches) => JobSource::Cached(std::sync::Arc::clone(&caches[i])),
            ReplaySource::Store(store) => JobSource::Sharded {
                store: std::sync::Arc::clone(store),
                slide: i,
            },
            ReplaySource::None => JobSource::Spec(spec),
        };
        let mut job = JobSpec::new(source, thr.clone())
            .with_priority(prios[i % prios.len()])
            .with_tenant(format!("tenant{}", i % tenants));
        if deadline_ms > 0 {
            job = job.with_deadline(Duration::from_millis(deadline_ms * (1 + i as u64 % 4)));
        }
        // Backpressure: poll until the queue has room, through the
        // shared bounded wait — a wedged scheduler fails the run loudly
        // instead of hanging the submitter forever.
        let mut fatal: Option<SubmitError> = None;
        let submitted = pyramidai::fault::poll_until(
            Duration::from_secs(600),
            Duration::from_millis(1),
            || match svc.submit(job.clone()) {
                Ok(_) => true,
                Err(SubmitError::QueueFull(_)) => false,
                Err(e) => {
                    fatal = Some(e);
                    true
                }
            },
        );
        if let Some(e) = fatal {
            return Err(e.into());
        }
        if !submitted {
            return Err(anyhow!("queue stayed full for 600s — scheduler wedged?"));
        }
    }
    let report = svc.shutdown();
    svc_metrics::print_report(&report.results, &report.metrics);
    if let ReplaySource::Store(store) = &replay_source {
        let st = store.stats();
        println!(
            "shard store: {} loads, {} hits, {} evictions, {} slide(s) resident ({} KiB)",
            st.loads,
            st.hits,
            st.evictions,
            st.resident_slides,
            st.resident_bytes / 1024
        );
    }
    if report.pool_panics > 0 {
        println!("pool absorbed {} analyzer panics", report.pool_panics);
    }
    // Recovery visibility (§10): operators see worker churn and the
    // resubmissions that papered over it, instead of silent self-healing.
    if let Some(f) = report.cluster_faults {
        println!(
            "cluster recovery: {} worker(s) lost, {} joined, {} chunk(s) resubmitted, {} abandoned",
            f.workers_lost, f.workers_joined, f.chunks_resubmitted, f.chunks_abandoned
        );
    }
    if csv {
        let path = svc_metrics::write_csv(&report.results, "service_jobs.csv")?;
        println!("wrote {}", path.display());
    }
    // With deadlines in play, expiry is a legitimate outcome (EDF sheds
    // late work instead of running it); anything else unfinished is a bug.
    let incomplete =
        report.results.len() - report.metrics.completed - report.metrics.expired;
    if incomplete > 0 {
        return Err(anyhow!("{incomplete} jobs did not complete"));
    }
    if report.metrics.expired > 0 && deadline_ms == 0 {
        return Err(anyhow!("{} jobs expired without deadlines", report.metrics.expired));
    }
    Ok(())
}

/// Verify (and unless `--dry-run`, repair) a sharded prediction cache:
/// the recovery half of the §16 disk-fault story. Damage on a dry run is
/// an error so scripts can gate on the exit code.
fn cmd_fsck(args: &Args) -> Result<()> {
    use pyramidai::predcache::store::fsck;
    let dir = args.require("cache-dir")?;
    let dry_run = args.bool("dry-run");
    args.finish()?;
    let report = fsck(Path::new(&dir), dry_run)?;
    println!(
        "fsck {}: {} shard(s) checked, {} bad, {} orphan(s), {} quarantined",
        dir,
        report.checked,
        report.bad.len(),
        report.orphans.len(),
        report.quarantined
    );
    for (file, reason) in &report.bad {
        println!("  bad    {file}: {reason}");
    }
    for file in &report.orphans {
        println!("  orphan {file}");
    }
    if dry_run && !report.clean() {
        return Err(anyhow!(
            "store has {} bad shard(s) and {} orphan(s); rerun without --dry-run to repair",
            report.bad.len(),
            report.orphans.len()
        ));
    }
    if !dry_run && !report.clean() {
        println!(
            "store repaired: bad shards moved to {}/, manifest rewritten",
            pyramidai::predcache::store::QUARANTINE_DIR
        );
    }
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<()> {
    use pyramidai::obs::chrome;
    let dir = args.require("dir")?;
    let out = args.str_or("out", "trace_chrome.json");
    let check = args.bool("check");
    let timelines = args.bool("timelines");
    args.finish()?;
    // merge_dir validates every record against the JSONL schema, so
    // --check needs no extra pass — reaching this line is the proof.
    let records = chrome::merge_dir(Path::new(&dir))?;
    println!("merged {} trace records from {dir}", records.len());
    if check {
        println!("schema check passed");
    }
    let doc = chrome::to_chrome_trace(&records);
    std::fs::write(&out, doc.to_string())?;
    println!("wrote Chrome trace-event file to {out} (open in Perfetto or chrome://tracing)");
    let summary = chrome::summarize(&records);
    let rows: Vec<Vec<String>> = summary
        .iter()
        .map(|s| {
            let (p50, p95) = if s.durs_us.is_empty() {
                ("-".to_string(), "-".to_string())
            } else {
                (
                    format!("{:.0}", s.dur_percentile(50.0)),
                    format!("{:.0}", s.dur_percentile(95.0)),
                )
            };
            vec![format!("{}.{}", s.sub, s.ev), s.count.to_string(), p50, p95]
        })
        .collect();
    print_table(
        "trace summary",
        &["event", "count", "p50 µs", "p95 µs"],
        &rows,
    );
    if timelines {
        for (key, steps) in chrome::chunk_timelines(&records) {
            let path: Vec<String> = steps
                .iter()
                .map(|s| match s.worker {
                    Some(w) => format!("{}[{}/w{w}]", s.ev, s.proc),
                    None => format!("{}[{}]", s.ev, s.proc),
                })
                .collect();
            println!("chunk {key}: {}", path.join(" -> "));
        }
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    use pyramidai::obs::bench::{
        next_bench_label, run_benches, validate_bench_json, BenchConfig,
    };
    let smoke = args.bool("smoke");
    let out = args.get("out").map(String::from);
    let label = match args.get("label") {
        Some(_) => args.u64_or("label", 0)?,
        None => next_bench_label(Path::new(".")),
    };
    args.finish()?;
    println!(
        "running {} benches (service_e2e + predcache_io + http_ingest + synth_tile + proto_framing)…",
        if smoke { "smoke" } else { "full" }
    );
    let doc = run_benches(BenchConfig { smoke }, label)?;
    validate_bench_json(&doc).map_err(|e| anyhow!("bench self-validation failed: {e}"))?;
    let svc = doc.get("benches")?.get("service_e2e")?;
    println!(
        "service_e2e: {:.0} tiles/s over {:.2}s wall ({} jobs)",
        svc.get("tiles_per_sec")?.as_f64()?,
        svc.get("wall_s")?.as_f64()?,
        svc.get("jobs")?.as_u64()?,
    );
    let pc = doc.get("benches")?.get("predcache_io")?;
    println!(
        "predcache_io: save {:.1} MB/s, load {:.1} MB/s",
        pc.get("save_mb_per_s")?.as_f64()?,
        pc.get("load_mb_per_s")?.as_f64()?,
    );
    let st = doc.get("benches")?.get("synth_tile")?;
    println!(
        "synth_tile: scalar {:.1} ns/px, renderer {:.1} ns/px ({:.2}x)",
        st.get("scalar_ns_per_px")?.as_f64()?,
        st.get("fast_ns_per_px")?.as_f64()?,
        st.get("speedup")?.as_f64()?,
    );
    let pf = doc.get("benches")?.get("proto_framing")?;
    println!(
        "proto_framing: json {:.0} ns/msg, binary {:.0} ns/msg ({:.2}x)",
        pf.get("json_ns_per_msg")?.as_f64()?,
        pf.get("binary_ns_per_msg")?.as_f64()?,
        pf.get("speedup")?.as_f64()?,
    );
    let path = out.unwrap_or_else(|| format!("BENCH_{label}.json"));
    std::fs::write(&path, doc.to_pretty())?;
    println!("wrote {path}");
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let model = model_kind(args)?;
    let fast = args.bool("fast");
    args.finish()?;

    println!("# PyramidAI full report (model={model:?}, fast={fast})");
    let ctx = Ctx::load(CtxConfig {
        model,
        ..Default::default()
    })?;

    // Tables 1-3
    if experiments::ctx::artifacts_dir().join("meta.json").exists() {
        let t12 = experiments::table12::run(!fast)?;
        experiments::table12::print_report(&t12)?;
    } else {
        println!("(artifacts/ missing — skipping Tables 1-2; run `make artifacts`)");
    }
    let t3 = experiments::table3::run(model, if fast { 10 } else { 100 }, 16)?;
    experiments::table3::print_report(&t3)?;

    // Fig 2 heatmaps
    let outputs = experiments::fig2::run(model)?;
    println!("\nFig 2 heatmaps written: {outputs:?}");

    // Figs 3-5
    experiments::fig345::fig3(&ctx)?;
    experiments::fig345::fig4(&ctx)?;
    experiments::fig345::fig5(&ctx)?;

    // Fig 6
    let workers = if fast {
        vec![1, 4, 12]
    } else {
        vec![1, 2, 4, 8, 12, 16, 24]
    };
    let rows = experiments::fig6::run(&ctx, &workers)?;
    experiments::fig6::print_report(&ctx, &rows)?;

    // Fig 7
    let wlist = if fast { vec![1, 4, 12] } else { vec![1, 2, 4, 8, 12] };
    let reps = if fast { 1 } else { 3 };
    let rows = experiments::fig7::run(
        &ctx,
        &wlist,
        reps,
        Duration::from_millis(if fast { 5 } else { 20 }),
    )?;
    experiments::fig7::print_report(&rows)?;

    // §4.6
    let rows = experiments::wsi46::run(&ctx)?;
    experiments::wsi46::print_report(&rows)?;

    println!("\nCSV outputs in bench_results/");
    Ok(())
}
