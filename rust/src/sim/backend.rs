//! The simulator's virtual workers behind the unified
//! [`ExecutionBackend`] API.
//!
//! [`SimBackend`] serves probabilities from a recorded [`ExecTree`]
//! (every analyzed tile's probability is in the tree) while accounting
//! per-worker load the way the §5.1 engine does: each dispatched chunk
//! lands on the least-loaded virtual worker, one tile = one time unit,
//! message latency neglected. Driving a `PyramidRun` through it
//! reconstructs the recorded tree exactly *and* yields the load profile a
//! chunk-granular distributed execution would have had — the engine's
//! tile-granular policies ([`super::engine`]) remain the reference for
//! the paper's Fig 6 sweep.

use std::collections::{HashMap, VecDeque};

use crate::pyramid::tree::ExecTree;
use crate::pyramid::{Completion, ExecutionBackend, FrontierRequest};
use crate::slide::tile::TileId;

/// Virtual-worker execution of frontier chunks over recorded
/// probabilities.
pub struct SimBackend {
    probs: HashMap<TileId, f32>,
    loads: Vec<usize>,
    ready: VecDeque<Completion>,
}

impl SimBackend {
    /// `tree` must be the recorded execution this backend will replay
    /// (same slide, same thresholds): every requested tile is looked up
    /// there. `workers` is the virtual cluster size.
    pub fn new(tree: &ExecTree, workers: usize) -> SimBackend {
        assert!(workers >= 1, "at least one virtual worker");
        let mut probs = HashMap::new();
        for lvl in &tree.nodes {
            for n in lvl {
                probs.insert(n.tile, n.prob);
            }
        }
        SimBackend {
            probs,
            loads: vec![0; workers],
            ready: VecDeque::new(),
        }
    }

    /// Tiles analyzed per virtual worker so far.
    pub fn per_worker(&self) -> &[usize] {
        &self.loads
    }

    /// Busiest worker's tile count — the §5.1 makespan proxy.
    pub fn makespan(&self) -> usize {
        self.loads.iter().copied().max().unwrap_or(0)
    }
}

impl ExecutionBackend for SimBackend {
    fn dispatch(&mut self, req: FrontierRequest) {
        // Least-loaded worker takes the chunk (ties → lowest id).
        let w = (0..self.loads.len())
            .min_by_key(|&w| (self.loads[w], w))
            .expect("workers >= 1");
        self.loads[w] += req.tiles.len();
        let probs: Vec<f32> = req
            .tiles
            .iter()
            .map(|t| {
                *self
                    .probs
                    .get(t)
                    .unwrap_or_else(|| panic!("tile {t} absent from recorded tree"))
            })
            .collect();
        self.ready.push_back(Completion { id: req.id, probs });
    }

    fn poll(&mut self, _block: bool) -> Option<Completion> {
        self.ready.pop_front()
    }

    fn in_flight(&self) -> usize {
        self.ready.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::oracle::OracleAnalyzer;
    use crate::pyramid::backend::run_on_backend;
    use crate::pyramid::driver::run_pyramidal;
    use crate::pyramid::tree::Thresholds;
    use crate::slide::pyramid::Slide;
    use crate::synth::slide_gen::{SlideKind, SlideSpec};

    fn recorded() -> (Slide, ExecTree, Thresholds) {
        let s = Slide::from_spec(SlideSpec::new(
            "simbk",
            93,
            32,
            16,
            3,
            64,
            SlideKind::LargeTumor,
        ));
        let thr = Thresholds::uniform(3, 0.35);
        let tree = run_pyramidal(&s, &OracleAnalyzer::new(1), &thr, 8);
        (s, tree, thr)
    }

    #[test]
    fn virtual_workers_rebuild_the_recorded_tree() {
        let (s, tree, thr) = recorded();
        for workers in [1usize, 4] {
            let mut backend = SimBackend::new(&tree, workers);
            let rebuilt = run_on_backend(
                s.id(),
                s.levels(),
                tree.initial.clone(),
                &thr,
                4,
                &mut backend,
            )
            .unwrap();
            assert_eq!(rebuilt.nodes, tree.nodes, "workers={workers}");
            // Conservation: every analyzed tile landed on some worker.
            assert_eq!(
                backend.per_worker().iter().sum::<usize>(),
                tree.total_analyzed()
            );
            assert!(backend.makespan() >= tree.total_analyzed() / workers);
        }
    }

    #[test]
    fn chunked_dispatch_spreads_load() {
        let (s, tree, thr) = recorded();
        let mut backend = SimBackend::new(&tree, 4);
        run_on_backend(s.id(), s.levels(), tree.initial.clone(), &thr, 2, &mut backend)
            .unwrap();
        let busy = backend.per_worker().iter().filter(|&&l| l > 0).count();
        assert!(busy >= 2, "chunks must spread over workers: {:?}", backend.per_worker());
    }
}
