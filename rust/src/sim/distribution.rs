//! Initial data-distribution strategies for the lowest-resolution tiles
//! (§5.1): Round-Robin, Random and Block.
//!
//! All three partition the same tile list (row-major over the lowest
//! level, i.e. sorted by location) among `w` workers; they differ in who
//! gets which tile, which matters because tumor density is spatially
//! heterogeneous.

use crate::slide::tile::TileId;
use crate::util::prng::Pcg32;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
/// Initial tile-distribution strategies (§5.2).
pub enum Distribution {
    /// Cyclic dispatch: tile i → worker i mod w.
    RoundRobin,
    /// Shuffle the list, then split into balanced contiguous blocks.
    Random,
    /// Location-sorted list split into balanced contiguous blocks — keeps
    /// spatial neighborhoods together (the paper shows this is the worst).
    Block,
}

impl Distribution {
    /// Every strategy, in sweep order.
    pub const ALL: [Distribution; 3] = [
        Distribution::RoundRobin,
        Distribution::Random,
        Distribution::Block,
    ];

    /// Stable name for tables/CSV.
    pub fn as_str(self) -> &'static str {
        match self {
            Distribution::RoundRobin => "round_robin",
            Distribution::Random => "random",
            Distribution::Block => "block",
        }
    }

    /// Inverse of [`Distribution::as_str`].
    pub fn from_str(s: &str) -> Option<Distribution> {
        match s {
            "round_robin" => Some(Distribution::RoundRobin),
            "random" => Some(Distribution::Random),
            "block" => Some(Distribution::Block),
            _ => None,
        }
    }

    /// Partition `tiles` (row-major / location-sorted) among `w` workers.
    /// Every tile is assigned to exactly one worker.
    pub fn assign(self, tiles: &[TileId], w: usize, seed: u64) -> Vec<Vec<TileId>> {
        assert!(w >= 1);
        let mut out = vec![Vec::with_capacity(tiles.len() / w + 1); w];
        match self {
            Distribution::RoundRobin => {
                for (i, &t) in tiles.iter().enumerate() {
                    out[i % w].push(t);
                }
            }
            Distribution::Random => {
                let mut shuffled = tiles.to_vec();
                Pcg32::new(seed).shuffle(&mut shuffled);
                balanced_blocks(&shuffled, &mut out);
            }
            Distribution::Block => {
                balanced_blocks(tiles, &mut out);
            }
        }
        out
    }
}

/// Split a list into `out.len()` contiguous blocks whose sizes differ by at
/// most one.
fn balanced_blocks(tiles: &[TileId], out: &mut [Vec<TileId>]) {
    let w = out.len();
    let n = tiles.len();
    let base = n / w;
    let extra = n % w;
    let mut idx = 0;
    for (k, bucket) in out.iter_mut().enumerate() {
        let take = base + usize::from(k < extra);
        bucket.extend_from_slice(&tiles[idx..idx + take]);
        idx += take;
    }
    debug_assert_eq!(idx, n);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::forall_explain;

    fn tiles(n: usize) -> Vec<TileId> {
        (0..n).map(|i| TileId::new(2, i % 16, i / 16)).collect()
    }

    #[test]
    fn every_tile_assigned_exactly_once_property() {
        forall_explain(
            7,
            300,
            |r| {
                (
                    r.usize_range(0, 200),
                    r.usize_range(1, 24),
                    r.next_u64(),
                    r.usize_range(0, 3),
                )
            },
            |&(n, w, seed, d)| {
                let dist = Distribution::ALL[d];
                let ts = tiles(n);
                let parts = dist.assign(&ts, w, seed);
                if parts.len() != w {
                    return Err(format!("{} partitions, want {w}", parts.len()));
                }
                let mut all: Vec<TileId> = parts.iter().flatten().copied().collect();
                all.sort();
                let mut want = ts.clone();
                want.sort();
                if all != want {
                    return Err("assignment is not a partition".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn balanced_sizes() {
        for dist in Distribution::ALL {
            let parts = dist.assign(&tiles(103), 12, 9);
            let sizes: Vec<usize> = parts.iter().map(|p| p.len()).collect();
            let min = *sizes.iter().min().unwrap();
            let max = *sizes.iter().max().unwrap();
            assert!(max - min <= 1, "{dist:?}: sizes {sizes:?}");
        }
    }

    #[test]
    fn round_robin_is_cyclic() {
        let ts = tiles(10);
        let parts = Distribution::RoundRobin.assign(&ts, 3, 0);
        assert_eq!(parts[0], vec![ts[0], ts[3], ts[6], ts[9]]);
        assert_eq!(parts[1], vec![ts[1], ts[4], ts[7]]);
    }

    #[test]
    fn block_keeps_contiguity() {
        let ts = tiles(12);
        let parts = Distribution::Block.assign(&ts, 3, 0);
        assert_eq!(parts[0], ts[0..4].to_vec());
        assert_eq!(parts[2], ts[8..12].to_vec());
    }

    #[test]
    fn random_is_seed_deterministic() {
        let ts = tiles(50);
        let a = Distribution::Random.assign(&ts, 4, 42);
        let b = Distribution::Random.assign(&ts, 4, 42);
        let c = Distribution::Random.assign(&ts, 4, 43);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn name_roundtrip() {
        for d in Distribution::ALL {
            assert_eq!(Distribution::from_str(d.as_str()), Some(d));
        }
    }
}
