//! Distributed-execution simulator (§5.1-5.3): initial data distributions
//! × load-balancing policies over recorded pyramidal execution trees.

pub mod distribution;
pub mod engine;

pub use distribution::Distribution;
pub use engine::{simulate, Policy, SimResult};
