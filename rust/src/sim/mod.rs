//! Distributed-execution simulator (§5.1-5.3): initial data distributions
//! × load-balancing policies over recorded pyramidal execution trees,
//! plus the virtual-worker [`SimBackend`] that drives the unified
//! `PyramidRun`/`ExecutionBackend` machinery.

pub mod backend;
pub mod distribution;
pub mod engine;

pub use backend::SimBackend;
pub use distribution::Distribution;
pub use engine::{simulate, Policy, SimResult};
