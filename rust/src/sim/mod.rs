//! Distributed-execution simulator (§5.1-5.3): initial data distributions
//! × load-balancing policies over recorded pyramidal execution trees,
//! the virtual-worker [`SimBackend`] that drives the unified
//! `PyramidRun`/`ExecutionBackend` machinery, and the multi-job workload
//! simulator ([`simulate_workload`]) that drives the *same*
//! [`crate::sched::SchedulingPolicy`] objects as the multi-slide service
//! scheduler.

/// Virtual-worker `ExecutionBackend` over a recorded tree.
pub mod backend;
/// Initial tile-distribution strategies (§5.2).
pub mod distribution;
/// The simulators: single-tree sweep and multi-job workload.
pub mod engine;

pub use backend::SimBackend;
pub use distribution::Distribution;
pub use engine::{
    simulate, simulate_workload, Policy, SimJobOutcome, SimJobSpec, SimResult, Straggler,
    WorkerFailure, WorkloadConfig, WorkloadResult,
};
