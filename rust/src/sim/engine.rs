//! Offline distributed-execution simulator (§5.1).
//!
//! Two simulators live here:
//!
//! * [`simulate`] — the paper's single-tree sweep: replays one recorded
//!   pyramidal execution tree under a worker count, an initial
//!   distribution and a tile-granular load-balancing policy
//!   ([`Policy`]), reporting per-worker tile loads (Fig 6). As in the
//!   paper, analysis-block time dominates and is level-independent
//!   (Table 3), so *the number of tiles analyzed by the busiest worker*
//!   is the makespan proxy, and message latency is neglected.
//! * [`simulate_workload`] — the multi-job scheduling simulator: a
//!   stream of jobs (tenants, priorities, arrivals, deadlines) dispatched
//!   over virtual workers by a [`SchedulingPolicy`] object — the *same*
//!   trait objects the multi-slide service scheduler drives
//!   ([`crate::service::scheduler`]), consulted at the same three points
//!   (admission, dispatch order, preemption). A policy conclusion drawn
//!   here is the same code path the real service executes, which is what
//!   makes the paper's "simulator conclusions transfer to the real
//!   cluster" claim structural. The `Distribution` strategies remain the
//!   initial-placement story; policies govern steady state.
//!
//! [`SchedulingPolicy`]: crate::sched::SchedulingPolicy

use std::collections::{HashMap, VecDeque};

use crate::pyramid::tree::{ExecTree, Thresholds};
use crate::pyramid::PyramidRun;
use crate::sched::{
    aged_rank, pick_admission, pick_preemption_victims, SchedCandidate, SchedContext,
    SchedulingPolicy,
};
use crate::slide::tile::TileId;
use crate::util::prng::Pcg32;

use super::distribution::Distribution;

/// Load-balancing policies (§5.2-5.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// No rebalancing: each worker exhausts the subtrees it was dealt.
    NoBalancing,
    /// Barrier after every resolution level; the next level's tiles are
    /// redistributed evenly (§5.2).
    SyncPerLevel,
    /// Synchronization-free random-victim work stealing (§5.3).
    WorkStealing,
    /// Oracle: perfectly even split of the total load (lower bound).
    OracleIdeal,
}

impl Policy {
    /// Every policy, in sweep order.
    pub const ALL: [Policy; 4] = [
        Policy::NoBalancing,
        Policy::SyncPerLevel,
        Policy::WorkStealing,
        Policy::OracleIdeal,
    ];

    /// Stable name for tables/CSV.
    pub fn as_str(self) -> &'static str {
        match self {
            Policy::NoBalancing => "none",
            Policy::SyncPerLevel => "sync",
            Policy::WorkStealing => "steal",
            Policy::OracleIdeal => "ideal",
        }
    }

    /// Inverse of [`Policy::as_str`].
    pub fn from_str(s: &str) -> Option<Policy> {
        match s {
            "none" => Some(Policy::NoBalancing),
            "sync" => Some(Policy::SyncPerLevel),
            "steal" => Some(Policy::WorkStealing),
            "ideal" => Some(Policy::OracleIdeal),
            _ => None,
        }
    }
}

/// Outcome of one simulated distributed execution.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Tiles analyzed per worker.
    pub per_worker: Vec<usize>,
    /// Simulated time units (one tile analysis = one unit). For the
    /// synchronized policy this includes barrier effects
    /// (Σ per-level maxima); for the others it is the busiest worker's
    /// tile count (steals are instantaneous).
    pub makespan: usize,
    /// Successful steals (work-stealing policy only).
    pub steals: usize,
}

impl SimResult {
    /// Tile count of the busiest worker (the makespan proxy).
    pub fn max_tiles(&self) -> usize {
        self.per_worker.iter().copied().max().unwrap_or(0)
    }

    /// Total tiles analyzed across all workers.
    pub fn total(&self) -> usize {
        self.per_worker.iter().sum()
    }
}

/// Zoom decisions recorded in a tree, keyed by tile.
fn zoom_map(tree: &ExecTree) -> HashMap<TileId, bool> {
    let mut m = HashMap::new();
    for lvl in &tree.nodes {
        for n in lvl {
            m.insert(n.tile, n.zoom);
        }
    }
    m
}

/// Simulate one execution.
pub fn simulate(
    tree: &ExecTree,
    workers: usize,
    dist: Distribution,
    policy: Policy,
    seed: u64,
) -> SimResult {
    assert!(workers >= 1);
    let zoom = zoom_map(tree);
    let initial = dist.assign(&tree.initial, workers, seed);
    match policy {
        Policy::NoBalancing => sim_no_balancing(&zoom, initial),
        Policy::SyncPerLevel => sim_sync(&zoom, initial, workers),
        Policy::WorkStealing => sim_steal(&zoom, initial, workers, seed),
        Policy::OracleIdeal => {
            let total = tree.total_analyzed();
            let base = total / workers;
            let extra = total % workers;
            let per_worker: Vec<usize> = (0..workers)
                .map(|w| base + usize::from(w < extra))
                .collect();
            let makespan = *per_worker.iter().max().unwrap();
            SimResult {
                per_worker,
                makespan,
                steals: 0,
            }
        }
    }
}

/// Size of the subtree rooted at `t` within the recorded execution.
fn subtree_size(zoom: &HashMap<TileId, bool>, t: TileId) -> usize {
    // Tiles not in the map were never analyzed (pruned initial tiles do
    // not occur — initial tiles are always analyzed).
    let mut size = 1;
    if zoom.get(&t).copied().unwrap_or(false) {
        for c in t.children() {
            if zoom.contains_key(&c) {
                size += subtree_size(zoom, c);
            }
        }
    }
    size
}

fn sim_no_balancing(zoom: &HashMap<TileId, bool>, initial: Vec<Vec<TileId>>) -> SimResult {
    let per_worker: Vec<usize> = initial
        .iter()
        .map(|tiles| tiles.iter().map(|&t| subtree_size(zoom, t)).sum())
        .collect();
    let makespan = per_worker.iter().copied().max().unwrap_or(0);
    SimResult {
        per_worker,
        makespan,
        steals: 0,
    }
}

fn sim_sync(
    zoom: &HashMap<TileId, bool>,
    initial: Vec<Vec<TileId>>,
    workers: usize,
) -> SimResult {
    let mut per_worker = vec![0usize; workers];
    let mut makespan = 0usize;
    let mut current = initial;
    loop {
        let mut level_counts = vec![0usize; workers];
        let mut next: Vec<TileId> = Vec::new();
        for (w, tiles) in current.iter().enumerate() {
            level_counts[w] += tiles.len();
            for &t in tiles {
                if zoom.get(&t).copied().unwrap_or(false) {
                    for c in t.children() {
                        if zoom.contains_key(&c) {
                            next.push(c);
                        }
                    }
                }
            }
        }
        for w in 0..workers {
            per_worker[w] += level_counts[w];
        }
        makespan += level_counts.iter().copied().max().unwrap_or(0);
        if next.is_empty() {
            break;
        }
        // Barrier: redistribute the next level evenly (round-robin).
        let mut redistributed = vec![Vec::new(); workers];
        for (i, t) in next.into_iter().enumerate() {
            redistributed[i % workers].push(t);
        }
        current = redistributed;
    }
    SimResult {
        per_worker,
        makespan,
        steals: 0,
    }
}

fn sim_steal(
    zoom: &HashMap<TileId, bool>,
    initial: Vec<Vec<TileId>>,
    workers: usize,
    seed: u64,
) -> SimResult {
    let mut rng = Pcg32::new(seed ^ 0x57EA_1000);
    let mut queues: Vec<VecDeque<TileId>> = initial
        .into_iter()
        .map(|tiles| tiles.into_iter().collect())
        .collect();
    let mut per_worker = vec![0usize; workers];
    let mut steals = 0usize;
    let mut makespan = 0usize;

    loop {
        if queues.iter().all(|q| q.is_empty()) {
            break;
        }
        makespan += 1;
        // Analysis phase: every busy worker processes one tile.
        let mut spawned: Vec<Vec<TileId>> = vec![Vec::new(); workers];
        let mut idle: Vec<usize> = Vec::new();
        for w in 0..workers {
            match queues[w].pop_front() {
                Some(t) => {
                    per_worker[w] += 1;
                    if zoom.get(&t).copied().unwrap_or(false) {
                        for c in t.children() {
                            if zoom.contains_key(&c) {
                                spawned[w].push(c);
                            }
                        }
                    }
                }
                None => idle.push(w),
            }
        }
        for (w, sp) in spawned.into_iter().enumerate() {
            queues[w].extend(sp);
        }
        // Steal phase: each idle worker targets one random victim with
        // more than one task and takes one (message time neglected, §5.1).
        for &thief in &idle {
            let candidates: Vec<usize> = (0..workers)
                .filter(|&v| v != thief && queues[v].len() > 1)
                .collect();
            if let Some(&victim) = rng.choose(&candidates) {
                if let Some(task) = queues[victim].pop_front() {
                    queues[thief].push_back(task);
                    steals += 1;
                }
            }
        }
    }
    SimResult {
        per_worker,
        makespan,
        steals,
    }
}

/// One job of a simulated multi-tenant workload: a recorded execution
/// tree re-driven as a [`PyramidRun`] (probabilities come from the tree,
/// zoom decisions from `thresholds` — the pair that produced the
/// recording), plus the scheduling attributes a policy ranks on. All
/// times are virtual ticks: one tile analysis = one tick on one worker.
#[derive(Debug, Clone)]
pub struct SimJobSpec {
    /// Fair-share accounting key.
    pub tenant: String,
    /// Numeric priority (higher = more urgent), as
    /// [`crate::service::Priority::rank`] produces.
    pub priority_rank: u8,
    /// Tick at which the job enters the admission queue.
    pub arrival: u64,
    /// Absolute deadline tick (EDF input); `None` = none.
    pub deadline: Option<u64>,
    /// The recorded execution to re-drive.
    pub tree: ExecTree,
    /// The thresholds that produced the recording.
    pub thresholds: Thresholds,
}

/// One injected worker fault for [`simulate_workload`]: the §10
/// failure-model counterpart of a machine rebooting mid-run. At tick
/// `at` the worker dies — its in-flight chunks are lost and requeued
/// into their [`PyramidRun`]s (re-dispatched to survivors by the
/// ordinary pump) — and it takes no new work until `rejoin` (or ever,
/// with `None`). The simulator predicts *recovery overhead* the same
/// way it predicts scheduling: results stay byte-identical; only the
/// makespan (and re-dispatched tile count) grows.
/// One injected gray worker for [`simulate_workload`]: the simulator
/// counterpart of a machine that is slow but alive (thermal throttling,
/// a half-duplex link, a failing disk) — the §16 gray-failure model.
/// While the window is open, every chunk the worker *starts* takes
/// `factor`× its normal service time. The worker never dies, so none of
/// the recovery machinery fires; only placement and the makespan feel
/// it. Results stay byte-identical — a straggler can slow a run, never
/// corrupt it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Straggler {
    /// Index of the slowed virtual worker.
    pub worker: usize,
    /// First tick of the slow window (a chunk starting at `from` is
    /// already slow).
    pub from: u64,
    /// Tick the worker recovers (chunks starting at `until` run at full
    /// speed again); `None` = gray for the rest of the run.
    pub until: Option<u64>,
    /// Integer service-time multiplier (values `< 1` are read as 1).
    pub factor: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkerFailure {
    /// Index of the virtual worker that dies.
    pub worker: usize,
    /// Tick of the crash. A chunk finishing exactly at `at` survives;
    /// anything later on this worker is lost.
    pub at: u64,
    /// Tick the worker rejoins (must be `> at`); `None` = never.
    pub rejoin: Option<u64>,
}

/// Simulator counterpart of the service's scheduler knobs.
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    /// Virtual workers (one tile = one tick each).
    pub workers: usize,
    /// Running-set size (jobs in flight at once).
    pub max_in_flight: usize,
    /// Frontier request granularity (0 = whole frontier per request).
    pub chunk: usize,
    /// Allow the policy to park running jobs at frontier boundaries.
    pub preempt: bool,
    /// Starvation aging for parked jobs, in virtual ticks per rank step
    /// (the service's [`crate::service::ServiceConfig::park_aging`] in
    /// tick units): every `park_aging` ticks of parked time raise a
    /// parked job's effective priority rank by one, and the earned boost
    /// freezes in on resume. `0` disables aging.
    pub park_aging: u64,
    /// Injected worker faults (§10 failure model). A schedule that
    /// leaves no worker alive (and none rejoining) while work remains
    /// cannot drain and panics — leave capacity.
    pub failures: Vec<WorkerFailure>,
    /// Injected leader failovers (§15): at each tick the leader's entire
    /// dispatch state is discarded — every in-flight chunk dies with the
    /// old leader's pending map and every running job requeues *all* its
    /// outstanding work wholesale, exactly the service's
    /// `Event::LeaderFailover` recovery. Workers survive (they re-Hello
    /// the standby); only already-dealt work is lost. Results stay
    /// byte-identical; makespan and the requeue counters grow.
    pub leader_failures: Vec<u64>,
    /// Injected gray workers (§16): slow-but-alive windows that stretch
    /// chunk service time without tripping the failure model.
    pub stragglers: Vec<Straggler>,
}

impl Default for WorkloadConfig {
    fn default() -> WorkloadConfig {
        WorkloadConfig {
            workers: 4,
            max_in_flight: 4,
            chunk: 16,
            preempt: false,
            park_aging: 0,
            failures: Vec::new(),
            leader_failures: Vec::new(),
            stragglers: Vec::new(),
        }
    }
}

/// Terminal record of one simulated job.
#[derive(Debug, Clone)]
pub struct SimJobOutcome {
    /// Tick the job left the queue for the running set (the expiry tick
    /// for expired jobs, which never ran).
    pub admitted_at: u64,
    /// Tick its last chunk completed (the expiry tick for expired jobs).
    pub completed_at: u64,
    /// Tiles dispatched for the job (lost attempts included).
    pub tiles: usize,
    /// Frontier-boundary preemptions suffered (actual suspensions).
    pub preemptions: usize,
    /// The deadline lapsed while the job waited in queue; it was dropped
    /// at admission without running — the same `Expired` semantics the
    /// service applies. `tree` is empty for such jobs.
    pub expired: bool,
    /// The rebuilt execution tree — byte-identical to `SimJobSpec::tree`
    /// no matter how the policy interleaved, parked or resumed the job
    /// (empty for expired jobs).
    pub tree: ExecTree,
}

/// Outcome of one simulated workload.
#[derive(Debug, Clone)]
pub struct WorkloadResult {
    /// Per-job outcomes, indexed like the input slice.
    pub outcomes: Vec<SimJobOutcome>,
    /// Job indices in completion order — the scheduling fingerprint the
    /// service reproduces on the same workload. Expired jobs never
    /// complete and are not listed.
    pub completion_order: Vec<usize>,
    /// Tiles *completed* per worker (chunks lost to an injected failure
    /// count where their retry finished, so the sum always equals the
    /// total analyzed).
    pub per_worker: Vec<usize>,
    /// Tick the last chunk completed.
    pub makespan: u64,
    /// Frontier-boundary preemptions across all jobs.
    pub preemptions: usize,
    /// Chunks lost to injected worker failures and requeued — the
    /// recovery-overhead counter ([`WorkerFailure`]).
    pub requeued_chunks: usize,
    /// Virtual-time metrics snapshot from the sim's scoped registry. The
    /// counter names (`sched.chunks_dealt`, `sched.chunks_stolen`,
    /// `sched.chunks_requeued`, ...) match the service scheduler's
    /// registry exactly, so sim and service snapshots are directly
    /// comparable on the same workload; histograms are in ticks, not µs.
    pub metrics: crate::obs::MetricsSnapshot,
}

/// Internal per-job state of the workload simulator.
struct SimJob {
    /// Service-style 1-based id (deterministic FIFO tiebreak, matching
    /// the admission queue's id assignment).
    id: u64,
    probs: HashMap<TileId, f32>,
    run: Option<PyramidRun>,
    admitted_at: u64,
    tiles: usize,
    preemptions: usize,
    /// In-flight chunk count (the service's `dispatched`).
    dispatched: usize,
    parking: bool,
    /// Tick of the last park transition (aging clock while Parked).
    parked_at: u64,
    /// Rank boost frozen in at resume (the service's `RunningJob::boost`).
    boost: u8,
    state: SimState,
}

#[derive(PartialEq)]
enum SimState {
    NotArrived,
    Waiting,
    Running,
    Parked,
    Done,
}

/// A dispatched chunk travelling through virtual time.
struct InFlightChunk {
    /// Tick the chunk was dealt (virtual-latency histogram input).
    fired: u64,
    finish: u64,
    /// Dispatch sequence number: deterministic tiebreak for chunks
    /// finishing at the same tick.
    seq: u64,
    job: usize,
    /// Virtual worker executing the chunk (failure-injection target).
    worker: usize,
    req: crate::pyramid::RequestId,
    probs: Vec<f32>,
}

/// Simulate a multi-job workload under a shared [`SchedulingPolicy`].
///
/// The loop mirrors the service scheduler event loop step for step —
/// admission over the union of waiting and parked jobs (quota-gated,
/// policy-ranked), dispatch of pending frontier requests in policy order
/// with live per-tenant usage accounting, and (with
/// [`WorkloadConfig::preempt`]) parking the policy-worst preemptible
/// running job at its next frontier boundary. Chunks land on the
/// least-loaded *live* virtual worker and take one tick per tile;
/// message latency is neglected (§5.1). Injected faults
/// ([`WorkloadConfig::failures`]) kill a worker's in-flight chunks —
/// their spans are requeued into the owning [`PyramidRun`] and
/// re-dispatched, the same recovery path the real cluster drives — so
/// the simulator predicts recovery overhead without ever changing a
/// result tree. Fully deterministic: same workload + same policy + same
/// fault schedule ⇒ same trace.
pub fn simulate_workload(
    jobs: &[SimJobSpec],
    policy: &dyn SchedulingPolicy,
    cfg: &WorkloadConfig,
) -> WorkloadResult {
    assert!(cfg.workers >= 1, "at least one virtual worker");
    for f in &cfg.failures {
        assert!(
            f.worker < cfg.workers,
            "failure names worker {} of {}",
            f.worker,
            cfg.workers
        );
        if let Some(r) = f.rejoin {
            assert!(r > f.at, "rejoin tick must be after the failure tick");
        }
    }
    // Scoped virtual-time registry: same counter names as the service
    // scheduler's, so the parity test can compare totals directly.
    let registry = crate::obs::Registry::new();
    let m_admitted = registry.counter("sched.jobs_admitted");
    let m_parked = registry.counter("sched.jobs_parked");
    let m_resumed = registry.counter("sched.jobs_resumed");
    let m_dealt = registry.counter("sched.chunks_dealt");
    let m_requeued = registry.counter("sched.chunks_requeued");
    let m_leader_failovers = registry.counter("sched.leader_failovers");
    registry.counter("sched.chunks_stolen");
    let m_latency = registry.histogram("sched.chunk_latency_ticks");
    let mut fails: Vec<(u64, usize)> = cfg.failures.iter().map(|f| (f.at, f.worker)).collect();
    fails.sort_unstable();
    let mut lfails: Vec<u64> = cfg.leader_failures.clone();
    lfails.sort_unstable();
    let mut li = 0usize;
    let mut rejoins: Vec<(u64, usize)> = cfg
        .failures
        .iter()
        .filter_map(|f| f.rejoin.map(|r| (r, f.worker)))
        .collect();
    rejoins.sort_unstable();
    let (mut fi, mut ri) = (0usize, 0usize);
    let mut failed = vec![false; cfg.workers];
    let mut requeued_chunks = 0usize;
    let slots = cfg.max_in_flight.max(1);
    let mut sim: Vec<SimJob> = jobs
        .iter()
        .enumerate()
        .map(|(i, j)| SimJob {
            id: i as u64 + 1,
            probs: zoom_probs(&j.tree),
            run: None,
            admitted_at: 0,
            tiles: 0,
            preemptions: 0,
            dispatched: 0,
            parking: false,
            parked_at: 0,
            boost: 0,
            state: SimState::NotArrived,
        })
        .collect();
    let mut usage: HashMap<String, u64> = HashMap::new();
    let mut worker_free = vec![0u64; cfg.workers];
    let mut per_worker = vec![0usize; cfg.workers];
    let mut in_flight: Vec<InFlightChunk> = Vec::new();
    // Pulled-but-undispatched requests. Persists across iterations so
    // work can wait out a window with every worker down.
    let mut pending: Vec<(usize, crate::pyramid::FrontierRequest)> = Vec::new();
    let mut seq = 0u64;
    let mut now = 0u64;
    let mut completion_order = Vec::new();
    let mut outcomes: Vec<Option<SimJobOutcome>> = jobs.iter().map(|_| None).collect();
    let mut total_preemptions = 0usize;
    let mut makespan = 0u64;

    // Effective rank mirrors the service's tuple helpers: nominal rank
    // plus the frozen boost, and — while parked — one more rank per
    // elapsed aging interval.
    let cand_of = |i: usize, sim: &[SimJob], now: u64| {
        let base = jobs[i].priority_rank.saturating_add(sim[i].boost);
        let rank = if sim[i].state == SimState::Parked {
            aged_rank(base, now.saturating_sub(sim[i].parked_at), cfg.park_aging)
        } else {
            base
        };
        SchedCandidate {
            job: sim[i].id,
            priority_rank: rank,
            tenant: &jobs[i].tenant,
            arrival: jobs[i].arrival,
            deadline: jobs[i].deadline,
        }
    };

    loop {
        // Arrivals up to the current tick join the waiting set.
        for (i, s) in sim.iter_mut().enumerate() {
            if s.state == SimState::NotArrived && jobs[i].arrival <= now {
                s.state = SimState::Waiting;
            }
        }
        let running_count =
            |sim: &[SimJob]| sim.iter().filter(|s| s.state == SimState::Running).count();
        let tenants_running = |sim: &[SimJob]| {
            let mut m: HashMap<String, usize> = HashMap::new();
            for (i, s) in sim.iter().enumerate() {
                if s.state == SimState::Running {
                    *m.entry(jobs[i].tenant.clone()).or_insert(0) += 1;
                }
            }
            m
        };
        // Admission: waiting and parked jobs compete for free slots.
        loop {
            if running_count(&sim) >= slots {
                break;
            }
            let running_per_tenant = tenants_running(&sim);
            let ctx = SchedContext {
                usage: &usage,
                running_per_tenant: &running_per_tenant,
                now,
            };
            let waiting: Vec<usize> = (0..sim.len())
                .filter(|&i| matches!(sim[i].state, SimState::Waiting | SimState::Parked))
                .collect();
            let cands: Vec<SchedCandidate<'_>> =
                waiting.iter().map(|&i| cand_of(i, &sim, now)).collect();
            let Some(sel) = pick_admission(policy, &cands, &ctx) else {
                break;
            };
            let i = waiting[sel];
            if sim[i].state == SimState::Waiting {
                // Mirror of the service's admission expiry: a queued job
                // whose deadline lapsed is dropped here instead of
                // running late. (Parked jobs already ran; no expiry.)
                if jobs[i].deadline.map_or(false, |d| now > d) {
                    sim[i].state = SimState::Done;
                    outcomes[i] = Some(SimJobOutcome {
                        admitted_at: now,
                        completed_at: now,
                        tiles: 0,
                        preemptions: sim[i].preemptions,
                        expired: true,
                        tree: ExecTree::new(
                            jobs[i].tree.slide_id.clone(),
                            jobs[i].tree.levels,
                        ),
                    });
                    continue;
                }
                sim[i].admitted_at = now;
                m_admitted.inc();
                sim[i].run = Some(PyramidRun::new(
                    jobs[i].tree.slide_id.as_str(),
                    jobs[i].tree.levels,
                    jobs[i].tree.initial.clone(),
                    jobs[i].thresholds.clone(),
                    cfg.chunk,
                ));
            }
            if sim[i].state == SimState::Parked {
                m_resumed.inc();
                // Freeze the age earned while parked into the boost, the
                // same freeze the service applies on resume.
                sim[i].boost = aged_rank(
                    sim[i].boost,
                    now.saturating_sub(sim[i].parked_at),
                    cfg.park_aging,
                );
            }
            sim[i].state = SimState::Running;
            sim[i].parking = false;
        }
        // Preemption: pair each preempting waiter with the policy-worst
        // preemptible running job; every picked victim parks at its next
        // frontier boundary. Suspensions already draining count against
        // the pairing budget (the first `parking` pairs are treated as
        // satisfied by them), exactly like the service's maybe_preempt.
        if cfg.preempt && running_count(&sim) >= slots {
            let parking = sim
                .iter()
                .filter(|s| s.state == SimState::Running && s.parking)
                .count();
            let running_per_tenant = tenants_running(&sim);
            let ctx = SchedContext {
                usage: &usage,
                running_per_tenant: &running_per_tenant,
                now,
            };
            let waiting: Vec<usize> = (0..sim.len())
                .filter(|&i| {
                    // Lapsed-deadline waiters will be dropped at
                    // admission; they must not park a healthy job first
                    // (same filter as the service's maybe_preempt).
                    match sim[i].state {
                        SimState::Waiting => jobs[i].deadline.map_or(true, |d| now <= d),
                        SimState::Parked => true,
                        _ => false,
                    }
                })
                .collect();
            let waiting_cands: Vec<SchedCandidate<'_>> =
                waiting.iter().map(|&i| cand_of(i, &sim, now)).collect();
            let running_idx: Vec<usize> = (0..sim.len())
                .filter(|&i| sim[i].state == SimState::Running && !sim[i].parking)
                .collect();
            let running_cands: Vec<SchedCandidate<'_>> =
                running_idx.iter().map(|&i| cand_of(i, &sim, now)).collect();
            let pairs = pick_preemption_victims(
                policy,
                &waiting_cands,
                &running_cands,
                &ctx,
                parking + running_cands.len(),
            );
            for (_, v) in pairs.into_iter().skip(parking) {
                // Counted at the actual park transition, not here — a
                // victim that completes while draining was never really
                // suspended.
                sim[running_idx[v]].parking = true;
            }
        }
        // Pump + dispatch: drain every available request of every
        // healthy running job, in policy order, with live usage
        // accounting — chunks land on the least-loaded live virtual
        // worker. With every worker down, requests wait in `pending`
        // for a rejoin.
        for i in 0..sim.len() {
            if sim[i].state != SimState::Running || sim[i].parking {
                continue;
            }
            let run = sim[i].run.as_mut().expect("running implies run");
            while let Some(req) = run.next_request() {
                pending.push((i, req));
            }
        }
        {
            let running_per_tenant = tenants_running(&sim);
            while !pending.is_empty() {
                let Some(w) = (0..cfg.workers)
                    .filter(|&w| !failed[w])
                    .min_by_key(|&w| (worker_free[w], w))
                else {
                    break; // every worker down: hold work for a rejoin
                };
                let ctx = SchedContext {
                    usage: &usage,
                    running_per_tenant: &running_per_tenant,
                    now,
                };
                let cands: Vec<SchedCandidate<'_>> =
                    pending.iter().map(|&(i, _)| cand_of(i, &sim, now)).collect();
                let sel = policy.select(&cands, &ctx).expect("nonempty pending");
                let (i, req) = pending.remove(sel);
                sim[i].tiles += req.tiles.len();
                sim[i].dispatched += 1;
                m_dealt.inc();
                *usage.entry(jobs[i].tenant.clone()).or_default() += req.tiles.len() as u64;
                let start = worker_free[w].max(now);
                // A gray window stretches the whole chunk by the largest
                // matching factor — service time, not correctness.
                let slow = straggler_factor(&cfg.stragglers, w, start);
                let finish = start + (req.tiles.len() as u64).saturating_mul(slow);
                worker_free[w] = finish;
                let probs: Vec<f32> = req
                    .tiles
                    .iter()
                    .map(|t| {
                        *sim[i]
                            .probs
                            .get(t)
                            .unwrap_or_else(|| panic!("tile {t} absent from recorded tree"))
                    })
                    .collect();
                in_flight.push(InFlightChunk {
                    fired: now,
                    finish,
                    seq,
                    job: i,
                    worker: w,
                    req: req.id,
                    probs,
                });
                seq += 1;
            }
        }
        // A job admitted with an empty initial set is complete without
        // ever dispatching (mirrors the service's immediate finalize).
        let instant_done: Vec<usize> = (0..sim.len())
            .filter(|&i| {
                sim[i].state == SimState::Running
                    && sim[i].dispatched == 0
                    && sim[i].run.as_ref().is_some_and(|r| r.is_complete())
            })
            .collect();
        let mut progressed = !instant_done.is_empty();
        for i in instant_done {
            finish_job(i, now, &mut sim, &mut outcomes, &mut completion_order);
        }
        // Mirror of the service's settle(): a parking job with nothing in
        // flight — and no undispatched work stranded by an all-workers-
        // down window — parks right away.
        for i in 0..sim.len() {
            let stranded = pending.iter().any(|&(j, _)| j == i);
            let s = &mut sim[i];
            if s.state == SimState::Running && s.parking && s.dispatched == 0 && !stranded {
                s.state = SimState::Parked;
                s.parking = false;
                s.parked_at = now;
                s.preemptions += 1;
                total_preemptions += 1;
                m_parked.inc();
                progressed = true;
            }
        }
        if !progressed {
            // Advance virtual time to the next event — the earliest of
            // the next chunk completion, worker rejoin, worker failure
            // and job arrival. At equal ticks completions land first (a
            // chunk finishing exactly at a death tick survives), then
            // rejoins, then deaths, then arrivals (an arriving job must
            // be admitted at its arrival tick, as in the service).
            let next_completion = in_flight
                .iter()
                .enumerate()
                .min_by_key(|(_, c)| (c.finish, c.seq))
                .map(|(pos, _)| pos);
            let next_arrival = (0..sim.len())
                .filter(|&i| sim[i].state == SimState::NotArrived)
                .map(|i| jobs[i].arrival)
                .min();
            let mut events: Vec<(u64, u8)> = Vec::new();
            if let Some(pos) = next_completion {
                events.push((in_flight[pos].finish, 0));
            }
            if let Some(&(at, _)) = rejoins.get(ri) {
                events.push((at, 1));
            }
            if let Some(&(at, _)) = fails.get(fi) {
                events.push((at, 2));
            }
            if let Some(&at) = lfails.get(li) {
                events.push((at, 3));
            }
            if let Some(at) = next_arrival {
                events.push((at, 4));
            }
            match events.into_iter().min() {
                Some((_, 0)) => {
                    let pos = next_completion.expect("rank 0 implies a completion");
                    let chunk = in_flight.remove(pos);
                    let i = chunk.job;
                    now = now.max(chunk.finish);
                    makespan = makespan.max(chunk.finish);
                    m_latency.record(chunk.finish - chunk.fired);
                    per_worker[chunk.worker] += chunk.probs.len();
                    sim[i].dispatched -= 1;
                    sim[i]
                        .run
                        .as_mut()
                        .expect("in-flight implies run")
                        .feed(chunk.req, chunk.probs)
                        .expect("recorded probabilities always fit");
                    let run_done = sim[i].run.as_ref().is_some_and(|r| r.is_complete());
                    if run_done && sim[i].dispatched == 0 {
                        finish_job(i, now, &mut sim, &mut outcomes, &mut completion_order);
                    } else if sim[i].parking && sim[i].dispatched == 0 && !run_done {
                        // Suspension point: every issued chunk has been
                        // fed — the run sits exactly at a level-frontier
                        // boundary.
                        sim[i].state = SimState::Parked;
                        sim[i].parking = false;
                        sim[i].parked_at = now;
                        sim[i].preemptions += 1;
                        total_preemptions += 1;
                        m_parked.inc();
                    }
                    progressed = true;
                }
                Some((at, 1)) => {
                    let (_, w) = rejoins[ri];
                    ri += 1;
                    // Only a worker that is actually down rejoins — a
                    // stale rejoin (its death was skipped as a duplicate
                    // of an overlapping failure window) must not rewind
                    // worker_free under a live worker's feet.
                    if failed[w] {
                        failed[w] = false;
                        worker_free[w] = at;
                    }
                    now = now.max(at);
                    progressed = true;
                }
                Some((at, 2)) => {
                    let (_, w) = fails[fi];
                    fi += 1;
                    if !failed[w] {
                        failed[w] = true;
                        worker_free[w] = at;
                        // The dead worker's unfinished chunks are lost:
                        // hand their spans back to the owning runs — the
                        // pump re-dispatches them to survivors, exactly
                        // the real leader's resubmission path.
                        let mut keep = Vec::with_capacity(in_flight.len());
                        for c in in_flight.drain(..) {
                            if c.worker == w && c.finish > at {
                                sim[c.job].dispatched -= 1;
                                requeued_chunks += 1;
                                m_requeued.inc();
                                sim[c.job]
                                    .run
                                    .as_mut()
                                    .expect("in-flight implies run")
                                    .requeue(c.req)
                                    .expect("killed chunk was outstanding");
                            } else {
                                keep.push(c);
                            }
                        }
                        in_flight = keep;
                    }
                    now = now.max(at);
                    progressed = true;
                }
                Some((at, 3)) => {
                    li += 1;
                    m_leader_failovers.inc();
                    // The leader's dispatch state dies wholesale: every
                    // in-flight chunk was tracked only in the old
                    // leader's pending map, and every issued-but-
                    // undispatched request holds an id the requeue below
                    // invalidates. Mirror of Event::LeaderFailover.
                    in_flight.clear();
                    pending.clear();
                    let mut lost = 0usize;
                    for s in sim.iter_mut() {
                        if s.state != SimState::Running {
                            continue;
                        }
                        if let Some(run) = s.run.as_mut() {
                            lost += run.requeue_all_outstanding();
                        }
                        s.dispatched = 0;
                    }
                    requeued_chunks += lost;
                    m_requeued.add(lost as u64);
                    now = now.max(at);
                    progressed = true;
                }
                Some((at, _)) => {
                    now = now.max(at);
                    progressed = true;
                }
                None => {}
            }
        }
        if !progressed {
            break; // no running work, no arrivals, nothing in flight
        }
        if sim.iter().all(|s| s.state == SimState::Done) {
            break;
        }
    }
    assert!(
        sim.iter().all(|s| s.state == SimState::Done),
        "workload drained every job"
    );
    let outcomes: Vec<SimJobOutcome> =
        outcomes.into_iter().map(|o| o.expect("job done")).collect();
    // Virtual-time analogues of the service's queue-wait / run-time
    // histograms (ticks instead of µs).
    let queue_wait = registry.histogram("sched.queue_wait_ticks");
    let run_time = registry.histogram("sched.run_time_ticks");
    for (i, o) in outcomes.iter().enumerate() {
        if !o.expired {
            queue_wait.record(o.admitted_at.saturating_sub(jobs[i].arrival));
            run_time.record(o.completed_at.saturating_sub(o.admitted_at));
        }
    }
    WorkloadResult {
        outcomes,
        completion_order,
        per_worker,
        makespan,
        preemptions: total_preemptions,
        requeued_chunks,
        metrics: registry.snapshot(),
    }
}

/// Probabilities of every analyzed tile in a recorded tree.
fn zoom_probs(tree: &ExecTree) -> HashMap<TileId, f32> {
    let mut m = HashMap::new();
    for lvl in &tree.nodes {
        for n in lvl {
            m.insert(n.tile, n.prob);
        }
    }
    m
}

/// The service-time multiplier for a chunk starting on worker `w` at
/// tick `start`: the largest factor among open gray windows, 1 when
/// none match.
fn straggler_factor(stragglers: &[Straggler], w: usize, start: u64) -> u64 {
    stragglers
        .iter()
        .filter(|s| s.worker == w && start >= s.from && s.until.map_or(true, |u| start < u))
        .map(|s| s.factor.max(1))
        .max()
        .unwrap_or(1)
}

fn finish_job(
    i: usize,
    now: u64,
    sim: &mut [SimJob],
    outcomes: &mut [Option<SimJobOutcome>],
    completion_order: &mut Vec<usize>,
) {
    let s = &mut sim[i];
    s.state = SimState::Done;
    let tree = s.run.take().expect("finished job ran").finish();
    outcomes[i] = Some(SimJobOutcome {
        admitted_at: s.admitted_at,
        completed_at: now,
        tiles: s.tiles,
        preemptions: s.preemptions,
        expired: false,
        tree,
    });
    completion_order.push(i);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::oracle::OracleAnalyzer;
    use crate::pyramid::driver::run_pyramidal;
    use crate::slide::pyramid::Slide;
    use crate::synth::slide_gen::{SlideKind, SlideSpec};
    use crate::util::quickcheck::forall_explain;

    fn tree(seed: u64) -> ExecTree {
        let s = Slide::from_spec(SlideSpec::new(
            "sim",
            seed,
            32,
            16,
            3,
            64,
            SlideKind::LargeTumor,
        ));
        run_pyramidal(&s, &OracleAnalyzer::new(1), &Thresholds::uniform(3, 0.35), 32)
    }

    #[test]
    fn conservation_all_policies_all_distributions() {
        let t = tree(60);
        let total = t.total_analyzed();
        forall_explain(
            3,
            60,
            |r| {
                (
                    r.usize_range(1, 25),
                    r.usize_range(0, 3),
                    r.usize_range(0, 4),
                    r.next_u64(),
                )
            },
            |&(w, d, p, seed)| {
                let res = simulate(&t, w, Distribution::ALL[d], Policy::ALL[p], seed);
                if res.total() != total {
                    return Err(format!(
                        "tiles lost/duplicated: {} vs {total} (w={w} d={d} p={p})",
                        res.total()
                    ));
                }
                if res.per_worker.len() != w {
                    return Err("wrong worker count".into());
                }
                if res.makespan < (total + w - 1) / w {
                    return Err(format!(
                        "makespan {} below ideal {}",
                        res.makespan,
                        (total + w - 1) / w
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn one_worker_all_policies_equal_total() {
        let t = tree(61);
        for p in Policy::ALL {
            let r = simulate(&t, 1, Distribution::RoundRobin, p, 5);
            assert_eq!(r.max_tiles(), t.total_analyzed());
            assert_eq!(r.makespan, t.total_analyzed());
        }
    }

    #[test]
    fn ideal_is_lower_bound() {
        let t = tree(62);
        for w in [2, 4, 8, 12] {
            let ideal = simulate(&t, w, Distribution::RoundRobin, Policy::OracleIdeal, 1);
            for p in [Policy::NoBalancing, Policy::SyncPerLevel, Policy::WorkStealing] {
                for d in Distribution::ALL {
                    let r = simulate(&t, w, d, p, 1);
                    assert!(
                        r.max_tiles() >= ideal.max_tiles(),
                        "{p:?}/{d:?} beat the oracle: {} < {}",
                        r.max_tiles(),
                        ideal.max_tiles()
                    );
                }
            }
        }
    }

    #[test]
    fn work_stealing_close_to_ideal() {
        // The paper's §5.3 conclusion: with ≥4 workers work stealing is
        // essentially ideal (message latency neglected).
        let t = tree(63);
        for w in [4, 8, 12] {
            let ideal =
                simulate(&t, w, Distribution::RoundRobin, Policy::OracleIdeal, 1).max_tiles();
            let steal =
                simulate(&t, w, Distribution::RoundRobin, Policy::WorkStealing, 1).max_tiles();
            // On this small test tree the end-game (victims with ≤1 task
            // cannot be stolen from) costs a few units; the paper's
            // "equivalent to ideal" claim is asymptotic in tree size.
            assert!(
                (steal as f64) <= ideal as f64 * 1.30 + 3.0,
                "w={w}: steal {steal} vs ideal {ideal}"
            );
        }
    }

    #[test]
    fn block_distribution_is_worst_without_balancing() {
        // Tumor heterogeneity makes location-contiguous blocks uneven
        // (§5.2). Average over a few slides to avoid flakiness.
        let mut block = 0.0;
        let mut rr = 0.0;
        for seed in [70u64, 71, 72, 73, 74] {
            let t = tree(seed);
            block +=
                simulate(&t, 8, Distribution::Block, Policy::NoBalancing, 2).max_tiles() as f64;
            rr += simulate(&t, 8, Distribution::RoundRobin, Policy::NoBalancing, 2).max_tiles()
                as f64;
        }
        assert!(
            block > rr,
            "block ({block}) should be worse than round-robin ({rr})"
        );
    }

    #[test]
    fn stealing_reports_steals_when_imbalanced() {
        let t = tree(75);
        let r = simulate(&t, 8, Distribution::Block, Policy::WorkStealing, 3);
        assert!(r.steals > 0, "block distribution should trigger steals");
    }

    #[test]
    fn policy_name_roundtrip() {
        for p in Policy::ALL {
            assert_eq!(Policy::from_str(p.as_str()), Some(p));
        }
    }

    // ---- multi-job workload simulator (shared scheduling-policy core) ----

    use crate::sched::{Edf, Fifo, SchedulingPolicy, StrictPriority, WeightedFairShare};

    fn workload_job(
        seed: u64,
        tenant: &str,
        rank: u8,
        arrival: u64,
        deadline: Option<u64>,
    ) -> SimJobSpec {
        SimJobSpec {
            tenant: tenant.to_string(),
            priority_rank: rank,
            arrival,
            deadline,
            tree: tree(seed),
            thresholds: Thresholds::uniform(3, 0.35),
        }
    }

    #[test]
    fn workload_rebuilds_every_tree_under_every_policy() {
        // Deadlines far beyond any possible makespan: they order EDF
        // without ever expiring a job (expiry is its own test below).
        let jobs: Vec<SimJobSpec> = (0..4)
            .map(|i| {
                workload_job(
                    80 + i,
                    ["a", "b"][i as usize % 2],
                    (i % 3) as u8,
                    0,
                    Some(1_000_000 + i),
                )
            })
            .collect();
        let total: usize = jobs.iter().map(|j| j.tree.total_analyzed()).sum();
        let policies: Vec<Box<dyn SchedulingPolicy>> = vec![
            Box::new(Fifo),
            Box::new(StrictPriority),
            Box::new(WeightedFairShare::default()),
            Box::new(Edf),
        ];
        for policy in &policies {
            for preempt in [false, true] {
                let cfg = WorkloadConfig {
                    workers: 3,
                    max_in_flight: 2,
                    chunk: 8,
                    preempt,
                    park_aging: 0,
                    failures: vec![],
                    leader_failures: vec![],
                    stragglers: vec![],
                };
                let res = simulate_workload(&jobs, policy.as_ref(), &cfg);
                assert_eq!(res.completion_order.len(), jobs.len());
                for (i, out) in res.outcomes.iter().enumerate() {
                    assert_eq!(
                        out.tree, jobs[i].tree,
                        "{}/preempt={preempt}: job {i} tree diverged",
                        policy.name()
                    );
                    assert_eq!(out.tiles, jobs[i].tree.total_analyzed());
                }
                // Conservation: every analyzed tile landed on some worker.
                assert_eq!(res.per_worker.iter().sum::<usize>(), total);
                assert!(res.makespan as usize >= total / cfg.workers);
            }
        }
    }

    #[test]
    fn workload_is_deterministic() {
        let jobs: Vec<SimJobSpec> = (0..3)
            .map(|i| workload_job(85 + i, "t", i as u8, i * 5, None))
            .collect();
        let cfg = WorkloadConfig {
            workers: 2,
            max_in_flight: 2,
            chunk: 4,
            preempt: true,
            park_aging: 0,
            failures: vec![],
            leader_failures: vec![],
            stragglers: vec![],
        };
        let a = simulate_workload(&jobs, &StrictPriority, &cfg);
        let b = simulate_workload(&jobs, &StrictPriority, &cfg);
        assert_eq!(a.completion_order, b.completion_order);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.per_worker, b.per_worker);
        assert_eq!(a.preemptions, b.preemptions);
    }

    #[test]
    fn preempted_job_resumes_with_byte_identical_tree() {
        // A low-priority job is parked at a frontier boundary when a
        // high-priority job arrives mid-run, then resumed — the final
        // tree must be byte-identical to the uninterrupted recording
        // (which run_pyramidal produced), and the high job finishes
        // first.
        let low = workload_job(90, "lab", 0, 0, None);
        let high = workload_job(91, "lab", 2, 10, None);
        let jobs = vec![low, high];
        let cfg = WorkloadConfig {
            workers: 1,
            max_in_flight: 1,
            chunk: 8,
            preempt: true,
            park_aging: 0,
            failures: vec![],
            leader_failures: vec![],
            stragglers: vec![],
        };
        let res = simulate_workload(&jobs, &StrictPriority, &cfg);
        assert!(
            res.outcomes[0].preemptions >= 1,
            "low-priority job must be parked at least once"
        );
        assert_eq!(
            res.preemptions,
            res.outcomes.iter().map(|o| o.preemptions).sum::<usize>()
        );
        assert_eq!(
            res.completion_order.last(),
            Some(&0),
            "preempted job finishes after its preemptor: {:?}",
            res.completion_order
        );
        assert_eq!(res.outcomes[0].tree, jobs[0].tree, "suspend/resume changed the tree");
        assert_eq!(res.outcomes[1].tree, jobs[1].tree);
        jobs.iter()
            .for_each(|j| j.tree.check_consistency().unwrap());
        // Without preemption the high job waits for the low one instead.
        let cfg = WorkloadConfig {
            preempt: false,
            failures: vec![],
            leader_failures: vec![],
            stragglers: vec![],
            ..cfg
        };
        let res = simulate_workload(&jobs, &StrictPriority, &cfg);
        assert_eq!(res.preemptions, 0);
        assert_eq!(res.completion_order, vec![0, 1]);
        assert_eq!(res.outcomes[0].tree, jobs[0].tree);
    }

    #[test]
    fn weighted_fair_share_bounds_a_heavy_tenant_where_fifo_does_not() {
        // Tenant "heavy" floods five jobs; tenant "light" submits one,
        // last. FIFO serves strictly by submission, so the light tenant
        // waits out the whole backlog; weighted fair share lets it
        // through as soon as a slot frees.
        let mut jobs: Vec<SimJobSpec> = (0..5)
            .map(|i| workload_job(100 + i, "heavy", 1, 0, None))
            .collect();
        jobs.push(workload_job(110, "light", 1, 0, None));
        let light = jobs.len() - 1;
        let cfg = WorkloadConfig {
            workers: 2,
            max_in_flight: 2,
            chunk: 16,
            preempt: false,
            park_aging: 0,
            failures: vec![],
            leader_failures: vec![],
            stragglers: vec![],
        };
        let fifo = simulate_workload(&jobs, &Fifo, &cfg);
        let wfs = simulate_workload(&jobs, &WeightedFairShare::default(), &cfg);
        let pos = |r: &WorkloadResult| {
            r.completion_order
                .iter()
                .position(|&i| i == light)
                .expect("light job completed")
        };
        assert_eq!(
            pos(&fifo),
            jobs.len() - 1,
            "FIFO starves the light tenant to the very end"
        );
        assert!(
            pos(&wfs) < pos(&fifo),
            "fair share must beat FIFO for the light tenant ({} vs {})",
            pos(&wfs),
            pos(&fifo)
        );
        assert!(
            wfs.outcomes[light].completed_at < fifo.outcomes[light].completed_at,
            "light tenant turnaround must shrink under WFS"
        );
    }

    #[test]
    fn edf_orders_by_deadline_not_submission() {
        // Deadlines run opposite to submission order; with one slot the
        // completion order must follow the deadlines.
        let jobs: Vec<SimJobSpec> = (0..3)
            .map(|i| workload_job(120 + i, "t", 1, 0, Some(1_000 * (3 - i))))
            .collect();
        let cfg = WorkloadConfig {
            workers: 1,
            max_in_flight: 1,
            chunk: 0,
            preempt: false,
            park_aging: 0,
            failures: vec![],
            leader_failures: vec![],
            stragglers: vec![],
        };
        let res = simulate_workload(&jobs, &Edf, &cfg);
        assert_eq!(res.completion_order, vec![2, 1, 0]);
        let fifo = simulate_workload(&jobs, &Fifo, &cfg);
        assert_eq!(fifo.completion_order, vec![0, 1, 2]);
    }

    #[test]
    fn lapsed_deadline_jobs_expire_at_admission() {
        // Job 0 holds the single slot; job 1's absolute deadline lapses
        // while it waits, so admission drops it (the service's Expired
        // semantics) instead of running it late; job 2 completes.
        let jobs = vec![
            workload_job(140, "t", 1, 0, None),
            workload_job(141, "t", 1, 0, Some(1)),
            workload_job(142, "t", 1, 0, None),
        ];
        let cfg = WorkloadConfig {
            workers: 1,
            max_in_flight: 1,
            chunk: 0,
            preempt: false,
            park_aging: 0,
            failures: vec![],
            leader_failures: vec![],
            stragglers: vec![],
        };
        let res = simulate_workload(&jobs, &Fifo, &cfg);
        assert!(res.outcomes[1].expired, "lapsed job must expire");
        assert_eq!(res.outcomes[1].tiles, 0);
        assert_eq!(res.outcomes[1].tree.total_analyzed(), 0);
        assert!(!res.outcomes[0].expired && !res.outcomes[2].expired);
        assert_eq!(
            res.completion_order,
            vec![0, 2],
            "expired jobs never complete"
        );
    }

    #[test]
    fn wfs_quota_caps_concurrent_jobs_per_tenant() {
        // Four one-tenant jobs, quota 1, two slots: the second slot must
        // sit idle rather than exceed the tenant's quota, so jobs run
        // one after another — makespan ≈ the serial total.
        let jobs: Vec<SimJobSpec> = (0..3)
            .map(|i| workload_job(130 + i, "solo", 1, 0, None))
            .collect();
        let total: u64 = jobs.iter().map(|j| j.tree.total_analyzed() as u64).sum();
        let cfg = WorkloadConfig {
            workers: 4,
            max_in_flight: 2,
            failures: vec![],
            leader_failures: vec![],
            stragglers: vec![],
            chunk: 0,
            preempt: false,
            park_aging: 0,
        };
        let quota = WeightedFairShare::new(HashMap::new(), 1.0, Some(1));
        let res = simulate_workload(&jobs, &quota, &cfg);
        assert!(
            res.makespan >= total,
            "quota 1 must serialize the tenant's jobs ({} < {total})",
            res.makespan
        );
        let free = simulate_workload(&jobs, &WeightedFairShare::default(), &cfg);
        assert!(
            free.makespan < res.makespan,
            "without the quota two jobs overlap ({} vs {})",
            free.makespan,
            res.makespan
        );
    }

    #[test]
    fn multiple_jobs_park_concurrently_for_simultaneous_preemptors() {
        // Two low-priority jobs own both slots; two high-priority jobs
        // arrive together. The shared core pairs each preemptor with its
        // own victim, so BOTH lows park (concurrently — both highs run
        // while both lows sit in the parked set) instead of the old
        // one-suspension-at-a-time serialization.
        let jobs = vec![
            workload_job(170, "t", 0, 0, None),
            workload_job(171, "t", 0, 0, None),
            workload_job(172, "t", 2, 5, None),
            workload_job(173, "t", 2, 5, None),
        ];
        let cfg = WorkloadConfig {
            workers: 2,
            max_in_flight: 2,
            chunk: 4,
            preempt: true,
            park_aging: 0,
            failures: vec![],
            leader_failures: vec![],
            stragglers: vec![],
        };
        let res = simulate_workload(&jobs, &StrictPriority, &cfg);
        assert!(
            res.outcomes[0].preemptions >= 1 && res.outcomes[1].preemptions >= 1,
            "both low jobs must be parked: {:?}",
            res.outcomes.iter().map(|o| o.preemptions).collect::<Vec<_>>()
        );
        assert_eq!(
            &res.completion_order[..2],
            &[2, 3],
            "both preemptors run (and finish) while both victims are parked: {:?}",
            res.completion_order
        );
        for (i, out) in res.outcomes.iter().enumerate() {
            assert_eq!(out.tree, jobs[i].tree, "park/resume changed job {i}'s tree");
        }
        assert!(res.metrics.counter("sched.jobs_parked") >= 2);
        assert!(res.metrics.counter("sched.jobs_resumed") >= 2);
    }

    #[test]
    fn park_aging_breaks_starvation_under_a_sustained_high_priority_stream() {
        // One low-priority job, then a backlog of high-priority jobs deep
        // enough to starve it for the whole run under strict priority.
        // Without aging the low job is parked once and only resumes after
        // the entire backlog drains — it completes last. With aging its
        // effective rank climbs one step per interval of parked time, so
        // it wins a slot back mid-backlog and is NOT last; the earned
        // boost freezes in on resume, so the still-queued (never-parked,
        // never-aged) high jobs cannot re-victimize it.
        let mut jobs = vec![workload_job(180, "t", 0, 0, None)];
        for i in 0..5 {
            jobs.push(workload_job(181 + i, "t", 2, 1 + i, None));
        }
        let base = WorkloadConfig {
            workers: 1,
            max_in_flight: 1,
            chunk: 8,
            preempt: true,
            park_aging: 0,
            failures: vec![],
            leader_failures: vec![],
            stragglers: vec![],
        };
        let starved = simulate_workload(&jobs, &StrictPriority, &base);
        assert_eq!(
            starved.completion_order.last(),
            Some(&0),
            "without aging the low job starves to the very end: {:?}",
            starved.completion_order
        );
        let aged_cfg = WorkloadConfig {
            park_aging: 50,
            ..base
        };
        let aged = simulate_workload(&jobs, &StrictPriority, &aged_cfg);
        assert_ne!(
            aged.completion_order.last(),
            Some(&0),
            "aging must let the low job back in before the backlog drains: {:?}",
            aged.completion_order
        );
        let pos = |order: &[usize]| order.iter().position(|&i| i == 0).unwrap();
        assert!(
            pos(&aged.completion_order) < pos(&starved.completion_order),
            "aging must strictly improve the low job's completion position"
        );
        // Aging changes *when*, never *what*: every tree byte-identical.
        for (i, out) in aged.outcomes.iter().enumerate() {
            assert_eq!(out.tree, jobs[i].tree, "aging changed job {i}'s tree");
        }
        assert!(aged.metrics.counter("sched.jobs_resumed") >= 1);
        // Determinism holds with aging on.
        let again = simulate_workload(&jobs, &StrictPriority, &aged_cfg);
        assert_eq!(aged.completion_order, again.completion_order);
        assert_eq!(aged.makespan, again.makespan);
    }

    // ---- §10 failure injection -------------------------------------

    #[test]
    fn injected_failures_change_makespan_but_not_results() {
        // Worker 0 dies almost immediately (never rejoins); worker 1
        // dies mid-run and rejoins later. Every in-flight chunk on a
        // dying worker is requeued and re-dispatched to a survivor, so
        // every tree is still byte-identical to its recording — only
        // the makespan (and re-dispatch counter) shows the faults.
        let jobs: Vec<SimJobSpec> = (0..3)
            .map(|i| workload_job(150 + i, "t", 1, 0, None))
            .collect();
        let total: usize = jobs.iter().map(|j| j.tree.total_analyzed()).sum();
        let clean_cfg = WorkloadConfig {
            workers: 3,
            max_in_flight: 2,
            chunk: 4,
            preempt: false,
            park_aging: 0,
            failures: vec![],
            leader_failures: vec![],
            stragglers: vec![],
        };
        let clean = simulate_workload(&jobs, &Fifo, &clean_cfg);
        assert_eq!(clean.requeued_chunks, 0);

        let faulty_cfg = WorkloadConfig {
            failures: vec![
                WorkerFailure {
                    worker: 0,
                    at: 1,
                    rejoin: None,
                },
                WorkerFailure {
                    worker: 1,
                    at: 6,
                    rejoin: Some(40),
                },
            ],
            ..clean_cfg.clone()
        };
        let faulty = simulate_workload(&jobs, &Fifo, &faulty_cfg);
        for (i, out) in faulty.outcomes.iter().enumerate() {
            assert_eq!(
                out.tree, jobs[i].tree,
                "job {i}: failures must not change the result"
            );
            // Dispatched-tile counts include the lost attempts — the
            // per-job face of recovery overhead.
            assert!(out.tiles >= jobs[i].tree.total_analyzed());
        }
        assert_eq!(
            faulty.completion_order.len(),
            jobs.len(),
            "every job still completes"
        );
        assert!(
            faulty.requeued_chunks > 0,
            "tick-1 failure must catch chunks in flight"
        );
        assert!(
            faulty.makespan > clean.makespan,
            "losing workers must cost virtual time ({} vs {})",
            faulty.makespan,
            clean.makespan
        );
        // Conservation: every analyzed tile completed on exactly one
        // worker, lost attempts excluded.
        assert_eq!(faulty.per_worker.iter().sum::<usize>(), total);
        assert_eq!(clean.per_worker.iter().sum::<usize>(), total);
    }

    #[test]
    fn failure_injection_is_deterministic_and_survives_total_outage() {
        // Both workers die early; one rejoins — during the outage the
        // pending requests wait, then drain. Same schedule twice ⇒ same
        // trace.
        let jobs: Vec<SimJobSpec> = (0..2)
            .map(|i| workload_job(160 + i, "t", 1, 0, None))
            .collect();
        let cfg = WorkloadConfig {
            workers: 2,
            max_in_flight: 2,
            chunk: 8,
            preempt: false,
            park_aging: 0,
            failures: vec![
                WorkerFailure {
                    worker: 0,
                    at: 2,
                    rejoin: Some(30),
                },
                WorkerFailure {
                    worker: 1,
                    at: 2,
                    rejoin: None,
                },
            ],
            leader_failures: vec![],
            stragglers: vec![],
        };
        let a = simulate_workload(&jobs, &Fifo, &cfg);
        let b = simulate_workload(&jobs, &Fifo, &cfg);
        assert_eq!(a.completion_order, b.completion_order);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.per_worker, b.per_worker);
        assert_eq!(a.requeued_chunks, b.requeued_chunks);
        for (i, out) in a.outcomes.iter().enumerate() {
            assert_eq!(out.tree, jobs[i].tree);
        }
        // Only the rejoined worker can have completed work after tick 2
        // (everything on worker 1 after the outage was requeued).
        assert!(a.requeued_chunks > 0);
    }

    #[test]
    fn gray_straggler_slows_the_run_but_never_changes_a_tree() {
        // §16 gray-failure mirror: a worker that is slow-but-alive for a
        // window stretches the makespan, trips none of the recovery
        // machinery, and leaves every tree byte-identical.
        let jobs: Vec<SimJobSpec> = (0..3)
            .map(|i| workload_job(180 + i, "t", 1, 0, None))
            .collect();
        let total: usize = jobs.iter().map(|j| j.tree.total_analyzed()).sum();
        let clean_cfg = WorkloadConfig {
            workers: 3,
            max_in_flight: 2,
            chunk: 4,
            preempt: false,
            park_aging: 0,
            failures: vec![],
            leader_failures: vec![],
            stragglers: vec![],
        };
        let clean = simulate_workload(&jobs, &Fifo, &clean_cfg);
        let gray_cfg = WorkloadConfig {
            stragglers: vec![Straggler {
                worker: 0,
                from: 0,
                until: None,
                factor: 8,
            }],
            ..clean_cfg.clone()
        };
        let gray = simulate_workload(&jobs, &Fifo, &gray_cfg);
        for (i, out) in gray.outcomes.iter().enumerate() {
            assert_eq!(
                out.tree, jobs[i].tree,
                "job {i}: a straggler must not change the result"
            );
            // No chunk was ever lost: dispatched == analyzed.
            assert_eq!(out.tiles, jobs[i].tree.total_analyzed());
        }
        assert!(
            gray.makespan > clean.makespan,
            "an 8x straggler must cost virtual time ({} vs {})",
            gray.makespan,
            clean.makespan
        );
        assert_eq!(gray.requeued_chunks, 0, "gray is not dead: nothing requeues");
        assert_eq!(gray.per_worker.iter().sum::<usize>(), total);

        // A window that closes lets the worker recover: bounded gray
        // costs less than permanent gray.
        let windowed_cfg = WorkloadConfig {
            stragglers: vec![Straggler {
                worker: 0,
                from: 0,
                until: Some(4),
                factor: 8,
            }],
            ..clean_cfg
        };
        let windowed = simulate_workload(&jobs, &Fifo, &windowed_cfg);
        assert!(windowed.makespan <= gray.makespan);
        for (i, out) in windowed.outcomes.iter().enumerate() {
            assert_eq!(out.tree, jobs[i].tree);
        }

        // Same schedule twice ⇒ same trace.
        let again = simulate_workload(&jobs, &Fifo, &gray_cfg);
        assert_eq!(again.makespan, gray.makespan);
        assert_eq!(again.per_worker, gray.per_worker);
        assert_eq!(again.completion_order, gray.completion_order);
    }

    #[test]
    fn injected_leader_failover_requeues_everything_but_changes_no_tree() {
        // At tick 3 the leader's dispatch state dies wholesale (§15):
        // every chunk in flight is orphaned and every running job
        // requeues all outstanding work. The trees must still be
        // byte-identical to their recordings — failover is pure
        // recovery overhead, same as the service's Event::LeaderFailover.
        let jobs: Vec<SimJobSpec> = (0..3)
            .map(|i| workload_job(170 + i, "t", 1, 0, None))
            .collect();
        let total: usize = jobs.iter().map(|j| j.tree.total_analyzed()).sum();
        let clean_cfg = WorkloadConfig {
            workers: 3,
            max_in_flight: 2,
            chunk: 4,
            preempt: false,
            park_aging: 0,
            failures: vec![],
            leader_failures: vec![],
            stragglers: vec![],
        };
        let clean = simulate_workload(&jobs, &Fifo, &clean_cfg);
        let failover_cfg = WorkloadConfig {
            leader_failures: vec![3],
            stragglers: vec![],
            ..clean_cfg
        };
        let hit = simulate_workload(&jobs, &Fifo, &failover_cfg);
        for (i, out) in hit.outcomes.iter().enumerate() {
            assert_eq!(
                out.tree, jobs[i].tree,
                "job {i}: a leader failover must not change the result"
            );
        }
        assert_eq!(hit.completion_order.len(), jobs.len());
        assert_eq!(hit.metrics.counter("sched.leader_failovers"), 1);
        assert!(
            hit.requeued_chunks > 0,
            "a tick-3 failover must orphan chunks in flight"
        );
        assert!(
            hit.makespan > clean.makespan,
            "redoing orphaned work must cost virtual time ({} vs {})",
            hit.makespan,
            clean.makespan
        );
        // Conservation: every analyzed tile completed on exactly one
        // worker; orphaned attempts are excluded.
        assert_eq!(hit.per_worker.iter().sum::<usize>(), total);
        // Same schedule twice ⇒ same trace.
        let again = simulate_workload(&jobs, &Fifo, &failover_cfg);
        assert_eq!(again.makespan, hit.makespan);
        assert_eq!(again.per_worker, hit.per_worker);
        assert_eq!(again.requeued_chunks, hit.requeued_chunks);
    }
}
