//! Offline distributed-execution simulator (§5.1).
//!
//! Replays a recorded pyramidal execution tree under a worker count, an
//! initial distribution and a load-balancing policy, and reports the
//! per-worker tile loads. As in the paper, analysis-block time dominates
//! and is level-independent (Table 3), so *the number of tiles analyzed by
//! the busiest worker* is the makespan proxy, and message latency is
//! neglected.

use std::collections::{HashMap, VecDeque};

use crate::pyramid::tree::ExecTree;
use crate::slide::tile::TileId;
use crate::util::prng::Pcg32;

use super::distribution::Distribution;

/// Load-balancing policies (§5.2-5.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// No rebalancing: each worker exhausts the subtrees it was dealt.
    NoBalancing,
    /// Barrier after every resolution level; the next level's tiles are
    /// redistributed evenly (§5.2).
    SyncPerLevel,
    /// Synchronization-free random-victim work stealing (§5.3).
    WorkStealing,
    /// Oracle: perfectly even split of the total load (lower bound).
    OracleIdeal,
}

impl Policy {
    pub const ALL: [Policy; 4] = [
        Policy::NoBalancing,
        Policy::SyncPerLevel,
        Policy::WorkStealing,
        Policy::OracleIdeal,
    ];

    pub fn as_str(self) -> &'static str {
        match self {
            Policy::NoBalancing => "none",
            Policy::SyncPerLevel => "sync",
            Policy::WorkStealing => "steal",
            Policy::OracleIdeal => "ideal",
        }
    }

    pub fn from_str(s: &str) -> Option<Policy> {
        match s {
            "none" => Some(Policy::NoBalancing),
            "sync" => Some(Policy::SyncPerLevel),
            "steal" => Some(Policy::WorkStealing),
            "ideal" => Some(Policy::OracleIdeal),
            _ => None,
        }
    }
}

/// Outcome of one simulated distributed execution.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub per_worker: Vec<usize>,
    /// Simulated time units (one tile analysis = one unit). For the
    /// synchronized policy this includes barrier effects
    /// (Σ per-level maxima); for the others it is the busiest worker's
    /// tile count (steals are instantaneous).
    pub makespan: usize,
    pub steals: usize,
}

impl SimResult {
    pub fn max_tiles(&self) -> usize {
        self.per_worker.iter().copied().max().unwrap_or(0)
    }

    pub fn total(&self) -> usize {
        self.per_worker.iter().sum()
    }
}

/// Zoom decisions recorded in a tree, keyed by tile.
fn zoom_map(tree: &ExecTree) -> HashMap<TileId, bool> {
    let mut m = HashMap::new();
    for lvl in &tree.nodes {
        for n in lvl {
            m.insert(n.tile, n.zoom);
        }
    }
    m
}

/// Simulate one execution.
pub fn simulate(
    tree: &ExecTree,
    workers: usize,
    dist: Distribution,
    policy: Policy,
    seed: u64,
) -> SimResult {
    assert!(workers >= 1);
    let zoom = zoom_map(tree);
    let initial = dist.assign(&tree.initial, workers, seed);
    match policy {
        Policy::NoBalancing => sim_no_balancing(&zoom, initial),
        Policy::SyncPerLevel => sim_sync(&zoom, initial, workers),
        Policy::WorkStealing => sim_steal(&zoom, initial, workers, seed),
        Policy::OracleIdeal => {
            let total = tree.total_analyzed();
            let base = total / workers;
            let extra = total % workers;
            let per_worker: Vec<usize> = (0..workers)
                .map(|w| base + usize::from(w < extra))
                .collect();
            let makespan = *per_worker.iter().max().unwrap();
            SimResult {
                per_worker,
                makespan,
                steals: 0,
            }
        }
    }
}

/// Size of the subtree rooted at `t` within the recorded execution.
fn subtree_size(zoom: &HashMap<TileId, bool>, t: TileId) -> usize {
    // Tiles not in the map were never analyzed (pruned initial tiles do
    // not occur — initial tiles are always analyzed).
    let mut size = 1;
    if zoom.get(&t).copied().unwrap_or(false) {
        for c in t.children() {
            if zoom.contains_key(&c) {
                size += subtree_size(zoom, c);
            }
        }
    }
    size
}

fn sim_no_balancing(zoom: &HashMap<TileId, bool>, initial: Vec<Vec<TileId>>) -> SimResult {
    let per_worker: Vec<usize> = initial
        .iter()
        .map(|tiles| tiles.iter().map(|&t| subtree_size(zoom, t)).sum())
        .collect();
    let makespan = per_worker.iter().copied().max().unwrap_or(0);
    SimResult {
        per_worker,
        makespan,
        steals: 0,
    }
}

fn sim_sync(
    zoom: &HashMap<TileId, bool>,
    initial: Vec<Vec<TileId>>,
    workers: usize,
) -> SimResult {
    let mut per_worker = vec![0usize; workers];
    let mut makespan = 0usize;
    let mut current = initial;
    loop {
        let mut level_counts = vec![0usize; workers];
        let mut next: Vec<TileId> = Vec::new();
        for (w, tiles) in current.iter().enumerate() {
            level_counts[w] += tiles.len();
            for &t in tiles {
                if zoom.get(&t).copied().unwrap_or(false) {
                    for c in t.children() {
                        if zoom.contains_key(&c) {
                            next.push(c);
                        }
                    }
                }
            }
        }
        for w in 0..workers {
            per_worker[w] += level_counts[w];
        }
        makespan += level_counts.iter().copied().max().unwrap_or(0);
        if next.is_empty() {
            break;
        }
        // Barrier: redistribute the next level evenly (round-robin).
        let mut redistributed = vec![Vec::new(); workers];
        for (i, t) in next.into_iter().enumerate() {
            redistributed[i % workers].push(t);
        }
        current = redistributed;
    }
    SimResult {
        per_worker,
        makespan,
        steals: 0,
    }
}

fn sim_steal(
    zoom: &HashMap<TileId, bool>,
    initial: Vec<Vec<TileId>>,
    workers: usize,
    seed: u64,
) -> SimResult {
    let mut rng = Pcg32::new(seed ^ 0x57EA_1000);
    let mut queues: Vec<VecDeque<TileId>> = initial
        .into_iter()
        .map(|tiles| tiles.into_iter().collect())
        .collect();
    let mut per_worker = vec![0usize; workers];
    let mut steals = 0usize;
    let mut makespan = 0usize;

    loop {
        if queues.iter().all(|q| q.is_empty()) {
            break;
        }
        makespan += 1;
        // Analysis phase: every busy worker processes one tile.
        let mut spawned: Vec<Vec<TileId>> = vec![Vec::new(); workers];
        let mut idle: Vec<usize> = Vec::new();
        for w in 0..workers {
            match queues[w].pop_front() {
                Some(t) => {
                    per_worker[w] += 1;
                    if zoom.get(&t).copied().unwrap_or(false) {
                        for c in t.children() {
                            if zoom.contains_key(&c) {
                                spawned[w].push(c);
                            }
                        }
                    }
                }
                None => idle.push(w),
            }
        }
        for (w, sp) in spawned.into_iter().enumerate() {
            queues[w].extend(sp);
        }
        // Steal phase: each idle worker targets one random victim with
        // more than one task and takes one (message time neglected, §5.1).
        for &thief in &idle {
            let candidates: Vec<usize> = (0..workers)
                .filter(|&v| v != thief && queues[v].len() > 1)
                .collect();
            if let Some(&victim) = rng.choose(&candidates) {
                if let Some(task) = queues[victim].pop_front() {
                    queues[thief].push_back(task);
                    steals += 1;
                }
            }
        }
    }
    SimResult {
        per_worker,
        makespan,
        steals,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::oracle::OracleAnalyzer;
    use crate::pyramid::driver::run_pyramidal;
    use crate::pyramid::tree::Thresholds;
    use crate::slide::pyramid::Slide;
    use crate::synth::slide_gen::{SlideKind, SlideSpec};
    use crate::util::quickcheck::forall_explain;

    fn tree(seed: u64) -> ExecTree {
        let s = Slide::from_spec(SlideSpec::new(
            "sim",
            seed,
            32,
            16,
            3,
            64,
            SlideKind::LargeTumor,
        ));
        run_pyramidal(&s, &OracleAnalyzer::new(1), &Thresholds::uniform(3, 0.35), 32)
    }

    #[test]
    fn conservation_all_policies_all_distributions() {
        let t = tree(60);
        let total = t.total_analyzed();
        forall_explain(
            3,
            60,
            |r| {
                (
                    r.usize_range(1, 25),
                    r.usize_range(0, 3),
                    r.usize_range(0, 4),
                    r.next_u64(),
                )
            },
            |&(w, d, p, seed)| {
                let res = simulate(&t, w, Distribution::ALL[d], Policy::ALL[p], seed);
                if res.total() != total {
                    return Err(format!(
                        "tiles lost/duplicated: {} vs {total} (w={w} d={d} p={p})",
                        res.total()
                    ));
                }
                if res.per_worker.len() != w {
                    return Err("wrong worker count".into());
                }
                if res.makespan < (total + w - 1) / w {
                    return Err(format!(
                        "makespan {} below ideal {}",
                        res.makespan,
                        (total + w - 1) / w
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn one_worker_all_policies_equal_total() {
        let t = tree(61);
        for p in Policy::ALL {
            let r = simulate(&t, 1, Distribution::RoundRobin, p, 5);
            assert_eq!(r.max_tiles(), t.total_analyzed());
            assert_eq!(r.makespan, t.total_analyzed());
        }
    }

    #[test]
    fn ideal_is_lower_bound() {
        let t = tree(62);
        for w in [2, 4, 8, 12] {
            let ideal = simulate(&t, w, Distribution::RoundRobin, Policy::OracleIdeal, 1);
            for p in [Policy::NoBalancing, Policy::SyncPerLevel, Policy::WorkStealing] {
                for d in Distribution::ALL {
                    let r = simulate(&t, w, d, p, 1);
                    assert!(
                        r.max_tiles() >= ideal.max_tiles(),
                        "{p:?}/{d:?} beat the oracle: {} < {}",
                        r.max_tiles(),
                        ideal.max_tiles()
                    );
                }
            }
        }
    }

    #[test]
    fn work_stealing_close_to_ideal() {
        // The paper's §5.3 conclusion: with ≥4 workers work stealing is
        // essentially ideal (message latency neglected).
        let t = tree(63);
        for w in [4, 8, 12] {
            let ideal =
                simulate(&t, w, Distribution::RoundRobin, Policy::OracleIdeal, 1).max_tiles();
            let steal =
                simulate(&t, w, Distribution::RoundRobin, Policy::WorkStealing, 1).max_tiles();
            // On this small test tree the end-game (victims with ≤1 task
            // cannot be stolen from) costs a few units; the paper's
            // "equivalent to ideal" claim is asymptotic in tree size.
            assert!(
                (steal as f64) <= ideal as f64 * 1.30 + 3.0,
                "w={w}: steal {steal} vs ideal {ideal}"
            );
        }
    }

    #[test]
    fn block_distribution_is_worst_without_balancing() {
        // Tumor heterogeneity makes location-contiguous blocks uneven
        // (§5.2). Average over a few slides to avoid flakiness.
        let mut block = 0.0;
        let mut rr = 0.0;
        for seed in [70u64, 71, 72, 73, 74] {
            let t = tree(seed);
            block +=
                simulate(&t, 8, Distribution::Block, Policy::NoBalancing, 2).max_tiles() as f64;
            rr += simulate(&t, 8, Distribution::RoundRobin, Policy::NoBalancing, 2).max_tiles()
                as f64;
        }
        assert!(
            block > rr,
            "block ({block}) should be worse than round-robin ({rr})"
        );
    }

    #[test]
    fn stealing_reports_steals_when_imbalanced() {
        let t = tree(75);
        let r = simulate(&t, 8, Distribution::Block, Policy::WorkStealing, 3);
        assert!(r.steals > 0, "block distribution should trigger steals");
    }

    #[test]
    fn policy_name_roundtrip() {
        for p in Policy::ALL {
            assert_eq!(Policy::from_str(p.as_str()), Some(p));
        }
    }
}
