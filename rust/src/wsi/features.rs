//! Whole-slide feature extraction (§4.6).
//!
//! The paper trains a bagging decision-tree classifier "to predict tumoral
//! images from the distribution of tile prediction probabilities", and —
//! when PyramidAI stopped at a lower resolution — "projected the predicted
//! probability onto all corresponding tiles at the highest resolution".
//!
//! This module turns one execution tree into that distribution: every
//! level-0 lineage tile gets a probability (its own if analyzed, else its
//! deepest analyzed ancestor's), summarized as a histogram + tail stats.

use std::collections::HashMap;

use crate::pyramid::tree::ExecTree;
use crate::slide::tile::TileId;

/// Probability-histogram resolution of the feature vector.
pub const HIST_BINS: usize = 10;
/// Histogram + [mean, max, frac ≥ 0.5, frac ≥ 0.9].
pub const FEATURE_DIM: usize = HIST_BINS + 4;

/// Probability of every level-0 lineage tile, projecting pruned branches'
/// probabilities down from the deepest analyzed ancestor.
pub fn project_to_level0(tree: &ExecTree) -> Vec<f32> {
    let analyzed: HashMap<TileId, f32> = tree
        .nodes
        .iter()
        .flatten()
        .map(|n| (n.tile, n.prob))
        .collect();
    let mut out = Vec::new();
    // Walk down from every initial tile; where a node was not analyzed,
    // inherit the parent's probability for its whole sub-lineage.
    fn walk(
        t: TileId,
        inherited: f32,
        analyzed: &HashMap<TileId, f32>,
        out: &mut Vec<f32>,
    ) {
        let p = analyzed.get(&t).copied().unwrap_or(inherited);
        if t.level == 0 {
            out.push(p);
            return;
        }
        for c in t.children() {
            walk(c, p, analyzed, out);
        }
    }
    for &t in &tree.initial {
        let p = analyzed.get(&t).copied().unwrap_or(0.0);
        walk(t, p, &analyzed, &mut out);
    }
    out
}

/// Fixed-length feature vector from projected probabilities.
pub fn features(projected: &[f32]) -> Vec<f64> {
    let n = projected.len().max(1) as f64;
    let mut hist = vec![0.0f64; HIST_BINS];
    let mut sum = 0.0f64;
    let mut max = 0.0f64;
    let mut ge05 = 0.0f64;
    let mut ge09 = 0.0f64;
    for &p in projected {
        let b = ((p as f64 * HIST_BINS as f64) as usize).min(HIST_BINS - 1);
        hist[b] += 1.0;
        sum += p as f64;
        max = max.max(p as f64);
        if p >= 0.5 {
            ge05 += 1.0;
        }
        if p >= 0.9 {
            ge09 += 1.0;
        }
    }
    let mut f: Vec<f64> = hist.into_iter().map(|h| h / n).collect();
    f.push(sum / n);
    f.push(max);
    f.push(ge05 / n);
    f.push(ge09 / n);
    debug_assert_eq!(f.len(), FEATURE_DIM);
    f
}

/// Convenience: features straight from a tree.
pub fn tree_features(tree: &ExecTree) -> Vec<f64> {
    features(&project_to_level0(tree))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::oracle::OracleAnalyzer;
    use crate::pyramid::driver::{run_pyramidal, run_reference};
    use crate::pyramid::tree::Thresholds;
    use crate::slide::pyramid::Slide;
    use crate::slide::tile::SCALE_FACTOR;
    use crate::synth::slide_gen::{SlideKind, SlideSpec};

    fn slide(kind: SlideKind, seed: u64) -> Slide {
        Slide::from_spec(SlideSpec::new("w", seed, 16, 8, 3, 64, kind))
    }

    #[test]
    fn projection_covers_full_lineage() {
        let s = slide(SlideKind::LargeTumor, 80);
        let a = OracleAnalyzer::new(1);
        for thr in [0.0, 0.5, 1.1] {
            let tree = run_pyramidal(&s, &a, &Thresholds::uniform(3, thr), 8);
            let proj = project_to_level0(&tree);
            let f2 = SCALE_FACTOR * SCALE_FACTOR;
            assert_eq!(proj.len(), tree.initial.len() * f2 * f2, "thr={thr}");
        }
    }

    #[test]
    fn reference_projection_equals_level0_probs() {
        let s = slide(SlideKind::SmallScattered, 81);
        let a = OracleAnalyzer::new(1);
        let r = run_reference(&s, &a, 8);
        let proj = project_to_level0(&r);
        // Reference analyzes every level-0 tile, so projection = raw probs
        // (possibly reordered); compare as multisets via sorted lists.
        let mut got = proj;
        let mut want: Vec<f32> = r.level0().iter().map(|n| n.prob).collect();
        got.sort_by(|a, b| a.partial_cmp(b).unwrap());
        want.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(got, want);
    }

    #[test]
    fn pruned_branches_inherit_ancestor_probability() {
        let s = slide(SlideKind::Negative, 82);
        let a = OracleAnalyzer::new(1);
        // Prune everything: all L0 tiles inherit their L2 ancestor's prob.
        let tree = run_pyramidal(&s, &a, &Thresholds::uniform(3, 1.1), 8);
        let proj = project_to_level0(&tree);
        let l2: HashMap<TileId, f32> =
            tree.nodes[2].iter().map(|n| (n.tile, n.prob)).collect();
        // Every projected value must equal some L2 probability.
        for p in proj {
            assert!(
                l2.values().any(|&q| (q - p).abs() < 1e-6),
                "projected {p} not an L2 prob"
            );
        }
    }

    #[test]
    fn feature_vector_shape_and_normalization() {
        let s = slide(SlideKind::LargeTumor, 83);
        let a = OracleAnalyzer::new(1);
        let tree = run_pyramidal(&s, &a, &Thresholds::uniform(3, 0.4), 8);
        let f = tree_features(&tree);
        assert_eq!(f.len(), FEATURE_DIM);
        let hist_sum: f64 = f[..HIST_BINS].iter().sum();
        assert!((hist_sum - 1.0).abs() < 1e-9);
        assert!(f.iter().all(|&v| (0.0..=1.0 + 1e-9).contains(&v)));
    }

    #[test]
    fn tumor_slide_features_differ_from_negative() {
        let a = OracleAnalyzer::new(1);
        let thr = Thresholds::uniform(3, 0.4);
        let ft = tree_features(&run_pyramidal(&slide(SlideKind::LargeTumor, 84), &a, &thr, 8));
        let fn_ = tree_features(&run_pyramidal(&slide(SlideKind::Negative, 85), &a, &thr, 8));
        // frac ≥ 0.5 (index HIST_BINS+2) should separate them clearly.
        assert!(ft[HIST_BINS + 2] > fn_[HIST_BINS + 2] + 0.01);
    }

    #[test]
    fn empty_projection_is_safe() {
        let f = features(&[]);
        assert_eq!(f.len(), FEATURE_DIM);
        assert!(f.iter().all(|v| v.is_finite()));
    }
}
