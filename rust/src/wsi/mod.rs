//! Whole-slide image classification (§4.6): probability-distribution
//! features with pyramid→level-0 projection, CART trees, bagging.

/// Bagged ensemble over decision trees.
pub mod bagging;
/// Minimal decision tree (no external ML deps).
pub mod dtree;
/// Slide-level feature extraction from execution trees.
pub mod features;

pub use bagging::{BaggingClassifier, BaggingParams};
pub use dtree::{DecisionTree, Sample, TreeParams};
pub use features::{features, project_to_level0, tree_features};
