//! Bagging ensemble of CART trees — "a bagging decision tree classifier to
//! predict tumoral images from the distribution of tile prediction
//! probabilities" (§4.6).

use crate::util::prng::Pcg32;

use super::dtree::{DecisionTree, Sample, TreeParams};

#[derive(Debug, Clone)]
/// Ensemble hyperparameters.
pub struct BaggingParams {
    /// Trees in the ensemble.
    pub n_trees: usize,
    /// Per-tree hyperparameters.
    pub tree: TreeParams,
    /// Bootstrap sampling seed.
    pub seed: u64,
}

impl Default for BaggingParams {
    fn default() -> Self {
        Self {
            n_trees: 25,
            tree: TreeParams::default(),
            seed: 0xBA66,
        }
    }
}

#[derive(Debug, Clone)]
/// Majority-vote ensemble of decision trees.
pub struct BaggingClassifier {
    trees: Vec<DecisionTree>,
}

impl BaggingClassifier {
    /// Fit `n_trees` CARTs on bootstrap resamples of the training set.
    pub fn fit(samples: &[Sample], params: &BaggingParams) -> BaggingClassifier {
        assert!(!samples.is_empty());
        let mut rng = Pcg32::new(params.seed);
        let n = samples.len();
        let trees = (0..params.n_trees)
            .map(|_| {
                let boot: Vec<Sample> = (0..n)
                    .map(|_| samples[rng.usize_range(0, n)].clone())
                    .collect();
                DecisionTree::fit(&boot, params.tree)
            })
            .collect();
        BaggingClassifier { trees }
    }

    /// Mean leaf probability across the ensemble.
    pub fn predict_proba(&self, x: &[f64]) -> f64 {
        self.trees.iter().map(|t| t.predict_proba(x)).sum::<f64>() / self.trees.len() as f64
    }

    /// Majority vote over the ensemble.
    pub fn predict(&self, x: &[f64]) -> bool {
        self.predict_proba(x) >= 0.5
    }

    /// Accuracy over a labeled set.
    pub fn accuracy(&self, samples: &[Sample]) -> f64 {
        if samples.is_empty() {
            return 0.0;
        }
        samples
            .iter()
            .filter(|s| self.predict(&s.x) == s.y)
            .count() as f64
            / samples.len() as f64
    }

    /// (accuracy, true positives, false positives, positives detected).
    pub fn confusion(&self, samples: &[Sample]) -> (f64, usize, usize, usize) {
        let mut tp = 0;
        let mut fp = 0;
        let mut detected = 0;
        for s in samples {
            let pred = self.predict(&s.x);
            if pred {
                detected += 1;
                if s.y {
                    tp += 1;
                } else {
                    fp += 1;
                }
            }
        }
        (self.accuracy(samples), tp, fp, detected)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;

    fn noisy_data(n: usize, seed: u64) -> Vec<Sample> {
        let mut rng = Pcg32::new(seed);
        (0..n)
            .map(|_| {
                let a = rng.f64();
                let b = rng.f64();
                let y = a + 0.3 * b > 0.6;
                // 10% label noise
                let y = if rng.bool(0.1) { !y } else { y };
                Sample { x: vec![a, b], y }
            })
            .collect()
    }

    #[test]
    fn beats_chance_on_noisy_data() {
        let train = noisy_data(400, 1);
        let test = noisy_data(200, 2);
        let clf = BaggingClassifier::fit(&train, &BaggingParams::default());
        let acc = clf.accuracy(&test);
        assert!(acc > 0.8, "test accuracy {acc}");
    }

    #[test]
    fn ensemble_beats_or_matches_single_stump() {
        let train = noisy_data(300, 3);
        let test = noisy_data(200, 4);
        let single = BaggingClassifier::fit(
            &train,
            &BaggingParams {
                n_trees: 1,
                ..Default::default()
            },
        );
        let bagged = BaggingClassifier::fit(&train, &BaggingParams::default());
        assert!(bagged.accuracy(&test) + 0.05 >= single.accuracy(&test));
    }

    #[test]
    fn deterministic_by_seed() {
        let train = noisy_data(100, 5);
        let a = BaggingClassifier::fit(&train, &BaggingParams::default());
        let b = BaggingClassifier::fit(&train, &BaggingParams::default());
        for s in &train {
            assert_eq!(a.predict_proba(&s.x), b.predict_proba(&s.x));
        }
    }

    #[test]
    fn confusion_counts_consistent() {
        let train = noisy_data(200, 6);
        let clf = BaggingClassifier::fit(&train, &BaggingParams::default());
        let (acc, tp, fp, det) = clf.confusion(&train);
        assert_eq!(tp + fp, det);
        assert!((0.0..=1.0).contains(&acc));
    }
}
