//! CART decision tree (gini impurity, axis-aligned splits) — the base
//! learner of the §4.6 bagging classifier. Built from scratch (no ML crate
//! in the offline vendor set).

/// One labeled sample: fixed-length features + binary label.
#[derive(Debug, Clone)]
pub struct Sample {
    /// Feature vector.
    pub x: Vec<f64>,
    /// Ground-truth label (slide contains tumor).
    pub y: bool,
}

#[derive(Debug, Clone)]
/// One node of a fitted tree.
pub enum Node {
    /// Terminal node carrying the positive fraction.
    Leaf {
        /// Probability of the positive class at this leaf.
        p: f64,
    },
    /// Internal split on one feature.
    Split {
        feature: usize,
        threshold: f64,
        left: Box<Node>,  // x[feature] <= threshold
        right: Box<Node>, // x[feature] >  threshold
    },
}

#[derive(Debug, Clone)]
/// A fitted CART-style decision tree.
pub struct DecisionTree {
    root: Node,
}

#[derive(Debug, Clone, Copy)]
/// Tree hyperparameters.
pub struct TreeParams {
    /// Depth bound.
    pub max_depth: usize,
    /// Minimum samples a leaf may hold.
    pub min_samples_leaf: usize,
}

impl Default for TreeParams {
    fn default() -> Self {
        Self {
            max_depth: 5,
            min_samples_leaf: 2,
        }
    }
}

fn gini(pos: f64, n: f64) -> f64 {
    if n == 0.0 {
        return 0.0;
    }
    let p = pos / n;
    2.0 * p * (1.0 - p) // binary gini = 1 - p² - (1-p)²
}

impl DecisionTree {
    /// Fit a tree greedily (Gini impurity).
    pub fn fit(samples: &[Sample], params: TreeParams) -> DecisionTree {
        assert!(!samples.is_empty());
        let idx: Vec<usize> = (0..samples.len()).collect();
        DecisionTree {
            root: build(samples, &idx, params, 0),
        }
    }

    /// Positive probability for one feature vector.
    pub fn predict_proba(&self, x: &[f64]) -> f64 {
        let mut node = &self.root;
        loop {
            match node {
                Node::Leaf { p } => return *p,
                Node::Split {
                    feature,
                    threshold,
                    left,
                    right,
                } => {
                    node = if x[*feature] <= *threshold { left } else { right };
                }
            }
        }
    }

    /// Hard classification at 0.5.
    pub fn predict(&self, x: &[f64]) -> bool {
        self.predict_proba(x) >= 0.5
    }

    /// Depth of the fitted tree.
    pub fn depth(&self) -> usize {
        fn d(n: &Node) -> usize {
            match n {
                Node::Leaf { .. } => 0,
                Node::Split { left, right, .. } => 1 + d(left).max(d(right)),
            }
        }
        d(&self.root)
    }
}

fn leaf(samples: &[Sample], idx: &[usize]) -> Node {
    let pos = idx.iter().filter(|&&i| samples[i].y).count() as f64;
    Node::Leaf {
        p: pos / idx.len().max(1) as f64,
    }
}

fn build(samples: &[Sample], idx: &[usize], params: TreeParams, depth: usize) -> Node {
    let n = idx.len();
    let pos = idx.iter().filter(|&&i| samples[i].y).count();
    if depth >= params.max_depth || n < 2 * params.min_samples_leaf || pos == 0 || pos == n {
        return leaf(samples, idx);
    }
    let dim = samples[idx[0]].x.len();
    let parent_gini = gini(pos as f64, n as f64);

    let mut best: Option<(f64, usize, f64)> = None; // (impurity decrease, feature, threshold)
    for f in 0..dim {
        // Sort indices by feature value; candidate thresholds are midpoints
        // between distinct consecutive values.
        let mut order: Vec<usize> = idx.to_vec();
        order.sort_by(|&a, &b| samples[a].x[f].partial_cmp(&samples[b].x[f]).unwrap());
        let mut left_n = 0.0;
        let mut left_pos = 0.0;
        let total_pos = pos as f64;
        for w in 0..n - 1 {
            let i = order[w];
            left_n += 1.0;
            if samples[i].y {
                left_pos += 1.0;
            }
            let a = samples[order[w]].x[f];
            let b = samples[order[w + 1]].x[f];
            if a == b {
                continue;
            }
            let right_n = n as f64 - left_n;
            if (left_n as usize) < params.min_samples_leaf
                || (right_n as usize) < params.min_samples_leaf
            {
                continue;
            }
            let g = (left_n / n as f64) * gini(left_pos, left_n)
                + (right_n / n as f64) * gini(total_pos - left_pos, right_n);
            let gain = parent_gini - g;
            if best.map_or(true, |(bg, _, _)| gain > bg) {
                best = Some((gain, f, (a + b) / 2.0));
            }
        }
    }

    match best {
        Some((gain, feature, threshold)) if gain > 1e-12 => {
            let (li, ri): (Vec<usize>, Vec<usize>) = idx
                .iter()
                .partition(|&&i| samples[i].x[feature] <= threshold);
            Node::Split {
                feature,
                threshold,
                left: Box::new(build(samples, &li, params, depth + 1)),
                right: Box::new(build(samples, &ri, params, depth + 1)),
            }
        }
        _ => leaf(samples, idx),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;

    fn xor_data(n: usize, seed: u64) -> Vec<Sample> {
        let mut rng = Pcg32::new(seed);
        (0..n)
            .map(|_| {
                let a = rng.f64();
                let b = rng.f64();
                Sample {
                    x: vec![a, b],
                    y: (a > 0.5) != (b > 0.5),
                }
            })
            .collect()
    }

    #[test]
    fn learns_axis_aligned_rule() {
        let data: Vec<Sample> = (0..100)
            .map(|i| Sample {
                x: vec![i as f64 / 100.0],
                y: i >= 30,
            })
            .collect();
        let t = DecisionTree::fit(&data, TreeParams::default());
        assert!(!t.predict(&[0.1]));
        assert!(t.predict(&[0.9]));
        assert!(t.depth() >= 1);
    }

    #[test]
    fn learns_xor_with_depth() {
        let data = xor_data(400, 1);
        let t = DecisionTree::fit(
            &data,
            TreeParams {
                max_depth: 4,
                min_samples_leaf: 2,
            },
        );
        let acc = data
            .iter()
            .filter(|s| t.predict(&s.x) == s.y)
            .count() as f64
            / data.len() as f64;
        assert!(acc > 0.95, "xor train accuracy {acc}");
    }

    #[test]
    fn pure_node_becomes_leaf() {
        let data: Vec<Sample> = (0..10)
            .map(|i| Sample {
                x: vec![i as f64],
                y: true,
            })
            .collect();
        let t = DecisionTree::fit(&data, TreeParams::default());
        assert_eq!(t.depth(), 0);
        assert!((t.predict_proba(&[3.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn respects_max_depth() {
        let data = xor_data(300, 2);
        let t = DecisionTree::fit(
            &data,
            TreeParams {
                max_depth: 2,
                min_samples_leaf: 1,
            },
        );
        assert!(t.depth() <= 2);
    }

    #[test]
    fn constant_features_yield_leaf() {
        let data: Vec<Sample> = (0..20)
            .map(|i| Sample {
                x: vec![1.0, 1.0],
                y: i % 2 == 0,
            })
            .collect();
        let t = DecisionTree::fit(&data, TreeParams::default());
        assert_eq!(t.depth(), 0);
        assert!((t.predict_proba(&[1.0, 1.0]) - 0.5).abs() < 1e-12);
    }
}
