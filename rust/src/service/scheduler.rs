//! Scheduling policies and the service event loop.
//!
//! Each running job is driven by a lightweight coordinator thread that
//! executes the unmodified [`run_with_provider`] driver; the probability
//! provider ships every level frontier to the scheduler as a
//! [`BatchRequest`] and blocks for the probabilities. The scheduler orders
//! pending requests by policy and fires them at the shared
//! [`AnalyzerPool`], so the level-by-level progress of different slides
//! interleaves on the same workers. Because the provider returns exactly
//! what a standalone run would compute, a job's ExecTree is identical to
//! `run_pyramidal` / `SlidePredictions::replay` no matter how the
//! scheduler interleaved it.
//!
//! [`run_with_provider`]: crate::pyramid::driver::run_with_provider

use std::collections::HashMap;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::predcache::SlidePredictions;
use crate::preprocess::otsu::background_removal;
use crate::pyramid::driver::{run_with_provider, BG_MARGIN};
use crate::pyramid::tree::ExecTree;
use crate::slide::pyramid::Slide;
use crate::slide::tile::TileId;

use super::job::{JobId, JobResult, JobState, Priority};
use super::pool::AnalyzerPool;
use super::queue::{AdmissionQueue, QueuedJob};

/// Which job goes next — both at admission (queue → running set) and at
/// batch dispatch (pending frontiers → pool).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Strict submission order.
    Fifo,
    /// Higher [`Priority`] first; submission order breaks ties.
    Priority,
    /// The tenant with the fewest tiles consumed so far goes first, so one
    /// heavy tenant cannot starve the others.
    FairShare,
}

impl Policy {
    pub fn as_str(self) -> &'static str {
        match self {
            Policy::Fifo => "fifo",
            Policy::Priority => "priority",
            Policy::FairShare => "fair",
        }
    }

    pub fn from_str(s: &str) -> Option<Policy> {
        match s {
            "fifo" => Some(Policy::Fifo),
            "priority" => Some(Policy::Priority),
            "fair" | "fair_share" | "fair-share" => Some(Policy::FairShare),
            _ => None,
        }
    }

    /// Pick the next candidate's index. `usage` is tiles consumed per
    /// tenant (fair-share state). Ties always fall back to submission
    /// order (lowest job id), which makes every policy deterministic for a
    /// fixed candidate set.
    pub fn select(self, cands: &[Candidate<'_>], usage: &HashMap<String, u64>) -> Option<usize> {
        if cands.is_empty() {
            return None;
        }
        let idx = match self {
            Policy::Fifo => {
                cands
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, c)| c.id)
                    .unwrap()
                    .0
            }
            Policy::Priority => {
                cands
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, c)| (std::cmp::Reverse(c.priority.rank()), c.id))
                    .unwrap()
                    .0
            }
            Policy::FairShare => {
                cands
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, c)| (usage.get(c.tenant).copied().unwrap_or(0), c.id))
                    .unwrap()
                    .0
            }
        };
        Some(idx)
    }
}

/// What a policy needs to know about one schedulable unit.
#[derive(Debug, Clone, Copy)]
pub struct Candidate<'a> {
    pub id: JobId,
    pub priority: Priority,
    pub tenant: &'a str,
}

/// One level frontier of one job, awaiting pool time.
pub(crate) struct BatchRequest {
    pub id: JobId,
    pub level: usize,
    pub tiles: Vec<TileId>,
    pub reply: Sender<Vec<f32>>,
}

/// Scheduler-internal events (coordinators and the service handle feed
/// these into the loop).
pub(crate) enum Event {
    /// New submissions may be waiting in the admission queue.
    JobsAvailable,
    /// A queued job was removed by `AnalysisService::cancel`.
    Cancelled(QueuedJob),
    /// A coordinator wants its next frontier analyzed.
    Batch(BatchRequest),
    /// A coordinator finished (tree) or its driver panicked (message).
    Done {
        id: JobId,
        outcome: Result<ExecTree, String>,
    },
    /// Admission is closed; exit once everything drains.
    Close,
}

/// Scheduler tuning knobs.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    pub policy: Policy,
    /// How many jobs may be in the running set at once. Small values make
    /// the policy order starkly visible; larger values increase overlap.
    pub max_in_flight: usize,
    /// Analysis chunk size within one frontier batch.
    pub batch: usize,
}

#[derive(Clone)]
enum RunSource {
    Live(Arc<Slide>),
    Cached(Arc<SlidePredictions>),
}

struct RunningJob {
    slide_id: String,
    tenant: String,
    priority: Priority,
    source: RunSource,
    queue_wait: Duration,
    started: Instant,
    tiles: usize,
    /// The coordinator thread; reaped when its `Done` event is handled so
    /// handles don't accumulate over a long-lived service.
    handle: std::thread::JoinHandle<()>,
}

pub(crate) struct Scheduler {
    cfg: SchedulerConfig,
    queue: Arc<AdmissionQueue>,
    pool: Arc<AnalyzerPool>,
    events_tx: Sender<Event>,
    running: HashMap<JobId, RunningJob>,
    pending: Vec<BatchRequest>,
    usage: HashMap<String, u64>,
    results: Vec<JobResult>,
    closed: bool,
}

impl Scheduler {
    pub(crate) fn new(
        cfg: SchedulerConfig,
        queue: Arc<AdmissionQueue>,
        pool: Arc<AnalyzerPool>,
        events_tx: Sender<Event>,
    ) -> Scheduler {
        Scheduler {
            cfg,
            queue,
            pool,
            events_tx,
            running: HashMap::new(),
            pending: Vec::new(),
            usage: HashMap::new(),
            results: Vec::new(),
            closed: false,
        }
    }

    /// The event loop. Returns every job's terminal record, in completion
    /// order.
    pub(crate) fn run(mut self, rx: Receiver<Event>) -> Vec<JobResult> {
        loop {
            while let Ok(ev) = rx.try_recv() {
                self.handle(ev);
            }
            self.admit();
            self.dispatch();
            if self.closed && self.running.is_empty() && self.queue.is_empty() {
                break;
            }
            match rx.recv() {
                Ok(ev) => self.handle(ev),
                Err(_) => break, // every sender gone: nothing can arrive
            }
        }
        self.results
    }

    fn handle(&mut self, ev: Event) {
        match ev {
            Event::JobsAvailable => {}
            Event::Cancelled(q) => {
                self.results.push(JobResult {
                    id: q.id,
                    slide_id: q.spec.source.slide_id().to_string(),
                    tenant: q.spec.tenant,
                    priority: q.spec.priority,
                    state: JobState::Cancelled,
                    tree: None,
                    queue_wait: q.submitted.elapsed(),
                    run_time: Duration::ZERO,
                    tiles: 0,
                });
            }
            Event::Batch(req) => self.pending.push(req),
            Event::Done { id, outcome } => {
                let r = self.running.remove(&id).expect("done job was running");
                // The coordinator sent Done as its last action; reap it now
                // instead of accumulating handles for the service lifetime.
                let _ = r.handle.join();
                let (state, tree, tiles) = match outcome {
                    Ok(tree) => {
                        let tiles = tree.total_analyzed();
                        (JobState::Completed, Some(tree), tiles)
                    }
                    Err(msg) => (JobState::Failed(msg), None, r.tiles),
                };
                self.results.push(JobResult {
                    id,
                    slide_id: r.slide_id,
                    tenant: r.tenant,
                    priority: r.priority,
                    state,
                    tree,
                    queue_wait: r.queue_wait,
                    run_time: r.started.elapsed(),
                    tiles,
                });
            }
            Event::Close => self.closed = true,
        }
    }

    /// Move jobs from the admission queue into the running set, in policy
    /// order, up to `max_in_flight`. Jobs whose deadline lapsed while they
    /// waited are dropped here (`Expired`) instead of running late.
    fn admit(&mut self) {
        while self.running.len() < self.cfg.max_in_flight.max(1) {
            let picked = self.queue.pop_with(|entries| {
                let cands: Vec<Candidate<'_>> = entries
                    .iter()
                    .map(|q| Candidate {
                        id: q.id,
                        priority: q.spec.priority,
                        tenant: &q.spec.tenant,
                    })
                    .collect();
                self.cfg.policy.select(&cands, &self.usage)
            });
            let Some(q) = picked else { break };
            let waited = q.submitted.elapsed();
            if q.spec.deadline.map_or(false, |d| waited > d) {
                self.results.push(JobResult {
                    id: q.id,
                    slide_id: q.spec.source.slide_id().to_string(),
                    tenant: q.spec.tenant,
                    priority: q.spec.priority,
                    state: JobState::Expired,
                    tree: None,
                    queue_wait: waited,
                    run_time: Duration::ZERO,
                    tiles: 0,
                });
                continue;
            }
            self.start_job(q, waited);
        }
    }

    fn start_job(&mut self, q: QueuedJob, queue_wait: Duration) {
        use super::job::JobSource;
        let source = match &q.spec.source {
            JobSource::Spec(spec) => RunSource::Live(Arc::new(Slide::from_spec(spec.clone()))),
            JobSource::Cached(c) => RunSource::Cached(Arc::clone(c)),
        };
        let coord_source = source.clone();
        let events = self.events_tx.clone();
        let thresholds = q.spec.thresholds.clone();
        let id = q.id;
        let handle = std::thread::Builder::new()
            .name(format!("job-{id}"))
            .spawn(move || {
                let events_for_provider = events.clone();
                let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    let (slide_id, levels, initial) = match &coord_source {
                        RunSource::Live(slide) => (
                            slide.id().to_string(),
                            slide.levels(),
                            background_removal(slide, BG_MARGIN).tissue_tiles,
                        ),
                        RunSource::Cached(c) => {
                            (c.spec.id.clone(), c.spec.levels, c.initial.clone())
                        }
                    };
                    run_with_provider(&slide_id, levels, initial, &thresholds, |level, tiles| {
                        let (tx, rx) = std::sync::mpsc::channel();
                        events_for_provider
                            .send(Event::Batch(BatchRequest {
                                id,
                                level,
                                tiles: tiles.to_vec(),
                                reply: tx,
                            }))
                            .expect("scheduler alive");
                        rx.recv().expect("scheduler replies to batch")
                    })
                }));
                let outcome = outcome.map_err(|p| panic_message(&p));
                let _ = events.send(Event::Done { id, outcome });
            })
            .expect("spawn job coordinator");
        // Insert after spawning so the handle rides along; the coordinator's
        // first Batch event is only processed by this same thread after
        // start_job returns, so the entry is in place in time.
        self.running.insert(
            q.id,
            RunningJob {
                slide_id: q.spec.source.slide_id().to_string(),
                tenant: q.spec.tenant.clone(),
                priority: q.spec.priority,
                source,
                queue_wait,
                started: Instant::now(),
                tiles: 0,
                handle,
            },
        );
    }

    /// Fire every pending frontier at the pool, in policy order. Dispatch
    /// is asynchronous, so batches of different jobs overlap on the pool;
    /// the order still matters because the pool serves its queue FIFO.
    fn dispatch(&mut self) {
        loop {
            let idx = {
                let cands: Vec<Candidate<'_>> = self
                    .pending
                    .iter()
                    .map(|req| {
                        let r = self.running.get(&req.id).expect("pending implies running");
                        Candidate {
                            id: req.id,
                            priority: r.priority,
                            tenant: &r.tenant,
                        }
                    })
                    .collect();
                self.cfg.policy.select(&cands, &self.usage)
            };
            let Some(idx) = idx else { break };
            let req = self.pending.remove(idx);
            let ntiles = req.tiles.len();
            let r = self.running.get_mut(&req.id).expect("pending implies running");
            r.tiles += ntiles;
            *self.usage.entry(r.tenant.clone()).or_default() += ntiles as u64;
            match &r.source {
                RunSource::Live(slide) => {
                    let reply = req.reply;
                    self.pool.analyze_async(
                        Arc::clone(slide),
                        req.level,
                        req.tiles,
                        self.cfg.batch,
                        Box::new(move |ps| {
                            let _ = reply.send(ps);
                        }),
                    );
                }
                RunSource::Cached(c) => {
                    // Replay: look the frontier up in the cache. A missing
                    // lineage tile means a corrupt cache; reply short so
                    // the driver's count check fails that one job.
                    let probs: Vec<f32> = req
                        .tiles
                        .iter()
                        .filter_map(|t| c.preds.get(t).map(|p| p.prob))
                        .collect();
                    let _ = req.reply.send(probs);
                }
            }
        }
    }
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "job coordinator panicked".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cands<'a>(v: &'a [(JobId, Priority, &'a str)]) -> Vec<Candidate<'a>> {
        v.iter()
            .map(|&(id, priority, tenant)| Candidate {
                id,
                priority,
                tenant,
            })
            .collect()
    }

    #[test]
    fn fifo_picks_lowest_id() {
        let c = cands(&[
            (3, Priority::High, "a"),
            (1, Priority::Low, "b"),
            (2, Priority::High, "a"),
        ]);
        assert_eq!(Policy::Fifo.select(&c, &HashMap::new()), Some(1));
        assert_eq!(Policy::Fifo.select(&[], &HashMap::new()), None);
    }

    #[test]
    fn priority_beats_submission_order_with_fifo_tiebreak() {
        let c = cands(&[
            (1, Priority::Normal, "a"),
            (2, Priority::High, "a"),
            (3, Priority::High, "a"),
        ]);
        // Both high-priority jobs beat job 1; id 2 beats id 3.
        assert_eq!(Policy::Priority.select(&c, &HashMap::new()), Some(1));
    }

    #[test]
    fn fair_share_prefers_least_served_tenant() {
        let c = cands(&[
            (1, Priority::Normal, "heavy"),
            (2, Priority::Normal, "light"),
        ]);
        let mut usage = HashMap::new();
        usage.insert("heavy".to_string(), 500u64);
        assert_eq!(Policy::FairShare.select(&c, &usage), Some(1));
        // Unknown tenants count as zero usage; ties fall back to FIFO.
        usage.insert("heavy".to_string(), 0);
        assert_eq!(Policy::FairShare.select(&c, &usage), Some(0));
    }

    #[test]
    fn policy_strings_roundtrip() {
        for p in [Policy::Fifo, Policy::Priority, Policy::FairShare] {
            assert_eq!(Policy::from_str(p.as_str()), Some(p));
        }
        assert_eq!(Policy::from_str("fair_share"), Some(Policy::FairShare));
        assert_eq!(Policy::from_str("lifo"), None);
    }
}
