//! The service event loop over the shared scheduling-policy core.
//!
//! Each running job is a [`PyramidRun`] state machine stepped *directly*
//! by the scheduler — no coordinator threads, no blocking providers. The
//! loop pulls every available [`FrontierRequest`] from every running job,
//! orders them by the configured [`SchedulingPolicy`], and fires them at
//! the job's execution substrate: the shared [`AnalyzerPool`] (same-level
//! requests from different jobs coalesce into one dispatch group), an
//! inline predcache replay (pinned `Arc` or streamed through a budgeted
//! [`ShardedPredStore`]), or the persistent TCP cluster
//! ([`ClusterExec`]). Completions come back as events and are fed into
//! the owning run; because a run's tree depends only on what was
//! analyzed — never on scheduling or feed order — a job's ExecTree is
//! identical to a standalone `run_pyramidal` / `SlidePredictions::replay`
//! no matter how the scheduler interleaved, preempted or resumed it.
//!
//! The policy object is consulted at three points, the same three the
//! workload simulator ([`crate::sim::engine::simulate_workload`]) drives
//! with the *same trait objects*:
//!
//! * **admission** — queued and parked jobs compete for free running
//!   slots ([`SchedulingPolicy::select`]), gated by per-tenant quotas
//!   ([`SchedulingPolicy::admit`]);
//! * **dispatch** — pending frontier requests drain in policy order with
//!   live per-tenant usage accounting;
//! * **preemption** — with [`SchedulerConfig::preempt`], a waiting
//!   candidate that [`SchedulingPolicy::preempts`] a running job parks
//!   that job at its next level-frontier boundary: the run stops being
//!   issued requests, its in-flight chunks drain, and the suspended
//!   [`PyramidRun`] moves to the parked set with its partial state
//!   intact. Resuming simply re-enters it into the running set — the
//!   final tree is byte-identical to an uninterrupted run.
//!
//! [`PyramidRun`]: crate::pyramid::PyramidRun
//! [`FrontierRequest`]: crate::pyramid::FrontierRequest
//! [`AnalyzerPool`]: crate::service::pool::AnalyzerPool
//! [`ClusterExec`]: crate::cluster::ClusterExec
//! [`SchedulingPolicy`]: crate::sched::SchedulingPolicy

use std::collections::{HashMap, HashSet};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::cluster::ClusterExec;
use crate::obs::metrics::{Counter, Histogram, Registry};
use crate::obs::{self, Level};
use crate::predcache::{ShardedPredStore, SlidePredictions};
use crate::preprocess::otsu::background_removal;
use crate::pyramid::driver::BG_MARGIN;
use crate::pyramid::{FrontierRequest, PyramidRun, RequestId};
use crate::sched::{
    aged_rank, pick_admission, pick_preemption_victims, SchedCandidate, SchedContext,
    SchedulingPolicy,
};
use crate::slide::pyramid::Slide;
use crate::synth::slide_gen::SlideSpec;

use super::board::{JobBoard, JobPhase};
use super::job::{JobId, JobResult, JobState, Priority};
use super::pool::{AnalyzerPool, CoalescedItem};
use super::queue::{AdmissionQueue, QueuedJob};

/// Scheduler-internal events (submitters, completion callbacks and the
/// cluster pump feed these into the loop).
pub(crate) enum Event {
    /// New submissions may be waiting in the admission queue.
    JobsAvailable,
    /// A queued job was removed by `AnalysisService::cancel`.
    Cancelled(QueuedJob),
    /// Cancel a *running or parked* job at its next frontier boundary.
    CancelRunning(JobId),
    /// One frontier chunk finished on some substrate.
    ChunkDone {
        job: JobId,
        req: RequestId,
        probs: Vec<f32>,
    },
    /// The cluster abandoned one dispatched chunk (every worker that
    /// could run it died — [`crate::cluster::ExecEvent::Lost`]). The
    /// owning run requeues it and the ordinary pump/dispatch path
    /// re-fires it, with a fresh excluded-victim list.
    ChunkLost { job: JobId, req: RequestId },
    /// The cluster leader's dispatch state was discarded wholesale
    /// ([`crate::cluster::ExecEvent::Failover`]): a standby took over, or
    /// failure injection simulated one. Every in-flight cluster chunk is
    /// gone; the owning runs requeue *all* outstanding work and the
    /// ordinary dispatch path re-fires it on the (re-registered) workers.
    LeaderFailover,
    /// Admission is closed; exit once everything drains.
    Close,
}

/// Pack a (job, request) pair into the cluster routing key. Keys travel
/// the wire as JSON numbers (f64), which are exact only below 2⁵³ — so
/// the request id gets 21 bits (a run issues one id per frontier chunk,
/// far below 2²¹) and the job id 32, keeping every key exactly
/// representable. Checked in release builds too: a rounded key would
/// silently misroute probabilities.
pub(crate) fn pack_key(job: JobId, req: RequestId) -> u64 {
    assert!(
        job < (1 << 32) && req < (1 << 21),
        "cluster routing key overflow (job {job}, request {req})"
    );
    (job << 21) | req
}

/// Inverse of [`pack_key`].
pub(crate) fn unpack_key(key: u64) -> (JobId, RequestId) {
    (key >> 21, key & ((1 << 21) - 1))
}

/// Owned snapshot of one candidate: (job id, priority rank, tenant,
/// arrival µs, absolute deadline µs). Snapshots decouple policy
/// consultation from the scheduler's mutable state.
type CandTuple = (JobId, u8, String, u64, Option<u64>);

fn tuple_cand(o: &CandTuple) -> SchedCandidate<'_> {
    SchedCandidate {
        job: o.0,
        priority_rank: o.1,
        tenant: o.2.as_str(),
        arrival: o.3,
        deadline: o.4,
    }
}

/// Scheduler tuning knobs (the policy object travels separately — it is
/// a trait object, not `Clone`).
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// How many jobs may be in the running set at once. Small values make
    /// the policy order starkly visible; larger values increase overlap.
    pub max_in_flight: usize,
    /// Analysis chunk size: both the PyramidRun request granularity and
    /// the pool's per-task tile count.
    pub batch: usize,
    /// Merge same-level requests from different jobs into one pool
    /// dispatch group (amortizes per-dispatch overhead).
    pub coalesce: bool,
    /// Allow the policy to park running jobs at frontier boundaries in
    /// favor of waiting ones ([`crate::sched::SchedulingPolicy::preempts`]).
    pub preempt: bool,
    /// Starvation aging for parked jobs: every elapsed interval of parked
    /// time raises the job's effective priority rank by one
    /// ([`crate::sched::aged_rank`]), and the earned boost is frozen into
    /// the job on resume so it cannot be re-preempted by the same
    /// sustained high-priority stream forever. `None` disables aging.
    pub park_aging: Option<Duration>,
}

/// Where one job's frontier requests execute.
enum JobExec {
    /// Live analysis through the shared pool.
    Pool(Arc<Slide>),
    /// Inline predcache replay (no analyzer time).
    Replay(Arc<SlidePredictions>),
    /// Inline streamed replay: each chunk re-resolves the slide through
    /// the sharded store, so its LRU may evict the shard between chunks
    /// — nothing is pinned for the job's lifetime.
    Sharded {
        store: Arc<ShardedPredStore>,
        slide: usize,
    },
    /// Chunks dealt to the persistent TCP cluster.
    Cluster(SlideSpec),
}

struct RunningJob {
    slide_id: String,
    tenant: String,
    priority: Priority,
    /// Arrival stamp (queue submission time) — EDF/queue-age input.
    submitted: Instant,
    /// Relative deadline from the job spec (EDF ranks by `submitted +
    /// deadline`).
    deadline: Option<Duration>,
    queue_wait: Duration,
    /// Start of the job's *first* running segment — preserved across
    /// park/resume, so `run_time` spans first start → terminal event,
    /// parked intervals included (the victim-side cost of preemption,
    /// matching the simulator's completed-minus-admitted turnaround).
    first_started: Instant,
    run: PyramidRun,
    exec: JobExec,
    /// Tiles dispatched so far (metrics; counts even chunks that later
    /// fail).
    tiles: usize,
    /// Chunks fired and not yet completed — a job never finalizes or
    /// parks while this is nonzero, so no pool/cluster work ever leaks
    /// into a dead or suspended job.
    dispatched: usize,
    /// Preemption requested: stop issuing requests and move to the parked
    /// set at the next frontier boundary (once in-flight chunks drain).
    parking: bool,
    /// Times this job has been parked so far.
    preemptions: usize,
    /// Starvation-aging rank boost frozen in at the last resume: the
    /// job's effective rank is `priority.rank() + boost`, which keeps a
    /// previously starved job from being immediately re-victimized.
    boost: u8,
    cancelled: bool,
    failed: Option<String>,
}

/// A job suspended at a level-frontier boundary: the [`PyramidRun`] holds
/// the completed levels and the next frontier, unissued. Resuming is
/// just re-entering the running set — nothing about the run is rebuilt.
struct ParkedJob {
    slide_id: String,
    tenant: String,
    priority: Priority,
    submitted: Instant,
    deadline: Option<Duration>,
    queue_wait: Duration,
    first_started: Instant,
    run: PyramidRun,
    exec: JobExec,
    tiles: usize,
    preemptions: usize,
    /// When the job parked — the aging clock for
    /// [`SchedulerConfig::park_aging`].
    parked_at: Instant,
    /// Rank boost carried from previous park/resume cycles (see
    /// `RunningJob::boost`). While parked, the *effective* rank also
    /// includes the age earned since `parked_at`.
    boost: u8,
}

/// Metric handles resolved once at construction, so hot-path recording
/// is a single relaxed atomic op per event. Counter names are shared
/// verbatim with [`crate::sim::engine::simulate_workload`]'s virtual-time
/// registry, making service and sim snapshots directly comparable.
struct SchedObs {
    jobs_admitted: Arc<Counter>,
    jobs_parked: Arc<Counter>,
    jobs_resumed: Arc<Counter>,
    chunks_dealt: Arc<Counter>,
    chunks_requeued: Arc<Counter>,
    leader_failovers: Arc<Counter>,
    queue_wait_us: Arc<Histogram>,
    run_time_us: Arc<Histogram>,
    chunk_latency_us: Arc<Histogram>,
}

impl SchedObs {
    fn new(registry: &Registry) -> SchedObs {
        // Touch the steal counter so parity snapshots always carry it,
        // even for workloads where nothing is ever stolen.
        registry.counter("sched.chunks_stolen");
        SchedObs {
            jobs_admitted: registry.counter("sched.jobs_admitted"),
            jobs_parked: registry.counter("sched.jobs_parked"),
            jobs_resumed: registry.counter("sched.jobs_resumed"),
            chunks_dealt: registry.counter("sched.chunks_dealt"),
            chunks_requeued: registry.counter("sched.chunks_requeued"),
            leader_failovers: registry.counter("sched.leader_failovers"),
            queue_wait_us: registry.histogram("sched.queue_wait_us"),
            run_time_us: registry.histogram("sched.run_time_us"),
            chunk_latency_us: registry.histogram("sched.chunk_latency_us"),
        }
    }
}

pub(crate) struct Scheduler {
    cfg: SchedulerConfig,
    policy: Box<dyn SchedulingPolicy>,
    queue: Arc<AdmissionQueue>,
    pool: Arc<AnalyzerPool>,
    /// Present when the service runs its live jobs on the TCP cluster.
    cluster: Option<Arc<ClusterExec>>,
    events_tx: Sender<Event>,
    /// Policy clock origin: candidate times are µs since this instant.
    epoch: Instant,
    running: HashMap<JobId, RunningJob>,
    /// Jobs suspended at a frontier boundary, waiting to resume.
    parked: HashMap<JobId, ParkedJob>,
    /// Mirror of the running ∪ parked key set shared with the service
    /// handle so `cancel` can tell live jobs from unknown ones.
    running_ids: Arc<Mutex<HashSet<JobId>>>,
    pending: Vec<(JobId, FrontierRequest)>,
    usage: HashMap<String, u64>,
    results: Vec<JobResult>,
    closed: bool,
    obs: SchedObs,
    /// Fire stamp of every in-flight chunk, keyed by the routing key —
    /// feeds the dispatch→completion latency histogram.
    chunk_fired: HashMap<u64, Instant>,
    /// Progress board external consumers (the HTTP front-end) observe:
    /// the scheduler publishes phase transitions, per-level tree deltas
    /// and terminal records here.
    board: Arc<JobBoard>,
}

impl Scheduler {
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn new(
        cfg: SchedulerConfig,
        policy: Box<dyn SchedulingPolicy>,
        queue: Arc<AdmissionQueue>,
        pool: Arc<AnalyzerPool>,
        cluster: Option<Arc<ClusterExec>>,
        events_tx: Sender<Event>,
        running_ids: Arc<Mutex<HashSet<JobId>>>,
        registry: Arc<Registry>,
        board: Arc<JobBoard>,
    ) -> Scheduler {
        let obs = SchedObs::new(&registry);
        Scheduler {
            cfg,
            policy,
            queue,
            pool,
            cluster,
            events_tx,
            epoch: Instant::now(),
            running: HashMap::new(),
            parked: HashMap::new(),
            running_ids,
            pending: Vec::new(),
            usage: HashMap::new(),
            results: Vec::new(),
            closed: false,
            obs,
            chunk_fired: HashMap::new(),
            board,
        }
    }

    /// The event loop. Returns every job's terminal record, in completion
    /// order.
    pub(crate) fn run(mut self, rx: Receiver<Event>) -> Vec<JobResult> {
        loop {
            while let Ok(ev) = rx.try_recv() {
                self.handle(ev);
            }
            // Step until quiescent: finalizing or parking a job frees an
            // admission slot, so admission must re-run before the loop
            // may block.
            loop {
                self.admit();
                self.maybe_preempt();
                self.pump();
                self.dispatch();
                if self.settle() == 0 {
                    break;
                }
            }
            if self.closed
                && self.running.is_empty()
                && self.parked.is_empty()
                && self.queue.is_empty()
            {
                break;
            }
            match rx.recv() {
                Ok(ev) => self.handle(ev),
                Err(_) => break, // every sender gone: nothing can arrive
            }
        }
        self.results
    }

    fn handle(&mut self, ev: Event) {
        match ev {
            Event::JobsAvailable => {}
            Event::Cancelled(q) => {
                let res = JobResult {
                    id: q.id,
                    slide_id: q.spec.source.slide_id().to_string(),
                    tenant: q.spec.tenant,
                    priority: q.spec.priority,
                    state: JobState::Cancelled,
                    tree: None,
                    queue_wait: q.submitted.elapsed(),
                    run_time: Duration::ZERO,
                    tiles: 0,
                    preemptions: 0,
                };
                self.board.finished(q.id, &res);
                self.results.push(res);
            }
            Event::CancelRunning(id) => {
                if let Some(r) = self.running.get_mut(&id) {
                    r.cancelled = true;
                    // Undispatched requests of this job will never run;
                    // in-flight ones drain normally and feed the run, so
                    // the job stops exactly at a frontier boundary.
                    self.pending.retain(|(j, _)| *j != id);
                } else if let Some(p) = self.parked.remove(&id) {
                    // A parked job has no in-flight work: finalize now
                    // with the partial tree of its completed levels.
                    self.running_ids.lock().unwrap().remove(&id);
                    let tree = p.run.finish();
                    let tiles = tree.total_analyzed();
                    let res = JobResult {
                        id,
                        slide_id: p.slide_id,
                        tenant: p.tenant,
                        priority: p.priority,
                        state: JobState::Cancelled,
                        tree: Some(tree),
                        queue_wait: p.queue_wait,
                        run_time: p.first_started.elapsed(),
                        tiles,
                        preemptions: p.preemptions,
                    };
                    self.board.finished(id, &res);
                    self.results.push(res);
                }
            }
            Event::ChunkDone { job, req, probs } => {
                if let Some(t0) = self.chunk_fired.remove(&pack_key(job, req)) {
                    self.obs.chunk_latency_us.record_duration(t0.elapsed());
                }
                obs::event(
                    Level::Trace,
                    "sched",
                    "chunk_done",
                    &[
                        ("job", job.into()),
                        ("req", req.into()),
                        ("key", pack_key(job, req).into()),
                        ("probs", probs.len().into()),
                    ],
                );
                let mut failed_now = false;
                if let Some(r) = self.running.get_mut(&job) {
                    r.dispatched = r.dispatched.saturating_sub(1);
                    if r.failed.is_none() {
                        if let Err(e) = r.run.feed(req, probs) {
                            r.failed = Some(e.to_string());
                            failed_now = true;
                        }
                    }
                }
                if failed_now {
                    // Its undispatched requests will never be needed.
                    self.pending.retain(|(j, _)| *j != job);
                } else if let Some(r) = self.running.get(&job) {
                    // Publish any level this feed finalized, so streaming
                    // consumers see coarse results while finer levels are
                    // still being analyzed.
                    self.board.progress(job, &r.run);
                }
            }
            Event::ChunkLost { job, req } => {
                self.chunk_fired.remove(&pack_key(job, req));
                obs::event(
                    Level::Warn,
                    "sched",
                    "chunk_lost",
                    &[
                        ("job", job.into()),
                        ("req", req.into()),
                        ("key", pack_key(job, req).into()),
                    ],
                );
                if let Some(r) = self.running.get_mut(&job) {
                    r.dispatched = r.dispatched.saturating_sub(1);
                    // Cancelled/failed jobs just drain; healthy ones get
                    // the span back for re-dispatch (the tree cannot
                    // change — only when it materializes).
                    if !r.cancelled && r.failed.is_none() {
                        let _ = r.run.requeue(req);
                        self.obs.chunks_requeued.inc();
                    }
                }
            }
            Event::LeaderFailover => {
                self.obs.leader_failovers.inc();
                let mut requeued = 0usize;
                let mut jobs_hit = 0usize;
                for (id, r) in self.running.iter_mut() {
                    if !matches!(r.exec, JobExec::Cluster(_)) {
                        continue;
                    }
                    // Every chunk this job had on the old leader —
                    // dispatched or still queued behind the policy — is
                    // re-issued from scratch: the dispatched ones died
                    // with the leader's pending map, and the queued ones
                    // hold request ids the requeue below invalidates.
                    self.pending.retain(|(j, _)| j != id);
                    if r.cancelled || r.failed.is_some() {
                        // Draining jobs only waited for their in-flight
                        // chunks, which no longer exist.
                        r.dispatched = 0;
                        continue;
                    }
                    let n = r.run.requeue_all_outstanding();
                    r.dispatched = 0;
                    requeued += n;
                    if n > 0 {
                        jobs_hit += 1;
                    }
                }
                self.obs.chunks_requeued.add(requeued as u64);
                self.chunk_fired.retain(|key, _| {
                    let (job, _) = unpack_key(*key);
                    !matches!(
                        self.running.get(&job).map(|r| &r.exec),
                        Some(JobExec::Cluster(_))
                    )
                });
                obs::event(
                    Level::Warn,
                    "sched",
                    "leader_failover",
                    &[
                        ("jobs", jobs_hit.into()),
                        ("chunks_requeued", requeued.into()),
                    ],
                );
            }
            Event::Close => self.closed = true,
        }
    }

    fn slots(&self) -> usize {
        self.cfg.max_in_flight.max(1)
    }

    fn now_micros(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    fn micros_of(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.epoch).as_micros() as u64
    }

    fn abs_deadline(&self, submitted: Instant, deadline: Option<Duration>) -> Option<u64> {
        deadline.map(|d| self.micros_of(submitted) + d.as_micros() as u64)
    }

    fn running_per_tenant(&self) -> HashMap<String, usize> {
        let mut m = HashMap::new();
        for r in self.running.values() {
            *m.entry(r.tenant.clone()).or_insert(0) += 1;
        }
        m
    }

    fn queued_tuple(&self, q: &QueuedJob) -> CandTuple {
        (
            q.id,
            q.spec.priority.rank(),
            q.spec.tenant.clone(),
            self.micros_of(q.submitted),
            self.abs_deadline(q.submitted, q.spec.deadline),
        )
    }

    /// Park-aging interval in µs (0 disables — [`aged_rank`]'s contract).
    fn aging_interval_us(&self) -> u64 {
        self.cfg
            .park_aging
            .map(|d| d.as_micros() as u64)
            .unwrap_or(0)
    }

    /// A parked candidate's effective rank grows while it waits: frozen
    /// boost from earlier cycles plus one rank per elapsed aging
    /// interval. This is what breaks starvation under a sustained
    /// high-priority stream — the parked job eventually outranks the
    /// newcomers.
    fn parked_tuple(&self, id: JobId, p: &ParkedJob) -> CandTuple {
        let base = p.priority.rank().saturating_add(p.boost);
        let waited = p.parked_at.elapsed().as_micros() as u64;
        (
            id,
            aged_rank(base, waited, self.aging_interval_us()),
            p.tenant.clone(),
            self.micros_of(p.submitted),
            self.abs_deadline(p.submitted, p.deadline),
        )
    }

    fn running_tuple(&self, id: JobId, r: &RunningJob) -> CandTuple {
        (
            id,
            // The frozen boost shields a previously starved job from
            // being immediately re-victimized after resume.
            r.priority.rank().saturating_add(r.boost),
            r.tenant.clone(),
            self.micros_of(r.submitted),
            self.abs_deadline(r.submitted, r.deadline),
        )
    }

    /// Fill free running slots, in policy order, from the union of the
    /// admission queue and the parked set — a suspended job competes for
    /// slots exactly like a queued one (its original arrival stamp keeps
    /// its queue-age and EDF standing). Jobs whose deadline lapsed while
    /// they waited in the queue are dropped here (`Expired`) instead of
    /// running late; a parked job already ran, so expiry never applies to
    /// a resume.
    fn admit(&mut self) {
        loop {
            if self.running.len() >= self.slots() {
                return;
            }
            let running_per_tenant = self.running_per_tenant();
            let now = self.now_micros();
            // Owned snapshot of the parked candidates.
            let parked: Vec<CandTuple> = self
                .parked
                .iter()
                .map(|(id, p)| self.parked_tuple(*id, p))
                .collect();
            let mut resume: Option<JobId> = None;
            let this = &*self;
            let picked = this.queue.pop_with(|entries| {
                let ctx = SchedContext {
                    usage: &this.usage,
                    running_per_tenant: &running_per_tenant,
                    now,
                };
                // One construction path for every candidate snapshot
                // (same helpers maybe_preempt uses), so admission and
                // preemption can never rank the same job differently.
                let tuples: Vec<CandTuple> = entries
                    .iter()
                    .map(|q| this.queued_tuple(q))
                    .chain(parked.iter().cloned())
                    .collect();
                let cands: Vec<SchedCandidate<'_>> = tuples.iter().map(tuple_cand).collect();
                let chosen = pick_admission(&*this.policy, &cands, &ctx)?;
                if chosen < entries.len() {
                    // Registered while the queue lock is still held, so
                    // `cancel` always finds a job either queued or
                    // running — no handoff window where a live job looks
                    // unknown.
                    this.running_ids.lock().unwrap().insert(entries[chosen].id);
                    Some(chosen)
                } else {
                    resume = Some(tuples[chosen].0);
                    None
                }
            });
            match (picked, resume) {
                (Some(q), _) => {
                    let waited = q.submitted.elapsed();
                    if q.spec.deadline.map_or(false, |d| waited > d) {
                        obs::event(
                            Level::Warn,
                            "sched",
                            "job_expired",
                            &[
                                ("job", q.id.into()),
                                ("tenant", q.spec.tenant.as_str().into()),
                                ("waited_us", (waited.as_micros() as u64).into()),
                                (
                                    "deadline_us",
                                    (q.spec.deadline.unwrap_or_default().as_micros() as u64)
                                        .into(),
                                ),
                            ],
                        );
                        self.running_ids.lock().unwrap().remove(&q.id);
                        let res = JobResult {
                            id: q.id,
                            slide_id: q.spec.source.slide_id().to_string(),
                            tenant: q.spec.tenant,
                            priority: q.spec.priority,
                            state: JobState::Expired,
                            tree: None,
                            queue_wait: waited,
                            run_time: Duration::ZERO,
                            tiles: 0,
                            preemptions: 0,
                        };
                        self.board.finished(q.id, &res);
                        self.results.push(res);
                        continue;
                    }
                    self.start_job(q, waited);
                }
                (None, Some(id)) => self.resume_job(id),
                (None, None) => return,
            }
        }
    }

    /// Re-enter a parked job into the running set. The suspended
    /// [`PyramidRun`] continues from its frontier boundary; nothing is
    /// re-analyzed, so the final tree is the one an uninterrupted run
    /// would have produced.
    fn resume_job(&mut self, id: JobId) {
        let p = self.parked.remove(&id).expect("resume targets parked job");
        self.obs.jobs_resumed.inc();
        // Freeze the age earned while parked into the job's boost: the
        // effective rank that won this slot keeps protecting the job
        // while it runs (and across any future park).
        let boost = aged_rank(
            p.boost,
            p.parked_at.elapsed().as_micros() as u64,
            self.aging_interval_us(),
        );
        obs::event(
            Level::Info,
            "sched",
            "job_resumed",
            &[
                ("job", id.into()),
                ("slide", p.slide_id.as_str().into()),
                ("policy", self.policy.name().into()),
                ("preemptions", p.preemptions.into()),
                ("boost", boost.into()),
            ],
        );
        self.board.phase(id, JobPhase::Running);
        self.running.insert(
            id,
            RunningJob {
                slide_id: p.slide_id,
                tenant: p.tenant,
                priority: p.priority,
                submitted: p.submitted,
                deadline: p.deadline,
                queue_wait: p.queue_wait,
                first_started: p.first_started,
                run: p.run,
                exec: p.exec,
                tiles: p.tiles,
                dispatched: 0,
                parking: false,
                preemptions: p.preemptions,
                boost,
                cancelled: false,
                failed: None,
            },
        );
    }

    /// When the running set is full and waiting candidates (queued or
    /// parked) outrank running jobs per [`SchedulingPolicy::preempts`],
    /// mark running jobs for parking: each stops being issued requests
    /// and moves to the parked set once its in-flight chunks drain — a
    /// clean suspension at the next level-frontier boundary.
    ///
    /// Multiple jobs may drain concurrently, but churn stays bounded:
    /// the shared core pairs each preempting waiter with exactly one
    /// victim ([`pick_preemption_victims`]), and suspensions already in
    /// flight are counted against the pairing budget — the first
    /// `parking` pairs are treated as satisfied by the jobs already
    /// draining, so a single waiter can never cascade multiple parks.
    fn maybe_preempt(&mut self) {
        if !self.cfg.preempt || self.running.len() < self.slots() {
            return;
        }
        // Suspensions already draining: they will free one slot each, so
        // that many of the strongest waiters need no fresh victim.
        let parking = self.running.values().filter(|r| r.parking).count();
        let running_per_tenant = self.running_per_tenant();
        let now = self.now_micros();
        let ctx = SchedContext {
            usage: &self.usage,
            running_per_tenant: &running_per_tenant,
            now,
        };
        let mut waiting: Vec<CandTuple> = self.queue.peek_with(|entries| {
            entries
                .iter()
                // A job whose deadline already lapsed will be dropped as
                // Expired the moment admission pops it — it must not park
                // a healthy running job on its way out (under EDF a
                // lapsed deadline is the *earliest* deadline, so without
                // this filter it would always win the incoming slot).
                .filter(|q| q.spec.deadline.map_or(true, |d| q.submitted.elapsed() <= d))
                .map(|q| self.queued_tuple(q))
                .collect()
        });
        waiting.extend(self.parked.iter().map(|(id, p)| self.parked_tuple(*id, p)));
        let waiting_cands: Vec<SchedCandidate<'_>> = waiting.iter().map(tuple_cand).collect();
        // Candidate victims: running, healthy, not already suspending.
        let victims: Vec<CandTuple> = self
            .running
            .iter()
            .filter(|(_, r)| !r.cancelled && r.failed.is_none() && !r.parking)
            .map(|(id, r)| self.running_tuple(*id, r))
            .collect();
        let victim_cands: Vec<SchedCandidate<'_>> = victims.iter().map(tuple_cand).collect();
        let pairs = pick_preemption_victims(
            &*self.policy,
            &waiting_cands,
            &victim_cands,
            &ctx,
            parking + victim_cands.len(),
        );
        for (_, vidx) in pairs.into_iter().skip(parking) {
            let victim = victims[vidx].0;
            let r = self.running.get_mut(&victim).expect("victim is running");
            // The preemption *count* is recorded at the actual park
            // transition in settle() — a victim whose draining chunks
            // turn out to complete its run was never really suspended.
            r.parking = true;
            obs::event(
                Level::Info,
                "sched",
                "preempt_marked",
                &[
                    ("job", victim.into()),
                    ("tenant", r.tenant.as_str().into()),
                    ("policy", self.policy.name().into()),
                    ("waiting", waiting.len().into()),
                ],
            );
        }
    }

    /// Materialize a job into a running [`PyramidRun`]. Source faults
    /// (invalid specs) fail the one job, never the scheduler.
    fn start_job(&mut self, q: QueuedJob, queue_wait: Duration) {
        use super::job::JobSource;
        let thresholds = q.spec.thresholds.clone();
        let cluster_mode = self.cluster.is_some();
        // admit() already registered q.id in running_ids (under the queue
        // lock), so `cancel` can see this job throughout the slide
        // materialization below.
        type Prep = Result<
            (
                String,
                usize,
                (usize, usize),
                Vec<crate::slide::tile::TileId>,
                JobExec,
            ),
            String,
        >;
        let prep = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| -> Prep {
            match &q.spec.source {
                JobSource::Spec(spec) => {
                    let grid = (spec.tiles_x, spec.tiles_y);
                    let slide = Arc::new(Slide::from_spec(spec.clone()));
                    let initial = background_removal(&slide, BG_MARGIN).tissue_tiles;
                    let exec = if cluster_mode {
                        JobExec::Cluster(spec.clone())
                    } else {
                        JobExec::Pool(Arc::clone(&slide))
                    };
                    Ok((slide.id().to_string(), slide.levels(), grid, initial, exec))
                }
                JobSource::Cached(c) => Ok((
                    c.spec.id.clone(),
                    c.spec.levels,
                    (c.spec.tiles_x, c.spec.tiles_y),
                    c.initial.clone(),
                    JobExec::Replay(Arc::clone(c)),
                )),
                JobSource::Sharded { store, slide } => {
                    // The first shard load happens here (initial working
                    // set + depth); a corrupt/missing shard fails this
                    // one job, never the scheduler.
                    let preds = store
                        .slide(*slide)
                        .map_err(|e| format!("shard load failed: {e}"))?;
                    // Admission validated the threshold count against the
                    // *manifest* depth; a shard whose spec disagrees with
                    // its manifest row must fail here, not panic the
                    // PyramidRun constructor below.
                    if store.slide_levels(*slide) != Some(preds.spec.levels) {
                        return Err(format!(
                            "shard {} declares {} levels, manifest says {:?}",
                            preds.spec.id,
                            preds.spec.levels,
                            store.slide_levels(*slide)
                        ));
                    }
                    Ok((
                        preds.spec.id.clone(),
                        preds.spec.levels,
                        (preds.spec.tiles_x, preds.spec.tiles_y),
                        preds.initial.clone(),
                        JobExec::Sharded {
                            store: Arc::clone(store),
                            slide: *slide,
                        },
                    ))
                }
            }
        }));
        let prep = match prep {
            Ok(r) => r,
            Err(p) => Err(panic_message(&p)),
        };
        let (slide_id, levels, grid, initial, exec) = match prep {
            Ok(t) => t,
            Err(msg) => {
                self.running_ids.lock().unwrap().remove(&q.id);
                obs::event(
                    Level::Warn,
                    "sched",
                    "job_setup_failed",
                    &[("job", q.id.into()), ("error", msg.as_str().into())],
                );
                let res = JobResult {
                    id: q.id,
                    slide_id: q.spec.source.slide_id().to_string(),
                    tenant: q.spec.tenant,
                    priority: q.spec.priority,
                    state: JobState::Failed(msg),
                    tree: None,
                    queue_wait,
                    run_time: Duration::ZERO,
                    tiles: 0,
                    preemptions: 0,
                };
                self.board.finished(q.id, &res);
                self.results.push(res);
                return;
            }
        };
        self.obs.jobs_admitted.inc();
        self.obs
            .queue_wait_us
            .record(queue_wait.as_micros() as u64);
        obs::event(
            Level::Info,
            "sched",
            "job_admitted",
            &[
                ("job", q.id.into()),
                ("slide", slide_id.as_str().into()),
                ("tenant", q.spec.tenant.as_str().into()),
                ("priority", q.spec.priority.rank().into()),
                ("policy", self.policy.name().into()),
                ("queue_wait_us", (queue_wait.as_micros() as u64).into()),
            ],
        );
        // Cluster jobs enter the replicated ledger before their first
        // chunk can be dealt, so a standby always holds the run's full
        // recipe (no-op without a standby).
        if let (JobExec::Cluster(spec), Some(exec)) = (&exec, self.cluster.as_ref()) {
            exec.register_run(q.id, spec, &thresholds.zoom, &initial, self.cfg.batch);
        }
        // The admission queue validated levels and threshold counts, so
        // this constructor cannot panic.
        let run = PyramidRun::new(slide_id.as_str(), levels, initial, thresholds, self.cfg.batch);
        self.board.started(
            q.id,
            slide_id.as_str(),
            q.spec.tenant.as_str(),
            levels,
            Some(grid),
            run.initial(),
        );
        self.running.insert(
            q.id,
            RunningJob {
                slide_id,
                tenant: q.spec.tenant.clone(),
                priority: q.spec.priority,
                submitted: q.submitted,
                deadline: q.spec.deadline,
                queue_wait,
                first_started: Instant::now(),
                run,
                exec,
                tiles: 0,
                dispatched: 0,
                parking: false,
                preemptions: 0,
                boost: 0,
                cancelled: false,
                failed: None,
            },
        );
    }

    /// Pull every available request from every live run into the pending
    /// set. Cancelled/failed/parking jobs stop being issued work here —
    /// that is the frontier-boundary preemption point.
    fn pump(&mut self) {
        for (id, r) in self.running.iter_mut() {
            if r.cancelled || r.parking || r.failed.is_some() {
                continue;
            }
            while let Some(req) = r.run.next_request() {
                self.pending.push((*id, req));
            }
        }
    }

    /// Fire every pending request, in policy order with live per-tenant
    /// usage accounting. Adjacent same-level pool requests (usually from
    /// different jobs) merge into one coalesced dispatch group; replay
    /// requests complete inline; cluster requests are dealt to the TCP
    /// workers.
    fn dispatch(&mut self) {
        if self.pending.is_empty() {
            return;
        }
        let running_per_tenant = self.running_per_tenant();
        let now = self.now_micros();
        // Policy-ordered drain with live fair-share accounting.
        let mut order: Vec<(JobId, FrontierRequest)> = Vec::with_capacity(self.pending.len());
        loop {
            let idx = {
                let cands: Vec<SchedCandidate<'_>> = self
                    .pending
                    .iter()
                    .map(|(job, _)| {
                        let r = self.running.get(job).expect("pending implies running");
                        SchedCandidate {
                            job: *job,
                            priority_rank: r.priority.rank(),
                            tenant: &r.tenant,
                            arrival: self.micros_of(r.submitted),
                            deadline: self.abs_deadline(r.submitted, r.deadline),
                        }
                    })
                    .collect();
                let ctx = SchedContext {
                    usage: &self.usage,
                    running_per_tenant: &running_per_tenant,
                    now,
                };
                self.policy.select(&cands, &ctx)
            };
            let Some(idx) = idx else { break };
            let (job, req) = self.pending.remove(idx);
            let r = self.running.get_mut(&job).expect("pending implies running");
            r.tiles += req.tiles.len();
            r.dispatched += 1;
            let tenant = r.tenant.clone();
            *self.usage.entry(tenant).or_default() += req.tiles.len() as u64;
            self.obs.chunks_dealt.inc();
            self.chunk_fired.insert(pack_key(job, req.id), Instant::now());
            obs::event(
                Level::Debug,
                "sched",
                "chunk_dispatched",
                &[
                    ("job", job.into()),
                    ("req", req.id.into()),
                    ("key", pack_key(job, req.id).into()),
                    ("level", req.level.into()),
                    ("tiles", req.tiles.len().into()),
                ],
            );
            order.push((job, req));
        }
        // Fire, grouping adjacent same-level pool requests.
        let mut group: Vec<(JobId, FrontierRequest)> = Vec::new();
        let mut group_level = 0usize;
        for (job, req) in order {
            enum Fire {
                Pool,
                Replay(Arc<SlidePredictions>),
                Sharded(Arc<ShardedPredStore>, usize),
                Cluster(SlideSpec),
            }
            let fire = match &self.running.get(&job).expect("dispatch implies running").exec {
                JobExec::Pool(_) => Fire::Pool,
                JobExec::Replay(c) => Fire::Replay(Arc::clone(c)),
                JobExec::Sharded { store, slide } => Fire::Sharded(Arc::clone(store), *slide),
                JobExec::Cluster(spec) => Fire::Cluster(spec.clone()),
            };
            match fire {
                Fire::Pool => {
                    if !group.is_empty() && (group_level != req.level || !self.cfg.coalesce) {
                        let g = std::mem::take(&mut group);
                        self.flush_group(group_level, g);
                    }
                    group_level = req.level;
                    group.push((job, req));
                }
                Fire::Replay(c) => {
                    let g = std::mem::take(&mut group);
                    self.flush_group(group_level, g);
                    // Missing lineage tiles (corrupt cache) reply short;
                    // the feed rejects that and fails the one job.
                    let probs: Vec<f32> =
                        req.tiles.iter().filter_map(|&t| c.prob(t)).collect();
                    let _ = self.events_tx.send(Event::ChunkDone {
                        job,
                        req: req.id,
                        probs,
                    });
                }
                Fire::Sharded(store, slide) => {
                    let g = std::mem::take(&mut group);
                    self.flush_group(group_level, g);
                    // Re-resolve through the store each chunk: the shard
                    // may have been evicted since the last one, in which
                    // case it streams back in off disk. A load failure
                    // (file corrupted after admission) fails this one
                    // job, never the service.
                    match store.slide(slide) {
                        Ok(preds) => {
                            let probs: Vec<f32> =
                                req.tiles.iter().filter_map(|&t| preds.prob(t)).collect();
                            let _ = self.events_tx.send(Event::ChunkDone {
                                job,
                                req: req.id,
                                probs,
                            });
                        }
                        Err(e) => {
                            self.chunk_fired.remove(&pack_key(job, req.id));
                            if let Some(r) = self.running.get_mut(&job) {
                                r.dispatched = r.dispatched.saturating_sub(1);
                                r.failed = Some(format!("shard load failed: {e}"));
                            }
                            self.pending.retain(|(j, _)| *j != job);
                        }
                    }
                }
                Fire::Cluster(spec) => {
                    let g = std::mem::take(&mut group);
                    self.flush_group(group_level, g);
                    let exec = self.cluster.as_ref().expect("cluster exec configured");
                    // A dead worker fails this one job, never the service
                    // — the same fault isolation the pool path has.
                    let sent = exec.submit(pack_key(job, req.id), &spec, req.level, req.tiles);
                    if let Err(e) = sent {
                        self.chunk_fired.remove(&pack_key(job, req.id));
                        if let Some(r) = self.running.get_mut(&job) {
                            r.dispatched = r.dispatched.saturating_sub(1);
                            r.failed = Some(format!("cluster dispatch failed: {e}"));
                        }
                        self.pending.retain(|(j, _)| *j != job);
                    }
                }
            }
        }
        if !group.is_empty() {
            self.flush_group(group_level, group);
        }
    }

    /// Send one group of same-level pool requests to the shared pool as a
    /// single coalesced dispatch.
    fn flush_group(&self, level: usize, group: Vec<(JobId, FrontierRequest)>) {
        if group.is_empty() {
            return;
        }
        let items: Vec<CoalescedItem> = group
            .into_iter()
            .map(|(job, req)| {
                let slide = match &self.running.get(&job).expect("grouped job running").exec {
                    JobExec::Pool(s) => Arc::clone(s),
                    _ => unreachable!("grouped requests are pool-backed"),
                };
                let tx = self.events_tx.clone();
                let req_id = req.id;
                CoalescedItem {
                    slide,
                    tiles: req.tiles,
                    done: Box::new(move |probs| {
                        let _ = tx.send(Event::ChunkDone {
                            job,
                            req: req_id,
                            probs,
                        });
                    }),
                }
            })
            .collect();
        self.pool.analyze_coalesced_async(level, items, self.cfg.batch);
    }

    /// Retire finished runs and park drained preempted ones. Completed
    /// jobs leave with their full tree; cancelled/failed ones once their
    /// last in-flight chunk drained (so nothing ever leaks), cancelled
    /// ones carrying the partial tree of every completed level. A
    /// `parking` job whose chunks have drained moves to the parked set —
    /// suspended at a frontier boundary with its run intact. Returns how
    /// many jobs changed state (retired or parked), so the caller re-runs
    /// admission.
    fn settle(&mut self) -> usize {
        let ready: Vec<JobId> = self
            .running
            .iter()
            .filter_map(|(id, r)| {
                let done = r.run.is_complete()
                    || ((r.cancelled || r.parking || r.failed.is_some()) && r.dispatched == 0);
                done.then_some(*id)
            })
            .collect();
        let mut changed = 0;
        for id in ready {
            let r = self.running.get(&id).expect("listed above");
            let complete = r.run.is_complete();
            if r.parking && !complete && !r.cancelled && r.failed.is_none() {
                // Suspension point: every issued chunk has been fed, so
                // the run sits exactly at a level-frontier boundary.
                if self.pending.iter().any(|(j, _)| *j == id) {
                    continue; // undispatched work still queued; next round
                }
                let r = self.running.remove(&id).expect("listed above");
                debug_assert_eq!(r.run.in_flight(), 0, "park with chunks in flight");
                self.obs.jobs_parked.inc();
                obs::event(
                    Level::Info,
                    "sched",
                    "job_parked",
                    &[
                        ("job", id.into()),
                        ("slide", r.slide_id.as_str().into()),
                        ("tenant", r.tenant.as_str().into()),
                        ("level", r.run.current_level().into()),
                        ("preemptions", (r.preemptions + 1).into()),
                    ],
                );
                self.board.phase(id, JobPhase::Parked);
                self.parked.insert(
                    id,
                    ParkedJob {
                        slide_id: r.slide_id,
                        tenant: r.tenant,
                        priority: r.priority,
                        submitted: r.submitted,
                        deadline: r.deadline,
                        queue_wait: r.queue_wait,
                        first_started: r.first_started,
                        run: r.run,
                        exec: r.exec,
                        tiles: r.tiles,
                        // Counted here, at the real suspension, not at
                        // the parking mark — a job that completed while
                        // draining was never preempted.
                        preemptions: r.preemptions + 1,
                        parked_at: Instant::now(),
                        boost: r.boost,
                    },
                );
                changed += 1;
                continue;
            }
            let r = self.running.remove(&id).expect("listed above");
            self.running_ids.lock().unwrap().remove(&id);
            self.pending.retain(|(j, _)| *j != id);
            // Terminal in every state — a standby must not resurrect a
            // cancelled or failed run any more than a completed one.
            if let (JobExec::Cluster(_), Some(exec)) = (&r.exec, self.cluster.as_ref()) {
                exec.ledger_run_done(id);
            }
            let tree = r.run.finish();
            let run_time = r.first_started.elapsed();
            let (state, tree, tiles) = if let Some(msg) = r.failed {
                (JobState::Failed(msg), None, r.tiles)
            } else if complete {
                let tiles = tree.total_analyzed();
                (JobState::Completed, Some(tree), tiles)
            } else {
                // Cancelled mid-run: the partial tree holds exactly the
                // fully analyzed levels.
                let tiles = tree.total_analyzed();
                (JobState::Cancelled, Some(tree), tiles)
            };
            self.obs.run_time_us.record_duration(run_time);
            obs::event(
                Level::Info,
                "sched",
                "job_done",
                &[
                    ("job", id.into()),
                    ("slide", r.slide_id.as_str().into()),
                    (
                        "state",
                        match &state {
                            JobState::Completed => "completed",
                            JobState::Cancelled => "cancelled",
                            JobState::Failed(_) => "failed",
                            JobState::Expired => "expired",
                        }
                        .into(),
                    ),
                    ("tiles", tiles.into()),
                    ("run_time_us", (run_time.as_micros() as u64).into()),
                    ("preemptions", r.preemptions.into()),
                ],
            );
            let res = JobResult {
                id,
                slide_id: r.slide_id,
                tenant: r.tenant,
                priority: r.priority,
                state,
                tree,
                queue_wait: r.queue_wait,
                run_time,
                tiles,
                preemptions: r.preemptions,
            };
            self.board.finished(id, &res);
            self.results.push(res);
            changed += 1;
        }
        changed
    }
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "job setup panicked".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    use crate::model::oracle::OracleAnalyzer;
    use crate::model::Analyzer;
    use crate::pyramid::tree::{ExecTree, Thresholds};
    use crate::sched::PolicySpec;
    use crate::service::job::{JobSource, JobSpec};
    use crate::sim::{simulate_workload, SimJobSpec, WorkloadConfig};
    use crate::synth::slide_gen::{SlideKind, SlideSpec};

    #[test]
    fn key_packing_roundtrips() {
        for (job, req) in [(1u64, 0u64), (7, 3), (123_456, 654_321)] {
            assert_eq!(unpack_key(pack_key(job, req)), (job, req));
        }
    }

    /// One job of the shared sim/service workload: a prediction cache
    /// (service side replays it; the sim re-drives its replay tree).
    struct WorkloadJob {
        preds: Arc<SlidePredictions>,
        tree: ExecTree,
        tenant: &'static str,
        priority: Priority,
        deadline_secs: u64,
    }

    const CHUNK: usize = 8;

    fn thr() -> Thresholds {
        Thresholds::uniform(3, 0.35)
    }

    fn build_workload() -> Vec<WorkloadJob> {
        let analyzer = OracleAnalyzer::new(1);
        let kinds = [
            SlideKind::LargeTumor,
            SlideKind::SmallScattered,
            SlideKind::Negative,
        ];
        let tenants = ["lab_a", "lab_a", "lab_b", "lab_a", "lab_b"];
        let prios = [
            Priority::Low,
            Priority::High,
            Priority::Normal,
            Priority::High,
            Priority::Low,
        ];
        // Distinct, generous (seconds-scale) deadlines in an order that
        // disagrees with both submission order and priority order, so
        // every policy produces a different fingerprint.
        let deadlines = [500u64, 100, 300, 200, 400];
        (0..5)
            .map(|i| {
                let spec = SlideSpec::new(
                    format!("eq_{i}"),
                    900 + i as u64,
                    32,
                    16,
                    3,
                    64,
                    kinds[i % 3],
                );
                let slide = Slide::from_spec(spec);
                let preds = Arc::new(SlidePredictions::collect(&slide, &analyzer, 16));
                let tree = preds.replay(&thr());
                WorkloadJob {
                    preds,
                    tree,
                    tenant: tenants[i],
                    priority: prios[i],
                    deadline_secs: deadlines[i],
                }
            })
            .collect()
    }

    /// Run the *real* service scheduler synchronously over cached-replay
    /// jobs: the queue is pre-filled, `Close` is pre-sent, and replay
    /// completions flow deterministically through the event channel — so
    /// the completion order is exactly the policy's decision sequence.
    /// Also returns the scheduler's scoped metrics snapshot, so parity
    /// checks can compare counter totals against the simulator's.
    fn service_completion_order(
        spec: &PolicySpec,
        wl: &[WorkloadJob],
    ) -> (Vec<JobId>, crate::obs::MetricsSnapshot) {
        let queue = Arc::new(AdmissionQueue::new(16));
        for w in wl {
            queue
                .submit(
                    JobSpec::new(JobSource::Cached(Arc::clone(&w.preds)), thr())
                        .with_priority(w.priority)
                        .with_tenant(w.tenant)
                        .with_deadline(Duration::from_secs(w.deadline_secs)),
                )
                .unwrap();
        }
        let analyzer: Arc<dyn Analyzer> = Arc::new(OracleAnalyzer::new(1));
        let pool = Arc::new(AnalyzerPool::new(analyzer, 1));
        let (tx, rx) = mpsc::channel();
        tx.send(Event::Close).unwrap();
        let registry = Arc::new(crate::obs::Registry::new());
        let sched = Scheduler::new(
            SchedulerConfig {
                max_in_flight: 1,
                batch: CHUNK,
                coalesce: false,
                preempt: false,
                park_aging: None,
            },
            spec.build(),
            Arc::clone(&queue),
            pool,
            None,
            tx,
            Arc::new(Mutex::new(HashSet::new())),
            Arc::clone(&registry),
            Arc::new(crate::service::board::JobBoard::new(64)),
        );
        let results = sched.run(rx);
        assert_eq!(results.len(), wl.len());
        let order = results
            .iter()
            .map(|r| {
                assert_eq!(r.state, JobState::Completed, "job {} not completed", r.id);
                r.id
            })
            .collect();
        (order, registry.snapshot())
    }

    /// Run the workload simulator with the *same* policy object
    /// configuration over the same jobs (arrival 0, deadlines in µs to
    /// match the service's clock units).
    fn sim_completion_order(
        spec: &PolicySpec,
        wl: &[WorkloadJob],
    ) -> (Vec<JobId>, crate::obs::MetricsSnapshot) {
        let jobs: Vec<SimJobSpec> = wl
            .iter()
            .map(|w| SimJobSpec {
                tenant: w.tenant.to_string(),
                priority_rank: w.priority.rank(),
                arrival: 0,
                deadline: Some(w.deadline_secs * 1_000_000),
                tree: w.tree.clone(),
                thresholds: thr(),
            })
            .collect();
        let policy = spec.build();
        let res = simulate_workload(
            &jobs,
            policy.as_ref(),
            &WorkloadConfig {
                workers: 1,
                max_in_flight: 1,
                chunk: CHUNK,
                preempt: false,
                park_aging: 0,
                failures: vec![],
                leader_failures: vec![],
                stragglers: vec![],
            },
        );
        // Sim job index i ↔ service id i+1 (the admission queue assigns
        // 1-based monotonic ids in submission order).
        let order = res.completion_order.iter().map(|&i| i as JobId + 1).collect();
        (order, res.metrics)
    }

    #[test]
    fn simulator_and_service_reproduce_the_same_policy_decisions() {
        // The acceptance bar for the shared policy core: on the same
        // workload, the simulator and the real service scheduler make
        // identical ordering decisions for every policy — because they
        // consult the same SchedulingPolicy objects, not re-derivations.
        let wl = build_workload();
        let specs = [
            PolicySpec::fifo(),
            PolicySpec::priority(),
            PolicySpec::wfs([("lab_a".to_string(), 3.0), ("lab_b".to_string(), 1.0)]),
            PolicySpec::edf(),
        ];
        let mut fingerprints = Vec::new();
        for spec in &specs {
            let (svc, svc_metrics) = service_completion_order(spec, &wl);
            let (sim, sim_metrics) = sim_completion_order(spec, &wl);
            assert_eq!(
                svc,
                sim,
                "policy {} diverged between service and simulator",
                spec.as_str()
            );
            // The two substrates emit the same counter names into their
            // scoped registries; on the same workload the totals must be
            // identical — chunks dealt, stolen and requeued.
            for c in ["sched.chunks_dealt", "sched.chunks_stolen", "sched.chunks_requeued"] {
                assert_eq!(
                    svc_metrics.counter(c),
                    sim_metrics.counter(c),
                    "policy {}: counter {c} diverged",
                    spec.as_str()
                );
            }
            assert!(
                svc_metrics.counter("sched.chunks_dealt") > 0,
                "workload dealt no chunks — counter parity is vacuous"
            );
            fingerprints.push(svc);
        }
        // Sanity: the workload actually distinguishes the policies
        // (otherwise the equality above would be vacuous).
        assert!(
            fingerprints.windows(2).any(|w| w[0] != w[1]),
            "workload too bland: every policy produced {:?}",
            fingerprints[0]
        );
    }
}
