//! Scheduling policies and the service event loop.
//!
//! Each running job is a [`PyramidRun`] state machine stepped *directly*
//! by the scheduler — no coordinator threads, no blocking providers. The
//! loop pulls every available [`FrontierRequest`] from every running job,
//! orders them by policy, and fires them at the job's execution substrate:
//! the shared [`AnalyzerPool`] (same-level requests from different jobs
//! coalesce into one dispatch group), an inline predcache replay, or the
//! persistent TCP cluster ([`ClusterExec`]). Completions come back as
//! events and are fed into the owning run; because a run's tree depends
//! only on what was analyzed — never on scheduling or feed order — a
//! job's ExecTree is identical to a standalone `run_pyramidal` /
//! `SlidePredictions::replay` no matter how the scheduler interleaved it.
//!
//! Stepping the runs directly is what makes mid-run cancellation natural:
//! a cancelled job simply stops being issued requests; its in-flight
//! chunks drain into the run and the job finalizes at the last completed
//! frontier boundary with a consistent partial tree.
//!
//! [`PyramidRun`]: crate::pyramid::PyramidRun
//! [`FrontierRequest`]: crate::pyramid::FrontierRequest
//! [`AnalyzerPool`]: crate::service::pool::AnalyzerPool
//! [`ClusterExec`]: crate::cluster::ClusterExec

use std::collections::{HashMap, HashSet};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::cluster::ClusterExec;
use crate::predcache::SlidePredictions;
use crate::preprocess::otsu::background_removal;
use crate::pyramid::driver::BG_MARGIN;
use crate::pyramid::{FrontierRequest, PyramidRun, RequestId};
use crate::slide::pyramid::Slide;
use crate::synth::slide_gen::SlideSpec;

use super::job::{JobId, JobResult, JobState, Priority};
use super::pool::{AnalyzerPool, CoalescedItem};
use super::queue::{AdmissionQueue, QueuedJob};

/// Which job goes next — both at admission (queue → running set) and at
/// request dispatch (pending frontier chunks → execution substrate).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Strict submission order.
    Fifo,
    /// Higher [`Priority`] first; submission order breaks ties.
    Priority,
    /// The tenant with the fewest tiles consumed so far goes first, so one
    /// heavy tenant cannot starve the others.
    FairShare,
}

impl Policy {
    pub fn as_str(self) -> &'static str {
        match self {
            Policy::Fifo => "fifo",
            Policy::Priority => "priority",
            Policy::FairShare => "fair",
        }
    }

    pub fn from_str(s: &str) -> Option<Policy> {
        match s {
            "fifo" => Some(Policy::Fifo),
            "priority" => Some(Policy::Priority),
            "fair" | "fair_share" | "fair-share" => Some(Policy::FairShare),
            _ => None,
        }
    }

    /// Pick the next candidate's index. `usage` is tiles consumed per
    /// tenant (fair-share state). Ties always fall back to submission
    /// order (lowest job id), which makes every policy deterministic for a
    /// fixed candidate set.
    pub fn select(self, cands: &[Candidate<'_>], usage: &HashMap<String, u64>) -> Option<usize> {
        if cands.is_empty() {
            return None;
        }
        let idx = match self {
            Policy::Fifo => {
                cands
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, c)| c.id)
                    .unwrap()
                    .0
            }
            Policy::Priority => {
                cands
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, c)| (std::cmp::Reverse(c.priority.rank()), c.id))
                    .unwrap()
                    .0
            }
            Policy::FairShare => {
                cands
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, c)| (usage.get(c.tenant).copied().unwrap_or(0), c.id))
                    .unwrap()
                    .0
            }
        };
        Some(idx)
    }
}

/// What a policy needs to know about one schedulable unit.
#[derive(Debug, Clone, Copy)]
pub struct Candidate<'a> {
    pub id: JobId,
    pub priority: Priority,
    pub tenant: &'a str,
}

/// Scheduler-internal events (submitters, completion callbacks and the
/// cluster pump feed these into the loop).
pub(crate) enum Event {
    /// New submissions may be waiting in the admission queue.
    JobsAvailable,
    /// A queued job was removed by `AnalysisService::cancel`.
    Cancelled(QueuedJob),
    /// Cancel a *running* job at its next frontier boundary.
    CancelRunning(JobId),
    /// One frontier chunk finished on some substrate.
    ChunkDone {
        job: JobId,
        req: RequestId,
        probs: Vec<f32>,
    },
    /// Admission is closed; exit once everything drains.
    Close,
}

/// Pack a (job, request) pair into the cluster routing key. Keys travel
/// the wire as JSON numbers (f64), which are exact only below 2⁵³ — so
/// the request id gets 21 bits (a run issues one id per frontier chunk,
/// far below 2²¹) and the job id 32, keeping every key exactly
/// representable. Checked in release builds too: a rounded key would
/// silently misroute probabilities.
pub(crate) fn pack_key(job: JobId, req: RequestId) -> u64 {
    assert!(
        job < (1 << 32) && req < (1 << 21),
        "cluster routing key overflow (job {job}, request {req})"
    );
    (job << 21) | req
}

/// Inverse of [`pack_key`].
pub(crate) fn unpack_key(key: u64) -> (JobId, RequestId) {
    (key >> 21, key & ((1 << 21) - 1))
}

/// Scheduler tuning knobs.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    pub policy: Policy,
    /// How many jobs may be in the running set at once. Small values make
    /// the policy order starkly visible; larger values increase overlap.
    pub max_in_flight: usize,
    /// Analysis chunk size: both the PyramidRun request granularity and
    /// the pool's per-task tile count.
    pub batch: usize,
    /// Merge same-level requests from different jobs into one pool
    /// dispatch group (amortizes per-dispatch overhead).
    pub coalesce: bool,
}

/// Where one job's frontier requests execute.
enum JobExec {
    /// Live analysis through the shared pool.
    Pool(Arc<Slide>),
    /// Inline predcache replay (no analyzer time).
    Replay(Arc<SlidePredictions>),
    /// Chunks dealt to the persistent TCP cluster.
    Cluster(SlideSpec),
}

struct RunningJob {
    slide_id: String,
    tenant: String,
    priority: Priority,
    queue_wait: Duration,
    started: Instant,
    run: PyramidRun,
    exec: JobExec,
    /// Tiles dispatched so far (metrics; counts even chunks that later
    /// fail).
    tiles: usize,
    /// Chunks fired and not yet completed — a job never finalizes while
    /// this is nonzero, so no pool/cluster work ever leaks into a dead
    /// job.
    dispatched: usize,
    cancelled: bool,
    failed: Option<String>,
}

pub(crate) struct Scheduler {
    cfg: SchedulerConfig,
    queue: Arc<AdmissionQueue>,
    pool: Arc<AnalyzerPool>,
    /// Present when the service runs its live jobs on the TCP cluster.
    cluster: Option<Arc<ClusterExec>>,
    events_tx: Sender<Event>,
    running: HashMap<JobId, RunningJob>,
    /// Mirror of `running`'s keys shared with the service handle so
    /// `cancel` can tell running jobs from unknown ones.
    running_ids: Arc<Mutex<HashSet<JobId>>>,
    pending: Vec<(JobId, FrontierRequest)>,
    usage: HashMap<String, u64>,
    results: Vec<JobResult>,
    closed: bool,
}

impl Scheduler {
    pub(crate) fn new(
        cfg: SchedulerConfig,
        queue: Arc<AdmissionQueue>,
        pool: Arc<AnalyzerPool>,
        cluster: Option<Arc<ClusterExec>>,
        events_tx: Sender<Event>,
        running_ids: Arc<Mutex<HashSet<JobId>>>,
    ) -> Scheduler {
        Scheduler {
            cfg,
            queue,
            pool,
            cluster,
            events_tx,
            running: HashMap::new(),
            running_ids,
            pending: Vec::new(),
            usage: HashMap::new(),
            results: Vec::new(),
            closed: false,
        }
    }

    /// The event loop. Returns every job's terminal record, in completion
    /// order.
    pub(crate) fn run(mut self, rx: Receiver<Event>) -> Vec<JobResult> {
        loop {
            while let Ok(ev) = rx.try_recv() {
                self.handle(ev);
            }
            // Step until quiescent: finalizing a job frees an admission
            // slot, so admission must re-run before the loop may block.
            loop {
                self.admit();
                self.pump();
                self.dispatch();
                if self.finalize() == 0 {
                    break;
                }
            }
            if self.closed && self.running.is_empty() && self.queue.is_empty() {
                break;
            }
            match rx.recv() {
                Ok(ev) => self.handle(ev),
                Err(_) => break, // every sender gone: nothing can arrive
            }
        }
        self.results
    }

    fn handle(&mut self, ev: Event) {
        match ev {
            Event::JobsAvailable => {}
            Event::Cancelled(q) => {
                self.results.push(JobResult {
                    id: q.id,
                    slide_id: q.spec.source.slide_id().to_string(),
                    tenant: q.spec.tenant,
                    priority: q.spec.priority,
                    state: JobState::Cancelled,
                    tree: None,
                    queue_wait: q.submitted.elapsed(),
                    run_time: Duration::ZERO,
                    tiles: 0,
                });
            }
            Event::CancelRunning(id) => {
                if let Some(r) = self.running.get_mut(&id) {
                    r.cancelled = true;
                    // Undispatched requests of this job will never run;
                    // in-flight ones drain normally and feed the run, so
                    // the job stops exactly at a frontier boundary.
                    self.pending.retain(|(j, _)| *j != id);
                }
            }
            Event::ChunkDone { job, req, probs } => {
                let mut failed_now = false;
                if let Some(r) = self.running.get_mut(&job) {
                    r.dispatched = r.dispatched.saturating_sub(1);
                    if r.failed.is_none() {
                        if let Err(e) = r.run.feed(req, probs) {
                            r.failed = Some(e.to_string());
                            failed_now = true;
                        }
                    }
                }
                if failed_now {
                    // Its undispatched requests will never be needed.
                    self.pending.retain(|(j, _)| *j != job);
                }
            }
            Event::Close => self.closed = true,
        }
    }

    /// Move jobs from the admission queue into the running set, in policy
    /// order, up to `max_in_flight`. Jobs whose deadline lapsed while they
    /// waited are dropped here (`Expired`) instead of running late.
    fn admit(&mut self) {
        while self.running.len() < self.cfg.max_in_flight.max(1) {
            let picked = self.queue.pop_with(|entries| {
                let cands: Vec<Candidate<'_>> = entries
                    .iter()
                    .map(|q| Candidate {
                        id: q.id,
                        priority: q.spec.priority,
                        tenant: &q.spec.tenant,
                    })
                    .collect();
                let idx = self.cfg.policy.select(&cands, &self.usage);
                if let Some(i) = idx {
                    // Registered while the queue lock is still held, so
                    // `cancel` always finds a job either queued or
                    // running — no handoff window where a live job looks
                    // unknown.
                    self.running_ids.lock().unwrap().insert(entries[i].id);
                }
                idx
            });
            let Some(q) = picked else { break };
            let waited = q.submitted.elapsed();
            if q.spec.deadline.map_or(false, |d| waited > d) {
                self.running_ids.lock().unwrap().remove(&q.id);
                self.results.push(JobResult {
                    id: q.id,
                    slide_id: q.spec.source.slide_id().to_string(),
                    tenant: q.spec.tenant,
                    priority: q.spec.priority,
                    state: JobState::Expired,
                    tree: None,
                    queue_wait: waited,
                    run_time: Duration::ZERO,
                    tiles: 0,
                });
                continue;
            }
            self.start_job(q, waited);
        }
    }

    /// Materialize a job into a running [`PyramidRun`]. Source faults
    /// (invalid specs) fail the one job, never the scheduler.
    fn start_job(&mut self, q: QueuedJob, queue_wait: Duration) {
        use super::job::JobSource;
        let thresholds = q.spec.thresholds.clone();
        let cluster_mode = self.cluster.is_some();
        // admit() already registered q.id in running_ids (under the queue
        // lock), so `cancel` can see this job throughout the slide
        // materialization below.
        let prep = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || -> (String, usize, Vec<crate::slide::tile::TileId>, JobExec) {
                match &q.spec.source {
                    JobSource::Spec(spec) => {
                        let slide = Arc::new(Slide::from_spec(spec.clone()));
                        let initial = background_removal(&slide, BG_MARGIN).tissue_tiles;
                        let exec = if cluster_mode {
                            JobExec::Cluster(spec.clone())
                        } else {
                            JobExec::Pool(Arc::clone(&slide))
                        };
                        (slide.id().to_string(), slide.levels(), initial, exec)
                    }
                    JobSource::Cached(c) => (
                        c.spec.id.clone(),
                        c.spec.levels,
                        c.initial.clone(),
                        JobExec::Replay(Arc::clone(c)),
                    ),
                }
            },
        ));
        let (slide_id, levels, initial, exec) = match prep {
            Ok(t) => t,
            Err(p) => {
                self.running_ids.lock().unwrap().remove(&q.id);
                self.results.push(JobResult {
                    id: q.id,
                    slide_id: q.spec.source.slide_id().to_string(),
                    tenant: q.spec.tenant,
                    priority: q.spec.priority,
                    state: JobState::Failed(panic_message(&p)),
                    tree: None,
                    queue_wait,
                    run_time: Duration::ZERO,
                    tiles: 0,
                });
                return;
            }
        };
        // The admission queue validated levels and threshold counts, so
        // this constructor cannot panic.
        let run = PyramidRun::new(slide_id.as_str(), levels, initial, thresholds, self.cfg.batch);
        self.running.insert(
            q.id,
            RunningJob {
                slide_id,
                tenant: q.spec.tenant.clone(),
                priority: q.spec.priority,
                queue_wait,
                started: Instant::now(),
                run,
                exec,
                tiles: 0,
                dispatched: 0,
                cancelled: false,
                failed: None,
            },
        );
    }

    /// Pull every available request from every live run into the pending
    /// set. Cancelled/failed jobs stop being issued work here — that is
    /// the frontier-boundary preemption point.
    fn pump(&mut self) {
        for (id, r) in self.running.iter_mut() {
            if r.cancelled || r.failed.is_some() {
                continue;
            }
            while let Some(req) = r.run.next_request() {
                self.pending.push((*id, req));
            }
        }
    }

    /// Fire every pending request, in policy order. Adjacent same-level
    /// pool requests (usually from different jobs) merge into one
    /// coalesced dispatch group; replay requests complete inline; cluster
    /// requests are dealt to the TCP workers.
    fn dispatch(&mut self) {
        if self.pending.is_empty() {
            return;
        }
        // Policy-ordered drain with live fair-share accounting.
        let mut order: Vec<(JobId, FrontierRequest)> = Vec::with_capacity(self.pending.len());
        loop {
            let idx = {
                let cands: Vec<Candidate<'_>> = self
                    .pending
                    .iter()
                    .map(|(job, _)| {
                        let r = self.running.get(job).expect("pending implies running");
                        Candidate {
                            id: *job,
                            priority: r.priority,
                            tenant: &r.tenant,
                        }
                    })
                    .collect();
                self.cfg.policy.select(&cands, &self.usage)
            };
            let Some(idx) = idx else { break };
            let (job, req) = self.pending.remove(idx);
            let r = self.running.get_mut(&job).expect("pending implies running");
            r.tiles += req.tiles.len();
            r.dispatched += 1;
            let tenant = r.tenant.clone();
            *self.usage.entry(tenant).or_default() += req.tiles.len() as u64;
            order.push((job, req));
        }
        // Fire, grouping adjacent same-level pool requests.
        let mut group: Vec<(JobId, FrontierRequest)> = Vec::new();
        let mut group_level = 0usize;
        for (job, req) in order {
            enum Fire {
                Pool,
                Replay(Arc<SlidePredictions>),
                Cluster(SlideSpec),
            }
            let fire = match &self.running.get(&job).expect("dispatch implies running").exec {
                JobExec::Pool(_) => Fire::Pool,
                JobExec::Replay(c) => Fire::Replay(Arc::clone(c)),
                JobExec::Cluster(spec) => Fire::Cluster(spec.clone()),
            };
            match fire {
                Fire::Pool => {
                    if !group.is_empty() && (group_level != req.level || !self.cfg.coalesce) {
                        let g = std::mem::take(&mut group);
                        self.flush_group(group_level, g);
                    }
                    group_level = req.level;
                    group.push((job, req));
                }
                Fire::Replay(c) => {
                    let g = std::mem::take(&mut group);
                    self.flush_group(group_level, g);
                    // Missing lineage tiles (corrupt cache) reply short;
                    // the feed rejects that and fails the one job.
                    let probs: Vec<f32> = req
                        .tiles
                        .iter()
                        .filter_map(|t| c.preds.get(t).map(|p| p.prob))
                        .collect();
                    let _ = self.events_tx.send(Event::ChunkDone {
                        job,
                        req: req.id,
                        probs,
                    });
                }
                Fire::Cluster(spec) => {
                    let g = std::mem::take(&mut group);
                    self.flush_group(group_level, g);
                    let exec = self.cluster.as_ref().expect("cluster exec configured");
                    // A dead worker fails this one job, never the service
                    // — the same fault isolation the pool path has.
                    let sent = exec.submit(pack_key(job, req.id), &spec, req.level, req.tiles);
                    if let Err(e) = sent {
                        if let Some(r) = self.running.get_mut(&job) {
                            r.dispatched = r.dispatched.saturating_sub(1);
                            r.failed = Some(format!("cluster dispatch failed: {e}"));
                        }
                        self.pending.retain(|(j, _)| *j != job);
                    }
                }
            }
        }
        if !group.is_empty() {
            self.flush_group(group_level, group);
        }
    }

    /// Send one group of same-level pool requests to the shared pool as a
    /// single coalesced dispatch.
    fn flush_group(&self, level: usize, group: Vec<(JobId, FrontierRequest)>) {
        if group.is_empty() {
            return;
        }
        let items: Vec<CoalescedItem> = group
            .into_iter()
            .map(|(job, req)| {
                let slide = match &self.running.get(&job).expect("grouped job running").exec {
                    JobExec::Pool(s) => Arc::clone(s),
                    _ => unreachable!("grouped requests are pool-backed"),
                };
                let tx = self.events_tx.clone();
                let req_id = req.id;
                CoalescedItem {
                    slide,
                    tiles: req.tiles,
                    done: Box::new(move |probs| {
                        let _ = tx.send(Event::ChunkDone {
                            job,
                            req: req_id,
                            probs,
                        });
                    }),
                }
            })
            .collect();
        self.pool.analyze_coalesced_async(level, items, self.cfg.batch);
    }

    /// Retire finished runs: completed ones with their full tree,
    /// cancelled/failed ones once their last in-flight chunk drained (so
    /// nothing ever leaks), cancelled ones carrying the partial tree of
    /// every completed level. Returns how many jobs were retired.
    fn finalize(&mut self) -> usize {
        let ready: Vec<JobId> = self
            .running
            .iter()
            .filter_map(|(id, r)| {
                let done = r.run.is_complete()
                    || ((r.cancelled || r.failed.is_some()) && r.dispatched == 0);
                done.then_some(*id)
            })
            .collect();
        let retired = ready.len();
        for id in ready {
            let r = self.running.remove(&id).expect("listed above");
            self.running_ids.lock().unwrap().remove(&id);
            self.pending.retain(|(j, _)| *j != id);
            let complete = r.run.is_complete();
            let tree = r.run.finish();
            let (state, tree, tiles) = if let Some(msg) = r.failed {
                (JobState::Failed(msg), None, r.tiles)
            } else if complete {
                let tiles = tree.total_analyzed();
                (JobState::Completed, Some(tree), tiles)
            } else {
                // Cancelled mid-run: the partial tree holds exactly the
                // fully analyzed levels.
                let tiles = tree.total_analyzed();
                (JobState::Cancelled, Some(tree), tiles)
            };
            self.results.push(JobResult {
                id,
                slide_id: r.slide_id,
                tenant: r.tenant,
                priority: r.priority,
                state,
                tree,
                queue_wait: r.queue_wait,
                run_time: r.started.elapsed(),
                tiles,
            });
        }
        retired
    }
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "job setup panicked".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cands<'a>(v: &'a [(JobId, Priority, &'a str)]) -> Vec<Candidate<'a>> {
        v.iter()
            .map(|&(id, priority, tenant)| Candidate {
                id,
                priority,
                tenant,
            })
            .collect()
    }

    #[test]
    fn fifo_picks_lowest_id() {
        let c = cands(&[
            (3, Priority::High, "a"),
            (1, Priority::Low, "b"),
            (2, Priority::High, "a"),
        ]);
        assert_eq!(Policy::Fifo.select(&c, &HashMap::new()), Some(1));
        assert_eq!(Policy::Fifo.select(&[], &HashMap::new()), None);
    }

    #[test]
    fn priority_beats_submission_order_with_fifo_tiebreak() {
        let c = cands(&[
            (1, Priority::Normal, "a"),
            (2, Priority::High, "a"),
            (3, Priority::High, "a"),
        ]);
        // Both high-priority jobs beat job 1; id 2 beats id 3.
        assert_eq!(Policy::Priority.select(&c, &HashMap::new()), Some(1));
    }

    #[test]
    fn fair_share_prefers_least_served_tenant() {
        let c = cands(&[
            (1, Priority::Normal, "heavy"),
            (2, Priority::Normal, "light"),
        ]);
        let mut usage = HashMap::new();
        usage.insert("heavy".to_string(), 500u64);
        assert_eq!(Policy::FairShare.select(&c, &usage), Some(1));
        // Unknown tenants count as zero usage; ties fall back to FIFO.
        usage.insert("heavy".to_string(), 0);
        assert_eq!(Policy::FairShare.select(&c, &usage), Some(0));
    }

    #[test]
    fn policy_strings_roundtrip() {
        for p in [Policy::Fifo, Policy::Priority, Policy::FairShare] {
            assert_eq!(Policy::from_str(p.as_str()), Some(p));
        }
        assert_eq!(Policy::from_str("fair_share"), Some(Policy::FairShare));
        assert_eq!(Policy::from_str("lifo"), None);
    }

    #[test]
    fn key_packing_roundtrips() {
        for (job, req) in [(1u64, 0u64), (7, 3), (123_456, 654_321)] {
            assert_eq!(unpack_key(pack_key(job, req)), (job, req));
        }
    }
}
