//! Shared analyzer pool: one [`ThreadPool`] serving every job's frontier
//! batches.
//!
//! A level frontier is split into `batch`-sized chunks that spread over
//! the pool's workers; chunk results are reassembled in submission order,
//! so probabilities come back exactly as a serial `analyze_batched` would
//! produce them — scheduling never changes a job's ExecTree. Dispatch is
//! asynchronous (`analyze_async`): the scheduler fires a batch and moves
//! on, so frontier batches of *different* slides genuinely overlap on the
//! same workers.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::model::Analyzer;
use crate::slide::pyramid::Slide;
use crate::slide::tile::TileId;
use crate::util::threadpool::ThreadPool;

/// Shared analysis-worker pool.
pub struct AnalyzerPool {
    pool: ThreadPool,
    analyzer: Arc<dyn Analyzer>,
    workers: usize,
    /// Analyzer panics caught in chunk closures (the inner catch fires
    /// before `ThreadPool`'s own counter can see the unwind).
    panics: Arc<AtomicUsize>,
}

/// In-flight chunk results of one frontier batch (order-preserving).
struct BatchSlots {
    out: Vec<Option<Vec<f32>>>,
    left: usize,
    done: Option<Box<dyn FnOnce(Vec<f32>) + Send>>,
}

impl AnalyzerPool {
    pub fn new(analyzer: Arc<dyn Analyzer>, workers: usize) -> AnalyzerPool {
        let workers = workers.max(1);
        AnalyzerPool {
            pool: ThreadPool::new(workers),
            analyzer,
            workers,
            panics: Arc::new(AtomicUsize::new(0)),
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Analyzer faults absorbed so far (the workers survive them).
    pub fn panic_count(&self) -> usize {
        self.panics.load(Ordering::SeqCst) + self.pool.panic_count()
    }

    pub fn analyzer_name(&self) -> &str {
        self.analyzer.name()
    }

    /// Analyze one frontier batch asynchronously: chunk, fan out over the
    /// pool, and call `done` with the reassembled per-tile probabilities
    /// once the last chunk lands. A chunk whose analyzer call panics
    /// reports an empty result, which the driver's provider-count check
    /// turns into a per-job failure instead of a wedged service.
    pub fn analyze_async(
        &self,
        slide: Arc<Slide>,
        level: usize,
        tiles: Vec<TileId>,
        batch: usize,
        done: Box<dyn FnOnce(Vec<f32>) + Send>,
    ) {
        let chunks: Vec<Vec<TileId>> = tiles
            .chunks(batch.max(1))
            .map(|c| c.to_vec())
            .collect();
        let n = chunks.len();
        if n == 0 {
            done(Vec::new());
            return;
        }
        let slots = Arc::new(Mutex::new(BatchSlots {
            out: (0..n).map(|_| None).collect(),
            left: n,
            done: Some(done),
        }));
        for (i, chunk) in chunks.into_iter().enumerate() {
            let slide = Arc::clone(&slide);
            let analyzer = Arc::clone(&self.analyzer);
            let slots = Arc::clone(&slots);
            let panics = Arc::clone(&self.panics);
            self.pool.execute(move || {
                let ps = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    analyzer.analyze(&slide, level, &chunk)
                }))
                .unwrap_or_else(|_| {
                    panics.fetch_add(1, Ordering::SeqCst);
                    Vec::new()
                });
                let finish = {
                    let mut s = slots.lock().unwrap();
                    s.out[i] = Some(ps);
                    s.left -= 1;
                    if s.left == 0 {
                        let probs: Vec<f32> =
                            s.out.iter_mut().flat_map(|o| o.take().unwrap()).collect();
                        Some((s.done.take().expect("done callback set"), probs))
                    } else {
                        None
                    }
                };
                if let Some((done, probs)) = finish {
                    done(probs);
                }
            });
        }
    }

    /// Synchronous convenience wrapper around [`Self::analyze_async`].
    pub fn analyze(
        &self,
        slide: &Arc<Slide>,
        level: usize,
        tiles: &[TileId],
        batch: usize,
    ) -> Vec<f32> {
        let (tx, rx) = std::sync::mpsc::channel();
        self.analyze_async(
            Arc::clone(slide),
            level,
            tiles.to_vec(),
            batch,
            Box::new(move |ps| {
                let _ = tx.send(ps);
            }),
        );
        rx.recv().expect("pool completes batch")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::oracle::OracleAnalyzer;
    use crate::synth::slide_gen::{SlideKind, SlideSpec};

    fn slide() -> Arc<Slide> {
        Arc::new(Slide::from_spec(SlideSpec::new(
            "pool",
            5,
            16,
            8,
            3,
            64,
            SlideKind::LargeTumor,
        )))
    }

    #[test]
    fn pooled_analysis_matches_direct_call() {
        let analyzer: Arc<dyn Analyzer> = Arc::new(OracleAnalyzer::new(1));
        let pool = AnalyzerPool::new(Arc::clone(&analyzer), 4);
        let s = slide();
        let tiles = s.level_tile_ids(2);
        let direct = analyzer.analyze(&s, 2, &tiles);
        // Any chunking must reassemble to the same ordered probabilities.
        for batch in [1, 3, 16, 1000] {
            let pooled = pool.analyze(&s, 2, &tiles, batch);
            assert_eq!(pooled, direct, "batch={batch}");
        }
    }

    #[test]
    fn empty_frontier_completes_immediately() {
        let analyzer: Arc<dyn Analyzer> = Arc::new(OracleAnalyzer::new(1));
        let pool = AnalyzerPool::new(analyzer, 2);
        let s = slide();
        assert_eq!(pool.analyze(&s, 0, &[], 8), Vec::<f32>::new());
    }

    #[test]
    fn analyzer_panic_is_counted_and_pool_survives() {
        let pool = AnalyzerPool::new(Arc::new(crate::service::FaultyAnalyzer), 2);
        let s = slide();
        let tiles = s.level_tile_ids(1);
        // Faulting level: chunks report empty, the counter records them.
        let ps = pool.analyze(&s, 1, &tiles, 8);
        assert!(ps.len() < tiles.len(), "faulting chunks yield no probs");
        assert!(pool.panic_count() >= 1);
        // The pool still serves healthy levels afterwards.
        let ok = pool.analyze(&s, 2, &s.level_tile_ids(2), 8);
        assert_eq!(ok.len(), s.level_tile_ids(2).len());
    }

    #[test]
    fn concurrent_batches_from_many_threads() {
        let analyzer: Arc<dyn Analyzer> = Arc::new(OracleAnalyzer::new(1));
        let pool = Arc::new(AnalyzerPool::new(Arc::clone(&analyzer), 3));
        let s = slide();
        let tiles = s.level_tile_ids(1);
        let expect = analyzer.analyze(&s, 1, &tiles);
        let handles: Vec<_> = (0..6)
            .map(|_| {
                let pool = Arc::clone(&pool);
                let s = Arc::clone(&s);
                let tiles = tiles.clone();
                std::thread::spawn(move || pool.analyze(&s, 1, &tiles, 4))
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), expect);
        }
    }
}
