//! Shared analyzer pool: one [`ThreadPool`] serving every job's frontier
//! batches.
//!
//! A level frontier is split into `batch`-sized chunks that spread over
//! the pool's workers; chunk results are reassembled in submission order,
//! so probabilities come back exactly as a serial `analyze_batched` would
//! produce them — scheduling never changes a job's ExecTree. Dispatch is
//! asynchronous (`analyze_async`): the scheduler fires a batch and moves
//! on, so frontier batches of *different* slides genuinely overlap on the
//! same workers.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::model::Analyzer;
use crate::slide::pyramid::Slide;
use crate::slide::tile::TileId;
use crate::util::threadpool::ThreadPool;

/// Shared analysis-worker pool.
pub struct AnalyzerPool {
    pool: ThreadPool,
    analyzer: Arc<dyn Analyzer>,
    workers: usize,
    /// Analyzer panics caught in chunk closures (the inner catch fires
    /// before `ThreadPool`'s own counter can see the unwind).
    panics: Arc<AtomicUsize>,
}

/// One member of a coalesced dispatch group
/// ([`AnalyzerPool::analyze_coalesced_async`]): a same-level frontier
/// chunk of one slide plus its completion callback.
pub struct CoalescedItem {
    /// Slide the tiles belong to.
    pub slide: Arc<Slide>,
    /// Tiles to analyze (all at the group's level).
    pub tiles: Vec<TileId>,
    /// Called with the probabilities, in tile order.
    pub done: Box<dyn FnOnce(Vec<f32>) + Send>,
}

/// Positional results of one coalesced item (filled span by span, spans
/// may complete on different workers in any order).
struct ItemSlots {
    out: Vec<Option<f32>>,
    left: usize,
    done: Option<Box<dyn FnOnce(Vec<f32>) + Send>>,
}

impl AnalyzerPool {
    /// Spawn `workers` threads sharing one analyzer.
    pub fn new(analyzer: Arc<dyn Analyzer>, workers: usize) -> AnalyzerPool {
        let workers = workers.max(1);
        AnalyzerPool {
            pool: ThreadPool::new(workers),
            analyzer,
            workers,
            panics: Arc::new(AtomicUsize::new(0)),
        }
    }

    /// Worker-thread count.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Analyzer faults absorbed so far (the workers survive them).
    pub fn panic_count(&self) -> usize {
        self.panics.load(Ordering::SeqCst) + self.pool.panic_count()
    }

    /// Name of the underlying analyzer (tables/logs).
    pub fn analyzer_name(&self) -> &str {
        self.analyzer.name()
    }

    /// Analyze one frontier batch asynchronously: chunk, fan out over the
    /// pool, and call `done` with the reassembled per-tile probabilities
    /// once the last chunk lands. A chunk whose analyzer call panics
    /// reports a short result, which the driver's probability-count check
    /// turns into a per-job failure instead of a wedged service.
    ///
    /// This is the one-item case of [`Self::analyze_coalesced_async`] —
    /// one protocol, one implementation.
    pub fn analyze_async(
        &self,
        slide: Arc<Slide>,
        level: usize,
        tiles: Vec<TileId>,
        batch: usize,
        done: Box<dyn FnOnce(Vec<f32>) + Send>,
    ) {
        self.analyze_coalesced_async(level, vec![CoalescedItem { slide, tiles, done }], batch);
    }

    /// Coalesced dispatch: several same-level frontier chunks — typically
    /// from *different* jobs/slides — submitted as one group. The group's
    /// tiles are re-chunked by `batch` across item boundaries, so a
    /// trailing sliver of one job shares a pool task (one "analyzer
    /// dispatch", the PJRT-overhead unit this testbed stands in for) with
    /// the head of the next, while large groups still fan out over every
    /// worker. Each item's `done` fires with its own reassembled,
    /// tile-ordered probabilities; a panicking span yields a short result
    /// for exactly the items it covered (the per-job failure signal),
    /// never a wedged pool.
    pub fn analyze_coalesced_async(&self, level: usize, items: Vec<CoalescedItem>, batch: usize) {
        // Items with no tiles complete immediately; the rest get slots.
        let mut live: Vec<CoalescedItem> = Vec::with_capacity(items.len());
        for item in items {
            if item.tiles.is_empty() {
                (item.done)(Vec::new());
            } else {
                live.push(item);
            }
        }
        if live.is_empty() {
            return;
        }
        let batch = batch.max(1);
        // Global chunking: spans of (item, start, len) filling `batch`
        // tiles per pool task, crossing item boundaries.
        let mut chunks: Vec<Vec<(usize, usize, usize)>> = Vec::new();
        let mut cur: Vec<(usize, usize, usize)> = Vec::new();
        let mut room = batch;
        for (i, item) in live.iter().enumerate() {
            let mut start = 0;
            while start < item.tiles.len() {
                let take = room.min(item.tiles.len() - start);
                cur.push((i, start, take));
                start += take;
                room -= take;
                if room == 0 {
                    chunks.push(std::mem::take(&mut cur));
                    room = batch;
                }
            }
        }
        if !cur.is_empty() {
            chunks.push(cur);
        }

        let mut slots_vec = Vec::with_capacity(live.len());
        let mut shared_vec = Vec::with_capacity(live.len());
        for item in live {
            slots_vec.push(ItemSlots {
                out: vec![None; item.tiles.len()],
                left: item.tiles.len(),
                done: Some(item.done),
            });
            shared_vec.push((item.slide, item.tiles));
        }
        let slots = Arc::new(Mutex::new(slots_vec));
        let shared: Arc<Vec<(Arc<Slide>, Vec<TileId>)>> = Arc::new(shared_vec);

        for spans in chunks {
            let slots = Arc::clone(&slots);
            let shared = Arc::clone(&shared);
            let analyzer = Arc::clone(&self.analyzer);
            let panics = Arc::clone(&self.panics);
            self.pool.execute(move || {
                for (item_idx, start, len) in spans {
                    let (slide, tiles) = &shared[item_idx];
                    let span = &tiles[start..start + len];
                    let ps = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        analyzer.analyze(slide, level, span)
                    }))
                    .unwrap_or_else(|_| {
                        panics.fetch_add(1, Ordering::SeqCst);
                        Vec::new()
                    });
                    let finish = {
                        let mut g = slots.lock().unwrap();
                        let it = &mut g[item_idx];
                        for (j, p) in ps.into_iter().enumerate().take(len) {
                            it.out[start + j] = Some(p);
                        }
                        it.left -= len;
                        if it.left == 0 {
                            let probs: Vec<f32> =
                                it.out.iter_mut().filter_map(|o| o.take()).collect();
                            Some((it.done.take().expect("done set once"), probs))
                        } else {
                            None
                        }
                    };
                    if let Some((done, probs)) = finish {
                        done(probs);
                    }
                }
            });
        }
    }

    /// Synchronous convenience wrapper around [`Self::analyze_async`].
    pub fn analyze(
        &self,
        slide: &Arc<Slide>,
        level: usize,
        tiles: &[TileId],
        batch: usize,
    ) -> Vec<f32> {
        let (tx, rx) = std::sync::mpsc::channel();
        self.analyze_async(
            Arc::clone(slide),
            level,
            tiles.to_vec(),
            batch,
            Box::new(move |ps| {
                let _ = tx.send(ps);
            }),
        );
        rx.recv().expect("pool completes batch")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::oracle::OracleAnalyzer;
    use crate::synth::slide_gen::{SlideKind, SlideSpec};

    fn slide() -> Arc<Slide> {
        Arc::new(Slide::from_spec(SlideSpec::new(
            "pool",
            5,
            16,
            8,
            3,
            64,
            SlideKind::LargeTumor,
        )))
    }

    #[test]
    fn pooled_analysis_matches_direct_call() {
        let analyzer: Arc<dyn Analyzer> = Arc::new(OracleAnalyzer::new(1));
        let pool = AnalyzerPool::new(Arc::clone(&analyzer), 4);
        let s = slide();
        let tiles = s.level_tile_ids(2);
        let direct = analyzer.analyze(&s, 2, &tiles);
        // Any chunking must reassemble to the same ordered probabilities.
        for batch in [1, 3, 16, 1000] {
            let pooled = pool.analyze(&s, 2, &tiles, batch);
            assert_eq!(pooled, direct, "batch={batch}");
        }
    }

    #[test]
    fn empty_frontier_completes_immediately() {
        let analyzer: Arc<dyn Analyzer> = Arc::new(OracleAnalyzer::new(1));
        let pool = AnalyzerPool::new(analyzer, 2);
        let s = slide();
        assert_eq!(pool.analyze(&s, 0, &[], 8), Vec::<f32>::new());
    }

    #[test]
    fn analyzer_panic_is_counted_and_pool_survives() {
        let pool = AnalyzerPool::new(Arc::new(crate::service::FaultyAnalyzer), 2);
        let s = slide();
        let tiles = s.level_tile_ids(1);
        // Faulting level: chunks report empty, the counter records them.
        let ps = pool.analyze(&s, 1, &tiles, 8);
        assert!(ps.len() < tiles.len(), "faulting chunks yield no probs");
        assert!(pool.panic_count() >= 1);
        // The pool still serves healthy levels afterwards.
        let ok = pool.analyze(&s, 2, &s.level_tile_ids(2), 8);
        assert_eq!(ok.len(), s.level_tile_ids(2).len());
    }

    #[test]
    fn coalesced_group_matches_per_item_results() {
        use std::sync::mpsc::channel;
        let analyzer: Arc<dyn Analyzer> = Arc::new(OracleAnalyzer::new(1));
        let pool = AnalyzerPool::new(Arc::clone(&analyzer), 3);
        // Two different slides, one group, chunk boundaries crossing items.
        let s1 = slide();
        let s2 = Arc::new(Slide::from_spec(SlideSpec::new(
            "pool2",
            6,
            16,
            8,
            3,
            64,
            SlideKind::SmallScattered,
        )));
        let t1 = s1.level_tile_ids(1);
        let t2 = s2.level_tile_ids(1);
        let want1 = analyzer.analyze(&s1, 1, &t1);
        let want2 = analyzer.analyze(&s2, 1, &t2);
        for batch in [1usize, 5, 7, 1000] {
            let (tx1, rx1) = channel();
            let (tx2, rx2) = channel();
            let (tx3, rx3) = channel();
            pool.analyze_coalesced_async(
                1,
                vec![
                    CoalescedItem {
                        slide: Arc::clone(&s1),
                        tiles: t1.clone(),
                        done: Box::new(move |ps| {
                            let _ = tx1.send(ps);
                        }),
                    },
                    CoalescedItem {
                        slide: Arc::clone(&s2),
                        tiles: t2.clone(),
                        done: Box::new(move |ps| {
                            let _ = tx2.send(ps);
                        }),
                    },
                    CoalescedItem {
                        slide: Arc::clone(&s1),
                        tiles: Vec::new(),
                        done: Box::new(move |ps| {
                            let _ = tx3.send(ps);
                        }),
                    },
                ],
                batch,
            );
            assert_eq!(rx1.recv().unwrap(), want1, "batch={batch}");
            assert_eq!(rx2.recv().unwrap(), want2, "batch={batch}");
            assert_eq!(rx3.recv().unwrap(), Vec::<f32>::new(), "empty item");
        }
    }

    #[test]
    fn coalesced_fault_fails_only_covered_items() {
        use std::sync::mpsc::channel;
        let pool = AnalyzerPool::new(Arc::new(crate::service::FaultyAnalyzer), 2);
        let s = slide();
        let tiles = s.level_tile_ids(1);
        let (tx, rx) = channel();
        pool.analyze_coalesced_async(
            1,
            vec![CoalescedItem {
                slide: Arc::clone(&s),
                tiles: tiles.clone(),
                done: Box::new(move |ps| {
                    let _ = tx.send(ps);
                }),
            }],
            8,
        );
        let got = rx.recv().unwrap();
        assert!(got.len() < tiles.len(), "faulting spans yield short results");
        assert!(pool.panic_count() >= 1);
    }

    #[test]
    fn concurrent_batches_from_many_threads() {
        let analyzer: Arc<dyn Analyzer> = Arc::new(OracleAnalyzer::new(1));
        let pool = Arc::new(AnalyzerPool::new(Arc::clone(&analyzer), 3));
        let s = slide();
        let tiles = s.level_tile_ids(1);
        let expect = analyzer.analyze(&s, 1, &tiles);
        let handles: Vec<_> = (0..6)
            .map(|_| {
                let pool = Arc::clone(&pool);
                let s = Arc::clone(&s);
                let tiles = tiles.clone();
                std::thread::spawn(move || pool.analyze(&s, 1, &tiles, 4))
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), expect);
        }
    }
}
