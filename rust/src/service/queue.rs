//! Bounded admission queue with backpressure and cancellation.
//!
//! Submissions beyond `capacity` are rejected immediately (the caller sees
//! [`SubmitError::QueueFull`] and decides whether to retry, shed or defer)
//! rather than buffered without bound — under sustained overload an
//! unbounded queue only converts memory into latency. Pop order is decided
//! by the scheduler's policy, not the queue, so one queue serves all
//! policies.

use std::collections::VecDeque;
use std::sync::Mutex;
use std::time::Instant;

use super::job::{JobId, JobSpec};

/// Why a submission was not admitted.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
pub enum SubmitError {
    #[error("admission queue full ({0} jobs)")]
    /// Queue at capacity — retry later or shed.
    QueueFull(usize),
    #[error("service is shutting down")]
    /// Admission closed; no further submissions.
    Closed,
    #[error("invalid job: {0}")]
    /// The spec failed validation.
    Invalid(String),
}

/// A job admitted to the queue, stamped with identity and arrival time.
#[derive(Debug, Clone)]
pub struct QueuedJob {
    /// Service-assigned id (1-based, submission order).
    pub id: JobId,
    /// The submitted job.
    pub spec: JobSpec,
    /// Submission stamp (queue-age / deadline basis).
    pub submitted: Instant,
}

struct Inner {
    entries: VecDeque<QueuedJob>,
    next_id: JobId,
    closed: bool,
}

/// The service's admission queue. Thread-safe; submitters and the
/// scheduler share it through an `Arc`.
pub struct AdmissionQueue {
    capacity: usize,
    inner: Mutex<Inner>,
}

impl AdmissionQueue {
    /// Bounded queue holding at most `capacity` jobs.
    pub fn new(capacity: usize) -> AdmissionQueue {
        AdmissionQueue {
            capacity: capacity.max(1),
            inner: Mutex::new(Inner {
                entries: VecDeque::new(),
                next_id: 1,
                closed: false,
            }),
        }
    }

    /// The backpressure bound.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Admit a job, returning its service-assigned id, or reject it when
    /// the queue is at capacity (backpressure) or closed.
    pub fn submit(&self, spec: JobSpec) -> Result<JobId, SubmitError> {
        if spec.source.levels() == 0 {
            return Err(SubmitError::Invalid(format!(
                "job {:?} has zero pyramid levels",
                spec.source
            )));
        }
        // The scheduler builds a PyramidRun from these; a mismatched
        // threshold vector must be rejected here, not panic the service.
        if spec.thresholds.zoom.len() != spec.source.levels() {
            return Err(SubmitError::Invalid(format!(
                "job {:?} has {} levels but {} thresholds",
                spec.source,
                spec.source.levels(),
                spec.thresholds.zoom.len()
            )));
        }
        let mut inner = self.inner.lock().unwrap();
        if inner.closed {
            return Err(SubmitError::Closed);
        }
        if inner.entries.len() >= self.capacity {
            return Err(SubmitError::QueueFull(self.capacity));
        }
        let id = inner.next_id;
        inner.next_id += 1;
        inner.entries.push_back(QueuedJob {
            id,
            spec,
            submitted: Instant::now(),
        });
        Ok(id)
    }

    /// Remove a still-queued job. Returns it so the caller can record a
    /// `Cancelled` result; `None` when the job already left the queue
    /// (started, finished, or never existed) — cancellation is
    /// admission-time only, a running analysis is never aborted mid-level.
    pub fn cancel(&self, id: JobId) -> Option<QueuedJob> {
        let mut inner = self.inner.lock().unwrap();
        let pos = inner.entries.iter().position(|q| q.id == id)?;
        inner.entries.remove(pos)
    }

    /// Remove and return the queued job selected by `pick` (an index into
    /// the current queue snapshot). The scheduler passes its policy here.
    pub fn pop_with<F>(&self, pick: F) -> Option<QueuedJob>
    where
        F: FnOnce(&[QueuedJob]) -> Option<usize>,
    {
        let mut inner = self.inner.lock().unwrap();
        inner.entries.make_contiguous();
        let idx = pick(inner.entries.as_slices().0)?;
        inner.entries.remove(idx)
    }

    /// Read-only view of the queued entries, under the queue lock. The
    /// scheduler's preemption check uses this to rank waiting candidates
    /// without popping anything.
    pub fn peek_with<F, T>(&self, f: F) -> T
    where
        F: FnOnce(&[QueuedJob]) -> T,
    {
        let mut inner = self.inner.lock().unwrap();
        inner.entries.make_contiguous();
        f(inner.entries.as_slices().0)
    }

    /// Jobs currently queued.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    /// Whether nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Stop accepting new submissions; queued jobs still drain.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pyramid::tree::Thresholds;
    use crate::service::job::JobSource;
    use crate::synth::slide_gen::{SlideKind, SlideSpec};

    fn job(name: &str) -> JobSpec {
        let spec = SlideSpec::new(name, 1, 16, 8, 3, 64, SlideKind::Negative);
        JobSpec::new(JobSource::Spec(spec), Thresholds::uniform(3, 0.4))
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let q = AdmissionQueue::new(2);
        assert!(q.submit(job("a")).is_ok());
        assert!(q.submit(job("b")).is_ok());
        assert_eq!(q.submit(job("c")), Err(SubmitError::QueueFull(2)));
        // Draining one slot re-opens admission.
        q.pop_with(|e| (!e.is_empty()).then_some(0)).unwrap();
        assert!(q.submit(job("c")).is_ok());
    }

    #[test]
    fn ids_are_monotonic_and_pop_sees_fifo_order() {
        let q = AdmissionQueue::new(8);
        let a = q.submit(job("a")).unwrap();
        let b = q.submit(job("b")).unwrap();
        assert!(b > a);
        let first = q.pop_with(|e| {
            assert_eq!(e.len(), 2);
            assert!(e[0].id < e[1].id);
            Some(0)
        });
        assert_eq!(first.unwrap().id, a);
    }

    #[test]
    fn cancel_removes_only_queued_jobs() {
        let q = AdmissionQueue::new(8);
        let a = q.submit(job("a")).unwrap();
        let b = q.submit(job("b")).unwrap();
        let got = q.cancel(a).expect("a still queued");
        assert_eq!(got.id, a);
        assert_eq!(q.len(), 1);
        assert!(q.cancel(a).is_none(), "double cancel");
        assert!(q.cancel(9999).is_none(), "unknown id");
        let left = q.pop_with(|_| Some(0)).unwrap();
        assert_eq!(left.id, b);
    }

    #[test]
    fn close_stops_admission_but_drains() {
        let q = AdmissionQueue::new(8);
        q.submit(job("a")).unwrap();
        q.close();
        assert_eq!(q.submit(job("b")), Err(SubmitError::Closed));
        assert_eq!(q.len(), 1, "queued work survives close");
    }

    #[test]
    fn zero_level_jobs_rejected_at_submission() {
        let q = AdmissionQueue::new(8);
        // Build an invalid spec bypassing SlideSpec::new's validation.
        let mut spec = SlideSpec::new("z", 1, 16, 8, 1, 64, SlideKind::Negative);
        spec.levels = 0;
        let j = JobSpec::new(JobSource::Spec(spec), Thresholds::uniform(0, 0.4));
        assert!(matches!(q.submit(j), Err(SubmitError::Invalid(_))));
    }

    #[test]
    fn threshold_count_mismatch_rejected_at_submission() {
        let q = AdmissionQueue::new(8);
        let spec = SlideSpec::new("t", 1, 16, 8, 3, 64, SlideKind::Negative);
        let j = JobSpec::new(JobSource::Spec(spec), Thresholds::uniform(2, 0.4));
        assert!(matches!(q.submit(j), Err(SubmitError::Invalid(_))));
    }
}
