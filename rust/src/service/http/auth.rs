//! Bearer-token authentication mapping tokens onto scheduler tenants.
//!
//! The token table is a plain text file of `token tenant` lines — the
//! deployment story for a modest cluster is "scp a file", not an IdP.
//! Multiple tokens may map to the same tenant (per-client credentials,
//! shared fair-share account); the tenant string is the same key the
//! scheduler's weighted-fair-share policy weighs and quota-gates, so an
//! authenticated submission lands directly in its tenant's share.
//!
//! Token comparison is length-then-byte equality over short secrets;
//! the threat model here is a modest trusted cluster's LAN, not a
//! public internet edge.

use std::collections::HashMap;
use std::path::Path;

/// Immutable token → tenant table, loaded once at startup.
#[derive(Debug, Clone, Default)]
pub struct TokenTable {
    tokens: HashMap<String, String>,
}

impl TokenTable {
    /// Parse a table from `token tenant` lines. Blank lines and `#`
    /// comments are skipped; a line with fewer or more than two fields,
    /// or a duplicate token, is an error.
    pub fn parse(text: &str) -> Result<TokenTable, String> {
        let mut tokens = HashMap::new();
        for (i, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = line.split_whitespace().collect();
            if fields.len() != 2 {
                return Err(format!(
                    "tokens file line {}: expected `token tenant`, got {} fields",
                    i + 1,
                    fields.len()
                ));
            }
            if tokens.insert(fields[0].to_string(), fields[1].to_string()).is_some() {
                return Err(format!("tokens file line {}: duplicate token", i + 1));
            }
        }
        if tokens.is_empty() {
            return Err("tokens file has no credentials".to_string());
        }
        Ok(TokenTable { tokens })
    }

    /// Load and parse a tokens file.
    pub fn load(path: impl AsRef<Path>) -> Result<TokenTable, String> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| format!("read tokens file {}: {e}", path.as_ref().display()))?;
        TokenTable::parse(&text)
    }

    /// A single-credential table (tests, ephemeral servers).
    pub fn single(token: &str, tenant: &str) -> TokenTable {
        let mut tokens = HashMap::new();
        tokens.insert(token.to_string(), tenant.to_string());
        TokenTable { tokens }
    }

    /// Resolve an `Authorization` header value to a tenant. `None` for a
    /// missing header, a non-Bearer scheme, or an unknown token — the
    /// caller answers 401 without distinguishing which (no oracle).
    pub fn tenant(&self, authorization: Option<&str>) -> Option<&str> {
        let auth = authorization?;
        let (scheme, token) = auth.split_once(' ')?;
        if !scheme.eq_ignore_ascii_case("bearer") {
            return None;
        }
        self.tokens.get(token.trim()).map(String::as_str)
    }

    /// Number of credentials in the table.
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    /// Whether the table holds no credentials.
    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_comments_blanks_and_multiple_tenants() {
        let t = TokenTable::parse(
            "# credentials\n\nalpha-key lab_a\nbeta-key lab_b\nalpha-key2  lab_a\n",
        )
        .unwrap();
        assert_eq!(t.len(), 3);
        assert_eq!(t.tenant(Some("Bearer alpha-key")), Some("lab_a"));
        assert_eq!(t.tenant(Some("bearer beta-key")), Some("lab_b"));
        assert_eq!(t.tenant(Some("Bearer alpha-key2")), Some("lab_a"));
    }

    #[test]
    fn rejects_malformed_tables() {
        assert!(TokenTable::parse("").is_err());
        assert!(TokenTable::parse("just-a-token\n").is_err());
        assert!(TokenTable::parse("a b c\n").is_err());
        assert!(TokenTable::parse("k t1\nk t2\n").is_err());
    }

    #[test]
    fn unknown_scheme_or_token_resolves_to_none() {
        let t = TokenTable::single("s3cret", "lab_a");
        assert_eq!(t.tenant(None), None);
        assert_eq!(t.tenant(Some("s3cret")), None, "missing scheme");
        assert_eq!(t.tenant(Some("Basic s3cret")), None);
        assert_eq!(t.tenant(Some("Bearer wrong")), None);
        assert_eq!(t.tenant(Some("Bearer s3cret")), Some("lab_a"));
        assert!(!t.is_empty());
    }
}
