//! Hardened incremental HTTP/1.1 request parser.
//!
//! Hand-rolled because the crate carries zero HTTP dependencies, and
//! hardened because the admission front-end is the one surface an
//! untrusted peer can reach. The parser is strict where the RFCs allow
//! leniency whenever that leniency is a known request-smuggling vector:
//!
//! * every line must end in CRLF — a bare LF is rejected, not repaired;
//! * `Transfer-Encoding` together with `Content-Length` is rejected
//!   outright (the classic CL.TE / TE.CL desync primitive), as are
//!   duplicate or non-digit `Content-Length` values;
//! * only the `chunked` transfer coding is accepted, chunk-size
//!   extensions and trailer fields are rejected, and decoded bodies are
//!   capped before buffering;
//! * header names must be RFC 7230 tokens (no embedded whitespace before
//!   the colon), obs-fold continuation lines are rejected, and control
//!   bytes in values are rejected;
//! * request line, per-header size, header count and body size are all
//!   bounded by [`Limits`]; a peer that trickles bytes (slow-loris) hits
//!   the socket read timeout and is dropped with `408`.
//!
//! Every rejection maps to a deterministic 4xx/5xx via
//! [`ParseError::status`]; malformed input can never panic the service.

use std::io::Read;
use std::time::Duration;

/// Size and patience bounds enforced while parsing one request.
#[derive(Debug, Clone)]
pub struct Limits {
    /// Maximum request-line length in bytes (method + target + version).
    pub request_line: usize,
    /// Maximum length of a single header line in bytes.
    pub header_line: usize,
    /// Maximum number of headers per request.
    pub max_headers: usize,
    /// Maximum decoded body size in bytes (fixed or chunked).
    pub max_body: usize,
    /// Socket read timeout the owner arms on the stream; the parser maps
    /// the resulting `WouldBlock`/`TimedOut` errors to
    /// [`ParseError::Timeout`].
    pub read_timeout: Duration,
}

impl Default for Limits {
    fn default() -> Limits {
        Limits {
            request_line: 8 * 1024,
            header_line: 8 * 1024,
            max_headers: 64,
            max_body: 1024 * 1024,
            read_timeout: Duration::from_secs(5),
        }
    }
}

/// Why a request was rejected. [`ParseError::status`] maps each variant
/// to the response status the connection handler sends before closing.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
pub enum ParseError {
    #[error("bad request: {0}")]
    /// Malformed syntax or a smuggling-shaped construct (400).
    Bad(&'static str),
    #[error("request line too long")]
    /// Request line exceeded [`Limits::request_line`] (414).
    UriTooLong,
    #[error("headers too large")]
    /// A header line or the header count exceeded its bound (431).
    HeadersTooLarge,
    #[error("body too large")]
    /// Declared or decoded body exceeded [`Limits::max_body`] (413).
    BodyTooLarge,
    #[error("read timed out mid-request")]
    /// The peer stalled after starting a request — slow-loris (408).
    Timeout,
    #[error("http version not supported")]
    /// Not HTTP/1.0 or HTTP/1.1 (505).
    Version,
    #[error("connection closed mid-request")]
    /// EOF after the request started but before it completed; nothing to
    /// answer, the handler just drops the connection.
    Truncated,
    #[error("socket error: {0}")]
    /// Transport-level failure; the handler drops the connection.
    Io(String),
}

impl ParseError {
    /// The HTTP status this rejection answers with (`None`: close the
    /// connection without a response — there is no one to answer).
    pub fn status(&self) -> Option<u16> {
        match self {
            ParseError::Bad(_) => Some(400),
            ParseError::UriTooLong => Some(414),
            ParseError::HeadersTooLarge => Some(431),
            ParseError::BodyTooLarge => Some(413),
            ParseError::Timeout => Some(408),
            ParseError::Version => Some(505),
            ParseError::Truncated | ParseError::Io(_) => None,
        }
    }
}

/// HTTP version of a parsed request (only 1.0 / 1.1 are accepted).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Version {
    /// HTTP/1.0 — connections close by default.
    V10,
    /// HTTP/1.1 — connections persist by default.
    V11,
}

/// One fully-read request: head plus buffered body.
#[derive(Debug, Clone)]
pub struct Request {
    /// Request method, uppercase token (`GET`, `POST`, …).
    pub method: String,
    /// Path component of the request target (before `?`).
    pub path: String,
    /// Raw query string (after `?`, empty when absent).
    pub query: String,
    /// Protocol version.
    pub version: Version,
    /// Headers in arrival order; names lowercased, values trimmed.
    pub headers: Vec<(String, String)>,
    /// The decoded body (empty when the request carried none).
    pub body: Vec<u8>,
}

impl Request {
    /// First value of a header, by lowercase name.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v.as_str())
    }

    /// Whether the connection persists after this request
    /// (`Connection` header over the version default).
    pub fn keep_alive(&self) -> bool {
        match self.header("connection").map(str::to_ascii_lowercase) {
            Some(v) if v == "close" => false,
            Some(v) if v == "keep-alive" => true,
            _ => self.version == Version::V11,
        }
    }

    /// First value of a query parameter (`?format=png`). No percent
    /// decoding — the API's parameter values are plain tokens.
    pub fn query_param(&self, name: &str) -> Option<&str> {
        self.query.split('&').find_map(|pair| {
            let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
            (k == name).then_some(v)
        })
    }
}

/// RFC 7230 `tchar`: the characters legal in a header-name / method token.
fn is_tchar(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b"!#$%&'*+-.^_`|~".contains(&b)
}

/// Incremental request reader over one connection. Owns a buffer so
/// pipelined bytes read past one request are kept for the next.
pub struct RequestReader<R: Read> {
    src: R,
    buf: Vec<u8>,
    /// Consumed prefix of `buf`.
    pos: usize,
    limits: Limits,
}

impl<R: Read> RequestReader<R> {
    /// A reader enforcing `limits` over `src`. The caller is responsible
    /// for arming [`Limits::read_timeout`] on the underlying socket.
    pub fn new(src: R, limits: Limits) -> RequestReader<R> {
        RequestReader {
            src,
            buf: Vec::new(),
            pos: 0,
            limits,
        }
    }

    /// Read one complete request. `Ok(None)` on clean EOF (or an idle
    /// timeout) before the first byte of a request — the keep-alive
    /// connection just ended.
    pub fn read_request(&mut self) -> Result<Option<Request>, ParseError> {
        let line = match self.read_line(self.limits.request_line, ParseError::UriTooLong) {
            Ok(l) => l,
            // An idle keep-alive peer that times out or disconnects
            // between requests is not an error worth answering.
            Err(ParseError::Truncated) | Err(ParseError::Timeout) if self.buf.len() == self.pos => {
                return Ok(None)
            }
            Err(e) => return Err(e),
        };
        let (method, path, query, version) = parse_request_line(&line)?;
        let headers = self.read_headers()?;
        let body = self.read_body(&headers, version)?;
        Ok(Some(Request {
            method,
            path,
            query,
            version,
            headers,
            body,
        }))
    }

    /// Pull more bytes from the socket into the buffer. `Ok(false)` on EOF.
    fn fill(&mut self) -> Result<bool, ParseError> {
        // Compact the consumed prefix occasionally so pipelining cannot
        // grow the buffer without bound.
        if self.pos > 0 && self.pos == self.buf.len() {
            self.buf.clear();
            self.pos = 0;
        }
        let mut chunk = [0u8; 4096];
        match self.src.read(&mut chunk) {
            Ok(0) => Ok(false),
            Ok(n) => {
                self.buf.extend_from_slice(&chunk[..n]);
                Ok(true)
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                Err(ParseError::Timeout)
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => Ok(true),
            Err(e) => Err(ParseError::Io(e.to_string())),
        }
    }

    /// Read one CRLF-terminated line (returned without the CRLF),
    /// rejecting bare-LF terminators and lines longer than `max`.
    fn read_line(&mut self, max: usize, too_long: ParseError) -> Result<Vec<u8>, ParseError> {
        loop {
            if let Some(nl) = self.buf[self.pos..].iter().position(|&b| b == b'\n') {
                let end = self.pos + nl;
                if end == self.pos || self.buf[end - 1] != b'\r' {
                    return Err(ParseError::Bad("bare LF line terminator"));
                }
                if end - 1 - self.pos > max {
                    return Err(too_long);
                }
                let line = self.buf[self.pos..end - 1].to_vec();
                self.pos = end + 1;
                return Ok(line);
            }
            if self.buf.len() - self.pos > max + 2 {
                return Err(too_long);
            }
            if !self.fill()? {
                return Err(ParseError::Truncated);
            }
        }
    }

    /// Read exactly `n` body bytes.
    fn read_exact_body(&mut self, n: usize) -> Result<Vec<u8>, ParseError> {
        while self.buf.len() - self.pos < n {
            if !self.fill()? {
                return Err(ParseError::Truncated);
            }
        }
        let out = self.buf[self.pos..self.pos + n].to_vec();
        self.pos += n;
        Ok(out)
    }

    /// Parse the header block up to the empty line.
    fn read_headers(&mut self) -> Result<Vec<(String, String)>, ParseError> {
        let mut headers = Vec::new();
        loop {
            let line = self.read_line(self.limits.header_line, ParseError::HeadersTooLarge)?;
            if line.is_empty() {
                return Ok(headers);
            }
            if headers.len() >= self.limits.max_headers {
                return Err(ParseError::HeadersTooLarge);
            }
            if line[0] == b' ' || line[0] == b'\t' {
                // RFC 7230 deprecated line folding; accepting it lets a
                // front/back-end pair disagree about header boundaries.
                return Err(ParseError::Bad("obsolete header line folding"));
            }
            let colon = line
                .iter()
                .position(|&b| b == b':')
                .ok_or(ParseError::Bad("header line without colon"))?;
            let name = &line[..colon];
            if name.is_empty() || !name.iter().all(|&b| is_tchar(b)) {
                // Catches embedded whitespace before the colon, another
                // classic boundary-disagreement primitive.
                return Err(ParseError::Bad("invalid header name"));
            }
            let value = &line[colon + 1..];
            if value.iter().any(|&b| b < 0x20 && b != b'\t') || value.contains(&0x7f) {
                return Err(ParseError::Bad("control byte in header value"));
            }
            let name = String::from_utf8_lossy(name).to_ascii_lowercase();
            let value = String::from_utf8_lossy(value).trim().to_string();
            headers.push((name, value));
        }
    }

    /// Read the message body as declared by the headers.
    fn read_body(
        &mut self,
        headers: &[(String, String)],
        version: Version,
    ) -> Result<Vec<u8>, ParseError> {
        let te: Vec<&str> = headers
            .iter()
            .filter(|(n, _)| n == "transfer-encoding")
            .map(|(_, v)| v.as_str())
            .collect();
        let cl: Vec<&str> = headers
            .iter()
            .filter(|(n, _)| n == "content-length")
            .map(|(_, v)| v.as_str())
            .collect();
        if !te.is_empty() && !cl.is_empty() {
            // The CL.TE / TE.CL smuggling primitive: two framing
            // declarations that different parsers may rank differently.
            return Err(ParseError::Bad(
                "both transfer-encoding and content-length",
            ));
        }
        if !te.is_empty() {
            if version == Version::V10 {
                return Err(ParseError::Bad("transfer-encoding in HTTP/1.0"));
            }
            if te.len() > 1 || !te[0].eq_ignore_ascii_case("chunked") {
                return Err(ParseError::Bad("unsupported transfer-encoding"));
            }
            return self.read_chunked_body();
        }
        match cl.len() {
            0 => Ok(Vec::new()),
            1 => {
                let v = cl[0];
                if v.is_empty() || v.len() > 19 || !v.bytes().all(|b| b.is_ascii_digit()) {
                    return Err(ParseError::Bad("malformed content-length"));
                }
                let n: u64 = v.parse().map_err(|_| ParseError::Bad("malformed content-length"))?;
                if n as usize > self.limits.max_body {
                    return Err(ParseError::BodyTooLarge);
                }
                self.read_exact_body(n as usize)
            }
            // Duplicate Content-Length headers — even when they agree —
            // are rejected rather than reconciled.
            _ => Err(ParseError::Bad("duplicate content-length")),
        }
    }

    /// Decode a `chunked` body: strict hex sizes, no chunk extensions,
    /// no trailer fields, total bounded by [`Limits::max_body`].
    fn read_chunked_body(&mut self) -> Result<Vec<u8>, ParseError> {
        let mut body = Vec::new();
        loop {
            let line = self.read_line(32, ParseError::Bad("chunk size line too long"))?;
            if line.is_empty() || line.len() > 8 {
                return Err(ParseError::Bad("malformed chunk size"));
            }
            if !line.iter().all(|b| b.is_ascii_hexdigit()) {
                // Also rejects chunk extensions (`;ext=…`), which some
                // chains parse and others ignore.
                return Err(ParseError::Bad("malformed chunk size"));
            }
            let size = usize::from_str_radix(std::str::from_utf8(&line).unwrap_or(""), 16)
                .map_err(|_| ParseError::Bad("malformed chunk size"))?;
            if size == 0 {
                // Strict final sequence: `0 CRLF CRLF`, no trailers.
                let trailer = self.read_line(self.limits.header_line, ParseError::HeadersTooLarge)?;
                if !trailer.is_empty() {
                    return Err(ParseError::Bad("trailer fields not accepted"));
                }
                return Ok(body);
            }
            if body.len() + size > self.limits.max_body {
                return Err(ParseError::BodyTooLarge);
            }
            body.extend_from_slice(&self.read_exact_body(size)?);
            let sep = self.read_exact_body(2)?;
            if sep != b"\r\n" {
                return Err(ParseError::Bad("chunk data not CRLF-terminated"));
            }
        }
    }
}

/// Split and validate `METHOD SP target SP HTTP/1.x`.
fn parse_request_line(line: &[u8]) -> Result<(String, String, String, Version), ParseError> {
    if line.is_empty() {
        return Err(ParseError::Bad("empty request line"));
    }
    if line.iter().any(|&b| b < 0x20 || b == 0x7f) {
        return Err(ParseError::Bad("control byte in request line"));
    }
    let parts: Vec<&[u8]> = line.split(|&b| b == b' ').collect();
    if parts.len() != 3 || parts.iter().any(|p| p.is_empty()) {
        return Err(ParseError::Bad("malformed request line"));
    }
    let (method, target, version) = (parts[0], parts[1], parts[2]);
    if method.len() > 16 || !method.iter().all(|&b| is_tchar(b)) {
        return Err(ParseError::Bad("malformed method token"));
    }
    let version = match version {
        b"HTTP/1.1" => Version::V11,
        b"HTTP/1.0" => Version::V10,
        v if v.starts_with(b"HTTP/") => return Err(ParseError::Version),
        _ => return Err(ParseError::Bad("malformed http version")),
    };
    if target[0] != b'/' {
        // No absolute-form or authority-form targets: this server is an
        // origin, never a proxy.
        return Err(ParseError::Bad("request target must be origin-form"));
    }
    let target = String::from_utf8_lossy(target).to_string();
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target, String::new()),
    };
    let method = String::from_utf8_lossy(method).to_string();
    Ok((method, path, query, version))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(input: &[u8]) -> Result<Option<Request>, ParseError> {
        RequestReader::new(input, Limits::default()).read_request()
    }

    #[test]
    fn simple_get_parses() {
        let r = parse(b"GET /v1/jobs/3?format=png HTTP/1.1\r\nHost: x\r\n\r\n")
            .unwrap()
            .unwrap();
        assert_eq!(r.method, "GET");
        assert_eq!(r.path, "/v1/jobs/3");
        assert_eq!(r.query_param("format"), Some("png"));
        assert_eq!(r.header("host"), Some("x"));
        assert!(r.keep_alive());
        assert!(r.body.is_empty());
    }

    #[test]
    fn fixed_and_chunked_bodies_decode() {
        let r = parse(b"POST / HTTP/1.1\r\nContent-Length: 4\r\n\r\nabcd")
            .unwrap()
            .unwrap();
        assert_eq!(r.body, b"abcd");
        let r = parse(b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n3\r\nabc\r\n2\r\nde\r\n0\r\n\r\n")
            .unwrap()
            .unwrap();
        assert_eq!(r.body, b"abcde");
    }

    #[test]
    fn smuggling_shapes_are_rejected() {
        // TE + CL together.
        assert_eq!(
            parse(b"POST / HTTP/1.1\r\nContent-Length: 3\r\nTransfer-Encoding: chunked\r\n\r\n0\r\n\r\n")
                .unwrap_err()
                .status(),
            Some(400)
        );
        // Duplicate Content-Length.
        assert!(parse(b"POST / HTTP/1.1\r\nContent-Length: 3\r\nContent-Length: 3\r\n\r\nabc").is_err());
        // Bare LF terminator.
        assert!(parse(b"GET / HTTP/1.1\nHost: x\r\n\r\n").is_err());
        // Whitespace before the header colon.
        assert!(parse(b"GET / HTTP/1.1\r\nHost : x\r\n\r\n").is_err());
        // Obsolete folding.
        assert!(parse(b"GET / HTTP/1.1\r\nA: b\r\n c\r\n\r\n").is_err());
        // Chunk extension.
        assert!(parse(b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n3;x=1\r\nabc\r\n0\r\n\r\n").is_err());
    }

    #[test]
    fn limits_map_to_statuses() {
        let long = format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(9000));
        assert_eq!(parse(long.as_bytes()).unwrap_err().status(), Some(414));
        let big = format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n", 2 * 1024 * 1024);
        assert_eq!(parse(big.as_bytes()).unwrap_err().status(), Some(413));
        assert_eq!(
            parse(b"GET / HTTP/2.0\r\n\r\n").unwrap_err().status(),
            Some(505)
        );
        let many = format!("GET / HTTP/1.1\r\n{}\r\n", "X-A: 1\r\n".repeat(100));
        assert_eq!(parse(many.as_bytes()).unwrap_err().status(), Some(431));
    }

    #[test]
    fn eof_before_a_request_is_a_clean_end() {
        assert!(parse(b"").unwrap().is_none());
        assert!(matches!(
            parse(b"GET / HTT"),
            Err(ParseError::Truncated)
        ));
    }

    #[test]
    fn http10_closes_by_default_and_rejects_te() {
        let r = parse(b"GET / HTTP/1.0\r\n\r\n").unwrap().unwrap();
        assert!(!r.keep_alive());
        assert!(parse(b"POST / HTTP/1.0\r\nTransfer-Encoding: chunked\r\n\r\n0\r\n\r\n").is_err());
    }
}
