//! Zero-dependency HTTP/1.1 admission front-end (§ deployment).
//!
//! Turns the in-process [`AnalysisService`] into a network service an
//! external client can drive with nothing but `curl`: jobs come in over
//! the wire as SlideSpec JSON, results stream back progressively as
//! per-level tree deltas while the scheduler is still working. The
//! stack is hand-rolled on `std::net` — the crate's dependency budget
//! (anyhow/thiserror/log/once_cell) stays untouched:
//!
//! * [`parser`] — hardened incremental request parser: strict limits,
//!   smuggling-shaped inputs rejected, every malformed request a clean
//!   4xx/5xx, never a panic.
//! * [`wire`] — response serialization + the chunked-transfer writer
//!   behind progressive result streaming.
//! * [`auth`] — bearer-token → tenant table; the resolved tenant is the
//!   scheduler's fair-share key, so HTTP clients land directly in the
//!   weighted-fair-share/quota machinery.
//! * [`api`] — routing and handlers over the admission queue, the
//!   scheduler's [`JobBoard`](crate::service::board::JobBoard) and the
//!   shared metrics registry.
//!
//! [`HttpFrontend`] owns the listener thread and one thread per
//! connection (bounded by [`HttpConfig::max_connections`]; excess
//! connections get an immediate `503`). Backpressure from the bounded
//! admission queue surfaces as `429 Too Many Requests` + `Retry-After`;
//! gray degradation (an impaired shard store, a cluster with no live
//! workers) surfaces as `503` on submission and a degraded `/healthz`,
//! driven by the shared [`HealthState`] registry.
//! Shutdown is cooperative: the stop flag short-circuits keep-alive
//! loops and in-flight result streams, and the socket read timeout
//! bounds how long an idle connection can delay [`HttpFrontend::stop`].

/// Request routing and endpoint handlers.
pub mod api;
/// Bearer-token → tenant authentication.
pub mod auth;
/// Hardened HTTP/1.1 request parsing.
pub mod parser;
/// Response serialization and chunked streaming.
pub mod wire;

use std::collections::BTreeSet;
use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::obs::{self, Level};
use crate::service::AnalysisService;

pub use auth::TokenTable;
pub use parser::Limits;

use api::Router;

/// Shared degraded-mode registry: gray failures observed elsewhere in
/// the process (an impaired shard store, a cluster with no live
/// workers) are posted here by watchdog threads, and the HTTP surface
/// consults it — `/healthz` answers `503` with the active reasons and
/// job submission sheds load with `Retry-After` instead of accepting
/// work the service cannot currently finish.
///
/// Degradation is a *set* of independent reason strings: each source
/// sets and clears its own reason, and the service is degraded while
/// the set is non-empty. Transitions are logged as `http` events.
#[derive(Debug, Default)]
pub struct HealthState {
    reasons: Mutex<BTreeSet<String>>,
}

impl HealthState {
    /// Mark the service degraded for `reason` (idempotent).
    pub fn set_degraded(&self, reason: &str) {
        let mut r = self.reasons.lock().unwrap();
        if r.insert(reason.to_string()) {
            obs::event(Level::Warn, "http", "degraded", &[("reason", reason.into())]);
        }
    }

    /// Clear `reason` (idempotent); the service recovers when the last
    /// reason clears.
    pub fn clear_degraded(&self, reason: &str) {
        let mut r = self.reasons.lock().unwrap();
        if r.remove(reason) && r.is_empty() {
            obs::event(Level::Info, "http", "recovered", &[]);
        }
    }

    /// Whether any degradation reason is active.
    pub fn is_degraded(&self) -> bool {
        !self.reasons.lock().unwrap().is_empty()
    }

    /// The active reasons, sorted.
    pub fn reasons(&self) -> Vec<String> {
        self.reasons.lock().unwrap().iter().cloned().collect()
    }
}

/// Front-end configuration.
#[derive(Debug, Clone)]
pub struct HttpConfig {
    /// Bind address (`host:port`; port 0 picks an ephemeral port).
    pub listen: String,
    /// Credential table mapping bearer tokens onto scheduler tenants.
    pub tokens: TokenTable,
    /// Parser size/patience bounds.
    pub limits: Limits,
    /// Maximum concurrent connections; excess accepts answer `503`.
    pub max_connections: usize,
    /// Degraded-state registry consulted by `/healthz` and submission.
    /// Clone the `Arc` before [`HttpFrontend::start`] to drive it from
    /// a watchdog.
    pub health: Arc<HealthState>,
}

impl HttpConfig {
    /// A config with default limits and connection bound.
    pub fn new(listen: impl Into<String>, tokens: TokenTable) -> HttpConfig {
        HttpConfig {
            listen: listen.into(),
            tokens,
            limits: Limits::default(),
            max_connections: 64,
            health: Arc::new(HealthState::default()),
        }
    }
}

/// A running HTTP front-end over an [`AnalysisService`].
///
/// The service itself is shared behind an `Arc`: the front-end never
/// owns shutdown of the scheduler, it only stops accepting and serving
/// connections — the embedding binary stops the front-end first, then
/// drains the service for its final report.
pub struct HttpFrontend {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    listener: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

/// Decrements the active-connection count even if a handler panics.
struct ActiveGuard(Arc<AtomicUsize>);

impl Drop for ActiveGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }
}

impl HttpFrontend {
    /// Bind `cfg.listen` and start serving `svc`. Fails on bind errors
    /// or an empty token table (an unauthenticated admission endpoint is
    /// a misconfiguration, not a default).
    pub fn start(svc: Arc<AnalysisService>, cfg: HttpConfig) -> Result<HttpFrontend, String> {
        if cfg.tokens.is_empty() {
            return Err("refusing to serve without credentials (empty token table)".to_string());
        }
        let listener =
            TcpListener::bind(&cfg.listen).map_err(|e| format!("bind {}: {e}", cfg.listen))?;
        let addr = listener
            .local_addr()
            .map_err(|e| format!("local_addr: {e}"))?;
        let stop = Arc::new(AtomicBool::new(false));
        let registry = svc.registry();
        let m_conns = registry.counter("http.connections");
        let m_busy = registry.counter("http.rejected_busy");
        let router = Arc::new(Router::new(
            svc,
            cfg.tokens.clone(),
            Arc::clone(&stop),
            Arc::clone(&cfg.health),
        ));
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let active = Arc::new(AtomicUsize::new(0));
        obs::event(
            Level::Info,
            "http",
            "listen",
            &[("addr", addr.to_string().into())],
        );
        let accept_stop = Arc::clone(&stop);
        let accept_conns = Arc::clone(&conns);
        let limits = cfg.limits.clone();
        let max_conns = cfg.max_connections.max(1);
        let listener_thread = std::thread::Builder::new()
            .name("http-listener".to_string())
            .spawn(move || {
                let accept_policy = crate::fault::RetryPolicy {
                    base: std::time::Duration::from_millis(1),
                    cap: std::time::Duration::from_millis(250),
                    deadline: std::time::Duration::from_secs(3600),
                    max_attempts: u32::MAX,
                };
                let mut nap = crate::fault::Backoff::new("http.accept", &accept_policy);
                loop {
                    let (stream, _peer) = match listener.accept() {
                        Ok(pair) => {
                            nap.reset();
                            pair
                        }
                        Err(_) => {
                            if accept_stop.load(Ordering::Relaxed) {
                                break;
                            }
                            // Transient accept failure (e.g. fd
                            // exhaustion): back off instead of spinning.
                            // The listener has no deadline of its own —
                            // exhaustion rewinds the ladder and keeps
                            // retrying at the capped cadence.
                            if !nap.sleep() {
                                nap.reset();
                            }
                            continue;
                        }
                    };
                    if accept_stop.load(Ordering::Relaxed) {
                        // Woken by the stop() self-connect (or a late client).
                        let _ = stream.shutdown(Shutdown::Both);
                        break;
                    }
                    m_conns.inc();
                    let mut pool = accept_conns.lock().unwrap();
                    // Reap finished handler threads so a long-lived server
                    // doesn't accumulate handles (dropping a finished handle
                    // is a no-op join).
                    pool.retain(|h| !h.is_finished());
                    if active.load(Ordering::Relaxed) >= max_conns {
                        m_busy.inc();
                        let mut s = stream;
                        let _ = wire::respond_error(&mut s, 503, "connection limit", &[], false);
                        let _ = s.shutdown(Shutdown::Both);
                        continue;
                    }
                    active.fetch_add(1, Ordering::Relaxed);
                    let guard = ActiveGuard(Arc::clone(&active));
                    let router = Arc::clone(&router);
                    let limits = limits.clone();
                    let handle = std::thread::Builder::new()
                        .name("http-conn".to_string())
                        .spawn(move || {
                            let _guard = guard;
                            handle_connection(&router, &limits, stream);
                        });
                    match handle {
                        Ok(h) => pool.push(h),
                        Err(_) => { /* spawn failed; guard dropped with the closure */ }
                    }
                }
            })
            .map_err(|e| format!("spawn http listener: {e}"))?;
        Ok(HttpFrontend {
            addr,
            stop,
            listener: Some(listener_thread),
            conns,
        })
    }

    /// The bound address (resolves port 0 to the actual port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, interrupt keep-alive loops and in-flight streams,
    /// and join every thread. Bounded by the parser read timeout.
    pub fn stop(mut self) {
        self.drain();
    }

    fn drain(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Wake the blocking accept.
        let _ = TcpStream::connect(self.addr);
        if let Some(l) = self.listener.take() {
            let _ = l.join();
        }
        let handles: Vec<JoinHandle<()>> = std::mem::take(&mut *self.conns.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
        obs::event(Level::Info, "http", "stopped", &[]);
    }
}

impl Drop for HttpFrontend {
    fn drop(&mut self) {
        if self.listener.is_some() {
            self.drain();
        }
    }
}

/// Serve one connection: parse requests in a keep-alive loop, route
/// them, answer parser rejections with their mapped status. When a
/// fault plan is armed, both connection halves run through a
/// [`FaultyStream`](crate::fault::FaultyStream) labelled
/// `http:<peer>`, so `net.*` rules scoped to that peer (or `*`) apply
/// to this connection's reads and writes.
fn handle_connection(router: &Router, limits: &Limits, stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(limits.read_timeout));
    let _ = stream.set_nodelay(true);
    let peer = stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| "unknown".to_string());
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    let Ok(write_half) = stream.try_clone() else {
        return;
    };
    match crate::fault::active() {
        Some(inj) => {
            let label = format!("http:{peer}");
            serve_requests(
                router,
                limits,
                crate::fault::FaultyStream::new(read_half, label.as_str(), Arc::clone(&inj)),
                crate::fault::FaultyStream::new(write_half, label.as_str(), inj),
            );
        }
        None => serve_requests(router, limits, read_half, write_half),
    }
    let _ = stream.shutdown(Shutdown::Both);
}

/// The keep-alive request loop over any byte stream (plain socket
/// halves, or fault-wrapped ones).
fn serve_requests(router: &Router, limits: &Limits, read_half: impl Read, mut writer: impl Write) {
    let mut reader = parser::RequestReader::new(read_half, limits.clone());
    loop {
        match reader.read_request() {
            Ok(None) => break,
            Ok(Some(req)) => match router.handle(&req, &mut writer) {
                Ok(true) => continue,
                _ => break,
            },
            Err(e) => {
                router.note_parse_error(e.status());
                if let Some(code) = e.status() {
                    let _ = wire::respond_error(&mut writer, code, &e.to_string(), &[], false);
                }
                obs::event(
                    Level::Debug,
                    "http",
                    "parse_reject",
                    &[("reason", e.to_string().into())],
                );
                break;
            }
        }
    }
}
