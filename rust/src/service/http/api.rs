//! Request routing and handlers: the REST surface over the admission
//! queue, scheduler board and metrics registry.
//!
//! | Route | Semantics |
//! |---|---|
//! | `POST /v1/jobs` | submit a SlideSpec job → `201` + job id |
//! | `GET /v1/jobs/{id}` | status + progress counters |
//! | `DELETE /v1/jobs/{id}` | cancel at the next frontier boundary |
//! | `GET /v1/jobs/{id}/result` | progressive JSONL delta stream (`?format=png`; resume via `?from_level=N`) |
//! | `GET /v1/metrics` | scheduler + HTTP metrics snapshot |
//! | `GET /healthz` | unauthenticated liveness probe (`503` + reasons while degraded) |
//!
//! Every `/v1/*` route requires a bearer token; the resolved tenant is
//! both the scheduler's fair-share key and the authorization boundary —
//! a job submitted by tenant A does not exist for tenant B (`404`, not
//! `403`, so ids don't leak). Backpressure surfaces as
//! `429 Too Many Requests` with a `Retry-After` hint; the client
//! decides whether to retry or shed, exactly like an in-process
//! [`SubmitError::QueueFull`] consumer.

use std::io::Write;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::obs::metrics::{Counter, Histogram};
use crate::obs::{self, Level};
use crate::pyramid::tree::{ExecNode, Thresholds};
use crate::service::board::{JobBoard, JobPhase, JobView};
use crate::service::job::{JobSource, JobSpec, Priority};
use crate::service::{AnalysisService, SubmitError};
use crate::slide::tile::TileId;
use crate::synth::slide_gen::{SlideKind, SlideSpec};
use crate::util::json::Json;

use super::auth::TokenTable;
use super::parser::Request;
use super::wire::{respond, respond_error, ChunkedWriter};
use super::HealthState;

/// Hard caps on submitted slide geometry, enforced before
/// [`SlideSpec::new`] ever sees the values (its own validation panics —
/// fine for internal callers, unacceptable for wire input).
const MAX_LEVELS: usize = 12;
const MAX_GRID: usize = 1 << 13;
const MAX_TILE_PX: usize = 4096;
const MAX_ID_LEN: usize = 160;

/// `http.*` instrument handles, registered once in the service's shared
/// registry so one snapshot carries both `sched.*` and `http.*`.
struct HttpMetrics {
    requests: Arc<Counter>,
    responses_2xx: Arc<Counter>,
    responses_4xx: Arc<Counter>,
    responses_5xx: Arc<Counter>,
    parse_errors: Arc<Counter>,
    auth_failures: Arc<Counter>,
    jobs_submitted: Arc<Counter>,
    jobs_cancelled: Arc<Counter>,
    rejected_queue_full: Arc<Counter>,
    rejected_degraded: Arc<Counter>,
    bytes_streamed: Arc<Counter>,
    latency_us: Arc<Histogram>,
}

impl HttpMetrics {
    fn new(reg: &crate::obs::Registry) -> HttpMetrics {
        HttpMetrics {
            requests: reg.counter("http.requests"),
            responses_2xx: reg.counter("http.responses_2xx"),
            responses_4xx: reg.counter("http.responses_4xx"),
            responses_5xx: reg.counter("http.responses_5xx"),
            parse_errors: reg.counter("http.parse_errors"),
            auth_failures: reg.counter("http.auth_failures"),
            jobs_submitted: reg.counter("http.jobs_submitted"),
            jobs_cancelled: reg.counter("http.jobs_cancelled"),
            rejected_queue_full: reg.counter("http.rejected_queue_full"),
            rejected_degraded: reg.counter("http.rejected_degraded"),
            bytes_streamed: reg.counter("http.bytes_streamed"),
            latency_us: reg.histogram("http.request_latency_us"),
        }
    }

    fn classify(&self, status: u16) {
        match status {
            200..=299 => self.responses_2xx.inc(),
            400..=499 => self.responses_4xx.inc(),
            _ => self.responses_5xx.inc(),
        }
    }
}

/// Shared request router: one per front-end, used concurrently by every
/// connection handler thread.
pub struct Router {
    svc: Arc<AnalysisService>,
    tokens: TokenTable,
    stop: Arc<AtomicBool>,
    health: Arc<HealthState>,
    m: HttpMetrics,
}

impl Router {
    /// A router over a running service. `stop` is the front-end's
    /// shutdown flag — long-lived streams check it so server shutdown
    /// is not gated on jobs finishing. `health` is the degraded-state
    /// registry consulted by `/healthz` and submission.
    pub fn new(
        svc: Arc<AnalysisService>,
        tokens: TokenTable,
        stop: Arc<AtomicBool>,
        health: Arc<HealthState>,
    ) -> Router {
        let m = HttpMetrics::new(&svc.registry());
        Router { svc, tokens, stop, health, m }
    }

    /// Record a parser rejection (the connection loop answers it).
    pub fn note_parse_error(&self, status: Option<u16>) {
        self.m.parse_errors.inc();
        if let Some(s) = status {
            self.m.requests.inc();
            self.m.classify(s);
        }
    }

    /// Handle one parsed request, writing the complete response to `w`.
    /// Returns whether the connection may be reused.
    pub fn handle(&self, req: &Request, w: &mut impl Write) -> std::io::Result<bool> {
        let start = Instant::now();
        self.m.requests.inc();
        let keep = req.keep_alive();
        let segs: Vec<&str> = req
            .path
            .trim_start_matches('/')
            .trim_end_matches('/')
            .split('/')
            .collect();
        let status = self.dispatch(req, &segs, keep, w)?;
        self.m.classify(status);
        self.m.latency_us.record_duration(start.elapsed());
        obs::event(
            Level::Trace,
            "http",
            "request",
            &[
                ("method", req.method.as_str().into()),
                ("path", req.path.as_str().into()),
                ("status", status.into()),
            ],
        );
        Ok(keep)
    }

    fn dispatch(
        &self,
        req: &Request,
        segs: &[&str],
        keep: bool,
        w: &mut impl Write,
    ) -> std::io::Result<u16> {
        if segs == ["healthz"] {
            if req.method != "GET" {
                return self.method_not_allowed(w, "GET", keep);
            }
            // Degraded is still *alive*: the body carries the reasons so
            // an operator can tell a gray store/cluster from a dead
            // process, but the 503 lets dumb load-balancer probes shed
            // traffic without parsing anything.
            let reasons = self.health.reasons();
            let status = if reasons.is_empty() { 200 } else { 503 };
            let body = Json::obj()
                .set("ok", reasons.is_empty())
                .set("queued", self.svc.queued())
                .set("live", self.svc.board().live())
                .set(
                    "degraded",
                    Json::Arr(reasons.into_iter().map(Json::Str).collect()),
                )
                .to_string();
            respond(w, status, "application/json", &[], body.as_bytes(), keep)?;
            return Ok(status);
        }
        if segs.first() != Some(&"v1") {
            respond_error(w, 404, "unknown route", &[], keep)?;
            return Ok(404);
        }
        // Everything under /v1 is tenant-scoped.
        let Some(tenant) = self.tokens.tenant(req.header("authorization")) else {
            self.m.auth_failures.inc();
            respond_error(
                w,
                401,
                "missing or unknown bearer token",
                &[("WWW-Authenticate", "Bearer".to_string())],
                keep,
            )?;
            return Ok(401);
        };
        let tenant = tenant.to_string();
        match (req.method.as_str(), &segs[1..]) {
            ("POST", ["jobs"]) => self.submit(req, &tenant, keep, w),
            ("GET", ["jobs", id]) => self.status(*id, &tenant, keep, w),
            ("DELETE", ["jobs", id]) => self.cancel(*id, &tenant, keep, w),
            ("GET", ["jobs", id, "result"]) => self.result(req, *id, &tenant, keep, w),
            ("GET", ["metrics"]) => {
                let body = self.svc.registry().snapshot().to_json().to_string();
                respond(w, 200, "application/json", &[], body.as_bytes(), keep)?;
                Ok(200)
            }
            (_, ["jobs"]) => self.method_not_allowed(w, "POST", keep),
            (_, ["jobs", _]) => self.method_not_allowed(w, "GET, DELETE", keep),
            (_, ["jobs", _, "result"]) | (_, ["metrics"]) => {
                self.method_not_allowed(w, "GET", keep)
            }
            _ => {
                respond_error(w, 404, "unknown route", &[], keep)?;
                Ok(404)
            }
        }
    }

    fn method_not_allowed(
        &self,
        w: &mut impl Write,
        allow: &str,
        keep: bool,
    ) -> std::io::Result<u16> {
        respond_error(
            w,
            405,
            "method not allowed",
            &[("Allow", allow.to_string())],
            keep,
        )?;
        Ok(405)
    }

    /// The board view of `id` as seen by `tenant`: `None` when the job
    /// is unknown, evicted, or owned by another tenant — all three are
    /// indistinguishable on the wire.
    fn tenant_view(&self, board: &JobBoard, id: u64, tenant: &str) -> Option<JobView> {
        board.snapshot(id).filter(|v| v.tenant == tenant)
    }

    // ---- POST /v1/jobs -------------------------------------------------

    fn submit(
        &self,
        req: &Request,
        tenant: &str,
        keep: bool,
        w: &mut impl Write,
    ) -> std::io::Result<u16> {
        // Graceful degradation: while the store or cluster is impaired
        // the service refuses new work outright — accepting a job it
        // cannot finish just turns a gray failure into a queue of
        // broken promises. 503 + Retry-After tells the client when to
        // come back; in-flight jobs keep streaming.
        if self.health.is_degraded() {
            self.m.rejected_degraded.inc();
            let body = Json::obj()
                .set("error", "service degraded")
                .set(
                    "degraded",
                    Json::Arr(self.health.reasons().into_iter().map(Json::Str).collect()),
                )
                .set("retry_after", 5u32)
                .to_string();
            let retry = ("Retry-After", "5".to_string());
            respond(w, 503, "application/json", &[retry], body.as_bytes(), keep)?;
            return Ok(503);
        }
        let spec = match parse_submit(&req.body, tenant) {
            Ok(s) => s,
            Err(msg) => {
                respond_error(w, 400, &msg, &[], keep)?;
                return Ok(400);
            }
        };
        let slide = spec.source.slide_id().to_string();
        match self.svc.submit(spec) {
            Ok(id) => {
                self.m.jobs_submitted.inc();
                let body = Json::obj()
                    .set("job", id)
                    .set("slide", slide.as_str())
                    .set("tenant", tenant)
                    .to_string();
                let loc = ("Location", format!("/v1/jobs/{id}"));
                respond(w, 201, "application/json", &[loc], body.as_bytes(), keep)?;
                Ok(201)
            }
            Err(SubmitError::QueueFull(cap)) => {
                self.m.rejected_queue_full.inc();
                let body = Json::obj()
                    .set("error", "admission queue full")
                    .set("capacity", cap)
                    .set("retry_after", 1u32)
                    .to_string();
                let retry = ("Retry-After", "1".to_string());
                respond(w, 429, "application/json", &[retry], body.as_bytes(), keep)?;
                Ok(429)
            }
            Err(SubmitError::Closed) => {
                respond_error(w, 503, "service is shutting down", &[], keep)?;
                Ok(503)
            }
            Err(SubmitError::Invalid(msg)) => {
                respond_error(w, 400, &msg, &[], keep)?;
                Ok(400)
            }
        }
    }

    // ---- GET /v1/jobs/{id} ---------------------------------------------

    fn status(
        &self,
        id: &str,
        tenant: &str,
        keep: bool,
        w: &mut impl Write,
    ) -> std::io::Result<u16> {
        let board = self.svc.board();
        let Some(v) = parse_id(id).and_then(|id| self.tenant_view(&board, id, tenant)) else {
            respond_error(w, 404, "no such job", &[], keep)?;
            return Ok(404);
        };
        let mut body = Json::obj()
            .set("job", parse_id(id).unwrap_or(0))
            .set("slide", v.slide_id.as_str())
            .set("phase", v.phase.as_str())
            .set("levels", v.levels)
            .set("deltas", v.delta_count)
            .set("tiles_streamed", v.tiles_streamed)
            .set("preemptions", v.preemptions);
        if let Some((gx, gy)) = v.grid {
            body = body.set("grid", vec![gx, gy]);
        }
        if let Some(r) = &v.result {
            body = body
                .set("state", r.state.as_str())
                .set("tiles", r.tiles)
                .set("queue_wait_us", r.queue_wait.as_micros() as u64)
                .set("run_time_us", r.run_time.as_micros() as u64);
        }
        respond(w, 200, "application/json", &[], body.to_string().as_bytes(), keep)?;
        Ok(200)
    }

    // ---- DELETE /v1/jobs/{id} ------------------------------------------

    fn cancel(
        &self,
        id: &str,
        tenant: &str,
        keep: bool,
        w: &mut impl Write,
    ) -> std::io::Result<u16> {
        let board = self.svc.board();
        let Some(jid) = parse_id(id).filter(|&jid| self.tenant_view(&board, jid, tenant).is_some())
        else {
            respond_error(w, 404, "no such job", &[], keep)?;
            return Ok(404);
        };
        let accepted = self.svc.cancel(jid);
        if accepted {
            self.m.jobs_cancelled.inc();
        }
        let body = Json::obj()
            .set("job", jid)
            .set("cancelled", accepted)
            .to_string();
        respond(w, 202, "application/json", &[], body.as_bytes(), keep)?;
        Ok(202)
    }

    // ---- GET /v1/jobs/{id}/result --------------------------------------

    fn result(
        &self,
        req: &Request,
        id: &str,
        tenant: &str,
        keep: bool,
        w: &mut impl Write,
    ) -> std::io::Result<u16> {
        let board = self.svc.board();
        let Some(jid) = parse_id(id).filter(|&jid| self.tenant_view(&board, jid, tenant).is_some())
        else {
            respond_error(w, 404, "no such job", &[], keep)?;
            return Ok(404);
        };
        let from_level = match req.query_param("from_level") {
            None => None,
            Some(raw) => match raw.parse::<usize>() {
                Ok(n) => Some(n),
                Err(_) => {
                    respond_error(
                        w,
                        400,
                        "from_level must be a non-negative integer",
                        &[],
                        keep,
                    )?;
                    return Ok(400);
                }
            },
        };
        if req.query_param("format") == Some("png") {
            return self.result_png(&board, jid, tenant, keep, w);
        }
        self.result_stream(&board, jid, tenant, from_level, keep, w)
    }

    /// Block (in shutdown-aware slices) until the job is terminal, then
    /// render the level-0 probability heatmap as a grayscale PNG.
    fn result_png(
        &self,
        board: &JobBoard,
        id: u64,
        tenant: &str,
        keep: bool,
        w: &mut impl Write,
    ) -> std::io::Result<u16> {
        let view = loop {
            let Some(v) = self.tenant_view(board, id, tenant) else {
                respond_error(w, 404, "no such job", &[], keep)?;
                return Ok(404);
            };
            if v.phase == JobPhase::Done {
                break v;
            }
            if self.stop.load(Ordering::Relaxed) {
                respond_error(w, 503, "server shutting down", &[], false)?;
                return Ok(503);
            }
            let _ = board.wait_deltas(id, v.delta_count, Duration::from_millis(200));
        };
        let tree = view.result.as_ref().and_then(|r| r.tree.as_ref());
        let (Some(tree), Some((gx, gy))) = (tree, view.grid) else {
            respond_error(w, 409, "job finished without a result tree", &[], keep)?;
            return Ok(409);
        };
        let mut pixels = vec![0u8; gx * gy];
        for n in &tree.nodes[0] {
            let (tx, ty) = (n.tile.tx as usize, n.tile.ty as usize);
            if tx < gx && ty < gy {
                pixels[ty * gx + tx] = (n.prob.clamp(0.0, 1.0) * 255.0).round() as u8;
            }
        }
        let png = crate::util::png::encode_gray_png(gx, gy, &pixels);
        self.m.bytes_streamed.add(png.len() as u64);
        respond(w, 200, "image/png", &[], &png, keep)?;
        Ok(200)
    }

    /// Progressive JSONL stream: header line (identity + initial working
    /// set), one line per finalized level as the scheduler publishes it,
    /// then a terminal line. The concatenated lines reassemble the
    /// byte-identical ExecTree of a standalone run.
    ///
    /// `from_level` is the resume cursor for a disconnected client:
    /// levels finalize coarsest-first (descending level numbers), so a
    /// client that already holds every level above `N` reconnects with
    /// `?from_level=N` and receives only the deltas for levels `<= N` —
    /// concatenated after what it already has, the stream is still the
    /// byte-identical tree.
    fn result_stream(
        &self,
        board: &JobBoard,
        id: u64,
        tenant: &str,
        from_level: Option<usize>,
        keep: bool,
        w: &mut impl Write,
    ) -> std::io::Result<u16> {
        // Wait for the initial working set (published when the scheduler
        // starts the job) so the header line is complete; a job that goes
        // terminal while queued (cancel/expiry) proceeds with an empty set.
        let head = loop {
            let Some(v) = self.tenant_view(board, id, tenant) else {
                respond_error(w, 404, "no such job", &[], keep)?;
                return Ok(404);
            };
            if !v.initial.is_empty() || v.phase == JobPhase::Done {
                break v;
            }
            if self.stop.load(Ordering::Relaxed) {
                respond_error(w, 503, "server shutting down", &[], false)?;
                return Ok(503);
            }
            let _ = board.wait_deltas(id, v.delta_count, Duration::from_millis(200));
        };
        let mut cw = ChunkedWriter::start(w, 200, "application/x-ndjson", keep)?;
        let header = Json::obj()
            .set("job", id)
            .set("slide", head.slide_id.as_str())
            .set("levels", head.levels)
            .set(
                "initial",
                Json::Arr(head.initial.iter().map(tile_json).collect()),
            )
            .to_string();
        cw.chunk(format!("{header}\n").as_bytes())?;
        let mut seen = 0usize;
        let status = loop {
            if self.stop.load(Ordering::Relaxed) {
                cw.chunk(b"{\"error\":\"server shutting down\"}\n")?;
                break 503;
            }
            let Some((deltas, view)) = board.wait_deltas(id, seen, Duration::from_millis(250))
            else {
                // Evicted mid-stream (tiny board + heavy churn).
                cw.chunk(b"{\"error\":\"job evicted from board\"}\n")?;
                break 500;
            };
            seen += deltas.len();
            for d in &deltas {
                // Resume filter: the client already holds the coarser
                // levels. Skip their replay but keep counting them in
                // `seen`, so the board cursor stays correct.
                if from_level.is_some_and(|n| d.level > n) {
                    continue;
                }
                let line = Json::obj()
                    .set("level", d.level)
                    .set(
                        "nodes",
                        Json::Arr(d.nodes.iter().map(node_json).collect()),
                    )
                    .to_string();
                cw.chunk(format!("{line}\n").as_bytes())?;
            }
            if view.phase == JobPhase::Done {
                let mut line = Json::obj().set("done", true).set("preemptions", view.preemptions);
                if let Some(r) = &view.result {
                    line = line.set("state", r.state.as_str()).set("tiles", r.tiles);
                }
                let line = line.to_string();
                cw.chunk(format!("{line}\n").as_bytes())?;
                break 200;
            }
        };
        self.m.bytes_streamed.add(cw.sent() as u64);
        cw.finish()?;
        Ok(status)
    }
}

/// Parse a path segment as a job id.
fn parse_id(s: &str) -> Option<u64> {
    if s.is_empty() || s.len() > 19 || !s.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    s.parse().ok()
}

/// `[level, tx, ty]` — the ExecTree initial-set wire form.
fn tile_json(t: &TileId) -> Json {
    Json::Arr(vec![
        Json::Num(t.level as f64),
        Json::Num(t.tx as f64),
        Json::Num(t.ty as f64),
    ])
}

/// `[level, tx, ty, prob, zoom]` — the ExecTree node wire form.
fn node_json(n: &ExecNode) -> Json {
    Json::Arr(vec![
        Json::Num(n.tile.level as f64),
        Json::Num(n.tile.tx as f64),
        Json::Num(n.tile.ty as f64),
        Json::Num(n.prob as f64),
        Json::Bool(n.zoom),
    ])
}

/// Parse and validate a submission body into a [`JobSpec`] for `tenant`.
///
/// Body shape:
/// ```json
/// {
///   "slide": {"id": "...", "seed": 1, "tiles_x": 48, "tiles_y": 32,
///             "levels": 3, "tile_px": 64, "kind": "large_tumor"},
///   "thresholds": 0.35,            // or [0.35, 0.35, 0.35]; optional
///   "priority": "normal",          // optional
///   "deadline_ms": 5000            // optional
/// }
/// ```
///
/// Geometry is bounded and checked *here*, because [`SlideSpec::new`]
/// asserts — a panic is fine for internal misuse but must never be
/// reachable from the wire.
fn parse_submit(body: &[u8], tenant: &str) -> Result<JobSpec, String> {
    let text = std::str::from_utf8(body).map_err(|_| "body is not UTF-8".to_string())?;
    let v = Json::parse(text).map_err(|e| e.to_string())?;
    let slide = v.get("slide").map_err(|e| e.to_string())?;
    let spec = parse_slide(slide)?;
    let levels = spec.levels;
    let thresholds = match v.opt("thresholds") {
        None => Thresholds::uniform(levels, 0.35),
        Some(Json::Num(t)) => {
            if !t.is_finite() {
                return Err("thresholds must be finite".to_string());
            }
            Thresholds::uniform(levels, *t)
        }
        Some(Json::Arr(a)) => {
            if a.len() != levels {
                return Err(format!(
                    "thresholds has {} entries for {} levels",
                    a.len(),
                    levels
                ));
            }
            let zoom = a
                .iter()
                .map(|x| x.as_f64().map_err(|e| e.to_string()))
                .collect::<Result<Vec<f64>, String>>()?;
            if zoom.iter().any(|t| !t.is_finite()) {
                return Err("thresholds must be finite".to_string());
            }
            Thresholds { zoom }
        }
        Some(other) => {
            return Err(format!(
                "thresholds must be a number or array, got {}",
                other.type_name()
            ))
        }
    };
    let mut spec = JobSpec::new(JobSource::Spec(spec), thresholds).with_tenant(tenant);
    if let Some(p) = v.opt("priority") {
        let p = p.as_str().map_err(|e| e.to_string())?;
        let p = Priority::from_str(p).ok_or_else(|| format!("unknown priority {p:?}"))?;
        spec = spec.with_priority(p);
    }
    if let Some(d) = v.opt("deadline_ms") {
        let ms = d.as_u64().map_err(|e| e.to_string())?;
        spec = spec.with_deadline(Duration::from_millis(ms));
    }
    Ok(spec)
}

/// Validate wire geometry and build the [`SlideSpec`].
fn parse_slide(v: &Json) -> Result<SlideSpec, String> {
    let id = v
        .get("id")
        .and_then(|x| x.as_str())
        .map_err(|e| e.to_string())?;
    if id.is_empty() || id.len() > MAX_ID_LEN {
        return Err(format!("slide id must be 1..={MAX_ID_LEN} bytes"));
    }
    let num = |key: &str| -> Result<usize, String> {
        v.get(key).and_then(|x| x.as_usize()).map_err(|e| e.to_string())
    };
    let seed = v
        .get("seed")
        .and_then(|x| x.as_u64())
        .map_err(|e| e.to_string())?;
    let (tiles_x, tiles_y) = (num("tiles_x")?, num("tiles_y")?);
    let levels = num("levels")?;
    let tile_px = num("tile_px")?;
    let kind = v
        .get("kind")
        .and_then(|x| x.as_str())
        .map_err(|e| e.to_string())?;
    let kind = SlideKind::from_str(kind).ok_or_else(|| format!("unknown slide kind {kind:?}"))?;
    if !(1..=MAX_LEVELS).contains(&levels) {
        return Err(format!("levels must be 1..={MAX_LEVELS}"));
    }
    if !(1..=MAX_GRID).contains(&tiles_x) || !(1..=MAX_GRID).contains(&tiles_y) {
        return Err(format!("tile grid must be 1..={MAX_GRID} per side"));
    }
    let div = 1usize << (levels - 1);
    if tiles_x % div != 0 || tiles_y % div != 0 {
        return Err(format!(
            "tile grid {tiles_x}x{tiles_y} not divisible by 2^(levels-1)={div}"
        ));
    }
    if !(8..=MAX_TILE_PX).contains(&tile_px) {
        return Err(format!("tile_px must be 8..={MAX_TILE_PX}"));
    }
    Ok(SlideSpec::new(id, seed, tiles_x, tiles_y, levels, tile_px, kind))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn slide_json() -> Json {
        Json::obj()
            .set("id", "s0")
            .set("seed", 7u64)
            .set("tiles_x", 16usize)
            .set("tiles_y", 8usize)
            .set("levels", 3usize)
            .set("tile_px", 64usize)
            .set("kind", "large_tumor")
    }

    #[test]
    fn submit_body_parses_with_defaults_and_options() {
        let body = Json::obj().set("slide", slide_json()).to_string();
        let spec = parse_submit(body.as_bytes(), "lab_a").unwrap();
        assert_eq!(spec.tenant, "lab_a");
        assert_eq!(spec.source.slide_id(), "s0");
        assert_eq!(spec.thresholds, Thresholds::uniform(3, 0.35));
        assert_eq!(spec.priority, Priority::Normal);
        assert_eq!(spec.deadline, None);

        let body = Json::obj()
            .set("slide", slide_json())
            .set("thresholds", Json::Arr(vec![0.1.into(), 0.2.into(), 0.3.into()]))
            .set("priority", "high")
            .set("deadline_ms", 1500u64)
            .to_string();
        let spec = parse_submit(body.as_bytes(), "lab_b").unwrap();
        assert_eq!(spec.thresholds.zoom, vec![0.1, 0.2, 0.3]);
        assert_eq!(spec.priority, Priority::High);
        assert_eq!(spec.deadline, Some(Duration::from_millis(1500)));
    }

    #[test]
    fn invalid_geometry_is_an_error_not_a_panic() {
        for (key, val) in [
            ("levels", Json::Num(0.0)),
            ("levels", Json::Num(99.0)),
            ("tiles_x", Json::Num(0.0)),
            ("tiles_x", Json::Num(15.0)), // not divisible by 2^(levels-1)
            ("tile_px", Json::Num(2.0)),
            ("kind", Json::Str("bogus".to_string())),
        ] {
            let body = Json::obj().set("slide", slide_json().set(key, val)).to_string();
            assert!(
                parse_submit(body.as_bytes(), "t").is_err(),
                "bad {key} must be rejected"
            );
        }
        assert!(parse_submit(b"not json", "t").is_err());
        assert!(parse_submit(b"{}", "t").is_err());
        assert!(parse_submit(&[0xff, 0xfe], "t").is_err());
    }

    #[test]
    fn threshold_count_must_match_levels() {
        let body = Json::obj()
            .set("slide", slide_json())
            .set("thresholds", Json::Arr(vec![0.5.into()]))
            .to_string();
        assert!(parse_submit(body.as_bytes(), "t").is_err());
    }

    #[test]
    fn job_ids_parse_strictly() {
        assert_eq!(parse_id("12"), Some(12));
        assert_eq!(parse_id(""), None);
        assert_eq!(parse_id("12x"), None);
        assert_eq!(parse_id("-3"), None);
        assert_eq!(parse_id("99999999999999999999999"), None);
    }

    #[test]
    fn wire_forms_match_exec_tree_serialization() {
        let n = ExecNode {
            tile: TileId::new(1, 2, 3),
            prob: 0.5,
            zoom: true,
        };
        assert_eq!(node_json(&n).to_string(), "[1,2,3,0.5,true]");
        assert_eq!(tile_json(&n.tile).to_string(), "[1,2,3]");
    }
}
