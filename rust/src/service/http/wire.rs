//! HTTP/1.1 response serialization: fixed-length responses and the
//! chunked writer the result stream rides on.
//!
//! Responses are written in one buffered burst (status line, headers,
//! body) so a killed connection can never leave a half-written header
//! block followed by a reused socket. The [`ChunkedWriter`] frames each
//! payload as one `Transfer-Encoding: chunked` chunk and flushes it
//! immediately — progressive consumers (a `curl` following a running
//! job) see every per-level delta the moment it is published, not when
//! the job ends.

use std::io::{self, Write};

/// Reason phrase for the status codes this API emits.
pub fn reason(code: u16) -> &'static str {
    match code {
        200 => "OK",
        201 => "Created",
        202 => "Accepted",
        400 => "Bad Request",
        401 => "Unauthorized",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        409 => "Conflict",
        413 => "Payload Too Large",
        414 => "URI Too Long",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        505 => "HTTP Version Not Supported",
        _ => "Unknown",
    }
}

/// Write a complete fixed-length response in one burst.
///
/// `extra` headers are emitted verbatim after the standard set
/// (`Retry-After`, `Allow`, `WWW-Authenticate`…).
pub fn respond(
    w: &mut impl Write,
    code: u16,
    content_type: &str,
    extra: &[(&str, String)],
    body: &[u8],
    keep_alive: bool,
) -> io::Result<()> {
    let mut out = Vec::with_capacity(256 + body.len());
    write!(out, "HTTP/1.1 {} {}\r\n", code, reason(code))?;
    write!(out, "Content-Type: {content_type}\r\n")?;
    write!(out, "Content-Length: {}\r\n", body.len())?;
    for (k, v) in extra {
        write!(out, "{k}: {v}\r\n")?;
    }
    write!(
        out,
        "Connection: {}\r\n\r\n",
        if keep_alive { "keep-alive" } else { "close" }
    )?;
    out.extend_from_slice(body);
    w.write_all(&out)?;
    w.flush()
}

/// Write a JSON error body with the conventional shape
/// `{"error": "..."}` plus any extra headers.
pub fn respond_error(
    w: &mut impl Write,
    code: u16,
    msg: &str,
    extra: &[(&str, String)],
    keep_alive: bool,
) -> io::Result<()> {
    let body = crate::util::json::Json::obj()
        .set("error", msg)
        .to_string();
    respond(w, code, "application/json", extra, body.as_bytes(), keep_alive)
}

/// Progressive chunked-transfer body writer. Construct with
/// [`ChunkedWriter::start`] (which emits the response head), feed
/// payloads with [`ChunkedWriter::chunk`], and terminate the stream
/// with [`ChunkedWriter::finish`].
pub struct ChunkedWriter<'a, W: Write> {
    w: &'a mut W,
    /// Payload bytes framed so far (the `http.bytes_streamed` series).
    sent: usize,
}

impl<'a, W: Write> ChunkedWriter<'a, W> {
    /// Emit the chunked response head and return the writer.
    pub fn start(
        w: &'a mut W,
        code: u16,
        content_type: &str,
        keep_alive: bool,
    ) -> io::Result<ChunkedWriter<'a, W>> {
        let head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nTransfer-Encoding: chunked\r\nConnection: {}\r\n\r\n",
            code,
            reason(code),
            content_type,
            if keep_alive { "keep-alive" } else { "close" }
        );
        w.write_all(head.as_bytes())?;
        w.flush()?;
        Ok(ChunkedWriter { w, sent: 0 })
    }

    /// Frame and flush one payload. Empty payloads are skipped — an
    /// empty chunk would terminate the stream.
    pub fn chunk(&mut self, data: &[u8]) -> io::Result<()> {
        if data.is_empty() {
            return Ok(());
        }
        let mut framed = Vec::with_capacity(data.len() + 16);
        write!(framed, "{:x}\r\n", data.len())?;
        framed.extend_from_slice(data);
        framed.extend_from_slice(b"\r\n");
        self.w.write_all(&framed)?;
        self.sent += data.len();
        self.w.flush()
    }

    /// Payload bytes framed so far.
    pub fn sent(&self) -> usize {
        self.sent
    }

    /// Terminate the stream (`0 CRLF CRLF`).
    pub fn finish(self) -> io::Result<()> {
        self.w.write_all(b"0\r\n\r\n")?;
        self.w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_response_has_length_and_connection_headers() {
        let mut out = Vec::new();
        respond(&mut out, 201, "application/json", &[], b"{}", true).unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 201 Created\r\n"));
        assert!(s.contains("Content-Length: 2\r\n"));
        assert!(s.contains("Connection: keep-alive\r\n"));
        assert!(s.ends_with("\r\n\r\n{}"));
    }

    #[test]
    fn error_response_carries_extra_headers() {
        let mut out = Vec::new();
        respond_error(
            &mut out,
            429,
            "queue full",
            &[("Retry-After", "1".to_string())],
            false,
        )
        .unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 429 Too Many Requests\r\n"));
        assert!(s.contains("Retry-After: 1\r\n"));
        assert!(s.contains("Connection: close\r\n"));
        assert!(s.contains("{\"error\":\"queue full\"}"));
    }

    #[test]
    fn chunked_stream_frames_and_terminates() {
        let mut out = Vec::new();
        let mut cw = ChunkedWriter::start(&mut out, 200, "application/x-ndjson", true).unwrap();
        cw.chunk(b"hello\n").unwrap();
        cw.chunk(b"").unwrap(); // skipped, not a terminator
        cw.chunk(b"world\n").unwrap();
        assert_eq!(cw.sent(), 12);
        cw.finish().unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.contains("Transfer-Encoding: chunked\r\n"));
        assert!(s.ends_with("6\r\nhello\n\r\n6\r\nworld\n\r\n0\r\n\r\n"));
    }
}
