//! Job descriptors and results of the multi-slide analysis service.
//!
//! A job is one slide analysis request: either a live [`SlideSpec`] run
//! through the shared analyzer pool, or a replay of a cached
//! [`SlidePredictions`] under (possibly new) thresholds — the same two
//! execution modes the single-slide driver supports (§4.3).

use std::sync::Arc;
use std::time::Duration;

use crate::predcache::{ShardedPredStore, SlidePredictions};
use crate::pyramid::tree::{ExecTree, Thresholds};
use crate::synth::slide_gen::SlideSpec;

/// Service-assigned job identifier (monotonic per service instance).
pub type JobId = u64;

/// Scheduling priority: higher runs first under the
/// [`StrictPriority`](crate::sched::StrictPriority) policy, which (with
/// preemption enabled) also parks lower-priority running jobs at their
/// next frontier boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    /// Background/batch work.
    Low,
    /// The default.
    Normal,
    /// Urgent (e.g. intra-operative) work.
    High,
}

impl Priority {
    /// Numeric rank for selection (higher wins).
    pub fn rank(self) -> u8 {
        match self {
            Priority::Low => 0,
            Priority::Normal => 1,
            Priority::High => 2,
        }
    }

    /// Stable name for tables/CSV.
    pub fn as_str(self) -> &'static str {
        match self {
            Priority::Low => "low",
            Priority::Normal => "normal",
            Priority::High => "high",
        }
    }

    /// Inverse of [`Priority::as_str`].
    pub fn from_str(s: &str) -> Option<Priority> {
        match s {
            "low" => Some(Priority::Low),
            "normal" => Some(Priority::Normal),
            "high" => Some(Priority::High),
            _ => None,
        }
    }
}

/// Where a job's probabilities come from.
#[derive(Clone)]
pub enum JobSource {
    /// Live analysis: rebuild the slide from its spec and run the shared
    /// analyzer pool over every frontier batch.
    Spec(SlideSpec),
    /// Post-mortem replay of a fully-resident prediction cache pinned
    /// behind an `Arc` for the job's lifetime (no analyzer time).
    Cached(Arc<SlidePredictions>),
    /// Streamed replay out of a sharded on-disk store: the slide's shard
    /// is loaded lazily under the store's memory budget — and may be
    /// evicted and reloaded between frontier chunks — so replay jobs
    /// over huge slide sets never pin the whole set in memory.
    Sharded {
        /// The shared shard store (one per slide set).
        store: Arc<ShardedPredStore>,
        /// Manifest index of the slide to replay.
        slide: usize,
    },
}

impl JobSource {
    /// The slide this source analyzes.
    pub fn slide_id(&self) -> &str {
        match self {
            JobSource::Spec(s) => &s.id,
            JobSource::Cached(c) => &c.spec.id,
            JobSource::Sharded { store, slide } => {
                store.slide_id(*slide).unwrap_or("<invalid-slide>")
            }
        }
    }

    /// Pyramid depth of the source slide. An out-of-range shard index
    /// reports 0 levels, which admission rejects as invalid.
    pub fn levels(&self) -> usize {
        match self {
            JobSource::Spec(s) => s.levels,
            JobSource::Cached(c) => c.spec.levels,
            JobSource::Sharded { store, slide } => store.slide_levels(*slide).unwrap_or(0),
        }
    }
}

impl std::fmt::Debug for JobSource {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JobSource::Spec(s) => write!(f, "Spec({})", s.id),
            JobSource::Cached(c) => write!(f, "Cached({})", c.spec.id),
            JobSource::Sharded { slide, .. } => {
                write!(f, "Sharded({}#{slide})", self.slide_id())
            }
        }
    }
}

/// One analysis request.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Where the pixels/probabilities come from.
    pub source: JobSource,
    /// Per-level zoom thresholds for the run.
    pub thresholds: Thresholds,
    /// Scheduling priority.
    pub priority: Priority,
    /// Fair-share accounting key (a user, a lab, a billing account…).
    pub tenant: String,
    /// Maximum time the job may wait in the admission queue; expired jobs
    /// are dropped at admission instead of running late (`None` = wait
    /// forever). Under the [`Edf`](crate::sched::Edf) policy the absolute
    /// deadline (submission + this duration) also ranks the job: earliest
    /// deadline dispatches first and, with preemption enabled, parks
    /// later-deadline running jobs at their next frontier boundary.
    pub deadline: Option<Duration>,
}

impl JobSpec {
    /// A job with default priority/tenant and no deadline.
    pub fn new(source: JobSource, thresholds: Thresholds) -> JobSpec {
        JobSpec {
            source,
            thresholds,
            priority: Priority::Normal,
            tenant: "default".to_string(),
            deadline: None,
        }
    }

    /// Set the priority (builder style).
    pub fn with_priority(mut self, p: Priority) -> JobSpec {
        self.priority = p;
        self
    }

    /// Set the fair-share tenant (builder style).
    pub fn with_tenant(mut self, tenant: impl Into<String>) -> JobSpec {
        self.tenant = tenant.into();
        self
    }

    /// Set a relative deadline (builder style).
    pub fn with_deadline(mut self, d: Duration) -> JobSpec {
        self.deadline = Some(d);
        self
    }
}

/// Terminal state of a job.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum JobState {
    /// Ran to completion; `JobResult::tree` is set.
    Completed,
    /// Cancelled: either while still queued (no tree) or mid-run at a
    /// level-frontier boundary, in which case `JobResult::tree` holds the
    /// consistent partial tree of every completed level.
    Cancelled,
    /// Queue wait exceeded the job's deadline; dropped at admission.
    Expired,
    /// The job's execution panicked (analyzer fault); the service survives.
    Failed(String),
}

impl JobState {
    /// Stable name for tables/CSV.
    pub fn as_str(&self) -> &str {
        match self {
            JobState::Completed => "completed",
            JobState::Cancelled => "cancelled",
            JobState::Expired => "expired",
            JobState::Failed(_) => "failed",
        }
    }
}

/// Terminal record of one job: state, execution tree and timings.
#[derive(Debug, Clone)]
pub struct JobResult {
    /// Service-assigned id (1-based, submission order).
    pub id: JobId,
    /// The analyzed slide.
    pub slide_id: String,
    /// Fair-share tenant.
    pub tenant: String,
    /// Priority it was scheduled under.
    pub priority: Priority,
    /// How the job ended.
    pub state: JobState,
    /// The execution tree (identical to a standalone `run_pyramidal` /
    /// `replay` of the same source). Set for `Completed` jobs and — as a
    /// partial tree of the completed levels — for jobs cancelled mid-run.
    pub tree: Option<ExecTree>,
    /// Time spent in the admission queue before the scheduler started it.
    pub queue_wait: Duration,
    /// Time from scheduler start to completion.
    pub run_time: Duration,
    /// Tiles analyzed (0 for queue-cancelled/expired jobs; the partial
    /// tree's count for mid-run cancellations).
    pub tiles: usize,
    /// How many times the scheduler parked this job at a frontier
    /// boundary in favor of another (and later resumed it).
    pub preemptions: usize,
}

impl JobResult {
    /// End-to-end latency: queue wait + run time.
    pub fn latency(&self) -> Duration {
        self.queue_wait + self.run_time
    }

    /// Throughput of the run phase in tiles per second.
    pub fn tiles_per_sec(&self) -> f64 {
        let s = self.run_time.as_secs_f64();
        if s > 0.0 {
            self.tiles as f64 / s
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::slide_gen::SlideKind;

    #[test]
    fn priority_ordering_and_strings() {
        assert!(Priority::High.rank() > Priority::Normal.rank());
        assert!(Priority::Normal.rank() > Priority::Low.rank());
        for p in [Priority::Low, Priority::Normal, Priority::High] {
            assert_eq!(Priority::from_str(p.as_str()), Some(p));
        }
        assert_eq!(Priority::from_str("urgent"), None);
    }

    #[test]
    fn job_spec_builder() {
        let spec = SlideSpec::new("j", 1, 16, 8, 3, 64, SlideKind::Negative);
        let j = JobSpec::new(JobSource::Spec(spec), Thresholds::uniform(3, 0.4))
            .with_priority(Priority::High)
            .with_tenant("lab_a")
            .with_deadline(Duration::from_secs(5));
        assert_eq!(j.source.slide_id(), "j");
        assert_eq!(j.source.levels(), 3);
        assert_eq!(j.priority, Priority::High);
        assert_eq!(j.tenant, "lab_a");
        assert_eq!(j.deadline, Some(Duration::from_secs(5)));
    }

    #[test]
    fn result_latency_and_throughput() {
        let r = JobResult {
            id: 1,
            slide_id: "s".into(),
            tenant: "t".into(),
            priority: Priority::Normal,
            state: JobState::Completed,
            tree: None,
            queue_wait: Duration::from_millis(200),
            run_time: Duration::from_millis(800),
            tiles: 400,
            preemptions: 0,
        };
        assert_eq!(r.latency(), Duration::from_secs(1));
        assert!((r.tiles_per_sec() - 500.0).abs() < 1e-9);
        assert_eq!(r.state.as_str(), "completed");
    }
}
